"""Kernel-vs-reference parity — the CORE correctness signal for L1.

The pallas kernels must agree with the pure-jnp oracles to f32
tolerance for every shape and value regime the system feeds them.
Hypothesis sweeps shapes/values; fixed seeds keep CI deterministic.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.ref import (
    FEAT_DIM,
    N_CHANNELS,
    WINDOW,
    featurize_ref,
    init_params,
    mlp_forward_ref,
)
from compile.kernels.score_hosts import BLOCK_B, score_hosts_pallas
from compile.kernels.telemetry import featurize_pallas


def params(seed=0):
    return init_params(jax.random.PRNGKey(seed))


def feats_batch(seed, b, lo=0.0, hi=1.0):
    key = jax.random.PRNGKey(seed)
    return jax.random.uniform(key, (b, FEAT_DIM), jnp.float32, lo, hi)


class TestScoreHosts:
    def test_matches_ref_single_block(self):
        f = feats_batch(1, BLOCK_B)
        p = params(1)
        got = score_hosts_pallas(f, *p)
        want = mlp_forward_ref(f, p)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)

    def test_matches_ref_multi_block(self):
        f = feats_batch(2, 4 * BLOCK_B)
        p = params(2)
        np.testing.assert_allclose(
            score_hosts_pallas(f, *p), mlp_forward_ref(f, p), rtol=1e-5, atol=1e-6
        )

    def test_outputs_nonnegative(self):
        # Softplus head: both outputs are ≥ 0 for any input.
        f = feats_batch(3, BLOCK_B, lo=-5.0, hi=5.0)
        out = np.asarray(score_hosts_pallas(f, *params(3)))
        assert (out >= 0.0).all()

    def test_zero_features_give_bias_only_output(self):
        f = jnp.zeros((BLOCK_B, FEAT_DIM), jnp.float32)
        p = params(4)
        got = score_hosts_pallas(f, *p)
        want = mlp_forward_ref(f, p)
        np.testing.assert_allclose(got, want, rtol=1e-6)
        # All rows identical.
        assert np.allclose(got[0], got[-1])

    def test_rejects_unpadded_batch(self):
        with pytest.raises(AssertionError):
            score_hosts_pallas(feats_batch(5, BLOCK_B - 1), *params(5))

    @settings(max_examples=20, deadline=None)
    @given(
        seed=st.integers(0, 2**31 - 1),
        blocks=st.integers(1, 3),
        scale=st.floats(0.1, 10.0),
    )
    def test_hypothesis_value_sweep(self, seed, blocks, scale):
        f = feats_batch(seed % 1000, blocks * BLOCK_B) * scale
        p = params(seed % 17)
        got = score_hosts_pallas(f, *p)
        want = mlp_forward_ref(f, p)
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=1e-5)

    def test_row_independence(self):
        # Changing one row must not affect others (blocked matmul
        # correctness under the BlockSpec schedule).
        f = feats_batch(7, BLOCK_B)
        p = params(7)
        base = np.asarray(score_hosts_pallas(f, *p))
        f2 = f.at[5].set(f[5] * 3.0 + 1.0)
        out2 = np.asarray(score_hosts_pallas(f2, *p))
        changed = np.abs(out2 - base).max(axis=1) > 1e-9
        assert changed[5]
        assert not changed[np.arange(BLOCK_B) != 5].any()


def windows_batch(seed, b):
    key = jax.random.PRNGKey(seed)
    return jax.random.uniform(key, (b, WINDOW, N_CHANNELS), jnp.float32)


class TestFeaturize:
    def test_matches_ref(self):
        w = windows_batch(1, BLOCK_B)
        np.testing.assert_allclose(
            featurize_pallas(w), featurize_ref(w), rtol=1e-5, atol=1e-6
        )

    def test_multi_block(self):
        w = windows_batch(2, 2 * BLOCK_B)
        np.testing.assert_allclose(
            featurize_pallas(w), featurize_ref(w), rtol=1e-5, atol=1e-6
        )

    def test_idle_window_zero_burstiness(self):
        w = jnp.zeros((BLOCK_B, WINDOW, N_CHANNELS), jnp.float32)
        out = np.asarray(featurize_pallas(w))
        np.testing.assert_allclose(out, 0.0, atol=1e-7)

    def test_constant_window_stats(self):
        w = jnp.full((BLOCK_B, WINDOW, N_CHANNELS), 0.5, jnp.float32)
        out = np.asarray(featurize_pallas(w))
        np.testing.assert_allclose(out[:, :4], 0.5, rtol=1e-6)  # means
        np.testing.assert_allclose(out[:, 4], 0.5, rtol=1e-6)  # cpu peak
        np.testing.assert_allclose(out[:, 5], 0.5, rtol=1e-6)  # io peak
        np.testing.assert_allclose(out[:, 6], 0.0, atol=1e-5)  # burstiness

    def test_peak_detection(self):
        w = jnp.zeros((BLOCK_B, WINDOW, N_CHANNELS), jnp.float32)
        w = w.at[0, 3, 0].set(0.9)  # one cpu spike in row 0
        w = w.at[0, 7, 3].set(0.8)  # one net spike
        out = np.asarray(featurize_pallas(w))
        assert abs(out[0, 4] - 0.9) < 1e-6
        assert abs(out[0, 5] - 0.8) < 1e-6
        assert out[1, 4] == 0.0

    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1), blocks=st.integers(1, 2))
    def test_hypothesis_sweep(self, seed, blocks):
        w = windows_batch(seed % 999, blocks * BLOCK_B)
        np.testing.assert_allclose(
            featurize_pallas(w), featurize_ref(w), rtol=2e-5, atol=1e-5
        )
