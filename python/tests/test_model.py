"""L2 model tests: train_step learning dynamics, shape contracts, and
the AOT lowering (HLO text generation)."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model
from compile.aot import (
    lower_featurize,
    lower_predict,
    lower_train_step,
    param_specs,
    to_hlo_text,
)
from compile.kernels.ref import (
    FEAT_DIM,
    HIDDEN1,
    HIDDEN2,
    OUT_DIM,
    init_params,
    mlp_forward_ref,
)


def zeros_like_params():
    return tuple(jnp.zeros(s.shape, jnp.float32) for s in param_specs())


def synthetic_batch(seed):
    """Oracle-ish labels: y0 linear-ish in features, y1 hinge — enough
    structure to verify learning without porting the rust oracle."""
    key = jax.random.PRNGKey(seed)
    f = jax.random.uniform(key, (model.TRAIN_BATCH, FEAT_DIM), jnp.float32)
    y0 = 0.35 * f[:, 0] + 0.05 * f[:, 1] + 0.05 * jnp.maximum(f[:, 2], f[:, 3])
    y1 = jnp.maximum(f[:, 8] + 0.25 * f[:, 0] - 1.0, 0.0) * 2.0
    return f, jnp.stack([y0, y1], axis=1)


class TestTrainStep:
    def run_steps(self, n, seed=0):
        params = init_params(jax.random.PRNGKey(seed))
        m = zeros_like_params()
        v = zeros_like_params()
        step_fn = jax.jit(model.train_step)
        losses = []
        for t in range(1, n + 1):
            f, y = synthetic_batch(seed * 1000 + t)
            out = step_fn(
                *params, *m, *v, jnp.array([[float(t)]], jnp.float32), f, y
            )
            params, m, v = out[0:6], out[6:12], out[12:18]
            losses.append(float(out[18][0, 0]))
        return params, losses

    def test_loss_decreases(self):
        _, losses = self.run_steps(60)
        first = np.mean(losses[:5])
        last = np.mean(losses[-5:])
        assert last < first * 0.5, f"loss {first:.4f} → {last:.4f}"

    def test_shapes_preserved(self):
        params, _ = self.run_steps(2)
        shapes = [p.shape for p in params]
        assert shapes == [
            (FEAT_DIM, HIDDEN1),
            (1, HIDDEN1),
            (HIDDEN1, HIDDEN2),
            (1, HIDDEN2),
            (HIDDEN2, OUT_DIM),
            (1, OUT_DIM),
        ]

    def test_trained_model_predicts_structure(self):
        params, _ = self.run_steps(150, seed=3)
        f, y = synthetic_batch(99999)
        pred = mlp_forward_ref(f, params)
        mse = float(jnp.mean((pred - y) ** 2))
        assert mse < 0.01, f"val mse {mse}"

    def test_returns_19_tensors(self):
        params = init_params(jax.random.PRNGKey(0))
        f, y = synthetic_batch(1)
        out = model.train_step(
            *params,
            *zeros_like_params(),
            *zeros_like_params(),
            jnp.ones((1, 1), jnp.float32),
            f,
            y,
        )
        assert len(out) == 19
        assert out[18].shape == (1, 1)


class TestAotLowering:
    def test_predict_lowers_to_hlo_text(self):
        text = to_hlo_text(lower_predict())
        assert text.startswith("HloModule")
        # Batched input shape appears in the entry layout.
        assert f"f32[{model.BATCH},{FEAT_DIM}]" in text

    def test_featurize_lowers(self):
        text = to_hlo_text(lower_featurize())
        assert "HloModule" in text

    def test_train_step_lowers(self):
        text = to_hlo_text(lower_train_step())
        assert "HloModule" in text
        assert f"f32[{model.TRAIN_BATCH},{FEAT_DIM}]" in text

    def test_predict_artifact_matches_python_exec(self):
        # The lowered computation, run through jax, equals the direct
        # call — guards against lowering-time shape/layout drift.
        f = jax.random.uniform(
            jax.random.PRNGKey(5), (model.BATCH, FEAT_DIM), jnp.float32
        )
        p = init_params(jax.random.PRNGKey(5))
        direct = model.predict(f, *p)[0]
        compiled = jax.jit(model.predict).lower(f, *p).compile()(f, *p)[0]
        np.testing.assert_allclose(direct, compiled, rtol=1e-6)


class TestFeatureContract:
    def test_feature_names_match_dim(self):
        assert len(model.FEATURE_NAMES) == FEAT_DIM

    def test_constants_consistency(self):
        assert model.BATCH % 128 == 0
        assert model.TRAIN_BATCH % 128 == 0
