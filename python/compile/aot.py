"""AOT compilation: lower the L2 functions to HLO **text** artifacts.

Interchange is HLO text, not ``.serialize()``: jax ≥ 0.5 emits
HloModuleProto with 64-bit instruction ids that the rust crate's XLA
(xla_extension 0.5.1) rejects (``proto.id() <= INT_MAX``); the text
parser reassigns ids and round-trips cleanly. See
/opt/xla-example/README.md.

Usage:  cd python && python -m compile.aot --out ../artifacts
Idempotent; `make artifacts` skips it when inputs are unchanged.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model
from compile.kernels.ref import (
    FEAT_DIM,
    HIDDEN1,
    HIDDEN2,
    N_CHANNELS,
    OUT_DIM,
    WINDOW,
)


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (return_tuple=True so rust
    unwraps a tuple regardless of arity)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def param_specs():
    return (
        f32(FEAT_DIM, HIDDEN1),
        f32(1, HIDDEN1),
        f32(HIDDEN1, HIDDEN2),
        f32(1, HIDDEN2),
        f32(HIDDEN2, OUT_DIM),
        f32(1, OUT_DIM),
    )


def lower_predict():
    return jax.jit(model.predict).lower(f32(model.BATCH, FEAT_DIM), *param_specs())


def lower_featurize():
    return jax.jit(model.featurize).lower(f32(model.BATCH, WINDOW, N_CHANNELS))


def lower_train_step():
    ps = param_specs()
    return jax.jit(model.train_step).lower(
        *ps, *ps, *ps,  # params, m, v share shapes
        f32(1, 1),
        f32(model.TRAIN_BATCH, FEAT_DIM),
        f32(model.TRAIN_BATCH, OUT_DIM),
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    for name, lower in [
        ("predict", lower_predict),
        ("featurize", lower_featurize),
        ("train_step", lower_train_step),
    ]:
        text = to_hlo_text(lower())
        path = os.path.join(args.out, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {len(text):>9} chars  {path}")

    meta = {
        "batch": model.BATCH,
        "feat_dim": FEAT_DIM,
        "hidden": [HIDDEN1, HIDDEN2],
        "out_dim": OUT_DIM,
        "window": WINDOW,
        "train_batch": model.TRAIN_BATCH,
        "lr": model.LR,
    }
    meta_path = os.path.join(args.out, "meta.json")
    with open(meta_path, "w") as f:
        json.dump(meta, f, indent=1)
    print(f"wrote meta {meta_path}: {meta}")


if __name__ == "__main__":
    main()
