"""L2: the prediction engine `f_θ` (Eq. 4) as a JAX model.

Three exported computations (AOT-lowered by aot.py, executed from rust
through PJRT — python never runs on the decision path):

* ``predict``    — batched scoring. Calls the L1 Pallas kernel
                   (`score_hosts_pallas`), so the kernel lowers into
                   the same HLO module rust loads.
* ``train_step`` — one fused forward + MSE loss + backward + Adam
                   update. Differentiates the *jnp reference* forward
                   (identical math to the kernel — pallas interpret
                   calls are not differentiable); kernel/ref parity is
                   pinned by pytest.
* ``featurize``  — telemetry windows → Eq. 1 feature vectors via the
                   L1 telemetry kernel.

Feature layout (must match rust/src/profile/features.rs):
    0..3  workload mean cpu/mem/disk/net        8..11 host cpu/mem/disk/net
    4     workload p95 cpu                      12    host vm-count/8
    5     workload p95 io                       13    host DVFS freq
    6     workload cpu burstiness (≤2)          14    w_cpu·h_cpu
    7     log1p(remaining solo s)/10            15    max(0, w_mem+h_mem−1)
"""

import jax
import jax.numpy as jnp

from compile.kernels.ref import mlp_forward_ref
from compile.kernels.score_hosts import score_hosts_pallas
from compile.kernels.telemetry import featurize_pallas

# Shapes baked into the AOT artifacts (mirrored in artifacts/meta.json;
# rust reads them from there, never hardcodes).
BATCH = 128
TRAIN_BATCH = 256
LR = 1e-3
ADAM_B1 = 0.9
ADAM_B2 = 0.999
ADAM_EPS = 1e-8

FEATURE_NAMES = [
    "w_cpu", "w_mem", "w_disk", "w_net",
    "w_cpu_p95", "w_io_p95", "w_burst", "w_log_remaining",
    "h_cpu", "h_mem", "h_disk", "h_net",
    "h_vms", "h_freq", "x_cpu_contention", "x_mem_pressure",
]


def predict(feats, w1, b1, w2, b2, w3, b3):
    """Score [BATCH, 16] feature rows → [BATCH, 2] (power/100, slowdown)."""
    return (score_hosts_pallas(feats, w1, b1, w2, b2, w3, b3),)


def featurize(windows):
    """[BATCH, WINDOW, 4] telemetry → [BATCH, 7] Eq. 1 vectors."""
    return (featurize_pallas(windows),)


def train_step(
    w1, b1, w2, b2, w3, b3,
    m1, mb1, m2, mb2, m3, mb3,
    v1, vb1, v2, vb2, v3, vb3,
    step, feats, targets,
):
    """One Adam step on MSE loss. All state flows through as tensors so
    rust can drive the epoch loop statelessly.

    step: f32 [1, 1] — the 1-based Adam timestep (bias correction).
    feats: [TRAIN_BATCH, 16]; targets: [TRAIN_BATCH, 2].
    Returns 19 tensors: 6 params, 6 m, 6 v, loss [1, 1].
    """
    params = (w1, b1, w2, b2, w3, b3)
    m = (m1, mb1, m2, mb2, m3, mb3)
    v = (v1, vb1, v2, vb2, v3, vb3)

    def loss_fn(ps):
        pred = mlp_forward_ref(feats, ps)
        return jnp.mean((pred - targets) ** 2)

    loss, grads = jax.value_and_grad(loss_fn)(params)
    t = step[0, 0]
    new_params, new_m, new_v = [], [], []
    for p, g, mi, vi in zip(params, grads, m, v):
        nm = ADAM_B1 * mi + (1.0 - ADAM_B1) * g
        nv = ADAM_B2 * vi + (1.0 - ADAM_B2) * g * g
        mhat = nm / (1.0 - ADAM_B1**t)
        vhat = nv / (1.0 - ADAM_B2**t)
        new_params.append(p - LR * mhat / (jnp.sqrt(vhat) + ADAM_EPS))
        new_m.append(nm)
        new_v.append(nv)
    return (*new_params, *new_m, *new_v, loss.reshape(1, 1))
