"""L1 kernel: windowed telemetry featurization (Eq. 1 vectors from raw
dstat-style samples).

Input: [B, WINDOW, 4] normalized utilization windows (cpu, mem, disk,
net per 5 s sample). Output: [B, 7] — channel means, cpu peak, io
peak, cpu burstiness. One grid step per BLOCK_B windows; the window
block is VMEM-resident (24 × 4 f32 per row ≈ 384 B, a 128-row block is
≈ 48 KB).

Peaks use max (not the p95 the rust-native profiler computes): a
sort-free reduction keeps the kernel a pure VPU pipeline. The two
paths are *alternative* profilers; parity of the shared moments is
asserted in pytest, the max-vs-p95 difference is documented here and
exercised in rust/tests/runtime_xla.rs.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from compile.kernels.ref import N_CHANNELS, N_FEATURES, WINDOW

BLOCK_B = 128


def _featurize_kernel(w_ref, o_ref):
    w = w_ref[...]  # [BLOCK_B, WINDOW, 4]
    means = jnp.mean(w, axis=1)  # [BLOCK_B, 4]
    cpu = w[:, :, 0]
    io = jnp.maximum(w[:, :, 2], w[:, :, 3])
    cpu_peak = jnp.max(cpu, axis=1)
    io_peak = jnp.max(io, axis=1)
    cpu_mean = means[:, 0]
    # Population std (matches jnp.std in the ref).
    var = jnp.mean((cpu - cpu_mean[:, None]) ** 2, axis=1)
    burst = jnp.where(
        cpu_mean > 1e-6, jnp.sqrt(var) / jnp.maximum(cpu_mean, 1e-6), 0.0
    )
    o_ref[...] = jnp.concatenate(
        [means, cpu_peak[:, None], io_peak[:, None], burst[:, None]], axis=1
    )


@jax.jit
def featurize_pallas(windows):
    """windows: [B, WINDOW, 4], B % BLOCK_B == 0 → [B, 7]."""
    b = windows.shape[0]
    assert b % BLOCK_B == 0, f"batch {b} not a multiple of {BLOCK_B}"
    grid = (b // BLOCK_B,)
    return pl.pallas_call(
        _featurize_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((BLOCK_B, WINDOW, N_CHANNELS), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((BLOCK_B, N_FEATURES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, N_FEATURES), jnp.float32),
        interpret=True,
    )(windows)
