"""L1 kernel: batched placement scoring — the MLP forward of Eq. 4.

The scheduler's hot spot is scoring all candidate (workload, host)
pairs per decision; consolidation scans score all VM × host pairs.
This kernel processes the feature batch in row blocks with all weight
matrices pinned in VMEM.

TPU mapping (DESIGN.md §Hardware-Adaptation):
  * grid: one step per BLOCK_B rows of the batch; the feature block
    streams HBM→VMEM while weights stay resident (index_map ``(0, 0)``).
  * matmul shapes (BLOCK_B × 16)·(16 × 64) etc. — zero-padded to the
    128-lane register tile by Mosaic; with BLOCK_B = 128 each layer is
    one MXU pass.
  * VMEM: weights ≈ (16·64 + 64·32 + 32·2) · 4 B ≈ 12.5 KB padded to
    ~192 KB at 128 lanes, plus a 128 × 128 f32 block ≈ 64 KB — far
    under the 16 MB budget, leaving room for double buffering.

``interpret=True`` everywhere: the CPU PJRT client cannot execute
Mosaic custom-calls; the paper's decision path runs this kernel's HLO
through the rust client.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from compile.kernels.ref import FEAT_DIM, HIDDEN1, HIDDEN2, OUT_DIM

# Rows per grid step. 128 matches the MXU tile; the AOT batch (128)
# lowers to a single grid step.
BLOCK_B = 128


def _mlp_kernel(f_ref, w1_ref, b1_ref, w2_ref, b2_ref, w3_ref, b3_ref, o_ref):
    """One block: two ReLU layers + softplus head, all in VMEM."""
    x = f_ref[...]
    h1 = jnp.maximum(
        jnp.dot(x, w1_ref[...], preferred_element_type=jnp.float32) + b1_ref[...], 0.0
    )
    h2 = jnp.maximum(
        jnp.dot(h1, w2_ref[...], preferred_element_type=jnp.float32) + b2_ref[...], 0.0
    )
    y = jnp.dot(h2, w3_ref[...], preferred_element_type=jnp.float32) + b3_ref[...]
    o_ref[...] = jax.nn.softplus(y)


@functools.partial(jax.jit, static_argnames=())
def score_hosts_pallas(feats, w1, b1, w2, b2, w3, b3):
    """Score a feature batch. feats: [B, FEAT_DIM], B % BLOCK_B == 0
    (the AOT wrapper pads). Returns [B, OUT_DIM]."""
    b = feats.shape[0]
    assert b % BLOCK_B == 0, f"batch {b} not a multiple of {BLOCK_B}"
    grid = (b // BLOCK_B,)
    weight_spec = lambda shape: pl.BlockSpec(shape, lambda i: (0, 0))
    return pl.pallas_call(
        _mlp_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK_B, FEAT_DIM), lambda i: (i, 0)),
            weight_spec((FEAT_DIM, HIDDEN1)),
            weight_spec((1, HIDDEN1)),
            weight_spec((HIDDEN1, HIDDEN2)),
            weight_spec((1, HIDDEN2)),
            weight_spec((HIDDEN2, OUT_DIM)),
            weight_spec((1, OUT_DIM)),
        ],
        out_specs=pl.BlockSpec((BLOCK_B, OUT_DIM), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, OUT_DIM), jnp.float32),
        interpret=True,
    )(feats, w1, b1, w2, b2, w3, b3)
