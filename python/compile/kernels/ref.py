"""Pure-jnp oracles for the L1 kernels — the correctness reference.

pytest asserts kernel == ref to f32 tolerance across shape/value sweeps
(hypothesis); the rust integration test then asserts the XLA path ==
native-rust MLP, closing the three-implementation parity triangle:

    pallas kernel  ==  jnp ref  ==  rust native MLP
"""

import jax
import jax.numpy as jnp

# Architecture constants — the single source of truth shared with
# model.py; rust mirrors them in predict/engine.rs (HIDDEN1/2, OUT_DIM)
# and profile/features.rs (FEAT_DIM).
FEAT_DIM = 16
HIDDEN1 = 64
HIDDEN2 = 32
OUT_DIM = 2
# Telemetry featurize window (5 s samples → 2 min).
WINDOW = 24
N_CHANNELS = 4  # cpu, mem, disk, net
N_FEATURES = 7  # means(4) + cpu_peak + io_peak + burstiness


def mlp_forward_ref(feats, params):
    """Reference MLP forward: relu → relu → softplus head.

    feats: [B, FEAT_DIM]; params: (w1, b1, w2, b2, w3, b3) with biases
    shaped [1, H] (the layout rust sends through PJRT).
    Returns [B, OUT_DIM] with softplus outputs (both targets ≥ 0).
    """
    w1, b1, w2, b2, w3, b3 = params
    h1 = jnp.maximum(feats @ w1 + b1, 0.0)
    h2 = jnp.maximum(h1 @ w2 + b2, 0.0)
    y = h2 @ w3 + b3
    return jax.nn.softplus(y)


def featurize_ref(windows):
    """Reference telemetry featurization.

    windows: [B, WINDOW, 4] normalized utilization samples
    (cpu, mem, disk, net), oldest→newest.
    Returns [B, 7]: channel means, cpu peak (max), io peak
    (max over max(disk, net)), cpu burstiness (std/mean, 0 when idle).
    """
    means = jnp.mean(windows, axis=1)  # [B, 4]
    cpu = windows[:, :, 0]
    io = jnp.maximum(windows[:, :, 2], windows[:, :, 3])
    cpu_peak = jnp.max(cpu, axis=1)
    io_peak = jnp.max(io, axis=1)
    cpu_mean = means[:, 0]
    cpu_std = jnp.std(cpu, axis=1)
    burst = jnp.where(cpu_mean > 1e-6, cpu_std / jnp.maximum(cpu_mean, 1e-6), 0.0)
    return jnp.concatenate(
        [means, cpu_peak[:, None], io_peak[:, None], burst[:, None]], axis=1
    )


def init_params(key):
    """He-initialized params (shapes as sent by rust)."""
    k1, k2, k3 = jax.random.split(key, 3)

    def he(k, fan_in, shape):
        return (jax.random.normal(k, shape) * (2.0 / fan_in) ** 0.5).astype(jnp.float32)

    return (
        he(k1, FEAT_DIM, (FEAT_DIM, HIDDEN1)),
        jnp.zeros((1, HIDDEN1), jnp.float32),
        he(k2, HIDDEN1, (HIDDEN1, HIDDEN2)),
        jnp.zeros((1, HIDDEN2), jnp.float32),
        he(k3, HIDDEN2, (HIDDEN2, OUT_DIM)),
        jnp.zeros((1, OUT_DIM), jnp.float32),
    )
