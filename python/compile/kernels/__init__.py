"""L1 Pallas kernels: the compute hot spots of the prediction engine.

Kernels are authored for the TPU memory model (VMEM-resident weights,
128-lane tiling, MXU-shaped matmuls) but lowered with ``interpret=True``
so the emitted HLO runs on any PJRT backend, including the rust CPU
client. Real-TPU performance is estimated analytically in
DESIGN.md §8 — interpret-mode timings are correctness signals only.
"""

from compile.kernels.score_hosts import score_hosts_pallas  # noqa: F401
from compile.kernels.telemetry import featurize_pallas  # noqa: F401
