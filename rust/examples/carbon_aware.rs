//! Carbon-aware scheduling — the §VI-E research extension: "exploring
//! energy-carbon aware scheduling that considers renewable
//! availability or power grid conditions".
//!
//! The grid's carbon intensity follows a typical duck-curve day
//! (compressed into the campaign): dirty morning/evening, clean solar
//! midday. The capping logic this example originally sketched now
//! lives in the scheduler proper as
//! [`ecosched::sched::PowerCapLoop`]: set a watt budget (here, what a
//! dirty-grid contract would allow) and the loop holds the fleet
//! under it by throttling I/O-bound hosts first. We report gCO₂ for
//! baseline vs energy-aware vs energy-aware-plus-cap.
//!
//! Run: `cargo run --release --example carbon_aware`

use ecosched::coordinator::{make_policy, CampaignConfig, Coordinator};
use ecosched::sched::PowerCapParams;
use ecosched::util::timeline::sparkline;
use ecosched::workload::{Arrivals, Mix, TraceSpec};

/// Grid carbon intensity (gCO₂/kWh) over the campaign phase x∈[0,1]:
/// duck curve — ~450 at the edges, ~120 in the solar trough.
fn carbon_intensity(x: f64) -> f64 {
    let solar = (-((x - 0.5) / 0.18_f64).powi(2)).exp();
    450.0 - 330.0 * solar
}

fn grams_co2(report: &ecosched::coordinator::CampaignReport) -> f64 {
    // Integrate measured power against the intensity curve.
    let n = 200;
    let mut g = 0.0;
    for i in 0..n {
        let t0 = report.makespan * i as f64 / n as f64;
        let t1 = report.makespan * (i + 1) as f64 / n as f64;
        let joules = report.power_trace.integrate(t0, t1);
        let kwh = joules / 3.6e6;
        g += kwh * carbon_intensity((t0 / report.makespan).clamp(0.0, 1.0));
    }
    g
}

fn main() {
    ecosched::util::logger::init();
    // Deferrable-heavy mix (ETL dominates) on a diurnal day.
    let trace = TraceSpec {
        mix: Mix::io_heavy(),
        n_jobs: 28,
        arrivals: Arrivals::Diurnal {
            mean_gap: 30.0,
            peak_to_trough: 3.0,
        },
        horizon: 5400.0,
    }
    .generate(3);

    println!("grid intensity over the day:");
    let curve: Vec<f64> = (0..64).map(|i| carbon_intensity(i as f64 / 63.0)).collect();
    println!("  {}\n", sparkline(&curve));

    // (policy, power cap): the capped run models a dirty-hours grid
    // contract of ~480 W across the five-host fleet.
    let configs: [(&str, Option<PowerCapParams>); 3] = [
        ("round_robin", None),
        ("energy_aware", None),
        (
            "energy_aware",
            Some(PowerCapParams {
                budget_w: 480.0,
                ..Default::default()
            }),
        ),
    ];
    for (policy, power_cap) in configs {
        let capped = power_cap.is_some();
        let mut coordinator = Coordinator::new(
            CampaignConfig {
                seed: 3,
                power_cap,
                ..Default::default()
            },
            make_policy(policy).unwrap(),
        );
        let r = coordinator.run(trace.clone());
        let g = grams_co2(&r);
        println!(
            "{:<13}{} energy {:>9.1} Wh | carbon {:>7.1} gCO₂ | SLA {:>5.1} %",
            r.policy,
            if capped { "+cap" } else { "    " },
            r.energy_j / 3600.0,
            g,
            r.sla_compliance * 100.0
        );
    }
    println!(
        "\nenergy-aware consolidation reduces both joules and gCO₂, and the\n\
         PowerCapLoop bounds peak draw during dirty hours; a full carbon-aware\n\
         policy would additionally shift deferrable load into the solar trough\n\
         (extension of Eq. 6 with a time-varying intensity weight — feed\n\
         carbon_intensity() into PowerCapLoop::set_budget between scans)."
    );
}
