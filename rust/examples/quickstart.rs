//! Quickstart: the smallest end-to-end use of the public API.
//!
//! Builds a 5-host cluster campaign with a mixed big-data trace, runs
//! it under the OpenStack-style round-robin baseline and under the
//! paper's energy-aware scheduler, and prints the headline comparison
//! (§V-A: expect the energy-aware run to use 15–20 % less energy per
//! unit of work with zero SLA violations).
//!
//! Run: `cargo run --release --example quickstart`

use ecosched::coordinator::{make_policy, CampaignConfig, Coordinator};
use ecosched::util::table::{fmt_dur, fmt_energy};
use ecosched::workload::Mix;

fn main() {
    ecosched::util::logger::init();

    // 1. A workload trace: jobs across Hadoop MapReduce, Spark MLlib,
    //    and ETL pipelines, Poisson arrivals at the moderate-load
    //    operating point (§V-A) — self-calibrated by standard_trace.
    let trace = ecosched::exp::common::standard_trace(Mix::paper(), 24, 42);
    println!("trace: {} jobs, first kinds: {:?}\n",
        trace.len(),
        trace.iter().take(5).map(|j| j.kind.name()).collect::<Vec<_>>()
    );

    // 2. Run the same trace under both schedulers.
    let mut results = Vec::new();
    for policy in ["round_robin", "energy_aware"] {
        let mut coordinator = Coordinator::new(
            CampaignConfig {
                n_hosts: 5,
                seed: 42,
                ..Default::default()
            },
            make_policy(policy).expect("known policy"),
        );
        let report = coordinator.run(trace.clone());
        println!("=== {} ===", report.policy);
        println!("  completed        : {} jobs in {}", report.jobs.len(), fmt_dur(report.makespan));
        println!("  energy           : {} ({:.1} J per solo-second)",
            fmt_energy(report.energy_j), report.j_per_solo_second());
        println!("  SLA              : {:.1} % compliant, {} violations",
            report.sla_compliance * 100.0, report.sla_violations);
        println!("  mean JCT slowdown: {:+.2} %", report.mean_slowdown * 100.0);
        println!("  migrations       : {}, host power cycles: {}",
            report.migrations, report.power_cycles);
        println!("  hosts powered off: {:.2} host-hours\n", report.host_off_s / 3600.0);
        results.push(report);
    }

    // 3. The headline number.
    let savings = 1.0 - results[1].j_per_solo_second() / results[0].j_per_solo_second();
    println!("energy-aware saves {:.1} % energy per unit of work (paper: 15–20 %)",
        savings * 100.0);
    assert!(results[1].sla_violations == 0, "SLA must hold");
}
