//! Train the prediction engine end-to-end through the XLA stack:
//!
//! 1. run a calibration campaign in the simulator (history store),
//! 2. synthesize an oracle-labeled dataset biased toward the observed
//!    workload profiles (the paper's "historical execution outcomes"),
//! 3. drive `train_step.hlo.txt` (forward + backward + Adam fused by
//!    XLA) from rust — python is not involved,
//! 4. compare predictor families on a held-out set,
//! 5. persist `artifacts/weights.json` for the scheduler.
//!
//! Run: `make artifacts && cargo run --release --example train_predictor`

use ecosched::coordinator::{make_policy, CampaignConfig, Coordinator};
use ecosched::predict::{
    synthesize, DecisionTree, LinearModel, MlpWeights, NativeMlp, Trainer, TreeParams,
};
use ecosched::runtime::Runtime;
use ecosched::util::timeline::sparkline;
use ecosched::workload::{Arrivals, Mix, TraceSpec};

fn main() {
    ecosched::util::logger::init();
    let artifacts = ecosched::exp::common::find_artifacts();
    if !artifacts.join("meta.json").exists() {
        eprintln!("this example needs the AOT artifacts: run `make artifacts` first");
        std::process::exit(1);
    }

    // 1. Calibration campaign → execution history.
    println!("1. calibration campaign (best-fit, 16 jobs) …");
    let mut coordinator = Coordinator::new(
        CampaignConfig::default(),
        make_policy("best_fit").unwrap(),
    );
    let trace = TraceSpec {
        mix: Mix::paper(),
        n_jobs: 16,
        arrivals: Arrivals::Poisson { mean_gap: 45.0 },
        horizon: 3600.0,
    }
    .generate(11);
    coordinator.run(trace);
    println!("   history: {} execution records", coordinator.history.len());

    // 2. Dataset biased toward observed profiles.
    let ds = synthesize(6144, 7, Some(&coordinator.history));
    let (train, val) = ds.split(0.9);
    println!("   dataset: {} train / {} val\n", train.len(), val.len());

    // 3. Train through train_step.hlo.
    println!("2. training f_θ through train_step.hlo (Adam, fused fwd+bwd) …");
    let runtime = Runtime::new(&artifacts).expect("runtime");
    let mut trainer = Trainer::new(runtime, MlpWeights::init(42)).expect("trainer");
    let report = trainer.train(&train, &val, 40, 1).expect("training");
    let curve: Vec<f64> = report.loss_curve.clone();
    println!("   loss curve {} ({:.5} → {:.5})", sparkline(&curve),
        curve.first().unwrap(), curve.last().unwrap());
    println!("   val MSE: {:.6}\n", report.val_mse);

    // 4. Family comparison on the same held-out set.
    println!("3. predictor family comparison (held-out MSE):");
    let mut native = NativeMlp::new(trainer.weights.clone());
    let mlp_mse = val.mse(|x| {
        let (a, b) = native.forward(x);
        [a, b]
    });
    let tree = DecisionTree::fit(&train.xs, &train.ys, TreeParams::default());
    let tree_mse = val.mse(|x| tree.eval(x));
    let lin = LinearModel::fit(&train.xs, &train.ys, 1e-4);
    let lin_mse = val.mse(|x| lin.eval(x));
    println!("   mlp (xla-trained) : {mlp_mse:.6}");
    println!("   decision tree     : {tree_mse:.6}");
    println!("   linear (ridge)    : {lin_mse:.6}");
    assert!(
        mlp_mse < lin_mse,
        "the MLP should beat the linear model on oracle-labeled data"
    );

    // 5. Persist.
    let path = artifacts.join("weights.json");
    trainer.weights.save(&path).expect("save");
    println!("\nweights → {} (picked up by `ecosched experiment`/examples)", path.display());
}
