//! TeraSort campaign: reproduces the paper's flagship §V-A datapoint —
//! "the TeraSort workload exhibited a 19 % decrease in power
//! consumption without any measurable increase in execution time" —
//! across the 5–50 GB dataset sweep of §IV-B.
//!
//! Run: `cargo run --release --example terasort_campaign`

use ecosched::coordinator::{make_policy, CampaignConfig, Coordinator};
use ecosched::util::stats::linear_fit;
use ecosched::util::table::TableBuilder;
use ecosched::workload::{Mix, WorkloadKind};

fn main() {
    ecosched::util::logger::init();
    let mut table = TableBuilder::new(
        "TeraSort 5–50 GB sweep — baseline vs energy-aware",
        &["seed", "baseline J/solo-s", "optimized J/solo-s", "savings %", "JCT dev %", "SLA %"],
    );
    let mut savings_all = Vec::new();
    let mut sizes = Vec::new();
    let mut energies = Vec::new();
    for seed in [1u64, 2, 3] {
        let trace = ecosched::exp::common::standard_trace(
            Mix::only(WorkloadKind::HadoopTeraSort),
            20,
            seed,
        );
        let run = |policy: &str| {
            let mut c = Coordinator::new(
                CampaignConfig {
                    seed,
                    ..Default::default()
                },
                make_policy(policy).unwrap(),
            );
            c.run(trace.clone())
        };
        let base = run("round_robin");
        let opt = run("energy_aware");
        let savings = 1.0 - opt.j_per_solo_second() / base.j_per_solo_second();
        savings_all.push(savings);
        let jct_dev = opt
            .jobs
            .iter()
            .map(|j| j.jct)
            .sum::<f64>()
            / base.jobs.iter().map(|j| j.jct).sum::<f64>()
            - 1.0;
        table.row(&[
            seed.to_string(),
            format!("{:.1}", base.j_per_solo_second()),
            format!("{:.1}", opt.j_per_solo_second()),
            format!("{:.1}", savings * 100.0),
            format!("{:+.2}", jct_dev * 100.0),
            format!("{:.1}", opt.sla_compliance * 100.0),
        ]);
        for j in &opt.jobs {
            sizes.push(j.gb);
            energies.push(j.energy_j);
        }
    }
    println!("{}", table.render());
    let mean_savings = ecosched::util::stats::mean(&savings_all);
    println!(
        "mean TeraSort savings: {:.1} % (paper §V-A: 19 %)",
        mean_savings * 100.0
    );

    // Per-job energy must scale ~linearly with dataset size (sanity of
    // the energy attribution).
    let (a, b, r2) = linear_fit(&sizes, &energies);
    println!(
        "energy vs dataset size: E ≈ {a:.0} + {b:.0}·GB (r² = {r2:.3}) over {} jobs",
        sizes.len()
    );
    assert!(r2 > 0.5, "energy should scale with dataset size");
}
