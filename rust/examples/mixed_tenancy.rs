//! Mixed multi-tenant campaign — the END-TO-END DRIVER exercising the
//! full three-layer system on a realistic workload:
//!
//! 1. `make artifacts` products (L1 Pallas kernel inside the L2 JAX
//!    model, AOT-lowered to HLO) are loaded through the PJRT runtime;
//! 2. the MLP predictor is trained *through the `train_step.hlo`
//!    artifact* on oracle-labeled calibration data (weights cached in
//!    `artifacts/weights.json`);
//! 3. a diurnal multi-tenant trace (CPU-heavy analytics tenant +
//!    I/O-heavy warehousing tenant) runs under round-robin and under
//!    the energy-aware scheduler with the **XLA MLP on the decision
//!    path**;
//! 4. the paper's headline metrics are printed and checked.
//!
//! Falls back to the analytic oracle when artifacts are absent, so the
//! example always runs.
//!
//! Run: `make artifacts && cargo run --release --example mixed_tenancy`

use ecosched::coordinator::{make_policy, CampaignConfig, Coordinator};
use ecosched::exp::ExpContext;
use ecosched::util::table::{fmt_dur, fmt_energy};
use ecosched::util::timeline::sparkline;
use ecosched::workload::{Arrivals, Mix, TraceSpec, WorkloadKind};

fn main() {
    ecosched::util::logger::init();
    let mut ctx = ExpContext::default();
    ctx.artifacts = ecosched::exp::common::find_artifacts();
    println!(
        "artifacts: {} ({})\n",
        ctx.artifacts.display(),
        if ctx.has_artifacts() {
            "present — decisions run through predict.hlo via PJRT"
        } else {
            "missing — falling back to the analytic oracle"
        }
    );

    // Two tenants with a diurnal arrival pattern.
    let tenant_mix = Mix::new(
        "two-tenant",
        &[
            (WorkloadKind::SparkLogReg, 1.5),
            (WorkloadKind::SparkKMeans, 1.5),
            (WorkloadKind::HadoopTeraSort, 1.0),
            (WorkloadKind::HadoopGrep, 1.0),
            (WorkloadKind::EtlPipeline, 2.5),
        ],
    );
    let trace = TraceSpec {
        mix: tenant_mix,
        n_jobs: 32,
        arrivals: Arrivals::Diurnal {
            mean_gap: 26.0,
            peak_to_trough: 3.0,
        },
        horizon: 5400.0,
    }
    .generate(7);

    let mut reports = Vec::new();
    for (label, policy) in [
        ("round_robin (baseline)", make_policy("round_robin").unwrap()),
        ("energy_aware (paper)", ctx.energy_aware_policy()),
    ] {
        let mut coordinator = Coordinator::new(
            CampaignConfig {
                n_hosts: 5,
                seed: 7,
                ..Default::default()
            },
            policy,
        );
        let t0 = std::time::Instant::now();
        let r = coordinator.run(trace.clone());
        let wall = t0.elapsed().as_secs_f64();
        println!("=== {label} ===");
        println!(
            "  {} jobs | makespan {} | wall {:.2} s ({:.0}× realtime)",
            r.jobs.len(),
            fmt_dur(r.makespan),
            wall,
            r.makespan / wall
        );
        println!(
            "  energy {} | {:.1} J/solo-s | SLA {:.1} % | slowdown {:+.2} %",
            fmt_energy(r.energy_j),
            r.j_per_solo_second(),
            r.sla_compliance * 100.0,
            r.mean_slowdown * 100.0
        );
        println!(
            "  decisions {} @ {:.1} µs | migrations {} | power cycles {}",
            r.overhead.n_decisions,
            r.overhead.per_decision_us(),
            r.migrations,
            r.power_cycles
        );
        let hosts_on: Vec<f64> = r
            .hosts_on_trace
            .resample(0.0, r.makespan, 64)
            .iter()
            .map(|(_, v)| *v)
            .collect();
        println!("  hosts-on  {}", sparkline(&hosts_on));
        let power: Vec<f64> = r
            .power_trace
            .resample(0.0, r.makespan, 64)
            .iter()
            .map(|(_, v)| *v)
            .collect();
        println!("  power     {}\n", sparkline(&power));
        reports.push(r);
    }

    let savings = 1.0 - reports[1].j_per_solo_second() / reports[0].j_per_solo_second();
    println!(
        "headline: {:.1} % energy-per-work savings, {} SLA violations (paper: 15–20 %, zero)",
        savings * 100.0,
        reports[1].sla_violations
    );
    assert_eq!(reports[1].sla_violations, 0);
    assert!(savings > 0.05, "expected meaningful savings, got {savings:.3}");
}
