//! Tick-vs-event engine equivalence, and event-engine determinism.
//!
//! The event core's correctness contract is that under piecewise-
//! constant contention it computes the *same campaign* as the tick
//! oracle — same per-job outcomes, same energy, same counters — while
//! popping far fewer events. These tests pin that contract on a
//! hand-built trace where the tick engine itself is exact:
//!
//! - **All submits land at t = 0.** The tick engine quantizes to its
//!   grid: a job placed mid-campaign first contributes demand (and
//!   receives progress) at the *next* tick, over the whole preceding
//!   interval — up to one full tick of progress/energy attributed to
//!   time before the VM existed. The event core integrates from the
//!   exact placement instant, so mid-campaign arrivals diverge by
//!   design. A burst at t = 0 starts both engines at the same instant
//!   and removes the artifact, leaving only what the equivalence is
//!   about: closed-form progress/energy integration vs per-tick
//!   stepping.
//! - **Phase durations are multiples of 5 s** so completions and
//!   phase boundaries land on every tick grid in the sweep
//!   (`tick_interval ∈ {0.5, 1.0, 2.5}`), keeping the tick engine's
//!   energy horizon identical to the event core's.
//! - **No contention, no noise, no faults, no FaaS, round-robin** —
//!   every remaining feature (multi-phase demand switching, shared-
//!   host attribution weights, completion ordering) is exercised; no
//!   timing-sensitive control loop muddies the comparison.
//!
//! Not compared: `makespan` and `active_energy_j` (the event engine's
//! trailing cadence events advance the report horizon past the last
//! completion), `util_hist`/`power_trace` (different sampling
//! cadences), `events_processed` (differing by design — that's the
//! point), and whole-report fingerprints (which fold `makespan` in).

use ecosched::cluster::Demand;
use ecosched::coordinator::{make_policy, CampaignConfig, Coordinator, EngineKind};
use ecosched::workload::{Arrivals, Job, JobId, Mix, Phase, TraceSpec, WorkloadKind};

/// Eight two-phase jobs, all submitted at t = 0, with distinct
/// integer durations (multiples of 5) and per-phase demand switches.
/// On 4 hosts under round-robin that is 2 MEDIUM VMs per host —
/// no contention, no deferrals.
fn burst_trace() -> Vec<Job> {
    (0..8)
        .map(|i| {
            Job::new(
                JobId(i),
                WorkloadKind::HadoopWordCount,
                10.0 + i as f64,
                vec![
                    Phase {
                        name: "map",
                        duration: 120.0 + 20.0 * i as f64,
                        demand: Demand {
                            cpu: 4.0,
                            mem_gb: 4.0,
                            disk_mbps: 20.0,
                            net_mbps: 10.0,
                        },
                    },
                    Phase {
                        name: "reduce",
                        duration: 80.0 + 10.0 * i as f64,
                        demand: Demand {
                            cpu: 2.0,
                            mem_gb: 6.0,
                            disk_mbps: 40.0,
                            net_mbps: 5.0,
                        },
                    },
                ],
                0.0,
            )
        })
        .collect()
}

fn equiv_config(engine: EngineKind, tick_interval: f64) -> CampaignConfig {
    CampaignConfig {
        engine,
        tick_interval,
        n_hosts: 4,
        seed: 5,
        meter_noise: 0.0,
        telemetry_noise: 0.0,
        consolidation: None,
        dvfs: None,
        faas: None,
        faults: None,
        ..Default::default()
    }
}

fn rel_close(a: f64, b: f64, what: &str) {
    let denom = a.abs().max(b.abs()).max(1e-12);
    assert!(
        ((a - b) / denom).abs() < 1e-9,
        "{what}: tick={a} event={b}"
    );
}

#[test]
fn event_core_matches_tick_oracle_across_tick_grids() {
    let mut ev = Coordinator::new(
        equiv_config(EngineKind::Event, 1.0),
        make_policy("round_robin").unwrap(),
    );
    let event = ev.run(burst_trace());
    assert_eq!(event.jobs.len(), 8);

    for dt in [0.5, 1.0, 2.5] {
        let mut tk = Coordinator::new(
            equiv_config(EngineKind::Tick, dt),
            make_policy("round_robin").unwrap(),
        );
        let tick = tk.run(burst_trace());

        assert_eq!(tick.jobs.len(), event.jobs.len(), "dt={dt}");
        for (t, e) in tick.jobs.iter().zip(&event.jobs) {
            assert_eq!(t.id, e.id, "dt={dt}");
            assert!(
                (t.jct - e.jct).abs() < 1e-9,
                "dt={dt} job {:?}: tick jct {} event jct {}",
                t.id,
                t.jct,
                e.jct
            );
            rel_close(t.energy_j, e.energy_j, &format!("dt={dt} job energy"));
            assert_eq!(t.sla_met, e.sla_met, "dt={dt}");
            assert_eq!(t.migrations, e.migrations, "dt={dt}");
            assert_eq!(t.wait, e.wait, "dt={dt}");
        }
        rel_close(tick.energy_j, event.energy_j, &format!("dt={dt} energy_j"));
        rel_close(
            tick.energy_true_j,
            event.energy_true_j,
            &format!("dt={dt} energy_true_j"),
        );
        for (h, (a, b)) in tick
            .per_host_energy_j
            .iter()
            .zip(&event.per_host_energy_j)
            .enumerate()
        {
            rel_close(*a, *b, &format!("dt={dt} host {h} energy"));
        }
        assert_eq!(tick.sla_violations, event.sla_violations, "dt={dt}");
        assert_eq!(tick.migrations, event.migrations, "dt={dt}");
        assert_eq!(tick.power_cycles, event.power_cycles, "dt={dt}");
        assert_eq!(tick.deferrals, event.deferrals, "dt={dt}");
        assert_eq!(tick.host_off_s, event.host_off_s, "dt={dt}");
        assert_eq!(tick.interrupted_jobs, event.interrupted_jobs, "dt={dt}");
    }
}

/// The efficiency half of the contract on the same trace: the event
/// engine must pop strictly fewer events than any tick run (and the
/// margin must widen as the grid refines).
#[test]
fn event_core_pops_fewer_events_than_every_tick_grid() {
    let mut ev = Coordinator::new(
        equiv_config(EngineKind::Event, 1.0),
        make_policy("round_robin").unwrap(),
    );
    let event = ev.run(burst_trace());
    let mut prev = u64::MAX;
    for dt in [2.5, 1.0, 0.5] {
        let mut tk = Coordinator::new(
            equiv_config(EngineKind::Tick, dt),
            make_policy("round_robin").unwrap(),
        );
        let tick = tk.run(burst_trace());
        assert!(
            event.events_processed < tick.events_processed,
            "dt={dt}: event popped {} >= tick's {}",
            event.events_processed,
            tick.events_processed
        );
        assert!(tick.events_processed < prev, "refining the grid must add events");
        prev = tick.events_processed;
    }
}

fn poisson_trace(n: usize, seed: u64) -> Vec<Job> {
    TraceSpec {
        mix: Mix::paper(),
        n_jobs: n,
        arrivals: Arrivals::Poisson { mean_gap: 45.0 },
        horizon: 3600.0,
    }
    .generate(seed)
}

fn fingerprint_at(workers: usize, faulted: bool) -> u64 {
    let mut coord = Coordinator::new(
        CampaignConfig {
            engine: EngineKind::Event,
            n_hosts: 8,
            shard_count: 4,
            worker_threads: workers,
            seed: 29,
            faults: faulted.then(|| ecosched::sim::FaultConfig {
                host_crash_rate_per_hour: 12.0,
                mean_downtime_s: 180.0,
                worker_panics: 1,
                ..Default::default()
            }),
            ..Default::default()
        },
        make_policy("energy_aware").unwrap(),
    );
    coord.run(poisson_trace(14, 29)).fingerprint()
}

/// Event-engine determinism: the full report fingerprint (bit-level
/// JCTs, energy, fault ledger, shard digests) is identical across
/// same-seed reruns and across worker widths {1, 8}, with staggered
/// arrivals, consolidation + DVFS scans, and power transients in
/// play — clean and faulted.
#[test]
fn event_engine_fingerprint_stable_across_widths_and_reruns() {
    for faulted in [false, true] {
        let serial = fingerprint_at(1, faulted);
        assert_eq!(
            serial,
            fingerprint_at(1, faulted),
            "faulted={faulted}: same-seed rerun diverged"
        );
        assert_eq!(
            serial,
            fingerprint_at(8, faulted),
            "faulted={faulted}: worker width changed the campaign"
        );
    }
}

/// Power transients are priced into campaign energy under the event
/// engine: an energy-aware campaign that parks hosts must record
/// off-time, and its energy must stay conservative (noise-free total
/// no less than BMC floor × horizon would imply zero activity).
#[test]
fn event_engine_campaign_with_consolidation_is_well_formed() {
    let mut coord = Coordinator::new(
        CampaignConfig {
            engine: EngineKind::Event,
            n_hosts: 5,
            seed: 3,
            ..Default::default()
        },
        make_policy("energy_aware").unwrap(),
    );
    let r = coord.run(poisson_trace(12, 3));
    assert_eq!(r.jobs.len(), 12);
    assert!(r.energy_true_j > 0.0);
    assert!(r.events_processed > 0);
    assert!(r.makespan > 0.0);
    // Every completion was settled: per-job energy attributed.
    assert!(r.jobs.iter().all(|j| j.energy_j > 0.0));
}
