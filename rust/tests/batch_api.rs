//! Tests for the batched scheduling API: `decide_batch` must be
//! decision-equivalent (bit-identical) to the sequential `decide`
//! loop on a frozen context, the energy-aware policy must score a
//! whole burst through ONE predictor invocation, and the unified
//! `ControlLoop` trait must preserve the consolidation safety rails
//! (single-donor evacuation, `min_hosts_on`, the migration-ceiling
//! gate).

use ecosched::cluster::{Cluster, Demand, HostId, VmId};
use ecosched::coordinator::{make_policy, CampaignConfig, Coordinator};
use ecosched::predict::{oracle_eval, EnergyPredictor, Prediction};
use ecosched::profile::{ResourceVector, FEAT_DIM};
use ecosched::sched::{
    ConsolidationParams, Consolidator, ControlAction, ControlLoop, Decision, DvfsGovernor,
    DvfsParams, EnergyAware, EnergyAwareParams, PlacementPolicy, PlacementRequest,
    ScheduleContext, VmContext,
};
use ecosched::sim::Telemetry;
use ecosched::workload::{flavor_for, Arrivals, JobId, Mix, TraceSpec};
use std::cell::Cell;
use std::collections::BTreeMap;
use std::rc::Rc;

/// Placement requests captured from a fixed-seed campaign trace.
fn requests(n: usize, seed: u64) -> Vec<PlacementRequest> {
    TraceSpec {
        mix: Mix::paper(),
        n_jobs: n,
        arrivals: Arrivals::Poisson { mean_gap: 30.0 },
        horizon: 7200.0,
    }
    .generate(seed)
    .iter()
    .map(|job| {
        let flavor = flavor_for(job.kind);
        PlacementRequest {
            job: job.id,
            flavor,
            vector: ResourceVector::from_phases(&job.phases, &flavor),
            remaining_solo: job.solo_duration(),
            avoid_rack: None,
        }
    })
    .collect()
}

/// A few representative cluster states: idle, mixed load, one host
/// hot + one powered off, and memory-saturated.
fn cluster_states() -> Vec<Cluster> {
    let idle = Cluster::homogeneous(4);

    let mut mixed = Cluster::homogeneous(4);
    for i in 0..4 {
        mixed.host_mut(HostId(i)).demand = Demand {
            cpu: (i as f64 * 7.0) % 26.0,
            mem_gb: (i as f64 * 11.0) % 40.0,
            disk_mbps: (i as f64 * 130.0) % 700.0,
            net_mbps: (i as f64 * 23.0) % 90.0,
        };
    }
    for i in 0..3 {
        let vm = mixed.create_vm(
            ecosched::cluster::flavor::MEDIUM,
            JobId(100 + i as u64),
            0.0,
        );
        mixed.place_vm(vm, HostId(i)).unwrap();
    }

    let mut hot_and_off = Cluster::homogeneous(4);
    hot_and_off.host_mut(HostId(0)).demand.cpu = 30.0;
    hot_and_off.host_mut(HostId(3)).power_off(0.0);
    hot_and_off.advance_power_states(500.0);

    let mut saturated = Cluster::homogeneous(2);
    for h in 0..2 {
        for k in 0..4 {
            let vm = saturated.create_vm(
                ecosched::cluster::flavor::MEDIUM,
                JobId(200 + (h * 4 + k) as u64),
                0.0,
            );
            saturated.place_vm(vm, HostId(h)).unwrap();
        }
    }

    vec![idle, mixed, hot_and_off, saturated]
}

#[test]
fn decide_batch_matches_sequential_for_every_policy() {
    let reqs = requests(12, 42);
    for state in cluster_states() {
        let ctx = ScheduleContext::new(0.0, &state);
        for name in ["round_robin", "first_fit", "best_fit", "energy_aware"] {
            // Two fresh instances: stateful policies (round-robin's
            // cursor) must advance identically along both paths.
            let mut batched = make_policy(name).unwrap();
            let mut sequential = make_policy(name).unwrap();
            let batch = batched.decide_batch(&reqs, &ctx);
            let seq: Vec<Decision> =
                reqs.iter().map(|r| sequential.decide(r, &ctx)).collect();
            assert_eq!(batch, seq, "policy {name} diverged");
        }
    }
}

/// Oracle-equivalent predictor that counts invocations and rows.
struct CountingOracle {
    calls: Rc<Cell<u64>>,
    rows: Rc<Cell<u64>>,
}

impl EnergyPredictor for CountingOracle {
    fn name(&self) -> &'static str {
        "counting-oracle"
    }

    fn predict(&mut self, feats: &[[f32; FEAT_DIM]]) -> Vec<Prediction> {
        self.calls.set(self.calls.get() + 1);
        self.rows.set(self.rows.get() + feats.len() as u64);
        feats.iter().map(oracle_eval).collect()
    }
}

#[test]
fn energy_aware_scores_a_burst_in_one_predictor_call() {
    let reqs = requests(16, 7);
    let cluster = Cluster::homogeneous(5);
    let ctx = ScheduleContext::new(0.0, &cluster);

    let calls = Rc::new(Cell::new(0u64));
    let rows = Rc::new(Cell::new(0u64));
    let mut policy = EnergyAware::new(
        Box::new(CountingOracle {
            calls: Rc::clone(&calls),
            rows: Rc::clone(&rows),
        }),
        EnergyAwareParams::default(),
    );
    let decisions = policy.decide_batch(&reqs, &ctx);
    assert_eq!(decisions.len(), reqs.len());
    assert_eq!(calls.get(), 1, "batch must be ONE predictor invocation");
    // All 5 hosts are feasible for every request on an idle cluster.
    assert_eq!(rows.get(), (reqs.len() * 5) as u64);

    // The sequential loop pays one invocation per request.
    calls.set(0);
    for r in &reqs {
        policy.decide(r, &ctx);
    }
    assert_eq!(calls.get(), reqs.len() as u64);
}

#[test]
fn batched_campaign_is_deterministic_and_completes() {
    let run = || {
        let trace = TraceSpec {
            mix: Mix::paper(),
            n_jobs: 12,
            arrivals: Arrivals::Poisson { mean_gap: 40.0 },
            horizon: 3600.0,
        }
        .generate(21);
        let mut coord = Coordinator::new(
            CampaignConfig {
                seed: 21,
                ..Default::default()
            },
            make_policy("energy_aware").unwrap(),
        );
        coord.run(trace)
    };
    let (a, b) = (run(), run());
    assert_eq!(a.jobs.len(), 12);
    assert_eq!(a.energy_j, b.energy_j);
    assert_eq!(a.makespan, b.makespan);
    assert_eq!(a.migrations, b.migrations);
    let jct_a: Vec<f64> = a.jobs.iter().map(|j| j.jct).collect();
    let jct_b: Vec<f64> = b.jobs.iter().map(|j| j.jct).collect();
    assert_eq!(jct_a, jct_b);
}

#[test]
fn simultaneous_submit_burst_places_every_job() {
    // Batch arrivals: every job submits at t=0 — the whole trace goes
    // through one decide_batch call and must still complete.
    let trace = TraceSpec {
        mix: Mix::paper(),
        n_jobs: 10,
        arrivals: Arrivals::Batch,
        horizon: 3600.0,
    }
    .generate(5);
    let mut coord = Coordinator::new(
        CampaignConfig {
            seed: 5,
            ..Default::default()
        },
        make_policy("energy_aware").unwrap(),
    );
    let r = coord.run(trace);
    assert_eq!(r.jobs.len(), 10, "all burst jobs must finish");
    assert!(r.overhead.n_decisions >= 10);
}

// ---- ControlLoop safety rails under the unified trait ----

fn vm_context() -> VmContext {
    VmContext {
        vector: ResourceVector {
            cpu: 0.15,
            mem: 0.4,
            disk: 0.5,
            net: 0.3,
            cpu_peak: 0.2,
            io_peak: 0.6,
            burstiness: 0.1,
        },
        remaining_solo: 1500.0,
        slack_left: 0.08,
    }
}

/// Two lightly-loaded donors + one loaded receiver, with telemetry.
fn two_donor_setup() -> (Cluster, BTreeMap<VmId, VmContext>, Telemetry) {
    let mut c = Cluster::homogeneous(4);
    let mut ctxs = BTreeMap::new();
    for h in 0..3 {
        let vm = c.create_vm(ecosched::cluster::flavor::MEDIUM, JobId(h as u64), 0.0);
        c.place_vm(vm, HostId(h)).unwrap();
        ctxs.insert(vm, vm_context());
    }
    // Hosts 0 and 1: donors far below δ_low. Host 2: healthy receiver.
    for h in 0..2 {
        c.host_mut(HostId(h)).demand = Demand {
            cpu: 1.0,
            mem_gb: 4.0,
            disk_mbps: 40.0,
            net_mbps: 10.0,
        };
    }
    c.host_mut(HostId(2)).demand = Demand {
        cpu: 12.0,
        mem_gb: 14.0,
        disk_mbps: 120.0,
        net_mbps: 30.0,
    };
    let mut t = Telemetry::new(4, 1, 0.0);
    for k in 1..=6 {
        t.sample(k as f64 * 5.0, &c, &BTreeMap::new());
    }
    (c, ctxs, t)
}

#[test]
fn control_loop_evacuates_at_most_one_donor_per_scan() {
    let (c, ctxs, t) = two_donor_setup();
    let mut cons = Consolidator::new(ConsolidationParams::default());
    let mut pred = ecosched::predict::OraclePredictor;
    let ctx = ScheduleContext::new(1000.0, &c)
        .with_telemetry(&t)
        .with_vm_ctx(&ctxs);
    let actions = cons.scan(&ctx, Some(&mut pred));
    let migrated_from: Vec<HostId> = actions
        .iter()
        .filter_map(|a| match a {
            ControlAction::Migrate { vm, .. } => c.vms[vm].host,
            _ => None,
        })
        .collect();
    assert!(
        !migrated_from.is_empty(),
        "expected an evacuation: {actions:?}"
    );
    let first = migrated_from[0];
    assert!(
        migrated_from.iter().all(|&h| h == first),
        "migrations must come from ONE donor per scan: {actions:?}"
    );
}

#[test]
fn control_loop_respects_min_hosts_on() {
    let mut c = Cluster::homogeneous(3);
    c.host_mut(HostId(1)).power_off(0.0);
    c.host_mut(HostId(2)).power_off(0.0);
    c.advance_power_states(200.0);
    let t = Telemetry::new(3, 1, 0.0);
    let empty = BTreeMap::new();
    let mut cons = Consolidator::new(ConsolidationParams {
        min_hosts_on: 1,
        empty_grace_s: 0.0,
        ..Default::default()
    });
    let mut pred = ecosched::predict::OraclePredictor;
    let ctx = ScheduleContext::new(1000.0, &c)
        .with_telemetry(&t)
        .with_vm_ctx(&empty);
    let actions = cons.scan(&ctx, Some(&mut pred));
    // Host 0 is empty and past grace, but it is the last host on.
    assert!(
        !actions
            .iter()
            .any(|a| matches!(a, ControlAction::PowerOff(_))),
        "{actions:?}"
    );
}

#[test]
fn control_loop_gates_migrations_on_cluster_utilization() {
    let (mut c, ctxs, _) = two_donor_setup();
    // Push the receiver (and one donor) busy enough that the cluster
    // mean exceeds the migration ceiling.
    c.host_mut(HostId(1)).demand.cpu = 32.0;
    c.host_mut(HostId(2)).demand.cpu = 32.0;
    c.host_mut(HostId(3)).demand.cpu = 32.0;
    let mut t = Telemetry::new(4, 1, 0.0);
    for k in 1..=6 {
        t.sample(k as f64 * 5.0, &c, &BTreeMap::new());
    }
    let mut cons = Consolidator::new(ConsolidationParams::default());
    let mut pred = ecosched::predict::OraclePredictor;
    let ctx = ScheduleContext::new(1000.0, &c)
        .with_telemetry(&t)
        .with_vm_ctx(&ctxs);
    let actions = cons.scan(&ctx, Some(&mut pred));
    assert!(
        !actions
            .iter()
            .any(|a| matches!(a, ControlAction::Migrate { .. })),
        "migrations must wait for a low-activity window: {actions:?}"
    );
}

#[test]
fn dvfs_governor_emits_setfreq_through_the_same_trait() {
    let mut c = Cluster::homogeneous(2);
    c.host_mut(HostId(0)).demand = Demand {
        cpu: 2.0,
        mem_gb: 8.0,
        disk_mbps: 650.0,
        net_mbps: 10.0,
    };
    let mut t = Telemetry::new(2, 1, 0.0);
    for k in 1..=15 {
        t.sample(k as f64 * 5.0, &c, &BTreeMap::new());
    }
    let mut gov = DvfsGovernor::new(DvfsParams::default());
    let ctx = ScheduleContext::new(100.0, &c).with_telemetry(&t);
    // The governor needs no scoring handle.
    let actions = gov.scan(&ctx, None);
    assert_eq!(actions.len(), 1);
    assert!(matches!(
        actions[0],
        ControlAction::SetFreq {
            host: HostId(0),
            freq
        } if freq < 1.0
    ));
}
