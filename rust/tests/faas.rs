//! Serverless subsystem end-to-end: a 100k-invocation Burr-sampled
//! trace replays bit-identically per seed at worker widths {1, 8},
//! with cold-start count/energy and warm-pool occupancy surfaced in
//! the campaign report; and the hybrid-histogram keep-alive policy
//! beats the fixed window on cold-start rate at equal-or-lower
//! energy on the same trace.

use ecosched::coordinator::{make_policy, CampaignConfig, CampaignReport, Coordinator};
use ecosched::workload::faas::{FaasConfig, HybridParams, KeepAliveConfig};
use ecosched::workload::FaasTraceSpec;

/// Every deterministic field of a report, flattened for bit-exact
/// comparison (wall-clock overhead fields are excluded — they are the
/// one part of a report that legitimately varies run to run).
fn fingerprint(r: &CampaignReport) -> (Vec<(u64, f64, f64, f64)>, Vec<f64>, Vec<u64>) {
    let jobs = r
        .jobs
        .iter()
        .map(|j| (j.id.0, j.jct, j.energy_j, j.wait))
        .collect();
    let floats = vec![
        r.makespan,
        r.energy_j,
        r.energy_true_j,
        r.active_energy_j,
        r.sla_compliance,
        r.mean_slowdown,
        r.migration_stall_s,
        r.host_off_s,
        r.cold_start_energy_j,
        r.warm_pool_mean,
    ];
    let counts = vec![
        r.sla_violations as u64,
        r.migrations,
        r.power_cycles as u64,
        r.deferrals,
        r.cold_starts,
        r.warm_starts,
        r.containers_expired,
    ];
    (jobs, floats, counts)
}

fn replay(trace: &[ecosched::workload::Job], seed: u64, workers: usize) -> CampaignReport {
    let mut coord = Coordinator::new(
        CampaignConfig {
            n_hosts: 32,
            shard_count: 4,
            worker_threads: workers,
            seed,
            faas: Some(FaasConfig::default()),
            ..Default::default()
        },
        make_policy("round_robin").unwrap(),
    );
    coord.run(trace.to_vec())
}

#[test]
fn hundred_k_invocation_replay_is_deterministic_across_widths() {
    let spec = FaasTraceSpec {
        n_functions: 300,
        n_invocations: 100_000,
        iat_scale: 20.0,
    };
    let trace = spec.generate(17);
    assert_eq!(trace.len(), 100_000);

    let serial = replay(&trace, 17, 1);
    // Same seed ⇒ bit-identical report, at width 1 and width 8.
    let again = replay(&trace, 17, 1);
    let wide = replay(&trace, 17, 8);
    assert_eq!(fingerprint(&serial), fingerprint(&again), "width-1 rerun diverged");
    assert_eq!(fingerprint(&serial), fingerprint(&wide), "width 8 diverged from serial");

    // The serverless accounting the report must carry.
    assert_eq!(serial.jobs.len(), 100_000, "every invocation completes");
    assert_eq!(
        serial.cold_starts + serial.warm_starts,
        100_000,
        "every invocation resolves cold or warm"
    );
    assert!(serial.cold_starts > 0, "some invocations must cold-start");
    assert!(serial.warm_starts > 0, "hot functions must hit the warm pool");
    assert!(serial.cold_start_energy_j > 0.0);
    assert!(serial.warm_pool_mean > 0.0, "warm-pool occupancy must be sampled");
    assert!(serial.containers_expired > 0, "the keep-alive loop must evict");
}

#[test]
fn hybrid_keep_alive_beats_fixed_on_cold_rate_at_no_energy_cost() {
    let trace = FaasTraceSpec::default().generate(23);
    let run = |keep_alive: KeepAliveConfig| {
        let mut coord = Coordinator::new(
            CampaignConfig {
                n_hosts: 8,
                shard_count: 2,
                seed: 23,
                faas: Some(FaasConfig {
                    keep_alive,
                    ..Default::default()
                }),
                ..Default::default()
            },
            make_policy("round_robin").unwrap(),
        );
        coord.run(trace.clone())
    };
    let fixed = run(KeepAliveConfig::Fixed { window: 120.0 });
    let hybrid = run(KeepAliveConfig::Hybrid(HybridParams::default()));

    // Both policies evict, and every invocation resolves either way.
    for r in [&fixed, &hybrid] {
        assert_eq!(r.cold_starts + r.warm_starts, trace.len() as u64);
        assert!(r.containers_expired > 0);
    }
    // The headline: per-function windows cover mid-frequency functions
    // the fixed window misses, so the hybrid cold-starts strictly less
    // often ...
    assert!(
        hybrid.cold_starts < fixed.cold_starts,
        "hybrid cold starts {} not below fixed {}",
        hybrid.cold_starts,
        fixed.cold_starts
    );
    assert!(hybrid.cold_start_rate() < fixed.cold_start_rate());
    // ... while spending no more energy (shorter windows for hot and
    // rare functions give back the warm memory the longer mid-band
    // windows cost, plus the avoided boot-draw windows).
    let fixed_j = fixed.energy_j + fixed.cold_start_energy_j;
    let hybrid_j = hybrid.energy_j + hybrid.cold_start_energy_j;
    assert!(
        hybrid_j <= fixed_j * 1.01,
        "hybrid energy {hybrid_j:.0} J above fixed {fixed_j:.0} J"
    );
}
