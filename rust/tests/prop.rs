//! Property-based tests — randomized invariants with a from-scratch
//! harness (`proptest` is not in the offline vendor set). Each
//! property runs across many seeded random scenarios; failures print
//! the seed for reproduction.

use ecosched::cluster::{Cluster, Demand, HostId, VmState};
use ecosched::coordinator::{make_policy, CampaignConfig, Coordinator};
use ecosched::predict::{oracle_eval, synthesize, MlpWeights, NativeMlp};
use ecosched::profile::{ResourceVector, FEAT_DIM};
use ecosched::sched::{ConsolidationParams, Consolidator, ControlLoop, ScheduleContext, VmContext};
use ecosched::sim::Telemetry;
use ecosched::util::rng::Xoshiro256;
use ecosched::workload::{Arrivals, JobId, Mix, TraceSpec};
use std::collections::BTreeMap;

/// Mini property harness: run `f` for `n` cases with derived seeds.
fn for_all_seeds(n: u64, f: impl Fn(u64)) {
    for seed in 1..=n {
        f(seed);
    }
}

#[test]
fn prop_cluster_operations_preserve_invariants() {
    // Random sequences of place/migrate/finish/terminate never break
    // reservation accounting or cross-references.
    for_all_seeds(25, |seed| {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let mut cluster = Cluster::homogeneous(4);
        let mut live: Vec<ecosched::cluster::VmId> = Vec::new();
        let mut t = 0.0;
        for step in 0..120 {
            t += rng.uniform(0.1, 5.0);
            cluster.advance_power_states(t);
            match rng.range(0, 4) {
                0 => {
                    // Place a new VM anywhere it fits.
                    let flavor = ecosched::cluster::flavor::CATALOG[rng.range(0, 3)];
                    let feas = cluster.feasible_hosts(&flavor);
                    if !feas.is_empty() {
                        let host = feas[rng.range(0, feas.len())];
                        let vm = cluster.create_vm(
                            flavor,
                            ecosched::workload::JobId(step as u64),
                            t,
                        );
                        cluster.place_vm(vm, host).expect("fits");
                        // Random profiled demand exercises the
                        // incremental expected-load cache across the
                        // migration/terminate lifecycle below.
                        cluster.set_expected_demand(
                            vm,
                            Demand {
                                cpu: rng.uniform(0.0, 8.0),
                                mem_gb: rng.uniform(0.0, 16.0),
                                disk_mbps: rng.uniform(0.0, 200.0),
                                net_mbps: rng.uniform(0.0, 60.0),
                            },
                        );
                        live.push(vm);
                    }
                }
                1 => {
                    // Migrate a random running VM.
                    if !live.is_empty() {
                        let vm = live[rng.range(0, live.len())];
                        if matches!(cluster.vms[&vm].state, VmState::Running) {
                            let flavor = cluster.vms[&vm].flavor;
                            let from = cluster.vms[&vm].host.unwrap();
                            let targets: Vec<HostId> = cluster
                                .feasible_hosts(&flavor)
                                .into_iter()
                                .filter(|&h| h != from)
                                .collect();
                            if !targets.is_empty() {
                                let to = targets[rng.range(0, targets.len())];
                                let _ = cluster.start_migration(vm, to, t, 50.0);
                            }
                        }
                    }
                }
                2 => {
                    // Finish any in-flight migration.
                    let migrating: Vec<_> = live
                        .iter()
                        .copied()
                        .filter(|vm| {
                            matches!(cluster.vms[vm].state, VmState::Migrating { .. })
                        })
                        .collect();
                    for vm in migrating {
                        cluster.finish_migration(vm);
                    }
                }
                _ => {
                    // Terminate a random running VM.
                    if !live.is_empty() {
                        let idx = rng.range(0, live.len());
                        let vm = live[idx];
                        if matches!(cluster.vms[&vm].state, VmState::Running) {
                            cluster.terminate_vm(vm);
                            live.swap_remove(idx);
                        }
                    }
                }
            }
            cluster
                .check_invariants()
                .unwrap_or_else(|e| panic!("seed {seed} step {step}: {e}"));
        }
    });
}

#[test]
fn prop_batched_consolidation_scan_matches_sequential() {
    // The one-predictor-call scan must emit exactly the ControlActions
    // of the per-VM reference loop, whatever the cluster looks like.
    for_all_seeds(20, |seed| {
        let mut rng = Xoshiro256::seed_from_u64(seed ^ 0xC0FFEE);
        let n_hosts = 3 + rng.range(0, 5);
        let mut c = Cluster::homogeneous(n_hosts);
        let mut ctxs = BTreeMap::new();
        for j in 0..(2 * n_hosts) {
            let flavor = ecosched::cluster::flavor::CATALOG[rng.range(0, 3)];
            let feas = c.feasible_hosts(&flavor);
            if feas.is_empty() {
                continue;
            }
            let host = feas[rng.range(0, feas.len())];
            let vm = c.create_vm(flavor, JobId(j as u64), 0.0);
            c.place_vm(vm, host).unwrap();
            if rng.chance(0.5) {
                c.set_expected_demand(
                    vm,
                    Demand {
                        cpu: rng.uniform(0.0, 6.0),
                        mem_gb: rng.uniform(0.0, 12.0),
                        disk_mbps: rng.uniform(0.0, 150.0),
                        net_mbps: rng.uniform(0.0, 40.0),
                    },
                );
            }
            ctxs.insert(
                vm,
                VmContext {
                    vector: ResourceVector {
                        cpu: rng.uniform(0.0, 0.9),
                        mem: rng.uniform(0.0, 0.9),
                        disk: rng.uniform(0.0, 0.9),
                        net: rng.uniform(0.0, 0.9),
                        cpu_peak: rng.uniform(0.0, 1.0),
                        io_peak: rng.uniform(0.0, 1.0),
                        burstiness: rng.uniform(0.0, 1.0),
                    },
                    remaining_solo: rng.uniform(100.0, 5000.0),
                    slack_left: rng.uniform(0.0, 0.1),
                },
            );
        }
        for h in 0..n_hosts {
            c.host_mut(HostId(h)).demand = Demand {
                cpu: rng.uniform(0.0, 20.0),
                mem_gb: rng.uniform(0.0, 30.0),
                disk_mbps: rng.uniform(0.0, 400.0),
                net_mbps: rng.uniform(0.0, 60.0),
            };
        }
        let mut t = Telemetry::new(n_hosts, 1, 0.0);
        for k in 1..=10 {
            t.sample(k as f64 * 5.0, &c, &BTreeMap::new());
        }
        let ctx = ScheduleContext::new(1000.0, &c)
            .with_telemetry(&t)
            .with_vm_ctx(&ctxs);
        // Same MLP weights on both sides; the batched side scores
        // through forward_batch, the reference through per-VM calls —
        // bit-identical kernels make the actions exactly equal.
        let mut p1 = NativeMlp::new(MlpWeights::init(seed));
        let mut p2 = NativeMlp::new(MlpWeights::init(seed));
        let mut batched = Consolidator::new(ConsolidationParams::default());
        let mut sequential = Consolidator::new(ConsolidationParams::default());
        let a = batched.scan(&ctx, Some(&mut p1));
        let b = sequential.scan_sequential(&ctx, &mut p2);
        assert_eq!(a, b, "seed {seed}: batched {a:?} != sequential {b:?}");
        c.check_invariants()
            .unwrap_or_else(|e| panic!("seed {seed}: {e}"));
    });
}

#[test]
fn prop_all_jobs_complete_across_seeds() {
    // However the campaign unfolds, every submitted job completes and
    // internal accounting stays consistent.
    for_all_seeds(6, |seed| {
        let trace = TraceSpec {
            mix: Mix::paper(),
            n_jobs: 14,
            arrivals: Arrivals::Poisson { mean_gap: 30.0 },
            horizon: 3600.0,
        }
        .generate(seed);
        let mut coord = Coordinator::new(
            CampaignConfig {
                seed,
                ..Default::default()
            },
            make_policy("energy_aware").unwrap(),
        );
        let report = coord.run(trace);
        assert_eq!(report.jobs.len(), 14, "seed {seed}: all jobs complete");
        assert!(report.makespan < 4.0 * 3600.0, "seed {seed}: runaway makespan");
    });
}

#[test]
fn prop_energy_accounting_consistent() {
    // Measured energy ≈ ∫ power dt; per-host energies sum to the
    // total; noise-free meter equals ground truth.
    for_all_seeds(5, |seed| {
        let trace = TraceSpec {
            mix: Mix::paper(),
            n_jobs: 10,
            arrivals: Arrivals::Poisson { mean_gap: 45.0 },
            horizon: 3600.0,
        }
        .generate(seed);
        let mut coord = Coordinator::new(
            CampaignConfig {
                seed,
                meter_noise: 0.0,
                ..Default::default()
            },
            make_policy("best_fit").unwrap(),
        );
        let r = coord.run(trace);
        let per_host: f64 = r.per_host_energy_j.iter().sum();
        assert!(
            (per_host - r.energy_j).abs() < 1e-6,
            "seed {seed}: per-host sum {per_host} != total {}",
            r.energy_j
        );
        assert!(
            (r.energy_j - r.energy_true_j).abs() < 1e-6,
            "no noise configured"
        );
        let integral = r.power_trace.integrate(0.0, r.makespan);
        let rel = (integral - r.energy_j).abs() / r.energy_j;
        assert!(rel < 0.02, "seed {seed}: trace integral off by {rel}");
    });
}

#[test]
fn prop_campaigns_deterministic() {
    for_all_seeds(3, |seed| {
        let run = || {
            let trace = TraceSpec {
                mix: Mix::paper(),
                n_jobs: 8,
                arrivals: Arrivals::Poisson { mean_gap: 40.0 },
                horizon: 3600.0,
            }
            .generate(seed);
            let mut coord = Coordinator::new(
                CampaignConfig {
                    seed,
                    ..Default::default()
                },
                make_policy("energy_aware").unwrap(),
            );
            coord.run(trace)
        };
        let (a, b) = (run(), run());
        assert_eq!(a.energy_j, b.energy_j, "seed {seed}");
        assert_eq!(a.makespan, b.makespan);
        assert_eq!(a.migrations, b.migrations);
        assert_eq!(a.power_cycles, b.power_cycles);
    });
}

#[test]
fn prop_oracle_monotone_in_host_load_for_cpu_jobs() {
    // More CPU-loaded host ⇒ never less predicted slowdown for a
    // CPU-bound workload (placement sanity).
    for_all_seeds(200, |seed| {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let mut f = [0f32; FEAT_DIM];
        f[0] = rng.uniform(0.5, 1.0) as f32; // cpu-bound workload
        f[1] = rng.uniform(0.1, 0.6) as f32;
        f[13] = 1.0;
        let u1 = rng.uniform(0.0, 0.9);
        let u2 = (u1 + rng.uniform(0.0, 1.0 - u1)).min(1.0);
        let mut lo = f;
        lo[8] = u1 as f32;
        let mut hi = f;
        hi[8] = u2 as f32;
        let (p_lo, p_hi) = (oracle_eval(&lo), oracle_eval(&hi));
        assert!(
            p_hi.slowdown >= p_lo.slowdown - 1e-9,
            "seed {seed}: slowdown not monotone ({} vs {})",
            p_lo.slowdown,
            p_hi.slowdown
        );
    });
}

#[test]
fn prop_predictions_finite_and_bounded_everywhere() {
    // Oracle + dataset labels stay in their documented ranges across
    // the whole sampled feature space.
    let ds = synthesize(5000, 99, None);
    for (i, x) in ds.xs.iter().enumerate() {
        let p = oracle_eval(x);
        assert!(p.power_w.is_finite() && p.power_w >= 0.0, "row {i}");
        assert!(p.power_w < 200.0, "row {i}: power {}", p.power_w);
        assert!((0.0..=2.0).contains(&p.slowdown), "row {i}");
    }
}

#[test]
fn prop_sla_never_violated_by_energy_aware_at_moderate_load() {
    // The core paper claim, stress-tested across seeds.
    for_all_seeds(6, |seed| {
        let trace = ecosched::exp::common::standard_trace(Mix::paper(), 18, seed);
        let mut coord = Coordinator::new(
            CampaignConfig {
                seed,
                ..Default::default()
            },
            make_policy("energy_aware").unwrap(),
        );
        let r = coord.run(trace);
        assert_eq!(
            r.sla_violations, 0,
            "seed {seed}: {} violations",
            r.sla_violations
        );
    });
}

#[test]
fn prop_demand_application_conserves_totals() {
    // Sum of host demands == sum of capped VM demands, regardless of
    // placement pattern.
    for_all_seeds(20, |seed| {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let mut cluster = Cluster::homogeneous(3);
        let mut demands = std::collections::BTreeMap::new();
        for i in 0..8 {
            let flavor = ecosched::cluster::flavor::MEDIUM;
            let feas = cluster.feasible_hosts(&flavor);
            if feas.is_empty() {
                break;
            }
            let host = feas[rng.range(0, feas.len())];
            let vm = cluster.create_vm(flavor, ecosched::workload::JobId(i), 0.0);
            cluster.place_vm(vm, host).unwrap();
            demands.insert(
                vm,
                Demand {
                    cpu: rng.uniform(0.0, 10.0),
                    mem_gb: rng.uniform(0.0, 20.0),
                    disk_mbps: rng.uniform(0.0, 250.0),
                    net_mbps: rng.uniform(0.0, 80.0),
                },
            );
        }
        cluster.apply_demands(&demands);
        let host_total: f64 = cluster.hosts.iter().map(|h| h.demand.cpu).sum();
        let vm_total: f64 = demands
            .iter()
            .map(|(vm, d)| d.capped_by(&cluster.vms[vm].flavor).cpu)
            .sum();
        assert!(
            (host_total - vm_total).abs() < 1e-9,
            "seed {seed}: {host_total} vs {vm_total}"
        );
    });
}
