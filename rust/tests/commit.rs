//! Commit-protocol properties: the multi-coordinator placement
//! pipeline (N schedulers deciding against stale shard-epoch
//! snapshots, one `PlacementStore` validating in total order) must be
//! a pure *refactoring* of the single-leader scheduler at N = 1 and a
//! replayable, deterministic protocol at N > 1.
//!
//! The contract pinned here:
//!
//! - **Record/replay bit-identity.** An N-coordinator campaign
//!   (N ∈ {1, 2, 4}) appends every commit verdict to a totally-
//!   ordered log (`(time, class, coordinator, seq)`). Replaying that
//!   log through `Coordinator::with_replay` with ONE coordinator —
//!   no decide phase at all — reproduces the campaign fingerprint
//!   bit for bit, plus the store's `commits`/`commit_conflicts`
//!   counters, clean and faulted.
//! - **Width invariance.** The N-coordinator pipeline stays
//!   bit-identical across worker widths {1, 8}, like every other
//!   layer of the stack.
//! - **Conflicts resolve, campaigns complete.** On a deliberately
//!   contended fleet the store rejects double-booked commits
//!   (`commit_conflicts > 0`) and every rejected request is
//!   re-decided live — no job is lost to a conflict.
//!
//! The decision-level conflict rules (double-booked last slot,
//! commit-after-crash, snapshot-lag bound) are unit-tested in
//! `src/coordinator/placement_store.rs`; these tests exercise the
//! same paths end to end through full campaigns.

use ecosched::cluster::Demand;
use ecosched::coordinator::{make_policy, CampaignConfig, Coordinator, EngineKind};
use ecosched::workload::{Arrivals, Job, JobId, Mix, Phase, TraceSpec, WorkloadKind};

fn poisson_trace(n: usize, seed: u64) -> Vec<Job> {
    TraceSpec {
        mix: Mix::paper(),
        n_jobs: n,
        arrivals: Arrivals::Poisson { mean_gap: 45.0 },
        horizon: 3600.0,
    }
    .generate(seed)
}

/// The `engine_equiv.rs` campaign shape, parameterized over
/// coordinator count and worker width — staggered arrivals,
/// consolidation + DVFS scans, sharded cluster, optional faults.
fn commit_config(coordinators: usize, workers: usize, faulted: bool) -> CampaignConfig {
    let mut b = CampaignConfig::builder()
        .engine(EngineKind::Event)
        .hosts(8)
        .shards(4)
        .workers(workers)
        .seed(29)
        .coordinators(coordinators);
    if faulted {
        b = b.faults(ecosched::sim::FaultConfig {
            host_crash_rate_per_hour: 12.0,
            mean_downtime_s: 180.0,
            worker_panics: 1,
            ..Default::default()
        });
    }
    b.build().expect("valid campaign config")
}

/// Run the recorded side: an N-coordinator campaign at the given
/// worker width. Returns `(fingerprint, commits, conflicts, log)`.
fn record(
    coordinators: usize,
    workers: usize,
    faulted: bool,
) -> (u64, u64, u64, Vec<ecosched::coordinator::CommitRecord>) {
    let mut coord = Coordinator::new(
        commit_config(coordinators, workers, faulted),
        make_policy("energy_aware").unwrap(),
    );
    let r = coord.run(poisson_trace(14, 29));
    (
        r.fingerprint(),
        r.commits,
        r.commit_conflicts,
        std::mem::take(&mut coord.commit_log),
    )
}

/// A one-coordinator replay of an N-coordinator commit log must
/// reproduce the campaign bit for bit: the log IS the campaign.
#[test]
fn commit_log_replay_is_bit_identical_across_coordinator_counts() {
    for faulted in [false, true] {
        for n in [1usize, 2, 4] {
            let (fp, commits, conflicts, log) = record(n, 1, faulted);
            assert!(commits > 0, "n={n} faulted={faulted}: no commits recorded");
            assert_eq!(
                commits as usize,
                log.len(),
                "n={n} faulted={faulted}: log length disagrees with commit count"
            );

            let mut replayer = Coordinator::with_replay(
                commit_config(1, 1, faulted),
                make_policy("energy_aware").unwrap(),
                log,
            );
            let replayed = replayer.run(poisson_trace(14, 29));
            assert_eq!(
                fp,
                replayed.fingerprint(),
                "n={n} faulted={faulted}: replay diverged from the recorded campaign"
            );
            assert_eq!(
                commits, replayed.commits,
                "n={n} faulted={faulted}: replay commit count diverged"
            );
            assert_eq!(
                conflicts, replayed.commit_conflicts,
                "n={n} faulted={faulted}: replay conflict count diverged"
            );
        }
    }
}

/// Worker width never changes a multi-coordinator campaign: the
/// decide phases are planning over a frozen context and the commit
/// loop runs on the coordinator thread, so widths {1, 8} must agree
/// bit for bit at every coordinator count, clean and faulted.
#[test]
fn commit_pipeline_is_width_invariant() {
    for faulted in [false, true] {
        for n in [1usize, 2, 4] {
            let (fp1, c1, x1, _) = record(n, 1, faulted);
            let (fp8, c8, x8, _) = record(n, 8, faulted);
            assert_eq!(fp1, fp8, "n={n} faulted={faulted}: width changed the campaign");
            assert_eq!(c1, c8, "n={n} faulted={faulted}: width changed commit count");
            assert_eq!(x1, x8, "n={n} faulted={faulted}: width changed conflicts");
        }
    }
}

/// Ten same-instant MEDIUM jobs against two hosts: a burst dense
/// enough that schedulers double-book the best-scored host and the
/// store has to reject and re-decide. Every job must still land —
/// conflicts cost a re-decision, never a placement.
fn contended_trace() -> Vec<Job> {
    (0..10)
        .map(|i| {
            Job::new(
                JobId(i),
                WorkloadKind::SparkKMeans,
                8.0 + i as f64,
                vec![Phase {
                    name: "iterate",
                    duration: 300.0 + 15.0 * i as f64,
                    demand: Demand {
                        cpu: 6.0,
                        mem_gb: 12.0,
                        disk_mbps: 10.0,
                        net_mbps: 10.0,
                    },
                }],
                0.0,
            )
        })
        .collect()
}

/// Two coordinators racing into the same hosts' last capacity slots:
/// the store detects the double-booking (`commit_conflicts > 0`),
/// losers are re-decided live, and the campaign still completes every
/// job. The replay identity holds on the conflicted log too — the
/// log records the *resolved* decisions.
#[test]
fn contended_commits_conflict_then_resolve() {
    let config = || {
        CampaignConfig::builder()
            .hosts(2)
            .shards(2)
            .seed(11)
            .coordinators(2)
            .build()
            .expect("valid campaign config")
    };
    let mut coord = Coordinator::new(config(), make_policy("energy_aware").unwrap());
    let r = coord.run(contended_trace());
    assert!(
        r.commit_conflicts > 0,
        "contended burst produced no commit conflicts"
    );
    assert!(r.commits >= 10, "every request must reach the commit loop");
    assert_eq!(r.jobs.len(), 10, "a conflict must never lose a job");

    let log = std::mem::take(&mut coord.commit_log);
    let mut replayer =
        Coordinator::with_replay(config(), make_policy("energy_aware").unwrap(), log);
    let replayed = replayer.run(contended_trace());
    assert_eq!(r.fingerprint(), replayed.fingerprint());
    assert_eq!(r.commit_conflicts, replayed.commit_conflicts);
}

/// A zero snapshot-lag bound is the harshest staleness regime: any
/// cross-coordinator epoch movement forces a refresh-and-re-decide.
/// The campaign must still complete deterministically, and its log
/// must still replay bit for bit (stale verdicts are resolved in the
/// log like any other rejection). Own commits never trip the bound —
/// N = 1 under lag 0 must sail through with zero stale rejections,
/// which the placement-store unit tests pin at the decision level.
#[test]
fn zero_snapshot_lag_commits_stay_deterministic() {
    let config = || {
        CampaignConfig::builder()
            .hosts(8)
            .shards(4)
            .seed(29)
            .coordinators(4)
            .max_snapshot_lag(0)
            .build()
            .expect("valid campaign config")
    };
    let mut coord = Coordinator::new(config(), make_policy("energy_aware").unwrap());
    let r = coord.run(poisson_trace(14, 29));
    assert_eq!(r.jobs.len(), 14);

    let log = std::mem::take(&mut coord.commit_log);
    let mut replayer =
        Coordinator::with_replay(config(), make_policy("energy_aware").unwrap(), log);
    let replayed = replayer.run(poisson_trace(14, 29));
    assert_eq!(r.fingerprint(), replayed.fingerprint());
    assert_eq!(r.commit_conflicts, replayed.commit_conflicts);
}
