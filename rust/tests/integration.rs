//! Integration tests across modules: full campaigns under every
//! policy, consolidation dynamics, DVFS effects, history-driven
//! profiling, failure-ish edges (saturation, tiny clusters), and the
//! paper's headline comparisons at the shape level.

use ecosched::coordinator::{make_policy, CampaignConfig, Coordinator};
use ecosched::exp::common::standard_trace;
use ecosched::sla::SlaSpec;
use ecosched::workload::{Arrivals, Mix, TraceSpec, WorkloadKind};

fn cfg(seed: u64) -> CampaignConfig {
    CampaignConfig {
        seed,
        ..Default::default()
    }
}

#[test]
fn every_policy_completes_the_same_trace() {
    let trace = standard_trace(Mix::paper(), 16, 3);
    for policy in ["round_robin", "first_fit", "best_fit", "energy_aware"] {
        let mut coord = Coordinator::new(cfg(3), make_policy(policy).unwrap());
        let r = coord.run(trace.clone());
        assert_eq!(r.jobs.len(), 16, "{policy}");
        assert!(r.energy_j > 0.0);
        assert!(r.sla_compliance > 0.9, "{policy}: {}", r.sla_compliance);
    }
}

#[test]
fn headline_energy_savings_with_sla_intact() {
    // §V-A + §V-B shape: energy-aware wins on energy-per-work with
    // zero violations and small JCT deviation.
    let trace = standard_trace(Mix::paper(), 24, 1);
    let mut base = Coordinator::new(cfg(1), make_policy("round_robin").unwrap());
    let b = base.run(trace.clone());
    let mut opt = Coordinator::new(cfg(1), make_policy("energy_aware").unwrap());
    let o = opt.run(trace);
    let savings = 1.0 - o.j_per_solo_second() / b.j_per_solo_second();
    assert!(
        savings > 0.08,
        "expected ≥8 % savings at moderate load, got {:.1} %",
        savings * 100.0
    );
    assert_eq!(o.sla_violations, 0);
    // §V-B: mean JCT deviation below 5 %.
    let jct_b: f64 = b.jobs.iter().map(|j| j.jct).sum::<f64>() / b.jobs.len() as f64;
    let jct_o: f64 = o.jobs.iter().map(|j| j.jct).sum::<f64>() / o.jobs.len() as f64;
    assert!(
        (jct_o / jct_b - 1.0).abs() < 0.05,
        "JCT deviation {:.1} %",
        (jct_o / jct_b - 1.0) * 100.0
    );
}

#[test]
fn consolidation_powers_hosts_down() {
    let trace = standard_trace(Mix::paper(), 20, 5);
    let mut coord = Coordinator::new(cfg(5), make_policy("energy_aware").unwrap());
    let r = coord.run(trace);
    assert!(r.host_off_s > 0.0, "no host-off time recorded");
    let mean_on = r.hosts_on_trace.time_mean(0.0, r.makespan);
    assert!(mean_on < 4.6, "mean hosts-on {mean_on}");
}

#[test]
fn disabling_consolidation_erases_power_downs() {
    let trace = standard_trace(Mix::paper(), 16, 7);
    let mut coord = Coordinator::new(
        CampaignConfig {
            seed: 7,
            consolidation: None,
            ..Default::default()
        },
        make_policy("energy_aware").unwrap(),
    );
    let r = coord.run(trace);
    assert_eq!(r.power_cycles, 0);
    assert_eq!(r.migrations, 0);
}

#[test]
fn saturated_cluster_still_completes_and_reports_violations_honestly() {
    // Overload: 40 jobs arriving almost at once on 5 hosts. Jobs must
    // still all finish; SLA accounting must stay coherent (violations
    // allowed here — this is far beyond the paper's operating point).
    let trace = TraceSpec {
        mix: Mix::cpu_heavy(),
        n_jobs: 40,
        arrivals: Arrivals::Poisson { mean_gap: 3.0 },
        horizon: 3600.0,
    }
    .generate(11);
    let mut coord = Coordinator::new(cfg(11), make_policy("energy_aware").unwrap());
    let r = coord.run(trace);
    assert_eq!(r.jobs.len(), 40);
    assert!(r.sla_compliance <= 1.0);
    assert!(r.deferrals > 0, "saturation must show up as deferrals");
}

#[test]
fn single_host_cluster_degenerate_case() {
    let trace = TraceSpec {
        mix: Mix::only(WorkloadKind::HadoopGrep),
        n_jobs: 6,
        arrivals: Arrivals::Poisson { mean_gap: 60.0 },
        horizon: 3600.0,
    }
    .generate(13);
    let mut coord = Coordinator::new(
        CampaignConfig {
            n_hosts: 1,
            seed: 13,
            ..Default::default()
        },
        make_policy("energy_aware").unwrap(),
    );
    let r = coord.run(trace);
    assert_eq!(r.jobs.len(), 6);
    // min_hosts_on=1: the only host must never power off.
    assert_eq!(r.power_cycles, 0);
}

#[test]
fn history_improves_over_campaigns() {
    // Run two campaigns through the same coordinator: the second one
    // profiles recurring kinds from history (Eq. 1 static path).
    let mut coord = Coordinator::new(cfg(17), make_policy("energy_aware").unwrap());
    let t1 = standard_trace(Mix::paper(), 12, 17);
    coord.run(t1);
    let n_hist = coord.history.len();
    assert_eq!(n_hist, 12);
    for kind in WorkloadKind::ALL {
        if coord.history.of_kind(kind).count() > 0 {
            assert!(coord.history.mean_profile(kind).is_some());
        }
    }
    let t2 = standard_trace(Mix::paper(), 12, 18);
    let r2 = coord.run(t2);
    assert_eq!(coord.history.len(), n_hist + 12);
    assert_eq!(r2.sla_violations, 0);
}

#[test]
fn tight_sla_forces_more_spread_than_loose() {
    // Tighter slack ⇒ the scheduler must be at least as conservative
    // (no more energy savings than the loose-SLA run).
    let trace = standard_trace(Mix::paper(), 18, 19);
    let run_with = |slack: f64| {
        let mut coord = Coordinator::new(
            CampaignConfig {
                seed: 19,
                sla: SlaSpec { slack, tau: 1.0 },
                ..Default::default()
            },
            make_policy("energy_aware").unwrap(),
        );
        coord.run(trace.clone())
    };
    let tight = run_with(0.02);
    let loose = run_with(0.30);
    assert_eq!(tight.jobs.len(), loose.jobs.len());
    // Both comply with their own contracts at this load.
    assert_eq!(loose.sla_violations, 0);
}

#[test]
fn diurnal_trace_consolidates_in_troughs() {
    let trace = TraceSpec {
        mix: Mix::io_heavy(),
        n_jobs: 24,
        arrivals: Arrivals::Diurnal {
            mean_gap: 40.0,
            peak_to_trough: 4.0,
        },
        horizon: 5400.0,
    }
    .generate(23);
    let mut coord = Coordinator::new(cfg(23), make_policy("energy_aware").unwrap());
    let r = coord.run(trace);
    assert_eq!(r.jobs.len(), 24);
    // Hosts-on must vary over the day (consolidation follows load).
    let series: Vec<f64> = r
        .hosts_on_trace
        .resample(0.0, r.makespan, 50)
        .iter()
        .map(|(_, v)| *v)
        .collect();
    let max = series.iter().cloned().fold(0.0f64, f64::max);
    let min = series.iter().cloned().fold(f64::INFINITY, f64::min);
    assert!(max - min >= 1.0, "hosts-on flat: {min}..{max}");
}

#[test]
fn overhead_stays_under_paper_bound() {
    // §V-E: profiling + prediction below 5 % CPU.
    let trace = standard_trace(Mix::paper(), 20, 29);
    let mut coord = Coordinator::new(cfg(29), make_policy("energy_aware").unwrap());
    let r = coord.run(trace);
    assert!(
        r.overhead.cpu_share(r.makespan) < 0.05,
        "controller share {:.4}",
        r.overhead.cpu_share(r.makespan)
    );
}
