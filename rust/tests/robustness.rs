//! PR 10 robustness properties: correlated fault domains, partial
//! degradation with proactive draining, and checkpoint/restart.
//!
//! The headline property extends the chaos determinism contract to
//! the three new failure layers: a campaign under rack crashes,
//! degrade/restore episodes, *and* checkpointed restarts must be
//! bit-identical (report fingerprint) across worker widths {1, 8}
//! and across same-seed reruns — in both engines. Non-vacuity
//! asserts pin that every new mechanism actually fired: racks
//! crashed, degraded hosts were proactively drained, checkpoints
//! were written, and restarts genuinely resumed saved progress.
//!
//! The second acceptance property is the economic one: with the
//! identical fault schedule (checkpoint cadence does not enter plan
//! generation), turning checkpointing on must strictly reduce
//! replacement energy — the work the campaign pays for twice.

use ecosched::coordinator::{make_policy, CampaignConfig, Coordinator, EngineKind};
use ecosched::sim::{FaultConfig, FaultPlan};
use ecosched::workload::{Arrivals, Mix, TraceSpec};

/// Four racks of two hosts each — explicit, so the test does not
/// depend on the shard hash's host grouping.
fn rack_map() -> Vec<usize> {
    vec![0, 0, 1, 1, 2, 2, 3, 3]
}

/// A busy three-layer fault plan: independent host crashes, frequent
/// rack crashes, long degradation episodes (so consolidation scans
/// catch hosts while degraded), and a tight checkpoint cadence.
fn chaotic_faults(checkpoint: Option<f64>) -> FaultConfig {
    FaultConfig {
        host_crash_rate_per_hour: 2.0,
        rack_crash_rate_per_hour: 3.0,
        degrade_rate_per_hour: 3.0,
        degraded_duration_s: 900.0,
        checkpoint_interval_s: checkpoint,
        blackout_rate_per_hour: 0.5,
        migration_failure_prob: 0.1,
        worker_panics: 1,
        ..Default::default()
    }
}

fn run(engine: EngineKind, workers: usize, checkpoint: Option<f64>) -> ecosched::coordinator::CampaignReport {
    let trace = TraceSpec {
        mix: Mix::paper(),
        n_jobs: 16,
        arrivals: Arrivals::Poisson { mean_gap: 30.0 },
        horizon: 3600.0,
    }
    .generate(47);
    let mut coord = Coordinator::new(
        CampaignConfig {
            engine,
            n_hosts: 8,
            shard_count: 4,
            seed: 47,
            worker_threads: workers,
            rack_map: Some(rack_map()),
            faults: Some(chaotic_faults(checkpoint)),
            ..Default::default()
        },
        make_policy("energy_aware").unwrap(),
    );
    coord.run(trace)
}

/// The PR 10 determinism property, per engine: rack-faulted +
/// degraded + checkpointed campaigns are bit-identical across widths
/// {1, 8} and same-seed reruns, with every new fault layer
/// demonstrably active.
fn assert_deterministic(engine: EngineKind) {
    let serial = run(engine, 1, Some(30.0));
    // Non-vacuity: each of the three new layers actually fired.
    assert!(serial.rack_crashes > 0, "no rack crash fired — vacuous");
    assert!(
        serial.degraded_hosts > 0,
        "no degradation episode landed — vacuous"
    );
    assert!(
        serial.drains > 0,
        "consolidation never drained a degraded host — vacuous"
    );
    assert!(
        serial.checkpoints_taken > 0,
        "no checkpoint was written — vacuous"
    );
    assert!(
        serial.progress_saved_s > 0.0,
        "no crash resumed from a checkpoint — vacuous"
    );
    assert!(serial.checkpoint_energy_j > 0.0);
    // Every job is accounted for: finished or interrupted.
    assert_eq!(serial.jobs.len() + serial.interrupted_jobs, 16);
    let wide = run(engine, 8, Some(30.0));
    let rerun = run(engine, 8, Some(30.0));
    assert_eq!(
        serial.fingerprint(),
        wide.fingerprint(),
        "{engine:?}: rack/degrade/checkpoint campaign diverged between widths 1 and 8"
    );
    assert_eq!(
        wide.fingerprint(),
        rerun.fingerprint(),
        "{engine:?}: campaign not replayable from (seed, config)"
    );
}

#[test]
fn rack_degrade_checkpoint_campaign_is_bit_identical_event_engine() {
    assert_deterministic(EngineKind::Event);
}

#[test]
fn rack_degrade_checkpoint_campaign_is_bit_identical_tick_engine() {
    assert_deterministic(EngineKind::Tick);
}

/// Checkpointing pays: with the identical fault schedule (the
/// checkpoint interval never enters plan generation — asserted
/// below), replacement energy is strictly lower than the
/// full-restart baseline, because each crashed job replays only its
/// unsaved progress.
#[test]
fn checkpointed_restarts_strictly_reduce_replacement_energy() {
    // Same seed + config shape → the two campaigns draw the exact
    // same fault plan.
    let a = FaultPlan::generate(47, &chaotic_faults(None), 8, 4, 4);
    let b = FaultPlan::generate(47, &chaotic_faults(Some(30.0)), 8, 4, 4);
    assert_eq!(
        a.events(),
        b.events(),
        "checkpoint interval leaked into fault-plan generation"
    );
    let bare = run(EngineKind::Event, 1, None);
    let ckpt = run(EngineKind::Event, 1, Some(30.0));
    assert!(bare.replacement_energy_j > 0.0, "no work was lost — vacuous");
    assert!(ckpt.progress_saved_s > 0.0, "nothing was saved — vacuous");
    assert_eq!(bare.checkpoints_taken, 0);
    assert_eq!(bare.checkpoint_energy_j, 0.0);
    assert!(
        ckpt.replacement_energy_j < bare.replacement_energy_j,
        "checkpointing did not reduce replacement energy: {} !< {}",
        ckpt.replacement_energy_j,
        bare.replacement_energy_j
    );
}
