//! Parity suite for the batched GEMM inference path: `forward_batch`
//! must be **bit-identical** to row-by-row `forward` (same per-row
//! accumulation order), and the `predict_into` buffer path must agree
//! with `predict`, across batch sizes and random weights.

use ecosched::predict::{EnergyPredictor, MlpWeights, NativeMlp, OraclePredictor, Prediction};
use ecosched::profile::FEAT_DIM;
use ecosched::util::rng::Xoshiro256;

/// Feature rows spanning the realistic range, with exact zeros mixed
/// in to exercise the branch-free accumulation.
fn random_feats(rng: &mut Xoshiro256, n: usize) -> Vec<[f32; FEAT_DIM]> {
    (0..n)
        .map(|_| {
            let mut f = [0f32; FEAT_DIM];
            for x in f.iter_mut() {
                *x = if rng.chance(0.2) {
                    0.0
                } else {
                    rng.uniform(-0.5, 2.0) as f32
                };
            }
            f
        })
        .collect()
}

#[test]
fn forward_batch_bit_identical_across_batch_sizes_and_weights() {
    for seed in 1..=6u64 {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let mut m = NativeMlp::new(MlpWeights::init(seed * 101));
        for &batch in &[1usize, 2, 17, 128] {
            let feats = random_feats(&mut rng, batch);
            let singles: Vec<(f32, f32)> = feats.iter().map(|f| m.forward(f)).collect();
            let batched = m.forward_batch(&feats).to_vec();
            assert_eq!(
                batched, singles,
                "bitwise divergence at seed {seed} batch {batch}"
            );
        }
    }
}

#[test]
fn forward_batch_spanning_multiple_blocks_stays_identical() {
    // 300 rows forces three internal row blocks (BLOCK = 128).
    let mut rng = Xoshiro256::seed_from_u64(9);
    let mut m = NativeMlp::new(MlpWeights::init(9));
    let feats = random_feats(&mut rng, 300);
    let singles: Vec<(f32, f32)> = feats.iter().map(|f| m.forward(f)).collect();
    assert_eq!(m.forward_batch(&feats), &singles[..]);
}

#[test]
fn predict_into_agrees_with_predict_for_all_predictors() {
    let mut rng = Xoshiro256::seed_from_u64(4);
    let feats = random_feats(&mut rng, 33);
    let mut buf: Vec<Prediction> = Vec::new();

    let mut mlp = NativeMlp::new(MlpWeights::init(4));
    let fresh = mlp.predict(&feats);
    mlp.predict_into(&feats, &mut buf);
    assert_eq!(buf, fresh);

    let mut oracle = OraclePredictor;
    let fresh = oracle.predict(&feats);
    oracle.predict_into(&feats, &mut buf);
    assert_eq!(buf, fresh);
}

#[test]
fn empty_batch_is_a_no_op() {
    let mut m = NativeMlp::new(MlpWeights::init(2));
    assert!(m.forward_batch(&[]).is_empty());
    let mut buf = vec![Prediction { power_w: 1.0, slowdown: 1.0 }; 4];
    m.predict_into(&[], &mut buf);
    assert!(buf.is_empty(), "predict_into clears stale contents");
}
