//! Parallel shard workers: serial/parallel equivalence properties,
//! against the **persistent** `runtime::WorkerPool` (long-lived
//! threads, epoch-cached per-worker predictor clones, shard
//! affinity).
//!
//! The worker pool may only change *latency*, never decisions:
//!
//! * `decide_batch` is bit-identical between `worker_threads = 1`
//!   (the serial oracle) and widths {2, 3, 8}, over randomized
//!   sharded clusters at shard counts {1, 4, 16} — including when a
//!   mid-campaign `set_weights` lands between fan-outs on the same
//!   long-lived pool (the weight-epoch invalidation property).
//! * A worker re-clones the predictor exactly once per `set_weights`,
//!   not once per fan-out, and a stale clone is never scored against
//!   new weights.
//! * Consolidation plans (migrations + power-offs) are bit-identical
//!   across the same widths — the gather/score phases parallelize,
//!   the planned-load selection merge stays serial in shard order.
//! * Power-cap action sequences are bit-identical across widths over
//!   multi-round scans (ceiling re-assertion and restore included).
//! * Whole campaigns are bit-identical between `worker_threads` 1
//!   and 8 — including two campaigns running **concurrently** on
//!   independent pools, each matching its own serial oracle (nested
//!   parallelism shares no hidden state).

use ecosched::cluster::flavor::CATALOG;
use ecosched::cluster::{Cluster, Demand, HostId, ShardedCluster, VmId};
use ecosched::coordinator::{make_policy, CampaignConfig, Coordinator};
use ecosched::predict::{EnergyPredictor, MlpWeights, NativeMlp, OraclePredictor, Prediction};
use ecosched::profile::{ResourceVector, FEAT_DIM};
use ecosched::runtime::WorkerPool;
use ecosched::sched::{
    ConsolidationParams, Consolidator, ControlAction, ControlLoop, EnergyAware,
    EnergyAwareParams, PlacementPolicy, PlacementRequest, PowerCapLoop, PowerCapParams,
    ScheduleContext, VmContext,
};
use ecosched::sim::{FaultConfig, Telemetry};
use ecosched::util::rng::Xoshiro256;
use ecosched::workload::{flavor_for, Arrivals, JobId, Mix, TraceSpec};
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

fn for_all_seeds(n: u64, f: impl Fn(u64)) {
    for seed in 1..=n {
        f(seed);
    }
}

/// Randomized cluster biased toward a consolidation-friendly shape:
/// even hosts lightly loaded (Eq. 8 donor candidates), odd hosts
/// moderately loaded (viable targets), everything below the busy
/// ceiling so migrations are not postponed.
fn random_cluster(rng: &mut Xoshiro256, n_hosts: usize) -> Cluster {
    let mut c = Cluster::homogeneous(n_hosts);
    for j in 0..(2 * n_hosts) {
        let flavor = CATALOG[rng.range(0, 3)];
        let feas = c.feasible_hosts(&flavor);
        if feas.is_empty() {
            continue;
        }
        let host = feas[rng.range(0, feas.len())];
        let vm = c.create_vm(flavor, JobId(j as u64), 0.0);
        c.place_vm(vm, host).unwrap();
        if rng.chance(0.7) {
            c.set_expected_demand(
                vm,
                Demand {
                    cpu: rng.uniform(0.0, 4.0),
                    mem_gb: rng.uniform(0.0, 8.0),
                    disk_mbps: rng.uniform(0.0, 120.0),
                    net_mbps: rng.uniform(0.0, 30.0),
                },
            );
        }
    }
    for h in 0..n_hosts {
        let cpu = if h % 2 == 0 {
            rng.uniform(0.0, 7.0)
        } else {
            rng.uniform(8.0, 20.0)
        };
        c.host_mut(HostId(h)).demand = Demand {
            cpu,
            mem_gb: rng.uniform(2.0, 30.0),
            disk_mbps: rng.uniform(0.0, 300.0),
            net_mbps: rng.uniform(0.0, 50.0),
        };
    }
    c
}

/// Placement requests from a fixed-seed trace.
fn requests(n: usize, seed: u64) -> Vec<PlacementRequest> {
    TraceSpec {
        mix: Mix::paper(),
        n_jobs: n,
        arrivals: Arrivals::Poisson { mean_gap: 30.0 },
        horizon: 7200.0,
    }
    .generate(seed)
    .iter()
    .map(|job| {
        let flavor = flavor_for(job.kind);
        PlacementRequest {
            job: job.id,
            flavor,
            vector: ResourceVector::from_phases(&job.phases, &flavor),
            remaining_solo: job.solo_duration(),
            avoid_rack: None,
        }
    })
    .collect()
}

/// Params for the pool properties: dispatch is forced (the
/// small-burst inline fast path is serial by construction, so it
/// would bypass what these tests exercise) and the Eq. 7 slowdown
/// gate is effectively disabled — untrained random MLPs predict
/// large slowdowns, and with the default gate every decision would
/// collapse to the weight-INsensitive boot fallback, making the
/// weight-epoch properties vacuous.
fn pool_test_params() -> EnergyAwareParams {
    EnergyAwareParams {
        inline_burst_rows: 0,
        max_slowdown: 1e9,
        ..Default::default()
    }
}

fn mlp_policy(seed: u64) -> EnergyAware {
    EnergyAware::new(
        Box::new(NativeMlp::new(MlpWeights::init(seed))),
        pool_test_params(),
    )
}

#[test]
fn prop_parallel_decide_batch_is_bit_identical_to_serial() {
    for &shards in &[1usize, 4, 16] {
        for_all_seeds(8, |seed| {
            let mut rng = Xoshiro256::seed_from_u64(seed ^ 0x9001 ^ shards as u64);
            let n_hosts = 16 + rng.range(0, 17);
            let cluster = random_cluster(&mut rng, n_hosts);
            let sc = ShardedCluster::new(cluster, shards);
            let reqs = requests(10, seed);
            let serial_ctx = ScheduleContext::new(0.0, &sc).with_shards(&sc);
            let serial = mlp_policy(seed).decide_batch(&reqs, &serial_ctx);
            for &workers in &[2usize, 3, 8] {
                let pool = WorkerPool::new(workers);
                let ctx = ScheduleContext::new(0.0, &sc)
                    .with_shards(&sc)
                    .with_pool(&pool);
                let parallel = mlp_policy(seed).decide_batch(&reqs, &ctx);
                assert_eq!(
                    serial, parallel,
                    "seed {seed} shards {shards} workers {workers}"
                );
            }
        });
    }
}

/// Telemetry reflecting the cluster's current demand, plus a runtime
/// context for every placed VM (long remaining work so no VM is
/// pinned by its own copy time).
fn scan_inputs(sc: &ShardedCluster) -> (Telemetry, BTreeMap<VmId, VmContext>) {
    let mut t = Telemetry::new(sc.n_hosts(), 1, 0.0);
    for k in 1..=5 {
        t.sample(k as f64 * 5.0, sc, &BTreeMap::new());
    }
    let mut ctxs = BTreeMap::new();
    for &vm_id in sc.vms.keys() {
        ctxs.insert(
            vm_id,
            VmContext {
                vector: ResourceVector {
                    cpu: 0.15,
                    mem: 0.4,
                    disk: 0.5,
                    net: 0.3,
                    cpu_peak: 0.2,
                    io_peak: 0.6,
                    burstiness: 0.1,
                },
                remaining_solo: 2000.0,
                slack_left: 0.08,
            },
        );
    }
    (t, ctxs)
}

#[test]
fn prop_parallel_consolidation_plan_is_bit_identical_to_serial() {
    let mut saw_migration = false;
    for &shards in &[4usize, 16] {
        for seed in 1..=8u64 {
            let mut rng = Xoshiro256::seed_from_u64(seed ^ 0xC0_5011_DA7E ^ shards as u64);
            let cluster = random_cluster(&mut rng, 24);
            let sc = ShardedCluster::new(cluster, shards);
            let (t, ctxs) = scan_inputs(&sc);
            let scan_with = |workers: usize| -> Vec<ControlAction> {
                let pool = WorkerPool::new(workers);
                let mut cons = Consolidator::new(ConsolidationParams::default());
                // Oracle: deterministic, cloneable, and SLA-safe on
                // quiet targets, so the migration path is actually
                // exercised (an untrained MLP can gate everything
                // out and make the property vacuous).
                let mut pred = OraclePredictor;
                let ctx = ScheduleContext::new(1000.0, &sc)
                    .with_telemetry(&t)
                    .with_vm_ctx(&ctxs)
                    .with_shards(&sc)
                    .with_pool(&pool);
                cons.scan(&ctx, Some(&mut pred))
            };
            let serial = scan_with(1);
            saw_migration |= serial
                .iter()
                .any(|a| matches!(a, ControlAction::Migrate { .. }));
            for &workers in &[2usize, 3, 8] {
                assert_eq!(
                    serial,
                    scan_with(workers),
                    "seed {seed} shards {shards} workers {workers}"
                );
            }
        }
    }
    assert!(
        saw_migration,
        "no randomized scenario planned a migration — the property is vacuous"
    );
}

#[test]
fn prop_parallel_power_cap_actions_are_bit_identical_to_serial() {
    for_all_seeds(6, |seed| {
        let mut rng = Xoshiro256::seed_from_u64(seed ^ 0xCAB1E);
        let base = random_cluster(&mut rng, 24);
        let budget = base.total_power() * 0.9;
        // Three rounds with actuation between scans exercises
        // throttle, ceiling re-assert, and restore paths.
        let rounds_with = |workers: usize| -> Vec<Vec<ControlAction>> {
            let pool = WorkerPool::new(workers);
            let mut sc = ShardedCluster::new(base.clone(), 16);
            let mut cap = PowerCapLoop::new(PowerCapParams {
                budget_w: budget,
                ..Default::default()
            });
            let mut rounds = Vec::new();
            for round in 0..3 {
                let actions = {
                    let ctx = ScheduleContext::new(round as f64 * 30.0, &sc)
                        .with_shards(&sc)
                        .with_pool(&pool);
                    cap.scan(&ctx, None)
                };
                for a in &actions {
                    if let ControlAction::SetFreq { host, freq } = a {
                        sc.set_freq(*host, *freq);
                    }
                }
                rounds.push(actions);
            }
            rounds
        };
        let serial = rounds_with(1);
        assert!(
            serial.iter().any(|r| !r.is_empty()),
            "seed {seed}: budget never forced a throttle — vacuous"
        );
        for &workers in &[2usize, 3, 8] {
            assert_eq!(serial, rounds_with(workers), "seed {seed} workers {workers}");
        }
    });
}

#[test]
fn campaign_is_bit_identical_across_worker_counts() {
    let trace = TraceSpec {
        mix: Mix::paper(),
        n_jobs: 12,
        arrivals: Arrivals::Poisson { mean_gap: 40.0 },
        horizon: 3600.0,
    }
    .generate(13);
    let run = |workers: usize| {
        let mut coord = Coordinator::new(
            CampaignConfig {
                seed: 13,
                shard_count: 4,
                worker_threads: workers,
                ..Default::default()
            },
            make_policy("energy_aware").unwrap(),
        );
        coord.run(trace.clone())
    };
    let (serial, wide) = (run(1), run(8));
    assert_eq!(serial.jobs.len(), 12);
    assert_eq!(serial.energy_j, wide.energy_j);
    assert_eq!(serial.makespan, wide.makespan);
    assert_eq!(serial.migrations, wide.migrations);
    assert_eq!(serial.sla_violations, wide.sla_violations);
    assert_eq!(serial.final_digests.len(), wide.final_digests.len());
}

/// The chaos determinism property (PR 7 acceptance): a campaign under
/// an aggressive fault plan — host crashes with evacuations, telemetry
/// blackouts, transient migration failures, injected scoring-worker
/// panics — must be **bit-identical** across worker widths {1, 8} and
/// across same-seed reruns. The comparison is the report fingerprint,
/// which folds per-job outcomes, every fault counter, and the final
/// shard digests; the non-vacuity asserts guarantee faults actually
/// fired and jobs were actually evacuated.
#[test]
fn faulted_campaign_is_bit_identical_across_widths_and_reruns() {
    let trace = TraceSpec {
        mix: Mix::paper(),
        n_jobs: 14,
        arrivals: Arrivals::Poisson { mean_gap: 40.0 },
        horizon: 3600.0,
    }
    .generate(31);
    let run = |workers: usize| {
        let mut coord = Coordinator::new(
            CampaignConfig {
                seed: 31,
                shard_count: 4,
                worker_threads: workers,
                faults: Some(FaultConfig {
                    host_crash_rate_per_hour: 4.0,
                    blackout_rate_per_hour: 1.0,
                    migration_failure_prob: 0.2,
                    worker_panics: 2,
                    ..Default::default()
                }),
                ..Default::default()
            },
            make_policy("energy_aware").unwrap(),
        );
        coord.run(trace.clone())
    };
    let serial = run(1);
    // Non-vacuous: the plan actually crashed hosts, evacuated running
    // VMs, and exercised the pool's panic-heal path at BOTH widths.
    assert!(serial.host_crashes > 0, "no crashes fired — vacuous");
    assert!(serial.evacuations > 0, "no VM was evacuated — vacuous");
    assert_eq!(serial.worker_panics, 2, "panic probes did not run");
    // Every job is accounted for: finished or interrupted.
    assert_eq!(serial.jobs.len() + serial.interrupted_jobs, 14);
    let wide = run(8);
    let rerun = run(8);
    assert_eq!(
        serial.fingerprint(),
        wide.fingerprint(),
        "faulted campaign diverged between widths 1 and 8"
    );
    assert_eq!(
        wide.fingerprint(),
        rerun.fingerprint(),
        "faulted campaign not replayable from (seed, config)"
    );
}

/// Nested parallelism: two campaigns running **concurrently** (each
/// with its own width-4 `WorkerPool`, so up to 8 pool workers plus 2
/// driver threads are live at once) must each be bit-identical to the
/// same campaign run serially at width 1. Pools share nothing —
/// crossed state (a global pool, a shared RNG, a static counter)
/// would show up here as divergence or a crash.
#[test]
fn concurrent_campaigns_match_their_serial_oracles() {
    let specs = [(21u64, 10usize), (22u64, 14usize)];
    let run = |seed: u64, n_jobs: usize, workers: usize| {
        let trace = TraceSpec {
            mix: Mix::paper(),
            n_jobs,
            arrivals: Arrivals::Poisson { mean_gap: 40.0 },
            horizon: 3600.0,
        }
        .generate(seed);
        let mut coord = Coordinator::new(
            CampaignConfig {
                seed,
                shard_count: 4,
                worker_threads: workers,
                ..Default::default()
            },
            make_policy("energy_aware").unwrap(),
        );
        coord.run(trace)
    };
    let serial: Vec<_> = specs.iter().map(|&(s, n)| run(s, n, 1)).collect();
    let concurrent = std::thread::scope(|scope| {
        let handles: Vec<_> = specs
            .iter()
            .map(|&(s, n)| scope.spawn(move || run(s, n, 4)))
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("campaign thread panicked"))
            .collect::<Vec<_>>()
    });
    for ((oracle, nested), &(seed, n_jobs)) in serial.iter().zip(&concurrent).zip(&specs) {
        assert_eq!(oracle.jobs.len(), n_jobs, "seed {seed}");
        assert_eq!(oracle.energy_j, nested.energy_j, "seed {seed}");
        assert_eq!(oracle.makespan, nested.makespan, "seed {seed}");
        assert_eq!(oracle.migrations, nested.migrations, "seed {seed}");
        assert_eq!(oracle.sla_violations, nested.sla_violations, "seed {seed}");
        assert_eq!(oracle.deferrals, nested.deferrals, "seed {seed}");
        assert_eq!(
            oracle.final_digests.len(),
            nested.final_digests.len(),
            "seed {seed}"
        );
    }
}

/// A predictor whose weights can be swapped mid-test through a shared
/// handle (the policy owns one end, the test keeps the other) and
/// whose `try_clone` calls are counted — the instrumentation for the
/// weight-epoch invalidation properties. Clones are weight snapshots
/// (plain `NativeMlp`s), so they carry the epoch of the weights they
/// were cut from, exactly like a production clone.
struct SharedMlp {
    inner: Arc<Mutex<NativeMlp>>,
    clones: Arc<AtomicU64>,
}

impl EnergyPredictor for SharedMlp {
    fn name(&self) -> &'static str {
        "shared-mlp"
    }

    fn predict(&mut self, feats: &[[f32; FEAT_DIM]]) -> Vec<Prediction> {
        self.inner.lock().unwrap().predict(feats)
    }

    fn predict_into(&mut self, feats: &[[f32; FEAT_DIM]], out: &mut Vec<Prediction>) {
        self.inner.lock().unwrap().predict_into(feats, out)
    }

    fn try_clone(&self) -> Option<Box<dyn EnergyPredictor + Send>> {
        self.clones.fetch_add(1, Ordering::Relaxed);
        Some(Box::new(self.inner.lock().unwrap().clone()))
    }

    fn weight_epoch(&self) -> u64 {
        self.inner.lock().unwrap().weight_epoch()
    }
}

fn shared_policy(
    handle: &Arc<Mutex<NativeMlp>>,
    clones: &Arc<AtomicU64>,
) -> EnergyAware {
    EnergyAware::new(
        Box::new(SharedMlp {
            inner: Arc::clone(handle),
            clones: Arc::clone(clones),
        }),
        pool_test_params(),
    )
}

#[test]
fn prop_set_weights_between_fanouts_is_bit_identical_at_any_width() {
    use ecosched::sched::Decision;
    let mut saw_weight_sensitivity = false;
    for &shards in &[1usize, 4] {
        for seed in 1..=4u64 {
            let mut rng = Xoshiro256::seed_from_u64(seed ^ 0xE90C ^ shards as u64);
            let n_hosts = 16 + rng.range(0, 17);
            let sc = ShardedCluster::new(random_cluster(&mut rng, n_hosts), shards);
            let burst_a = requests(10, seed);
            let burst_b = requests(10, seed ^ 0x55);
            let (w1, w2) = (seed * 2 + 1, seed * 2 + 1000);
            // One run = two fan-outs with a set_weights between them,
            // all against the SAME long-lived pool, so widths > 1
            // must invalidate their cached clones to agree with the
            // serial oracle.
            let run = |workers: usize| -> (Vec<Decision>, Vec<Decision>) {
                let pool = WorkerPool::new(workers);
                let handle = Arc::new(Mutex::new(NativeMlp::new(MlpWeights::init(w1))));
                let clones = Arc::new(AtomicU64::new(0));
                let mut policy = shared_policy(&handle, &clones);
                let ctx = ScheduleContext::new(0.0, &sc)
                    .with_shards(&sc)
                    .with_pool(&pool);
                let a = policy.decide_batch(&burst_a, &ctx);
                handle.lock().unwrap().set_weights(MlpWeights::init(w2));
                let b = policy.decide_batch(&burst_b, &ctx);
                (a, b)
            };
            let serial = run(1);
            for &workers in &[2usize, 3, 8] {
                assert_eq!(
                    serial,
                    run(workers),
                    "seed {seed} shards {shards} workers {workers}"
                );
            }
            // Non-vacuity: scoring burst B with the STALE weights
            // must change some decision on some scenario, otherwise
            // the invalidation property proves nothing.
            let stale_ctx = ScheduleContext::new(0.0, &sc).with_shards(&sc);
            let stale = mlp_policy(w1).decide_batch(&burst_b, &stale_ctx);
            saw_weight_sensitivity |= stale != serial.1;
        }
    }
    assert!(
        saw_weight_sensitivity,
        "no scenario was weight-sensitive — the set_weights property is vacuous"
    );
}

#[test]
fn worker_reclones_once_per_set_weights_not_per_fanout() {
    // 4 shards, K = shard_count: every fan-out dispatches all four
    // shards, whose stable affinity workers on a width-2 pool are the
    // expected clone targets.
    let mut rng = Xoshiro256::seed_from_u64(0xC10E5);
    let sc = ShardedCluster::new(random_cluster(&mut rng, 24), 4);
    let reqs = requests(8, 3);
    let pool = WorkerPool::new(2);
    let affinity_workers = (0..4)
        .map(|s| pool.worker_for(s))
        .collect::<std::collections::BTreeSet<_>>()
        .len() as u64;
    assert!(affinity_workers >= 1);
    let handle = Arc::new(Mutex::new(NativeMlp::new(MlpWeights::init(9))));
    let clones = Arc::new(AtomicU64::new(0));
    let mut policy = shared_policy(&handle, &clones);
    let ctx = ScheduleContext::new(0.0, &sc)
        .with_shards(&sc)
        .with_pool(&pool);
    for _ in 0..3 {
        policy.decide_batch(&reqs, &ctx);
    }
    assert_eq!(
        clones.load(Ordering::Relaxed),
        affinity_workers,
        "one clone per participating worker on first use, then cache hits"
    );
    handle.lock().unwrap().set_weights(MlpWeights::init(10));
    for _ in 0..2 {
        policy.decide_batch(&reqs, &ctx);
    }
    assert_eq!(
        clones.load(Ordering::Relaxed),
        2 * affinity_workers,
        "exactly one re-clone per worker per set_weights, not per fan-out"
    );
    // And the re-cloned workers score the NEW weights: pooled
    // decisions equal a fresh serial policy built directly on them.
    let pooled = policy.decide_batch(&reqs, &ctx);
    let serial_ctx = ScheduleContext::new(0.0, &sc).with_shards(&sc);
    let fresh = mlp_policy(10).decide_batch(&reqs, &serial_ctx);
    assert_eq!(pooled, fresh, "a stale clone must never score against new weights");
    assert_eq!(
        clones.load(Ordering::Relaxed),
        2 * affinity_workers,
        "the extra fan-out hit the cache"
    );
}
