//! Integration tests over the real PJRT runtime + AOT artifacts.
//!
//! Require `make artifacts` to have run; each test skips (with a
//! loud message) when artifacts are absent so `cargo test` stays
//! green on a fresh checkout.

use ecosched::predict::{
    synthesize, EnergyPredictor, MlpWeights, NativeMlp, Trainer, XlaMlp,
};
use ecosched::profile::FEAT_DIM;
use ecosched::runtime::Runtime;
use ecosched::util::rng::Xoshiro256;
use std::path::PathBuf;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = std::env::var("ECOSCHED_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts"));
    if dir.join("meta.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts missing at {dir:?} — run `make artifacts`");
        None
    }
}

fn random_feats(n: usize, seed: u64) -> Vec<[f32; FEAT_DIM]> {
    let mut rng = Xoshiro256::seed_from_u64(seed);
    (0..n)
        .map(|_| {
            let mut f = [0f32; FEAT_DIM];
            for v in f.iter_mut() {
                *v = rng.next_f64() as f32;
            }
            f
        })
        .collect()
}

#[test]
fn meta_loads_and_matches_crate_constants() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::new(&dir).expect("runtime");
    assert_eq!(rt.meta.feat_dim, FEAT_DIM);
    assert_eq!(rt.meta.hidden, vec![64, 32]);
    assert_eq!(rt.meta.out_dim, 2);
}

#[test]
fn predict_artifact_executes_and_matches_native_mlp() {
    let Some(dir) = artifacts_dir() else { return };
    let weights = MlpWeights::init(11);
    let mut xla = XlaMlp::new(Runtime::new(&dir).unwrap(), weights.clone()).unwrap();
    let mut native = NativeMlp::new(weights);
    let feats = random_feats(100, 1); // < batch → exercises padding
    let from_xla = xla.predict(&feats);
    let from_native = native.predict(&feats);
    assert_eq!(from_xla.len(), 100);
    for (i, (a, b)) in from_xla.iter().zip(&from_native).enumerate() {
        assert!(
            (a.power_w - b.power_w).abs() < 1e-2,
            "row {i}: xla {} vs native {}",
            a.power_w,
            b.power_w
        );
        assert!((a.slowdown - b.slowdown).abs() < 1e-4, "row {i}");
    }
}

#[test]
fn predict_handles_multi_chunk_batches() {
    let Some(dir) = artifacts_dir() else { return };
    let weights = MlpWeights::init(13);
    let mut xla = XlaMlp::new(Runtime::new(&dir).unwrap(), weights.clone()).unwrap();
    let feats = random_feats(300, 2); // 3 chunks of 128
    let out = xla.predict(&feats);
    assert_eq!(out.len(), 300);
    // Chunking must not change results vs one-at-a-time.
    let single = xla.predict(&feats[200..201]);
    assert!((single[0].power_w - out[200].power_w).abs() < 1e-6);
}

#[test]
fn train_step_reduces_loss_and_beats_init() {
    let Some(dir) = artifacts_dir() else { return };
    let ds = synthesize(4096, 7, None);
    let (train, val) = ds.split(0.9);
    let init = MlpWeights::init(42);

    // Baseline: untrained validation MSE.
    let mut untrained = NativeMlp::new(init.clone());
    let mse0 = val.mse(|x| {
        let (a, b) = untrained.forward(x);
        [a, b]
    });

    let mut trainer = Trainer::new(Runtime::new(&dir).unwrap(), init).unwrap();
    let report = trainer.train(&train, &val, 12, 1).expect("training");
    assert!(report.steps > 0);
    let first = report.loss_curve.first().copied().unwrap();
    let last = report.loss_curve.last().copied().unwrap();
    assert!(
        last < first * 0.6,
        "loss did not drop: {first:.5} → {last:.5}"
    );
    assert!(
        report.val_mse < mse0 * 0.5,
        "val mse {:.5} vs untrained {:.5}",
        report.val_mse,
        mse0
    );

    // Trained weights flow back into the XLA predictor and agree with
    // the native path (full weight round-trip through PJRT).
    let mut xla = XlaMlp::new(Runtime::new(&dir).unwrap(), trainer.weights.clone()).unwrap();
    let mut native = NativeMlp::new(trainer.weights.clone());
    let feats = random_feats(32, 3);
    for (a, b) in xla.predict(&feats).iter().zip(native.predict(&feats)) {
        assert!((a.power_w - b.power_w).abs() < 1e-2);
    }
}

#[test]
fn featurize_artifact_matches_native_featurization() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = Runtime::new(&dir).unwrap();
    let batch = rt.meta.batch;
    let window = rt.meta.window;
    // Build windows: [batch, window, 4].
    let mut rng = Xoshiro256::seed_from_u64(5);
    let mut data = vec![0f32; batch * window * 4];
    for v in data.iter_mut() {
        *v = rng.next_f64() as f32;
    }
    let out = rt
        .execute_f32(
            "featurize",
            &[(&data, &[batch as i64, window as i64, 4])],
        )
        .expect("featurize exec");
    let y = &out[0];
    assert_eq!(y.len(), batch * 7);
    // Independent check of row 0: means + maxes + burstiness.
    let row: Vec<f64> = (0..window).map(|t| data[t * 4] as f64).collect();
    let mean_cpu = row.iter().sum::<f64>() / window as f64;
    assert!((y[0] as f64 - mean_cpu).abs() < 1e-5, "mean cpu");
    let max_cpu = row.iter().cloned().fold(0.0f64, f64::max);
    assert!((y[4] as f64 - max_cpu).abs() < 1e-5, "cpu peak");
    let var = row.iter().map(|x| (x - mean_cpu).powi(2)).sum::<f64>() / window as f64;
    let burst = var.sqrt() / mean_cpu;
    assert!((y[6] as f64 - burst).abs() < 1e-4, "burstiness");
}

#[test]
fn exec_count_tracks_executions() {
    let Some(dir) = artifacts_dir() else { return };
    let mut xla = XlaMlp::new(Runtime::new(&dir).unwrap(), MlpWeights::init(1)).unwrap();
    let feats = random_feats(10, 9);
    assert_eq!(xla.exec_count(), 0);
    xla.predict(&feats);
    assert_eq!(xla.exec_count(), 1);
    xla.predict(&random_feats(200, 9)); // 2 chunks
    assert_eq!(xla.exec_count(), 3);
}
