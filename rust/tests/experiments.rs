//! Shape-level assertions over the paper-reproduction experiments in
//! fast mode: every table/figure generator runs, produces the right
//! structure, and the headline directions hold.

use ecosched::exp::{self, ExpContext};
use ecosched::profile::WorkloadClass;
use std::path::PathBuf;

fn ctx() -> ExpContext {
    let mut c = ExpContext::fast();
    c.out_dir = std::env::temp_dir().join("ecosched-exp-test");
    // Oracle predictor: these tests must not require artifacts.
    c.artifacts = PathBuf::from("/nonexistent");
    c
}

#[test]
fn all_experiment_ids_run_in_fast_mode() {
    let ctx = ctx();
    for id in exp::ALL {
        assert!(exp::run(id, &ctx), "experiment {id} failed to run");
        assert!(
            ctx.out_dir.join(format!("{id}.csv")).exists(),
            "{id}.csv missing"
        );
    }
    assert!(exp::run("scale", &ctx));
    std::fs::remove_dir_all(&ctx.out_dir).ok();
}

#[test]
fn class_expectations_hold() {
    // §V-C classification claims (Eq. 2 over the phase models).
    use ecosched::cluster::flavor::MEDIUM;
    use ecosched::profile::{classify, ResourceVector};
    let mut rng = ecosched::util::rng::Xoshiro256::seed_from_u64(31);
    for (kind, expect) in ecosched::exp::classes::class_expectations() {
        let phases = ecosched::workload::phases_for(kind, 20.0, &mut rng);
        let got = classify(&ResourceVector::from_phases(&phases, &MEDIUM));
        assert_eq!(got, expect, "{kind:?}");
    }
    assert_ne!(WorkloadClass::CpuBound, WorkloadClass::IoBound);
}

#[test]
fn fig3_direction_headline() {
    // Full-size mixed campaign: savings positive, compliance 100 %.
    let mut c = ExpContext::default();
    c.artifacts = PathBuf::from("/nonexistent");
    c.seeds = vec![1];
    let pair = ecosched::exp::common::run_pair(&c, &ecosched::workload::Mix::paper(), 5);
    assert!(
        pair.savings() > 0.10,
        "mixed savings {:.1} % below band",
        pair.savings() * 100.0
    );
    assert!(pair.compliance() >= 1.0 - 1e-9);
    assert!(
        pair.jct_deviation().abs() < 0.05,
        "JCT deviation {:.1} %",
        pair.jct_deviation() * 100.0
    );
}
