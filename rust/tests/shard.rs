//! Sharded cluster state: property tests.
//!
//! * shard_count = 1 `decide_batch` is ACTION-IDENTICAL to the
//!   pre-shard (flat) path over randomized clusters and bursts — the
//!   refactor's central equivalence guarantee.
//! * top-K sufficiency: K = shard_count fan-out equals the flat
//!   sweep at any shard count; K < shard_count only ever places into
//!   the top-K shards by digest headroom.
//! * every `ShardDigest` matches recomputation from the VM inventory
//!   across randomized mutation sequences (the `check_invariants`
//!   extension).
//! * sharded campaigns complete, stay deterministic, and account
//!   per-shard actuations.

use ecosched::cluster::flavor::CATALOG;
use ecosched::cluster::{Cluster, Demand, HostId, ShardedCluster, VmState};
use ecosched::coordinator::{make_policy, CampaignConfig, Coordinator};
use ecosched::predict::{MlpWeights, NativeMlp};
use ecosched::profile::ResourceVector;
use ecosched::sched::{
    Decision, EnergyAware, EnergyAwareParams, PlacementPolicy, PlacementRequest, PowerCapParams,
    ScheduleContext,
};
use ecosched::util::rng::Xoshiro256;
use ecosched::workload::{flavor_for, Arrivals, JobId, Mix, TraceSpec};

fn for_all_seeds(n: u64, f: impl Fn(u64)) {
    for seed in 1..=n {
        f(seed);
    }
}

/// Randomized cluster: placed VMs with profiled demands, random host
/// load, occasionally a powered-off host.
fn random_cluster(rng: &mut Xoshiro256, n_hosts: usize) -> Cluster {
    let mut c = Cluster::homogeneous(n_hosts);
    for j in 0..(2 * n_hosts) {
        let flavor = CATALOG[rng.range(0, 3)];
        let feas = c.feasible_hosts(&flavor);
        if feas.is_empty() {
            continue;
        }
        let host = feas[rng.range(0, feas.len())];
        let vm = c.create_vm(flavor, JobId(j as u64), 0.0);
        c.place_vm(vm, host).unwrap();
        if rng.chance(0.7) {
            c.set_expected_demand(
                vm,
                Demand {
                    cpu: rng.uniform(0.0, 6.0),
                    mem_gb: rng.uniform(0.0, 12.0),
                    disk_mbps: rng.uniform(0.0, 150.0),
                    net_mbps: rng.uniform(0.0, 40.0),
                },
            );
        }
    }
    for h in 0..n_hosts {
        c.host_mut(HostId(h)).demand = Demand {
            cpu: rng.uniform(0.0, 24.0),
            mem_gb: rng.uniform(0.0, 40.0),
            disk_mbps: rng.uniform(0.0, 500.0),
            net_mbps: rng.uniform(0.0, 80.0),
        };
    }
    if rng.chance(0.4) {
        let empty: Vec<HostId> = c
            .hosts
            .iter()
            .filter(|h| h.vms.is_empty() && h.state.is_on())
            .map(|h| h.id)
            .collect();
        if !empty.is_empty() {
            let h = empty[rng.range(0, empty.len())];
            c.host_mut(h).power_off(0.0);
            c.advance_power_states(1000.0);
        }
    }
    c
}

/// Placement requests from a fixed-seed trace.
fn requests(n: usize, seed: u64) -> Vec<PlacementRequest> {
    TraceSpec {
        mix: Mix::paper(),
        n_jobs: n,
        arrivals: Arrivals::Poisson { mean_gap: 30.0 },
        horizon: 7200.0,
    }
    .generate(seed)
    .iter()
    .map(|job| {
        let flavor = flavor_for(job.kind);
        PlacementRequest {
            job: job.id,
            flavor,
            vector: ResourceVector::from_phases(&job.phases, &flavor),
            remaining_solo: job.solo_duration(),
            avoid_rack: None,
        }
    })
    .collect()
}

fn mlp_policy(seed: u64, params: EnergyAwareParams) -> EnergyAware {
    EnergyAware::new(Box::new(NativeMlp::new(MlpWeights::init(seed))), params)
}

#[test]
fn prop_shard1_decide_batch_matches_preshard_path() {
    // The acceptance gate: at shard_count = 1 the fan-out path must
    // produce bit-identical placement actions to the flat sweep,
    // whatever the cluster looks like.
    for_all_seeds(15, |seed| {
        let mut rng = Xoshiro256::seed_from_u64(seed ^ 0x5AAD);
        let n_hosts = 3 + rng.range(0, 6);
        let cluster = random_cluster(&mut rng, n_hosts);
        let reqs = requests(10, seed);
        let flat_ctx = ScheduleContext::new(0.0, &cluster);
        let mut flat = mlp_policy(seed, EnergyAwareParams::default());
        let a = flat.decide_batch(&reqs, &flat_ctx);
        let sc = ShardedCluster::new(cluster.clone(), 1);
        sc.check_invariants().unwrap();
        let shard_ctx = ScheduleContext::new(0.0, &sc).with_shards(&sc);
        let mut sharded = mlp_policy(seed, EnergyAwareParams::default());
        let b = sharded.decide_batch(&reqs, &shard_ctx);
        assert_eq!(a, b, "seed {seed}: sharded {b:?} != flat {a:?}");
    });
}

#[test]
fn prop_full_coverage_topk_matches_preshard_path() {
    // K >= shard_count: every shard is scored, so the merged argmin
    // must equal the flat sweep at ANY shard count (the merge is
    // lexicographic (energy, host id) — shard order cannot matter).
    for_all_seeds(10, |seed| {
        let mut rng = Xoshiro256::seed_from_u64(seed ^ 0x70FF);
        let n_hosts = 4 + rng.range(0, 9);
        let cluster = random_cluster(&mut rng, n_hosts);
        let reqs = requests(8, seed);
        let flat_ctx = ScheduleContext::new(0.0, &cluster);
        let mut flat = mlp_policy(seed, EnergyAwareParams::default());
        let a = flat.decide_batch(&reqs, &flat_ctx);
        for shards in [2usize, 4] {
            let sc = ShardedCluster::new(cluster.clone(), shards);
            let shard_ctx = ScheduleContext::new(0.0, &sc).with_shards(&sc);
            let mut sharded = mlp_policy(
                seed,
                EnergyAwareParams {
                    top_k_shards: shards,
                    ..Default::default()
                },
            );
            let b = sharded.decide_batch(&reqs, &shard_ctx);
            assert_eq!(a, b, "seed {seed} shards {shards}");
        }
    });
}

#[test]
fn topk_routing_places_only_into_ranked_shards() {
    // K < shard_count: placements must land inside the top-K shards
    // by digest headroom — the sufficiency property that makes the
    // sub-linear bench meaningful.
    for_all_seeds(10, |seed| {
        let mut rng = Xoshiro256::seed_from_u64(seed ^ 0xBEEF);
        let cluster = random_cluster(&mut rng, 12);
        let reqs = requests(8, seed);
        let sc = ShardedCluster::new(cluster, 4);
        // Mirror the routing order: headroom score descending, lowest
        // shard id on ties.
        let mut order: Vec<usize> = (0..4).collect();
        order.sort_by(|&a, &b| {
            sc.digest(b)
                .headroom_score()
                .partial_cmp(&sc.digest(a).headroom_score())
                .unwrap()
                .then(a.cmp(&b))
        });
        let allowed: Vec<HostId> = order[..2]
            .iter()
            .flat_map(|&s| sc.members(s).iter().copied())
            .collect();
        let shard_ctx = ScheduleContext::new(0.0, &sc).with_shards(&sc);
        let mut policy = mlp_policy(
            seed,
            EnergyAwareParams {
                top_k_shards: 2,
                ..Default::default()
            },
        );
        for d in policy.decide_batch(&reqs, &shard_ctx) {
            if let Decision::Place(h) = d {
                assert!(
                    allowed.contains(&h),
                    "seed {seed}: {h} outside the top-2 shards {allowed:?}"
                );
            }
        }
    });
}

#[test]
fn prop_shard_digests_survive_random_mutation_sequences() {
    // The check_invariants extension: every incrementally-maintained
    // ShardDigest matches recomputation from the VM inventory after
    // arbitrary mutation sequences through the shard handles.
    for_all_seeds(12, |seed| {
        let mut rng = Xoshiro256::seed_from_u64(seed ^ 0xD16E);
        for shard_count in [1usize, 2, 4] {
            let mut sc = ShardedCluster::new(Cluster::homogeneous(5), shard_count);
            let mut live: Vec<ecosched::cluster::VmId> = Vec::new();
            let mut t = 0.0;
            for step in 0..100 {
                t += rng.uniform(0.1, 5.0);
                sc.advance_power_states(t);
                match rng.range(0, 6) {
                    0 => {
                        let flavor = CATALOG[rng.range(0, 3)];
                        let feas = sc.feasible_hosts(&flavor);
                        if !feas.is_empty() {
                            let host = feas[rng.range(0, feas.len())];
                            let vm = sc.create_vm(flavor, JobId(step as u64), t);
                            sc.place_vm(vm, host).unwrap();
                            sc.set_expected_demand(
                                vm,
                                Demand {
                                    cpu: rng.uniform(0.0, 8.0),
                                    mem_gb: rng.uniform(0.0, 16.0),
                                    disk_mbps: rng.uniform(0.0, 200.0),
                                    net_mbps: rng.uniform(0.0, 60.0),
                                },
                            );
                            live.push(vm);
                        }
                    }
                    1 => {
                        if !live.is_empty() {
                            let vm = live[rng.range(0, live.len())];
                            if matches!(sc.vms[&vm].state, VmState::Running) {
                                let flavor = sc.vms[&vm].flavor;
                                let from = sc.vms[&vm].host.unwrap();
                                let targets: Vec<HostId> = sc
                                    .feasible_hosts(&flavor)
                                    .into_iter()
                                    .filter(|&h| h != from)
                                    .collect();
                                if !targets.is_empty() {
                                    let to = targets[rng.range(0, targets.len())];
                                    let _ = sc.start_migration(vm, to, t, 50.0);
                                }
                            }
                        }
                    }
                    2 => {
                        let migrating: Vec<_> = live
                            .iter()
                            .copied()
                            .filter(|vm| {
                                matches!(sc.vms[vm].state, VmState::Migrating { .. })
                            })
                            .collect();
                        for vm in migrating {
                            sc.finish_migration(vm);
                        }
                    }
                    3 => {
                        // Re-profile a running VM (class may change).
                        if !live.is_empty() {
                            let vm = live[rng.range(0, live.len())];
                            if sc.vms[&vm].is_active() {
                                sc.set_expected_demand(
                                    vm,
                                    Demand {
                                        cpu: rng.uniform(0.0, 10.0),
                                        mem_gb: rng.uniform(0.0, 14.0),
                                        disk_mbps: rng.uniform(0.0, 300.0),
                                        net_mbps: rng.uniform(0.0, 50.0),
                                    },
                                );
                            }
                        }
                    }
                    4 => {
                        // Power transitions through the shard handles.
                        let empty_on: Vec<HostId> = sc
                            .hosts
                            .iter()
                            .filter(|h| h.vms.is_empty() && h.state.is_on())
                            .map(|h| h.id)
                            .collect();
                        if sc.hosts_on() > 1 && !empty_on.is_empty() {
                            sc.power_off(empty_on[rng.range(0, empty_on.len())], t);
                        }
                        let off: Vec<HostId> = sc
                            .hosts
                            .iter()
                            .filter(|h| h.state.is_off())
                            .map(|h| h.id)
                            .collect();
                        if !off.is_empty() && rng.chance(0.5) {
                            sc.power_on(off[rng.range(0, off.len())], t);
                        }
                    }
                    _ => {
                        if !live.is_empty() {
                            let idx = rng.range(0, live.len());
                            let vm = live[idx];
                            if matches!(sc.vms[&vm].state, VmState::Running) {
                                sc.terminate_vm(vm);
                                live.swap_remove(idx);
                            }
                        }
                    }
                }
                sc.check_invariants().unwrap_or_else(|e| {
                    panic!("seed {seed} shards {shard_count} step {step}: {e}")
                });
            }
        }
    });
}

#[test]
fn sharded_campaign_completes_and_accounts_per_shard() {
    let trace = TraceSpec {
        mix: Mix::paper(),
        n_jobs: 12,
        arrivals: Arrivals::Poisson { mean_gap: 40.0 },
        horizon: 3600.0,
    }
    .generate(9);
    let run = || {
        let mut coord = Coordinator::new(
            CampaignConfig {
                seed: 9,
                shard_count: 4,
                ..Default::default()
            },
            make_policy("energy_aware").unwrap(),
        );
        coord.run(trace.clone())
    };
    let (a, b) = (run(), run());
    assert_eq!(a.jobs.len(), 12, "all jobs complete under sharding");
    assert_eq!(a.sla_violations, 0);
    // Per-shard accounting: every job placed exactly once, somewhere.
    assert_eq!(a.per_shard.len(), 4);
    let placements: u64 = a.per_shard.iter().map(|s| s.placements).sum();
    assert_eq!(placements, 12);
    let (migrations_in, migrations_out) = a
        .per_shard
        .iter()
        .fold((0u64, 0u64), |(i, o), s| (i + s.migrations_in, o + s.migrations_out));
    assert_eq!(migrations_in, a.migrations);
    assert_eq!(migrations_out, a.migrations);
    // Sharded campaigns stay deterministic.
    assert_eq!(a.energy_j, b.energy_j);
    assert_eq!(a.makespan, b.makespan);
    assert_eq!(a.migrations, b.migrations);
}

#[test]
fn sharded_campaign_with_power_cap_completes() {
    let trace = TraceSpec {
        mix: Mix::paper(),
        n_jobs: 12,
        arrivals: Arrivals::Poisson { mean_gap: 40.0 },
        horizon: 3600.0,
    }
    .generate(11);
    let mut coord = Coordinator::new(
        CampaignConfig {
            seed: 11,
            shard_count: 2,
            power_cap: Some(PowerCapParams {
                budget_w: 700.0,
                ..Default::default()
            }),
            ..Default::default()
        },
        make_policy("energy_aware").unwrap(),
    );
    let r = coord.run(trace);
    assert_eq!(r.jobs.len(), 12, "capped campaign must still finish");
}
