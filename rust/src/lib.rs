//! # ecosched
//!
//! A production-shaped reproduction of *"Big Data Workload Profiling for
//! Energy-Aware Cloud Resource Management"*: a workload-aware scheduling
//! framework that profiles CPU/memory/disk/network behaviour of big-data
//! jobs (Hadoop MapReduce, Spark MLlib, ETL) and uses a learned
//! prediction engine to drive energy-efficient VM placement and adaptive
//! consolidation, without violating SLAs.
//!
//! The stack is three layers:
//! * **L3 (this crate)** — coordinator, schedulers, cluster/power/energy
//!   simulation, profiling, SLA tracking, experiments.
//! * **L2 (python/compile/model.py)** — the prediction engine `f_θ`
//!   (Eq. 4) as a JAX MLP, AOT-lowered to HLO text at build time.
//! * **L1 (python/compile/kernels/)** — Pallas kernels for batched
//!   placement scoring and telemetry featurization.
//!
//! Python never runs at decision time: [`runtime`] loads
//! `artifacts/*.hlo.txt` through the PJRT CPU client (`xla` crate).

pub mod cli;
pub mod cluster;
pub mod coordinator;
pub mod exp;
pub mod predict;
pub mod profile;
pub mod runtime;
pub mod sched;
pub mod sim;
pub mod sla;
pub mod util;
pub mod workload;
