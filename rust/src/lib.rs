//! # ecosched
//!
//! A production-shaped reproduction of *"Big Data Workload Profiling for
//! Energy-Aware Cloud Resource Management"*: a workload-aware scheduling
//! framework that profiles CPU/memory/disk/network behaviour of big-data
//! jobs (Hadoop MapReduce, Spark MLlib, ETL) and uses a learned
//! prediction engine to drive energy-efficient VM placement and adaptive
//! consolidation, without violating SLAs.
//!
//! The stack is three layers:
//! * **L3 (this crate)** — coordinator, schedulers, cluster/power/energy
//!   simulation, profiling, SLA tracking, experiments.
//! * **L2 (python/compile/model.py)** — the prediction engine `f_θ`
//!   (Eq. 4) as a JAX MLP, AOT-lowered to HLO text at build time.
//! * **L1 (python/compile/kernels/)** — Pallas kernels for batched
//!   placement scoring and telemetry featurization.
//!
//! ## The L3 scheduling API
//!
//! Scheduling flows through three abstractions in [`sched`]:
//!
//! 1. [`sched::ScheduleContext`] — one read-only view (cluster +
//!    telemetry window + history + sim clock) assembled by the
//!    coordinator at each decision point.
//! 2. [`sched::PlacementPolicy::decide_batch`] — the coordinator's
//!    only placement entry point: every same-instant submit burst and
//!    every deferred-queue drain is decided as a batch against one
//!    frozen context. The energy-aware policy prunes hosts once per
//!    batch through [`cluster::HostView`] snapshots (backed by the
//!    cluster's O(1) incremental expected-load cache), builds the
//!    full (request × feasible-host) feature matrix in a reusable
//!    scoring arena, and scores it with a single
//!    [`predict::EnergyPredictor::predict_into`] invocation — exactly
//!    the `[B, 16]` batch the L1 `score_hosts` kernel streams through
//!    the MXU as `(B×16)·(16×64)·(64×32)·(32×2)`. The native
//!    predictor executes that shape as blocked, arena-backed matmuls
//!    (`NativeMlp::forward_batch`), bit-identical to the row-by-row
//!    path; the sequential per-job loop is the trait's default
//!    fallback and is bit-identical by contract.
//! 3. [`sched::ControlLoop`] — the periodic scans (adaptive
//!    consolidation, DVFS governor, future loops such as carbon-aware
//!    capping) unified behind one trait that emits
//!    [`sched::ControlAction`]s; loops borrow the policy's predictor
//!    through an explicit [`sched::ScoringHandle`] — no downcasts.
//!    The consolidation scan scores its whole (donor VM × target)
//!    matrix with ONE predictor call per scan, same arena discipline
//!    as placement.
//!
//! Python never runs at decision time: [`runtime`] loads
//! `artifacts/*.hlo.txt` through the PJRT CPU client (`xla` crate).
//! The offline build links an API-compatible stub instead; the
//! predictor then falls back to the native-Rust MLP when trained
//! weights exist on disk, else to the analytic oracle.

pub mod cli;
pub mod cluster;
pub mod coordinator;
pub mod exp;
pub mod predict;
pub mod profile;
pub mod runtime;
pub mod sched;
pub mod sim;
pub mod sla;
pub mod util;
pub mod workload;
