//! # ecosched
//!
//! A production-shaped reproduction of *"Big Data Workload Profiling for
//! Energy-Aware Cloud Resource Management"*: a workload-aware scheduling
//! framework that profiles CPU/memory/disk/network behaviour of big-data
//! jobs (Hadoop MapReduce, Spark MLlib, ETL) and uses a learned
//! prediction engine to drive energy-efficient VM placement and adaptive
//! consolidation, without violating SLAs.
//!
//! The stack is three layers:
//! * **L3 (this crate)** — coordinator, schedulers, cluster/power/energy
//!   simulation, profiling, SLA tracking, experiments.
//! * **L2 (python/compile/model.py)** — the prediction engine `f_θ`
//!   (Eq. 4) as a JAX MLP, AOT-lowered to HLO text at build time.
//! * **L1 (python/compile/kernels/)** — Pallas kernels for batched
//!   placement scoring and telemetry featurization.
//!
//! ## The L3 scheduling API
//!
//! Scheduling flows through four abstractions in [`sched`] and
//! [`cluster`]:
//!
//! 1. [`cluster::ShardedCluster`] — cluster state behind a fixed
//!    power-of-two shard map (hash of host id). Each shard owns its
//!    hosts' view snapshots and caches; a thin per-shard
//!    [`cluster::ShardDigest`] (headroom, powered-on count, per-class
//!    expected load) is maintained incrementally by the mutation
//!    handles and read cross-shard without touching shard interiors.
//!    `shard_count = 1` (the default) reproduces the unsharded
//!    scheduler bit for bit — a property test pins this down.
//! 2. [`sched::ScheduleContext`] — one read-only view (cluster +
//!    telemetry window + history + sim clock + shard layer) assembled
//!    by the coordinator at each decision point; `context.shard(s)`
//!    yields a per-shard lens with the same read API.
//! 3. [`sched::PlacementPolicy::decide_batch`] — the coordinator's
//!    only placement entry point: every same-instant submit burst and
//!    every deferred-queue drain is decided as a batch against one
//!    frozen context. The energy-aware policy prunes hosts once per
//!    batch through [`cluster::HostView`] snapshots (backed by the
//!    cluster's O(1) incremental expected-load cache), builds the
//!    full (request × feasible-host) feature matrix in a reusable
//!    scoring arena, and scores it with a single
//!    [`predict::EnergyPredictor::predict_into`] invocation — exactly
//!    the `[B, 16]` batch the L1 `score_hosts` kernel streams through
//!    the MXU as `(B×16)·(16×64)·(64×32)·(32×2)`. On a sharded
//!    context the burst fans out to the top-K shards by digest
//!    headroom (one predictor call per shard, winners merged by
//!    `(energy, host id)`), bounding per-decision work by the K
//!    largest shards instead of the fleet. The native predictor
//!    executes each batch as blocked, arena-backed matmuls
//!    (`NativeMlp::forward_batch`), bit-identical to the row-by-row
//!    path; the sequential per-job loop is the trait's default
//!    fallback and is bit-identical by contract.
//! 4. [`sched::ControlLoop`] — the periodic scans (adaptive
//!    consolidation, DVFS governor, cluster power capping) unified
//!    behind one trait that emits [`sched::ControlAction`]s; loops
//!    borrow the policy's predictor through an explicit
//!    [`sched::ScoringHandle`] — no downcasts. Scans are per-shard
//!    passes: consolidation nominates at most one Eq. 8 donor per
//!    shard and scores its (donor VM × target) matrix with ONE
//!    predictor call, overflowing to the best remote shard (by
//!    digest) under a bounded cross-shard budget;
//!    [`sched::PowerCapLoop`] holds fleet draw under a watt budget by
//!    walking shards down the DVFS ladder, I/O-bound hosts first.
//!
//! ## Concurrency
//!
//! Per-shard work executes on a **persistent** [`runtime::WorkerPool`]
//! (`CampaignConfig::worker_threads`, default 1 = serial; std-only —
//! long-lived threads + `mpsc` channels). Worker threads spawn once
//! per campaign (owned by `CampaignState`, joined on drop); fan-outs
//! dispatch jobs to stable affinity workers
//! ([`runtime::WorkerPool::worker_for`]: a SplitMix64 mix of the
//! shard id modulo the width, so strided shard selections don't
//! alias onto one worker), so a worker's caches keep seeing the same
//! shards'
//! views scan after scan and a fan-out costs channel hops, not thread
//! spawns.
//!
//! The ownership rule: **workers own their cached scoring state — a
//! predictor clone ([`predict::EnergyPredictor::try_clone`]) plus the
//! feature/candidate/view/prediction arenas — persisted in their
//! [`runtime::WorkerSlot`] across `decide_batch`, consolidation,
//! DVFS, and power-cap fan-outs; the coordinator thread is the only
//! writer of cluster state and the only epoch-bumper.** Cached clones
//! invalidate by weight epoch
//! ([`predict::EnergyPredictor::weight_epoch`], advanced by
//! `set_weights`/retraining): the coordinator stages a fresh clone
//! only for workers whose cached epoch is stale, so steady-state
//! fan-outs re-clone zero times and a retrain re-clones exactly once
//! per worker — a stale clone can never score against new weights
//! (asserted at fetch time). Small bursts skip dispatch entirely
//! (`EnergyAwareParams::inline_burst_rows`) because the channel
//! round-trip would cost more than the scoring it parallelizes.
//!
//! Scans and sweeps are pure planning over a frozen context, so
//! sharing it immutably is safe by construction, and per-shard
//! results merge deterministically — placement winners by
//! lexicographic `(energy, host id)` (a total order), control actions
//! in ascending shard order — so worker count can never change a
//! decision: `worker_threads = 1` is the behavioral oracle and the
//! property tests in `rust/tests/pool.rs` (run in CI at both 1 and 8
//! workers) pin parallel against it, including across mid-campaign
//! `set_weights` calls. Shard digests flow back to the coordinator
//! over the pool's channel at report time. A panicking worker poisons
//! the pool: the failing fan-out reports the panic and every later
//! fan-out errors loudly (`PoolError::Poisoned`) instead of
//! deadlocking or planning from half-poisoned state. The
//! spawn-per-call [`runtime::ShardPool`] survives as the bench
//! baseline (`benches/bench_pool.rs` measures what persistence buys).
//!
//! Python never runs at decision time: [`runtime`] loads
//! `artifacts/*.hlo.txt` through the PJRT CPU client (`xla` crate).
//! The offline build links an API-compatible stub instead; the
//! predictor then falls back to the native-Rust MLP when trained
//! weights exist on disk, else to the analytic oracle.

pub mod cli;
pub mod cluster;
pub mod coordinator;
pub mod exp;
pub mod predict;
pub mod profile;
pub mod runtime;
pub mod sched;
pub mod sim;
pub mod sla;
pub mod util;
pub mod workload;
