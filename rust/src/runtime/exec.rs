//! Executable cache: compile each HLO artifact once, run many times.

use crate::util::json::Json;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Runtime errors.
#[derive(Debug)]
pub enum RuntimeError {
    ArtifactMissing(PathBuf),
    BadMeta(String),
    Xla(String),
}

impl std::fmt::Display for RuntimeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RuntimeError::ArtifactMissing(p) => {
                write!(f, "artifact not found: {}", p.display())
            }
            RuntimeError::BadMeta(msg) => write!(f, "artifact metadata invalid: {msg}"),
            RuntimeError::Xla(msg) => write!(f, "xla error: {msg}"),
        }
    }
}

impl std::error::Error for RuntimeError {}

impl From<xla::Error> for RuntimeError {
    fn from(e: xla::Error) -> Self {
        RuntimeError::Xla(e.to_string())
    }
}

/// Metadata emitted by `python/compile/aot.py` alongside the HLO text —
/// batch size, feature dim, hidden sizes — so L3 never hardcodes shapes
/// that L2 owns.
#[derive(Debug, Clone)]
pub struct ModelMeta {
    /// Fixed scoring batch (rows are padded up to this).
    pub batch: usize,
    /// Feature dimension (must equal `profile::FEAT_DIM`).
    pub feat_dim: usize,
    /// Hidden layer widths.
    pub hidden: Vec<usize>,
    /// Output dim (2: marginal power, slowdown risk).
    pub out_dim: usize,
    /// Telemetry featurize window length.
    pub window: usize,
    /// Training minibatch size baked into train_step.hlo.
    pub train_batch: usize,
    /// Adam learning rate baked into train_step.hlo.
    pub lr: f64,
}

impl ModelMeta {
    pub fn load(dir: &Path) -> Result<ModelMeta, RuntimeError> {
        let path = dir.join("meta.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|_| RuntimeError::ArtifactMissing(path.clone()))?;
        let j = Json::parse(&text).map_err(|e| RuntimeError::BadMeta(e.to_string()))?;
        let num = |k: &str| -> Result<f64, RuntimeError> {
            j.get(k)
                .and_then(Json::as_f64)
                .ok_or_else(|| RuntimeError::BadMeta(format!("missing key {k}")))
        };
        let hidden = j
            .get("hidden")
            .and_then(Json::as_f64_vec)
            .ok_or_else(|| RuntimeError::BadMeta("missing key hidden".into()))?;
        Ok(ModelMeta {
            batch: num("batch")? as usize,
            feat_dim: num("feat_dim")? as usize,
            hidden: hidden.into_iter().map(|x| x as usize).collect(),
            out_dim: num("out_dim")? as usize,
            window: num("window")? as usize,
            train_batch: num("train_batch")? as usize,
            lr: num("lr")?,
        })
    }
}

/// The PJRT runtime: one CPU client, a cache of compiled executables.
pub struct Runtime {
    client: xla::PjRtClient,
    executables: BTreeMap<String, xla::PjRtLoadedExecutable>,
    pub meta: ModelMeta,
    artifacts_dir: PathBuf,
    /// Executions performed (overhead accounting).
    pub exec_count: u64,
}

impl Runtime {
    /// Create a runtime over an artifacts directory. Compiles nothing
    /// yet; executables load lazily (or via [`Runtime::preload`]).
    pub fn new(artifacts_dir: &Path) -> Result<Runtime, RuntimeError> {
        let meta = ModelMeta::load(artifacts_dir)?;
        if meta.feat_dim != crate::profile::FEAT_DIM {
            return Err(RuntimeError::BadMeta(format!(
                "artifact feat_dim {} != crate FEAT_DIM {}",
                meta.feat_dim,
                crate::profile::FEAT_DIM
            )));
        }
        let client = xla::PjRtClient::cpu()?;
        Ok(Runtime {
            client,
            executables: BTreeMap::new(),
            meta,
            artifacts_dir: artifacts_dir.to_path_buf(),
            exec_count: 0,
        })
    }

    /// Default artifacts dir: `$ECOSCHED_ARTIFACTS` or `artifacts/`.
    pub fn artifacts_dir_default() -> PathBuf {
        std::env::var("ECOSCHED_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }

    /// Compile-and-cache one artifact by stem name (`predict`,
    /// `train_step`, `featurize`).
    pub fn load(&mut self, name: &str) -> Result<(), RuntimeError> {
        if self.executables.contains_key(name) {
            return Ok(());
        }
        let path = self.artifacts_dir.join(format!("{name}.hlo.txt"));
        if !path.exists() {
            return Err(RuntimeError::ArtifactMissing(path));
        }
        let proto = xla::HloModuleProto::from_text_file(&path)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        self.executables.insert(name.to_string(), exe);
        log::info!("compiled artifact {name} from {}", path.display());
        Ok(())
    }

    /// Load every standard artifact up front.
    pub fn preload(&mut self) -> Result<(), RuntimeError> {
        for name in ["predict", "train_step", "featurize"] {
            self.load(name)?;
        }
        Ok(())
    }

    /// Execute a named artifact with f32 tensor inputs given as
    /// (data, shape) pairs. Returns the flattened f32 outputs of the
    /// result tuple, in order.
    pub fn execute_f32(
        &mut self,
        name: &str,
        inputs: &[(&[f32], &[i64])],
    ) -> Result<Vec<Vec<f32>>, RuntimeError> {
        self.load(name)?;
        let exe = self.executables.get(name).expect("just loaded");
        let mut literals = Vec::with_capacity(inputs.len());
        for (data, shape) in inputs {
            let expect: i64 = shape.iter().product();
            assert_eq!(
                expect as usize,
                data.len(),
                "input shape {shape:?} vs data len {}",
                data.len()
            );
            literals.push(xla::Literal::vec1(data).reshape(shape)?);
        }
        let result = exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        self.exec_count += 1;
        // jax lowering uses return_tuple=True: the root is a tuple.
        let parts = result.to_tuple()?;
        let mut out = Vec::with_capacity(parts.len());
        for p in parts {
            out.push(p.to_vec::<f32>()?);
        }
        Ok(out)
    }

    pub fn is_loaded(&self, name: &str) -> bool {
        self.executables.contains_key(name)
    }

    /// Upload an f32 tensor to the device once; reuse across many
    /// executions (perf: model parameters don't change per call, so
    /// re-uploading them on every predict wastes most of the dispatch
    /// budget — see EXPERIMENTS.md §Perf).
    pub fn buffer_f32(
        &self,
        data: &[f32],
        dims: &[usize],
    ) -> Result<xla::PjRtBuffer, RuntimeError> {
        Ok(self.client.buffer_from_host_buffer(data, dims, None)?)
    }

    /// Execute a named artifact over pre-staged device buffers.
    /// Returns the flattened f32 outputs of the result tuple.
    pub fn execute_buffers(
        &mut self,
        name: &str,
        args: &[&xla::PjRtBuffer],
    ) -> Result<Vec<Vec<f32>>, RuntimeError> {
        self.load(name)?;
        let exe = self.executables.get(name).expect("just loaded");
        let result = exe.execute_b::<&xla::PjRtBuffer>(args)?[0][0].to_literal_sync()?;
        self.exec_count += 1;
        let parts = result.to_tuple()?;
        let mut out = Vec::with_capacity(parts.len());
        for p in parts {
            out.push(p.to_vec::<f32>()?);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Full runtime tests live in rust/tests/runtime_xla.rs (they need
    // `make artifacts` to have run). Here: metadata parsing only.

    #[test]
    fn meta_parses() {
        let dir = std::env::temp_dir().join("ecosched-meta-test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("meta.json"),
            r#"{"batch":128,"feat_dim":16,"hidden":[64,32],"out_dim":2,"window":24,"train_batch":256,"lr":0.001}"#,
        )
        .unwrap();
        let m = ModelMeta::load(&dir).unwrap();
        assert_eq!(m.batch, 128);
        assert_eq!(m.hidden, vec![64, 32]);
        assert_eq!(m.out_dim, 2);
        assert!((m.lr - 0.001).abs() < 1e-12);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn meta_missing_dir_errors() {
        let err = ModelMeta::load(Path::new("/nonexistent-ecosched")).unwrap_err();
        assert!(matches!(err, RuntimeError::ArtifactMissing(_)));
    }

    #[test]
    fn meta_bad_json_errors() {
        let dir = std::env::temp_dir().join("ecosched-meta-bad");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("meta.json"), "{not json").unwrap();
        assert!(matches!(
            ModelMeta::load(&dir).unwrap_err(),
            RuntimeError::BadMeta(_)
        ));
        std::fs::write(dir.join("meta.json"), r#"{"batch":1}"#).unwrap();
        assert!(matches!(
            ModelMeta::load(&dir).unwrap_err(),
            RuntimeError::BadMeta(_)
        ));
        std::fs::remove_dir_all(&dir).ok();
    }
}
