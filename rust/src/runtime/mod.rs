//! PJRT runtime — loads the AOT artifacts (`artifacts/*.hlo.txt`,
//! produced once by `make artifacts`) and executes them from the
//! decision path. Python is never involved at runtime.
//!
//! Interchange is **HLO text**, not serialized `HloModuleProto`:
//! jax ≥ 0.5 emits protos with 64-bit instruction ids that the crate's
//! XLA (xla_extension 0.5.1) rejects; the text parser reassigns ids.
//!
//! Also home to [`shard_pool`], the std-only worker pool the sharded
//! scheduling pipeline fans per-shard work out on.

mod exec;
pub mod shard_pool;

pub use exec::{ModelMeta, Runtime, RuntimeError};
pub use shard_pool::{PoolError, ShardPool};
