//! PJRT runtime — loads the AOT artifacts (`artifacts/*.hlo.txt`,
//! produced once by `make artifacts`) and executes them from the
//! decision path. Python is never involved at runtime.
//!
//! Interchange is **HLO text**, not serialized `HloModuleProto`:
//! jax ≥ 0.5 emits protos with 64-bit instruction ids that the crate's
//! XLA (xla_extension 0.5.1) rejects; the text parser reassigns ids.
//!
//! Also home to [`worker_pool`] — the persistent, std-only worker
//! runtime the sharded scheduling pipeline fans per-shard work out on
//! (long-lived threads, epoch-cached per-worker state, shard
//! affinity) — and [`shard_pool`], the spawn-per-call reference
//! implementation it superseded (kept as the bench baseline).

mod exec;
pub mod shard_pool;
pub mod worker_pool;

pub use exec::{ModelMeta, Runtime, RuntimeError};
pub use shard_pool::{PoolError, ShardPool};
pub use worker_pool::{WorkerPool, WorkerSlot};
