//! Persistent shard worker runtime — long-lived worker threads
//! spawned **once per campaign** (owned by `CampaignState`, joined on
//! drop), with job dispatch over per-worker channels and per-worker
//! state that **survives across fan-outs**. This replaces the
//! spawn-per-call [`crate::runtime::ShardPool`] design on every hot
//! path: a fan-out costs two channel hops per job instead of
//! `min(workers, jobs)` thread spawns plus a full rebuild of every
//! worker's predictor clone and scoring arenas.
//!
//! # Worker-owned state
//!
//! Each worker thread owns a [`WorkerSlot`]: a typed bag of state that
//! persists across dispatches (keyed by `TypeId`, so independent
//! subsystems — the placement sweep, the consolidation scan — keep
//! separate entries without knowing about each other). The scheduling
//! layer caches a predictor clone plus its feature/candidate/span/
//! view/prediction arenas there (`sched::worker_score`), invalidated
//! by **weight epoch** ([`crate::predict::EnergyPredictor::weight_epoch`]):
//! the coordinator stages a fresh clone for a worker only when that
//! worker's cached epoch is stale, so steady-state fan-outs re-clone
//! **zero** times and a retrain re-clones exactly once per worker.
//! The pool keeps the coordinator-side mirror of each worker's cached
//! epoch ([`WorkerPool::cached_state`]); only dispatching code updates
//! it, which is what keeps mirror and worker state consistent — the
//! coordinator thread is the only writer and the only epoch-bumper.
//!
//! # Shard affinity
//!
//! Jobs are dispatched with an affinity key (the shard index); key `k`
//! always runs on the same worker ([`WorkerPool::worker_for`] — a
//! SplitMix64 mix of the key modulo the width, so strided shard
//! selections don't alias onto one worker).
//! Shard→worker assignment is therefore stable across fan-outs: a
//! worker's arenas and cache lines keep seeing the same shards' views
//! scan after scan, instead of whichever shard it happened to pull
//! off a shared queue. Jobs for one worker run FIFO in dispatch
//! order.
//!
//! # Determinism contract
//!
//! Unchanged from the spawn-per-call pool: results come back indexed
//! by job, callers merge by total orders (lexicographic
//! `(energy, host id)` for placement winners, ascending shard order
//! for control actions), so worker count and affinity layout are
//! latency-only. `width = 1` builds no threads at all — every
//! consumer takes its inline serial path, the behavioral oracle the
//! property tests in `rust/tests/pool.rs` pin the pooled paths
//! against.
//!
//! # Self-healing panic recovery
//!
//! A job that panics is caught on the worker (`catch_unwind`; every
//! dispatched job sends exactly one message, so the collect loop
//! always terminates) and the in-flight dispatch returns
//! [`PoolError::WorkerPanicked`] **once**. The pool then heals
//! instead of dying: the panicked worker's thread is retired (its
//! [`WorkerSlot`] may hold state a half-finished job corrupted) and a
//! fresh thread with an empty slot is spawned at the same index, with
//! the coordinator-side epoch mirror zeroed so the next fan-out
//! re-stages scoring state for exactly the respawned worker through
//! the ordinary epoch-cache path (`stage_installs`). The *next*
//! dispatch succeeds. [`PoolError::Poisoned`] survives only for the
//! unrecoverable cases: the respawn itself fails, or a worker thread
//! vanishes without reporting (process teardown).

use crate::cluster::shard::splitmix64;
use crate::cluster::{DigestSnapshot, ShardDigest, ShardedCluster};
use std::any::{Any, TypeId};
use std::collections::{BTreeMap, BTreeSet};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Mutex};

pub use crate::runtime::shard_pool::PoolError;
use crate::runtime::shard_pool::{env_workers, panic_message};

/// Per-worker persistent state: lives on the worker thread for the
/// pool's whole lifetime, keyed by type so unrelated subsystems can
/// each cache their own entry. The scheduling layer stores its cached
/// predictor clone and scoring arenas here.
pub struct WorkerSlot {
    index: usize,
    state: BTreeMap<TypeId, Box<dyn Any + Send>>,
}

impl WorkerSlot {
    fn new(index: usize) -> WorkerSlot {
        WorkerSlot {
            index,
            state: BTreeMap::new(),
        }
    }

    /// This worker's index in the pool (stable for the pool's
    /// lifetime — the stable target of every key [`WorkerPool::worker_for`]
    /// maps here).
    pub fn index(&self) -> usize {
        self.index
    }

    /// The slot's cached `T`, if one was installed earlier.
    pub fn get_mut<T: Any + Send>(&mut self) -> Option<&mut T> {
        self.state
            .get_mut(&TypeId::of::<T>())
            .and_then(|b| b.downcast_mut::<T>())
    }

    /// Install (or replace) the slot's cached `T`.
    pub fn insert<T: Any + Send>(&mut self, value: T) {
        self.state.insert(TypeId::of::<T>(), Box::new(value));
    }

    /// The slot's cached `T`, created via `init` on first use.
    pub fn state_or_insert_with<T: Any + Send>(&mut self, init: impl FnOnce() -> T) -> &mut T {
        self.state
            .entry(TypeId::of::<T>())
            .or_insert_with(|| Box::new(init()))
            .downcast_mut::<T>()
            .expect("slot entry keyed by its own TypeId")
    }
}

/// A job with its lifetime erased for the trip through a worker
/// channel. Safety rests on the dispatch protocol: see
/// [`WorkerPool::dispatch`].
type ErasedJob = Box<dyn FnOnce(&mut WorkerSlot) + Send + 'static>;

struct Inner {
    /// Per-worker job senders. Behind a mutex (uncontended — the
    /// coordinator thread is the only dispatcher) so a panicked
    /// worker's channel can be swapped for a fresh one through
    /// `&self` during healing.
    job_txs: Mutex<Vec<mpsc::Sender<ErasedJob>>>,
    handles: Mutex<Vec<std::thread::JoinHandle<()>>>,
    poisoned: AtomicBool,
}

/// Spawn one worker thread with a fresh slot and a fresh channel.
fn spawn_worker(
    index: usize,
) -> std::io::Result<(mpsc::Sender<ErasedJob>, std::thread::JoinHandle<()>)> {
    let (tx, rx) = mpsc::channel::<ErasedJob>();
    let handle = std::thread::Builder::new()
        .name(format!("pallas-worker-{index}"))
        .spawn(move || {
            let mut slot = WorkerSlot::new(index);
            // The loop body is panic-free: user panics are caught
            // inside the job wrapper, so a worker thread only exits
            // when its sender drops (pool drop, or retirement after
            // a panic during healing).
            while let Ok(job) = rx.recv() {
                job(&mut slot);
            }
        })?;
    Ok((tx, handle))
}

/// The persistent worker pool. Threads spawn in [`WorkerPool::new`]
/// (none at `width = 1`) and join when the pool drops.
pub struct WorkerPool {
    width: usize,
    inner: Option<Inner>,
    /// Coordinator-side mirror of each worker's cached scoring-state
    /// epoch, stored as `epoch + 1` (0 = nothing cached). Written
    /// only by dispatching code on the coordinator thread; atomics
    /// only so the pool stays `Sync` (contexts holding `&WorkerPool`
    /// cross into worker jobs).
    cached: Vec<AtomicU64>,
    /// Identity tag of the engine behind each worker's cached state
    /// (see [`WorkerPool::cached_state`]); meaningful only where
    /// `cached` is non-zero.
    cached_tag: Vec<AtomicU64>,
}

impl Default for WorkerPool {
    /// Serial pool (width 1, no threads) — the oracle path.
    fn default() -> WorkerPool {
        WorkerPool::new(1)
    }
}

impl WorkerPool {
    /// Spawn the pool. `width = 1` (or 0, clamped) spawns no threads:
    /// consumers detect a serial pool via [`WorkerPool::parallel`]
    /// and take their inline paths.
    pub fn new(width: usize) -> WorkerPool {
        let width = width.max(1);
        let inner = (width > 1).then(|| {
            let mut job_txs = Vec::with_capacity(width);
            let mut handles = Vec::with_capacity(width);
            for index in 0..width {
                let (tx, handle) = spawn_worker(index).expect("spawn shard worker thread");
                job_txs.push(tx);
                handles.push(handle);
            }
            Inner {
                job_txs: Mutex::new(job_txs),
                handles: Mutex::new(handles),
                poisoned: AtomicBool::new(false),
            }
        });
        WorkerPool {
            width,
            inner,
            cached: (0..width).map(|_| AtomicU64::new(0)).collect(),
            cached_tag: (0..width).map(|_| AtomicU64::new(0)).collect(),
        }
    }

    /// Pool width from `PALLAS_WORKER_THREADS` (default 1).
    pub fn from_env() -> WorkerPool {
        WorkerPool::new(env_workers())
    }

    /// Configured width (threads spawned when > 1).
    pub fn workers(&self) -> usize {
        self.width
    }

    /// Whether dispatches actually cross threads. False at width 1 —
    /// consumers then run their inline serial paths.
    pub fn parallel(&self) -> bool {
        self.inner.is_some()
    }

    /// Stable affinity map: the worker that serves affinity key `key`
    /// (a shard index) on every dispatch. The key is SplitMix64-mixed
    /// before the modulo: a raw `key % width` would let the
    /// power-of-two stride patterns shard selection produces (e.g. a
    /// top-K pick landing on every second shard with an even width)
    /// alias onto one worker and silently serialize the fan-out;
    /// mixing spreads any fixed selection pattern while keeping the
    /// map perfectly stable across dispatches. The inherent tradeoff
    /// of ANY stable map remains — some selections use fewer than
    /// `min(width, jobs)` workers — which is the price of arenas and
    /// cache lines that keep seeing the same shards.
    pub fn worker_for(&self, key: usize) -> usize {
        (splitmix64(key as u64) % self.width as u64) as usize
    }

    /// The `(epoch, tag)` of worker `w`'s cached scoring state, if
    /// the coordinator has installed one (see the module docs on the
    /// mirror invariant). The tag identifies the *engine* the cache
    /// was cut from — epochs alone cannot, because the stateless
    /// default epoch 0 is shared by every oracle-like engine type.
    /// Always `None` on a serial pool — inline paths use the caller's
    /// own arenas, nothing is cached.
    pub fn cached_state(&self, worker: usize) -> Option<(u64, u64)> {
        if !self.parallel() {
            return None;
        }
        match self.cached[worker].load(Ordering::Relaxed) {
            0 => None,
            e => Some((e - 1, self.cached_tag[worker].load(Ordering::Relaxed))),
        }
    }

    /// Record that worker `w` now caches scoring state at `epoch` for
    /// the engine identified by `tag`. Call only from dispatching
    /// code that actually stages the matching install in the same
    /// dispatch.
    pub fn note_cached(&self, worker: usize, epoch: u64, tag: u64) {
        if self.parallel() {
            self.cached_tag[worker].store(tag, Ordering::Relaxed);
            self.cached[worker].store(epoch + 1, Ordering::Relaxed);
        }
    }

    /// Run jobs on their affinity workers and return the results in
    /// job order. On a serial pool the jobs run inline, in order, on
    /// a transient slot (nothing persists — the serial paths own
    /// their state).
    ///
    /// A panicking job fails this dispatch with
    /// [`PoolError::WorkerPanicked`] (all jobs still run to
    /// completion — the protocol below requires it — but the results
    /// are discarded), after which the pool **heals**: the panicked
    /// workers' threads are respawned with fresh slots and their
    /// epoch mirrors cleared, so the next dispatch succeeds and
    /// re-stages scoring state through the ordinary cache path. On a
    /// serial pool the panic is caught the same way and there is
    /// nothing to heal — the transient slot is discarded regardless.
    /// [`PoolError::Poisoned`] is returned only when recovery is
    /// impossible (a respawn failed, or a worker vanished without
    /// reporting).
    ///
    /// # Safety of the lifetime erasure
    ///
    /// Jobs may borrow from the caller's scope (`'env`): the closure
    /// is transmuted to `'static` for the channel trip. This is sound
    /// because dispatch does not return until every successfully sent
    /// job has run and reported back — each wrapped job sends exactly
    /// one message (its result or its caught panic), and the collect
    /// loop below receives exactly that many — so no job, nor
    /// anything it borrows, outlives this call. Healing happens after
    /// the collect loop, so a retired worker's queue is already
    /// drained when its channel is swapped.
    pub fn dispatch<'env, T, F>(&self, jobs: Vec<(usize, F)>) -> Result<Vec<T>, PoolError>
    where
        T: Send + 'env,
        F: FnOnce(&mut WorkerSlot) -> T + Send + 'env,
    {
        let Some(inner) = &self.inner else {
            // Serial path: run every job (mirroring the parallel
            // protocol, where all sent jobs execute) and surface the
            // first panic the same way the pooled path does.
            let mut slot = WorkerSlot::new(0);
            let mut results = Vec::with_capacity(jobs.len());
            let mut first_panic: Option<String> = None;
            for (_, job) in jobs {
                match catch_unwind(AssertUnwindSafe(|| job(&mut slot))) {
                    Ok(v) => results.push(v),
                    Err(p) => {
                        first_panic.get_or_insert(panic_message(p.as_ref()));
                    }
                }
            }
            return match first_panic {
                Some(msg) => Err(PoolError::WorkerPanicked(msg)),
                None => Ok(results),
            };
        };
        if inner.poisoned.load(Ordering::Acquire) {
            return Err(PoolError::Poisoned);
        }
        let n = jobs.len();
        let (tx, rx) = mpsc::channel::<(usize, Result<T, String>)>();
        let mut sent = 0usize;
        let mut lost_worker = false;
        // Worker index per job index — consulted when a job panics to
        // know which thread to retire.
        let mut worker_of = Vec::with_capacity(n);
        {
            let job_txs = inner.job_txs.lock().expect("job sender lock");
            for (i, (key, job)) in jobs.into_iter().enumerate() {
                let tx = tx.clone();
                let wrapped: Box<dyn FnOnce(&mut WorkerSlot) + Send + 'env> =
                    Box::new(move |slot: &mut WorkerSlot| {
                        let out = catch_unwind(AssertUnwindSafe(|| job(slot)));
                        // Exactly one message per job, success or panic.
                        let _ = tx.send((i, out.map_err(|p| panic_message(p.as_ref()))));
                    });
                // SAFETY: see the method docs — every sent job completes
                // (and is dropped) before this call returns, so the
                // erased borrows never dangle. Unsent jobs on the error
                // path below are dropped here, inside `'env`.
                let wrapped = unsafe {
                    std::mem::transmute::<Box<dyn FnOnce(&mut WorkerSlot) + Send + 'env>, ErasedJob>(
                        wrapped,
                    )
                };
                let w = self.worker_for(key);
                worker_of.push(w);
                if job_txs[w].send(wrapped).is_err() {
                    // A worker thread is gone — only possible if the
                    // process is tearing down. Stop sending; the jobs
                    // already in flight are still drained below.
                    lost_worker = true;
                    break;
                }
                sent += 1;
            }
        }
        drop(tx);
        let mut results: Vec<Option<T>> = (0..n).map(|_| None).collect();
        let mut first_panic: Option<String> = None;
        let mut panicked_workers: BTreeSet<usize> = BTreeSet::new();
        for _ in 0..sent {
            match rx.recv() {
                Ok((i, Ok(v))) => results[i] = Some(v),
                Ok((i, Err(msg))) => {
                    first_panic.get_or_insert(msg);
                    panicked_workers.insert(worker_of[i]);
                }
                // Unreachable (every sent job sends exactly once and
                // we hold the receiver), but never hang on it.
                Err(_) => {
                    lost_worker = true;
                    break;
                }
            }
        }
        if let Some(msg) = first_panic {
            if self.heal(inner, &panicked_workers).is_err() {
                inner.poisoned.store(true, Ordering::Release);
            }
            return Err(PoolError::WorkerPanicked(msg));
        }
        if lost_worker {
            inner.poisoned.store(true, Ordering::Release);
            return Err(PoolError::Poisoned);
        }
        Ok(results
            .into_iter()
            .map(|r| r.expect("every job sent exactly one result"))
            .collect())
    }

    /// Respawn each panicked worker: swap in a fresh channel + thread
    /// at the same index (the retired thread exits once its old
    /// sender drops — its queue is already drained, see dispatch) and
    /// zero the worker's epoch mirror so the next fan-out re-stages
    /// its scoring state. Errors only if a thread fails to spawn —
    /// the caller poisons the pool then.
    fn heal(&self, inner: &Inner, workers: &BTreeSet<usize>) -> std::io::Result<()> {
        for &w in workers {
            let (tx, handle) = spawn_worker(w)?;
            let old_tx = {
                let mut job_txs = inner.job_txs.lock().expect("job sender lock");
                std::mem::replace(&mut job_txs[w], tx)
            };
            drop(old_tx);
            let old_handle = {
                let mut handles = inner.handles.lock().expect("worker handle lock");
                std::mem::replace(&mut handles[w], handle)
            };
            // The retired thread is idle on a closed channel; the
            // join is immediate.
            let _ = old_handle.join();
            self.cached[w].store(0, Ordering::Relaxed);
            self.cached_tag[w].store(0, Ordering::Relaxed);
        }
        Ok(())
    }

    /// Read every shard's digest through the pool: digests flow back
    /// to the coordinator thread over the result channel instead of
    /// the coordinator walking shard state in place — the read path a
    /// distributed deployment (one process per shard) would use.
    /// Inline on a serial pool.
    pub fn gather_digests(&self, sc: &ShardedCluster) -> Result<Vec<ShardDigest>, PoolError> {
        if !self.parallel() || sc.shard_count() <= 1 {
            return Ok((0..sc.shard_count()).map(|s| *sc.digest(s)).collect());
        }
        let jobs: Vec<_> = (0..sc.shard_count())
            .map(|s| (s, move |_: &mut WorkerSlot| *sc.digest(s)))
            .collect();
        self.dispatch(jobs)
    }

    /// [`WorkerPool::gather_digests`] with the shard commit epochs
    /// attached — the snapshot a scheduler front end decides against
    /// in the commit protocol (see
    /// `crate::coordinator::placement_store`). Inline on a serial
    /// pool.
    pub fn gather_snapshots(&self, sc: &ShardedCluster) -> Result<Vec<DigestSnapshot>, PoolError> {
        if !self.parallel() || sc.shard_count() <= 1 {
            return Ok((0..sc.shard_count()).map(|s| sc.digest_snapshot(s)).collect());
        }
        let jobs: Vec<_> = (0..sc.shard_count())
            .map(|s| (s, move |_: &mut WorkerSlot| sc.digest_snapshot(s)))
            .collect();
        self.dispatch(jobs)
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        if let Some(inner) = self.inner.take() {
            // Closing the job channels ends each worker's recv loop.
            drop(inner.job_txs.into_inner().expect("job sender lock"));
            for h in inner.handles.into_inner().expect("worker handle lock") {
                let _ = h.join();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Cluster;

    #[test]
    fn dispatch_preserves_job_order_at_any_width() {
        for width in [1usize, 2, 3, 8] {
            let pool = WorkerPool::new(width);
            let jobs: Vec<_> = (0..17u64)
                .map(|i| (i as usize, move |_: &mut WorkerSlot| i * i))
                .collect();
            let out = pool.dispatch(jobs).unwrap();
            assert_eq!(out, (0..17u64).map(|i| i * i).collect::<Vec<_>>());
        }
    }

    #[test]
    fn affinity_is_stable_across_dispatches() {
        let pool = WorkerPool::new(4);
        let observe = |pool: &WorkerPool| -> Vec<usize> {
            let jobs: Vec<_> = (0..16usize)
                .map(|k| (k, move |slot: &mut WorkerSlot| slot.index()))
                .collect();
            pool.dispatch(jobs).unwrap()
        };
        let first = observe(&pool);
        for (k, &w) in first.iter().enumerate() {
            assert_eq!(w, pool.worker_for(k), "key {k} must run on its affinity worker");
            assert!(w < 4);
        }
        // The mixed map must spread a dense key range across workers,
        // not collapse it (the failure mode of a raw modulo under
        // strided selections).
        let distinct: std::collections::BTreeSet<usize> = first.iter().copied().collect();
        assert!(distinct.len() > 1, "16 keys all landed on one of 4 workers");
        assert_eq!(first, observe(&pool), "assignment must not drift");
    }

    #[test]
    fn worker_state_persists_across_dispatches_without_respawn() {
        let pool = WorkerPool::new(3);
        // Each job bumps a per-worker counter kept in the slot. If
        // workers (or their state) were rebuilt per fan-out, the
        // second dispatch would observe counters starting from zero.
        let count_up = |pool: &WorkerPool| -> Vec<u64> {
            let jobs: Vec<_> = (0..3usize)
                .map(|k| {
                    (k, move |slot: &mut WorkerSlot| {
                        let c = slot.state_or_insert_with(|| 0u64);
                        *c += 1;
                        *c
                    })
                })
                .collect();
            pool.dispatch(jobs).unwrap()
        };
        assert_eq!(count_up(&pool), vec![1, 1, 1]);
        assert_eq!(count_up(&pool), vec![2, 2, 2], "state must persist");
        assert_eq!(count_up(&pool), vec![3, 3, 3]);
    }

    #[test]
    fn serial_dispatch_runs_inline_in_order() {
        use std::sync::atomic::AtomicUsize;
        let pool = WorkerPool::new(1);
        assert!(!pool.parallel());
        // Jobs borrow the caller's scope via a shared sequence
        // counter — running totals prove in-order execution.
        let seq = AtomicUsize::new(0);
        let seq_ref = &seq;
        let jobs: Vec<_> = (0..5usize)
            .map(|k| {
                (k, move |_: &mut WorkerSlot| {
                    seq_ref.fetch_add(1, Ordering::Relaxed) + 1
                })
            })
            .collect();
        let out = pool.dispatch(jobs).unwrap();
        assert_eq!(out, vec![1, 2, 3, 4, 5]);
    }

    #[test]
    fn panicking_job_fails_once_then_pool_self_heals() {
        let pool = WorkerPool::new(4);
        let jobs: Vec<_> = (0..8usize)
            .map(|i| {
                (i, move |_: &mut WorkerSlot| {
                    if i == 3 {
                        panic!("boom in shard job {i}");
                    }
                    i
                })
            })
            .collect();
        let err = pool.dispatch(jobs).expect_err("panicking job must fail the dispatch");
        assert!(
            err.to_string().contains("boom in shard job 3"),
            "unhelpful error: {err}"
        );
        // The pool healed: the NEXT dispatch succeeds (no Poisoned, no
        // deadlock), across all workers.
        let retry: Vec<_> = (0..8usize)
            .map(|i| (i, move |_: &mut WorkerSlot| i * 10))
            .collect();
        let out = pool.dispatch(retry).expect("pool must heal after a panic");
        assert_eq!(out, (0..8usize).map(|i| i * 10).collect::<Vec<_>>());
    }

    #[test]
    fn healing_rebuilds_only_the_panicked_workers_slot() {
        let pool = WorkerPool::new(4);
        let bad = pool.worker_for(3);
        let other_key = (0..64usize)
            .find(|&k| pool.worker_for(k) != bad)
            .expect("4 workers: some key maps elsewhere");
        // Seed per-worker counters on both workers.
        let count = |pool: &WorkerPool, key: usize| -> u64 {
            let jobs: Vec<_> = vec![(key, move |slot: &mut WorkerSlot| {
                let c = slot.state_or_insert_with(|| 0u64);
                *c += 1;
                *c
            })];
            pool.dispatch(jobs).unwrap()[0]
        };
        assert_eq!(count(&pool, 3), 1);
        assert_eq!(count(&pool, 3), 2);
        assert_eq!(count(&pool, other_key), 1);
        // Mark scoring state cached on the panicking worker, then panic it.
        pool.note_cached(bad, 7, 1);
        assert_eq!(pool.cached_state(bad), Some((7, 1)));
        let boom: Vec<(usize, fn(&mut WorkerSlot) -> u64)> =
            vec![(3, |_| panic!("injected"))];
        match pool.dispatch(boom) {
            Err(PoolError::WorkerPanicked(_)) => {}
            other => panic!("expected WorkerPanicked, got {other:?}"),
        }
        // Respawned worker: fresh slot (counter restarts), mirror
        // cleared so the epoch cache re-stages for exactly this worker.
        assert_eq!(pool.cached_state(bad), None);
        assert_eq!(count(&pool, 3), 1, "slot must be rebuilt fresh");
        // Untouched worker keeps its slot.
        assert_eq!(count(&pool, other_key), 2, "healthy workers keep state");
    }

    #[test]
    fn serial_pool_catches_panics_and_keeps_working() {
        let pool = WorkerPool::new(1);
        let boom: Vec<(usize, fn(&mut WorkerSlot) -> usize)> =
            vec![(0, |_| 1usize), (1, |_| panic!("serial boom")), (2, |_| 3usize)];
        match pool.dispatch(boom) {
            Err(PoolError::WorkerPanicked(msg)) => assert!(msg.contains("serial boom")),
            other => panic!("expected WorkerPanicked, got {other:?}"),
        }
        let ok: Vec<(usize, fn(&mut WorkerSlot) -> usize)> = vec![(0, |_| 7usize)];
        assert_eq!(pool.dispatch(ok).unwrap(), vec![7]);
    }

    #[test]
    fn cached_state_mirror_round_trips() {
        let pool = WorkerPool::new(2);
        assert_eq!(pool.cached_state(0), None);
        pool.note_cached(0, 0, 7);
        assert_eq!(
            pool.cached_state(0),
            Some((0, 7)),
            "epoch 0 is distinguishable from empty"
        );
        pool.note_cached(1, 41, 9);
        assert_eq!(pool.cached_state(1), Some((41, 9)));
        assert_eq!(pool.cached_state(0), Some((0, 7)));
        // Same epoch, different engine tag: NOT a cache hit.
        assert_ne!(pool.cached_state(0), Some((0, 8)));
        // Serial pools cache nothing.
        let serial = WorkerPool::new(1);
        serial.note_cached(0, 5, 1);
        assert_eq!(serial.cached_state(0), None);
    }

    #[test]
    fn width_clamps_and_default_is_serial() {
        assert_eq!(WorkerPool::new(0).workers(), 1);
        assert!(!WorkerPool::new(0).parallel());
        assert_eq!(WorkerPool::default().workers(), 1);
        assert!(WorkerPool::new(2).parallel());
    }

    #[test]
    fn digests_over_the_channel_match_in_place_reads() {
        let sc = ShardedCluster::new(Cluster::homogeneous(13), 4);
        for width in [1usize, 4] {
            let pool = WorkerPool::new(width);
            let gathered = pool.gather_digests(&sc).unwrap();
            assert_eq!(gathered.len(), 4);
            for (g, d) in gathered.iter().zip(sc.digests()) {
                assert_eq!(g.hosts, d.hosts);
                assert_eq!(g.on, d.on);
            }
        }
    }

    #[test]
    fn snapshots_over_the_channel_carry_commit_epochs() {
        let mut sc = ShardedCluster::new(Cluster::homogeneous(13), 4);
        let vm = sc.create_vm(crate::cluster::flavor::SMALL, crate::workload::JobId(0), 0.0);
        sc.place_vm(vm, crate::cluster::HostId(0)).unwrap();
        for width in [1usize, 4] {
            let pool = WorkerPool::new(width);
            let snaps = pool.gather_snapshots(&sc).unwrap();
            assert_eq!(snaps.len(), 4);
            for (s, shard) in snaps.iter().zip(0..) {
                assert_eq!(s.shard, shard);
                assert_eq!(s.epoch, sc.shard_epoch(shard));
                assert_eq!(s.digest.hosts, sc.digest(shard).hosts);
            }
            // The placement bumped exactly host 0's shard.
            assert_eq!(snaps[sc.shard_of(crate::cluster::HostId(0))].epoch, 1);
        }
    }
}
