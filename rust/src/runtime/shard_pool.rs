//! Spawn-per-call shard worker pool — the **reference** fan-out
//! implementation, superseded on every hot path by the persistent
//! [`crate::runtime::WorkerPool`]. Retained for two jobs: it is the
//! spawn-per-call baseline `benches/bench_pool.rs` measures the
//! persistent pool against (the per-call overhead PR 5 removed), and
//! its scatter semantics are the simplest statement of the dispatch
//! contract the persistent pool must preserve.
//!
//! Std-only by design: the offline build vendors no crates, so the
//! pool is `std::thread::scope` + `std::sync::mpsc`. Workers are
//! spawned inside a scope per fan-out call — shard jobs borrow shard
//! interiors (`&` only; the coordinator thread remains the sole
//! writer), and scoped threads are what let those borrows cross the
//! spawn without `'static` gymnastics. Within one call each worker is
//! long-lived: it pulls shard jobs off a shared queue until the queue
//! drains, so a K-shard sweep costs at most `min(workers, K)` thread
//! spawns, not K — but every call still pays those spawns plus a full
//! rebuild of per-worker state, which is exactly what the persistent
//! pool's cached [`crate::runtime::WorkerSlot`]s amortize away.
//!
//! # Determinism contract
//!
//! `scatter`/`scatter_state` return results indexed by job, not by
//! completion order, and callers merge per-shard results by a
//! commutative rule (lexicographic `(energy, host id)` for placement
//! winners, ascending shard order for control actions). Worker count
//! therefore never changes observable output — `workers = 1` is the
//! serial oracle path, run inline with no threads at all, and the
//! equivalence property tests in `rust/tests/pool.rs` pin parallel
//! against it.
//!
//! # Panic poisoning
//!
//! A job that panics must not deadlock the channel: every job sends
//! exactly one message (its result or its panic payload, caught with
//! `catch_unwind`), so the receive loop always terminates and a
//! panicking worker surfaces as [`PoolError::WorkerPanicked`] with
//! the payload's message instead of a hang.

use crate::cluster::{ShardDigest, ShardedCluster};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Mutex};

/// Environment variable consulted for the default worker count — the
/// CI test matrix runs the suite under both `1` and `8`.
pub const WORKER_THREADS_ENV: &str = "PALLAS_WORKER_THREADS";

/// Worker-pool failure: the scan that scheduled the failing job is
/// poisoned and must not actuate partial results.
#[derive(Debug)]
pub enum PoolError {
    /// A worker panicked while running a shard job; the string is the
    /// panic payload's message.
    WorkerPanicked(String),
    /// The pool was poisoned by an earlier panic (persistent
    /// [`crate::runtime::WorkerPool`] only): this fan-out was refused
    /// outright rather than run against state a half-finished scan
    /// may have left behind.
    Poisoned,
}

impl std::fmt::Display for PoolError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PoolError::WorkerPanicked(msg) => {
                write!(f, "shard worker panicked: {msg}")
            }
            PoolError::Poisoned => {
                write!(f, "worker pool poisoned by an earlier panic; fan-out refused")
            }
        }
    }
}

impl std::error::Error for PoolError {}

pub(crate) fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&'static str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Worker count from `PALLAS_WORKER_THREADS` (default 1 = serial).
pub fn env_workers() -> usize {
    std::env::var(WORKER_THREADS_ENV)
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .filter(|&n| n >= 1)
        .unwrap_or(1)
}

/// The shard worker pool. Construction is cheap (the pool holds only
/// its configured width; threads live per fan-out call), so the
/// coordinator owns one for the campaign and attaches it to every
/// context it freezes.
#[derive(Debug, Clone, Copy)]
pub struct ShardPool {
    workers: usize,
}

impl Default for ShardPool {
    /// Serial pool (one worker) — the oracle path.
    fn default() -> ShardPool {
        ShardPool::new(1)
    }
}

impl ShardPool {
    pub fn new(workers: usize) -> ShardPool {
        ShardPool {
            workers: workers.max(1),
        }
    }

    /// Pool width from `PALLAS_WORKER_THREADS` (default 1).
    pub fn from_env() -> ShardPool {
        ShardPool::new(env_workers())
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Workers a fan-out of `jobs` shard jobs would actually spawn:
    /// one per job up to the configured width, never zero.
    pub fn plan_workers(&self, jobs: usize) -> usize {
        self.workers.min(jobs).max(1)
    }

    /// Run stateless shard jobs, returning their results in job order.
    /// With one planned worker the jobs run inline on the calling
    /// thread in order (the serial oracle); otherwise workers pull
    /// jobs off a shared queue and results come back over the channel.
    pub fn scatter<T, F>(&self, jobs: Vec<F>) -> Result<Vec<T>, PoolError>
    where
        T: Send,
        F: FnOnce() -> T + Send,
    {
        let states = vec![(); self.plan_workers(jobs.len())];
        let jobs: Vec<_> = jobs.into_iter().map(|job| move |_: &mut ()| job()).collect();
        self.scatter_state(states, jobs)
    }

    /// Run shard jobs with per-worker state, returning results in job
    /// order. `states` carries one scoring arena (predictor clone,
    /// feature/prediction buffers) per worker — the shared single
    /// arena the serial paths reuse is inherently serial, so each
    /// worker must own its own. `states.len()` is the worker count;
    /// size it with [`ShardPool::plan_workers`]. One state means the
    /// jobs run inline, in order, threading that single state through
    /// all of them — exactly the serial sweep.
    pub fn scatter_state<S, T, F>(&self, states: Vec<S>, jobs: Vec<F>) -> Result<Vec<T>, PoolError>
    where
        S: Send,
        T: Send,
        F: FnOnce(&mut S) -> T + Send,
    {
        assert!(!states.is_empty(), "scatter_state needs at least one worker state");
        if states.len() == 1 || jobs.len() <= 1 {
            let mut state = states.into_iter().next().expect("checked non-empty");
            return Ok(jobs.into_iter().map(|job| job(&mut state)).collect());
        }
        let n = jobs.len();
        let next = AtomicUsize::new(0);
        // Job handoff: each slot is taken exactly once, by whichever
        // worker claims its index off the shared counter.
        let slots: Vec<Mutex<Option<F>>> =
            jobs.into_iter().map(|job| Mutex::new(Some(job))).collect();
        let (tx, rx) = mpsc::channel::<(usize, std::thread::Result<T>)>();
        let mut results: Vec<Option<T>> = (0..n).map(|_| None).collect();
        let first_panic = std::thread::scope(|scope| {
            for mut state in states {
                let tx = tx.clone();
                let next = &next;
                let slots = &slots;
                scope.spawn(move || loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    let job = slots[i]
                        .lock()
                        .expect("job slot lock")
                        .take()
                        .expect("each job index is claimed once");
                    // Exactly one message per job, success or panic —
                    // the receive loop below can never starve.
                    let outcome = catch_unwind(AssertUnwindSafe(|| job(&mut state)));
                    if tx.send((i, outcome)).is_err() {
                        break;
                    }
                });
            }
            drop(tx);
            let mut first_panic: Option<String> = None;
            for (i, outcome) in rx {
                match outcome {
                    Ok(v) => results[i] = Some(v),
                    Err(payload) => {
                        first_panic.get_or_insert_with(|| panic_message(payload.as_ref()));
                    }
                }
            }
            first_panic
        });
        match first_panic {
            Some(msg) => Err(PoolError::WorkerPanicked(msg)),
            None => Ok(results
                .into_iter()
                .map(|r| r.expect("every job sent exactly one result"))
                .collect()),
        }
    }

    /// Read every shard's digest through the pool: digests flow back
    /// to the coordinator thread over the result channel instead of
    /// the coordinator walking shard state in place — the read path a
    /// distributed deployment (one process per shard) would use.
    pub fn gather_digests(&self, sc: &ShardedCluster) -> Result<Vec<ShardDigest>, PoolError> {
        let jobs: Vec<_> = (0..sc.shard_count()).map(|s| move || *sc.digest(s)).collect();
        self.scatter(jobs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Cluster;

    #[test]
    fn scatter_preserves_job_order_at_any_width() {
        for workers in [1usize, 2, 3, 8] {
            let pool = ShardPool::new(workers);
            let jobs: Vec<_> = (0..17u64).map(|i| move || i * i).collect();
            let out = pool.scatter(jobs).unwrap();
            assert_eq!(out, (0..17u64).map(|i| i * i).collect::<Vec<_>>());
        }
    }

    #[test]
    fn serial_pool_threads_one_state_through_jobs_in_order() {
        let pool = ShardPool::new(1);
        let jobs: Vec<_> = (0..5u64).map(|i| move |acc: &mut u64| {
            *acc += i;
            *acc
        })
        .collect();
        // Running totals prove in-order, single-state execution.
        let out = pool.scatter_state(vec![0u64], jobs).unwrap();
        assert_eq!(out, vec![0, 1, 3, 6, 10]);
    }

    #[test]
    fn parallel_workers_each_own_their_state() {
        let pool = ShardPool::new(4);
        let jobs: Vec<_> = (0..32u64).map(|i| move |calls: &mut u64| {
            *calls += 1;
            i
        })
        .collect();
        let out = pool.scatter_state(vec![0u64; 4], jobs).unwrap();
        assert_eq!(out, (0..32u64).collect::<Vec<_>>());
    }

    #[test]
    fn panicking_worker_poisons_the_scatter_instead_of_deadlocking() {
        let pool = ShardPool::new(4);
        let jobs: Vec<_> = (0..8usize)
            .map(|i| {
                move || {
                    if i == 3 {
                        panic!("boom in shard job {i}");
                    }
                    i
                }
            })
            .collect();
        let err = pool.scatter(jobs).expect_err("a panicking job must poison the scatter");
        let msg = err.to_string();
        assert!(msg.contains("boom in shard job 3"), "unhelpful error: {msg}");
    }

    #[test]
    fn plan_workers_caps_at_jobs_and_width() {
        let pool = ShardPool::new(8);
        assert_eq!(pool.plan_workers(3), 3);
        assert_eq!(pool.plan_workers(100), 8);
        assert_eq!(pool.plan_workers(0), 1);
        assert_eq!(ShardPool::new(0).workers(), 1, "width clamps to 1");
        assert_eq!(ShardPool::default().workers(), 1);
    }

    #[test]
    fn digests_over_the_channel_match_in_place_reads() {
        let sc = ShardedCluster::new(Cluster::homogeneous(13), 4);
        for workers in [1usize, 4] {
            let pool = ShardPool::new(workers);
            let gathered = pool.gather_digests(&sc).unwrap();
            assert_eq!(gathered.len(), 4);
            for (g, d) in gathered.iter().zip(sc.digests()) {
                assert_eq!(g.hosts, d.hosts);
                assert_eq!(g.on, d.on);
            }
        }
    }
}
