//! Typed campaign state — everything `Coordinator::run` mutates while
//! driving a campaign, gathered into one struct instead of ~20 loose
//! maps threaded through helper signatures.

use crate::cluster::{Cluster, HostId, ShardedCluster, VmId};
use crate::coordinator::leader::{remaining_solo, CampaignConfig};
use crate::coordinator::placement_store::{PlacementStore, Scheduler};
use crate::coordinator::report::{CampaignReport, JobRecord, Overhead, ShardCounters};
use crate::profile::ResourceVector;
use crate::runtime::WorkerPool;
use crate::sched::VmContext;
use crate::sim::{EnergyMeter, FaultPlan, Telemetry};
use crate::sla::SlaTracker;
use crate::util::rng::Xoshiro256;
use crate::util::stats::{Histogram, Online};
use crate::workload::{Job, JobId, JobState};
use std::collections::{BTreeMap, BTreeSet};

/// Monotonic campaign counters (reported at the end of the run).
#[derive(Debug, Clone, Default)]
pub struct Counters {
    pub migrations: u64,
    pub migration_stall_s: f64,
    pub deferrals: u64,
    /// Host-seconds spent not powered on (off, shutting down, or
    /// booting).
    pub host_off_s: f64,
    pub completed: usize,
    /// Function invocations that missed the warm pool.
    pub cold_starts: u64,
    /// Function invocations that hit a warm container.
    pub warm_starts: u64,
    /// Warm containers evicted by the keep-alive loop.
    pub containers_expired: u64,
    /// Energy charged to container boot windows (J).
    pub cold_start_energy_j: f64,
    /// Running VMs evacuated off crashed hosts.
    pub evacuations: u64,
    /// Fault-plan host crashes that fired (host was On).
    pub host_crashes: u64,
    /// Crashed hosts that completed their recovery reboot.
    pub host_recoveries: u64,
    /// Transient migration-actuation failures injected by the plan.
    pub migration_failures: u64,
    /// Worker panic probes injected (each healed the pool).
    pub worker_panics: u64,
    /// Recoveries deferred because the host was flapping.
    pub quarantines: u64,
    /// Energy attributed to jobs at the moment their host crashed (J),
    /// discounted by checkpointed progress: only the *wasted* fraction
    /// of each crashed job's energy counts.
    pub replacement_energy_j: f64,
    /// Correlated rack-crash events that fired.
    pub rack_crashes: u64,
    /// Degradation episodes that took effect (host was On).
    pub degraded_hosts: u64,
    /// Consolidation migrations whose source host was degraded — the
    /// proactive-drain tally.
    pub drains: u64,
    /// Checkpoints written (charged at crash or completion).
    pub checkpoints_taken: u64,
    /// Solo seconds of progress preserved across crashes by
    /// checkpoint restarts.
    pub progress_saved_s: f64,
    /// Energy spent writing checkpoints (J).
    pub checkpoint_energy_j: f64,
}

/// The mutable state of one campaign run.
pub struct CampaignState {
    /// Sharded cluster state. Reads deref to the inner cluster; the
    /// leader routes every mutation through the shard handles so the
    /// per-shard digests stay consistent.
    pub cluster: ShardedCluster,
    /// Per-shard actuation counters (placements, boots, migrations,
    /// power-offs), indexed by shard.
    pub shard_counters: Vec<ShardCounters>,
    /// The central placement store: validates every
    /// `AllocationCommit` against live capacity and commit epochs,
    /// and appends the total-order commit log.
    pub store: PlacementStore,
    /// The scheduler front ends (`CampaignConfig::coordinator_count`
    /// of them): per-coordinator snapshot epochs and commit sequence
    /// numbers. One scheduler = the classic single leader.
    pub schedulers: Vec<Scheduler>,
    /// Persistent shard worker pool (`CampaignConfig::worker_threads`
    /// wide): threads spawn once here, serve every fan-out of the
    /// campaign through the contexts the leader freezes, and join
    /// when this state drops. Width 1 spawns nothing — the serial
    /// oracle path. Worker-cached predictor clones invalidate by
    /// weight epoch, so the pool never needs telling about retrains.
    pub pool: WorkerPool,
    pub meter: EnergyMeter,
    pub telemetry: Telemetry,
    pub sla: SlaTracker,
    /// All jobs of the trace, by id.
    pub jobs: BTreeMap<JobId, Job>,
    pub vm_of_job: BTreeMap<JobId, VmId>,
    pub job_of_vm: BTreeMap<VmId, JobId>,
    /// Eq. 1 profiles captured at placement time.
    pub profiles: BTreeMap<JobId, ResourceVector>,
    /// Jobs waiting for a later placement retry.
    pub deferred: Vec<JobId>,
    /// Jobs waiting for a host to finish booting.
    pub waiting_boot: Vec<(JobId, HostId)>,
    /// Energy attribution per job (J).
    pub job_energy: BTreeMap<JobId, f64>,
    /// Migration stall attribution per job (s).
    pub job_stall: BTreeMap<JobId, f64>,
    /// Stop-and-copy stalls to apply at migration cut-over.
    pub pending_stalls: BTreeMap<VmId, f64>,
    pub overhead: Overhead,
    pub counters: Counters,
    /// CPU-utilization distribution over (host, sample) pairs.
    pub util_hist: Histogram,
    pub per_host_cpu: Vec<Online>,
    /// Fleet-wide warm-container occupancy, sampled on the telemetry
    /// cadence (only fed when the campaign configured `faas`).
    pub warm_pool: Online,
    /// At most ONE RetryQueue event may be pending at a time —
    /// otherwise k deferred jobs re-deferring from one retry spawn
    /// k new retries (exponential event growth).
    pub next_retry: Option<f64>,
    /// Number of jobs in the trace.
    pub n_jobs: usize,
    /// The campaign's fault schedule — empty ([`FaultPlan::none`])
    /// when `CampaignConfig::faults` is off.
    pub fault_plan: FaultPlan,
    /// Whether faults are configured; gates every fault-only code
    /// path (including jitter draws) so fault-free campaigns replay
    /// the pre-fault coordinator bit for bit.
    pub has_faults: bool,
    /// Backoff-jitter stream. Consumed only when `has_faults`.
    pub fault_rng: Xoshiro256,
    /// Placement attempts per job (defers + evacuation retries) —
    /// drives the bounded exponential backoff and the interruption
    /// cap.
    pub retry_attempts: BTreeMap<JobId, u32>,
    /// Jobs abandoned once their attempts hit
    /// `CampaignConfig::retry_max_attempts`. They count toward
    /// campaign termination but never toward SLA compliance.
    pub interrupted: BTreeSet<JobId>,
    /// When each evacuated job lost its host — cleared (into
    /// `recovery_latency`) at re-placement.
    pub evacuated_at: BTreeMap<JobId, f64>,
    /// Rack the job's crashed host belonged to — feeds
    /// `PlacementRequest::avoid_rack` so re-placement prefers a
    /// different fault domain. Cleared alongside `evacuated_at`.
    pub evacuated_rack: BTreeMap<JobId, usize>,
    /// Evacuation → re-placement latency samples (s).
    pub recovery_latency: Online,
    /// Crash timestamps per host, for flap detection.
    pub crash_history: BTreeMap<HostId, Vec<f64>>,
    /// Hosts whose scheduled recovery was already deferred once by
    /// the quarantine (the second firing proceeds).
    pub quarantine_deferred: BTreeSet<HostId>,
    /// Per-shard telemetry blackout end times (0 = clear).
    pub blackout_until: Vec<f64>,
    /// Campaign-global migration actuation counter — the input to the
    /// plan's stateless failure oracle.
    pub migration_attempts: u64,
    /// Transient failures per VM (bounded retry; at the cap the VM
    /// stays put for the rest of the campaign).
    pub migration_retries: BTreeMap<VmId, u32>,
    /// Events popped from the campaign queue (either engine).
    pub events_processed: u64,
}

impl CampaignState {
    pub fn new(cfg: &CampaignConfig) -> CampaignState {
        let shard_count = cfg.shard_count.max(1);
        let mut cluster = ShardedCluster::new(Cluster::homogeneous(cfg.n_hosts), shard_count);
        // Rack tags default to the shard partition (set by the
        // constructor above); an explicit map overrides them.
        if let Some(map) = &cfg.rack_map {
            cluster.set_rack_map(map);
        }
        let n_racks = cfg
            .rack_map
            .as_ref()
            .map(|m| m.iter().max().copied().unwrap_or(0) + 1)
            .unwrap_or(shard_count);
        CampaignState {
            cluster,
            shard_counters: vec![ShardCounters::default(); shard_count],
            store: PlacementStore::new(),
            schedulers: (0..cfg.coordinator_count.max(1) as u32)
                .map(|c| Scheduler::new(c, shard_count))
                .collect(),
            pool: WorkerPool::new(cfg.worker_threads),
            meter: EnergyMeter::new(cfg.n_hosts, cfg.seed, cfg.meter_noise),
            telemetry: Telemetry::new(cfg.n_hosts, cfg.seed, cfg.telemetry_noise),
            sla: SlaTracker::new(cfg.sla),
            jobs: BTreeMap::new(),
            vm_of_job: BTreeMap::new(),
            job_of_vm: BTreeMap::new(),
            profiles: BTreeMap::new(),
            deferred: Vec::new(),
            waiting_boot: Vec::new(),
            job_energy: BTreeMap::new(),
            job_stall: BTreeMap::new(),
            pending_stalls: BTreeMap::new(),
            overhead: Overhead::default(),
            counters: Counters::default(),
            util_hist: Histogram::new(0.0, 1.0, 10),
            per_host_cpu: (0..cfg.n_hosts).map(|_| Online::new()).collect(),
            warm_pool: Online::new(),
            next_retry: None,
            n_jobs: 0,
            fault_plan: cfg
                .faults
                .as_ref()
                .map(|f| FaultPlan::generate(cfg.seed, f, cfg.n_hosts, shard_count, n_racks))
                .unwrap_or_else(FaultPlan::none),
            has_faults: cfg.faults.is_some(),
            fault_rng: Xoshiro256::seed_from_u64(cfg.seed ^ 0xBAC0FF),
            retry_attempts: BTreeMap::new(),
            interrupted: BTreeSet::new(),
            evacuated_at: BTreeMap::new(),
            evacuated_rack: BTreeMap::new(),
            recovery_latency: Online::new(),
            crash_history: BTreeMap::new(),
            quarantine_deferred: BTreeSet::new(),
            blackout_until: vec![0.0; shard_count],
            migration_attempts: 0,
            migration_retries: BTreeMap::new(),
            events_processed: 0,
        }
    }

    /// Backoff jitter in [0.5, 1.5). Draws from the fault RNG only
    /// when faults are configured — fault-free campaigns keep the
    /// exact random streams of the pre-fault coordinator.
    pub fn retry_jitter(&mut self) -> f64 {
        if self.has_faults {
            self.fault_rng.uniform(0.5, 1.5)
        } else {
            1.0
        }
    }

    /// Per-VM runtime context for the control loops: current profile,
    /// remaining solo work, and SLA slack of every running job.
    pub fn vm_contexts(&self, now: f64) -> BTreeMap<VmId, VmContext> {
        let mut ctxs = BTreeMap::new();
        for (&vm_id, &job_id) in &self.job_of_vm {
            let job = &self.jobs[&job_id];
            if job.state != JobState::Running {
                continue;
            }
            let remaining = remaining_solo(job);
            let elapsed = now - job.started_at.unwrap_or(now);
            ctxs.insert(
                vm_id,
                VmContext {
                    vector: self.profiles.get(&job_id).copied().unwrap_or_default(),
                    remaining_solo: remaining,
                    slack_left: self.sla.slack_left(job_id, elapsed, remaining),
                },
            );
        }
        ctxs
    }

    /// Assemble the campaign report.
    pub fn report(&self, policy: &'static str, seed: u64, makespan: f64) -> CampaignReport {
        let idle_w = self.cluster.hosts[0].spec.power.p_idle;
        let jobs_out: Vec<JobRecord> = self
            .jobs
            .values()
            .filter(|j| j.state == JobState::Finished)
            .map(|j| {
                let jct = j.jct().unwrap();
                JobRecord {
                    id: j.id,
                    kind: j.kind,
                    gb: j.gb,
                    submit_at: j.submit_at,
                    jct,
                    solo: j.solo_duration(),
                    slowdown: jct / j.solo_duration() - 1.0,
                    energy_j: self.job_energy.get(&j.id).copied().unwrap_or(0.0),
                    wait: j.started_at.unwrap() - j.submit_at,
                    migrations: self
                        .vm_of_job
                        .get(&j.id)
                        .and_then(|vm| self.cluster.vms.get(vm))
                        .map(|v| v.migrations)
                        .unwrap_or(0),
                    sla_met: self.sla.jobs()[&j.id].met.unwrap_or(false),
                }
            })
            .collect();

        CampaignReport {
            policy,
            seed,
            makespan,
            energy_j: self.meter.total_j(),
            energy_true_j: self.meter.total_true_j(),
            active_energy_j: self.meter.active_j(idle_w, makespan),
            per_host_energy_j: self.meter.per_host_j().to_vec(),
            jobs: jobs_out,
            sla_compliance: self.sla.compliance(),
            sla_violations: self.sla.n_violations(),
            mean_slowdown: self.sla.mean_slowdown(),
            migrations: self.counters.migrations,
            migration_stall_s: self.counters.migration_stall_s,
            power_cycles: self.cluster.hosts.iter().map(|h| h.power_cycles).sum(),
            host_off_s: self.counters.host_off_s,
            power_trace: self.meter.power_trace.clone(),
            hosts_on_trace: self.meter.hosts_on_trace.clone(),
            util_hist: self.util_hist.clone(),
            per_host_mean_cpu: self.per_host_cpu.iter().map(|o| o.mean()).collect(),
            overhead: self.overhead.clone(),
            deferrals: self.counters.deferrals,
            per_shard: self.shard_counters.clone(),
            cold_starts: self.counters.cold_starts,
            warm_starts: self.counters.warm_starts,
            containers_expired: self.counters.containers_expired,
            cold_start_energy_j: self.counters.cold_start_energy_j,
            warm_pool_mean: self.warm_pool.mean(),
            // Digests flow back over the pool's result channel (the
            // distributed read path) rather than being walked in
            // place; a poisoned gather fails the report loudly.
            final_digests: self
                .pool
                .gather_digests(&self.cluster)
                .unwrap_or_else(|e| panic!("report digest gather: {e}")),
            interrupted_jobs: self.interrupted.len(),
            evacuations: self.counters.evacuations,
            mean_recovery_latency_s: self.recovery_latency.mean(),
            replacement_energy_j: self.counters.replacement_energy_j,
            host_crashes: self.counters.host_crashes,
            host_recoveries: self.counters.host_recoveries,
            migration_failures: self.counters.migration_failures,
            worker_panics: self.counters.worker_panics,
            quarantines: self.counters.quarantines,
            rack_crashes: self.counters.rack_crashes,
            degraded_hosts: self.counters.degraded_hosts,
            drains: self.counters.drains,
            checkpoints_taken: self.counters.checkpoints_taken,
            progress_saved_s: self.counters.progress_saved_s,
            checkpoint_energy_j: self.counters.checkpoint_energy_j,
            events_processed: self.events_processed,
            commits: self.store.commits(),
            commit_conflicts: self.store.conflicts(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fresh_state_is_empty() {
        let cfg = CampaignConfig::default();
        let st = CampaignState::new(&cfg);
        assert_eq!(st.cluster.n_hosts(), cfg.n_hosts);
        assert!(st.jobs.is_empty());
        assert!(st.vm_contexts(0.0).is_empty());
        assert_eq!(st.counters.deferrals, 0);
        let r = st.report("test", cfg.seed, 0.0);
        assert_eq!(r.jobs.len(), 0);
        assert_eq!(r.seed, cfg.seed);
        // Default config is a single shard covering the fleet.
        assert_eq!(r.per_shard.len(), 1);
        assert_eq!(r.final_digests.len(), 1);
        assert_eq!(r.final_digests[0].hosts, cfg.n_hosts);
        st.cluster.check_invariants().unwrap();
    }

    #[test]
    fn sharded_state_sizes_counters_to_shard_count() {
        let cfg = CampaignConfig {
            shard_count: 4,
            ..Default::default()
        };
        let st = CampaignState::new(&cfg);
        assert_eq!(st.shard_counters.len(), 4);
        assert_eq!(st.cluster.shard_count(), 4);
        st.cluster.check_invariants().unwrap();
    }
}
