//! Validated construction for [`CampaignConfig`]: a fluent builder
//! with cross-field checks, plus the [`LoopList`] carrier for control
//! loops registered through [`CampaignConfig::with_loop`].
//!
//! Struct-literal construction (`CampaignConfig { n_hosts: 8,
//! ..Default::default() }`) keeps working — the builder is the
//! validated front door for experiment harnesses, where a
//! tick-interval typo or a non-power-of-two shard count should fail
//! loudly at configuration time instead of panicking mid-campaign.

use crate::coordinator::leader::{CampaignConfig, EngineKind};
use crate::sched::ControlLoop;
use crate::sim::FaultConfig;
use crate::sla::SlaSpec;
use crate::workload::FaasConfig;
use std::fmt;

/// A cross-field validation failure from [`CampaignConfigBuilder::build`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ConfigError(pub String);

impl fmt::Display for ConfigError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "invalid campaign config: {}", self.0)
    }
}

impl std::error::Error for ConfigError {}

/// Control loops registered on a [`CampaignConfig`], appended after
/// the built-in wiring at campaign start. The list clones through
/// [`ControlLoop::box_clone`] (fresh configuration, no scan-to-scan
/// state), so one config can drive many runs.
#[derive(Default)]
pub struct LoopList(Vec<Box<dyn ControlLoop>>);

impl LoopList {
    pub fn push(&mut self, control: Box<dyn ControlLoop>) {
        self.0.push(control);
    }

    pub fn len(&self) -> usize {
        self.0.len()
    }

    pub fn is_empty(&self) -> bool {
        self.0.is_empty()
    }

    /// Registered loops, in registration order.
    pub fn iter(&self) -> impl Iterator<Item = &dyn ControlLoop> {
        self.0.iter().map(|b| b.as_ref())
    }
}

impl fmt::Debug for LoopList {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let names: Vec<&'static str> = self.0.iter().map(|l| l.name()).collect();
        f.debug_tuple("LoopList").field(&names).finish()
    }
}

impl Clone for LoopList {
    fn clone(&self) -> LoopList {
        LoopList(self.0.iter().map(|l| l.box_clone()).collect())
    }
}

/// Fluent, validated [`CampaignConfig`] construction:
///
/// ```
/// # use ecosched::coordinator::CampaignConfig;
/// let cfg = CampaignConfig::builder()
///     .hosts(16)
///     .shards(4)
///     .workers(2)
///     .seed(7)
///     .build()
///     .expect("valid campaign config");
/// assert_eq!(cfg.shard_count, 4);
/// ```
///
/// Every setter mirrors one config field; `build` runs the
/// cross-field checks and returns [`ConfigError`] on the first
/// violation.
#[derive(Debug, Clone, Default)]
pub struct CampaignConfigBuilder {
    cfg: CampaignConfig,
    /// Whether the caller set `tick_interval` explicitly — setting it
    /// while driving the event engine is the classic dead-knob
    /// mistake the builder exists to catch.
    tick_interval_set: bool,
}

impl CampaignConfig {
    /// Validated builder construction (struct literals with
    /// `..Default::default()` remain supported).
    pub fn builder() -> CampaignConfigBuilder {
        CampaignConfigBuilder::default()
    }

    /// Register an extra control loop, appended after the built-in
    /// wiring (keep-alive, consolidation, DVFS, power cap — in that
    /// documented order) in registration order.
    pub fn with_loop(mut self, control: Box<dyn ControlLoop>) -> CampaignConfig {
        self.extra_loops.push(control);
        self
    }
}

impl CampaignConfigBuilder {
    pub fn engine(mut self, engine: EngineKind) -> Self {
        self.cfg.engine = engine;
        self
    }

    /// Tick cadence for [`EngineKind::Tick`]. Setting this while the
    /// builder targets the event engine is a build error — the knob
    /// would be silently dead.
    pub fn tick_interval(mut self, dt: f64) -> Self {
        self.cfg.tick_interval = dt;
        self.tick_interval_set = true;
        self
    }

    pub fn hosts(mut self, n: usize) -> Self {
        self.cfg.n_hosts = n;
        self
    }

    pub fn shards(mut self, n: usize) -> Self {
        self.cfg.shard_count = n;
        self
    }

    pub fn workers(mut self, n: usize) -> Self {
        self.cfg.worker_threads = n;
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.cfg.seed = seed;
        self
    }

    pub fn sla(mut self, sla: SlaSpec) -> Self {
        self.cfg.sla = sla;
        self
    }

    pub fn consolidation(mut self, params: Option<crate::sched::ConsolidationParams>) -> Self {
        self.cfg.consolidation = params;
        self
    }

    pub fn dvfs(mut self, params: Option<crate::sched::DvfsParams>) -> Self {
        self.cfg.dvfs = params;
        self
    }

    pub fn power_cap(mut self, params: crate::sched::PowerCapParams) -> Self {
        self.cfg.power_cap = Some(params);
        self
    }

    pub fn faas(mut self, faas: FaasConfig) -> Self {
        self.cfg.faas = Some(faas);
        self
    }

    pub fn retry_backoff_base(mut self, base: f64) -> Self {
        self.cfg.retry_backoff_base = base;
        self
    }

    pub fn retry_max_attempts(mut self, attempts: u32) -> Self {
        self.cfg.retry_max_attempts = attempts;
        self
    }

    pub fn faults(mut self, faults: FaultConfig) -> Self {
        self.cfg.faults = Some(faults);
        self
    }

    /// Explicit host → rack map for correlated fault domains. Must
    /// cover every host (one entry per host, dense rack indices);
    /// omitted, racks default to the shard partition.
    pub fn rack_map(mut self, map: Vec<usize>) -> Self {
        self.cfg.rack_map = Some(map);
        self
    }

    pub fn scan_interval(mut self, interval: f64) -> Self {
        self.cfg.scan_interval = interval;
        self
    }

    pub fn meter_noise(mut self, noise: f64) -> Self {
        self.cfg.meter_noise = noise;
        self
    }

    pub fn telemetry_noise(mut self, noise: f64) -> Self {
        self.cfg.telemetry_noise = noise;
        self
    }

    pub fn max_sim_time(mut self, t: f64) -> Self {
        self.cfg.max_sim_time = t;
        self
    }

    /// Placement coordinators committing through the placement store
    /// (1 = the classic single leader).
    pub fn coordinators(mut self, n: usize) -> Self {
        self.cfg.coordinator_count = n;
        self
    }

    /// Commit-epoch staleness bound (see
    /// [`CampaignConfig::max_snapshot_lag`]).
    pub fn max_snapshot_lag(mut self, lag: u64) -> Self {
        self.cfg.max_snapshot_lag = lag;
        self
    }

    /// Append an extra control loop after the built-in wiring.
    pub fn with_loop(mut self, control: Box<dyn ControlLoop>) -> Self {
        self.cfg.extra_loops.push(control);
        self
    }

    /// Cross-field validation, then the finished config.
    pub fn build(self) -> Result<CampaignConfig, ConfigError> {
        let cfg = self.cfg;
        if cfg.n_hosts == 0 {
            return Err(ConfigError("n_hosts must be ≥ 1".into()));
        }
        if !cfg.shard_count.is_power_of_two() {
            return Err(ConfigError(format!(
                "shard_count must be a power of two (got {})",
                cfg.shard_count
            )));
        }
        if cfg.coordinator_count == 0 {
            return Err(ConfigError("coordinator_count must be ≥ 1".into()));
        }
        if self.tick_interval_set && cfg.engine != EngineKind::Tick {
            return Err(ConfigError(
                "tick_interval is set but the engine is Event — the knob would be dead \
                 (set .engine(EngineKind::Tick) or drop the tick_interval)"
                    .into(),
            ));
        }
        if cfg.engine == EngineKind::Tick && cfg.tick_interval <= 0.0 {
            return Err(ConfigError(format!(
                "tick_interval must be > 0 for the tick engine (got {})",
                cfg.tick_interval
            )));
        }
        if cfg.scan_interval <= 0.0 {
            return Err(ConfigError("scan_interval must be > 0".into()));
        }
        if cfg.retry_backoff_base <= 0.0 {
            return Err(ConfigError("retry_backoff_base must be > 0".into()));
        }
        if cfg.max_sim_time <= 0.0 {
            return Err(ConfigError("max_sim_time must be > 0".into()));
        }
        if let Some(f) = &cfg.faults {
            if let Some(interval) = f.checkpoint_interval_s {
                if !(interval > 0.0 && interval.is_finite()) {
                    return Err(ConfigError(format!(
                        "checkpoint_interval_s must be positive and finite (got {interval})"
                    )));
                }
            }
        }
        if let Some(map) = &cfg.rack_map {
            if map.len() != cfg.n_hosts {
                return Err(ConfigError(format!(
                    "rack_map must cover every host: {} entries for {} hosts",
                    map.len(),
                    cfg.n_hosts
                )));
            }
            if let Err(e) = crate::cluster::Topology::from_map(map.clone()) {
                return Err(ConfigError(format!("rack_map invalid: {e}")));
            }
        }
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_defaults_match_struct_default() {
        let built = CampaignConfig::builder().build().unwrap();
        let lit = CampaignConfig::default();
        assert_eq!(built.n_hosts, lit.n_hosts);
        assert_eq!(built.shard_count, lit.shard_count);
        assert_eq!(built.seed, lit.seed);
        assert_eq!(built.coordinator_count, 1);
        assert_eq!(built.max_snapshot_lag, lit.max_snapshot_lag);
        assert!(built.extra_loops.is_empty());
    }

    #[test]
    fn builder_sets_every_field_it_names() {
        let cfg = CampaignConfig::builder()
            .hosts(32)
            .shards(8)
            .workers(4)
            .seed(99)
            .coordinators(4)
            .max_snapshot_lag(16)
            .retry_max_attempts(5)
            .build()
            .unwrap();
        assert_eq!(cfg.n_hosts, 32);
        assert_eq!(cfg.shard_count, 8);
        assert_eq!(cfg.worker_threads, 4);
        assert_eq!(cfg.seed, 99);
        assert_eq!(cfg.coordinator_count, 4);
        assert_eq!(cfg.max_snapshot_lag, 16);
        assert_eq!(cfg.retry_max_attempts, 5);
    }

    #[test]
    fn tick_interval_without_tick_engine_is_an_error() {
        let err = CampaignConfig::builder()
            .tick_interval(0.5)
            .build()
            .unwrap_err();
        assert!(err.0.contains("tick_interval"), "got: {err}");
        // The same knob on the tick engine is fine.
        let cfg = CampaignConfig::builder()
            .engine(EngineKind::Tick)
            .tick_interval(0.5)
            .build()
            .unwrap();
        assert_eq!(cfg.tick_interval, 0.5);
    }

    #[test]
    fn non_power_of_two_shards_rejected() {
        let err = CampaignConfig::builder().shards(3).build().unwrap_err();
        assert!(err.0.contains("power of two"), "got: {err}");
    }

    #[test]
    fn zero_coordinators_rejected() {
        let err = CampaignConfig::builder().coordinators(0).build().unwrap_err();
        assert!(err.0.contains("coordinator_count"), "got: {err}");
    }

    #[test]
    fn checkpoint_interval_must_be_positive_and_finite() {
        for bad in [0.0, -30.0, f64::NAN, f64::INFINITY] {
            let err = CampaignConfig::builder()
                .faults(crate::sim::FaultConfig {
                    checkpoint_interval_s: Some(bad),
                    ..Default::default()
                })
                .build()
                .unwrap_err();
            assert!(err.0.contains("checkpoint_interval_s"), "got: {err}");
        }
        let cfg = CampaignConfig::builder()
            .faults(crate::sim::FaultConfig {
                checkpoint_interval_s: Some(60.0),
                ..Default::default()
            })
            .build()
            .unwrap();
        assert_eq!(
            cfg.faults.unwrap().checkpoint_interval_s,
            Some(60.0)
        );
    }

    #[test]
    fn rack_map_must_cover_every_host() {
        // Wrong length.
        let err = CampaignConfig::builder()
            .hosts(4)
            .rack_map(vec![0, 1])
            .build()
            .unwrap_err();
        assert!(err.0.contains("every host"), "got: {err}");
        // Sparse rack indices.
        let err = CampaignConfig::builder()
            .hosts(2)
            .rack_map(vec![0, 2])
            .build()
            .unwrap_err();
        assert!(err.0.contains("rack_map invalid"), "got: {err}");
        // A dense full-coverage map passes.
        let cfg = CampaignConfig::builder()
            .hosts(4)
            .rack_map(vec![0, 1, 0, 1])
            .build()
            .unwrap();
        assert_eq!(cfg.rack_map, Some(vec![0, 1, 0, 1]));
    }

    #[test]
    fn loop_list_registers_and_clones_fresh() {
        let cfg = CampaignConfig::builder()
            .with_loop(Box::new(crate::sched::DvfsGovernor::default()))
            .with_loop(Box::new(crate::workload::faas::KeepAliveLoop))
            .build()
            .unwrap();
        assert_eq!(cfg.extra_loops.len(), 2);
        let names: Vec<_> = cfg.extra_loops.iter().map(|l| l.name()).collect();
        assert_eq!(names, ["dvfs", "keep_alive"]);
        // Clone goes through box_clone and preserves order.
        let cloned = cfg.clone();
        let names: Vec<_> = cloned.extra_loops.iter().map(|l| l.name()).collect();
        assert_eq!(names, ["dvfs", "keep_alive"]);
        let dbg = format!("{:?}", cfg.extra_loops);
        assert!(dbg.contains("dvfs"), "got: {dbg}");
    }
}
