//! The coordinator: drives a full campaign — job arrivals, profiling,
//! predictive placement, consolidation scans, DVFS, migrations, power
//! management, SLA and energy accounting — over the discrete-event
//! engine. This is the system whose two configurations (round-robin
//! baseline vs energy-aware) the paper's evaluation compares.

use crate::cluster::{
    power::BOOT_SECS,
    Cluster, Demand, HostId, VmId, VmState,
};
use crate::coordinator::report::{CampaignReport, JobRecord, Overhead};
use crate::profile::{ExecutionRecord, HistoryStore, ResourceVector};
use crate::sched::{
    Action, Consolidator, Decision, DvfsGovernor, PlacementPolicy, PlacementRequest,
};
use crate::sim::{EnergyMeter, EventQueue, Telemetry, SAMPLE_INTERVAL};
use crate::sla::{SlaSpec, SlaTracker};
use crate::util::stats::Histogram;
use crate::workload::{flavor_for, Job, JobId, JobState};
use std::collections::BTreeMap;
use std::time::Instant;

/// Campaign configuration.
#[derive(Debug, Clone)]
pub struct CampaignConfig {
    pub n_hosts: usize,
    pub seed: u64,
    pub sla: SlaSpec,
    /// Consolidation scan settings (None disables the loop even for
    /// policies that want it — used by ablations).
    pub consolidation: Option<crate::sched::ConsolidationParams>,
    pub dvfs: Option<crate::sched::DvfsParams>,
    /// Seconds between consolidation/DVFS scans.
    pub scan_interval: f64,
    /// Watts-Up-Pro relative noise (0 disables).
    pub meter_noise: f64,
    /// dstat/perf sampling noise (0 disables).
    pub telemetry_noise: f64,
    /// Hard stop (simulated seconds).
    pub max_sim_time: f64,
}

impl Default for CampaignConfig {
    fn default() -> Self {
        CampaignConfig {
            n_hosts: 5,
            seed: 42,
            sla: SlaSpec::default(),
            consolidation: Some(crate::sched::ConsolidationParams::default()),
            dvfs: Some(crate::sched::DvfsParams::default()),
            scan_interval: 30.0,
            meter_noise: 0.01,
            telemetry_noise: 0.02,
            max_sim_time: 24.0 * 3600.0,
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq)]
enum Event {
    Submit(JobId),
    Tick,
    MigrationDone(VmId),
    RetryQueue,
}

/// The campaign driver.
pub struct Coordinator {
    pub config: CampaignConfig,
    policy: Box<dyn PlacementPolicy>,
    pub history: HistoryStore,
}

impl Coordinator {
    pub fn new(config: CampaignConfig, policy: Box<dyn PlacementPolicy>) -> Coordinator {
        Coordinator {
            config,
            policy,
            history: HistoryStore::new(),
        }
    }

    /// Run a campaign over the given trace. Deterministic per
    /// (config.seed, trace).
    pub fn run(&mut self, trace: Vec<Job>) -> CampaignReport {
        let cfg = self.config.clone();
        let mut cluster = Cluster::homogeneous(cfg.n_hosts);
        let mut meter = EnergyMeter::new(cfg.n_hosts, cfg.seed, cfg.meter_noise);
        let mut telemetry = Telemetry::new(cfg.n_hosts, cfg.seed, cfg.telemetry_noise);
        let mut sla = SlaTracker::new(cfg.sla);
        let mut consolidator = cfg.consolidation.map(Consolidator::new);
        let dvfs = cfg.dvfs.map(DvfsGovernor::new);
        let mut queue: EventQueue<Event> = EventQueue::new();
        let mut jobs: BTreeMap<JobId, Job> = BTreeMap::new();
        let mut vm_of_job: BTreeMap<JobId, VmId> = BTreeMap::new();
        let mut job_of_vm: BTreeMap<VmId, JobId> = BTreeMap::new();
        let mut profiles: BTreeMap<JobId, ResourceVector> = BTreeMap::new();
        let mut deferred: Vec<JobId> = Vec::new();
        let mut waiting_boot: Vec<(JobId, HostId)> = Vec::new();
        let mut job_energy: BTreeMap<JobId, f64> = BTreeMap::new();
        let mut job_stall: BTreeMap<JobId, f64> = BTreeMap::new();
        let mut pending_stalls: BTreeMap<VmId, f64> = BTreeMap::new();
        let mut overhead = Overhead::default();
        let mut migrations: u64 = 0;
        let mut migration_stall_s = 0.0;
        let mut deferrals: u64 = 0;
        let mut util_hist = Histogram::new(0.0, 1.0, 10);
        let mut per_host_cpu: Vec<crate::util::stats::Online> =
            (0..cfg.n_hosts).map(|_| crate::util::stats::Online::new()).collect();
        let mut host_off_s = 0.0;
        let n_jobs = trace.len();
        let mut completed = 0usize;
        // At most ONE RetryQueue event may be pending at a time —
        // otherwise k deferred jobs re-deferring from one retry spawn
        // k new retries (exponential event growth).
        let mut next_retry: Option<f64> = None;

        for job in trace {
            sla.register(job.id, job.solo_duration());
            queue.push(job.submit_at, Event::Submit(job.id));
            jobs.insert(job.id, job);
        }
        queue.push(1.0, Event::Tick);

        let mut last_scan = 0.0;
        let mut n_events: u64 = 0;
        while let Some((now, ev)) = queue.pop() {
            n_events += 1;
            if n_events % 1_000_000 == 0 {
                eprintln!("[coordinator] {n_events} events, sim t={now:.1}, queue len {}", queue.len());
            }
            if now > cfg.max_sim_time {
                break;
            }
            match ev {
                Event::Submit(id) => {
                    self.try_place(
                        now, id, &mut cluster, &mut jobs, &mut vm_of_job, &mut job_of_vm,
                        &mut profiles, &mut deferred, &mut waiting_boot, &mut queue,
                        &mut next_retry, &mut overhead, &mut deferrals,
                    );
                }
                Event::RetryQueue => {
                    next_retry = None;
                    let mut retry: Vec<JobId> = std::mem::take(&mut deferred);
                    // Boot completions are handled by the state machine;
                    // waiting_boot entries whose host is now On get placed.
                    // A host that was ShuttingDown when we asked for it
                    // ignored the power_on — ask again once it is Off.
                    let mut still_waiting = Vec::new();
                    for (id, host) in std::mem::take(&mut waiting_boot) {
                        if cluster.host(host).state.is_on() {
                            retry.push(id);
                        } else {
                            if cluster.host(host).state.is_off() {
                                cluster.host_mut(host).power_on(now);
                                request_retry(&mut queue, &mut next_retry, now + BOOT_SECS + 0.5);
                            }
                            still_waiting.push((id, host));
                        }
                    }
                    waiting_boot = still_waiting;
                    for id in retry {
                        self.try_place(
                            now, id, &mut cluster, &mut jobs, &mut vm_of_job, &mut job_of_vm,
                            &mut profiles, &mut deferred, &mut waiting_boot, &mut queue,
                            &mut next_retry, &mut overhead, &mut deferrals,
                        );
                    }
                }
                Event::MigrationDone(vm_id) => {
                    if matches!(
                        cluster.vms.get(&vm_id).map(|v| v.state),
                        Some(VmState::Migrating { .. })
                    ) {
                        cluster.finish_migration(vm_id);
                        // Stop-and-copy stall happens at cut-over, not
                        // during the pre-copy.
                        if let (Some(&job_id), Some(&stall)) =
                            (job_of_vm.get(&vm_id), pending_stalls.get(&vm_id))
                        {
                            jobs.get_mut(&job_id).unwrap().stall(now + stall);
                        }
                        pending_stalls.remove(&vm_id);
                    }
                }
                Event::Tick => {
                    let dt = 1.0;
                    cluster.advance_power_states(now);

                    // Gather per-VM demands from job phase state.
                    let mut demands: BTreeMap<VmId, Demand> = BTreeMap::new();
                    for (&vm_id, &job_id) in &job_of_vm {
                        let job = &jobs[&job_id];
                        if job.state == JobState::Running {
                            demands.insert(vm_id, job.current_demand(now));
                        }
                    }
                    cluster.apply_demands(&demands);

                    // Advance jobs under their hosts' contention.
                    let mut finished: Vec<(JobId, VmId)> = Vec::new();
                    for (&vm_id, &job_id) in &job_of_vm {
                        let vm = &cluster.vms[&vm_id];
                        if !vm.is_active() {
                            continue;
                        }
                        let host = match vm.state {
                            VmState::Migrating { from, .. } => from,
                            _ => vm.host.expect("active VM has host"),
                        };
                        let contention = cluster.host(host).contention();
                        if contention.0 < 0.999 || contention.1 < 0.999
                            || contention.2 < 0.999 || contention.3 < 0.999
                        {
                            log::debug!(
                                "t={now:.0} {job_id} on {host} contended {contention:?} demand {:?}",
                                cluster.host(host).demand
                            );
                        }
                        let job = jobs.get_mut(&job_id).unwrap();
                        if job.state == JobState::Running
                            && job.advance(now - dt, dt, contention)
                        {
                            finished.push((job_id, vm_id));
                        }
                    }

                    // Energy attribution, then metering.
                    for host in &cluster.hosts {
                        if !host.state.is_on() || host.vms.is_empty() {
                            continue;
                        }
                        let p = host.power();
                        let weights: Vec<f64> = host
                            .vms
                            .iter()
                            .map(|vm| {
                                demands
                                    .get(vm)
                                    .map(|d| {
                                        d.cpu / 32.0
                                            + d.mem_gb / 64.0
                                            + d.disk_mbps / 500.0
                                            + d.net_mbps / 117.0
                                    })
                                    .unwrap_or(0.0)
                                    .max(1e-6)
                            })
                            .collect();
                        let wsum: f64 = weights.iter().sum();
                        for (vm, w) in host.vms.iter().zip(&weights) {
                            if let Some(&job_id) = job_of_vm.get(vm) {
                                *job_energy.entry(job_id).or_default() += p * dt * w / wsum;
                            }
                        }
                    }
                    meter.sample(now, &cluster);
                    for h in &cluster.hosts {
                        if !h.state.is_on() {
                            host_off_s += dt;
                        }
                    }

                    // Telemetry at 5 s cadence.
                    if (now / SAMPLE_INTERVAL).fract().abs() < 1e-9 {
                        telemetry.sample(now, &cluster, &demands);
                        for h in &cluster.hosts {
                            if h.state.is_on() {
                                let u = h.utilization().cpu;
                                util_hist.push(u);
                                per_host_cpu[h.id.0].push(u);
                            }
                        }
                    }

                    // Consolidation + DVFS scans.
                    if now - last_scan >= cfg.scan_interval - 1e-9 {
                        last_scan = now;
                        let t0 = Instant::now();
                        if self.policy.wants_consolidation() {
                            if let Some(cons) = consolidator.as_mut() {
                                let mut ctxs = BTreeMap::new();
                                for (&vm_id, &job_id) in &job_of_vm {
                                    let job = &jobs[&job_id];
                                    if job.state != JobState::Running {
                                        continue;
                                    }
                                    let remaining = remaining_solo(job);
                                    let elapsed = now - job.started_at.unwrap_or(now);
                                    ctxs.insert(
                                        vm_id,
                                        crate::sched::VmContext {
                                            vector: profiles
                                                .get(&job_id)
                                                .copied()
                                                .unwrap_or_default(),
                                            remaining_solo: remaining,
                                            slack_left: sla.slack_left(
                                                job_id, elapsed, remaining,
                                            ),
                                        },
                                    );
                                }
                                let actions = {
                                    let predictor = policy_predictor(self.policy.as_mut());
                                    match predictor {
                                        Some(p) => cons.scan(now, &cluster, &telemetry, &ctxs, p),
                                        None => Vec::new(),
                                    }
                                };
                                for action in actions {
                                    match action {
                                        Action::PowerOff(h) => {
                                            if cluster.host(h).vms.is_empty()
                                                && cluster.host(h).state.is_on()
                                            {
                                                cluster.host_mut(h).power_off(now);
                                            }
                                        }
                                        Action::Migrate { vm, to } => {
                                            let link = link_headroom(&cluster, vm, to);
                                            if let Ok(cost) =
                                                cluster.start_migration(vm, to, now, link)
                                            {
                                                migrations += 1;
                                                migration_stall_s += cost.stall;
                                                pending_stalls.insert(vm, cost.stall);
                                                if let Some(&job_id) = job_of_vm.get(&vm) {
                                                    *job_stall.entry(job_id).or_default() +=
                                                        cost.stall;
                                                }
                                                queue.push(now + cost.duration,
                                                    Event::MigrationDone(vm));
                                            }
                                        }
                                    }
                                }
                            }
                            if let Some(gov) = dvfs.as_ref() {
                                for sf in gov.scan(&cluster, &telemetry) {
                                    cluster.host_mut(sf.host).set_freq(sf.freq);
                                }
                            }
                        }
                        overhead.scan_wall_s += t0.elapsed().as_secs_f64();
                    }

                    // Completions: release resources, record outcomes.
                    let had_finished = !finished.is_empty();
                    for (job_id, vm_id) in finished {
                        // A migration may still be in flight; cut it over
                        // so termination is clean.
                        if matches!(cluster.vms[&vm_id].state, VmState::Migrating { .. }) {
                            cluster.finish_migration(vm_id);
                        }
                        let migrations_n = cluster.vms[&vm_id].migrations;
                        cluster.terminate_vm(vm_id);
                        telemetry.forget_vm(vm_id);
                        let job = &jobs[&job_id];
                        let jct = job.jct().expect("finished job has jct");
                        sla.complete(job_id, jct);
                        completed += 1;
                        let profile = profiles.get(&job_id).copied().unwrap_or_default();
                        self.history.push(ExecutionRecord {
                            kind: job.kind,
                            gb: job.gb,
                            profile,
                            jct,
                            solo: job.solo_duration(),
                            energy_j: job_energy.get(&job_id).copied().unwrap_or(0.0),
                            host_cpu_mean: 0.0,
                        });
                        let _ = migrations_n;
                    }
                    if had_finished && !deferred.is_empty() {
                        request_retry(&mut queue, &mut next_retry, now);
                    }
                    if !deferred.is_empty() || !waiting_boot.is_empty() {
                        // Periodic retry while anything waits.
                        if (now as u64) % 15 == 0 {
                            request_retry(&mut queue, &mut next_retry, now + 0.5);
                        }
                    }
                    if completed < n_jobs {
                        queue.push_in(1.0, Event::Tick);
                    }
                }
            }
        }

        let makespan = queue.now();
        let idle_w = cluster.hosts[0].spec.power.p_idle;
        let jobs_out: Vec<JobRecord> = jobs
            .values()
            .filter(|j| j.state == JobState::Finished)
            .map(|j| {
                let jct = j.jct().unwrap();
                JobRecord {
                    id: j.id,
                    kind: j.kind,
                    gb: j.gb,
                    submit_at: j.submit_at,
                    jct,
                    solo: j.solo_duration(),
                    slowdown: jct / j.solo_duration() - 1.0,
                    energy_j: job_energy.get(&j.id).copied().unwrap_or(0.0),
                    wait: j.started_at.unwrap() - j.submit_at,
                    migrations: vm_of_job
                        .get(&j.id)
                        .and_then(|vm| cluster.vms.get(vm))
                        .map(|v| v.migrations)
                        .unwrap_or(0),
                    sla_met: sla.jobs()[&j.id].met.unwrap_or(false),
                }
            })
            .collect();

        CampaignReport {
            policy: self.policy.name(),
            seed: self.config.seed,
            makespan,
            energy_j: meter.total_j(),
            energy_true_j: meter.total_true_j(),
            active_energy_j: meter.active_j(idle_w, makespan),
            per_host_energy_j: meter.per_host_j().to_vec(),
            jobs: jobs_out,
            sla_compliance: sla.compliance(),
            sla_violations: sla.n_violations(),
            mean_slowdown: sla.mean_slowdown(),
            migrations,
            migration_stall_s,
            power_cycles: cluster.hosts.iter().map(|h| h.power_cycles).sum(),
            host_off_s,
            power_trace: meter.power_trace.clone(),
            hosts_on_trace: meter.hosts_on_trace.clone(),
            util_hist,
            per_host_mean_cpu: per_host_cpu.iter().map(|o| o.mean()).collect(),
            overhead,
            deferrals,
        }
    }

    /// Placement path: profile → classify → predict → place.
    #[allow(clippy::too_many_arguments)]
    fn try_place(
        &mut self,
        now: f64,
        id: JobId,
        cluster: &mut Cluster,
        jobs: &mut BTreeMap<JobId, Job>,
        vm_of_job: &mut BTreeMap<JobId, VmId>,
        job_of_vm: &mut BTreeMap<VmId, JobId>,
        profiles: &mut BTreeMap<JobId, ResourceVector>,
        deferred: &mut Vec<JobId>,
        waiting_boot: &mut Vec<(JobId, HostId)>,
        queue: &mut EventQueue<Event>,
        next_retry: &mut Option<f64>,
        overhead: &mut Overhead,
        deferrals: &mut u64,
    ) {
        let job = &jobs[&id];
        if job.state != JobState::Queued {
            return;
        }
        let t0 = Instant::now();
        let flavor = flavor_for(job.kind);
        // Eq. 1 profiling: history first (recurring kind), else the
        // phase model (the "static execution log" for a first run).
        let vector = self
            .history
            .mean_profile(job.kind)
            .unwrap_or_else(|| ResourceVector::from_phases(&job.phases, &flavor));
        profiles.insert(id, vector);
        let req = PlacementRequest {
            job: id,
            flavor,
            vector,
            remaining_solo: job.solo_duration(),
        };
        let decision = self.policy.decide(&req, cluster);
        overhead.n_decisions += 1;
        overhead.decision_wall_s += t0.elapsed().as_secs_f64();
        match decision {
            Decision::Place(host) => {
                let vm = cluster.create_vm(flavor, id, now);
                cluster
                    .place_vm(vm, host)
                    .expect("policy returned infeasible host");
                // Record the profiled mean demand for workload-aware
                // admission on later placements.
                cluster.vms.get_mut(&vm).unwrap().expected = crate::cluster::Demand {
                    cpu: vector.cpu * flavor.vcpus,
                    mem_gb: vector.mem * flavor.mem_gb,
                    disk_mbps: vector.disk * flavor.disk_mbps,
                    net_mbps: vector.net * flavor.net_mbps,
                };
                vm_of_job.insert(id, vm);
                job_of_vm.insert(vm, id);
                jobs.get_mut(&id).unwrap().start(now);
            }
            Decision::PowerOnAndPlace(host) => {
                cluster.host_mut(host).power_on(now);
                waiting_boot.push((id, host));
                request_retry(queue, next_retry, now + BOOT_SECS + 0.5);
            }
            Decision::Defer => {
                *deferrals += 1;
                deferred.push(id);
                request_retry(queue, next_retry, now + 5.0);
            }
        }
    }
}

/// Remaining solo seconds for a running job.
pub fn remaining_solo(job: &Job) -> f64 {
    let mut rem = job.phases[job.phase_idx].duration - job.phase_progress;
    for p in &job.phases[job.phase_idx + 1..] {
        rem += p.duration;
    }
    rem.max(0.0)
}

/// Usable migration bandwidth between a VM's host and the target.
fn link_headroom(cluster: &Cluster, vm: VmId, to: HostId) -> f64 {
    let from = match cluster.vms.get(&vm).and_then(|v| v.host) {
        Some(h) => h,
        None => return 50.0,
    };
    let cap = cluster.host(from).spec.net_mbps;
    let free_src = cap - cluster.host(from).demand.net_mbps - cluster.host(from).migration_net;
    let free_dst = cap - cluster.host(to).demand.net_mbps - cluster.host(to).migration_net;
    free_src.min(free_dst).clamp(10.0, 80.0)
}

/// Borrow the predictor out of an energy-aware policy for the
/// consolidation scan; other policies don't consolidate.
fn policy_predictor(
    policy: &mut dyn PlacementPolicy,
) -> Option<&mut (dyn crate::predict::EnergyPredictor + '_)> {
    policy
        .as_energy_aware()
        .map(|ea| ea.predictor.as_mut() as &mut dyn crate::predict::EnergyPredictor)
}

/// Schedule a RetryQueue event unless one is already pending at or
/// before `t` — prevents retry-event multiplication when many jobs
/// defer simultaneously.
fn request_retry(queue: &mut EventQueue<Event>, next_retry: &mut Option<f64>, t: f64) {
    match *next_retry {
        Some(x) if x <= t + 1e-9 => {}
        _ => {
            let at = t.max(queue.now());
            queue.push(at, Event::RetryQueue);
            *next_retry = Some(at);
        }
    }
}
