//! The coordinator: drives a full campaign — job arrivals, profiling,
//! batched predictive placement, the periodic control loops
//! (consolidation + DVFS), migrations, power management, SLA and
//! energy accounting — over the discrete-event engine. This is the
//! system whose two configurations (round-robin baseline vs
//! energy-aware) the paper's evaluation compares.
//!
//! Placement is batch-first: every submit burst and every deferred-
//! queue drain goes through [`PlacementPolicy::decide_batch`] against
//! one frozen [`ScheduleContext`], so a learned policy pays one
//! predictor invocation per burst instead of one per job. Bursts are
//! partitioned across `CampaignConfig::coordinator_count` schedulers
//! whose decisions commit through the central
//! [`crate::coordinator::PlacementStore`] in total order; a commit
//! the store can no longer justify — double-booked capacity, an
//! unavailable target, a stale snapshot epoch — is rejected back and
//! re-decided individually against the updated cluster, so the
//! admission guards see in-burst load exactly as the sequential path
//! would (the full conflict rules live in the crate-level "Commit
//! protocol" section).
//!
//! Cluster state is sharded (`CampaignConfig::shard_count`): the
//! leader routes every mutation through the
//! [`crate::cluster::ShardedCluster`] shard handles so the per-shard
//! digests stay consistent, attaches the shard layer to every
//! context it freezes (policies fan bursts out across shards, control
//! loops scan per shard), and tracks per-shard actuation counters in
//! [`CampaignState`]. `shard_count = 1` (the default) reproduces the
//! unsharded scheduler bit for bit.
//!
//! Per-shard work runs on a persistent [`crate::runtime::WorkerPool`]
//! owned by the campaign state (`CampaignConfig::worker_threads`,
//! default 1 = serial): worker threads spawn once per campaign,
//! placement sweeps and scan passes dispatch to their stable affinity
//! workers and merge deterministically, and shard digests flow back
//! to the coordinator over the pool's result channel at report time.
//! The coordinator thread remains the only writer of cluster state —
//! and the only epoch-bumper: workers see `&` shard interiors plus
//! their own cached scoring state (predictor clone + arenas,
//! invalidated by [`crate::predict::EnergyPredictor::weight_epoch`]
//! when retraining swaps weights).
//!
//! # Time advancement
//!
//! Two engines share this driver (`CampaignConfig::engine`). The
//! **event core** (the default) pops a time-ordered heap: job
//! completions are *predicted* events computed in closed form from
//! each host's current contention, epoch-stamped and invalidated
//! whenever the host's resident set or frequency changes (stale
//! predictions are skipped on pop — the stale-`MigrationDone` guard
//! generalized); control-loop scans and telemetry sampling are
//! self-re-arming scheduled events; host boot/shutdown windows are
//! `PowerTransition` events that price the transient draw exactly.
//! Per-host state is synchronized lazily (see
//! [`crate::coordinator::event_core`]), so sparse campaigns cost
//! events, not simulated seconds. The **tick engine**
//! (`EngineKind::Tick`) is the original fixed-cadence loop
//! (`tick_interval`), kept as the behavioral parity oracle: under
//! piecewise-constant contention aligned to the tick grid the two
//! engines produce equal reports (pinned by `tests/engine_equiv.rs`).
//!
//! Same-instant events in the event engine pop in a documented class
//! order (power edges, then faults, then submits, then the
//! default-class migration cutovers and retry drains FIFO, then
//! telemetry, scans, and job boundaries last — mirroring the intra-
//! tick ordering of the tick engine); the tick engine pushes
//! everything at the default class and remains pure FIFO,
//! bit-identical to the pre-event-core coordinator.
//!
//! # Fault handling
//!
//! With `CampaignConfig::faults` set, a [`crate::sim::FaultPlan`] —
//! generated up front from `(seed, config, cluster shape)` — is
//! pushed into the event queue before the first submit. Host crashes
//! kill resident VMs ([`crate::cluster::ShardedCluster::fail_host`]);
//! their jobs lose all progress and drain back through the ordinary
//! `decide_batch` retry path under bounded exponential backoff
//! (`retry_backoff_base`, capped attempts → the job is reported
//! interrupted). Recoveries pay a full boot, and are deferred by a
//! quarantine cooldown when the host is flapping (k crashes inside
//! the flap window). Telemetry blackouts mask whole shards' samples;
//! migration actuations can fail transiently per the plan's stateless
//! oracle; worker panic probes exercise the pool's self-healing.
//! Every resolution depends only on simulation state, so a faulted
//! campaign is bit-identical at any worker width.

use crate::cluster::{
    power::{BOOT_SECS, SHUTDOWN_SECS},
    Cluster, Demand, HostId, VmId, VmState, CONTAINER_BOOT_W,
};
use crate::coordinator::config::LoopList;
use crate::coordinator::event_core::EventCore;
use crate::coordinator::placement_store::{
    commit_order, target_shard, AllocationCommit, CommitOutcome, CommitRecord, RejectReason,
};
use crate::coordinator::report::CampaignReport;
use crate::coordinator::state::CampaignState;
use crate::profile::{ExecutionRecord, HistoryStore, ResourceVector};
use crate::runtime::{shard_pool, PoolError, WorkerSlot};
use crate::sched::{
    Consolidator, ControlAction, ControlLoop, Decision, DvfsGovernor, PlacementPolicy,
    PlacementRequest, ScheduleContext,
};
use crate::sim::engine::DEFAULT_CLASS;
use crate::sim::{EventQueue, FaultConfig, FaultKind, CHECKPOINT_J_PER_GB, SAMPLE_INTERVAL};
use crate::sla::SlaSpec;
use crate::workload::faas::{KeepAliveLoop, KeepAlivePolicy};
use crate::workload::{flavor_for, FaasConfig, Job, JobId, JobState};
use std::collections::{BTreeMap, VecDeque};
use std::time::Instant;

/// Which time-advancement core drives the campaign.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EngineKind {
    /// Fixed-cadence ticks every `tick_interval` simulated seconds —
    /// the original engine, kept as the behavioral parity oracle.
    Tick,
    /// Discrete-event heap with predicted completions, epoch
    /// invalidation, and priced power transients (the default).
    Event,
}

/// Campaign configuration.
#[derive(Debug, Clone)]
pub struct CampaignConfig {
    /// Time-advancement core (see [`EngineKind`]).
    pub engine: EngineKind,
    /// Tick cadence (simulated seconds) for `EngineKind::Tick`;
    /// ignored by the event core. Previously hard-coded to 1.0.
    pub tick_interval: f64,
    pub n_hosts: usize,
    /// Cluster shards (power of two). 1 = the whole fleet is one
    /// shard, which reproduces the unsharded scheduler exactly (the
    /// shard_count=1 property test pins this down); larger counts
    /// bound per-decision work by the top-K shards.
    pub shard_count: usize,
    /// Shard worker threads. 1 (the default) is the serial path —
    /// the behavioral oracle; larger widths dispatch per-shard
    /// placement sweeps and control-loop scan passes to a persistent
    /// [`crate::runtime::WorkerPool`] (spawned once per campaign),
    /// bit-identical to serial at any width. The default honors
    /// `PALLAS_WORKER_THREADS` so CI's worker-count matrix exercises
    /// the whole suite at both 1 and 8.
    pub worker_threads: usize,
    pub seed: u64,
    pub sla: SlaSpec,
    /// Consolidation scan settings (None disables the loop even for
    /// policies that want it — used by ablations).
    pub consolidation: Option<crate::sched::ConsolidationParams>,
    pub dvfs: Option<crate::sched::DvfsParams>,
    /// Cluster power capping (None = uncapped). Runs after
    /// consolidation and DVFS so the cap can override the governor.
    pub power_cap: Option<crate::sched::PowerCapParams>,
    /// Serverless sandbox semantics (cold starts, warm pools, the
    /// keep-alive expiry loop) for function-tagged jobs. `None` (the
    /// default) means such jobs run like plain VMs and nothing in the
    /// batch families changes.
    pub faas: Option<FaasConfig>,
    /// Base delay (s) for the bounded-exponential placement-retry
    /// backoff — attempt *k* re-polls after
    /// `base · 2^min(k−1, 7) · jitter`. Also the slack added to
    /// boot-wait re-polls (previously a hard-coded 0.5 s).
    pub retry_backoff_base: f64,
    /// Placement attempts per job before the coordinator gives up and
    /// reports the job as interrupted. The default is high enough
    /// that healthy campaigns never hit it; chaos experiments lower
    /// it to model real admission-control give-up.
    pub retry_max_attempts: u32,
    /// Deterministic fault injection (host crashes, telemetry
    /// blackouts, migration failures, worker panics, rack crashes,
    /// partial degradation). `None` (the default) replays the
    /// fault-free coordinator bit for bit.
    pub faults: Option<FaultConfig>,
    /// Explicit host → rack map for correlated fault domains (one
    /// entry per host, dense rack indices — validated by the
    /// builder). `None` (the default) uses the shard partition as the
    /// rack topology.
    pub rack_map: Option<Vec<usize>>,
    /// Seconds between control-loop scans.
    pub scan_interval: f64,
    /// Watts-Up-Pro relative noise (0 disables).
    pub meter_noise: f64,
    /// dstat/perf sampling noise (0 disables).
    pub telemetry_noise: f64,
    /// Hard stop (simulated seconds).
    pub max_sim_time: f64,
    /// Placement coordinators (≥ 1). Each submit burst is partitioned
    /// round-robin across N schedulers that decide against the same
    /// frozen pre-burst snapshot and commit through the placement
    /// store in total order; 1 (the default) reproduces the classic
    /// single-leader path bit for bit. The campaign driver runs the
    /// decide phases sequentially — what it models is decision
    /// *staleness* under contention, not wall-clock parallelism
    /// (`bench_commit` measures the latter with real threads).
    pub coordinator_count: usize,
    /// Commit-epoch staleness bound: a commit whose snapshot trails
    /// the target shard's live epoch by more than this many
    /// placement-visible mutations is rejected with `StaleSnapshot`
    /// and its coordinator refreshes before re-deciding. A
    /// coordinator always sees its own committed writes, so only
    /// *other* coordinators' commits accrue lag and the bound never
    /// fires with one coordinator.
    pub max_snapshot_lag: u64,
    /// Extra control loops appended after the built-in wiring (see
    /// [`default_loops`] for the ordering contract), registered via
    /// [`CampaignConfig::with_loop`]; cloned fresh per campaign run.
    pub extra_loops: LoopList,
}

impl Default for CampaignConfig {
    fn default() -> Self {
        CampaignConfig {
            engine: EngineKind::Event,
            tick_interval: 1.0,
            n_hosts: 5,
            shard_count: 1,
            worker_threads: shard_pool::env_workers(),
            seed: 42,
            sla: SlaSpec::default(),
            consolidation: Some(crate::sched::ConsolidationParams::default()),
            dvfs: Some(crate::sched::DvfsParams::default()),
            power_cap: None,
            faas: None,
            retry_backoff_base: 0.5,
            retry_max_attempts: 1000,
            faults: None,
            rack_map: None,
            scan_interval: 30.0,
            meter_noise: 0.01,
            telemetry_noise: 0.02,
            max_sim_time: 24.0 * 3600.0,
            coordinator_count: 1,
            max_snapshot_lag: 64,
            extra_loops: LoopList::default(),
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq)]
enum Event {
    Submit(JobId),
    Tick,
    MigrationDone(VmId),
    RetryQueue,
    /// A fault-plan entry (or a quarantine-deferred recovery).
    Fault(FaultKind),
    /// Event core: predicted next phase/stall boundary of the job on
    /// `vm`, stamped with the prediction epoch of its executing host.
    /// Dead (skipped on pop) unless the epoch still matches.
    JobAdvance { vm: VmId, epoch: u64 },
    /// Event core: self-re-arming control-loop scan cadence.
    Scan,
    /// Event core: self-re-arming 5 s telemetry/trace cadence.
    Telemetry,
    /// Event core: a host power-state edge — boot or shutdown window
    /// ends, or a container cold start retires.
    PowerTransition(HostId),
}

// Same-instant tie-break classes for the event engine (lower pops
// first; FIFO within a class). The tick engine never uses these —
// its plain pushes all carry `sim::engine::DEFAULT_CLASS` (128) and
// stay pure FIFO. `MigrationDone` and `RetryQueue` are pushed by
// engine-shared code and deliberately ride the default class: at
// equal timestamps they land after submits and before sampling,
// FIFO among themselves — matching where the tick engine's
// insertion order put them.
const CLASS_POWER: u8 = 0; // state edges settle before anything reads state
const CLASS_FAULT: u8 = 1; // crashes pre-empt same-instant arrivals
const CLASS_SUBMIT: u8 = 2; // arrivals before queue drains
const CLASS_TELEMETRY: u8 = 200; // sample before the scan reads the rings
const CLASS_SCAN: u8 = 210; // scans before completions (tick parity)
const CLASS_JOB: u8 = 220; // job boundaries and completions last

/// Push one batch of `(time, vm, epoch)` predictions as `JobAdvance`
/// events (event engine only).
fn push_preds(queue: &mut EventQueue<Event>, preds: Vec<(f64, VmId, u64)>) {
    for (t, vm, epoch) in preds {
        queue.push_class(t, CLASS_JOB, Event::JobAdvance { vm, epoch });
    }
}

/// Collect `h` into `hosts` once.
fn push_unique(hosts: &mut Vec<HostId>, h: HostId) {
    if !hosts.contains(&h) {
        hosts.push(h);
    }
}

/// The campaign driver.
pub struct Coordinator {
    pub config: CampaignConfig,
    policy: Box<dyn PlacementPolicy>,
    pub history: HistoryStore,
    /// The total-order commit log of the last [`Coordinator::run`]
    /// (drained from the placement store at campaign end). Feed it to
    /// [`Coordinator::with_replay`] to reproduce an N-coordinator
    /// campaign with a single coordinator, bit for bit.
    pub commit_log: Vec<CommitRecord>,
    /// Replay mode: actuate this recorded log instead of deciding.
    replay: Option<VecDeque<CommitRecord>>,
}

impl Coordinator {
    pub fn new(config: CampaignConfig, policy: Box<dyn PlacementPolicy>) -> Coordinator {
        Coordinator {
            config,
            policy,
            history: HistoryStore::new(),
            commit_log: Vec::new(),
            replay: None,
        }
    }

    /// Replay a recorded commit log: the decide phase is skipped and
    /// every burst actuates its records in their appended (total)
    /// order instead. Run against the same trace, this reproduces the
    /// recording campaign's report bit for bit regardless of how many
    /// coordinators recorded it — the determinism contract of the
    /// commit protocol (pinned by `tests/commit.rs`). The policy is
    /// still consulted for `scoring_handle`/`wants_consolidation`
    /// wiring, never for placement decisions.
    pub fn with_replay(
        config: CampaignConfig,
        policy: Box<dyn PlacementPolicy>,
        log: Vec<CommitRecord>,
    ) -> Coordinator {
        let mut coord = Coordinator::new(config, policy);
        coord.replay = Some(log.into());
        coord
    }

    /// Run a campaign over the given trace. Deterministic per
    /// (config.seed, trace).
    pub fn run(&mut self, trace: Vec<Job>) -> CampaignReport {
        let cfg = self.config.clone();
        let mut st = CampaignState::new(&cfg);
        // The serverless keep-alive policy lives outside the loop list:
        // it is observed on every arrival (IAT histograms), not just on
        // the scan cadence.
        let mut keep_alive: Option<Box<dyn KeepAlivePolicy>> =
            cfg.faas.as_ref().map(|f| f.keep_alive.build());
        // The periodic control loops, unified behind one trait: the
        // built-in wiring (see [`default_loops`] for the ordering
        // contract), then any loops registered through
        // [`CampaignConfig::with_loop`], in registration order.
        let mut loops: Vec<Box<dyn ControlLoop>> =
            default_loops(&cfg, self.policy.wants_consolidation());
        for control in cfg.extra_loops.iter() {
            loops.push(control.box_clone());
        }
        let mut queue: EventQueue<Event> = EventQueue::new();
        let event_mode = cfg.engine == EngineKind::Event;
        st.n_jobs = trace.len();
        for job in trace {
            st.sla.register(job.id, job.solo_duration());
            if event_mode {
                queue.push_class(job.submit_at, CLASS_SUBMIT, Event::Submit(job.id));
            } else {
                queue.push(job.submit_at, Event::Submit(job.id));
            }
            st.jobs.insert(job.id, job);
        }
        if event_mode {
            // Self-re-arming cadence chains; an empty trace needs
            // neither (the campaign ends immediately).
            if st.n_jobs > 0 {
                queue.push_class(SAMPLE_INTERVAL, CLASS_TELEMETRY, Event::Telemetry);
                queue.push_class(cfg.scan_interval, CLASS_SCAN, Event::Scan);
            }
        } else {
            queue.push(cfg.tick_interval, Event::Tick);
        }
        // Seed the fault schedule: the whole plan is closed over
        // before the first event pops, so the same faults fire at the
        // same simulated times regardless of how the campaign
        // unfolds (the chaos determinism contract).
        for e in st.fault_plan.events() {
            if e.t < cfg.max_sim_time {
                if event_mode {
                    queue.push_class(e.t.max(0.0), CLASS_FAULT, Event::Fault(e.kind));
                } else {
                    queue.push(e.t.max(0.0), Event::Fault(e.kind));
                }
            }
        }

        let mut core = if event_mode {
            Some(EventCore::new(&st))
        } else {
            None
        };
        // Set once every job is settled: the event core's energy and
        // off-time horizon ends where the tick engine's final tick
        // would have; trailing cadence events no longer integrate.
        let mut flushed = false;
        let mut last_scan = 0.0;
        while let Some((now, ev)) = queue.pop() {
            st.events_processed += 1;
            if st.events_processed % 1_000_000 == 0 {
                eprintln!(
                    "[coordinator] {} events, sim t={now:.1}, queue len {}",
                    st.events_processed,
                    queue.len()
                );
            }
            if now > cfg.max_sim_time {
                break;
            }
            match ev {
                Event::Submit(id) => {
                    // Coalesce the whole same-instant submit burst into
                    // one batched decision (consecutive head events
                    // only, so FIFO tie-breaking is preserved).
                    let mut burst = vec![id];
                    loop {
                        let next = match queue.peek() {
                            Some((t, &Event::Submit(next))) if t <= now => next,
                            _ => break,
                        };
                        burst.push(next);
                        queue.pop();
                    }
                    // Feed the keep-alive policy every function arrival
                    // exactly once (here, not in place_batch — retries
                    // would double-count the inter-arrival histograms).
                    if let Some(ka) = keep_alive.as_deref_mut() {
                        for id in &burst {
                            if let Some(f) = st.jobs[id].function {
                                ka.observe_arrival(f, now);
                            }
                        }
                    }
                    self.place_batch(now, CLASS_SUBMIT, &burst, &mut st, &mut queue, core.as_mut());
                }
                Event::RetryQueue => {
                    st.next_retry = None;
                    let mut retry: Vec<JobId> = std::mem::take(&mut st.deferred);
                    // Boot completions are handled by the state machine;
                    // waiting_boot entries whose host is now On get placed.
                    // A host that was ShuttingDown when we asked for it
                    // ignored the power_on — ask again once it is Off.
                    let mut still_waiting = Vec::new();
                    for (id, host) in std::mem::take(&mut st.waiting_boot) {
                        let hstate = st.cluster.host(host).state;
                        if hstate.is_on() {
                            retry.push(id);
                        } else if hstate.is_failed() {
                            // The host crashed while we waited for its
                            // boot: place the job somewhere else.
                            retry.push(id);
                        } else {
                            if hstate.is_off() {
                                if let Some(core) = core.as_mut() {
                                    // Settle the off-segment, then price
                                    // the boot window it is entering.
                                    core.sync_host(&mut st, host, now);
                                }
                                st.cluster.power_on(host, now);
                                if let Some(core) = core.as_mut() {
                                    core.refresh_power(&st, host);
                                    queue.push_class(
                                        now + BOOT_SECS,
                                        CLASS_POWER,
                                        Event::PowerTransition(host),
                                    );
                                }
                                request_retry(
                                    &mut queue,
                                    &mut st.next_retry,
                                    now + BOOT_SECS + cfg.retry_backoff_base,
                                );
                            }
                            still_waiting.push((id, host));
                        }
                    }
                    st.waiting_boot = still_waiting;
                    // Drain the whole retry queue through one batch.
                    // Retry drains ride the default event class, so
                    // their commits sort after same-instant submits —
                    // exactly where the event heap pops them.
                    self.place_batch(now, DEFAULT_CLASS, &retry, &mut st, &mut queue, core.as_mut());
                }
                Event::MigrationDone(vm_id) => {
                    // The `done` guard drops events staled by a
                    // crash-cancelled copy: if the VM has since begun
                    // a *new* migration, its `done` lies in the
                    // future and the stale event must not cut it
                    // over early.
                    if matches!(
                        st.cluster.vms.get(&vm_id).map(|v| v.state),
                        Some(VmState::Migrating { done, .. }) if done <= now + 1e-9
                    ) {
                        // Event core: close both hosts' segments at the
                        // pre-cutover wattage before the resident set
                        // and migration traffic change.
                        let peers = match (core.as_mut(), st.cluster.vms[&vm_id].state) {
                            (Some(core), VmState::Migrating { from, to, .. }) => {
                                core.sync_host(&mut st, from, now);
                                core.sync_host(&mut st, to, now);
                                Some((from, to))
                            }
                            _ => None,
                        };
                        st.cluster.finish_migration(vm_id);
                        // Stop-and-copy stall happens at cut-over, not
                        // during the pre-copy.
                        if let (Some(&job_id), Some(&stall)) =
                            (st.job_of_vm.get(&vm_id), st.pending_stalls.get(&vm_id))
                        {
                            st.jobs.get_mut(&job_id).unwrap().stall(now + stall);
                        }
                        st.pending_stalls.remove(&vm_id);
                        if let (Some(core), Some((from, to))) = (core.as_mut(), peers) {
                            let preds = core.reschedule_host(&mut st, from, now);
                            push_preds(&mut queue, preds);
                            let preds = core.reschedule_host(&mut st, to, now);
                            push_preds(&mut queue, preds);
                        }
                    }
                }
                Event::Tick => {
                    self.tick(
                        now,
                        &mut st,
                        &mut queue,
                        &mut loops,
                        &mut last_scan,
                        &cfg,
                        keep_alive.as_deref(),
                    );
                    // Interrupted jobs will never complete; counting
                    // them keeps the tick re-arm (and hence the
                    // campaign) from idling forever on abandoned work.
                    if st.counters.completed + st.interrupted.len() < st.n_jobs {
                        queue.push_in(cfg.tick_interval, Event::Tick);
                    }
                }
                Event::Fault(kind) => {
                    self.handle_fault(
                        now,
                        kind,
                        &mut st,
                        &mut queue,
                        keep_alive.as_deref(),
                        core.as_mut(),
                    );
                }
                Event::JobAdvance { vm, epoch } => {
                    if let Some(core) = core.as_mut() {
                        // Resolve the executing host; a dead VM or a
                        // stale epoch (the host's resident set or
                        // frequency changed since the prediction)
                        // skips the event.
                        let host = st.cluster.vms.get(&vm).and_then(|v| match v.state {
                            VmState::Migrating { from, .. } => Some(from),
                            _ => v.host,
                        });
                        if let Some(h) = host {
                            if core.is_current(h, epoch) {
                                core.sync_host(&mut st, h, now);
                                if !core.has_pending() {
                                    // A non-completing boundary (phase
                                    // crossing or stall expiry) still
                                    // changes demand: re-predict.
                                    let preds = core.reschedule_host(&mut st, h, now);
                                    push_preds(&mut queue, preds);
                                }
                                // Completions settle in the drain below,
                                // which also reschedules this host.
                            }
                        }
                    }
                }
                Event::Telemetry => {
                    if let Some(core) = core.as_mut() {
                        // Mirror of the tick engine's 5 s sampling
                        // block, fed from the maintained demand map;
                        // blackout masking identical.
                        if st.blackout_until.iter().any(|&u| u > now) {
                            let masked: Vec<bool> = st
                                .cluster
                                .hosts
                                .iter()
                                .map(|h| st.blackout_until[st.cluster.shard_of(h.id)] > now)
                                .collect();
                            st.telemetry
                                .sample_masked(now, &st.cluster, &core.cur_demand, &masked);
                        } else {
                            st.telemetry.sample(now, &st.cluster, &core.cur_demand);
                        }
                        for h in &st.cluster.hosts {
                            if h.state.is_on() {
                                let u = h.utilization().cpu;
                                st.util_hist.push(u);
                                st.per_host_cpu[h.id.0].push(u);
                            }
                        }
                        if cfg.faas.is_some() {
                            let warm: usize =
                                st.cluster.digests().iter().map(|d| d.warm_containers).sum();
                            st.warm_pool.push(warm as f64);
                        }
                        st.meter.trace_point(now, core.fleet_w, st.cluster.hosts_on());
                        if st.counters.completed + st.interrupted.len() < st.n_jobs {
                            queue.push_class_in(SAMPLE_INTERVAL, CLASS_TELEMETRY, Event::Telemetry);
                        }
                    }
                }
                Event::Scan => {
                    if let Some(core) = core.as_mut() {
                        // Bring every populated host current so the
                        // control loops see live phase progress, as
                        // they would under the tick engine.
                        let populated: Vec<HostId> = st
                            .cluster
                            .hosts
                            .iter()
                            .filter(|h| !h.vms.is_empty())
                            .map(|h| h.id)
                            .collect();
                        for h in populated {
                            core.sync_host(&mut st, h, now);
                        }
                        if core.has_pending() {
                            self.finish_batch(
                                now,
                                &mut st,
                                &mut queue,
                                keep_alive.as_deref(),
                                &mut *core,
                            );
                        }
                        if !loops.is_empty() {
                            let t0 = Instant::now();
                            self.run_control_loops(
                                now,
                                &mut st,
                                &mut queue,
                                &mut loops,
                                Some(&mut *core),
                            );
                            st.overhead.scan_wall_s += t0.elapsed().as_secs_f64();
                        }
                        // Retry safety net (the tick engine's periodic
                        // poll): anything still parked re-polls on the
                        // scan cadence.
                        if !st.deferred.is_empty() || !st.waiting_boot.is_empty() {
                            request_retry(
                                &mut queue,
                                &mut st.next_retry,
                                now + cfg.retry_backoff_base,
                            );
                        }
                        if st.counters.completed + st.interrupted.len() < st.n_jobs {
                            queue.push_class_in(cfg.scan_interval, CLASS_SCAN, Event::Scan);
                        }
                    }
                }
                Event::PowerTransition(h) => {
                    if let Some(core) = core.as_mut() {
                        // Close the transient segment at the boot/
                        // shutdown draw cached when the window opened,
                        // then advance the state machine (which also
                        // retires due container cold starts) and
                        // re-price. Resident contention is unchanged,
                        // so outstanding predictions stay live.
                        core.sync_host(&mut st, h, now);
                        st.cluster.advance_host(h, now);
                        core.refresh_power(&st, h);
                        // A host that just reached Off may strand boot-
                        // waiters whose power_on was refused while it
                        // was still ShuttingDown.
                        if st.cluster.host(h).state.is_off()
                            && st.waiting_boot.iter().any(|&(_, bh)| bh == h)
                        {
                            request_retry(
                                &mut queue,
                                &mut st.next_retry,
                                now + cfg.retry_backoff_base,
                            );
                        }
                    }
                }
            }
            if let Some(core) = core.as_mut() {
                // Settle completions any sync in this event surfaced.
                if core.has_pending() {
                    self.finish_batch(now, &mut st, &mut queue, keep_alive.as_deref(), &mut *core);
                }
                if !flushed
                    && st.n_jobs > 0
                    && st.counters.completed + st.interrupted.len() >= st.n_jobs
                {
                    core.flush_all(&mut st, now);
                    flushed = true;
                }
            }
        }
        if let Some(core) = core.as_mut() {
            if !flushed {
                // The campaign was cut short (max_sim_time, or the
                // queue drained with work parked forever): settle
                // energy/off-time up to where the tick engine's last
                // tick would have landed.
                let horizon = queue.now().min(cfg.max_sim_time);
                core.flush_all(&mut st, horizon);
                if core.has_pending() {
                    self.finish_batch(
                        horizon,
                        &mut st,
                        &mut queue,
                        keep_alive.as_deref(),
                        &mut *core,
                    );
                }
            }
        }

        // Hand the total-order commit log to the caller (the store's
        // commit/conflict counters stay behind for the report).
        self.commit_log = st.store.take_log();
        st.report(self.policy.name(), self.config.seed, queue.now())
    }

    /// Apply one fault-plan event. Every resolution here depends only
    /// on simulation state (never on wall clock or worker width), so
    /// replays are bit-identical.
    #[allow(clippy::too_many_arguments)]
    fn handle_fault(
        &mut self,
        now: f64,
        kind: FaultKind,
        st: &mut CampaignState,
        queue: &mut EventQueue<Event>,
        keep_alive: Option<&dyn KeepAlivePolicy>,
        mut core: Option<&mut EventCore>,
    ) {
        match kind {
            FaultKind::HostCrash(h) => {
                self.handle_host_crash(now, h, st, queue, keep_alive, core);
            }
            FaultKind::RackCrash { rack, downtime_s } => {
                // Correlated fail-stop: every powered-on member of
                // the rack crashes at the same instant, in ascending
                // host order (the order is part of the deterministic
                // replay). Each victim gets its own recovery event,
                // so per-host quarantine logic applies to rack
                // victims unchanged; a stale recovery (host was not
                // On when the rack went down) is dropped by the
                // HostRecover guard.
                st.counters.rack_crashes += 1;
                let members: Vec<HostId> = (0..st.cluster.n_hosts())
                    .map(HostId)
                    .filter(|&m| st.cluster.host(m).rack == rack)
                    .collect();
                for m in members {
                    if !st.cluster.host(m).state.is_on() {
                        continue;
                    }
                    self.handle_host_crash(now, m, st, queue, keep_alive, core.as_deref_mut());
                    if core.is_some() {
                        queue.push_class(
                            now + downtime_s,
                            CLASS_FAULT,
                            Event::Fault(FaultKind::HostRecover(m)),
                        );
                    } else {
                        queue.push(now + downtime_s, Event::Fault(FaultKind::HostRecover(m)));
                    }
                }
            }
            FaultKind::Degrade { host, condition } => {
                // Partial degradation only lands on a powered-on
                // host; like a crash on a parked host, the episode is
                // otherwise dropped (its paired Restore then no-ops).
                if !st.cluster.host(host).state.is_on() {
                    return;
                }
                if let Some(core) = core.as_deref_mut() {
                    // Effective capacity (and possibly the clock) is
                    // about to shrink: settle residents at the
                    // healthy rates first.
                    core.sync_host(st, host, now);
                }
                st.cluster.degrade_host(host, condition);
                st.counters.degraded_hosts += 1;
                if let Some(core) = core.as_deref_mut() {
                    core.refresh_power(st, host);
                    let preds = core.reschedule_host(st, host, now);
                    push_preds(queue, preds);
                }
            }
            FaultKind::Restore { host } => {
                // The condition layer is orthogonal to the power
                // machine: a restore clears the condition even on a
                // host that crashed or parked while degraded (no-op
                // if it was never degraded), but only a running host
                // needs settling and re-prediction.
                let on = st.cluster.host(host).state.is_on();
                if on {
                    if let Some(core) = core.as_deref_mut() {
                        core.sync_host(st, host, now);
                    }
                }
                st.cluster.restore_host(host);
                if on {
                    if let Some(core) = core.as_deref_mut() {
                        core.refresh_power(st, host);
                        let preds = core.reschedule_host(st, host, now);
                        push_preds(queue, preds);
                    }
                }
            }
            FaultKind::HostRecover(h) => {
                // Stale if the crash itself was dropped (or the host
                // somehow recovered already).
                if !st.cluster.host(h).state.is_failed() {
                    return;
                }
                let fcfg = self
                    .config
                    .faults
                    .as_ref()
                    .expect("recovery event without fault config");
                let flapping = st
                    .crash_history
                    .get(&h)
                    .map(|ts| {
                        ts.iter().filter(|&&t| now - t <= fcfg.flap_window_s).count()
                            >= fcfg.flap_threshold
                    })
                    .unwrap_or(false);
                if flapping && !st.quarantine_deferred.contains(&h) {
                    // Quarantine = delayed recovery: the host stays
                    // Failed (excluded from every scoring view and
                    // control loop for free) until the cooldown, when
                    // this same event fires again and proceeds.
                    st.quarantine_deferred.insert(h);
                    st.counters.quarantines += 1;
                    if core.is_some() {
                        queue.push_class(
                            now + fcfg.quarantine_s,
                            CLASS_FAULT,
                            Event::Fault(FaultKind::HostRecover(h)),
                        );
                    } else {
                        queue.push(
                            now + fcfg.quarantine_s,
                            Event::Fault(FaultKind::HostRecover(h)),
                        );
                    }
                    return;
                }
                st.quarantine_deferred.remove(&h);
                if let Some(core) = core.as_deref_mut() {
                    // Settle the failed (BMC-draw) segment, then price
                    // the recovery reboot it is entering.
                    core.sync_host(st, h, now);
                }
                st.cluster.recover_host(h, now);
                st.counters.host_recoveries += 1;
                if let Some(core) = core.as_deref_mut() {
                    core.refresh_power(st, h);
                    queue.push_class(now + BOOT_SECS, CLASS_POWER, Event::PowerTransition(h));
                }
            }
            FaultKind::BlackoutStart { shard, until } => {
                if let Some(u) = st.blackout_until.get_mut(shard) {
                    *u = u.max(until);
                }
            }
            FaultKind::WorkerPanic => {
                // A panic probe through the scoring pool: the dispatch
                // fails once with WorkerPanicked and the pool heals —
                // the next fan-out (placement or scan) must succeed.
                // The serial pool catches the panic identically, so
                // state evolution matches at every width.
                st.counters.worker_panics += 1;
                let probe: Vec<(usize, fn(&mut WorkerSlot))> =
                    vec![(0, |_| panic!("injected fault-plan worker panic"))];
                match st.pool.dispatch(probe) {
                    Err(PoolError::WorkerPanicked(_)) => {}
                    Err(PoolError::Poisoned) => {
                        panic!("worker pool failed to heal after injected panic")
                    }
                    Ok(_) => unreachable!("panic probe cannot succeed"),
                }
            }
        }
    }

    /// Fail-stop crash of one host: settle it (and its migration
    /// peers) in the event core, kill residents, requeue their jobs —
    /// rewound to the last checkpoint boundary when checkpointing is
    /// on — and queue the evacuations. Shared by the independent-
    /// crash and rack-crash fault arms; a crash scheduled for a host
    /// that is off/booting/already failed is dropped (the plan is
    /// generated blind to power state).
    #[allow(clippy::too_many_arguments)]
    fn handle_host_crash(
        &mut self,
        now: f64,
        h: HostId,
        st: &mut CampaignState,
        queue: &mut EventQueue<Event>,
        keep_alive: Option<&dyn KeepAlivePolicy>,
        mut core: Option<&mut EventCore>,
    ) {
        if !st.cluster.host(h).state.is_on() {
            return;
        }
        // Event core: the crashed host and any migration peers
        // (sources feeding it, destinations it feeds) must be brought
        // current at the pre-crash wattage before fail_host rewrites
        // resident sets and migration traffic. A job that crosses its
        // finish line in this sync completes *before* the crash lands
        // — at the same instant, completion wins (the tick engine,
        // with its coarser grid, cannot make this distinction).
        let mut peers: Vec<HostId> = Vec::new();
        if let Some(core) = core.as_deref_mut() {
            push_unique(&mut peers, h);
            for vm in st.cluster.vms.values() {
                if let VmState::Migrating { from, to, .. } = vm.state {
                    if to == h {
                        push_unique(&mut peers, from);
                    } else if from == h {
                        push_unique(&mut peers, to);
                    }
                }
            }
            for &p in &peers {
                core.sync_host(st, p, now);
            }
            if core.has_pending() {
                self.finish_batch(now, st, queue, keep_alive, core);
            }
        }
        st.crash_history.entry(h).or_default().push(now);
        let shard = st.cluster.shard_of(h);
        let rack = st.cluster.host(h).rack;
        let ckpt = self
            .config
            .faults
            .as_ref()
            .and_then(|f| f.checkpoint_interval_s);
        let outcome = st.cluster.fail_host(h, now);
        st.counters.host_crashes += 1;
        st.shard_counters[shard].crashes += 1;
        // Copies that were inbound to the crashed host were cancelled
        // (their VMs keep running on the source); the stall owed at
        // their cut-over is void.
        for vm in &outcome.cancelled_incoming {
            st.pending_stalls.remove(vm);
        }
        // Resident VMs are dead: their jobs rewind to the last
        // checkpoint boundary (to zero without checkpointing) and
        // enter the evacuation queue, drained through the ordinary
        // decide_batch retry path. Only the *unsaved* fraction of a
        // job's energy is work the campaign pays for twice.
        let mut evacuate: Vec<JobId> = Vec::new();
        for vm in &outcome.killed {
            st.telemetry.forget_vm(*vm);
            if let Some(core) = core.as_deref_mut() {
                core.forget_vm(*vm);
            }
            st.pending_stalls.remove(vm);
            if let Some(job_id) = st.job_of_vm.remove(vm) {
                if st.jobs[&job_id].state == JobState::Running {
                    let progress = st.jobs[&job_id].progress_time();
                    let spent = st.job_energy.get(&job_id).copied().unwrap_or(0.0);
                    // Checkpoints written since the last restart are
                    // real work: bill them before the rewind resets
                    // the billing base.
                    self.charge_checkpoints(st, job_id, progress);
                    let saved = st
                        .jobs
                        .get_mut(&job_id)
                        .unwrap()
                        .requeue_after_crash(now, ckpt);
                    st.counters.evacuations += 1;
                    st.shard_counters[shard].evacuated_vms += 1;
                    let wasted = if progress > 0.0 {
                        spent * (progress - saved) / progress
                    } else {
                        spent
                    };
                    st.counters.replacement_energy_j += wasted;
                    st.counters.progress_saved_s += saved;
                    st.evacuated_at.insert(job_id, now);
                    // Re-placement prefers a different rack: remember
                    // where the crash was until the job lands again.
                    st.evacuated_rack.insert(job_id, rack);
                    evacuate.push(job_id);
                }
            }
        }
        // Jobs parked on this host's boot queue will never see it
        // come up; re-place them elsewhere.
        let mut still = Vec::new();
        for (id, host) in std::mem::take(&mut st.waiting_boot) {
            if host == h {
                evacuate.push(id);
            } else {
                still.push((id, host));
            }
        }
        st.waiting_boot = still;
        if !evacuate.is_empty() {
            st.deferred.extend(evacuate);
            let delay = self.config.retry_backoff_base * st.retry_jitter();
            request_retry(queue, &mut st.next_retry, now + delay);
        }
        // Event core: the crash changed resident sets and migration
        // traffic on every peer — bump epochs (which strands
        // outstanding predictions) and re-predict.
        if let Some(core) = core.as_deref_mut() {
            for &p in &peers {
                let preds = core.reschedule_host(st, p, now);
                push_preds(queue, preds);
            }
        }
    }

    /// Bill the checkpoints `job_id` wrote between its last restart
    /// point and `progress` solo seconds: one write per interval
    /// boundary crossed, each costing the VM flavor's memory
    /// footprint at [`CHECKPOINT_J_PER_GB`]. Charged to the job (it
    /// shows up in per-job energy, hence the fingerprint) and to the
    /// campaign ledger — additive to metered host energy, like
    /// cold-start boot draw. A no-op when checkpointing is off.
    fn charge_checkpoints(&self, st: &mut CampaignState, job_id: JobId, progress: f64) {
        let interval = match self
            .config
            .faults
            .as_ref()
            .and_then(|f| f.checkpoint_interval_s)
        {
            Some(i) if i > 0.0 => i,
            _ => return,
        };
        let base = st.jobs[&job_id].restored_from;
        let n = ((progress / interval).floor() - (base / interval).floor()).max(0.0) as u64;
        if n == 0 {
            return;
        }
        let mem_gb = flavor_for(st.jobs[&job_id].kind).mem_gb;
        let joules = n as f64 * mem_gb * CHECKPOINT_J_PER_GB;
        st.counters.checkpoints_taken += n;
        st.counters.checkpoint_energy_j += joules;
        *st.job_energy.entry(job_id).or_insert(0.0) += joules;
    }

    /// One simulated second: demand propagation, job progress, energy
    /// accounting, telemetry, control-loop scans, and completions.
    #[allow(clippy::too_many_arguments)]
    fn tick(
        &mut self,
        now: f64,
        st: &mut CampaignState,
        queue: &mut EventQueue<Event>,
        loops: &mut [Box<dyn ControlLoop>],
        last_scan: &mut f64,
        cfg: &CampaignConfig,
        keep_alive: Option<&dyn KeepAlivePolicy>,
    ) {
        let dt = cfg.tick_interval;
        st.cluster.advance_power_states(now);

        // Gather per-VM demands from job phase state.
        let mut demands: std::collections::BTreeMap<VmId, Demand> =
            std::collections::BTreeMap::new();
        for (&vm_id, &job_id) in &st.job_of_vm {
            let job = &st.jobs[&job_id];
            if job.state == JobState::Running {
                demands.insert(vm_id, job.current_demand(now));
            }
        }
        st.cluster.apply_demands(&demands);

        // Advance jobs under their hosts' contention.
        let mut finished: Vec<(JobId, VmId)> = Vec::new();
        for (&vm_id, &job_id) in &st.job_of_vm {
            let vm = &st.cluster.vms[&vm_id];
            if !vm.is_active() {
                continue;
            }
            let host = match vm.state {
                VmState::Migrating { from, .. } => from,
                _ => vm.host.expect("active VM has host"),
            };
            let contention = st.cluster.host(host).contention();
            if contention.0 < 0.999
                || contention.1 < 0.999
                || contention.2 < 0.999
                || contention.3 < 0.999
            {
                log::debug!(
                    "t={now:.0} {job_id} on {host} contended {contention:?} demand {:?}",
                    st.cluster.host(host).demand
                );
            }
            let job = st.jobs.get_mut(&job_id).unwrap();
            if job.state == JobState::Running && job.advance(now - dt, dt, contention) {
                finished.push((job_id, vm_id));
            }
        }

        // Energy attribution, then metering.
        for host in &st.cluster.hosts {
            if !host.state.is_on() || host.vms.is_empty() {
                continue;
            }
            let p = host.power();
            let weights: Vec<f64> = host
                .vms
                .iter()
                .map(|vm| {
                    demands
                        .get(vm)
                        .map(|d| {
                            d.cpu / 32.0
                                + d.mem_gb / 64.0
                                + d.disk_mbps / 500.0
                                + d.net_mbps / 117.0
                        })
                        .unwrap_or(0.0)
                        .max(1e-6)
                })
                .collect();
            let wsum: f64 = weights.iter().sum();
            for (vm, w) in host.vms.iter().zip(&weights) {
                if let Some(&job_id) = st.job_of_vm.get(vm) {
                    *st.job_energy.entry(job_id).or_default() += p * dt * w / wsum;
                }
            }
        }
        st.meter.sample(now, &st.cluster);
        for h in &st.cluster.hosts {
            if !h.state.is_on() {
                st.counters.host_off_s += dt;
            }
        }

        // Telemetry at 5 s cadence. Shards inside a fault-plan
        // blackout window go dark: no new samples land for their
        // hosts (consumers see the stale ring tail) until the window
        // passes.
        if (now / SAMPLE_INTERVAL).fract().abs() < 1e-9 {
            if st.blackout_until.iter().any(|&u| u > now) {
                let masked: Vec<bool> = st
                    .cluster
                    .hosts
                    .iter()
                    .map(|h| st.blackout_until[st.cluster.shard_of(h.id)] > now)
                    .collect();
                st.telemetry
                    .sample_masked(now, &st.cluster, &demands, &masked);
            } else {
                st.telemetry.sample(now, &st.cluster, &demands);
            }
            for h in &st.cluster.hosts {
                if h.state.is_on() {
                    let u = h.utilization().cpu;
                    st.util_hist.push(u);
                    st.per_host_cpu[h.id.0].push(u);
                }
            }
            if cfg.faas.is_some() {
                let warm: usize = st.cluster.digests().iter().map(|d| d.warm_containers).sum();
                st.warm_pool.push(warm as f64);
            }
        }

        // Control-loop scans on the configured cadence. The loop list
        // already encodes what this campaign wants (keep-alive expiry
        // when FaaS is on, the consolidation/DVFS/cap trio only for
        // policies that opted in), so an empty list skips the pass.
        if now - *last_scan >= cfg.scan_interval - 1e-9 {
            *last_scan = now;
            if !loops.is_empty() {
                let t0 = Instant::now();
                self.run_control_loops(now, st, queue, loops, None);
                st.overhead.scan_wall_s += t0.elapsed().as_secs_f64();
            }
        }

        // Completions: release resources, record outcomes.
        let had_finished = !finished.is_empty();
        let mut affected = Vec::new();
        for (job_id, vm_id) in finished {
            self.complete_job(now, job_id, vm_id, st, &mut affected, keep_alive, None);
        }
        if had_finished && !st.deferred.is_empty() {
            request_retry(queue, &mut st.next_retry, now);
        }
        if !st.deferred.is_empty() || !st.waiting_boot.is_empty() {
            // Periodic retry while anything waits.
            if (now as u64) % 15 == 0 {
                request_retry(queue, &mut st.next_retry, now + cfg.retry_backoff_base);
            }
        }
    }

    /// Completion settlement shared by both engines: cut over any
    /// in-flight migration, release the VM, park a warm sandbox for a
    /// finishing function invocation, and record the outcome. Hosts
    /// whose resident set (or migration traffic) changed land in
    /// `affected` — the event engine re-predicts them afterwards; the
    /// tick engine passes a throwaway.
    #[allow(clippy::too_many_arguments)]
    fn complete_job(
        &mut self,
        now: f64,
        job_id: JobId,
        vm_id: VmId,
        st: &mut CampaignState,
        affected: &mut Vec<HostId>,
        keep_alive: Option<&dyn KeepAlivePolicy>,
        mut core: Option<&mut EventCore>,
    ) {
        // The executing host (migration source while in flight) loses
        // a VM here.
        let exec_host = match st.cluster.vms[&vm_id].state {
            VmState::Migrating { from, .. } => Some(from),
            _ => st.cluster.vms[&vm_id].host,
        };
        if let Some(h) = exec_host {
            push_unique(affected, h);
        }
        // A migration may still be in flight; cut it over so
        // termination is clean.
        if let VmState::Migrating { to, .. } = st.cluster.vms[&vm_id].state {
            if let Some(core) = core.as_deref_mut() {
                // The destination's copy traffic disappears at the
                // cut-over: close its segment first.
                core.sync_host(st, to, now);
            }
            push_unique(affected, to);
            st.cluster.finish_migration(vm_id);
        }
        // Capture the final host before the VM record disappears:
        // a completing function invocation parks its sandbox warm
        // there for the keep-alive window.
        let final_host = st.cluster.vms[&vm_id].host;
        st.cluster.terminate_vm(vm_id);
        // The VM is gone; drop the reverse mapping so demand/progress
        // walks stay proportional to *active* VMs (vm_of_job keeps
        // the forward record for reporting).
        st.job_of_vm.remove(&vm_id);
        st.telemetry.forget_vm(vm_id);
        if let Some(core) = core.as_deref_mut() {
            core.forget_vm(vm_id);
        }
        if let (Some(ka), Some(host)) = (keep_alive, final_host) {
            let job = &st.jobs[&job_id];
            if let Some(function) = job.function {
                st.cluster.park_warm_container(
                    host,
                    function,
                    job.gb.min(crate::cluster::flavor::FAAS.mem_gb),
                    now + ka.window(function),
                );
            }
        }
        // Checkpoints written on the way to the finish line are
        // billed at completion (crash segments were billed at each
        // crash). `progress_time` parks the cursor short of the last
        // phase at completion, so use the full plan length.
        let total = st.jobs[&job_id].solo_duration();
        self.charge_checkpoints(st, job_id, total);
        let job = &st.jobs[&job_id];
        let jct = job.jct().expect("finished job has jct");
        st.sla.complete(job_id, jct);
        st.counters.completed += 1;
        let profile = st.profiles.get(&job_id).copied().unwrap_or_default();
        self.history.push(ExecutionRecord {
            kind: job.kind,
            gb: job.gb,
            profile,
            jct,
            solo: job.solo_duration(),
            energy_j: st.job_energy.get(&job_id).copied().unwrap_or(0.0),
            host_cpu_mean: 0.0,
        });
    }

    /// Event engine: drain the completions the last sync surfaced,
    /// settle each through [`Coordinator::complete_job`], then bump
    /// epochs and re-predict every host whose resident set changed.
    /// Deferred work re-polls immediately — a completion is exactly
    /// the capacity signal the tick engine's same-second retry saw.
    fn finish_batch(
        &mut self,
        now: f64,
        st: &mut CampaignState,
        queue: &mut EventQueue<Event>,
        keep_alive: Option<&dyn KeepAlivePolicy>,
        core: &mut EventCore,
    ) {
        let mut affected: Vec<HostId> = Vec::new();
        let mut any = false;
        while let Some((job_id, vm_id)) = core.pop_pending() {
            any = true;
            self.complete_job(
                now,
                job_id,
                vm_id,
                st,
                &mut affected,
                keep_alive,
                Some(&mut *core),
            );
        }
        for h in affected {
            let preds = core.reschedule_host(st, h, now);
            push_preds(queue, preds);
        }
        if any && !st.deferred.is_empty() {
            request_retry(queue, &mut st.next_retry, now);
        }
    }

    /// Run every control loop once, actuating each loop's actions
    /// before the next loop scans (consolidation's power-downs and
    /// migrations are visible to the DVFS governor).
    fn run_control_loops(
        &mut self,
        now: f64,
        st: &mut CampaignState,
        queue: &mut EventQueue<Event>,
        loops: &mut [Box<dyn ControlLoop>],
        mut core: Option<&mut EventCore>,
    ) {
        let vm_ctx = st.vm_contexts(now);
        for control in loops.iter_mut() {
            let actions = {
                let ctx = ScheduleContext::new(now, &st.cluster)
                    .with_telemetry(&st.telemetry)
                    .with_history(&self.history)
                    .with_vm_ctx(&vm_ctx)
                    .with_shards(&st.cluster)
                    .with_pool(&st.pool);
                control.scan(&ctx, self.policy.scoring_handle())
            };
            for action in actions {
                match action {
                    ControlAction::PowerOff(h) => {
                        let host = st.cluster.host(h);
                        if host.vms.is_empty() && host.state.is_on() {
                            if let Some(core) = core.as_deref_mut() {
                                // Close the idle-On segment, then price
                                // the shutdown window it is entering.
                                core.sync_host(st, h, now);
                            }
                            st.cluster.power_off(h, now);
                            st.shard_counters[st.cluster.shard_of(h)].power_offs += 1;
                            if let Some(core) = core.as_deref_mut() {
                                core.refresh_power(st, h);
                                queue.push_class(
                                    now + SHUTDOWN_SECS,
                                    CLASS_POWER,
                                    Event::PowerTransition(h),
                                );
                            }
                        }
                    }
                    ControlAction::Migrate { vm, to } => {
                        // Fault plan: the actuation itself can fail
                        // transiently. The retry policy is the scan
                        // cadence — the next consolidation pass
                        // re-proposes the move — bounded per VM by
                        // `retry_max_attempts`, after which the VM
                        // stays put for the rest of the campaign.
                        if st.has_faults {
                            let tries = st.migration_retries.get(&vm).copied().unwrap_or(0);
                            if tries >= self.config.retry_max_attempts {
                                continue;
                            }
                            let attempt = st.migration_attempts;
                            st.migration_attempts += 1;
                            if st.fault_plan.migration_fails(attempt) {
                                st.counters.migration_failures += 1;
                                st.migration_retries.insert(vm, tries + 1);
                                continue;
                            }
                        }
                        let link = link_headroom(&st.cluster, vm, to);
                        let from = st.cluster.vms.get(&vm).and_then(|v| v.host);
                        // A consolidation move off a degraded source
                        // is a proactive drain — tally it if the
                        // actuation goes through.
                        let draining = from.map_or(false, |f| st.cluster.host(f).is_degraded());
                        if let Some(core) = core.as_deref_mut() {
                            // Both endpoints gain copy traffic (source
                            // contention changes): settle them at the
                            // pre-copy rates first.
                            if let Some(from) = from {
                                core.sync_host(st, from, now);
                            }
                            core.sync_host(st, to, now);
                        }
                        if let Ok(cost) = st.cluster.start_migration(vm, to, now, link) {
                            st.migration_retries.remove(&vm);
                            if let Some(from) = from {
                                st.shard_counters[st.cluster.shard_of(from)].migrations_out += 1;
                            }
                            st.shard_counters[st.cluster.shard_of(to)].migrations_in += 1;
                            st.counters.migrations += 1;
                            if draining {
                                st.counters.drains += 1;
                            }
                            st.counters.migration_stall_s += cost.stall;
                            st.pending_stalls.insert(vm, cost.stall);
                            if let Some(&job_id) = st.job_of_vm.get(&vm) {
                                *st.job_stall.entry(job_id).or_default() += cost.stall;
                            }
                            queue.push(now + cost.duration, Event::MigrationDone(vm));
                            if let Some(core) = core.as_deref_mut() {
                                if let Some(from) = from {
                                    let preds = core.reschedule_host(st, from, now);
                                    push_preds(queue, preds);
                                }
                                let preds = core.reschedule_host(st, to, now);
                                push_preds(queue, preds);
                            }
                        }
                    }
                    ControlAction::SetFreq { host, freq } => {
                        if let Some(core) = core.as_deref_mut() {
                            // Frequency changes power draw and job
                            // progress rates: settle, actuate,
                            // re-predict under the new p-state.
                            core.sync_host(st, host, now);
                            st.cluster.set_freq(host, freq);
                            core.refresh_power(st, host);
                            let preds = core.reschedule_host(st, host, now);
                            push_preds(queue, preds);
                        } else {
                            st.cluster.set_freq(host, freq);
                        }
                    }
                    ControlAction::ExpireContainers(h) => {
                        // Revalidates against the live clock inside
                        // expire_containers, so a stale plan is a no-op.
                        if let Some(core) = core.as_deref_mut() {
                            // Warm sandboxes hold memory (utilization →
                            // power): settle before they leave.
                            core.sync_host(st, h, now);
                        }
                        let n = st.cluster.expire_containers(h, now);
                        st.counters.containers_expired += n as u64;
                        if let Some(core) = core.as_deref_mut() {
                            core.refresh_power(st, h);
                        }
                    }
                }
            }
        }
    }

    /// Batched placement path: profile → decide → commit. The burst
    /// is partitioned round-robin across the configured coordinators;
    /// each decides its slice against the SAME frozen pre-burst
    /// context and submits typed [`AllocationCommit`]s, which the
    /// placement store validates and applies in total commit order —
    /// `(time, class, coordinator, seq)`, the event heap's tiebreak
    /// discipline — so the appended log replays the campaign exactly.
    /// Conflicts (double-booked capacity, unavailable targets, stale
    /// snapshots) are re-decided against the live cluster, exactly
    /// like the single leader's in-burst re-decisions. `ids` may
    /// contain jobs that are no longer queued; they are skipped.
    fn place_batch(
        &mut self,
        now: f64,
        class: u8,
        ids: &[JobId],
        st: &mut CampaignState,
        queue: &mut EventQueue<Event>,
        mut core: Option<&mut EventCore>,
    ) {
        let t0 = Instant::now();
        let mut reqs: Vec<PlacementRequest> = Vec::with_capacity(ids.len());
        for &id in ids {
            let job = match st.jobs.get(&id) {
                Some(j) if j.state == JobState::Queued => j,
                _ => continue,
            };
            let flavor = flavor_for(job.kind);
            // Eq. 1 profiling: history first (recurring kind), else the
            // phase model (the "static execution log" for a first run).
            let vector = self
                .history
                .mean_profile(job.kind)
                .unwrap_or_else(|| ResourceVector::from_phases(&job.phases, &flavor));
            st.profiles.insert(id, vector);
            reqs.push(PlacementRequest {
                job: id,
                flavor,
                vector,
                remaining_solo: job.solo_duration(),
                avoid_rack: st.evacuated_rack.get(&id).copied(),
            });
        }
        if reqs.is_empty() {
            return;
        }
        if self.replay.is_some() {
            self.replay_batch(now, &reqs, st, queue, core);
            return;
        }
        // Decide phase: request i goes to coordinator i mod N, every
        // slice decided against the same frozen pre-burst context.
        // With one coordinator this is exactly the classic single
        // decide_batch call.
        let n = st.schedulers.len();
        let mut commits: Vec<AllocationCommit> = Vec::with_capacity(reqs.len());
        {
            let ctx = ScheduleContext::new(now, &st.cluster)
                .with_telemetry(&st.telemetry)
                .with_history(&self.history)
                .with_shards(&st.cluster)
                .with_pool(&st.pool);
            for c in 0..n {
                let idxs: Vec<usize> = (c..reqs.len()).step_by(n).collect();
                if idxs.is_empty() {
                    continue;
                }
                let sub: Vec<PlacementRequest> = idxs.iter().map(|&i| reqs[i].clone()).collect();
                let decisions = self.policy.decide_batch(&sub, &ctx);
                assert_eq!(
                    decisions.len(),
                    sub.len(),
                    "decide_batch must return one decision per request"
                );
                let sched = &mut st.schedulers[c];
                sched.refresh_snapshot(&st.cluster);
                for (&i, d) in idxs.iter().zip(decisions) {
                    let req = &reqs[i];
                    commits.push(sched.request(now, class, &st.cluster, req.job, req.flavor, d));
                }
            }
        }
        st.overhead.n_decisions += reqs.len() as u64;
        st.overhead.decision_wall_s += t0.elapsed().as_secs_f64();
        // Commit phase, in total order.
        commits.sort_by(commit_order);
        let req_of: BTreeMap<JobId, usize> =
            reqs.iter().enumerate().map(|(i, r)| (r.job, i)).collect();
        // Predictive policies consult expected load and utilization
        // beyond the reservations `fits` checks, so any in-burst
        // placement invalidates their snapshot decisions for that
        // host. Reservation-only policies (round-robin, first/best
        // fit) stay valid as long as the flavor still fits — and
        // re-deciding them needlessly would double-advance stateful
        // cursors.
        let guard_sensitive = self.policy.scoring_handle().is_some();
        let mut placed_hosts: Vec<HostId> = Vec::new();
        for mut commit in commits {
            let coord = commit.coordinator as usize;
            // A coordinator sees its own committed writes: raise the
            // stamped snapshot to its current per-shard view
            // (advanced by note_commit below), so staleness measures
            // only what OTHER coordinators committed since. With one
            // coordinator the lag is always zero and validation
            // reduces to the classic in-burst capacity guard.
            if let (Some(shard), Some(snap)) = (
                target_shard(&st.cluster, commit.decision),
                commit.snapshot_epoch.as_mut(),
            ) {
                *snap = (*snap).max(st.schedulers[coord].snapshot_epoch(shard));
            }
            let req = &reqs[req_of[&commit.job]];
            let verdict = st.store.validate(
                &st.cluster,
                &commit,
                &placed_hosts,
                guard_sensitive,
                self.config.max_snapshot_lag,
            );
            let (outcome, decision) = match verdict {
                Ok(()) => (CommitOutcome::Committed, commit.decision),
                Err(reason) => {
                    // Rejected: the losing coordinator refreshes (a
                    // stale snapshot demands it) and re-decides this
                    // request against the live cluster — the same
                    // re-decision the single leader performed for
                    // in-burst staleness.
                    if matches!(reason, RejectReason::StaleSnapshot { .. }) {
                        st.schedulers[coord].refresh_snapshot(&st.cluster);
                    }
                    let t1 = Instant::now();
                    let redecided = {
                        let ctx = ScheduleContext::new(now, &st.cluster)
                            .with_telemetry(&st.telemetry)
                            .with_history(&self.history)
                            .with_shards(&st.cluster)
                            .with_pool(&st.pool);
                        self.policy.decide(req, &ctx)
                    };
                    st.overhead.n_decisions += 1;
                    st.overhead.decision_wall_s += t1.elapsed().as_secs_f64();
                    (CommitOutcome::Rejected(reason), redecided)
                }
            };
            self.actuate_decision(
                now,
                req,
                decision,
                st,
                queue,
                &mut placed_hosts,
                core.as_deref_mut(),
            );
            // Advance the committer's view past its own write (and
            // everything already committed to that shard before it).
            if let Some(shard) = target_shard(&st.cluster, decision) {
                let epoch = st.cluster.shard_epoch(shard);
                st.schedulers[coord].note_commit(shard, epoch);
            }
            st.store.record(CommitRecord {
                time: commit.time,
                class: commit.class,
                coordinator: commit.coordinator,
                seq: commit.seq,
                job: commit.job,
                requested: commit.decision,
                outcome,
                decision,
            });
        }
    }

    /// Replay mode: no decide phase — pop this burst's records off
    /// the recorded log (already in total commit order) and actuate
    /// each record's final decision verbatim. Defer records route
    /// through the ordinary Defer arm, so the retry-jitter stream
    /// advances exactly as in the recording run; re-recording each
    /// popped entry reproduces the store's commit/conflict counters
    /// too.
    fn replay_batch(
        &mut self,
        now: f64,
        reqs: &[PlacementRequest],
        st: &mut CampaignState,
        queue: &mut EventQueue<Event>,
        mut core: Option<&mut EventCore>,
    ) {
        let req_of: BTreeMap<JobId, usize> =
            reqs.iter().enumerate().map(|(i, r)| (r.job, i)).collect();
        let mut placed_hosts: Vec<HostId> = Vec::new();
        for _ in 0..reqs.len() {
            let rec = self
                .replay
                .as_mut()
                .and_then(|log| log.pop_front())
                .expect("commit log exhausted before the replayed campaign finished");
            let req = &reqs[*req_of
                .get(&rec.job)
                .expect("commit log diverged from the replayed burst")];
            self.actuate_decision(
                now,
                req,
                rec.decision,
                st,
                queue,
                &mut placed_hosts,
                core.as_deref_mut(),
            );
            st.store.record(rec);
        }
    }

    /// Actuate one committed (or re-decided, or replayed) decision
    /// against the live cluster: mutate state, schedule the follow-up
    /// events, maintain the per-shard counters. Validation already
    /// happened in the placement store — this arm trusts its input,
    /// exactly as the classic leader trusted a fresh re-decision.
    #[allow(clippy::too_many_arguments)]
    fn actuate_decision(
        &mut self,
        now: f64,
        req: &PlacementRequest,
        decision: Decision,
        st: &mut CampaignState,
        queue: &mut EventQueue<Event>,
        placed_hosts: &mut Vec<HostId>,
        mut core: Option<&mut EventCore>,
    ) {
        match decision {
            Decision::Place(host) => {
                if let Some(core) = core.as_deref_mut() {
                    // Settle the host's pre-placement segment before a
                    // new resident changes its demand and power.
                    core.sync_host(st, host, now);
                }
                let vm = st.cluster.create_vm(req.flavor, req.job, now);
                st.cluster
                    .place_vm(vm, host)
                    .expect("policy returned infeasible host");
                // Record the profiled mean demand for workload-aware
                // admission on later placements (through the setter so
                // the expected-load cache stays consistent).
                st.cluster.set_expected_demand(
                    vm,
                    Demand {
                        cpu: req.vector.cpu * req.flavor.vcpus,
                        mem_gb: req.vector.mem * req.flavor.mem_gb,
                        disk_mbps: req.vector.disk * req.flavor.disk_mbps,
                        net_mbps: req.vector.net * req.flavor.net_mbps,
                    },
                );
                st.vm_of_job.insert(req.job, vm);
                st.job_of_vm.insert(vm, req.job);
                st.jobs.get_mut(&req.job).unwrap().start(now);
                // An evacuated job landing again closes its recovery
                // window (and its crash-rack avoidance preference).
                if let Some(t0) = st.evacuated_at.remove(&req.job) {
                    st.recovery_latency.push(now - t0);
                }
                st.evacuated_rack.remove(&req.job);
                // Serverless sandbox semantics: a warm container on the
                // chosen host absorbs the invocation instantly; a miss
                // pays the cold-start latency (execution stalls) and the
                // boot-draw energy window.
                if let Some(faas) = self.config.faas {
                    if let Some(function) = st.jobs[&req.job].function {
                        if st.cluster.claim_warm_container(host, function) {
                            st.counters.warm_starts += 1;
                        } else {
                            let mem = st.jobs[&req.job].gb.min(req.flavor.mem_gb);
                            st.cluster.install_booting_container(
                                host,
                                function,
                                mem,
                                now + faas.cold_start_secs,
                            );
                            st.jobs
                                .get_mut(&req.job)
                                .unwrap()
                                .stall(now + faas.cold_start_secs);
                            st.counters.cold_starts += 1;
                            st.counters.cold_start_energy_j +=
                                CONTAINER_BOOT_W * faas.cold_start_secs;
                            if core.is_some() {
                                // The sandbox's boot-draw window needs a
                                // bounding event (the tick engine's
                                // advance_power_states retires it as a
                                // side effect of the next second).
                                queue.push_class(
                                    now + faas.cold_start_secs,
                                    CLASS_POWER,
                                    Event::PowerTransition(host),
                                );
                            }
                        }
                    }
                }
                st.shard_counters[st.cluster.shard_of(host)].placements += 1;
                if !placed_hosts.contains(&host) {
                    placed_hosts.push(host);
                }
                if let Some(core) = core.as_deref_mut() {
                    // New resident (and possibly a cold-start stall):
                    // re-predict the whole host under the added demand.
                    let preds = core.reschedule_host(st, host, now);
                    push_preds(queue, preds);
                }
            }
            Decision::PowerOnAndPlace(host) => {
                // Store validation guarantees the host was still Off
                // at commit time; power_on itself is idempotent.
                if let Some(core) = core.as_deref_mut() {
                    core.sync_host(st, host, now);
                }
                st.cluster.power_on(host, now);
                st.shard_counters[st.cluster.shard_of(host)].boots += 1;
                st.waiting_boot.push((req.job, host));
                if let Some(core) = core.as_deref_mut() {
                    core.refresh_power(st, host);
                    queue.push_class(now + BOOT_SECS, CLASS_POWER, Event::PowerTransition(host));
                }
                request_retry(
                    queue,
                    &mut st.next_retry,
                    now + BOOT_SECS + self.config.retry_backoff_base,
                );
            }
            Decision::Defer => {
                st.counters.deferrals += 1;
                let attempts = {
                    let a = st.retry_attempts.entry(req.job).or_insert(0);
                    *a += 1;
                    *a
                };
                if attempts >= self.config.retry_max_attempts {
                    // Bounded retry gave up: the job is abandoned and
                    // reported as interrupted (it counts toward
                    // campaign termination, never toward SLA
                    // compliance).
                    st.interrupted.insert(req.job);
                } else {
                    st.deferred.push(req.job);
                    let delay = retry_backoff(self.config.retry_backoff_base, attempts)
                        * st.retry_jitter();
                    request_retry(queue, &mut st.next_retry, now + delay);
                }
            }
        }
    }
}

/// The built-in control-loop wiring. Order matters and is part of
/// the behavioral contract: keep-alive expiry frees sandbox memory
/// before consolidation plans against it, consolidation actuates
/// before DVFS observes, and the power cap runs last so it observes
/// (and may override) what the governor just actuated. Loops
/// registered via [`CampaignConfig::with_loop`] are appended after
/// these, in registration order.
pub fn default_loops(cfg: &CampaignConfig, wants_consolidation: bool) -> Vec<Box<dyn ControlLoop>> {
    let mut loops: Vec<Box<dyn ControlLoop>> = Vec::new();
    if cfg.faas.is_some() {
        loops.push(Box::new(KeepAliveLoop));
    }
    if wants_consolidation {
        if let Some(params) = cfg.consolidation {
            loops.push(Box::new(Consolidator::new(params)));
        }
        if let Some(params) = cfg.dvfs {
            loops.push(Box::new(DvfsGovernor::new(params)));
        }
        if let Some(params) = cfg.power_cap {
            loops.push(Box::new(crate::sched::PowerCapLoop::new(params)));
        }
    }
    loops
}

/// Remaining solo seconds for a running job.
pub fn remaining_solo(job: &Job) -> f64 {
    let mut rem = job.phases[job.phase_idx].duration - job.phase_progress;
    for p in &job.phases[job.phase_idx + 1..] {
        rem += p.duration;
    }
    rem.max(0.0)
}

/// Usable migration bandwidth between a VM's host and the target.
fn link_headroom(cluster: &Cluster, vm: VmId, to: HostId) -> f64 {
    let from = match cluster.vms.get(&vm).and_then(|v| v.host) {
        Some(h) => h,
        None => return 50.0,
    };
    let cap = cluster.host(from).spec.net_mbps;
    let free_src = cap - cluster.host(from).demand.net_mbps - cluster.host(from).migration_net;
    let free_dst = cap - cluster.host(to).demand.net_mbps - cluster.host(to).migration_net;
    free_src.min(free_dst).clamp(10.0, 80.0)
}

/// Bounded exponential backoff: attempt `k` (1-based) waits
/// `base · 2^min(k−1, 7)` — capped at 128× base (64 s at the default
/// base) so a long-deferred job still re-polls on a humane cadence.
/// The caller multiplies in jitter.
pub fn retry_backoff(base: f64, attempts: u32) -> f64 {
    base * f64::from(1u32 << attempts.saturating_sub(1).min(7))
}

/// Schedule a RetryQueue event unless one is already pending at or
/// before `t` — prevents retry-event multiplication when many jobs
/// defer simultaneously.
fn request_retry(queue: &mut EventQueue<Event>, next_retry: &mut Option<f64>, t: f64) {
    match *next_retry {
        Some(x) if x <= t + 1e-9 => {}
        _ => {
            let at = t.max(queue.now());
            queue.push(at, Event::RetryQueue);
            *next_retry = Some(at);
        }
    }
}
