//! Discrete-event campaign core: lazy per-host synchronization.
//!
//! The tick engine advances every host every second. The event core
//! instead keeps, per host, the time it was last brought up to date
//! (`last_sync`) and a cached instantaneous wattage (`power_w`), and
//! relies on one invariant: **between two consecutive events touching
//! a host, everything about it is piecewise-constant** — resident
//! set, per-VM demand, DVFS point, power state, and therefore
//! contention and power draw. Under that invariant job progress and
//! energy over a gap integrate in closed form, so the campaign only
//! pays for hosts at the moments something about them changes.
//!
//! Three primitives enforce the invariant:
//!
//! - [`EventCore::sync_host`] closes the open segment: it integrates
//!   the cached wattage into the meter, accrues off-seconds, advances
//!   resident jobs by the gap under the (constant) contention, and
//!   collects any completions into [`EventCore::pending`] for the
//!   coordinator to settle.
//! - [`EventCore::reschedule_host`] re-establishes the invariant
//!   after a mutation: it recomputes the host's demand from its
//!   residents, bumps the host's *prediction epoch* (drawn from a
//!   globally-unique counter so a VM hopping hosts can never collide
//!   into a stale-but-matching epoch), and returns fresh
//!   `(boundary_time, vm, epoch)` predictions for the coordinator to
//!   push as `JobAdvance` events. A popped prediction whose epoch no
//!   longer matches its host is dead — the generalization of the
//!   stale-`MigrationDone` guard.
//! - [`EventCore::refresh_power`] re-prices a host whose wattage
//!   changed without its contention changing (container park/expire,
//!   power-state edges on empty hosts), maintaining the fleet total
//!   incrementally for O(1) power-trace points.
//!
//! The discipline at every mutation site is therefore
//! *sync → mutate → reschedule (or refresh)*.

use crate::cluster::{Demand, HostId, VmId};
use crate::coordinator::state::CampaignState;
use crate::workload::{JobId, JobState};
use std::collections::BTreeMap;

/// Tolerance (in progress-seconds) for snapping a phase boundary the
/// float round-trip through wall time left fractionally short.
pub(crate) const SNAP_TOL: f64 = 1e-6;

/// Lazy-synchronization state for the event engine. Owned by
/// [`crate::coordinator::Coordinator::run`] when
/// `CampaignConfig::engine == EngineKind::Event`; never constructed
/// for tick campaigns, which keeps the tick path bit-identical.
pub(crate) struct EventCore {
    /// Prediction epoch per host; a `JobAdvance { epoch }` is live iff
    /// it matches the epoch of the VM's *executing* host. Distinct
    /// from the per-shard *commit* epochs of
    /// [`crate::cluster::ShardedCluster`] (the commit protocol's
    /// staleness currency): prediction epochs invalidate in-flight
    /// completion events, commit epochs invalidate scheduler
    /// snapshots.
    epoch_of: Vec<u64>,
    /// Single source of epochs — globally unique across hosts.
    next_epoch: u64,
    /// Per-host time up to which energy/progress is settled.
    last_sync: Vec<f64>,
    /// Cached instantaneous wattage per host, valid since `last_sync`.
    power_w: Vec<f64>,
    /// Incrementally-maintained fleet power (Σ `power_w`).
    pub fleet_w: f64,
    /// Maintained analogue of the tick engine's per-tick demand map:
    /// the current (uncapped) demand of every placed, running job.
    /// Updated on reschedule, dropped on completion/crash; feeds
    /// telemetry sampling and the energy-attribution weights.
    pub cur_demand: BTreeMap<VmId, Demand>,
    /// Completions discovered by syncs, awaiting settlement by the
    /// coordinator (FIFO). Every arm that syncs must drain this before
    /// the event ends — the main loop backstops it.
    pending: Vec<(JobId, VmId)>,
}

impl EventCore {
    pub fn new(st: &CampaignState) -> EventCore {
        let power_w: Vec<f64> = st.cluster.hosts.iter().map(|h| h.power()).collect();
        let fleet_w = power_w.iter().sum();
        EventCore {
            epoch_of: vec![0; power_w.len()],
            next_epoch: 0,
            last_sync: vec![0.0; power_w.len()],
            power_w,
            fleet_w,
            cur_demand: BTreeMap::new(),
            pending: Vec::new(),
        }
    }

    /// Is this prediction still live for its host?
    pub fn is_current(&self, host: HostId, epoch: u64) -> bool {
        self.epoch_of[host.0] == epoch
    }

    /// Oldest unsettled completion, if any.
    pub fn pop_pending(&mut self) -> Option<(JobId, VmId)> {
        if self.pending.is_empty() {
            None
        } else {
            Some(self.pending.remove(0))
        }
    }

    pub fn has_pending(&self) -> bool {
        !self.pending.is_empty()
    }

    /// Close the host's open segment at `now`: integrate the cached
    /// wattage, accrue off-seconds, and advance resident jobs under
    /// the segment's (constant) contention. Completions are appended
    /// to [`EventCore::pending`]. Idempotent at equal `now`.
    pub fn sync_host(&mut self, st: &mut CampaignState, h: HostId, now: f64) {
        let i = h.0;
        let dt = now - self.last_sync[i];
        if dt <= 0.0 {
            return;
        }
        self.last_sync[i] = now;
        st.meter.accumulate(i, self.power_w[i], dt);
        let host = st.cluster.host(h);
        if !host.state.is_on() {
            st.counters.host_off_s += dt;
            return;
        }
        if host.vms.is_empty() {
            return;
        }
        // Same attribution as the tick engine: host power split over
        // resident VMs by normalized demand weight, floored so an
        // all-stalled host still distributes its draw.
        let contention = host.contention();
        let p = self.power_w[i];
        let vms: Vec<VmId> = host.vms.clone();
        let weights: Vec<f64> = vms
            .iter()
            .map(|vm| {
                self.cur_demand
                    .get(vm)
                    .map(|d| {
                        d.cpu / 32.0 + d.mem_gb / 64.0 + d.disk_mbps / 500.0 + d.net_mbps / 117.0
                    })
                    .unwrap_or(0.0)
                    .max(1e-6)
            })
            .collect();
        let wsum: f64 = weights.iter().sum();
        for (vm, w) in vms.iter().zip(&weights) {
            if let Some(&job_id) = st.job_of_vm.get(vm) {
                *st.job_energy.entry(job_id).or_default() += p * dt * w / wsum;
                let job = st.jobs.get_mut(&job_id).unwrap();
                if job.state == JobState::Running
                    && (job.advance(now - dt, dt, contention)
                        || job.snap_phase_boundary(now, SNAP_TOL))
                {
                    self.pending.push((job_id, *vm));
                }
            }
        }
    }

    /// Re-establish the piecewise-constant invariant after a mutation
    /// of `h`'s resident set, demand, or frequency: recompute host
    /// demand from residents (ascending VM id, matching the tick
    /// engine's `apply_demands` float-summation order), invalidate
    /// every outstanding prediction by bumping the epoch, and return
    /// fresh `(time, vm, epoch)` predictions for the caller to push.
    /// Also re-prices the host. Callers must have synced `h` first.
    #[must_use]
    pub fn reschedule_host(
        &mut self,
        st: &mut CampaignState,
        h: HostId,
        now: f64,
    ) -> Vec<(f64, VmId, u64)> {
        self.next_epoch += 1;
        let epoch = self.next_epoch;
        self.epoch_of[h.0] = epoch;
        let mut vms: Vec<VmId> = st.cluster.host(h).vms.clone();
        vms.sort_unstable();
        let mut total = Demand::ZERO;
        for vm in &vms {
            if let Some(&job_id) = st.job_of_vm.get(vm) {
                let d = st.jobs[&job_id].current_demand(now);
                let flavor = st.cluster.vms[vm].flavor;
                total.add(&d.capped_by(&flavor));
                self.cur_demand.insert(*vm, d);
            }
        }
        st.cluster.set_host_demand(h, total);
        let contention = st.cluster.host(h).contention();
        let mut preds = Vec::with_capacity(vms.len());
        for vm in &vms {
            if let Some(&job_id) = st.job_of_vm.get(vm) {
                if let Some(t) = st.jobs[&job_id].predict_next_boundary(now, contention) {
                    preds.push((t.max(now), *vm, epoch));
                }
            }
        }
        self.refresh_power(st, h);
        preds
    }

    /// Re-price one host (wattage changed, contention did not) and
    /// maintain the fleet total by delta.
    pub fn refresh_power(&mut self, st: &CampaignState, h: HostId) {
        let p = st.cluster.host(h).power();
        self.fleet_w += p - self.power_w[h.0];
        self.power_w[h.0] = p;
    }

    /// Drop a terminated/killed VM from the demand map.
    pub fn forget_vm(&mut self, vm: VmId) {
        self.cur_demand.remove(&vm);
    }

    /// Sync every host to `now` — the end-of-campaign settlement that
    /// gives the event engine the same energy/off-time horizon the
    /// tick engine reaches with its final tick.
    pub fn flush_all(&mut self, st: &mut CampaignState, now: f64) {
        for i in 0..self.last_sync.len() {
            self.sync_host(st, HostId(i), now);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::leader::CampaignConfig;
    use crate::workload::{Job, JobId, Phase, WorkloadKind};

    fn state_with_job() -> (CampaignState, JobId) {
        let cfg = CampaignConfig {
            n_hosts: 2,
            meter_noise: 0.0,
            telemetry_noise: 0.0,
            ..Default::default()
        };
        let mut st = CampaignState::new(&cfg);
        let job = Job::new(
            JobId(0),
            WorkloadKind::HadoopWordCount,
            10.0,
            vec![Phase {
                name: "map",
                duration: 300.0,
                demand: Demand {
                    cpu: 4.0,
                    mem_gb: 4.0,
                    disk_mbps: 20.0,
                    net_mbps: 0.0,
                },
            }],
            0.0,
        );
        st.sla.register(job.id, job.solo_duration());
        st.jobs.insert(job.id, job);
        st.n_jobs = 1;
        (st, JobId(0))
    }

    #[test]
    fn sync_integrates_idle_power_and_is_idempotent() {
        let (mut st, _) = state_with_job();
        let mut core = EventCore::new(&st);
        core.sync_host(&mut st, HostId(0), 100.0);
        // Idle XEON_64GB: 110 W × 100 s on host 0 only.
        assert!((st.meter.total_true_j() - 11_000.0).abs() < 1e-9);
        core.sync_host(&mut st, HostId(0), 100.0);
        assert!((st.meter.total_true_j() - 11_000.0).abs() < 1e-9);
        core.flush_all(&mut st, 100.0);
        assert!((st.meter.total_true_j() - 22_000.0).abs() < 1e-9);
    }

    #[test]
    fn reschedule_predicts_running_job_boundary() {
        let (mut st, id) = state_with_job();
        let mut core = EventCore::new(&st);
        let vm = st.cluster.create_vm(crate::cluster::flavor::SMALL, id, 0.0);
        st.cluster.place_vm(vm, HostId(0)).unwrap();
        st.job_of_vm.insert(vm, id);
        st.jobs.get_mut(&id).unwrap().start(0.0);
        let preds = core.reschedule_host(&mut st, HostId(0), 0.0);
        assert_eq!(preds.len(), 1);
        let (t, pvm, epoch) = preds[0];
        assert_eq!(pvm, vm);
        assert!(core.is_current(HostId(0), epoch));
        assert!(t > 0.0);
        // Demand landed on the host and in the maintained map.
        assert!(st.cluster.host(HostId(0)).demand.cpu > 0.0);
        assert!(core.cur_demand.contains_key(&vm));
        // A second reschedule invalidates the first prediction.
        let _ = core.reschedule_host(&mut st, HostId(0), 1.0);
        assert!(!core.is_current(HostId(0), epoch));
    }

    #[test]
    fn epochs_are_globally_unique_across_hosts() {
        let (mut st, _) = state_with_job();
        let mut core = EventCore::new(&st);
        let _ = core.reschedule_host(&mut st, HostId(0), 0.0);
        let e0 = core.epoch_of[0];
        let _ = core.reschedule_host(&mut st, HostId(1), 0.0);
        let e1 = core.epoch_of[1];
        assert_ne!(e0, e1, "epochs must never collide across hosts");
    }

    /// Power transients are priced: a shutdown window integrates at
    /// `p_shutdown` until the transition instant, then at `p_off` —
    /// the CloudSim-Plus-style transient constants, charged into
    /// campaign energy rather than snapping On→Off for free.
    #[test]
    fn shutdown_window_charges_transient_power() {
        let (mut st, _) = state_with_job();
        let mut core = EventCore::new(&st);
        let h = HostId(1);
        let m = st.cluster.host(h).spec.power;
        st.cluster.power_off(h, 0.0);
        core.refresh_power(&st, h);
        // Close the 30 s shutdown window at p_shutdown, flip the state
        // machine at exactly the transition instant, then integrate the
        // off segment at the BMC floor.
        core.sync_host(&mut st, h, crate::cluster::power::SHUTDOWN_SECS);
        st.cluster.advance_host(h, crate::cluster::power::SHUTDOWN_SECS);
        assert!(st.cluster.host(h).state.is_off());
        core.refresh_power(&st, h);
        core.sync_host(&mut st, h, 100.0);
        let expected = m.p_shutdown * crate::cluster::power::SHUTDOWN_SECS
            + m.p_off * (100.0 - crate::cluster::power::SHUTDOWN_SECS);
        let host1_j = st.meter.per_host_j()[1];
        assert!(
            (host1_j - expected).abs() < 1e-9,
            "host 1 energy {host1_j} != {expected}"
        );
        // Off-time counts shutting-down and off segments alike,
        // matching the report's "powered off or shutting down".
        assert!((st.counters.host_off_s - 100.0).abs() < 1e-9);
    }

    #[test]
    fn refresh_power_maintains_fleet_delta() {
        let (mut st, _) = state_with_job();
        let mut core = EventCore::new(&st);
        let before = core.fleet_w;
        st.cluster.power_off(HostId(1), 0.0);
        core.refresh_power(&st, HostId(1));
        let m = st.cluster.host(HostId(1)).spec.power;
        assert!((core.fleet_w - (before - m.p_idle + m.p_shutdown)).abs() < 1e-9);
    }
}
