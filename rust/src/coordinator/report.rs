//! Campaign outcome: everything the experiment harness needs to
//! regenerate the paper's tables and figures from one run.

use crate::cluster::ShardDigest;
use crate::util::stats::Histogram;
use crate::util::timeline::Timeline;
use crate::workload::{JobId, WorkloadKind};

/// Per-job outcome.
#[derive(Debug, Clone)]
pub struct JobRecord {
    pub id: JobId,
    pub kind: WorkloadKind,
    pub gb: f64,
    pub submit_at: f64,
    pub jct: f64,
    pub solo: f64,
    /// JCT inflation over solo (can be negative if contention-free and
    /// jitter favored the run).
    pub slowdown: f64,
    /// Energy attributed to this job (J).
    pub energy_j: f64,
    /// Queueing delay before the VM started (s).
    pub wait: f64,
    pub migrations: u32,
    pub sla_met: bool,
}

/// Per-shard actuation counters — what the leader routed through each
/// shard handle over the campaign. One entry per shard; a campaign
/// without an explicit shard count has exactly one.
#[derive(Debug, Clone, Copy, Default)]
pub struct ShardCounters {
    /// VMs placed onto this shard's hosts.
    pub placements: u64,
    /// Boot requests issued to this shard's hosts.
    pub boots: u64,
    /// Migrations arriving into this shard.
    pub migrations_in: u64,
    /// Migrations leaving this shard.
    pub migrations_out: u64,
    /// Hosts powered off in this shard.
    pub power_offs: u64,
    /// Hosts of this shard crashed by the fault plan.
    pub crashes: u64,
    /// VMs evacuated off this shard's crashed hosts.
    pub evacuated_vms: u64,
}

/// Decision-path overhead accounting (§V-E).
#[derive(Debug, Clone, Default)]
pub struct Overhead {
    pub n_decisions: u64,
    /// Wall-clock seconds spent in profile→predict→decide.
    pub decision_wall_s: f64,
    /// Wall-clock seconds spent in consolidation + DVFS scans.
    pub scan_wall_s: f64,
    /// PJRT executions issued by the predictor.
    pub predictor_execs: u64,
}

impl Overhead {
    /// Mean decision latency (µs).
    pub fn per_decision_us(&self) -> f64 {
        if self.n_decisions == 0 {
            0.0
        } else {
            self.decision_wall_s / self.n_decisions as f64 * 1e6
        }
    }

    /// Controller CPU share: wall seconds consumed per simulated
    /// second — what fraction of one core the controller would occupy
    /// in deployment (the honest analog of §V-E's "<5 % CPU usage").
    pub fn cpu_share(&self, horizon_s: f64) -> f64 {
        if horizon_s <= 0.0 {
            0.0
        } else {
            (self.decision_wall_s + self.scan_wall_s) / horizon_s
        }
    }
}

/// Full campaign report.
#[derive(Debug, Clone)]
pub struct CampaignReport {
    pub policy: &'static str,
    pub seed: u64,
    /// Simulated seconds from t=0 to last completion.
    pub makespan: f64,
    /// Total measured energy over the makespan (J).
    pub energy_j: f64,
    /// Noise-free energy (J).
    pub energy_true_j: f64,
    /// Idle-subtracted energy (J).
    pub active_energy_j: f64,
    pub per_host_energy_j: Vec<f64>,
    pub jobs: Vec<JobRecord>,
    pub sla_compliance: f64,
    pub sla_violations: usize,
    pub mean_slowdown: f64,
    pub migrations: u64,
    pub migration_stall_s: f64,
    pub power_cycles: u32,
    /// Host-seconds spent powered off or shutting down.
    pub host_off_s: f64,
    pub power_trace: Timeline,
    pub hosts_on_trace: Timeline,
    /// CPU-utilization distribution over (host, 5 s sample) pairs,
    /// powered-on hosts only (§V-D).
    pub util_hist: Histogram,
    /// Mean CPU utilization per host over the campaign.
    pub per_host_mean_cpu: Vec<f64>,
    pub overhead: Overhead,
    /// Deferred-placement retries that eventually succeeded.
    pub deferrals: u64,
    /// Per-shard actuation counters (length = configured shard count).
    pub per_shard: Vec<ShardCounters>,
    /// Function invocations that paid the sandbox cold-start penalty
    /// (0 unless the campaign configured `faas`).
    pub cold_starts: u64,
    /// Function invocations absorbed by a warm container.
    pub warm_starts: u64,
    /// Warm containers evicted by the keep-alive expiry loop.
    pub containers_expired: u64,
    /// Energy charged to container boot windows (J) — the serverless
    /// analog of host boot draw, additive to metered host energy.
    pub cold_start_energy_j: f64,
    /// Mean fleet-wide warm-pool occupancy over the telemetry samples
    /// (0 unless the campaign configured `faas`).
    pub warm_pool_mean: f64,
    /// End-of-campaign per-shard digests, gathered from the shards
    /// over the worker pool's result channel (the coordinator never
    /// walks shard interiors to report).
    pub final_digests: Vec<ShardDigest>,
    /// Jobs abandoned after the bounded placement-retry policy gave
    /// up (`CampaignConfig::retry_max_attempts`). Not in `jobs`.
    pub interrupted_jobs: usize,
    /// Running VMs evacuated off crashed hosts into the retry queue.
    pub evacuations: u64,
    /// Mean seconds from a job's evacuation to its re-placement
    /// (0 when nothing was evacuated).
    pub mean_recovery_latency_s: f64,
    /// Energy already attributed to jobs at the moment their host
    /// crashed (J) — work the campaign had to pay for twice.
    pub replacement_energy_j: f64,
    /// Fault-plan host crashes that actually fired (host was On).
    pub host_crashes: u64,
    /// Crashed hosts that completed their scheduled recovery reboot.
    pub host_recoveries: u64,
    /// Transient migration-actuation failures injected by the plan.
    pub migration_failures: u64,
    /// Scoring-worker panic probes injected (each healed the pool).
    pub worker_panics: u64,
    /// Recoveries deferred because the host was flapping.
    pub quarantines: u64,
    /// Correlated rack-crash events that fired.
    pub rack_crashes: u64,
    /// Partial-degradation episodes that took effect (host was On).
    pub degraded_hosts: u64,
    /// Consolidation migrations off a degraded source host — the
    /// proactive-drain tally.
    pub drains: u64,
    /// Checkpoints written by running jobs (charged at crash or
    /// completion).
    pub checkpoints_taken: u64,
    /// Solo seconds of progress preserved across crashes by
    /// checkpoint restarts.
    pub progress_saved_s: f64,
    /// Energy spent writing checkpoints (J), additive to metered host
    /// energy like cold-start energy.
    pub checkpoint_energy_j: f64,
    /// Events popped from the campaign queue — the engine-efficiency
    /// denominator (`simulated seconds / events`). NOT folded into
    /// `fingerprint()`: the tick and event engines compute identical
    /// outcomes through different event counts by design.
    pub events_processed: u64,
    /// Allocation commits applied through the placement store — one
    /// per placement request that reached the commit loop.
    pub commits: u64,
    /// Commits the store rejected (double-booked capacity,
    /// unavailable target, stale snapshot) and re-decided live. Like
    /// `events_processed`, these protocol-accounting counters are NOT
    /// folded into `fingerprint()` — they describe how the campaign
    /// was computed, not what it computed; a replayed log reproduces
    /// them exactly anyway (asserted in `tests/commit.rs`).
    pub commit_conflicts: u64,
}

impl CampaignReport {
    /// Mean power draw over the campaign (W).
    pub fn mean_power_w(&self) -> f64 {
        if self.makespan <= 0.0 {
            0.0
        } else {
            self.energy_j / self.makespan
        }
    }

    /// Energy per unit of useful work (J per solo-second completed) —
    /// the makespan-independent efficiency metric used when comparing
    /// policies whose campaigns end at different times.
    pub fn j_per_solo_second(&self) -> f64 {
        let work: f64 = self.jobs.iter().map(|j| j.solo).sum();
        if work <= 0.0 {
            0.0
        } else {
            self.energy_j / work
        }
    }

    /// Fraction of function invocations that paid a cold start
    /// (0 when no invocation ran — e.g. batch-only campaigns).
    pub fn cold_start_rate(&self) -> f64 {
        let total = self.cold_starts + self.warm_starts;
        if total == 0 {
            0.0
        } else {
            self.cold_starts as f64 / total as f64
        }
    }

    /// Order-sensitive 64-bit digest of everything a campaign
    /// computed that scheduling or fault handling can influence: per-
    /// job outcomes (bit-level JCT and energy), energy totals, fault
    /// and actuation counters, and the final shard digests. This is
    /// the equality the chaos determinism tests assert — two runs
    /// with the same `(seed, config, trace)` must produce the same
    /// fingerprint at any worker width.
    pub fn fingerprint(&self) -> u64 {
        use crate::cluster::shard::splitmix64;
        let mut h: u64 = 0xEC0_5C4E_D0;
        let mut mix = |x: u64| h = splitmix64(h ^ x);
        mix(self.seed);
        mix(self.makespan.to_bits());
        mix(self.energy_j.to_bits());
        mix(self.energy_true_j.to_bits());
        mix(self.active_energy_j.to_bits());
        mix(self.jobs.len() as u64);
        for j in &self.jobs {
            mix(j.id.0);
            mix(j.jct.to_bits());
            mix(j.energy_j.to_bits());
            mix(j.migrations as u64);
            mix(j.sla_met as u64);
        }
        mix(self.sla_violations as u64);
        mix(self.migrations);
        mix(self.migration_stall_s.to_bits());
        mix(self.power_cycles as u64);
        mix(self.host_off_s.to_bits());
        mix(self.deferrals);
        mix(self.cold_starts);
        mix(self.warm_starts);
        mix(self.interrupted_jobs as u64);
        mix(self.evacuations);
        mix(self.mean_recovery_latency_s.to_bits());
        mix(self.replacement_energy_j.to_bits());
        mix(self.host_crashes);
        mix(self.host_recoveries);
        mix(self.migration_failures);
        mix(self.worker_panics);
        mix(self.quarantines);
        mix(self.rack_crashes);
        mix(self.degraded_hosts);
        mix(self.drains);
        mix(self.checkpoints_taken);
        mix(self.progress_saved_s.to_bits());
        mix(self.checkpoint_energy_j.to_bits());
        for s in &self.per_shard {
            mix(s.placements);
            mix(s.boots);
            mix(s.migrations_in);
            mix(s.migrations_out);
            mix(s.power_offs);
            mix(s.crashes);
            mix(s.evacuated_vms);
        }
        for d in &self.final_digests {
            mix(d.hosts as u64);
            mix(d.on as u64);
            mix(d.failed as u64);
            mix(d.warm_containers as u64);
            mix(d.reserved.cpu.to_bits());
            mix(d.expected.cpu.to_bits());
            mix(d.capacity_lost.cpu.to_bits());
            mix(d.degraded as u64);
            mix(d.capacity_degraded.cpu.to_bits());
        }
        h
    }

    pub fn energy_of_kind(&self, kind: WorkloadKind) -> f64 {
        self.jobs
            .iter()
            .filter(|j| j.kind == kind)
            .map(|j| j.energy_j)
            .sum()
    }

    pub fn mean_jct_of_kind(&self, kind: WorkloadKind) -> Option<f64> {
        let xs: Vec<f64> = self
            .jobs
            .iter()
            .filter(|j| j.kind == kind)
            .map(|j| j.jct)
            .collect();
        if xs.is_empty() {
            None
        } else {
            Some(crate::util::stats::mean(&xs))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn overhead_math() {
        let o = Overhead {
            n_decisions: 100,
            decision_wall_s: 0.01,
            scan_wall_s: 0.02,
            predictor_execs: 100,
        };
        assert!((o.per_decision_us() - 100.0).abs() < 1e-9);
        assert!((o.cpu_share(3.0) - 0.01).abs() < 1e-9);
        assert_eq!(Overhead::default().per_decision_us(), 0.0);
    }
}
