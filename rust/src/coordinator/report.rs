//! Campaign outcome: everything the experiment harness needs to
//! regenerate the paper's tables and figures from one run.

use crate::cluster::ShardDigest;
use crate::util::stats::Histogram;
use crate::util::timeline::Timeline;
use crate::workload::{JobId, WorkloadKind};

/// Per-job outcome.
#[derive(Debug, Clone)]
pub struct JobRecord {
    pub id: JobId,
    pub kind: WorkloadKind,
    pub gb: f64,
    pub submit_at: f64,
    pub jct: f64,
    pub solo: f64,
    /// JCT inflation over solo (can be negative if contention-free and
    /// jitter favored the run).
    pub slowdown: f64,
    /// Energy attributed to this job (J).
    pub energy_j: f64,
    /// Queueing delay before the VM started (s).
    pub wait: f64,
    pub migrations: u32,
    pub sla_met: bool,
}

/// Per-shard actuation counters — what the leader routed through each
/// shard handle over the campaign. One entry per shard; a campaign
/// without an explicit shard count has exactly one.
#[derive(Debug, Clone, Copy, Default)]
pub struct ShardCounters {
    /// VMs placed onto this shard's hosts.
    pub placements: u64,
    /// Boot requests issued to this shard's hosts.
    pub boots: u64,
    /// Migrations arriving into this shard.
    pub migrations_in: u64,
    /// Migrations leaving this shard.
    pub migrations_out: u64,
    /// Hosts powered off in this shard.
    pub power_offs: u64,
}

/// Decision-path overhead accounting (§V-E).
#[derive(Debug, Clone, Default)]
pub struct Overhead {
    pub n_decisions: u64,
    /// Wall-clock seconds spent in profile→predict→decide.
    pub decision_wall_s: f64,
    /// Wall-clock seconds spent in consolidation + DVFS scans.
    pub scan_wall_s: f64,
    /// PJRT executions issued by the predictor.
    pub predictor_execs: u64,
}

impl Overhead {
    /// Mean decision latency (µs).
    pub fn per_decision_us(&self) -> f64 {
        if self.n_decisions == 0 {
            0.0
        } else {
            self.decision_wall_s / self.n_decisions as f64 * 1e6
        }
    }

    /// Controller CPU share: wall seconds consumed per simulated
    /// second — what fraction of one core the controller would occupy
    /// in deployment (the honest analog of §V-E's "<5 % CPU usage").
    pub fn cpu_share(&self, horizon_s: f64) -> f64 {
        if horizon_s <= 0.0 {
            0.0
        } else {
            (self.decision_wall_s + self.scan_wall_s) / horizon_s
        }
    }
}

/// Full campaign report.
#[derive(Debug, Clone)]
pub struct CampaignReport {
    pub policy: &'static str,
    pub seed: u64,
    /// Simulated seconds from t=0 to last completion.
    pub makespan: f64,
    /// Total measured energy over the makespan (J).
    pub energy_j: f64,
    /// Noise-free energy (J).
    pub energy_true_j: f64,
    /// Idle-subtracted energy (J).
    pub active_energy_j: f64,
    pub per_host_energy_j: Vec<f64>,
    pub jobs: Vec<JobRecord>,
    pub sla_compliance: f64,
    pub sla_violations: usize,
    pub mean_slowdown: f64,
    pub migrations: u64,
    pub migration_stall_s: f64,
    pub power_cycles: u32,
    /// Host-seconds spent powered off or shutting down.
    pub host_off_s: f64,
    pub power_trace: Timeline,
    pub hosts_on_trace: Timeline,
    /// CPU-utilization distribution over (host, 5 s sample) pairs,
    /// powered-on hosts only (§V-D).
    pub util_hist: Histogram,
    /// Mean CPU utilization per host over the campaign.
    pub per_host_mean_cpu: Vec<f64>,
    pub overhead: Overhead,
    /// Deferred-placement retries that eventually succeeded.
    pub deferrals: u64,
    /// Per-shard actuation counters (length = configured shard count).
    pub per_shard: Vec<ShardCounters>,
    /// Function invocations that paid the sandbox cold-start penalty
    /// (0 unless the campaign configured `faas`).
    pub cold_starts: u64,
    /// Function invocations absorbed by a warm container.
    pub warm_starts: u64,
    /// Warm containers evicted by the keep-alive expiry loop.
    pub containers_expired: u64,
    /// Energy charged to container boot windows (J) — the serverless
    /// analog of host boot draw, additive to metered host energy.
    pub cold_start_energy_j: f64,
    /// Mean fleet-wide warm-pool occupancy over the telemetry samples
    /// (0 unless the campaign configured `faas`).
    pub warm_pool_mean: f64,
    /// End-of-campaign per-shard digests, gathered from the shards
    /// over the worker pool's result channel (the coordinator never
    /// walks shard interiors to report).
    pub final_digests: Vec<ShardDigest>,
}

impl CampaignReport {
    /// Mean power draw over the campaign (W).
    pub fn mean_power_w(&self) -> f64 {
        if self.makespan <= 0.0 {
            0.0
        } else {
            self.energy_j / self.makespan
        }
    }

    /// Energy per unit of useful work (J per solo-second completed) —
    /// the makespan-independent efficiency metric used when comparing
    /// policies whose campaigns end at different times.
    pub fn j_per_solo_second(&self) -> f64 {
        let work: f64 = self.jobs.iter().map(|j| j.solo).sum();
        if work <= 0.0 {
            0.0
        } else {
            self.energy_j / work
        }
    }

    /// Fraction of function invocations that paid a cold start
    /// (0 when no invocation ran — e.g. batch-only campaigns).
    pub fn cold_start_rate(&self) -> f64 {
        let total = self.cold_starts + self.warm_starts;
        if total == 0 {
            0.0
        } else {
            self.cold_starts as f64 / total as f64
        }
    }

    pub fn energy_of_kind(&self, kind: WorkloadKind) -> f64 {
        self.jobs
            .iter()
            .filter(|j| j.kind == kind)
            .map(|j| j.energy_j)
            .sum()
    }

    pub fn mean_jct_of_kind(&self, kind: WorkloadKind) -> Option<f64> {
        let xs: Vec<f64> = self
            .jobs
            .iter()
            .filter(|j| j.kind == kind)
            .map(|j| j.jct)
            .collect();
        if xs.is_empty() {
            None
        } else {
            Some(crate::util::stats::mean(&xs))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn overhead_math() {
        let o = Overhead {
            n_decisions: 100,
            decision_wall_s: 0.01,
            scan_wall_s: 0.02,
            predictor_execs: 100,
        };
        assert!((o.per_decision_us() - 100.0).abs() < 1e-9);
        assert!((o.cpu_share(3.0) - 0.01).abs() < 1e-9);
        assert_eq!(Overhead::default().per_decision_us(), 0.0);
    }
}
