//! Optimistic commit protocol: N scheduler front ends against one
//! placement store.
//!
//! The leader used to be one loop that decided *and* mutated. This
//! module splits that into the two roles a multi-node control plane
//! has (the `placement_store.rs` design the ROADMAP points at):
//!
//! - [`Scheduler`] — a coordinator front end. It refreshes an
//!   epoch-stamped digest snapshot
//!   ([`crate::cluster::DigestSnapshot`]), decides its slice of a
//!   submit burst against that *slightly stale* view, and emits typed
//!   [`AllocationCommit`] requests.
//! - [`PlacementStore`] — the central back end. It validates each
//!   commit against live cluster state (snapshot-epoch lag,
//!   double-booked capacity, power/crash state), rejects losers back
//!   to their coordinator for a refreshed re-decision, and appends
//!   every settled commit to a total-order log.
//!
//! ## Total order and replay
//!
//! Commits are ordered by `(time, class, coordinator, seq)` — the
//! same tiebreak discipline as the event heap, with the coordinator
//! id and its per-coordinator sequence number as the last words.
//! Within one burst all commits share `(time, class)`, so the order
//! is coordinator-major: everything coordinator 0 decided, then
//! coordinator 1, and so on. With one coordinator this degenerates to
//! request order — bit-identical to the pre-store leader.
//!
//! Each [`CommitRecord`] carries the decision that was *actuated*
//! (after any conflict re-decision), so replaying the log through a
//! single coordinator — applying each record's final decision without
//! consulting any policy — reproduces the N-coordinator campaign
//! bit for bit. The `commit` integration tests pin that property at
//! coordinator counts {1, 2, 4} × worker widths {1, 8}, clean and
//! faulted.
//!
//! ## Staleness currency
//!
//! Shard commit epochs (bumped by every placement-visible mutation,
//! see [`crate::cluster::ShardedCluster`]) are the staleness measure.
//! A scheduler's snapshot records the epoch of every shard; after one
//! of its commits is applied, its view of the touched shard advances
//! to the post-actuation epoch — a coordinator always sees its own
//! writes, so lag only accrues from *other* coordinators' commits.
//! The store rejects a commit whose target-shard lag exceeds
//! `max_snapshot_lag` ([`RejectReason::StaleSnapshot`]), forcing a
//! refresh. With one coordinator the lag is identically zero and the
//! bound can never fire.

use crate::cluster::{Flavor, HostId, ShardedCluster};
use crate::sched::Decision;
use crate::workload::JobId;

/// A typed placement-commit request: one coordinator's decision for
/// one job, stamped with where and when it was decided.
#[derive(Debug, Clone, Copy)]
pub struct AllocationCommit {
    /// Simulation time of the burst the decision belongs to.
    pub time: f64,
    /// Event class of the burst (submit vs retry) — second word of
    /// the total-order key.
    pub class: u8,
    /// Deciding coordinator.
    pub coordinator: u32,
    /// Per-coordinator sequence number (monotone over the campaign).
    pub seq: u64,
    /// Job being placed.
    pub job: JobId,
    /// Flavor to admit — what capacity validation checks.
    pub flavor: Flavor,
    /// The decision taken against the snapshot.
    pub decision: Decision,
    /// Epoch of the target host's shard in the coordinator's snapshot
    /// (`None` for [`Decision::Defer`] — no target, nothing to be
    /// stale about).
    pub snapshot_epoch: Option<u64>,
}

/// Why the store refused a commit.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RejectReason {
    /// The target can no longer admit the flavor: capacity was
    /// committed since the snapshot (or, for scoring-sensitive
    /// policies, an earlier commit in the same burst landed there and
    /// the scores are void).
    CapacityConflict(HostId),
    /// The target host left the required power state since the
    /// snapshot — crashed or powered down for a `Place`, no longer
    /// Off for a `PowerOnAndPlace`.
    HostUnavailable(HostId),
    /// The coordinator's snapshot of the target shard trails the
    /// shard's commit epoch by more than `max_snapshot_lag`.
    StaleSnapshot {
        shard: usize,
        snapshot_epoch: u64,
        commit_epoch: u64,
    },
}

/// How a commit settled.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CommitOutcome {
    /// Applied as requested.
    Committed,
    /// Refused; the coordinator re-decided against live state and the
    /// record's final decision is what was actuated instead.
    Rejected(RejectReason),
}

/// One entry of the total-order commit log: the request, how it
/// settled, and the decision that was actually actuated. The log is
/// the replay artifact — applying `decision` per record, in log
/// order, reproduces the campaign.
#[derive(Debug, Clone, Copy)]
pub struct CommitRecord {
    pub time: f64,
    pub class: u8,
    pub coordinator: u32,
    pub seq: u64,
    pub job: JobId,
    /// What the coordinator asked for.
    pub requested: Decision,
    pub outcome: CommitOutcome,
    /// What was actuated (== `requested` when committed).
    pub decision: Decision,
}

/// Order commits by the total-order key `(time, class, coordinator,
/// seq)` — the event heap's tiebreak discipline extended with the
/// deciding coordinator and its sequence number.
pub fn commit_order(a: &AllocationCommit, b: &AllocationCommit) -> std::cmp::Ordering {
    a.time
        .total_cmp(&b.time)
        .then(a.class.cmp(&b.class))
        .then(a.coordinator.cmp(&b.coordinator))
        .then(a.seq.cmp(&b.seq))
}

/// Shard of a decision's target host, if it has one.
pub fn target_shard(cluster: &ShardedCluster, decision: Decision) -> Option<usize> {
    match decision {
        Decision::Place(h) | Decision::PowerOnAndPlace(h) => Some(cluster.shard_of(h)),
        Decision::Defer => None,
    }
}

/// One coordinator front end: an id, a commit sequence counter, and
/// its per-shard snapshot epochs.
#[derive(Debug, Clone)]
pub struct Scheduler {
    id: u32,
    next_seq: u64,
    /// Epoch of each shard as of this coordinator's last refresh,
    /// advanced by its own commits (own writes are always visible).
    epochs: Vec<u64>,
}

impl Scheduler {
    pub fn new(id: u32, shard_count: usize) -> Scheduler {
        Scheduler {
            id,
            next_seq: 0,
            epochs: vec![0; shard_count],
        }
    }

    pub fn id(&self) -> u32 {
        self.id
    }

    /// Re-read every shard's commit epoch — taking a fresh snapshot.
    /// Digest *contents* are read through the frozen
    /// [`crate::sched::ScheduleContext`] at decision time; the epochs
    /// here are the part the store validates.
    pub fn refresh_snapshot(&mut self, cluster: &ShardedCluster) {
        self.epochs.copy_from_slice(cluster.shard_epochs());
    }

    /// This coordinator's snapshot epoch for one shard.
    pub fn snapshot_epoch(&self, shard: usize) -> u64 {
        self.epochs[shard]
    }

    /// Advance the snapshot of one shard to `epoch` — called after
    /// one of this coordinator's commits is actuated there, so its
    /// own writes never read as staleness.
    pub fn note_commit(&mut self, shard: usize, epoch: u64) {
        self.epochs[shard] = self.epochs[shard].max(epoch);
    }

    /// Stamp a decision into an [`AllocationCommit`], consuming one
    /// sequence number.
    pub fn request(
        &mut self,
        time: f64,
        class: u8,
        cluster: &ShardedCluster,
        job: JobId,
        flavor: Flavor,
        decision: Decision,
    ) -> AllocationCommit {
        let seq = self.next_seq;
        self.next_seq += 1;
        AllocationCommit {
            time,
            class,
            coordinator: self.id,
            seq,
            job,
            flavor,
            decision,
            snapshot_epoch: target_shard(cluster, decision).map(|s| self.epochs[s]),
        }
    }
}

/// The central placement back end: conflict validation plus the
/// total-order commit log. The store does not mutate the cluster —
/// actuation stays with the coordinator's event machinery — it is
/// the *arbiter* of which commits may be actuated as requested.
#[derive(Debug, Default, Clone)]
pub struct PlacementStore {
    log: Vec<CommitRecord>,
    commits: u64,
    conflicts: u64,
}

impl PlacementStore {
    pub fn new() -> PlacementStore {
        PlacementStore::default()
    }

    /// Validate one commit against live cluster state. `placed_hosts`
    /// and `guard_sensitive` carry the burst-local scoring guard: a
    /// scoring-sensitive policy's per-host scores are void once any
    /// commit of the same burst landed on that host.
    ///
    /// Check order: snapshot staleness first (the protocol-level
    /// currency), then the decision-specific live checks. The live
    /// checks are authoritative — an epoch within bounds never
    /// *admits* a conflicting commit, it only skips a forced refresh.
    pub fn validate(
        &self,
        cluster: &ShardedCluster,
        commit: &AllocationCommit,
        placed_hosts: &[HostId],
        guard_sensitive: bool,
        max_snapshot_lag: u64,
    ) -> Result<(), RejectReason> {
        if let (Some(shard), Some(snap)) = (
            target_shard(cluster, commit.decision),
            commit.snapshot_epoch,
        ) {
            let live = cluster.shard_epoch(shard);
            if live.saturating_sub(snap) > max_snapshot_lag {
                return Err(RejectReason::StaleSnapshot {
                    shard,
                    snapshot_epoch: snap,
                    commit_epoch: live,
                });
            }
        }
        match commit.decision {
            Decision::Place(host) => {
                if guard_sensitive && placed_hosts.contains(&host) {
                    Err(RejectReason::CapacityConflict(host))
                } else if !cluster.host(host).state.accepts_vms() {
                    Err(RejectReason::HostUnavailable(host))
                } else if !cluster.host(host).fits(&commit.flavor, cluster.reserved(host)) {
                    Err(RejectReason::CapacityConflict(host))
                } else {
                    Ok(())
                }
            }
            Decision::PowerOnAndPlace(host) => {
                if cluster.host(host).state.is_off() {
                    Ok(())
                } else {
                    Err(RejectReason::HostUnavailable(host))
                }
            }
            Decision::Defer => Ok(()),
        }
    }

    /// Append a settled commit to the log and count it. Counters are
    /// derived from the record, so replaying a recorded log
    /// reproduces them exactly.
    pub fn record(&mut self, rec: CommitRecord) {
        self.commits += 1;
        if matches!(rec.outcome, CommitOutcome::Rejected(_)) {
            self.conflicts += 1;
        }
        self.log.push(rec);
    }

    /// The total-order commit log so far.
    pub fn log(&self) -> &[CommitRecord] {
        &self.log
    }

    /// Commits processed (committed + rejected).
    pub fn commits(&self) -> u64 {
        self.commits
    }

    /// Commits rejected for re-decision.
    pub fn conflicts(&self) -> u64 {
        self.conflicts
    }

    /// Move the log out (counters stay) — the coordinator publishes
    /// it as the campaign's replay artifact at the end of a run.
    pub fn take_log(&mut self) -> Vec<CommitRecord> {
        std::mem::take(&mut self.log)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::flavor::{LARGE, MEDIUM};
    use crate::cluster::Cluster;

    fn commit_for(
        sched: &mut Scheduler,
        sc: &ShardedCluster,
        job: u64,
        decision: Decision,
    ) -> AllocationCommit {
        sched.request(0.0, 2, sc, JobId(job), LARGE, decision)
    }

    #[test]
    fn commit_order_is_time_class_coordinator_seq() {
        let sc = ShardedCluster::new(Cluster::homogeneous(2), 1);
        let mut s0 = Scheduler::new(0, 1);
        let mut s1 = Scheduler::new(1, 1);
        let a = s1.request(0.0, 2, &sc, JobId(0), MEDIUM, Decision::Defer);
        let b = s0.request(0.0, 2, &sc, JobId(1), MEDIUM, Decision::Defer);
        let c = s0.request(0.0, 2, &sc, JobId(2), MEDIUM, Decision::Defer);
        let d = s0.request(0.0, 1, &sc, JobId(3), MEDIUM, Decision::Defer);
        let e = s1.request(1.0, 0, &sc, JobId(4), MEDIUM, Decision::Defer);
        let mut v = [a, b, c, d, e];
        v.sort_by(commit_order);
        let jobs: Vec<u64> = v.iter().map(|c| c.job.0).collect();
        // Earlier class first, then coordinator 0's commits in seq
        // order, then coordinator 1's, then the later time.
        assert_eq!(jobs, vec![3, 1, 2, 0, 4]);
    }

    #[test]
    fn double_booked_last_slot_rejects_the_second_commit() {
        // 64 GB hosts; one LARGE (32 GB) pre-placed leaves exactly one
        // LARGE slot on host 0. Two coordinators, both deciding from
        // the same snapshot, both pick host 0.
        let mut sc = ShardedCluster::new(Cluster::homogeneous(2), 1);
        let filler = sc.create_vm(LARGE, JobId(90), 0.0);
        sc.place_vm(filler, HostId(0)).unwrap();
        let mut s0 = Scheduler::new(0, 1);
        let mut s1 = Scheduler::new(1, 1);
        s0.refresh_snapshot(&sc);
        s1.refresh_snapshot(&sc);
        let c0 = commit_for(&mut s0, &sc, 1, Decision::Place(HostId(0)));
        let c1 = commit_for(&mut s1, &sc, 2, Decision::Place(HostId(0)));
        let mut store = PlacementStore::new();
        // First commit wins and is actuated.
        store.validate(&sc, &c0, &[], false, 64).unwrap();
        let vm = sc.create_vm(LARGE, JobId(1), 0.0);
        sc.place_vm(vm, HostId(0)).unwrap();
        s0.note_commit(0, sc.shard_epoch(0));
        // Second commit finds the slot gone.
        assert_eq!(
            store.validate(&sc, &c1, &[], false, 64),
            Err(RejectReason::CapacityConflict(HostId(0)))
        );
        // The loser re-decides against live state: host 1 fits.
        store.validate(
            &sc,
            &commit_for(&mut s1, &sc, 2, Decision::Place(HostId(1))),
            &[],
            false,
            64,
        )
        .unwrap();
    }

    #[test]
    fn scoring_guard_conflicts_same_burst_same_host() {
        let sc = ShardedCluster::new(Cluster::homogeneous(2), 1);
        let mut s0 = Scheduler::new(0, 1);
        s0.refresh_snapshot(&sc);
        let c = commit_for(&mut s0, &sc, 1, Decision::Place(HostId(0)));
        let store = PlacementStore::new();
        // Capacity-wise fine, but a scoring-sensitive policy already
        // landed a commit on host 0 this burst.
        store.validate(&sc, &c, &[HostId(0)], false, 64).unwrap();
        assert_eq!(
            store.validate(&sc, &c, &[HostId(0)], true, 64),
            Err(RejectReason::CapacityConflict(HostId(0)))
        );
    }

    #[test]
    fn commit_to_crashed_host_is_unavailable_not_capacity() {
        let mut sc = ShardedCluster::new(Cluster::homogeneous(2), 1);
        let mut s0 = Scheduler::new(0, 1);
        s0.refresh_snapshot(&sc);
        let c = commit_for(&mut s0, &sc, 1, Decision::Place(HostId(0)));
        sc.fail_host(HostId(0), 1.0);
        let store = PlacementStore::new();
        assert_eq!(
            store.validate(&sc, &c, &[], false, u64::MAX),
            Err(RejectReason::HostUnavailable(HostId(0)))
        );
        // PowerOnAndPlace needs the host Off; Failed is not Off.
        let p = commit_for(&mut s0, &sc, 2, Decision::PowerOnAndPlace(HostId(0)));
        assert_eq!(
            store.validate(&sc, &p, &[], false, u64::MAX),
            Err(RejectReason::HostUnavailable(HostId(0)))
        );
    }

    #[test]
    fn snapshot_lag_past_bound_forces_refresh() {
        let mut sc = ShardedCluster::new(Cluster::homogeneous(8), 1);
        let mut s0 = Scheduler::new(0, 1);
        s0.refresh_snapshot(&sc);
        let stale = commit_for(&mut s0, &sc, 1, Decision::Place(HostId(0)));
        // Another coordinator churns the shard past the lag bound.
        for k in 0..3u64 {
            let vm = sc.create_vm(MEDIUM, JobId(50 + k), 0.0);
            sc.place_vm(vm, HostId(1)).unwrap();
        }
        let store = PlacementStore::new();
        let live = sc.shard_epoch(0);
        assert_eq!(
            store.validate(&sc, &stale, &[], false, 2),
            Err(RejectReason::StaleSnapshot {
                shard: 0,
                snapshot_epoch: 0,
                commit_epoch: live,
            })
        );
        // A generous bound tolerates the same lag...
        store.validate(&sc, &stale, &[], false, 64).unwrap();
        // ...and a refreshed snapshot clears it at any bound.
        s0.refresh_snapshot(&sc);
        let fresh = commit_for(&mut s0, &sc, 1, Decision::Place(HostId(0)));
        store.validate(&sc, &fresh, &[], false, 0).unwrap();
    }

    #[test]
    fn own_commits_are_never_stale() {
        let mut sc = ShardedCluster::new(Cluster::homogeneous(4), 1);
        let mut s0 = Scheduler::new(0, 1);
        s0.refresh_snapshot(&sc);
        let store = PlacementStore::new();
        // Even with a zero lag bound, a coordinator that notes its own
        // actuations never trips the staleness check.
        for k in 0..5u64 {
            let c = s0.request(0.0, 2, &sc, JobId(k), MEDIUM, Decision::Place(HostId(3)));
            store.validate(&sc, &c, &[], false, 0).unwrap();
            let vm = sc.create_vm(MEDIUM, JobId(k), 0.0);
            sc.place_vm(vm, HostId((k % 3) as usize)).unwrap();
            s0.note_commit(0, sc.shard_epoch(0));
        }
    }

    #[test]
    fn record_counts_commits_and_conflicts_deterministically() {
        let mut store = PlacementStore::new();
        let rec = CommitRecord {
            time: 0.0,
            class: 2,
            coordinator: 0,
            seq: 0,
            job: JobId(0),
            requested: Decision::Place(HostId(0)),
            outcome: CommitOutcome::Committed,
            decision: Decision::Place(HostId(0)),
        };
        store.record(rec);
        store.record(CommitRecord {
            outcome: CommitOutcome::Rejected(RejectReason::CapacityConflict(HostId(0))),
            decision: Decision::Place(HostId(1)),
            seq: 1,
            ..rec
        });
        assert_eq!(store.commits(), 2);
        assert_eq!(store.conflicts(), 1);
        assert_eq!(store.log().len(), 2);
        // Replaying the taken log into a fresh store reproduces the
        // counters exactly — they derive from record outcomes.
        let log = store.take_log();
        assert_eq!(store.log().len(), 0);
        let mut replayed = PlacementStore::new();
        for rec in log {
            replayed.record(rec);
        }
        assert_eq!(replayed.commits(), 2);
        assert_eq!(replayed.conflicts(), 1);
    }
}
