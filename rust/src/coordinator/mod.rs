//! The coordinator (L3's leader): campaign driver, batched placement
//! path, control-loop actuation, and outcome reporting.

pub mod config;
mod event_core;
pub mod leader;
pub mod placement_store;
pub mod report;
pub mod state;

pub use config::{CampaignConfigBuilder, ConfigError, LoopList};
pub use leader::{default_loops, remaining_solo, CampaignConfig, Coordinator, EngineKind};
pub use placement_store::{
    commit_order, target_shard, AllocationCommit, CommitOutcome, CommitRecord, PlacementStore,
    RejectReason, Scheduler,
};
pub use report::{CampaignReport, JobRecord, Overhead};
pub use state::{CampaignState, Counters};

use crate::predict::{EnergyPredictor, NativeMlp, OraclePredictor};
use crate::sched::{
    BestFit, EnergyAware, EnergyAwareParams, FirstFit, PlacementPolicy, RoundRobin,
};

/// Build a policy by name. The energy-aware policy takes its predictor
/// explicitly; `energy_aware` with no predictor defaults to the
/// analytic oracle (used in unit tests and quick runs without
/// artifacts — production runs pass the trained XLA MLP).
pub fn make_policy(name: &str) -> Option<Box<dyn PlacementPolicy>> {
    match name {
        "round_robin" => Some(Box::new(RoundRobin::default())),
        "first_fit" => Some(Box::new(FirstFit)),
        "best_fit" => Some(Box::new(BestFit)),
        "energy_aware" => Some(Box::new(EnergyAware::new(
            Box::new(OraclePredictor),
            EnergyAwareParams::default(),
        ))),
        _ => None,
    }
}

/// Energy-aware policy with a specific predictor.
pub fn energy_aware_with(predictor: Box<dyn EnergyPredictor>) -> Box<dyn PlacementPolicy> {
    Box::new(EnergyAware::new(predictor, EnergyAwareParams::default()))
}

/// Energy-aware policy backed by the native MLP with weights from
/// `artifacts/weights.json` (or a deterministic init when absent).
pub fn energy_aware_native_mlp(artifacts: &std::path::Path) -> Box<dyn PlacementPolicy> {
    let weights = crate::predict::MlpWeights::load(&artifacts.join("weights.json"))
        .unwrap_or_else(|| crate::predict::MlpWeights::init(42));
    energy_aware_with(Box::new(NativeMlp::new(weights)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{Arrivals, Mix, TraceSpec};

    fn small_trace(n: usize, seed: u64) -> Vec<crate::workload::Job> {
        TraceSpec {
            mix: Mix::paper(),
            n_jobs: n,
            arrivals: Arrivals::Poisson { mean_gap: 60.0 },
            horizon: 3600.0,
        }
        .generate(seed)
    }

    #[test]
    fn campaign_completes_all_jobs_round_robin() {
        let mut coord = Coordinator::new(
            CampaignConfig {
                n_hosts: 5,
                seed: 1,
                ..Default::default()
            },
            make_policy("round_robin").unwrap(),
        );
        let report = coord.run(small_trace(12, 1));
        assert_eq!(report.jobs.len(), 12);
        assert!(report.makespan > 0.0);
        assert!(report.energy_j > 0.0);
        assert_eq!(report.policy, "round_robin");
        // RR never powers down.
        assert_eq!(report.power_cycles, 0);
        assert_eq!(report.host_off_s, 0.0);
    }

    #[test]
    fn campaign_completes_all_jobs_energy_aware() {
        let mut coord = Coordinator::new(
            CampaignConfig {
                n_hosts: 5,
                seed: 1,
                ..Default::default()
            },
            make_policy("energy_aware").unwrap(),
        );
        let report = coord.run(small_trace(12, 1));
        assert_eq!(report.jobs.len(), 12);
        assert_eq!(report.sla_violations, 0, "energy-aware must not violate SLAs");
        assert!(report.sla_compliance >= 1.0 - 1e-9);
    }

    #[test]
    fn energy_aware_beats_round_robin_on_energy() {
        let trace = small_trace(16, 3);
        let mut rr = Coordinator::new(
            CampaignConfig {
                seed: 3,
                ..Default::default()
            },
            make_policy("round_robin").unwrap(),
        );
        let rep_rr = rr.run(trace.clone());
        let mut ea = Coordinator::new(
            CampaignConfig {
                seed: 3,
                ..Default::default()
            },
            make_policy("energy_aware").unwrap(),
        );
        let rep_ea = ea.run(trace);
        // Compare per unit of useful work (makespans differ slightly).
        let gain = 1.0 - rep_ea.j_per_solo_second() / rep_rr.j_per_solo_second();
        assert!(
            gain > 0.05,
            "energy-aware should save ≥5 % (got {:.1} %)",
            gain * 100.0
        );
    }

    #[test]
    fn deterministic_given_seed() {
        let run = || {
            let mut c = Coordinator::new(
                CampaignConfig {
                    seed: 7,
                    ..Default::default()
                },
                make_policy("energy_aware").unwrap(),
            );
            c.run(small_trace(10, 7))
        };
        let a = run();
        let b = run();
        assert_eq!(a.energy_j, b.energy_j);
        assert_eq!(a.makespan, b.makespan);
        assert_eq!(a.migrations, b.migrations);
        let jct_a: Vec<f64> = a.jobs.iter().map(|j| j.jct).collect();
        let jct_b: Vec<f64> = b.jobs.iter().map(|j| j.jct).collect();
        assert_eq!(jct_a, jct_b);
    }

    #[test]
    fn history_populated_after_campaign() {
        let mut coord = Coordinator::new(
            CampaignConfig::default(),
            make_policy("best_fit").unwrap(),
        );
        let report = coord.run(small_trace(8, 5));
        assert_eq!(coord.history.len(), 8);
        assert!(report.jobs.iter().all(|j| j.energy_j > 0.0));
    }

    #[test]
    fn make_policy_rejects_unknown() {
        assert!(make_policy("nope").is_none());
        for name in ["round_robin", "first_fit", "best_fit", "energy_aware"] {
            assert_eq!(make_policy(name).unwrap().name(), name);
        }
    }

    #[test]
    fn retry_backoff_doubles_then_caps() {
        use leader::retry_backoff;
        assert_eq!(retry_backoff(0.5, 1), 0.5);
        assert_eq!(retry_backoff(0.5, 2), 1.0);
        assert_eq!(retry_backoff(0.5, 3), 2.0);
        assert_eq!(retry_backoff(0.5, 8), 64.0);
        // Cap: 2^7 × base, no matter how many attempts pile up.
        assert_eq!(retry_backoff(0.5, 9), 64.0);
        assert_eq!(retry_backoff(0.5, 200), 64.0);
    }

    /// Permanent total failure: every host crashes early and never
    /// recovers, so evacuated and late-arriving jobs exhaust the
    /// bounded retry budget and land in `interrupted_jobs` — and the
    /// campaign still terminates cleanly with every job accounted for.
    #[test]
    fn exhausted_retries_interrupt_jobs_and_campaign_ends() {
        let mut coord = Coordinator::new(
            CampaignConfig {
                n_hosts: 3,
                seed: 11,
                retry_max_attempts: 4,
                faults: Some(crate::sim::FaultConfig {
                    host_crash_rate_per_hour: 60.0,
                    // Longer than any campaign: crashed hosts stay down.
                    mean_downtime_s: 1e7,
                    blackout_rate_per_hour: 0.0,
                    migration_failure_prob: 0.0,
                    worker_panics: 0,
                    ..Default::default()
                }),
                ..Default::default()
            },
            make_policy("round_robin").unwrap(),
        );
        let report = coord.run(small_trace(8, 11));
        assert!(report.host_crashes > 0, "no host crashed — vacuous");
        assert_eq!(report.host_recoveries, 0, "downtime outlives the campaign");
        assert!(
            report.interrupted_jobs > 0,
            "retry budget was never exhausted — vacuous"
        );
        // Conservation: finished + interrupted covers the whole trace.
        assert_eq!(report.jobs.len() + report.interrupted_jobs, 8);
    }

    /// A host that keeps crashing inside the flap window has its
    /// recovery deferred by the quarantine cooldown (and eventually
    /// rejoins — recoveries still happen).
    #[test]
    fn flapping_hosts_are_quarantined() {
        let mut coord = Coordinator::new(
            CampaignConfig {
                n_hosts: 4,
                seed: 17,
                faults: Some(crate::sim::FaultConfig {
                    host_crash_rate_per_hour: 30.0,
                    mean_downtime_s: 45.0,
                    blackout_rate_per_hour: 0.0,
                    migration_failure_prob: 0.0,
                    worker_panics: 0,
                    flap_threshold: 2,
                    flap_window_s: 3600.0,
                    quarantine_s: 600.0,
                    ..Default::default()
                }),
                ..Default::default()
            },
            make_policy("round_robin").unwrap(),
        );
        let report = coord.run(small_trace(8, 17));
        assert!(
            report.quarantines > 0,
            "no recovery was deferred — flap detection never fired"
        );
        assert!(
            report.host_recoveries > 0,
            "quarantined hosts must still rejoin after the cooldown"
        );
        assert_eq!(report.jobs.len() + report.interrupted_jobs, 8);
    }

    #[test]
    fn overhead_is_recorded() {
        let mut coord = Coordinator::new(
            CampaignConfig::default(),
            make_policy("energy_aware").unwrap(),
        );
        let report = coord.run(small_trace(8, 9));
        // At least one decision per job; deferrals and boot-waits add
        // re-decisions on top.
        assert!(report.overhead.n_decisions >= 8);
        assert!(report.overhead.decision_wall_s > 0.0);
        assert!(report.overhead.cpu_share(report.makespan) < 0.05);
    }
}
