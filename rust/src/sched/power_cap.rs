//! Cluster power capping — the §VI-E research extension promoted from
//! the `carbon_aware` example sketch into a real [`ControlLoop`]: when
//! the grid is dirty (or a rack breaker, battery, or contract bounds
//! draw), the operator sets a watt budget and the loop holds the
//! fleet's estimated draw under it by stepping hosts down the DVFS
//! ladder, least-harmful first.
//!
//! The loop plans under two invariants, unit-tested below:
//!
//! * **Cap-budget invariant** — a scan never plans actions that raise
//!   the estimated draw above the budget: over budget it only plans
//!   reductions; restorations happen only when the fleet is
//!   comfortably under budget (`restore_margin`) and only while the
//!   projected draw stays at or below the budget.
//! * **Ceiling persistence** — every throttle is recorded as a
//!   per-host frequency ceiling and *re-asserted* each scan. The DVFS
//!   governor restores clocks whenever it sees CPU pressure, so
//!   without a remembered ceiling the closed loop would flap one
//!   p-state below full clock forever and never converge to budgets
//!   that need deeper throttles. Restoration releases ceilings one
//!   p-state per host per scan (gentle ramps beat synchronized
//!   cliffs) and only ever touches hosts this loop throttled — the
//!   governor's own efficiency clock-downs are not undone.
//!
//! Throttle order is the DVFS governor's logic inverted: hosts whose
//! effective CPU utilization is lowest (I/O-bound tenants, §III-C)
//! lose frequency first, because frequency scaling is nearly free for
//! them and costly for CPU-bound tenants (§V-C). Restoration runs the
//! same list backwards — the most CPU-pressed capped host gets its
//! clock back first. Scans walk hosts shard by shard through the
//! context lens — on the worker pool when the context carries one,
//! with per-shard candidate buffers merged in ascending shard order —
//! so a sharded deployment caps without reading shard interiors
//! beyond its own pass. The budget walk itself is inherently global
//! (each step updates the fleet estimate) and stays serial; the
//! candidate sort's `(utilization, host id)` key is a total order, so
//! pooled and inline scans emit identical actions.
//!
//! The loop runs after consolidation and DVFS on the coordinator's
//! scan cadence (each loop's actions actuate before the next scans),
//! so the cap sees — and can override — what the governor just did.

use crate::cluster::power::{snap_to_pstate, PSTATES};
use crate::cluster::{Host, HostId};
use crate::sched::control::{ControlAction, ControlLoop, ScoringHandle};
use crate::sched::ScheduleContext;
use std::collections::BTreeMap;

/// Power-cap tunables.
#[derive(Debug, Clone, Copy)]
pub struct PowerCapParams {
    /// Cluster-wide draw budget (W). The default is infinite — the
    /// loop is inert until the operator (or a carbon-intensity
    /// schedule) sets a real budget.
    pub budget_w: f64,
    /// Restore frequencies only when the estimated draw is below
    /// `restore_margin × budget_w` — the hysteresis band that stops
    /// throttle/restore flapping at the cap boundary.
    pub restore_margin: f64,
    /// Maximum NEW p-state steps (down or up) per scan, at most one
    /// per host per scan. Re-assertions of already-recorded ceilings
    /// are always emitted — they restore the loop's own prior state,
    /// not new movement.
    pub max_actions: usize,
}

impl Default for PowerCapParams {
    fn default() -> Self {
        PowerCapParams {
            budget_w: f64::INFINITY,
            restore_margin: 0.9,
            max_actions: 8,
        }
    }
}

/// The capping loop. Scan-to-scan state is the set of frequency
/// ceilings it has imposed (see the module docs on why ceilings must
/// persist); everything else is recomputed from the context.
#[derive(Debug, Default)]
pub struct PowerCapLoop {
    pub params: PowerCapParams,
    /// Per-host frequency ceilings this loop has imposed. Re-asserted
    /// every scan; released stepwise on restoration.
    ceilings: BTreeMap<HostId, f64>,
}

impl PowerCapLoop {
    pub fn new(params: PowerCapParams) -> PowerCapLoop {
        PowerCapLoop {
            params,
            ceilings: BTreeMap::new(),
        }
    }

    /// Update the budget (e.g. from a time-varying carbon-intensity
    /// or demand-response signal) between scans.
    pub fn set_budget(&mut self, budget_w: f64) {
        self.params.budget_w = budget_w;
    }
}

/// Estimated draw of `host` at DVFS point `freq` (snapped to the
/// p-state catalog like `Host::set_freq`), holding demand fixed — the
/// planning model for one throttle/restore step. Mirrors
/// `Host::power` exactly at the host's own frequency, without cloning
/// the host.
fn power_at(host: &Host, freq: f64) -> f64 {
    if !host.state.is_on() {
        return host.power(); // off/transition draw is frequency-independent
    }
    let f = snap_to_pstate(freq);
    let u = host.utilization();
    let u_cpu = (host.demand.cpu / (host.spec.capacity().cpu * f)).min(1.0);
    host.spec.power.active_power(u_cpu, u.mem, u.io(), f)
}

/// Next p-state below `freq`, if any (PSTATES is descending).
fn next_pstate_down(freq: f64) -> Option<f64> {
    PSTATES.iter().copied().find(|&p| p < freq - 1e-9)
}

/// Next p-state above `freq`, if any.
fn next_pstate_up(freq: f64) -> Option<f64> {
    PSTATES.iter().rev().copied().find(|&p| p > freq + 1e-9)
}

/// This scan's planned frequency for a host: its live frequency
/// unless the plan already holds a target for it.
fn eff(host: &Host, target: &BTreeMap<HostId, f64>) -> f64 {
    target.get(&host.id).copied().unwrap_or(host.freq)
}

impl ControlLoop for PowerCapLoop {
    fn name(&self) -> &'static str {
        "power_cap"
    }

    fn box_clone(&self) -> Box<dyn ControlLoop> {
        Box::new(PowerCapLoop::new(self.params))
    }

    fn scan(
        &mut self,
        ctx: &ScheduleContext<'_>,
        _scoring: Option<ScoringHandle<'_>>,
    ) -> Vec<ControlAction> {
        let budget = self.params.budget_w;
        let cluster = ctx.cluster;
        if !budget.is_finite() {
            self.ceilings.clear();
            return Vec::new();
        }
        self.ceilings.retain(|h, _| cluster.hosts[h.0].state.is_on());
        // Phase 1 — re-assert ceilings: any capped host running above
        // its ceiling (another loop restored it) is planned back down
        // before the budget comparison.
        let mut target: BTreeMap<HostId, f64> = BTreeMap::new();
        for (&h, &ceil) in &self.ceilings {
            if cluster.hosts[h.0].freq > ceil + 1e-9 {
                target.insert(h, ceil);
            }
        }
        let mut est: f64 = cluster
            .hosts
            .iter()
            .map(|host| power_at(host, eff(host, &target)))
            .sum();
        let mut steps = 0usize;
        if est > budget {
            // Over budget: step hosts down the DVFS ladder, lowest
            // effective CPU utilization first (I/O-bound tenants lose
            // the least), one p-state per host per scan, until the
            // estimate is back under the cap or the step bound hits.
            // Candidate collection is the per-shard pass (pooled when
            // a worker pool is attached); the sort key is a total
            // order, so collection order cannot change the plan.
            let mut cands: Vec<(f64, HostId)> = ctx
                .for_each_shard(|shard| {
                    let mut c: Vec<(f64, HostId)> = Vec::new();
                    for host_id in ctx.shard(shard).hosts() {
                        let host = &cluster.hosts[host_id.0];
                        if !host.state.is_on() {
                            continue;
                        }
                        if next_pstate_down(eff(host, &target)).is_none() {
                            continue;
                        }
                        c.push((cluster.effective_util(host_id).cpu, host_id));
                    }
                    c
                })
                .into_iter()
                .flatten()
                .collect();
            cands.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap().then(a.1.cmp(&b.1)));
            for (_, host_id) in cands {
                if est <= budget || steps >= self.params.max_actions {
                    break;
                }
                let host = &cluster.hosts[host_id.0];
                let cur = eff(host, &target);
                let Some(next) = next_pstate_down(cur) else {
                    continue;
                };
                let saved = power_at(host, cur) - power_at(host, next);
                if saved <= 1e-9 {
                    continue; // no CPU term to shed on this host
                }
                est -= saved;
                target.insert(host_id, next);
                self.ceilings.insert(host_id, next);
                steps += 1;
            }
        } else if est < self.params.restore_margin * budget {
            // Comfortably under: release OUR ceilings one p-state per
            // host per scan, most CPU-pressed capped host first, never
            // planning past the budget. Hosts the DVFS governor
            // clocked down for efficiency carry no ceiling and are
            // left alone.
            let ceilings = &self.ceilings;
            let mut cands: Vec<(f64, HostId)> = ctx
                .for_each_shard(|shard| {
                    let mut c: Vec<(f64, HostId)> = Vec::new();
                    for host_id in ctx.shard(shard).hosts() {
                        if !ceilings.contains_key(&host_id) {
                            continue;
                        }
                        c.push((cluster.effective_util(host_id).cpu, host_id));
                    }
                    c
                })
                .into_iter()
                .flatten()
                .collect();
            cands.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap().then(a.1.cmp(&b.1)));
            for (_, host_id) in cands {
                if steps >= self.params.max_actions {
                    break;
                }
                let host = &cluster.hosts[host_id.0];
                let cur = eff(host, &target);
                let Some(up) = next_pstate_up(cur) else {
                    // Already at full clock: the ceiling is spent.
                    self.ceilings.remove(&host_id);
                    continue;
                };
                let delta = power_at(host, up) - power_at(host, cur);
                if est + delta > budget {
                    continue; // restoring this host would breach the cap
                }
                est += delta;
                if up >= 1.0 - 1e-9 {
                    self.ceilings.remove(&host_id);
                } else {
                    self.ceilings.insert(host_id, up);
                }
                target.insert(host_id, up);
                steps += 1;
            }
        }
        // One SetFreq per host whose planned point differs from its
        // live frequency (BTreeMap order: deterministic, ascending).
        target
            .into_iter()
            .filter(|&(h, f)| (cluster.hosts[h.0].freq - f).abs() > 1e-9)
            .map(|(host, freq)| ControlAction::SetFreq { host, freq })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{Cluster, Demand};

    fn loaded(n: usize, cpu: f64) -> Cluster {
        let mut c = Cluster::homogeneous(n);
        for i in 0..n {
            c.host_mut(HostId(i)).demand = Demand {
                cpu,
                mem_gb: 8.0,
                disk_mbps: 200.0,
                net_mbps: 20.0,
            };
        }
        c
    }

    /// Apply planned SetFreq actions to a scratch cluster and return
    /// the resulting total draw — the test-side check of the loop's
    /// internal estimate.
    fn projected_power(c: &Cluster, actions: &[ControlAction]) -> f64 {
        let mut scratch = c.clone();
        for a in actions {
            if let ControlAction::SetFreq { host, freq } = a {
                scratch.host_mut(*host).set_freq(*freq);
            }
        }
        scratch.total_power()
    }

    #[test]
    fn default_budget_is_inert() {
        let c = loaded(3, 20.0);
        let mut cap = PowerCapLoop::default();
        let ctx = ScheduleContext::new(0.0, &c);
        assert!(cap.scan(&ctx, None).is_empty());
        assert_eq!(cap.name(), "power_cap");
    }

    #[test]
    fn planning_model_matches_host_power_at_live_frequency() {
        let mut c = loaded(2, 18.0);
        c.host_mut(HostId(1)).set_freq(0.7);
        for h in &c.hosts {
            assert!((power_at(h, h.freq) - h.power()).abs() < 1e-9);
        }
    }

    #[test]
    fn over_budget_plans_only_reductions() {
        let c = loaded(4, 24.0);
        let before = c.total_power();
        let mut cap = PowerCapLoop::new(PowerCapParams {
            budget_w: before - 100.0,
            ..Default::default()
        });
        let ctx = ScheduleContext::new(0.0, &c);
        let actions = cap.scan(&ctx, None);
        assert!(!actions.is_empty());
        for a in &actions {
            match a {
                ControlAction::SetFreq { host, freq } => {
                    assert!(*freq < c.host(*host).freq, "cap must only throttle: {a:?}");
                }
                other => panic!("power cap must only emit SetFreq: {other:?}"),
            }
        }
        // Cap-budget invariant: the plan strictly reduces draw.
        assert!(projected_power(&c, &actions) < before);
        assert!(actions.len() <= PowerCapParams::default().max_actions);
    }

    #[test]
    fn throttles_io_bound_hosts_before_cpu_bound() {
        let mut c = loaded(2, 4.0); // host 0: I/O-ish (low CPU)
        c.host_mut(HostId(1)).demand.cpu = 28.0; // host 1: CPU-bound
        let before = c.total_power();
        let mut cap = PowerCapLoop::new(PowerCapParams {
            budget_w: before - 5.0,
            max_actions: 1,
            ..Default::default()
        });
        let ctx = ScheduleContext::new(0.0, &c);
        let actions = cap.scan(&ctx, None);
        assert_eq!(actions.len(), 1);
        assert!(
            matches!(actions[0], ControlAction::SetFreq { host, .. } if host == HostId(0)),
            "the I/O-bound host must be throttled first: {actions:?}"
        );
    }

    #[test]
    fn reasserts_ceilings_after_external_restore_and_converges() {
        // One CPU-loaded host; a budget that needs 0.7. The DVFS
        // governor restores clocks under CPU pressure between scans;
        // the cap must re-assert its remembered ceiling AND keep
        // stepping down — not flap at one step below full clock.
        let mut c = loaded(1, 24.0);
        let p_full = c.total_power();
        let budget = {
            let mut s = c.clone();
            s.host_mut(HostId(0)).set_freq(0.7);
            s.total_power() + 1.0
        };
        assert!(budget < p_full);
        let mut cap = PowerCapLoop::new(PowerCapParams {
            budget_w: budget,
            ..Default::default()
        });
        // Scan 1: one step, 1.0 → 0.85, ceiling recorded.
        let a1 = {
            let ctx = ScheduleContext::new(0.0, &c);
            cap.scan(&ctx, None)
        };
        assert_eq!(
            a1,
            vec![ControlAction::SetFreq {
                host: HostId(0),
                freq: 0.85
            }]
        );
        c.host_mut(HostId(0)).set_freq(0.85);
        // Adversarial restore (what the governor does to a contended
        // clocked-down host).
        c.host_mut(HostId(0)).set_freq(1.0);
        // Scan 2: ceiling re-asserted and stepped DEEPER in one plan.
        let a2 = {
            let ctx = ScheduleContext::new(30.0, &c);
            cap.scan(&ctx, None)
        };
        assert_eq!(
            a2,
            vec![ControlAction::SetFreq {
                host: HostId(0),
                freq: 0.7
            }]
        );
    }

    #[test]
    fn restore_is_stepwise_bounded_by_budget_and_releases_ceilings() {
        let mut c = loaded(2, 14.0);
        let full = c.total_power();
        let mut cap = PowerCapLoop::new(PowerCapParams {
            budget_w: full - 5.0,
            restore_margin: 0.99,
            ..Default::default()
        });
        // Scan 1: over budget → both hosts throttle one step and
        // acquire ceilings.
        let a1 = {
            let ctx = ScheduleContext::new(0.0, &c);
            cap.scan(&ctx, None)
        };
        assert_eq!(a1.len(), 2, "{a1:?}");
        for a in &a1 {
            if let ControlAction::SetFreq { host, freq } = a {
                assert_eq!(*freq, 0.85);
                c.host_mut(*host).set_freq(*freq);
            }
        }
        // Budget with room to restore exactly ONE host by one step.
        let delta = {
            let mut s = c.clone();
            s.host_mut(HostId(0)).set_freq(1.0);
            s.total_power() - c.total_power()
        };
        let budget = c.total_power() + 1.5 * delta;
        cap.set_budget(budget);
        let a2 = {
            let ctx = ScheduleContext::new(30.0, &c);
            cap.scan(&ctx, None)
        };
        assert_eq!(a2.len(), 1, "room for exactly one restore: {a2:?}");
        assert!(matches!(
            a2[0],
            ControlAction::SetFreq { freq, .. } if freq == 1.0
        ));
        assert!(projected_power(&c, &a2) <= budget + 1e-9);
        // The restored host's ceiling is released: with ample budget
        // only the still-capped host moves.
        for a in &a2 {
            if let ControlAction::SetFreq { host, freq } = a {
                c.host_mut(*host).set_freq(*freq);
            }
        }
        cap.set_budget(full + 100.0);
        let a3 = {
            let ctx = ScheduleContext::new(60.0, &c);
            cap.scan(&ctx, None)
        };
        assert_eq!(a3.len(), 1, "{a3:?}");
        for a in &a3 {
            if let ControlAction::SetFreq { host, freq } = a {
                c.host_mut(*host).set_freq(*freq);
            }
        }
        // Everything restored, all ceilings spent: steady state.
        let a4 = {
            let ctx = ScheduleContext::new(90.0, &c);
            cap.scan(&ctx, None)
        };
        assert!(a4.is_empty(), "{a4:?}");
    }

    #[test]
    fn dead_band_plans_nothing_and_leaves_foreign_clockdowns_alone() {
        // Host 0 was clocked down by the DVFS governor (no ceiling
        // recorded here): inside the hysteresis band the cap must not
        // touch it, and even comfortably under budget it must not
        // restore a clock-down it does not own.
        let mut c = loaded(2, 14.0);
        c.host_mut(HostId(0)).set_freq(0.7);
        let now = c.total_power();
        let mut cap = PowerCapLoop::new(PowerCapParams {
            budget_w: now * 1.02, // within 2 %: above the 0.9 margin
            ..Default::default()
        });
        let ctx = ScheduleContext::new(0.0, &c);
        assert!(cap.scan(&ctx, None).is_empty());
        // Far under budget: still no restore — the ceiling set is empty.
        cap.set_budget(now * 3.0);
        assert!(cap.scan(&ctx, None).is_empty());
        // Over budget: throttling remains available.
        cap.set_budget(now - 50.0);
        assert!(!cap.scan(&ctx, None).is_empty());
    }
}
