//! Scheduling layer (§III-C): the batch-first placement API and the
//! unified periodic control loops.
//!
//! * [`ScheduleContext`] — one read-only view (cluster + telemetry
//!   window + history + sim clock) every decision consults.
//! * [`PlacementPolicy`] — batch-first placement: `decide_batch`
//!   scores a whole submit burst against one frozen context; the
//!   energy-aware policy runs it as a single predictor call over the
//!   full (request × host) feature matrix.
//! * [`ControlLoop`] — the periodic scans (adaptive consolidation,
//!   DVFS governor) behind one trait, borrowing the policy's
//!   predictor through an explicit [`ScoringHandle`].
//! * Policies: the energy-aware predictive scheduler (Eqs. 6–9), the
//!   round-robin baseline (§IV-E), and classic bin-packing baselines.

pub mod best_fit;
pub mod consolidation;
pub mod context;
pub mod control;
pub mod dvfs;
pub mod energy_aware;
pub mod first_fit;
pub mod policy;
pub mod round_robin;

pub use best_fit::BestFit;
pub use consolidation::{ConsolidationParams, Consolidator, VmContext};
pub use context::ScheduleContext;
pub use control::{ControlAction, ControlLoop, ScoringHandle};
pub use dvfs::{DvfsGovernor, DvfsParams};
pub use energy_aware::{EnergyAware, EnergyAwareParams};
pub use first_fit::FirstFit;
pub use policy::{Decision, PlacementPolicy, PlacementRequest};
pub use round_robin::RoundRobin;
