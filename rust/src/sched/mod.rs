//! Scheduling policies (§III-C): the energy-aware predictive scheduler
//! (Eqs. 6–9), the round-robin baseline (§IV-E), classic bin-packing
//! baselines, adaptive consolidation, and the DVFS governor.

pub mod best_fit;
pub mod consolidation;
pub mod dvfs;
pub mod energy_aware;
pub mod first_fit;
pub mod policy;
pub mod round_robin;

pub use best_fit::BestFit;
pub use consolidation::{Action, ConsolidationParams, Consolidator, VmContext};
pub use dvfs::{DvfsGovernor, DvfsParams, SetFreq};
pub use energy_aware::{EnergyAware, EnergyAwareParams};
pub use first_fit::FirstFit;
pub use policy::{Decision, PlacementPolicy, PlacementRequest};
pub use round_robin::RoundRobin;
