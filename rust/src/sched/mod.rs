//! Scheduling layer (§III-C): the batch-first placement API and the
//! unified periodic control loops, both shard-addressable.
//!
//! * [`ScheduleContext`] — one read-only view (cluster + telemetry
//!   window + history + sim clock, plus the optional shard layer)
//!   every decision consults; `context.shard(s)` yields a per-shard
//!   lens with the same read API.
//! * [`PlacementPolicy`] — batch-first placement: `decide_batch`
//!   scores a whole submit burst against one frozen context; the
//!   energy-aware policy runs it as a single predictor call over the
//!   full (request × host) feature matrix — or, on a sharded
//!   context, fans the burst out to the top-K shards by digest
//!   headroom with one predictor call per shard.
//! * [`ControlLoop`] — the periodic scans (adaptive consolidation,
//!   DVFS governor, power capping) behind one trait, borrowing the
//!   policy's predictor through an explicit [`ScoringHandle`]; scans
//!   run as per-shard passes with digest-driven cross-shard
//!   fallbacks.
//! * Policies: the energy-aware predictive scheduler (Eqs. 6–9), the
//!   round-robin baseline (§IV-E), and classic bin-packing baselines.

pub mod best_fit;
pub mod consolidation;
pub mod context;
pub mod control;
pub mod dvfs;
pub mod energy_aware;
pub mod first_fit;
pub mod policy;
pub mod power_cap;
pub mod round_robin;
pub(crate) mod worker_score;

pub use best_fit::BestFit;
pub use consolidation::{ConsolidationParams, Consolidator, VmContext};
pub use context::{ScheduleContext, ShardContext, ShardHosts};
pub use control::{ControlAction, ControlLoop, ScoringHandle};
pub use dvfs::{DvfsGovernor, DvfsParams};
pub use energy_aware::{EnergyAware, EnergyAwareParams};
pub use first_fit::FirstFit;
pub use policy::{Decision, PlacementPolicy, PlacementRequest};
pub use power_cap::{PowerCapLoop, PowerCapParams};
pub use round_robin::RoundRobin;
