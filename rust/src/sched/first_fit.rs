//! First-fit placement: lowest-index powered-on host that fits. A
//! classic bin-packing baseline — denser than round-robin but blind to
//! workload behaviour and energy.

use crate::sched::policy::{Decision, PlacementPolicy, PlacementRequest};
use crate::sched::ScheduleContext;

#[derive(Debug, Default)]
pub struct FirstFit;

impl PlacementPolicy for FirstFit {
    fn name(&self) -> &'static str {
        "first_fit"
    }

    fn decide(&mut self, req: &PlacementRequest, ctx: &ScheduleContext<'_>) -> Decision {
        for host in &ctx.cluster.hosts {
            if host.fits(&req.flavor, ctx.cluster.reserved(host.id)) {
                return Decision::Place(host.id);
            }
        }
        Decision::Defer
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::flavor::{LARGE, MEDIUM};
    use crate::cluster::{Cluster, HostId};
    use crate::profile::ResourceVector;
    use crate::workload::JobId;

    fn req() -> PlacementRequest {
        PlacementRequest {
            job: JobId(0),
            flavor: MEDIUM,
            vector: ResourceVector::default(),
            remaining_solo: 100.0,
            avoid_rack: None,
        }
    }

    fn decide(p: &mut FirstFit, req: &PlacementRequest, c: &Cluster) -> Decision {
        p.decide(req, &ScheduleContext::new(0.0, c))
    }

    #[test]
    fn packs_first_host_until_full() {
        let mut c = Cluster::homogeneous(2);
        let mut ff = FirstFit;
        // MEDIUM = 16 GB → 4 fit in 64 GB.
        for _ in 0..4 {
            assert_eq!(decide(&mut ff, &req(), &c), Decision::Place(HostId(0)));
            let vm = c.create_vm(MEDIUM, JobId(0), 0.0);
            c.place_vm(vm, HostId(0)).unwrap();
        }
        assert_eq!(decide(&mut ff, &req(), &c), Decision::Place(HostId(1)));
    }

    #[test]
    fn defers_when_nothing_fits() {
        let mut c = Cluster::homogeneous(1);
        for _ in 0..2 {
            let vm = c.create_vm(LARGE, JobId(0), 0.0);
            c.place_vm(vm, HostId(0)).unwrap();
        }
        let mut ff = FirstFit;
        let r = PlacementRequest {
            flavor: LARGE,
            ..req()
        };
        assert_eq!(decide(&mut ff, &r, &c), Decision::Defer);
    }
}
