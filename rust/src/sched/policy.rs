//! Placement-policy interface: given profiled workloads and the
//! scheduling context, choose hosts (or ask for capacity).
//!
//! The interface is batch-first: the coordinator hands every submit
//! burst and deferred-queue drain to [`PlacementPolicy::decide_batch`]
//! against one frozen [`ScheduleContext`]. Policies with a learned
//! predictor override it to score the full (request × candidate-host)
//! feature matrix in a single predictor call — the shape the L1
//! `score_hosts` Pallas kernel is built for.

use crate::cluster::{Cluster, Flavor, HostId};
use crate::profile::ResourceVector;
use crate::sched::{ScheduleContext, ScoringHandle};
use crate::workload::JobId;

/// Everything a policy may consult about the workload being placed.
#[derive(Debug, Clone)]
pub struct PlacementRequest {
    pub job: JobId,
    pub flavor: Flavor,
    /// Eq. 1 profile (from history for recurring kinds, else from the
    /// phase model at submission).
    pub vector: ResourceVector,
    /// Remaining solo work (s) — scales the energy stake of the choice.
    pub remaining_solo: f64,
    /// Fault domain (rack) the job was just evacuated from, if any:
    /// energy-aware scoring penalizes candidates in this rack so
    /// re-placements prefer cross-rack diversity. `None` for fresh
    /// submissions — the common case — leaves scoring untouched.
    pub avoid_rack: Option<usize>,
}

/// A policy's verdict.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Decision {
    /// Place on this powered-on host now.
    Place(HostId),
    /// Boot this host, then place there when it is up.
    PowerOnAndPlace(HostId),
    /// No acceptable host: queue the job and retry later.
    Defer,
}

/// Placement policy interface. `&mut self` because learned policies
/// carry predictors/buffers.
pub trait PlacementPolicy {
    fn name(&self) -> &'static str;

    /// Decide placement for a single request.
    fn decide(&mut self, req: &PlacementRequest, ctx: &ScheduleContext<'_>) -> Decision;

    /// Decide a whole batch against the same frozen context. The
    /// default is the sequential loop; native implementations must be
    /// decision-equivalent to it — bit-identical output on the same
    /// `(reqs, ctx)` — which the batch-API tests assert.
    fn decide_batch(
        &mut self,
        reqs: &[PlacementRequest],
        ctx: &ScheduleContext<'_>,
    ) -> Vec<Decision> {
        reqs.iter().map(|req| self.decide(req, ctx)).collect()
    }

    /// Whether this policy wants the periodic control loops active
    /// (the baseline round-robin runs without them, §IV-E).
    fn wants_consolidation(&self) -> bool {
        false
    }

    /// The policy's prediction engine, if it has one — control loops
    /// borrow it through this handle to score migration targets.
    /// Object-safe and explicit; no downcasting.
    fn scoring_handle(&mut self) -> Option<ScoringHandle<'_>> {
        None
    }
}

/// Hosts that can take the flavor *now* (powered on + fits).
pub fn feasible_now(cluster: &Cluster, flavor: &Flavor) -> Vec<HostId> {
    cluster.feasible_hosts(flavor)
}

/// Powered-off hosts (candidates for PowerOnAndPlace).
pub fn powered_off(cluster: &Cluster) -> Vec<HostId> {
    cluster
        .hosts
        .iter()
        .filter(|h| h.state.is_off())
        .map(|h| h.id)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::flavor::MEDIUM;

    #[test]
    fn feasibility_helpers() {
        let mut c = Cluster::homogeneous(3);
        c.host_mut(HostId(2)).power_off(0.0);
        c.advance_power_states(1000.0);
        assert_eq!(
            feasible_now(&c, &MEDIUM),
            vec![HostId(0), HostId(1)]
        );
        assert_eq!(powered_off(&c), vec![HostId(2)]);
    }

    #[test]
    fn default_decide_batch_is_the_sequential_loop() {
        // A policy whose decisions depend on internal mutable state:
        // the default decide_batch must advance that state exactly as
        // the sequential loop would.
        struct Cycler {
            next: usize,
        }
        impl PlacementPolicy for Cycler {
            fn name(&self) -> &'static str {
                "cycler"
            }
            fn decide(&mut self, _req: &PlacementRequest, ctx: &ScheduleContext<'_>) -> Decision {
                let n = ctx.cluster.n_hosts();
                let h = HostId(self.next % n);
                self.next += 1;
                Decision::Place(h)
            }
        }
        let c = Cluster::homogeneous(2);
        let ctx = ScheduleContext::new(0.0, &c);
        let req = PlacementRequest {
            job: crate::workload::JobId(0),
            flavor: MEDIUM,
            vector: ResourceVector::default(),
            remaining_solo: 10.0,
            avoid_rack: None,
        };
        let reqs = vec![req.clone(), req.clone(), req];
        let batch = Cycler { next: 0 }.decide_batch(&reqs, &ctx);
        let mut seq_policy = Cycler { next: 0 };
        let seq: Vec<Decision> = reqs.iter().map(|r| seq_policy.decide(r, &ctx)).collect();
        assert_eq!(batch, seq);
        assert_eq!(
            batch,
            vec![
                Decision::Place(HostId(0)),
                Decision::Place(HostId(1)),
                Decision::Place(HostId(0)),
            ]
        );
    }
}
