//! Placement-policy interface: given a profiled workload and the
//! cluster state, choose a host (or ask for capacity).

use crate::cluster::{Cluster, Flavor, HostId};
use crate::profile::ResourceVector;
use crate::workload::JobId;

/// Everything a policy may consult about the workload being placed.
#[derive(Debug, Clone)]
pub struct PlacementRequest {
    pub job: JobId,
    pub flavor: Flavor,
    /// Eq. 1 profile (from history for recurring kinds, else from the
    /// phase model at submission).
    pub vector: ResourceVector,
    /// Remaining solo work (s) — scales the energy stake of the choice.
    pub remaining_solo: f64,
}

/// A policy's verdict.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Decision {
    /// Place on this powered-on host now.
    Place(HostId),
    /// Boot this host, then place there when it is up.
    PowerOnAndPlace(HostId),
    /// No acceptable host: queue the job and retry later.
    Defer,
}

/// Placement policy interface. `&mut self` because learned policies
/// carry predictors/buffers.
pub trait PlacementPolicy {
    fn name(&self) -> &'static str;

    fn decide(&mut self, req: &PlacementRequest, cluster: &Cluster) -> Decision;

    /// Whether this policy wants the consolidation loop active
    /// (the baseline round-robin runs without it, §IV-E).
    fn wants_consolidation(&self) -> bool {
        false
    }

    /// Access to the policy's prediction engine, if it has one — the
    /// consolidation scan reuses it to score migration targets. (Rust
    /// trait objects have no downcasting without `Any`; this keeps the
    /// coupling explicit and object-safe.)
    fn as_energy_aware(&mut self) -> Option<&mut crate::sched::EnergyAware> {
        None
    }
}

/// Hosts that can take the flavor *now* (powered on + fits).
pub fn feasible_now(cluster: &Cluster, flavor: &Flavor) -> Vec<HostId> {
    cluster.feasible_hosts(flavor)
}

/// Powered-off hosts (candidates for PowerOnAndPlace).
pub fn powered_off(cluster: &Cluster) -> Vec<HostId> {
    cluster
        .hosts
        .iter()
        .filter(|h| h.state.is_off())
        .map(|h| h.id)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::flavor::MEDIUM;

    #[test]
    fn feasibility_helpers() {
        let mut c = Cluster::homogeneous(3);
        c.host_mut(HostId(2)).power_off(0.0);
        c.advance_power_states(1000.0);
        assert_eq!(
            feasible_now(&c, &MEDIUM),
            vec![HostId(0), HostId(1)]
        );
        assert_eq!(powered_off(&c), vec![HostId(2)]);
    }
}
