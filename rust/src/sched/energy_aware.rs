//! The paper's scheduler (§III-C): minimize predicted energy subject
//! to SLA constraints (Eqs. 6–7), with the adaptive placement
//! restriction of Eq. 9 (no placements onto hosts above δ_high).
//!
//! For each feasible host the prediction engine estimates the marginal
//! power and the slowdown the placement would cause; the scheduler
//! minimizes *predicted energy to completion*
//!
//! ```text
//! Ê = power_w · remaining_solo · (1 + slowdown)
//! ```
//!
//! rejecting hosts whose predicted slowdown would breach the job's SLA
//! slack. If no powered-on host qualifies, it asks for a powered-off
//! host (paying the boot-energy transient in the objective) rather
//! than violating Eq. 7.
//!
//! Batching: `decide_batch` assembles the feature rows of *every*
//! (request, candidate-host) pair into one matrix and issues a single
//! predictor call — the shape the L1 `score_hosts` kernel executes as
//! one (B × 16)·(16 × 64)·(64 × 32)·(32 × 2) pipeline. Per-row
//! results are independent of batch composition (dense per-row math),
//! so batched decisions are bit-identical to the sequential loop.
//!
//! Sharded fan-out: when the context carries a [`ShardedCluster`],
//! `decide_batch` routes the burst to the top-K shards by digest
//! headroom, scores each shard's request×host feature matrix with one
//! `predict_into` call per shard, and merges winners globally by
//! `(energy, host id)` — the same argmin the flat sweep computes, so
//! at shard_count = 1 (or K = shard_count) the fan-out is
//! action-identical to the unsharded path. Per-decision work is then
//! bounded by the K largest shards instead of the whole fleet.
//!
//! Parallel fan-out: when the context additionally carries a
//! persistent [`WorkerPool`] with more than one worker and the
//! predictor can be cloned ([`EnergyPredictor::try_clone`]), the
//! top-K shard sweeps are dispatched to their affinity workers
//! (shard `s` always runs on the same worker — `WorkerPool::worker_for`
//! — so a worker's arenas keep seeing the same shards' views). Each worker scores
//! through an **epoch-cached** predictor clone held in its
//! [`crate::runtime::WorkerSlot`] (see `sched::worker_score`):
//! re-cloned only when [`EnergyPredictor::weight_epoch`] says the
//! cached copy is stale, never per fan-out. Per-shard winners are
//! merged by the same `(energy, host id)` rule, which is a total
//! order: merge order, and therefore worker count, cannot change any
//! decision. The serial sweep stays the oracle path
//! (`worker_threads = 1`), pinned by the equivalence property tests
//! in `rust/tests/pool.rs` — including across mid-campaign
//! `set_weights` calls. Small bursts skip dispatch entirely
//! ([`EnergyAwareParams::inline_burst_rows`]): below the threshold
//! the channel round-trip costs more than the scoring it would
//! parallelize.

use crate::cluster::{HostId, HostView, ShardedCluster};
use crate::predict::{EnergyPredictor, Prediction};
use crate::runtime::{WorkerPool, WorkerSlot};
use crate::sched::policy::{powered_off, Decision, PlacementPolicy, PlacementRequest};
use crate::sched::worker_score::{stage_installs, WorkerScore};
use crate::sched::{ScheduleContext, ScoringHandle};

/// Tunables (defaults follow §III-C and the SLA slack of §V-B).
#[derive(Debug, Clone, Copy)]
pub struct EnergyAwareParams {
    /// Eq. 9 upper threshold: no placement onto hosts above this CPU
    /// utilization.
    pub delta_high: f64,
    /// Maximum predicted slowdown accepted for a placement — the SLA
    /// guard (the tracker enforces the real constraint; this is the
    /// predictive filter that keeps violations at zero).
    pub max_slowdown: f64,
    /// Amortized boot-energy penalty (J) charged when choosing a
    /// powered-off host.
    pub boot_penalty_j: f64,
    /// Post-placement utilization headroom: a candidate is rejected if
    /// any dimension the workload meaningfully uses would exceed this
    /// after placement. This is what keeps JCT deviation <5 % with
    /// zero violations (§V-B) — predicted slowdown alone is an
    /// instantaneous estimate and leaves no margin for phase shifts
    /// and future arrivals.
    pub headroom: f64,
    /// Shard fan-out width: `decide_batch` scores the top
    /// `min(top_k_shards, shard_count)` shards by digest headroom
    /// when the context carries a sharded cluster. Bounds
    /// per-decision work by the K largest shards instead of the
    /// fleet; K = shard_count recovers the exhaustive sweep.
    pub top_k_shards: usize,
    /// Small-burst fast path: when the burst's estimated candidate
    /// rows (requests × hosts in the selected shards, an upper bound
    /// on the feature matrix) fall below this, `decide_batch` skips
    /// pool dispatch and runs the inline serial sweep — below the
    /// threshold the per-fan-out channel round-trip costs more than
    /// the scoring it would parallelize. The default comes from the
    /// burst sweep in `benches/bench_pool.rs` (`BENCH_pool.json`);
    /// re-derive it there when dispatch costs change. `0` disables
    /// the fast path (benches/tests use it to force dispatch).
    pub inline_burst_rows: usize,
}

impl Default for EnergyAwareParams {
    fn default() -> Self {
        EnergyAwareParams {
            delta_high: 0.85,
            max_slowdown: 0.05,
            boot_penalty_j: 160.0 * 90.0, // HOST_START_UP_POWER × HOST_START_UP_DELAY
            headroom: 0.93,
            top_k_shards: 4,
            inline_burst_rows: 128,
        }
    }
}

/// Multiplicative score surcharge for a candidate host in the rack
/// the request was just evacuated from ([`PlacementRequest::avoid_rack`]):
/// re-placements prefer a different fault domain when one is within
/// 5 % predicted energy of the same-rack optimum.
pub const SAME_RACK_PENALTY: f64 = 0.05;

/// Append one request's SLA-safe candidates (and feature rows) from
/// the pruned views to the given arena; returns the `[start, end)`
/// span. The ONE gather body behind both the serial sweep (policy
/// arena) and the pooled sweep (worker arenas), so the two candidate
/// sets cannot drift.
fn gather_candidates_into(
    params: &EnergyAwareParams,
    req: &PlacementRequest,
    views: &[HostView],
    cands: &mut Vec<(HostId, f64, bool)>,
    feats: &mut Vec<[f32; crate::profile::FEAT_DIM]>,
) -> (usize, usize) {
    let start = cands.len();
    for v in views {
        if !v.fits(&req.flavor) {
            continue;
        }
        // Headroom filter on the dimensions the workload uses.
        let (pc, pm, pd, pn) = crate::predict::oracle::post_utilization(&req.vector, &v.util);
        let hr = params.headroom;
        if (req.vector.cpu > 0.1 && pc > hr)
            || (req.vector.mem > 0.1 && pm > hr)
            || (req.vector.disk > 0.1 && pd > hr)
            || (req.vector.net > 0.1 && pn > hr)
        {
            continue;
        }
        // Tag candidates sharing the evacuated job's fault domain;
        // the argmin applies the domain-diversity penalty. Fresh
        // submissions (`avoid_rack: None`) tag nothing.
        cands.push((v.id, v.idle_share, req.avoid_rack == Some(v.rack)));
        feats.push(crate::profile::features::build_features_from(
            &req.vector,
            req.remaining_solo,
            &v.util,
            v.n_vms,
            v.freq,
        ));
    }
    (start, cands.len())
}

/// Argmin of predicted energy-to-completion over one request's scored
/// candidates, honoring the Eq. 7 guard. Candidates are visited
/// ascending by host id and ties keep the first (lowest-id) host.
fn argmin_energy_span(
    params: &EnergyAwareParams,
    req: &PlacementRequest,
    cands: &[(HostId, f64, bool)],
    preds: &[Prediction],
) -> Option<(HostId, f64)> {
    let mut best: Option<(HostId, f64)> = None;
    for (&(host, idle_share, same_rack), p) in cands.iter().zip(preds) {
        if p.slowdown > params.max_slowdown {
            continue; // Eq. 7 predictive guard
        }
        // Eq. 6 minimizes *total* cluster energy, not marginal
        // power: under the linear Eq. 5 model the marginal draw
        // of a placement is nearly host-independent, and the real
        // lever is the idle floor of hosts kept on. Charge each
        // candidate an amortized share of its host's idle power —
        // an empty host carries the full P_idle for this job's
        // duration, a busy host's floor is already paid for.
        // Domain diversity for evacuations: staying in the crashed
        // rack risks eating the *next* correlated failure, modeled as
        // a flat expected-rework surcharge. Purely a scoring term —
        // same-rack hosts stay eligible when nothing else fits.
        let diversity = if same_rack { SAME_RACK_PENALTY } else { 0.0 };
        let energy =
            (p.power_w + idle_share) * req.remaining_solo * (1.0 + p.slowdown) * (1.0 + diversity);
        if best.map(|(_, e)| energy < e).unwrap_or(true) {
            best = Some((host, energy));
        }
    }
    best
}

/// Merge one shard's per-request winner into the running best by
/// lexicographic `(energy, host id)` — a total order over candidates
/// (host ids are unique), so neither shard iteration order nor the
/// pool's merge order can change the outcome. Shared by the serial
/// and pooled fan-outs.
fn merge_winner(best: &mut Option<(HostId, f64)>, winner: Option<(HostId, f64)>) {
    if let Some((host, energy)) = winner {
        let better = match *best {
            None => true,
            Some((bh, be)) => energy < be || (energy == be && host < bh),
        };
        if better {
            *best = Some((host, energy));
        }
    }
}

pub struct EnergyAware {
    pub predictor: Box<dyn EnergyPredictor>,
    pub params: EnergyAwareParams,
    /// Scratch buffers reused across decisions — the scoring arena.
    /// No per-call allocation at steady state: the candidate list,
    /// feature matrix, per-request `[start, end)` spans, pruned host
    /// views, and the predictor's output all live here and are
    /// refilled in place ([`EnergyPredictor::predict_into`]).
    feats: Vec<[f32; crate::profile::FEAT_DIM]>,
    /// Candidate hosts with their precomputed amortized idle share
    /// and the same-rack (domain-diversity penalty) tag.
    cands: Vec<(HostId, f64, bool)>,
    spans: Vec<(usize, usize)>,
    views: Vec<HostView>,
    preds: Vec<Prediction>,
}

impl EnergyAware {
    pub fn new(predictor: Box<dyn EnergyPredictor>, params: EnergyAwareParams) -> EnergyAware {
        EnergyAware {
            predictor,
            params,
            feats: Vec::new(),
            cands: Vec::new(),
            spans: Vec::new(),
            views: Vec::new(),
            preds: Vec::new(),
        }
    }

    /// Append this request's SLA-safe candidate hosts (and their
    /// feature rows) to the scratch buffers; returns the span.
    ///
    /// Candidates come from the pruned [`HostView`] snapshot, built
    /// once per frozen context: hot hosts (Eq. 9) and non-accepting
    /// hosts are already excluded, and each view carries the O(1)
    /// cached effective utilization — per-request work no longer
    /// touches every host or recomputes expected load.
    fn gather_candidates(&mut self, req: &PlacementRequest, views: &[HostView]) -> (usize, usize) {
        gather_candidates_into(&self.params, req, views, &mut self.cands, &mut self.feats)
    }

    /// Argmin of predicted energy-to-completion over one request's
    /// candidate span `[start, end)` of the policy arena — returning
    /// the energy alongside the winner lets the sharded fan-out merge
    /// per-shard argmins into exactly this global argmin.
    fn argmin_energy(
        &self,
        req: &PlacementRequest,
        start: usize,
        end: usize,
    ) -> Option<(HostId, f64)> {
        argmin_energy_span(&self.params, req, &self.cands[start..end], &self.preds[start..end])
    }

    /// Fan the selected shard sweeps out to their affinity workers on
    /// the persistent pool: each worker scores through the
    /// epoch-cached predictor clone and arenas in its slot
    /// ([`WorkerScore`]), running the same gather → predict → argmin
    /// body as the serial sweep, and returns one `(host, energy)`
    /// winner per request. Returns `None` (caller runs the serial
    /// sweep) when the predictor cannot be cloned.
    fn sweep_shards_pooled(
        &self,
        reqs: &[PlacementRequest],
        sh: &ShardedCluster,
        shards: &[usize],
        pool: &WorkerPool,
    ) -> Option<Vec<Vec<Option<(HostId, f64)>>>> {
        let mut staged = stage_installs(pool, shards.iter().copied(), self.predictor.as_ref())?;
        let epoch = staged.epoch;
        let params = self.params;
        let jobs: Vec<_> = shards
            .iter()
            .map(|&s| {
                // The first job per worker carries that worker's fresh
                // clone (if its cache was stale); later jobs reuse.
                let install = staged.take(pool.worker_for(s));
                (s, move |w: &mut WorkerSlot| {
                    let st = WorkerScore::fetch(w, epoch, install);
                    sh.shard_scoring_views(s, params.delta_high, &mut st.views);
                    st.feats.clear();
                    st.cands.clear();
                    st.spans.clear();
                    for req in reqs {
                        let span = gather_candidates_into(
                            &params,
                            req,
                            &st.views,
                            &mut st.cands,
                            &mut st.feats,
                        );
                        st.spans.push(span);
                    }
                    st.preds.clear();
                    if !st.feats.is_empty() {
                        st.predictor.predict_into(&st.feats, &mut st.preds);
                    }
                    reqs.iter()
                        .zip(&st.spans)
                        .map(|(req, &(a, b))| {
                            argmin_energy_span(&params, req, &st.cands[a..b], &st.preds[a..b])
                        })
                        .collect::<Vec<_>>()
                })
            })
            .collect();
        let winners = pool
            .dispatch(jobs)
            .unwrap_or_else(|e| panic!("parallel decide_batch fan-out poisoned: {e}"));
        Some(winners)
    }

    /// Sharded fan-out: route the burst to the top-K shards by digest
    /// headroom, score one request×host matrix per shard (one
    /// `predict_into` each), merge winners globally by
    /// `(energy, host id)`. At K = shard_count the candidate set is
    /// the whole fleet and the result is action-identical to the flat
    /// sweep — the shard_count = 1 property test pins this down. With
    /// a worker pool on the context the K sweeps run in parallel,
    /// bit-identical to this serial loop at any worker count (the
    /// merge rule is a total order).
    fn decide_batch_sharded(
        &mut self,
        reqs: &[PlacementRequest],
        ctx: &ScheduleContext<'_>,
        sh: &ShardedCluster,
    ) -> Vec<Decision> {
        let n_shards = sh.shard_count();
        let k = self.params.top_k_shards.clamp(1, n_shards);
        // Rank shards by headroom (descending), lowest id on ties.
        let mut order: Vec<usize> = (0..n_shards).collect();
        order.sort_by(|&a, &b| {
            sh.digest(b)
                .headroom_score()
                .partial_cmp(&sh.digest(a).headroom_score())
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.cmp(&b))
        });
        let mut best: Vec<Option<(HostId, f64)>> = vec![None; reqs.len()];
        let pooled = ctx.pool.and_then(|pool| {
            if !pool.parallel() || k <= 1 {
                return None; // width 1 / one shard: the inline oracle
            }
            // Small-burst fast path: upper-bound the feature matrix by
            // requests × member hosts of the selected shards; below
            // the threshold dispatch overhead dominates, run inline.
            let est_rows: usize =
                reqs.len() * order[..k].iter().map(|&s| sh.members(s).len()).sum::<usize>();
            if est_rows < self.params.inline_burst_rows {
                return None;
            }
            self.sweep_shards_pooled(reqs, sh, &order[..k], pool)
        });
        if let Some(per_shard) = pooled {
            for shard_winners in per_shard {
                for (b, w) in best.iter_mut().zip(shard_winners) {
                    merge_winner(b, w);
                }
            }
        } else {
            for &s in &order[..k] {
                self.feats.clear();
                self.cands.clear();
                self.spans.clear();
                sh.shard_scoring_views(s, self.params.delta_high, &mut self.views);
                let views = std::mem::take(&mut self.views);
                for req in reqs {
                    let span = self.gather_candidates(req, &views);
                    self.spans.push(span);
                }
                self.views = views;
                self.preds.clear();
                if !self.feats.is_empty() {
                    self.predictor.predict_into(&self.feats, &mut self.preds);
                }
                for (i, (req, &(start, end))) in reqs.iter().zip(&self.spans).enumerate() {
                    merge_winner(&mut best[i], self.argmin_energy(req, start, end));
                }
            }
        }
        let cluster = ctx.cluster;
        // Boot fallback, identical to the flat path: first powered-off
        // host, computed lazily once per batch.
        let mut boot: Option<Option<HostId>> = None;
        best.iter()
            .map(|b| match b {
                Some((host, _)) => Decision::Place(*host),
                None => {
                    let fallback =
                        *boot.get_or_insert_with(|| powered_off(cluster).first().copied());
                    match fallback {
                        Some(h) => Decision::PowerOnAndPlace(h),
                        None => Decision::Defer,
                    }
                }
            })
            .collect()
    }
}

impl PlacementPolicy for EnergyAware {
    fn name(&self) -> &'static str {
        "energy_aware"
    }

    /// Single-request fast path: same gather → predict → argmin as
    /// the batch, without materializing a decision vector. On a
    /// sharded context this routes through the fan-out as a burst of
    /// one, so live re-decisions (stale-placement retries, deferred
    /// drains) stay bounded by the top-K shards and agree with what
    /// `decide_batch` would have chosen — not an O(fleet) sweep.
    fn decide(&mut self, req: &PlacementRequest, ctx: &ScheduleContext<'_>) -> Decision {
        if let Some(sh) = ctx.shards {
            let mut out = self.decide_batch_sharded(std::slice::from_ref(req), ctx, sh);
            return out.pop().expect("one decision per request");
        }
        let cluster = ctx.cluster;
        self.feats.clear();
        self.cands.clear();
        self.spans.clear();
        cluster.scoring_views(self.params.delta_high, &mut self.views);
        let views = std::mem::take(&mut self.views);
        let (start, end) = self.gather_candidates(req, &views);
        self.views = views;
        self.preds.clear();
        if !self.feats.is_empty() {
            self.predictor.predict_into(&self.feats, &mut self.preds);
        }
        match self.argmin_energy(req, start, end) {
            Some((host, _)) => Decision::Place(host),
            // No SLA-safe powered-on host: boot one rather than
            // violate Eq. 7 (capacity beats consolidation when they
            // conflict).
            None => match powered_off(cluster).first().copied() {
                Some(h) => Decision::PowerOnAndPlace(h),
                None => Decision::Defer,
            },
        }
    }

    /// Native batched path: one predictor invocation scores the full
    /// (pending requests × feasible hosts) feature matrix. The pruned
    /// host views are built once for the whole batch. With a shard
    /// layer on the context the burst instead fans out across the
    /// top-K shards by digest headroom — one predictor call per shard,
    /// winners merged globally.
    fn decide_batch(
        &mut self,
        reqs: &[PlacementRequest],
        ctx: &ScheduleContext<'_>,
    ) -> Vec<Decision> {
        if let Some(sh) = ctx.shards {
            return self.decide_batch_sharded(reqs, ctx, sh);
        }
        let cluster = ctx.cluster;
        self.feats.clear();
        self.cands.clear();
        self.spans.clear();
        cluster.scoring_views(self.params.delta_high, &mut self.views);
        let views = std::mem::take(&mut self.views);
        for req in reqs {
            let span = self.gather_candidates(req, &views);
            self.spans.push(span);
        }
        self.views = views;
        self.preds.clear();
        if !self.feats.is_empty() {
            self.predictor.predict_into(&self.feats, &mut self.preds);
        }
        // Boot fallback: the first powered-off host, identical for
        // every request in the frozen context (the coordinator
        // re-decides duplicate boot requests against the live
        // cluster, spreading them across hosts). Computed lazily —
        // the common all-candidates-placeable case never pays the
        // host scan.
        let mut boot: Option<Option<HostId>> = None;
        let mut out = Vec::with_capacity(reqs.len());
        for (req, &(start, end)) in reqs.iter().zip(&self.spans) {
            out.push(match self.argmin_energy(req, start, end) {
                Some((host, _)) => Decision::Place(host),
                // No SLA-safe powered-on host: boot one rather than
                // violate Eq. 7 (capacity beats consolidation when
                // they conflict).
                None => {
                    let fallback =
                        *boot.get_or_insert_with(|| powered_off(cluster).first().copied());
                    match fallback {
                        Some(h) => Decision::PowerOnAndPlace(h),
                        None => Decision::Defer,
                    }
                }
            });
        }
        out
    }

    fn wants_consolidation(&self) -> bool {
        true
    }

    fn scoring_handle(&mut self) -> Option<ScoringHandle<'_>> {
        Some(self.predictor.as_mut())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::flavor::MEDIUM;
    use crate::cluster::{Cluster, Demand};
    use crate::predict::OraclePredictor;
    use crate::profile::ResourceVector;
    use crate::workload::JobId;

    fn policy() -> EnergyAware {
        EnergyAware::new(Box::new(OraclePredictor), EnergyAwareParams::default())
    }

    fn decide(p: &mut EnergyAware, req: &PlacementRequest, c: &Cluster) -> Decision {
        let ctx = ScheduleContext::new(0.0, c);
        p.decide(req, &ctx)
    }

    fn io_req() -> PlacementRequest {
        PlacementRequest {
            job: JobId(0),
            flavor: MEDIUM,
            vector: ResourceVector {
                cpu: 0.2,
                mem: 0.4,
                disk: 0.6,
                net: 0.8,
                cpu_peak: 0.3,
                io_peak: 0.9,
                burstiness: 0.2,
            },
            remaining_solo: 600.0,
            avoid_rack: None,
        }
    }

    fn cpu_req() -> PlacementRequest {
        PlacementRequest {
            vector: ResourceVector {
                cpu: 0.95,
                mem: 0.5,
                disk: 0.05,
                net: 0.05,
                cpu_peak: 1.0,
                io_peak: 0.1,
                burstiness: 0.1,
            },
            ..io_req()
        }
    }

    #[test]
    fn colocates_io_jobs_on_busy_io_host() {
        // Host 0 already runs I/O load → marginal I/O power there is
        // lower (max(d,n) saturates). The oracle-driven policy must
        // co-locate (the §V-C observation).
        let mut c = Cluster::homogeneous(2);
        c.host_mut(HostId(0)).demand = Demand {
            cpu: 4.0,
            mem_gb: 16.0,
            disk_mbps: 200.0,
            net_mbps: 40.0,
        };
        let mut p = policy();
        assert_eq!(decide(&mut p, &io_req(), &c), Decision::Place(HostId(0)));
    }

    use crate::cluster::HostId;

    #[test]
    fn avoids_cpu_contention_for_cpu_jobs() {
        // Host 0 nearly CPU-saturated: a CPU-bound job must go to
        // host 1 even though host 0 would be "denser".
        let mut c = Cluster::homogeneous(2);
        c.host_mut(HostId(0)).demand = Demand {
            cpu: 28.0,
            mem_gb: 8.0,
            disk_mbps: 0.0,
            net_mbps: 0.0,
        };
        let mut p = policy();
        assert_eq!(decide(&mut p, &cpu_req(), &c), Decision::Place(HostId(1)));
    }

    #[test]
    fn evacuations_prefer_a_different_rack() {
        // Two identical hosts in different racks: a symmetric request
        // ties on energy and falls to the lowest id (host 0). An
        // evacuation out of rack 0 must flip to host 1 — and the
        // penalty must not strand the job when only the crashed rack
        // has capacity.
        let mut c = Cluster::homogeneous(2);
        c.host_mut(HostId(0)).rack = 0;
        c.host_mut(HostId(1)).rack = 1;
        let mut p = policy();
        assert_eq!(decide(&mut p, &io_req(), &c), Decision::Place(HostId(0)));
        let evac = PlacementRequest {
            avoid_rack: Some(0),
            ..io_req()
        };
        assert_eq!(decide(&mut p, &evac, &c), Decision::Place(HostId(1)));
        // Same-rack hosts remain eligible: with every host in rack 0,
        // the penalty cancels out and the tie-break reasserts itself.
        c.host_mut(HostId(1)).rack = 0;
        assert_eq!(decide(&mut p, &evac, &c), Decision::Place(HostId(0)));
    }

    #[test]
    fn delta_high_restricts_hot_hosts() {
        let mut c = Cluster::homogeneous(2);
        c.host_mut(HostId(0)).demand = Demand {
            cpu: 28.0, // 0.875 > δ_high=0.85
            mem_gb: 8.0,
            disk_mbps: 0.0,
            net_mbps: 0.0,
        };
        let mut p = policy();
        // Even an I/O job (which would suffer no slowdown) is kept off
        // the hot host by Eq. 9.
        assert_eq!(decide(&mut p, &io_req(), &c), Decision::Place(HostId(1)));
    }

    #[test]
    fn boots_host_when_all_on_hosts_are_unsafe() {
        let mut c = Cluster::homogeneous(3);
        // Hosts 0/1 hot, host 2 off.
        for h in 0..2 {
            c.host_mut(HostId(h)).demand = Demand {
                cpu: 30.0,
                mem_gb: 8.0,
                disk_mbps: 0.0,
                net_mbps: 0.0,
            };
        }
        c.host_mut(HostId(2)).power_off(0.0);
        c.advance_power_states(100.0);
        let mut p = policy();
        assert_eq!(
            decide(&mut p, &cpu_req(), &c),
            Decision::PowerOnAndPlace(HostId(2))
        );
    }

    #[test]
    fn defers_when_no_capacity_anywhere() {
        let mut c = Cluster::homogeneous(1);
        for _ in 0..4 {
            let vm = c.create_vm(MEDIUM, JobId(0), 0.0);
            c.place_vm(vm, HostId(0)).unwrap();
        }
        let mut p = policy();
        // Memory is fully reserved and no off host exists.
        assert_eq!(decide(&mut p, &io_req(), &c), Decision::Defer);
        assert!(p.wants_consolidation());
    }

    #[test]
    fn prefers_already_on_busy_host_over_idle_for_energy() {
        // Two hosts on: one moderately loaded, one idle. Placing on
        // the loaded one lets consolidation later power the idle one
        // down; the marginal-power objective must NOT prefer the idle
        // host when the loaded host is SLA-safe and strictly cheaper.
        let mut c = Cluster::homogeneous(2);
        c.host_mut(HostId(0)).demand = Demand {
            cpu: 8.0,
            mem_gb: 16.0,
            disk_mbps: 150.0,
            net_mbps: 40.0,
        };
        let mut p = policy();
        let d = decide(&mut p, &io_req(), &c);
        assert_eq!(d, Decision::Place(HostId(0)));
    }

    #[test]
    fn batch_matches_sequential_loop_bit_for_bit() {
        let mut c = Cluster::homogeneous(3);
        c.host_mut(HostId(0)).demand = Demand {
            cpu: 10.0,
            mem_gb: 20.0,
            disk_mbps: 300.0,
            net_mbps: 50.0,
        };
        c.host_mut(HostId(1)).demand = Demand {
            cpu: 24.0,
            mem_gb: 8.0,
            disk_mbps: 50.0,
            net_mbps: 10.0,
        };
        let reqs: Vec<PlacementRequest> = (0..6)
            .map(|i| {
                let mut r = if i % 2 == 0 { io_req() } else { cpu_req() };
                r.job = JobId(i as u64);
                r.remaining_solo = 120.0 + 97.0 * i as f64;
                r
            })
            .collect();
        let ctx = ScheduleContext::new(0.0, &c);
        let batch = policy().decide_batch(&reqs, &ctx);
        let mut seq_policy = policy();
        let seq: Vec<Decision> = reqs.iter().map(|r| seq_policy.decide(r, &ctx)).collect();
        assert_eq!(batch, seq);
    }

    #[test]
    fn scoring_handle_exposes_predictor() {
        let mut p = policy();
        let handle = p.scoring_handle().expect("energy-aware has a predictor");
        assert_eq!(handle.name(), "oracle");
    }

    fn mixed_cluster() -> Cluster {
        let mut c = Cluster::homogeneous(4);
        c.host_mut(HostId(0)).demand = Demand {
            cpu: 10.0,
            mem_gb: 20.0,
            disk_mbps: 300.0,
            net_mbps: 50.0,
        };
        c.host_mut(HostId(1)).demand = Demand {
            cpu: 24.0,
            mem_gb: 8.0,
            disk_mbps: 50.0,
            net_mbps: 10.0,
        };
        c.host_mut(HostId(3)).demand = Demand {
            cpu: 4.0,
            mem_gb: 30.0,
            disk_mbps: 500.0,
            net_mbps: 20.0,
        };
        c
    }

    fn mixed_burst() -> Vec<PlacementRequest> {
        (0..6)
            .map(|i| {
                let mut r = if i % 2 == 0 { io_req() } else { cpu_req() };
                r.job = JobId(i as u64);
                r.remaining_solo = 120.0 + 97.0 * i as f64;
                r
            })
            .collect()
    }

    #[test]
    fn single_shard_fanout_matches_flat_batch() {
        use crate::cluster::ShardedCluster;
        let c = mixed_cluster();
        let reqs = mixed_burst();
        let flat_ctx = ScheduleContext::new(0.0, &c);
        let flat = policy().decide_batch(&reqs, &flat_ctx);
        let sc = ShardedCluster::new(c.clone(), 1);
        let shard_ctx = ScheduleContext::new(0.0, &sc).with_shards(&sc);
        let sharded = policy().decide_batch(&reqs, &shard_ctx);
        assert_eq!(flat, sharded);
    }

    #[test]
    fn full_coverage_fanout_matches_flat_batch() {
        use crate::cluster::ShardedCluster;
        // K >= shard_count: the fan-out covers every shard, so the
        // merged argmin must equal the flat sweep exactly.
        let c = mixed_cluster();
        let reqs = mixed_burst();
        let flat_ctx = ScheduleContext::new(0.0, &c);
        let flat = policy().decide_batch(&reqs, &flat_ctx);
        let sc = ShardedCluster::new(c.clone(), 4);
        let shard_ctx = ScheduleContext::new(0.0, &sc).with_shards(&sc);
        let mut p = EnergyAware::new(
            Box::new(OraclePredictor),
            EnergyAwareParams {
                top_k_shards: 4,
                ..Default::default()
            },
        );
        assert_eq!(flat, p.decide_batch(&reqs, &shard_ctx));
    }
}
