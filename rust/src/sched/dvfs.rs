//! DVFS governor (§III-C): "for I/O-bound workloads, CPU frequency
//! scaling can further reduce power usage". Per host, the governor
//! looks at sustained CPU vs I/O utilization and picks a p-state:
//! hosts doing I/O with an idle-ish CPU clock down; hosts with real
//! CPU demand stay at full frequency. Hysteresis prevents flapping.
//!
//! Runs as a [`ControlLoop`] on the coordinator's scan cadence; it
//! needs no predictor, so it ignores the scoring handle. The scan is
//! a per-shard pass (per-host decisions shard trivially): each shard's
//! hosts are walked through the context's shard lens, and when the
//! context carries a worker pool the shard passes run on its workers
//! ([`ScheduleContext::for_each_shard`]) with per-shard action
//! buffers merged in ascending shard order — identical output to the
//! inline walk at any worker count. Without a shard layer the single
//! implicit shard reproduces the flat host sweep exactly.

use crate::sched::control::{ControlAction, ControlLoop, ScoringHandle};
use crate::sched::ScheduleContext;

#[derive(Debug, Clone, Copy)]
pub struct DvfsParams {
    /// Scale down only when sustained CPU utilization is below this.
    pub cpu_low: f64,
    /// ... and sustained I/O utilization is above this.
    pub io_high: f64,
    /// Scale back up when CPU exceeds this (hysteresis gap).
    pub cpu_restore: f64,
    /// Telemetry window (samples).
    pub window_samples: usize,
}

impl Default for DvfsParams {
    fn default() -> Self {
        DvfsParams {
            cpu_low: 0.30,
            io_high: 0.40,
            cpu_restore: 0.55,
            window_samples: 12, // 1 min of 5 s samples
        }
    }
}

#[derive(Debug, Default)]
pub struct DvfsGovernor {
    pub params: DvfsParams,
}

impl DvfsGovernor {
    pub fn new(params: DvfsParams) -> DvfsGovernor {
        DvfsGovernor { params }
    }
}

impl ControlLoop for DvfsGovernor {
    fn name(&self) -> &'static str {
        "dvfs"
    }

    fn box_clone(&self) -> Box<dyn ControlLoop> {
        Box::new(DvfsGovernor::new(self.params))
    }

    fn scan(
        &mut self,
        ctx: &ScheduleContext<'_>,
        _scoring: Option<ScoringHandle<'_>>,
    ) -> Vec<ControlAction> {
        let params = self.params;
        // Per-shard passes on the pool (inline when serial); flatten
        // in ascending shard order — the deterministic merge.
        ctx.for_each_shard(|shard| scan_shard(&params, ctx, shard))
            .into_iter()
            .flatten()
            .collect()
    }
}

/// One shard's governor pass. Reads only the frozen context — safe on
/// a worker thread; per-host decisions are independent, so the pass
/// produces the same actions whether run inline or pooled.
fn scan_shard(
    params: &DvfsParams,
    ctx: &ScheduleContext<'_>,
    shard: usize,
) -> Vec<ControlAction> {
    let cluster = ctx.cluster;
    let mut out = Vec::new();
    for host_id in ctx.shard(shard).hosts() {
        let host = &cluster.hosts[host_id.0];
        if !host.state.is_on() {
            continue;
        }
        let last = ctx.host_window(host.id, params.window_samples);
        if last.is_empty() {
            continue;
        }
        let n = last.len() as f64;
        let cpu = last.iter().map(|s| s.util.cpu).sum::<f64>() / n;
        let io = last.iter().map(|s| s.util.io()).sum::<f64>() / n;
        // Account for the fact that utilization is measured
        // against the *scaled* capacity: convert back to
        // full-clock terms.
        let cpu_full_clock = cpu * host.freq;
        // Profiled mean CPU of resident jobs: a Spark tenant
        // in a brief I/O phase must NOT get its host clocked
        // down — that is exactly the §V-C failure mode (CPU
        // jobs hurt by frequency scaling) the paper restricts
        // DVFS to I/O-bound workloads to avoid.
        let expected_cpu = cluster.expected_util(host.id).cpu;
        // Restore fast on *instantaneous* pressure: a
        // clocked-down host whose CPU phase returned contends
        // until restored.
        let inst_cpu = host.utilization().cpu;
        // A thermally-degraded host cannot clock past its cap: the
        // governor restores to the cap at most, and never emits an
        // action the cap would turn into a no-op.
        let restore = host.freq_cap();
        if host.freq < restore
            && (inst_cpu > 0.7
                || cpu_full_clock > params.cpu_restore * host.freq
                || expected_cpu > params.cpu_low)
        {
            out.push(ControlAction::SetFreq {
                host: host.id,
                freq: restore,
            });
        } else if host.freq >= 1.0
            && cpu_full_clock < params.cpu_low
            && expected_cpu < params.cpu_low
            && io > params.io_high
        {
            // I/O-dominated host: clock down. Choose the
            // p-state that keeps CPU below ~70 % at the lower
            // clock.
            let target = if cpu_full_clock.max(expected_cpu) < 0.15 {
                0.6
            } else {
                0.7
            };
            out.push(ControlAction::SetFreq {
                host: host.id,
                freq: target,
            });
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{Cluster, Demand, HostId};
    use crate::sim::Telemetry;
    use std::collections::BTreeMap;

    fn telemetry_for(cluster: &Cluster, n_hosts: usize) -> Telemetry {
        let mut t = Telemetry::new(n_hosts, 1, 0.0);
        for k in 1..=15 {
            t.sample(k as f64 * 5.0, cluster, &BTreeMap::new());
        }
        t
    }

    fn scan(gov: &mut DvfsGovernor, c: &Cluster, t: &Telemetry) -> Vec<ControlAction> {
        let ctx = ScheduleContext::new(100.0, c).with_telemetry(t);
        gov.scan(&ctx, None)
    }

    #[test]
    fn clocks_down_io_dominated_host() {
        let mut c = Cluster::homogeneous(1);
        c.host_mut(HostId(0)).demand = Demand {
            cpu: 3.0, // 0.09 util
            mem_gb: 8.0,
            disk_mbps: 600.0, // 0.6 io
            net_mbps: 20.0,
        };
        let t = telemetry_for(&c, 1);
        let mut gov = DvfsGovernor::new(DvfsParams::default());
        let actions = scan(&mut gov, &c, &t);
        assert_eq!(actions.len(), 1);
        assert!(matches!(
            actions[0],
            ControlAction::SetFreq { freq, .. } if freq < 1.0
        ));
        assert_eq!(gov.name(), "dvfs");
    }

    #[test]
    fn leaves_cpu_hosts_at_full_clock() {
        let mut c = Cluster::homogeneous(1);
        c.host_mut(HostId(0)).demand = Demand {
            cpu: 20.0,
            mem_gb: 8.0,
            disk_mbps: 350.0,
            net_mbps: 20.0,
        };
        let t = telemetry_for(&c, 1);
        let mut gov = DvfsGovernor::new(DvfsParams::default());
        assert!(scan(&mut gov, &c, &t).is_empty());
    }

    #[test]
    fn leaves_idle_hosts_alone() {
        // Idle host: no I/O either, so no reason to touch the clock
        // (power-down is consolidation's job, not DVFS's).
        let c = Cluster::homogeneous(1);
        let t = telemetry_for(&c, 1);
        let mut gov = DvfsGovernor::new(DvfsParams::default());
        assert!(scan(&mut gov, &c, &t).is_empty());
    }

    #[test]
    fn restores_clock_when_cpu_returns() {
        let mut c = Cluster::homogeneous(1);
        c.host_mut(HostId(0)).set_freq(0.6);
        c.host_mut(HostId(0)).demand = Demand {
            cpu: 16.0, // util against 19.2 scaled cores ≈ 0.83
            mem_gb: 8.0,
            disk_mbps: 300.0,
            net_mbps: 20.0,
        };
        let t = telemetry_for(&c, 1);
        let mut gov = DvfsGovernor::new(DvfsParams::default());
        let actions = scan(&mut gov, &c, &t);
        assert_eq!(
            actions,
            vec![ControlAction::SetFreq {
                host: HostId(0),
                freq: 1.0
            }]
        );
    }

    #[test]
    fn thermal_cap_bounds_the_restore_target() {
        use crate::cluster::{HostCondition, THERMAL_FREQ_CAP};
        // Clocked down to 0.6, then thermally degraded, then CPU
        // pressure returns: restore only up to the thermal cap.
        let mut c = Cluster::homogeneous(1);
        c.host_mut(HostId(0)).set_freq(0.6);
        c.host_mut(HostId(0)).condition = HostCondition::Thermal;
        c.host_mut(HostId(0)).demand = Demand {
            cpu: 16.0,
            mem_gb: 8.0,
            disk_mbps: 300.0,
            net_mbps: 20.0,
        };
        let t = telemetry_for(&c, 1);
        let mut gov = DvfsGovernor::new(DvfsParams::default());
        let actions = scan(&mut gov, &c, &t);
        assert_eq!(
            actions,
            vec![ControlAction::SetFreq {
                host: HostId(0),
                freq: THERMAL_FREQ_CAP
            }]
        );
        // Already at the cap: no restore churn scan after scan.
        c.host_mut(HostId(0)).set_freq(THERMAL_FREQ_CAP);
        let t = telemetry_for(&c, 1);
        assert!(scan(&mut gov, &c, &t).is_empty());
    }

    #[test]
    fn skips_powered_off_hosts() {
        let mut c = Cluster::homogeneous(1);
        c.host_mut(HostId(0)).power_off(0.0);
        c.advance_power_states(100.0);
        let t = telemetry_for(&c, 1);
        let mut gov = DvfsGovernor::new(DvfsParams::default());
        assert!(scan(&mut gov, &c, &t).is_empty());
    }

    #[test]
    fn no_telemetry_means_no_actions() {
        let mut c = Cluster::homogeneous(1);
        c.host_mut(HostId(0)).demand.disk_mbps = 600.0;
        let mut gov = DvfsGovernor::new(DvfsParams::default());
        let ctx = ScheduleContext::new(0.0, &c);
        assert!(gov.scan(&ctx, None).is_empty());
    }
}
