//! Round-robin placement — the baseline: OpenStack's default scheduler
//! "distributes VMs evenly across hosts without considering workload
//! characteristics" (§IV-E). It never powers hosts down and never
//! consolidates; it skips hosts that cannot fit the flavor.

use crate::sched::policy::{Decision, PlacementPolicy, PlacementRequest};
use crate::sched::ScheduleContext;

#[derive(Debug, Default)]
pub struct RoundRobin {
    next: usize,
}

impl PlacementPolicy for RoundRobin {
    fn name(&self) -> &'static str {
        "round_robin"
    }

    fn decide(&mut self, req: &PlacementRequest, ctx: &ScheduleContext<'_>) -> Decision {
        let cluster = ctx.cluster;
        let n = cluster.n_hosts();
        for k in 0..n {
            let idx = (self.next + k) % n;
            let host = &cluster.hosts[idx];
            if host.fits(&req.flavor, cluster.reserved(host.id)) {
                self.next = (idx + 1) % n;
                return Decision::Place(host.id);
            }
        }
        Decision::Defer
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::flavor::{LARGE, MEDIUM};
    use crate::cluster::{Cluster, HostId};
    use crate::profile::ResourceVector;
    use crate::workload::JobId;

    fn req(flavor: crate::cluster::Flavor) -> PlacementRequest {
        PlacementRequest {
            job: JobId(0),
            flavor,
            vector: ResourceVector::default(),
            remaining_solo: 100.0,
            avoid_rack: None,
        }
    }

    fn decide(p: &mut RoundRobin, req: &PlacementRequest, c: &Cluster) -> Decision {
        p.decide(req, &ScheduleContext::new(0.0, c))
    }

    #[test]
    fn cycles_across_hosts() {
        let mut c = Cluster::homogeneous(3);
        let mut rr = RoundRobin::default();
        let seq: Vec<Decision> = (0..6).map(|_| {
            let d = decide(&mut rr, &req(MEDIUM), &c);
            if let Decision::Place(h) = d {
                let vm = c.create_vm(MEDIUM, JobId(0), 0.0);
                c.place_vm(vm, h).unwrap();
            }
            d
        }).collect();
        assert_eq!(
            seq,
            vec![
                Decision::Place(HostId(0)),
                Decision::Place(HostId(1)),
                Decision::Place(HostId(2)),
                Decision::Place(HostId(0)),
                Decision::Place(HostId(1)),
                Decision::Place(HostId(2)),
            ]
        );
    }

    #[test]
    fn skips_full_hosts() {
        let mut c = Cluster::homogeneous(2);
        // Fill host 0 with memory (2×LARGE = 64 GB).
        for _ in 0..2 {
            let vm = c.create_vm(LARGE, JobId(0), 0.0);
            c.place_vm(vm, HostId(0)).unwrap();
        }
        let mut rr = RoundRobin::default();
        assert_eq!(decide(&mut rr, &req(LARGE), &c), Decision::Place(HostId(1)));
    }

    #[test]
    fn defers_when_cluster_full() {
        let mut c = Cluster::homogeneous(1);
        for _ in 0..2 {
            let vm = c.create_vm(LARGE, JobId(0), 0.0);
            c.place_vm(vm, HostId(0)).unwrap();
        }
        let mut rr = RoundRobin::default();
        assert_eq!(decide(&mut rr, &req(LARGE), &c), Decision::Defer);
        assert!(!rr.wants_consolidation());
    }
}
