//! The scheduling context: one read-only view of everything a
//! placement policy or control loop may consult when deciding —
//! cluster state, the telemetry window, execution history, per-VM
//! runtime context, and the simulation clock.
//!
//! Policies used to receive a bare `&Cluster`; control loops each
//! took their own ad-hoc argument lists and recomputed sustained
//! utilization independently. `ScheduleContext` replaces both: the
//! coordinator assembles it once per decision point and every
//! consumer reads through the same lens.

use crate::cluster::{Cluster, DigestSnapshot, HostId, ShardDigest, ShardedCluster, VmId};
use crate::profile::HistoryStore;
use crate::runtime::{WorkerPool, WorkerSlot};
use crate::sched::consolidation::VmContext;
use crate::sim::telemetry::HostSample;
use crate::sim::Telemetry;
use std::collections::BTreeMap;

/// Fleet size below which [`ScheduleContext::for_each_shard`] runs
/// inline even when a parallel pool is attached. A per-shard pass over
/// a small fleet is a few hundred nanoseconds of host walking; the
/// pool's per-job channel round-trip is comfortably larger, so
/// dispatching it loses on every shard. 128 hosts ≈ the crossover
/// region observed for the scan-heavy benches; results are identical
/// either way (only latency differs), so the exact value is a
/// performance knob, not a correctness one.
pub const INLINE_FLEET_HOSTS: usize = 128;

/// Read-only decision context. Optional layers (telemetry, history,
/// per-VM context, shards) degrade gracefully: helpers fall back to
/// instantaneous cluster state when a layer is absent, so unit tests
/// can build a context from a cluster alone. Without a shard layer
/// the context behaves as a single shard covering every host.
pub struct ScheduleContext<'a> {
    /// Simulation clock (seconds).
    pub now: f64,
    /// Cluster state: hosts, VMs, reservations.
    pub cluster: &'a Cluster,
    /// Telemetry rings (sustained-utilization windows).
    pub telemetry: Option<&'a Telemetry>,
    /// Execution history (Eq. 1 profiles of recurring kinds).
    pub history: Option<&'a HistoryStore>,
    /// Per-VM runtime context (profiles, remaining work, SLA slack)
    /// for control loops that plan migrations.
    pub vm_ctx: Option<&'a BTreeMap<VmId, VmContext>>,
    /// Sharded cluster layer: shard membership and per-shard digests
    /// over the SAME cluster as `cluster`. Policies fan `decide_batch`
    /// out across shards and control loops scan shard by shard when
    /// this is present.
    pub shards: Option<&'a ShardedCluster>,
    /// Persistent shard worker pool: when present (and wider than one
    /// worker), per-shard work — placement sweeps, control-loop scan
    /// passes — is dispatched to the pool's long-lived workers
    /// (`WorkerPool::worker_for`, stable across fan-outs)
    /// instead of running inline. Absent (or at width 1) every
    /// consumer takes its serial path, which is the behavioral oracle
    /// the parallel paths are property-tested against.
    pub pool: Option<&'a WorkerPool>,
}

impl<'a> ScheduleContext<'a> {
    pub fn new(now: f64, cluster: &'a Cluster) -> ScheduleContext<'a> {
        ScheduleContext {
            now,
            cluster,
            telemetry: None,
            history: None,
            vm_ctx: None,
            shards: None,
            pool: None,
        }
    }

    pub fn with_telemetry(mut self, telemetry: &'a Telemetry) -> ScheduleContext<'a> {
        self.telemetry = Some(telemetry);
        self
    }

    pub fn with_history(mut self, history: &'a HistoryStore) -> ScheduleContext<'a> {
        self.history = Some(history);
        self
    }

    pub fn with_vm_ctx(mut self, vm_ctx: &'a BTreeMap<VmId, VmContext>) -> ScheduleContext<'a> {
        self.vm_ctx = Some(vm_ctx);
        self
    }

    /// Attach the shard layer. `shards` must wrap the very cluster
    /// this context reads — the coordinator passes the same
    /// [`ShardedCluster`] for both (the `cluster` field is its
    /// deref).
    pub fn with_shards(mut self, shards: &'a ShardedCluster) -> ScheduleContext<'a> {
        debug_assert!(
            std::ptr::eq(shards.cluster(), self.cluster),
            "with_shards must wrap the context's own cluster"
        );
        self.shards = Some(shards);
        self
    }

    /// Attach a persistent shard worker pool. Per-shard work is then
    /// dispatched to the pool's affinity workers; results merge
    /// deterministically (see [`WorkerPool`]'s determinism contract),
    /// so attaching a pool never changes decisions — only latency.
    pub fn with_pool(mut self, pool: &'a WorkerPool) -> ScheduleContext<'a> {
        self.pool = Some(pool);
        self
    }

    /// Run a read-only computation for every shard, dispatched to the
    /// worker pool when one is attached (and wider than one worker),
    /// inline otherwise. Results come back in ascending shard order
    /// either way — the merge rule control loops rely on — and a
    /// panicking worker poisons the whole pass with a clear error
    /// instead of deadlocking (see [`crate::runtime::PoolError`]).
    ///
    /// Small fleets stay inline even with a pool attached: below
    /// [`INLINE_FLEET_HOSTS`] hosts a shard pass is a short host walk,
    /// and the channel round-trip per shard costs more than the walk
    /// itself — the non-scoring analogue of the placement path's
    /// `inline_burst_rows` guard. Inline and pooled paths compute the
    /// same thing in the same order, so the guard never changes
    /// results, only latency.
    pub fn for_each_shard<T, F>(&self, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(usize) -> T + Sync,
    {
        let n = self.shard_count();
        match self.pool {
            Some(pool)
                if pool.parallel() && n > 1 && self.cluster.n_hosts() > INLINE_FLEET_HOSTS =>
            {
                let f = &f;
                let jobs: Vec<_> = (0..n)
                    .map(|s| (s, move |_: &mut WorkerSlot| f(s)))
                    .collect();
                pool.dispatch(jobs)
                    .unwrap_or_else(|e| panic!("per-shard fan-out poisoned: {e}"))
            }
            _ => (0..n).map(f).collect(),
        }
    }

    /// Number of shards this context is split into (1 when no shard
    /// layer is attached — the whole cluster is one shard).
    pub fn shard_count(&self) -> usize {
        self.shards.map(|s| s.shard_count()).unwrap_or(1)
    }

    /// Per-shard lens with the same read API as the whole-cluster
    /// view, restricted to one shard's hosts.
    pub fn shard(&self, id: usize) -> ShardContext<'_, 'a> {
        ShardContext { ctx: self, id }
    }

    /// Member hosts of one shard, ascending by id. Without a shard
    /// layer, shard 0 covers every host.
    pub fn shard_hosts(&self, id: usize) -> ShardHosts<'a> {
        match self.shards {
            Some(sc) => ShardHosts::Members(sc.members(id).iter()),
            None => {
                debug_assert_eq!(id, 0, "unsharded context has exactly one shard");
                ShardHosts::All(0..self.cluster.n_hosts())
            }
        }
    }

    /// One shard's digest. With the shard layer attached this is an
    /// O(1) copy of the incrementally-maintained digest; WITHOUT it
    /// the digest is recomputed over every host and VM on each call —
    /// per-scan callers on unsharded contexts should read it once and
    /// reuse the value, not treat it as a cheap field access.
    pub fn shard_digest(&self, id: usize) -> ShardDigest {
        match self.shards {
            Some(sc) => *sc.digest(id),
            None => ShardDigest::compute(
                self.cluster,
                (0..self.cluster.n_hosts()).map(HostId),
                |_| true,
            ),
        }
    }

    /// One shard's digest stamped with its commit epoch — what a
    /// commit-protocol coordinator decides against. With the shard
    /// layer attached this is an O(1) copy; without it the digest is
    /// recomputed over every host and VM and stamped with epoch 0
    /// (an unsharded context has no commit history to be stale
    /// against).
    pub fn digest_snapshot(&self, id: usize) -> DigestSnapshot {
        match self.shards {
            Some(sc) => sc.digest_snapshot(id),
            None => DigestSnapshot {
                shard: id,
                epoch: 0,
                digest: self.shard_digest(id),
            },
        }
    }

    /// Epoch-stamped snapshots of every shard, ascending by shard id
    /// — the full snapshot a coordinator refreshes at burst start.
    pub fn digest_snapshots(&self) -> Vec<DigestSnapshot> {
        (0..self.shard_count())
            .map(|s| self.digest_snapshot(s))
            .collect()
    }

    /// Runtime context of one VM, if the coordinator provided it.
    pub fn vm_context(&self, vm: VmId) -> Option<&'a VmContext> {
        self.vm_ctx.and_then(|m| m.get(&vm))
    }

    /// The most recent `n` telemetry samples for a host (oldest →
    /// newest); empty when no telemetry layer is attached.
    pub fn host_window(&self, host: HostId, n: usize) -> Vec<HostSample> {
        self.telemetry
            .map(|t| t.hosts[host.0].last_n(n))
            .unwrap_or_default()
    }

    /// Sustained CPU utilization of a host over the last `n` samples,
    /// falling back to the instantaneous reading when the window is
    /// empty (campaign start, or no telemetry attached).
    pub fn sustained_cpu(&self, host: HostId, n: usize) -> f64 {
        let w = self.host_window(host, n);
        if w.is_empty() {
            self.cluster.host(host).utilization().cpu
        } else {
            w.iter().map(|s| s.util.cpu).sum::<f64>() / w.len() as f64
        }
    }
}

/// Iterator over one shard's member host ids — a member-list walk
/// when the shard layer is attached, the plain host range otherwise.
/// Either way hosts come out ascending by id, which is what keeps the
/// single-shard paths bit-identical to the unsharded sweeps.
pub enum ShardHosts<'a> {
    All(std::ops::Range<usize>),
    Members(std::slice::Iter<'a, HostId>),
}

impl Iterator for ShardHosts<'_> {
    type Item = HostId;

    fn next(&mut self) -> Option<HostId> {
        match self {
            ShardHosts::All(r) => r.next().map(HostId),
            ShardHosts::Members(it) => it.next().copied(),
        }
    }
}

/// Per-shard lens over a [`ScheduleContext`]: the same read API the
/// whole-cluster view offers, restricted to one shard. Control loops
/// iterate `ctx.shard(s).hosts()` instead of the raw host vector so
/// their scans shard cleanly.
#[derive(Clone, Copy)]
pub struct ShardContext<'c, 'a> {
    ctx: &'c ScheduleContext<'a>,
    /// Shard index.
    pub id: usize,
}

impl<'c, 'a> ShardContext<'c, 'a> {
    pub fn hosts(&self) -> ShardHosts<'a> {
        self.ctx.shard_hosts(self.id)
    }

    pub fn digest(&self) -> ShardDigest {
        self.ctx.shard_digest(self.id)
    }

    pub fn sustained_cpu(&self, host: HostId, n: usize) -> f64 {
        self.ctx.sustained_cpu(host, n)
    }

    pub fn host_window(&self, host: HostId, n: usize) -> Vec<HostSample> {
        self.ctx.host_window(host, n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Demand;

    #[test]
    fn bare_context_falls_back_to_instantaneous() {
        let mut c = Cluster::homogeneous(2);
        c.host_mut(HostId(0)).demand = Demand {
            cpu: 16.0,
            mem_gb: 8.0,
            disk_mbps: 0.0,
            net_mbps: 0.0,
        };
        let ctx = ScheduleContext::new(10.0, &c);
        assert!(ctx.host_window(HostId(0), 12).is_empty());
        assert!((ctx.sustained_cpu(HostId(0), 12) - 0.5).abs() < 1e-9);
        assert_eq!(ctx.sustained_cpu(HostId(1), 12), 0.0);
        assert!(ctx.vm_context(VmId(0)).is_none());
    }

    #[test]
    fn unsharded_context_is_one_shard_covering_all_hosts() {
        let c = Cluster::homogeneous(3);
        let ctx = ScheduleContext::new(0.0, &c);
        assert_eq!(ctx.shard_count(), 1);
        let hosts: Vec<HostId> = ctx.shard(0).hosts().collect();
        assert_eq!(hosts, vec![HostId(0), HostId(1), HostId(2)]);
        let digest = ctx.shard(0).digest();
        assert_eq!(digest.hosts, 3);
        assert_eq!(digest.on, 3);
    }

    #[test]
    fn sharded_context_partitions_hosts_and_reads_digests() {
        use crate::cluster::ShardedCluster;
        let sc = ShardedCluster::new(Cluster::homogeneous(8), 2);
        let ctx = ScheduleContext::new(0.0, &sc).with_shards(&sc);
        assert_eq!(ctx.shard_count(), 2);
        let mut all: Vec<HostId> = (0..2).flat_map(|s| ctx.shard(s).hosts()).collect();
        all.sort();
        assert_eq!(all, (0..8).map(HostId).collect::<Vec<_>>());
        let total_hosts: usize = (0..2).map(|s| ctx.shard(s).digest().hosts).sum();
        assert_eq!(total_hosts, 8);
        // Digest reads agree with a fresh recomputation.
        for s in 0..2 {
            let d = ctx.shard(s).digest();
            let fresh = crate::cluster::ShardDigest::compute(
                &sc,
                ctx.shard(s).hosts(),
                |h| sc.shard_of(h) == s,
            );
            assert_eq!(d.on, fresh.on);
            assert_eq!(d.hosts, fresh.hosts);
        }
    }

    #[test]
    fn digest_snapshots_carry_shard_epochs() {
        use crate::cluster::flavor::MEDIUM;
        use crate::cluster::ShardedCluster;
        use crate::workload::JobId;
        let mut sc = ShardedCluster::new(Cluster::homogeneous(8), 2);
        let vm = sc.create_vm(MEDIUM, JobId(1), 0.0);
        sc.place_vm(vm, HostId(0)).unwrap();
        let shard = sc.shard_of(HostId(0));
        let ctx = ScheduleContext::new(0.0, &sc).with_shards(&sc);
        let snaps = ctx.digest_snapshots();
        assert_eq!(snaps.len(), 2);
        assert_eq!(snaps[shard].epoch, 1);
        assert_eq!(snaps[1 - shard].epoch, 0);
        assert_eq!(snaps[shard].shard, shard);
        // Unsharded contexts stamp epoch 0 (no commit history).
        let flat = Cluster::homogeneous(3);
        let fctx = ScheduleContext::new(0.0, &flat);
        let snap = fctx.digest_snapshot(0);
        assert_eq!(snap.epoch, 0);
        assert_eq!(snap.digest.hosts, 3);
    }

    #[test]
    fn for_each_shard_orders_results_with_and_without_pool() {
        use crate::cluster::ShardedCluster;
        use crate::runtime::WorkerPool;
        let sc = ShardedCluster::new(Cluster::homogeneous(8), 4);
        let ctx = ScheduleContext::new(0.0, &sc).with_shards(&sc);
        let serial = ctx.for_each_shard(|s| (s, ctx.shard(s).digest().hosts));
        let pool = WorkerPool::new(3);
        let pctx = ScheduleContext::new(0.0, &sc).with_shards(&sc).with_pool(&pool);
        let pooled = pctx.for_each_shard(|s| (s, pctx.shard(s).digest().hosts));
        assert_eq!(serial, pooled);
        let order: Vec<usize> = serial.iter().map(|x| x.0).collect();
        assert_eq!(order, vec![0, 1, 2, 3], "ascending shard order");
    }

    #[test]
    fn small_fleets_never_pay_a_channel_hop() {
        use crate::cluster::ShardedCluster;
        use crate::runtime::WorkerPool;
        // Worker threads are named "pallas-worker-N"; a closure that
        // runs on one would see that name. On a fleet at or under the
        // inline threshold it must run on the calling thread even
        // with a parallel pool attached.
        let sc = ShardedCluster::new(Cluster::homogeneous(INLINE_FLEET_HOSTS), 4);
        let pool = WorkerPool::new(3);
        let ctx = ScheduleContext::new(0.0, &sc).with_shards(&sc).with_pool(&pool);
        let caller = std::thread::current().id();
        let ran_on = ctx.for_each_shard(|s| (s, std::thread::current().id()));
        assert_eq!(ran_on.len(), 4);
        for (s, tid) in ran_on {
            assert_eq!(tid, caller, "shard {s} pass left the calling thread");
        }
        // One host past the threshold, the same context dispatches.
        let big = ShardedCluster::new(Cluster::homogeneous(INLINE_FLEET_HOSTS + 1), 4);
        let bctx = ScheduleContext::new(0.0, &big).with_shards(&big).with_pool(&pool);
        let dispatched = bctx.for_each_shard(|_| {
            std::thread::current()
                .name()
                .map(|n| n.starts_with("pallas-worker"))
                .unwrap_or(false)
        });
        assert!(
            dispatched.iter().all(|&on_worker| on_worker),
            "large fleet should fan out to the pool"
        );
    }

    #[test]
    fn telemetry_window_feeds_sustained_cpu() {
        let mut c = Cluster::homogeneous(1);
        c.host_mut(HostId(0)).demand = Demand {
            cpu: 8.0,
            mem_gb: 4.0,
            disk_mbps: 0.0,
            net_mbps: 0.0,
        };
        let mut t = Telemetry::new(1, 1, 0.0);
        let demands = BTreeMap::new();
        for k in 1..=6 {
            t.sample(k as f64 * 5.0, &c, &demands);
        }
        let ctx = ScheduleContext::new(30.0, &c).with_telemetry(&t);
        assert_eq!(ctx.host_window(HostId(0), 4).len(), 4);
        assert!((ctx.sustained_cpu(HostId(0), 6) - 0.25).abs() < 1e-9);
    }
}
