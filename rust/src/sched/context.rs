//! The scheduling context: one read-only view of everything a
//! placement policy or control loop may consult when deciding —
//! cluster state, the telemetry window, execution history, per-VM
//! runtime context, and the simulation clock.
//!
//! Policies used to receive a bare `&Cluster`; control loops each
//! took their own ad-hoc argument lists and recomputed sustained
//! utilization independently. `ScheduleContext` replaces both: the
//! coordinator assembles it once per decision point and every
//! consumer reads through the same lens.

use crate::cluster::{Cluster, HostId, VmId};
use crate::profile::HistoryStore;
use crate::sched::consolidation::VmContext;
use crate::sim::telemetry::HostSample;
use crate::sim::Telemetry;
use std::collections::BTreeMap;

/// Read-only decision context. Optional layers (telemetry, history,
/// per-VM context) degrade gracefully: helpers fall back to
/// instantaneous cluster state when a layer is absent, so unit tests
/// can build a context from a cluster alone.
pub struct ScheduleContext<'a> {
    /// Simulation clock (seconds).
    pub now: f64,
    /// Cluster state: hosts, VMs, reservations.
    pub cluster: &'a Cluster,
    /// Telemetry rings (sustained-utilization windows).
    pub telemetry: Option<&'a Telemetry>,
    /// Execution history (Eq. 1 profiles of recurring kinds).
    pub history: Option<&'a HistoryStore>,
    /// Per-VM runtime context (profiles, remaining work, SLA slack)
    /// for control loops that plan migrations.
    pub vm_ctx: Option<&'a BTreeMap<VmId, VmContext>>,
}

impl<'a> ScheduleContext<'a> {
    pub fn new(now: f64, cluster: &'a Cluster) -> ScheduleContext<'a> {
        ScheduleContext {
            now,
            cluster,
            telemetry: None,
            history: None,
            vm_ctx: None,
        }
    }

    pub fn with_telemetry(mut self, telemetry: &'a Telemetry) -> ScheduleContext<'a> {
        self.telemetry = Some(telemetry);
        self
    }

    pub fn with_history(mut self, history: &'a HistoryStore) -> ScheduleContext<'a> {
        self.history = Some(history);
        self
    }

    pub fn with_vm_ctx(mut self, vm_ctx: &'a BTreeMap<VmId, VmContext>) -> ScheduleContext<'a> {
        self.vm_ctx = Some(vm_ctx);
        self
    }

    /// Runtime context of one VM, if the coordinator provided it.
    pub fn vm_context(&self, vm: VmId) -> Option<&'a VmContext> {
        self.vm_ctx.and_then(|m| m.get(&vm))
    }

    /// The most recent `n` telemetry samples for a host (oldest →
    /// newest); empty when no telemetry layer is attached.
    pub fn host_window(&self, host: HostId, n: usize) -> Vec<HostSample> {
        self.telemetry
            .map(|t| t.hosts[host.0].last_n(n))
            .unwrap_or_default()
    }

    /// Sustained CPU utilization of a host over the last `n` samples,
    /// falling back to the instantaneous reading when the window is
    /// empty (campaign start, or no telemetry attached).
    pub fn sustained_cpu(&self, host: HostId, n: usize) -> f64 {
        let w = self.host_window(host, n);
        if w.is_empty() {
            self.cluster.host(host).utilization().cpu
        } else {
            w.iter().map(|s| s.util.cpu).sum::<f64>() / w.len() as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Demand;

    #[test]
    fn bare_context_falls_back_to_instantaneous() {
        let mut c = Cluster::homogeneous(2);
        c.host_mut(HostId(0)).demand = Demand {
            cpu: 16.0,
            mem_gb: 8.0,
            disk_mbps: 0.0,
            net_mbps: 0.0,
        };
        let ctx = ScheduleContext::new(10.0, &c);
        assert!(ctx.host_window(HostId(0), 12).is_empty());
        assert!((ctx.sustained_cpu(HostId(0), 12) - 0.5).abs() < 1e-9);
        assert_eq!(ctx.sustained_cpu(HostId(1), 12), 0.0);
        assert!(ctx.vm_context(VmId(0)).is_none());
    }

    #[test]
    fn telemetry_window_feeds_sustained_cpu() {
        let mut c = Cluster::homogeneous(1);
        c.host_mut(HostId(0)).demand = Demand {
            cpu: 8.0,
            mem_gb: 4.0,
            disk_mbps: 0.0,
            net_mbps: 0.0,
        };
        let mut t = Telemetry::new(1, 1, 0.0);
        let demands = BTreeMap::new();
        for k in 1..=6 {
            t.sample(k as f64 * 5.0, &c, &demands);
        }
        let ctx = ScheduleContext::new(30.0, &c).with_telemetry(&t);
        assert_eq!(ctx.host_window(HostId(0), 4).len(), 4);
        assert!((ctx.sustained_cpu(HostId(0), 6) - 0.25).abs() < 1e-9);
    }
}
