//! Adaptive consolidation (§III-C, Eqs. 8–9):
//!
//! ```text
//! U_h^cpu < δ_low  ⇒ migrate workloads away (then power down)
//! U_h^cpu > δ_high ⇒ restrict placements / relieve pressure
//! ```
//!
//! The scan runs periodically as a [`ControlLoop`], uses *sustained*
//! utilization from the context's telemetry window (not instantaneous
//! spikes), schedules migrations only in low-activity windows
//! (§III-C's "migrations are scheduled during low-activity
//! intervals"), and evacuates at most one donor host per scan to
//! avoid migration storms. Migration targets are scored through the
//! placement policy's predictor, borrowed via the scan's
//! [`ScoringHandle`].

use crate::cluster::{HostId, VmId, VmState};
use crate::predict::EnergyPredictor;
use crate::profile::{build_features, ResourceVector};
use crate::sched::control::{ControlAction, ControlLoop, ScoringHandle};
use crate::sched::ScheduleContext;
use std::collections::BTreeMap;

/// Consolidation tunables (`abl1` sweeps δ_low × δ_high).
#[derive(Debug, Clone, Copy)]
pub struct ConsolidationParams {
    /// Eq. 8 lower threshold on sustained host CPU utilization.
    pub delta_low: f64,
    /// Eq. 9 upper threshold.
    pub delta_high: f64,
    /// Telemetry samples the sustained-utilization window averages.
    pub window_samples: usize,
    /// Cluster-mean CPU utilization above which migrations wait
    /// (low-activity-window scheduling).
    pub migration_util_ceiling: f64,
    /// Never power below this many hosts.
    pub min_hosts_on: usize,
    /// Max predicted slowdown accepted on a migration target.
    pub max_slowdown: f64,
    /// Keep this many *empty* hosts on as boot-latency headroom —
    /// powering off the last spare forces a 90 s boot on the next
    /// burst, which costs more energy (and SLA slack) than it saves.
    pub spare_hosts: usize,
    /// A host must be continuously empty this long before power-off
    /// (hysteresis against placement/consolidation thrash).
    pub empty_grace_s: f64,
}

impl Default for ConsolidationParams {
    fn default() -> Self {
        ConsolidationParams {
            delta_low: 0.30,
            delta_high: 0.85,
            window_samples: 24, // 2 min of 5 s samples
            migration_util_ceiling: 0.75,
            min_hosts_on: 1,
            max_slowdown: 0.08,
            spare_hosts: 0,
            empty_grace_s: 45.0,
        }
    }
}

/// Network-utilization share of one live-migration copy stream
/// (40 MB/s throttle on a ~117 MB/s NIC).
pub const MIGRATION_NET_UTIL: f64 = 40.0 / 117.0;

/// Per-VM context the scan needs from the coordinator.
#[derive(Debug, Clone)]
pub struct VmContext {
    pub vector: ResourceVector,
    pub remaining_solo: f64,
    /// Current SLA headroom: max extra slowdown the job tolerates.
    pub slack_left: f64,
}

pub struct Consolidator {
    pub params: ConsolidationParams,
    /// Hosts currently under Eq. 9 restriction (informational; the
    /// energy-aware policy applies δ_high itself at placement time).
    pub restricted: Vec<HostId>,
    /// When each host was first observed empty (hysteresis state).
    empty_since: BTreeMap<HostId, f64>,
}

impl Consolidator {
    pub fn new(params: ConsolidationParams) -> Consolidator {
        Consolidator {
            params,
            restricted: Vec::new(),
            empty_since: BTreeMap::new(),
        }
    }

    /// One scan pass. Pure planning: no cluster mutation here.
    fn plan(
        &mut self,
        ctx: &ScheduleContext<'_>,
        predictor: &mut dyn EnergyPredictor,
    ) -> Vec<ControlAction> {
        let now = ctx.now;
        let cluster = ctx.cluster;
        let mut actions = Vec::new();
        let n = cluster.n_hosts();
        // Sustained per-host CPU utilization (telemetry window, with
        // instantaneous fallback — shared helper on the context).
        let sustained: Vec<f64> = (0..n)
            .map(|i| ctx.sustained_cpu(HostId(i), self.params.window_samples))
            .collect();

        // Eq. 9 bookkeeping.
        self.restricted = (0..n)
            .filter(|&i| cluster.hosts[i].state.is_on() && sustained[i] > self.params.delta_high)
            .map(HostId)
            .collect();

        // Power-off planning with hysteresis and spare-host headroom:
        // a host powers off only after `empty_grace_s` of continuous
        // emptiness, and only while more than `spare_hosts` empty
        // hosts (plus the absolute floor) remain on.
        for host in &cluster.hosts {
            if host.state.is_on() && host.vms.is_empty() {
                self.empty_since.entry(host.id).or_insert(now);
            } else {
                self.empty_since.remove(&host.id);
            }
        }
        let mut hosts_on = cluster.hosts_on();
        let mut empty_on = self
            .empty_since
            .iter()
            .filter(|(h, _)| cluster.host(**h).state.is_on())
            .count();
        let mut powering_off: Vec<HostId> = Vec::new();
        // Oldest-empty first (most likely genuinely idle).
        let mut candidates: Vec<(f64, HostId)> = self
            .empty_since
            .iter()
            .map(|(&h, &t)| (t, h))
            .collect();
        candidates.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        for (since, h) in candidates {
            if now - since < self.params.empty_grace_s {
                continue;
            }
            if hosts_on <= self.params.min_hosts_on
                || empty_on <= self.params.spare_hosts
            {
                break;
            }
            actions.push(ControlAction::PowerOff(h));
            powering_off.push(h);
            hosts_on -= 1;
            empty_on -= 1;
        }

        // Low-activity gate for migrations.
        let on_utils: Vec<f64> = (0..n)
            .filter(|&i| cluster.hosts[i].state.is_on())
            .map(|i| sustained[i])
            .collect();
        let cluster_mean = if on_utils.is_empty() {
            0.0
        } else {
            on_utils.iter().sum::<f64>() / on_utils.len() as f64
        };
        if cluster_mean > self.params.migration_util_ceiling {
            return actions; // busy: postpone consolidation migrations
        }

        // Eq. 8: pick ONE donor — the least-utilized on-host below
        // δ_low that still runs VMs and is migration-quiet.
        let donor = (0..n)
            .filter(|&i| {
                let h = &cluster.hosts[i];
                h.state.is_on()
                    && !h.vms.is_empty()
                    && sustained[i] < self.params.delta_low
                    && h.migration_net == 0.0
                    && h.vms.iter().all(|vm| {
                        matches!(cluster.vms[vm].state, VmState::Running)
                    })
            })
            .min_by(|&a, &b| sustained[a].partial_cmp(&sustained[b]).unwrap())
            .map(HostId);

        let Some(donor) = donor else {
            return actions;
        };

        // Plan a target for every VM on the donor; abort wholesale if
        // any VM has no SLA-safe target (partial evacuation strands
        // the host at even lower utilization).
        let mut planned: Vec<(VmId, HostId)> = Vec::new();
        let mut extra_mem: BTreeMap<HostId, f64> = BTreeMap::new();
        let mut extra_cpu: BTreeMap<HostId, f64> = BTreeMap::new();
        for &vm_id in &cluster.hosts[donor.0].vms {
            let vm = &cluster.vms[&vm_id];
            let vctx = match ctx.vm_context(vm_id) {
                Some(c) => c,
                None => return actions, // missing context: be conservative
            };
            // Pre-copy duration at the 40 MB/s throttle: migrating a
            // VM whose remaining work is shorter than the copy itself
            // cannot free the donor early enough to pay for the copy's
            // network pressure — let it drain instead.
            let copy_secs = vm.flavor.mem_gb * 1024.0 * 1.3 / 40.0;
            if vctx.remaining_solo < copy_secs {
                return actions;
            }
            let mut cands: Vec<HostId> = Vec::new();
            let mut feats = Vec::new();
            for host in &cluster.hosts {
                if host.id == donor || !host.state.is_on() {
                    continue;
                }
                // Never migrate onto a host we just planned to power
                // off, and never onto an *empty* host — moving load to
                // an empty machine swaps hosts instead of shrinking
                // the active set.
                if powering_off.contains(&host.id) || host.vms.is_empty() {
                    continue;
                }
                // δ_high and planned-load-aware fit check.
                if sustained[host.id.0] > self.params.delta_high {
                    continue;
                }
                let mut reserved = *cluster.reserved(host.id);
                reserved.mem_gb += extra_mem.get(&host.id).copied().unwrap_or(0.0);
                reserved.cpu += extra_cpu.get(&host.id).copied().unwrap_or(0.0);
                if !host.fits(&vm.flavor, &reserved) {
                    continue;
                }
                // Same effective-load headroom the placement path uses.
                let inst = host.utilization();
                let prof = cluster.expected_util(host.id);
                let u = crate::cluster::Utilization {
                    cpu: inst.cpu.max(prof.cpu),
                    mem: inst.mem.max(prof.mem),
                    disk: inst.disk.max(prof.disk),
                    net: inst.net.max(prof.net),
                };
                let (pc, pm, pd, pn) =
                    crate::predict::oracle::post_utilization(&vctx.vector, &u);
                if (vctx.vector.cpu > 0.1 && pc > 0.90)
                    || (vctx.vector.mem > 0.1 && pm > 0.90)
                    || (vctx.vector.disk > 0.1 && pd > 0.90)
                    || (vctx.vector.net > 0.1 && pn > 0.90)
                {
                    continue;
                }
                let _ = pc;
                // The migration copy itself occupies ~0.34 of a 1 GbE
                // NIC on the receiving end; co-located network-heavy
                // phases must still fit beside it.
                if pn + MIGRATION_NET_UTIL > 0.95 {
                    continue;
                }
                cands.push(host.id);
                feats.push(build_features(&vctx.vector, vctx.remaining_solo, host));
            }
            if cands.is_empty() {
                return actions; // cannot fully evacuate: give up this scan
            }
            let preds = predictor.predict(&feats);
            let mut best: Option<(HostId, f64)> = None;
            for (i, p) in preds.iter().enumerate() {
                if p.slowdown > self.params.max_slowdown.min(vctx.slack_left) {
                    continue;
                }
                // Same amortized-idle-floor objective as placement.
                let host = cluster.host(cands[i]);
                let idle_share =
                    host.spec.power.p_idle / (host.vms.len() as f64 + 1.0);
                let cost = (p.power_w + idle_share) * (1.0 + p.slowdown);
                if best.map(|(_, c)| cost < c).unwrap_or(true) {
                    best = Some((cands[i], cost));
                }
            }
            match best {
                Some((target, _)) => {
                    *extra_mem.entry(target).or_default() += vm.flavor.mem_gb;
                    *extra_cpu.entry(target).or_default() += vm.flavor.vcpus;
                    planned.push((vm_id, target));
                }
                None => return actions, // SLA-unsafe: skip consolidating this host
            }
        }
        for (vm, to) in planned {
            actions.push(ControlAction::Migrate { vm, to });
        }
        actions
    }
}

impl ControlLoop for Consolidator {
    fn name(&self) -> &'static str {
        "consolidation"
    }

    fn scan(
        &mut self,
        ctx: &ScheduleContext<'_>,
        scoring: Option<ScoringHandle<'_>>,
    ) -> Vec<ControlAction> {
        // Migration targets are ranked by predicted energy/slowdown;
        // without a predictor there is nothing safe to plan.
        match scoring {
            Some(predictor) => self.plan(ctx, predictor),
            None => Vec::new(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::flavor::MEDIUM;
    use crate::cluster::{Cluster, Demand};
    use crate::predict::OraclePredictor;
    use crate::sim::Telemetry;
    use crate::workload::JobId;

    fn ctx() -> VmContext {
        VmContext {
            vector: ResourceVector {
                cpu: 0.15,
                mem: 0.4,
                disk: 0.5,
                net: 0.3,
                cpu_peak: 0.2,
                io_peak: 0.6,
                burstiness: 0.1,
            },
            remaining_solo: 1200.0,
            slack_left: 0.08,
        }
    }

    fn scan_at(
        cons: &mut Consolidator,
        now: f64,
        c: &Cluster,
        t: &Telemetry,
        ctxs: &BTreeMap<VmId, VmContext>,
    ) -> Vec<ControlAction> {
        let mut pred = OraclePredictor;
        let sctx = ScheduleContext::new(now, c)
            .with_telemetry(t)
            .with_vm_ctx(ctxs);
        cons.scan(&sctx, Some(&mut pred))
    }

    /// Cluster with a lightly-loaded donor (host 0, one VM) and a
    /// moderately-loaded receiver (host 1).
    fn setup() -> (Cluster, BTreeMap<VmId, VmContext>, Telemetry) {
        let mut c = Cluster::homogeneous(3);
        let vm0 = c.create_vm(MEDIUM, JobId(0), 0.0);
        c.place_vm(vm0, HostId(0)).unwrap();
        let vm1 = c.create_vm(MEDIUM, JobId(1), 0.0);
        c.place_vm(vm1, HostId(1)).unwrap();
        c.host_mut(HostId(0)).demand = Demand {
            cpu: 1.5,
            mem_gb: 6.0,
            disk_mbps: 80.0,
            net_mbps: 20.0,
        };
        c.host_mut(HostId(1)).demand = Demand {
            cpu: 10.0,
            mem_gb: 12.0,
            disk_mbps: 100.0,
            net_mbps: 30.0,
        };
        let mut ctxs = BTreeMap::new();
        ctxs.insert(vm0, ctx());
        ctxs.insert(vm1, ctx());
        // Telemetry: a few samples reflecting current state.
        let mut t = Telemetry::new(3, 1, 0.0);
        let demands = BTreeMap::new();
        for k in 1..=5 {
            t.sample(k as f64 * 5.0, &c, &demands);
        }
        (c, ctxs, t)
    }

    #[test]
    fn evacuates_underutilized_donor_and_powers_off_empty() {
        let (c, ctxs, t) = setup();
        // No spare-host reserve for this test; grace still applies.
        let mut cons = Consolidator::new(ConsolidationParams {
            spare_hosts: 0,
            ..Default::default()
        });
        // First scan observes host 2 empty; no power-off before the
        // grace period elapses (hysteresis).
        let first = scan_at(&mut cons, 1000.0, &c, &t, &ctxs);
        assert!(
            !first.contains(&ControlAction::PowerOff(HostId(2))),
            "power-off before grace: {first:?}"
        );
        // After the grace period: host 2 powers off; host 0 (< δ_low)
        // evacuates its VM to host 1.
        let actions = scan_at(&mut cons, 1000.0 + 151.0, &c, &t, &ctxs);
        assert!(
            actions.contains(&ControlAction::PowerOff(HostId(2))),
            "{actions:?}"
        );
        let vm0 = *c.hosts[0].vms.first().unwrap();
        assert!(
            actions.contains(&ControlAction::Migrate { vm: vm0, to: HostId(1) }),
            "{actions:?}"
        );
    }

    #[test]
    fn spare_host_reserved() {
        let (c, ctxs, t) = setup();
        let mut cons = Consolidator::new(ConsolidationParams {
            spare_hosts: 1,
            ..Default::default()
        });
        scan_at(&mut cons, 1000.0, &c, &t, &ctxs);
        let actions = scan_at(&mut cons, 2000.0, &c, &t, &ctxs);
        // Host 2 is the ONLY empty host → kept on as the spare.
        assert!(
            !actions.iter().any(|a| matches!(a, ControlAction::PowerOff(_))),
            "{actions:?}"
        );
    }

    #[test]
    fn respects_min_hosts_on() {
        let mut c = Cluster::homogeneous(2);
        c.host_mut(HostId(1)).power_off(0.0);
        c.advance_power_states(100.0);
        let t = Telemetry::new(2, 1, 0.0);
        let mut cons = Consolidator::new(ConsolidationParams::default());
        let empty = BTreeMap::new();
        let actions = scan_at(&mut cons, 1000.0, &c, &t, &empty);
        // Host 0 is empty but it's the last one on.
        assert!(actions.is_empty(), "{actions:?}");
    }

    #[test]
    fn postpones_migrations_when_cluster_busy() {
        let (mut c, ctxs, _) = setup();
        // Saturate both active hosts per instantaneous util; telemetry
        // window reflects that.
        c.host_mut(HostId(1)).demand.cpu = 30.0;
        c.host_mut(HostId(2)).demand.cpu = 30.0;
        let vm2 = c.create_vm(MEDIUM, JobId(2), 0.0);
        c.place_vm(vm2, HostId(2)).unwrap();
        let mut t = Telemetry::new(3, 1, 0.0);
        for k in 1..=5 {
            t.sample(k as f64 * 5.0, &c, &BTreeMap::new());
        }
        let mut cons = Consolidator::new(ConsolidationParams::default());
        let actions = scan_at(&mut cons, 1000.0, &c, &t, &ctxs);
        assert!(
            !actions.iter().any(|a| matches!(a, ControlAction::Migrate { .. })),
            "migrations must wait for a low-activity window: {actions:?}"
        );
    }

    #[test]
    fn marks_hot_hosts_restricted() {
        let (mut c, ctxs, _) = setup();
        c.host_mut(HostId(1)).demand.cpu = 29.0; // > 0.85
        let mut t = Telemetry::new(3, 1, 0.0);
        for k in 1..=5 {
            t.sample(k as f64 * 5.0, &c, &BTreeMap::new());
        }
        let mut cons = Consolidator::new(ConsolidationParams::default());
        scan_at(&mut cons, 1000.0, &c, &t, &ctxs);
        assert!(cons.restricted.contains(&HostId(1)));
    }

    #[test]
    fn aborts_evacuation_without_sla_safe_targets() {
        let (mut c, mut ctxs, t) = setup();
        // Make the donor's VM extremely contention-sensitive.
        let vm0 = *c.hosts[0].vms.first().unwrap();
        ctxs.get_mut(&vm0).unwrap().slack_left = 0.0;
        // And make the only target CPU-hot enough that any CPU use slows.
        c.host_mut(HostId(1)).demand.cpu = 31.0;
        ctxs.get_mut(&vm0).unwrap().vector.cpu = 0.9;
        let mut cons = Consolidator::new(ConsolidationParams::default());
        let actions = scan_at(&mut cons, 1000.0, &c, &t, &ctxs);
        assert!(
            !actions.iter().any(|a| matches!(a, ControlAction::Migrate { .. })),
            "{actions:?}"
        );
    }

    #[test]
    fn ignores_hosts_already_migrating() {
        let (mut c, ctxs, t) = setup();
        c.host_mut(HostId(0)).migration_net = 50.0;
        let mut cons = Consolidator::new(ConsolidationParams::default());
        let actions = scan_at(&mut cons, 1000.0, &c, &t, &ctxs);
        assert!(
            !actions.iter().any(|a| matches!(a, ControlAction::Migrate { .. })),
            "{actions:?}"
        );
    }

    #[test]
    fn plans_nothing_without_a_scoring_handle() {
        let (c, ctxs, t) = setup();
        let mut cons = Consolidator::new(ConsolidationParams::default());
        let sctx = ScheduleContext::new(5000.0, &c)
            .with_telemetry(&t)
            .with_vm_ctx(&ctxs);
        assert!(cons.scan(&sctx, None).is_empty());
        assert_eq!(cons.name(), "consolidation");
    }
}
