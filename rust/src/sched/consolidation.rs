//! Adaptive consolidation (§III-C, Eqs. 8–9):
//!
//! ```text
//! U_h^cpu < δ_low  ⇒ migrate workloads away (then power down)
//! U_h^cpu > δ_high ⇒ restrict placements / relieve pressure
//! ```
//!
//! The scan runs periodically as a [`ControlLoop`], uses *sustained*
//! utilization from the context's telemetry window (not instantaneous
//! spikes), schedules migrations only in low-activity windows
//! (§III-C's "migrations are scheduled during low-activity
//! intervals"), and evacuates at most one donor host per scan to
//! avoid migration storms. Migration targets are scored through the
//! placement policy's predictor, borrowed via the scan's
//! [`ScoringHandle`].
//!
//! # Batched scoring
//!
//! The scan scores the full (donor VM × candidate target) matrix of a
//! donor with **one** predictor call, through the same reusable-arena
//! `predict_into` path `decide_batch` uses (it used to issue one call
//! per donor VM). Candidate gathering applies every filter that does
//! not depend on targets chosen for *earlier* VMs in the same scan;
//! the planned-load fit check — the only sequential dependence — is
//! applied afterwards at selection time, so the emitted actions are
//! identical to the per-VM loop. The per-VM reference survives as
//! [`Consolidator::scan_sequential`] and the equivalence is a
//! property test in `rust/tests/prop.rs`.
//!
//! # Sharded scans
//!
//! With a shard layer on the context the scan becomes a per-shard
//! pass: each shard nominates at most ONE Eq. 8 donor (so evacuation
//! stays bounded per shard, not per fleet) and evacuates it to
//! in-shard targets — one predictor call per donor shard. When a
//! donor VM has no viable in-shard target, a bounded cross-shard
//! fallback consults the [`crate::cluster::ShardDigest`]s and gathers
//! targets from the single best remote shard by headroom;
//! `cross_shard_budget` caps how many such migrations one scan may
//! plan. Without shards the context is one shard covering the fleet,
//! which reproduces the original single-donor scan exactly.
//!
//! # Parallel scans
//!
//! With a persistent [`WorkerPool`] on the context (and a cloneable
//! predictor) the per-donor gather + score passes are dispatched to
//! the donors' affinity workers (`WorkerPool::worker_for` of the
//! donor's shard, stable across scans). Each worker scores through the
//! **epoch-cached** predictor clone and feature arena in its slot
//! (`sched::worker_score`) — the same cache entry the
//! placement sweep uses, so a retrain invalidates both with one
//! epoch bump — and the gather body reads only frozen scan state
//! (prelude + cluster), never the planned loads. Selection, which
//! *does* depend on targets chosen for earlier donors, stays serial:
//! donors merge in ascending shard order through the same
//! [`Consolidator::merge_donor`] body the serial path uses, so the
//! emitted actions are bit-identical at any worker count
//! (property-tested in `rust/tests/pool.rs`).

use crate::cluster::{Cluster, Flavor, Host, HostId, Utilization, VmId, VmState};
use crate::predict::{EnergyPredictor, Prediction};
use crate::profile::{build_features, ResourceVector, FEAT_DIM};
use crate::runtime::{WorkerPool, WorkerSlot};
use crate::sched::control::{ControlAction, ControlLoop, ScoringHandle};
use crate::sched::worker_score::{stage_installs, WorkerScore};
use crate::sched::{ScheduleContext, ShardHosts};
use std::collections::BTreeMap;

/// Consolidation tunables (`abl1` sweeps δ_low × δ_high).
#[derive(Debug, Clone, Copy)]
pub struct ConsolidationParams {
    /// Eq. 8 lower threshold on sustained host CPU utilization.
    pub delta_low: f64,
    /// Eq. 9 upper threshold.
    pub delta_high: f64,
    /// Telemetry samples the sustained-utilization window averages.
    pub window_samples: usize,
    /// Cluster-mean CPU utilization above which migrations wait
    /// (low-activity-window scheduling).
    pub migration_util_ceiling: f64,
    /// Never power below this many hosts.
    pub min_hosts_on: usize,
    /// Max predicted slowdown accepted on a migration target.
    pub max_slowdown: f64,
    /// Keep this many *empty* hosts on as boot-latency headroom —
    /// powering off the last spare forces a 90 s boot on the next
    /// burst, which costs more energy (and SLA slack) than it saves.
    pub spare_hosts: usize,
    /// A host must be continuously empty this long before power-off
    /// (hysteresis against placement/consolidation thrash).
    pub empty_grace_s: f64,
    /// Maximum cross-shard migrations one sharded scan may plan.
    /// Cross-shard moves are the fallback when a donor VM has no
    /// in-shard target; bounding them keeps a scan's blast radius at
    /// the shard scale (irrelevant without a shard layer — a single
    /// shard has no remote targets).
    pub cross_shard_budget: usize,
}

impl Default for ConsolidationParams {
    fn default() -> Self {
        ConsolidationParams {
            delta_low: 0.30,
            delta_high: 0.85,
            window_samples: 24, // 2 min of 5 s samples
            migration_util_ceiling: 0.75,
            min_hosts_on: 1,
            max_slowdown: 0.08,
            spare_hosts: 0,
            empty_grace_s: 45.0,
            cross_shard_budget: 2,
        }
    }
}

/// Network-utilization share of one live-migration copy stream
/// (40 MB/s throttle on a ~117 MB/s NIC).
pub const MIGRATION_NET_UTIL: f64 = 40.0 / 117.0;

/// Per-VM context the scan needs from the coordinator.
#[derive(Debug, Clone)]
pub struct VmContext {
    pub vector: ResourceVector,
    pub remaining_solo: f64,
    /// Current SLA headroom: max extra slowdown the job tolerates.
    pub slack_left: f64,
}

pub struct Consolidator {
    pub params: ConsolidationParams,
    /// Hosts currently under Eq. 9 restriction (informational; the
    /// energy-aware policy applies δ_high itself at placement time).
    pub restricted: Vec<HostId>,
    /// When each host was first observed empty (hysteresis state).
    empty_since: BTreeMap<HostId, f64>,
    /// Scoring arena, refilled in place each scan: candidate targets,
    /// their feature rows, per-VM `[start, end)` spans, and the
    /// predictor output — no steady-state allocation on the scan
    /// path.
    feats: Vec<[f32; FEAT_DIM]>,
    cands: Vec<HostId>,
    spans: Vec<(VmId, usize, usize, bool)>,
    preds: Vec<Prediction>,
}

/// One donor's gathered + scored evacuation candidates — the output
/// of the (parallelizable) first half of a donor pass, consumed by
/// the serial selection merge. `spans` maps each donor VM to its
/// candidate range and whether the candidates came from the
/// cross-shard fallback.
#[derive(Default)]
struct DonorGather {
    spans: Vec<(VmId, usize, usize, bool)>,
    cands: Vec<HostId>,
    preds: Vec<Prediction>,
    /// False when the donor must be abandoned wholesale: a VM with
    /// missing context, shorter remaining work than its own copy, or
    /// no viable target anywhere.
    viable: bool,
}

/// Everything the evacuation planner needs from the first half of a
/// scan: Eq. 9 bookkeeping, power-off planning, the low-activity
/// gate, and donor selection. Shared by the batched scan and the
/// sequential reference so the two can only differ in how targets
/// are *scored*.
struct ScanPrelude {
    actions: Vec<ControlAction>,
    sustained: Vec<f64>,
    /// `None` when the cluster is busy (migrations postponed) or no
    /// host qualifies under Eq. 8. The per-host state the target
    /// filter needs lives *inside* the option so it cannot be read
    /// on a donor-less scan (and is never computed for one).
    evacuation: Option<Evacuation>,
}

/// The Eq. 8 donors (at most one per shard) plus the per-host scan
/// state the target filter consumes, computed once per scan —
/// VM-independent within the frozen context, so the gather loop must
/// not recompute it per (donor VM × target) pair.
struct Evacuation {
    /// `(shard, donor host)` pairs, ascending by shard. Without a
    /// shard layer this holds at most one entry.
    donors: Vec<(usize, HostId)>,
    /// Per-host flag: selected as a donor this scan (targets must
    /// never be donors — they are below δ_low and being drained).
    donor_flag: Vec<bool>,
    /// Per-host flag: planned for power-off this scan.
    off_planned: Vec<bool>,
    /// Per-host effective utilization — max(instantaneous, profiled).
    utils: Vec<Utilization>,
}

/// Static target filters for migrating a donor VM (of `flavor`, with
/// runtime context `vctx`) onto `host`: everything except the
/// planned-load fit check, whose inputs depend on targets chosen for
/// earlier VMs in the same scan and which is therefore applied at
/// selection time. One predicate shared by every gather path (serial,
/// pooled, and the sequential reference), so the candidate sets
/// cannot drift. Reads only frozen scan state — safe to run on a
/// worker thread.
fn target_ok(
    params: &ConsolidationParams,
    cluster: &Cluster,
    sustained: &[f64],
    ev: &Evacuation,
    host: &Host,
    flavor: &Flavor,
    vctx: &VmContext,
) -> bool {
    if ev.donor_flag[host.id.0] || !host.state.is_on() || host.is_degraded() {
        return false;
    }
    // Never migrate onto a host we just planned to power off, and
    // never onto an *empty* host — moving load to an empty machine
    // swaps hosts instead of shrinking the active set.
    if ev.off_planned[host.id.0] || host.vms.is_empty() {
        return false;
    }
    // Eq. 9 restriction on sustained utilization.
    if sustained[host.id.0] > params.delta_high {
        return false;
    }
    // Base admission fit (the planned-load variant, which only
    // shrinks this set, is re-checked at selection time).
    if !host.fits(flavor, cluster.reserved(host.id)) {
        return false;
    }
    // Same effective-load headroom the placement path uses.
    let u = &ev.utils[host.id.0];
    let (pc, pm, pd, pn) = crate::predict::oracle::post_utilization(&vctx.vector, u);
    if (vctx.vector.cpu > 0.1 && pc > 0.90)
        || (vctx.vector.mem > 0.1 && pm > 0.90)
        || (vctx.vector.disk > 0.1 && pd > 0.90)
        || (vctx.vector.net > 0.1 && pn > 0.90)
    {
        return false;
    }
    // The migration copy itself occupies ~0.34 of a 1 GbE NIC on
    // the receiving end; co-located network-heavy phases must
    // still fit beside it.
    if pn + MIGRATION_NET_UTIL > 0.95 {
        return false;
    }
    true
}

/// Gather one donor VM's viable targets from `hosts` into the given
/// arena — the ONE gather body shared by the in-shard pass and the
/// cross-shard fallback on every scan path.
#[allow(clippy::too_many_arguments)]
fn gather_targets_into(
    params: &ConsolidationParams,
    cluster: &Cluster,
    sustained: &[f64],
    ev: &Evacuation,
    hosts: ShardHosts<'_>,
    flavor: &Flavor,
    vctx: &VmContext,
    cands: &mut Vec<HostId>,
    feats: &mut Vec<[f32; FEAT_DIM]>,
) {
    for host_id in hosts {
        let host = &cluster.hosts[host_id.0];
        if !target_ok(params, cluster, sustained, ev, host, flavor, vctx) {
            continue;
        }
        cands.push(host.id);
        feats.push(build_features(&vctx.vector, vctx.remaining_solo, host));
    }
}

/// Pre-copy duration at the 40 MB/s throttle: migrating a VM whose
/// remaining work is shorter than the copy itself cannot free the
/// donor early enough to pay for the copy's network pressure.
fn copy_secs(flavor: &Flavor) -> f64 {
    flavor.mem_gb * 1024.0 * 1.3 / 40.0
}

/// The best remote shard (by digest headroom) to overflow into when a
/// donor VM has no in-shard target — the cross-shard pass reads only
/// the digests, never a remote shard's interior state.
fn best_remote_shard(ctx: &ScheduleContext<'_>, exclude: usize) -> Option<usize> {
    let mut best: Option<(usize, f64)> = None;
    for s in 0..ctx.shard_count() {
        if s == exclude {
            continue;
        }
        let score = ctx.shard_digest(s).headroom_score();
        if score <= 0.0 {
            continue;
        }
        if best.map(|(_, b)| score > b).unwrap_or(true) {
            best = Some((s, score));
        }
    }
    best.map(|(s, _)| s)
}

/// Gather every VM of one donor into the given arena: in-shard
/// targets first, then the digest-driven cross-shard fallback
/// (flagged in the span so the budget gate can count it at merge
/// time). Returns false when the donor must be abandoned wholesale —
/// a VM with missing context, remaining work shorter than its own
/// copy, or no viable target anywhere. Reads only frozen scan state;
/// in particular it never consults the planned loads, which is what
/// makes donors gatherable in parallel.
#[allow(clippy::too_many_arguments)]
fn gather_donor(
    params: &ConsolidationParams,
    ctx: &ScheduleContext<'_>,
    sustained: &[f64],
    ev: &Evacuation,
    shard: usize,
    donor: HostId,
    spans: &mut Vec<(VmId, usize, usize, bool)>,
    cands: &mut Vec<HostId>,
    feats: &mut Vec<[f32; FEAT_DIM]>,
) -> bool {
    let cluster = ctx.cluster;
    for &vm_id in &cluster.hosts[donor.0].vms {
        let vm = &cluster.vms[&vm_id];
        let Some(vctx) = ctx.vm_context(vm_id) else {
            return false; // missing context: be conservative
        };
        if vctx.remaining_solo < copy_secs(&vm.flavor) {
            return false; // let it drain instead
        }
        let start = cands.len();
        gather_targets_into(
            params,
            cluster,
            sustained,
            ev,
            ctx.shard(shard).hosts(),
            &vm.flavor,
            vctx,
            cands,
            feats,
        );
        let mut crossed = false;
        if cands.len() == start {
            // No in-shard target: cross-shard fallback into the
            // single best remote shard by digest headroom.
            let Some(remote) = best_remote_shard(ctx, shard) else {
                return false; // cannot fully evacuate
            };
            gather_targets_into(
                params,
                cluster,
                sustained,
                ev,
                ctx.shard(remote).hosts(),
                &vm.flavor,
                vctx,
                cands,
                feats,
            );
            if cands.len() == start {
                return false; // cannot fully evacuate: give up this donor
            }
            crossed = true;
        }
        spans.push((vm_id, start, cands.len(), crossed));
    }
    true
}

impl Consolidator {
    pub fn new(params: ConsolidationParams) -> Consolidator {
        Consolidator {
            params,
            restricted: Vec::new(),
            empty_since: BTreeMap::new(),
            feats: Vec::new(),
            cands: Vec::new(),
            spans: Vec::new(),
            preds: Vec::new(),
        }
    }

    /// First half of a scan: restriction bookkeeping, hysteresis
    /// power-offs, the low-activity migration gate, and Eq. 8 donor
    /// selection. Pure planning: no cluster mutation here.
    fn prelude(&mut self, ctx: &ScheduleContext<'_>) -> ScanPrelude {
        let now = ctx.now;
        let cluster = ctx.cluster;
        let mut actions = Vec::new();
        let n = cluster.n_hosts();
        // Sustained per-host CPU utilization (telemetry window, with
        // instantaneous fallback — shared helper on the context).
        let sustained: Vec<f64> = (0..n)
            .map(|i| ctx.sustained_cpu(HostId(i), self.params.window_samples))
            .collect();

        // Eq. 9 bookkeeping.
        self.restricted = (0..n)
            .filter(|&i| cluster.hosts[i].state.is_on() && sustained[i] > self.params.delta_high)
            .map(HostId)
            .collect();

        // Power-off planning with hysteresis and spare-host headroom:
        // a host powers off only after `empty_grace_s` of continuous
        // emptiness, and only while more than `spare_hosts` empty
        // hosts (plus the absolute floor) remain on.
        for host in &cluster.hosts {
            if host.state.is_on() && host.vms.is_empty() {
                self.empty_since.entry(host.id).or_insert(now);
            } else {
                self.empty_since.remove(&host.id);
            }
        }
        let mut hosts_on = cluster.hosts_on();
        let mut empty_on = self
            .empty_since
            .iter()
            .filter(|(h, _)| cluster.host(**h).state.is_on())
            .count();
        let mut powering_off: Vec<HostId> = Vec::new();
        // Oldest-empty first (most likely genuinely idle).
        let mut candidates: Vec<(f64, HostId)> = self
            .empty_since
            .iter()
            .map(|(&h, &t)| (t, h))
            .collect();
        candidates.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        for (since, h) in candidates {
            if now - since < self.params.empty_grace_s {
                continue;
            }
            if hosts_on <= self.params.min_hosts_on
                || empty_on <= self.params.spare_hosts
            {
                break;
            }
            actions.push(ControlAction::PowerOff(h));
            powering_off.push(h);
            hosts_on -= 1;
            empty_on -= 1;
        }

        // Low-activity gate for migrations.
        let on_utils: Vec<f64> = (0..n)
            .filter(|&i| cluster.hosts[i].state.is_on())
            .map(|i| sustained[i])
            .collect();
        let cluster_mean = if on_utils.is_empty() {
            0.0
        } else {
            on_utils.iter().sum::<f64>() / on_utils.len() as f64
        };
        // Eq. 8, per shard: each shard nominates at most ONE donor.
        // Degraded hosts are *preferred* donors — they stopped
        // accepting placements, so their tenants must drain regardless
        // of utilization or how busy the cluster is. Otherwise the
        // least-utilized on-host below δ_low that still runs VMs and
        // is migration-quiet, gated on low cluster activity. Without a
        // shard layer the whole cluster is one shard, i.e. the
        // original single-donor scan.
        let donors: Vec<(usize, HostId)> = (0..ctx.shard_count())
            .filter_map(|s| {
                let movable = |h: &HostId| {
                    let host = &cluster.hosts[h.0];
                    host.state.is_on()
                        && !host.vms.is_empty()
                        && host.migration_net == 0.0
                        && host
                            .vms
                            .iter()
                            .all(|vm| matches!(cluster.vms[vm].state, VmState::Running))
                };
                // Proactive drain: least-utilized degraded host first.
                let drain = ctx
                    .shard(s)
                    .hosts()
                    .filter(|h| movable(h) && cluster.hosts[h.0].is_degraded())
                    .min_by(|a, b| sustained[a.0].partial_cmp(&sustained[b.0]).unwrap());
                if let Some(h) = drain {
                    return Some((s, h));
                }
                if cluster_mean > self.params.migration_util_ceiling {
                    return None; // busy: postpone consolidation migrations
                }
                ctx.shard(s)
                    .hosts()
                    .filter(|h| movable(h) && sustained[h.0] < self.params.delta_low)
                    .min_by(|a, b| sustained[a.0].partial_cmp(&sustained[b.0]).unwrap())
                    .map(|h| (s, h))
            })
            .collect();
        // Per-host scan state for the target filter is only computed
        // when a donor exists — the common busy/no-donor scan skips
        // the O(hosts) effective-utilization sweep entirely.
        let evacuation = if donors.is_empty() {
            None
        } else {
            let mut off_planned = vec![false; n];
            for h in &powering_off {
                off_planned[h.0] = true;
            }
            let mut donor_flag = vec![false; n];
            for &(_, h) in &donors {
                donor_flag[h.0] = true;
            }
            Some(Evacuation {
                donors,
                donor_flag,
                off_planned,
                utils: (0..n).map(|i| cluster.effective_util(HostId(i))).collect(),
            })
        };
        ScanPrelude {
            actions,
            sustained,
            evacuation,
        }
    }

    // `target_ok`, `gather_targets_into`, `gather_donor`,
    // `best_remote_shard`, and `copy_secs` are module-level functions
    // above: they read only frozen scan state, which is what lets the
    // pooled scan run them on worker threads.

    /// Selection step shared by the batched scan and the sequential
    /// reference: among one VM's candidates (already filtered by
    /// [`target_ok`]), re-check admission against the
    /// load planned onto each target earlier in this scan, apply the
    /// SLA slowdown gate, and argmin the amortized-idle-floor cost.
    /// One function so a tweak to the cost formula or the planned-load
    /// accounting cannot break the batched == sequential equivalence
    /// the property test guards.
    #[allow(clippy::too_many_arguments)]
    fn select_target(
        &self,
        cluster: &Cluster,
        flavor: &Flavor,
        vctx: &VmContext,
        cands: &[HostId],
        preds: &[Prediction],
        extra_mem: &BTreeMap<HostId, f64>,
        extra_cpu: &BTreeMap<HostId, f64>,
    ) -> Option<HostId> {
        let mut best: Option<(HostId, f64)> = None;
        for (&cand, p) in cands.iter().zip(preds) {
            // Planned-load fit: targets filled by earlier VMs in this
            // scan may no longer take this one.
            let host = cluster.host(cand);
            let mut reserved = *cluster.reserved(cand);
            reserved.mem_gb += extra_mem.get(&cand).copied().unwrap_or(0.0);
            reserved.cpu += extra_cpu.get(&cand).copied().unwrap_or(0.0);
            if !host.fits(flavor, &reserved) {
                continue;
            }
            if p.slowdown > self.params.max_slowdown.min(vctx.slack_left) {
                continue;
            }
            // Same amortized-idle-floor objective as placement
            // (shared via Host::idle_share).
            let cost = (p.power_w + host.idle_share()) * (1.0 + p.slowdown);
            if best.map(|(_, c)| cost < c).unwrap_or(true) {
                best = Some((cand, cost));
            }
        }
        best.map(|(host, _)| host)
    }

    /// Selection + commit for one donor's scored gather — the ONE
    /// merge body shared by the serial and pooled scan paths, run in
    /// ascending shard order either way. Applies the donor-level
    /// cross-shard budget gate (identical in outcome to gating each
    /// fallback as it is gathered: a donor is abandoned exactly when
    /// its cross-shard fallbacks exceed the remaining budget), then
    /// plans a target for every VM in order with planned-load
    /// accounting, committing to the cross-donor maps — and the
    /// budget — only when the whole donor evacuates (partial
    /// evacuation strands the host at even lower utilization).
    #[allow(clippy::too_many_arguments)]
    fn merge_donor(
        &self,
        ctx: &ScheduleContext<'_>,
        spans: &[(VmId, usize, usize, bool)],
        cands: &[HostId],
        preds: &[Prediction],
        viable: bool,
        cross_budget: &mut usize,
        extra_mem: &mut BTreeMap<HostId, f64>,
        extra_cpu: &mut BTreeMap<HostId, f64>,
        actions: &mut Vec<ControlAction>,
    ) {
        if !viable || spans.is_empty() {
            return;
        }
        let cross_needed = spans.iter().filter(|s| s.3).count();
        if cross_needed > *cross_budget {
            return;
        }
        let cluster = ctx.cluster;
        let mut local_mem = extra_mem.clone();
        let mut local_cpu = extra_cpu.clone();
        let mut planned: Vec<(VmId, HostId)> = Vec::new();
        for &(vm_id, start, end, _) in spans {
            let vm = &cluster.vms[&vm_id];
            let vctx = ctx.vm_context(vm_id).expect("gathered above");
            let target = self.select_target(
                cluster,
                &vm.flavor,
                vctx,
                &cands[start..end],
                &preds[start..end],
                &local_mem,
                &local_cpu,
            );
            let Some(target) = target else {
                return; // SLA-unsafe: abandon this donor wholesale
            };
            *local_mem.entry(target).or_default() += vm.flavor.mem_gb;
            *local_cpu.entry(target).or_default() += vm.flavor.vcpus;
            planned.push((vm_id, target));
        }
        *cross_budget -= cross_needed;
        *extra_mem = local_mem;
        *extra_cpu = local_cpu;
        for (vm, to) in planned {
            actions.push(ControlAction::Migrate { vm, to });
        }
    }

    /// Gather + score every donor on the persistent worker pool: one
    /// job per donor, dispatched to the donor shard's affinity
    /// worker, scoring through the epoch-cached predictor clone and
    /// feature arena in that worker's slot ([`WorkerScore`] — shared
    /// with the placement sweep). Returns `None` (caller gathers
    /// inline) when the pool is serial, there is at most one donor,
    /// or the predictor cannot be cloned.
    fn gather_donors_parallel(
        &self,
        ctx: &ScheduleContext<'_>,
        sustained: &[f64],
        ev: &Evacuation,
        predictor: &dyn EnergyPredictor,
        pool: &WorkerPool,
    ) -> Option<Vec<DonorGather>> {
        if !pool.parallel() || ev.donors.len() <= 1 {
            return None;
        }
        let mut staged = stage_installs(pool, ev.donors.iter().map(|&(s, _)| s), predictor)?;
        let epoch = staged.epoch;
        let params = self.params;
        let jobs: Vec<_> = ev
            .donors
            .iter()
            .map(|&(shard, donor)| {
                let install = staged.take(pool.worker_for(shard));
                (shard, move |w: &mut WorkerSlot| {
                    let st = WorkerScore::fetch(w, epoch, install);
                    let mut g = DonorGather::default();
                    st.feats.clear();
                    g.viable = gather_donor(
                        &params,
                        ctx,
                        sustained,
                        ev,
                        shard,
                        donor,
                        &mut g.spans,
                        &mut g.cands,
                        &mut st.feats,
                    );
                    if g.viable && !g.spans.is_empty() {
                        // ONE predictor call per donor, same matrix as
                        // the serial pass.
                        st.predictor.predict_into(&st.feats, &mut g.preds);
                    }
                    g
                })
            })
            .collect();
        let gathers = pool
            .dispatch(jobs)
            .unwrap_or_else(|e| panic!("parallel consolidation scan poisoned: {e}"));
        Some(gathers)
    }

    /// One scan pass, batched and shard-aware: for each donor (one
    /// per shard at most), score its full (donor VM × candidate
    /// target) matrix with ONE predictor call, then run the serial
    /// selection with planned-load accounting in ascending shard
    /// order. Targets come from the donor's own shard, with a
    /// digest-driven, budget-bounded fallback to the best remote
    /// shard. Donor gathers run on the context's worker pool when one
    /// is attached — bit-identical to the inline pass because gather
    /// reads only frozen scan state and the merge is shared. Without
    /// a shard layer this emits the same actions as
    /// [`Consolidator::scan_sequential`]. Pure planning: no cluster
    /// mutation here.
    fn plan(
        &mut self,
        ctx: &ScheduleContext<'_>,
        predictor: &mut dyn EnergyPredictor,
    ) -> Vec<ControlAction> {
        let prelude = self.prelude(ctx);
        let mut actions = prelude.actions;
        let Some(ref ev) = prelude.evacuation else {
            return actions;
        };
        // Planned-load accounting shared across donors: a target
        // filled by one shard's evacuation is seen by the next.
        let mut extra_mem: BTreeMap<HostId, f64> = BTreeMap::new();
        let mut extra_cpu: BTreeMap<HostId, f64> = BTreeMap::new();
        let mut cross_budget = self.params.cross_shard_budget;
        let pooled = ctx.pool.and_then(|pool| {
            self.gather_donors_parallel(ctx, &prelude.sustained, ev, &*predictor, pool)
        });
        match pooled {
            Some(gathers) => {
                for g in &gathers {
                    self.merge_donor(
                        ctx,
                        &g.spans,
                        &g.cands,
                        &g.preds,
                        g.viable,
                        &mut cross_budget,
                        &mut extra_mem,
                        &mut extra_cpu,
                        &mut actions,
                    );
                }
            }
            None => {
                for &(shard, donor) in &ev.donors {
                    self.feats.clear();
                    self.cands.clear();
                    self.spans.clear();
                    self.preds.clear();
                    let viable = gather_donor(
                        &self.params,
                        ctx,
                        &prelude.sustained,
                        ev,
                        shard,
                        donor,
                        &mut self.spans,
                        &mut self.cands,
                        &mut self.feats,
                    );
                    if viable && !self.spans.is_empty() {
                        // Scoring phase: ONE predictor call per donor.
                        predictor.predict_into(&self.feats, &mut self.preds);
                    }
                    self.merge_donor(
                        ctx,
                        &self.spans,
                        &self.cands,
                        &self.preds,
                        viable,
                        &mut cross_budget,
                        &mut extra_mem,
                        &mut extra_cpu,
                        &mut actions,
                    );
                }
            }
        }
        actions
    }

    /// Reference implementation: the pre-batching, pre-sharding
    /// per-VM loop (one predictor call per donor VM, single donor per
    /// scan). Kept public-but-hidden as the parity oracle —
    /// `rust/tests/prop.rs` asserts `scan` emits identical
    /// [`ControlAction`]s across randomized *unsharded* clusters —
    /// and as the sequential baseline
    /// `benches/bench_consolidation.rs` measures the batched scan
    /// against. Only the first donor is considered, so compare it to
    /// `scan` on contexts without a shard layer.
    #[doc(hidden)]
    pub fn scan_sequential(
        &mut self,
        ctx: &ScheduleContext<'_>,
        predictor: ScoringHandle<'_>,
    ) -> Vec<ControlAction> {
        let prelude = self.prelude(ctx);
        let mut actions = prelude.actions;
        let Some(ref ev) = prelude.evacuation else {
            return actions;
        };
        let donor = ev.donors[0].1;
        let cluster = ctx.cluster;
        let mut planned: Vec<(VmId, HostId)> = Vec::new();
        let mut extra_mem: BTreeMap<HostId, f64> = BTreeMap::new();
        let mut extra_cpu: BTreeMap<HostId, f64> = BTreeMap::new();
        for &vm_id in &cluster.hosts[donor.0].vms {
            let vm = &cluster.vms[&vm_id];
            let vctx = match ctx.vm_context(vm_id) {
                Some(c) => c,
                None => return actions,
            };
            if vctx.remaining_solo < copy_secs(&vm.flavor) {
                return actions;
            }
            let mut cands: Vec<HostId> = Vec::new();
            let mut feats = Vec::new();
            for host in &cluster.hosts {
                if !target_ok(&self.params, cluster, &prelude.sustained, ev, host, &vm.flavor, vctx)
                {
                    continue;
                }
                cands.push(host.id);
                feats.push(build_features(&vctx.vector, vctx.remaining_solo, host));
            }
            if cands.is_empty() {
                return actions;
            }
            // One predictor call PER VM — the cost the batched scan
            // removes.
            let preds = predictor.predict(&feats);
            let target = self.select_target(
                cluster,
                &vm.flavor,
                vctx,
                &cands,
                &preds,
                &extra_mem,
                &extra_cpu,
            );
            match target {
                Some(target) => {
                    *extra_mem.entry(target).or_default() += vm.flavor.mem_gb;
                    *extra_cpu.entry(target).or_default() += vm.flavor.vcpus;
                    planned.push((vm_id, target));
                }
                None => return actions,
            }
        }
        for (vm, to) in planned {
            actions.push(ControlAction::Migrate { vm, to });
        }
        actions
    }
}

impl ControlLoop for Consolidator {
    fn name(&self) -> &'static str {
        "consolidation"
    }

    fn box_clone(&self) -> Box<dyn ControlLoop> {
        Box::new(Consolidator::new(self.params))
    }

    fn scan(
        &mut self,
        ctx: &ScheduleContext<'_>,
        scoring: Option<ScoringHandle<'_>>,
    ) -> Vec<ControlAction> {
        // Migration targets are ranked by predicted energy/slowdown;
        // without a predictor there is nothing safe to plan.
        match scoring {
            Some(predictor) => self.plan(ctx, predictor),
            None => Vec::new(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::flavor::MEDIUM;
    use crate::cluster::{Cluster, Demand};
    use crate::predict::OraclePredictor;
    use crate::sim::Telemetry;
    use crate::workload::JobId;

    fn ctx() -> VmContext {
        VmContext {
            vector: ResourceVector {
                cpu: 0.15,
                mem: 0.4,
                disk: 0.5,
                net: 0.3,
                cpu_peak: 0.2,
                io_peak: 0.6,
                burstiness: 0.1,
            },
            remaining_solo: 1200.0,
            slack_left: 0.08,
        }
    }

    fn scan_at(
        cons: &mut Consolidator,
        now: f64,
        c: &Cluster,
        t: &Telemetry,
        ctxs: &BTreeMap<VmId, VmContext>,
    ) -> Vec<ControlAction> {
        let mut pred = OraclePredictor;
        let sctx = ScheduleContext::new(now, c)
            .with_telemetry(t)
            .with_vm_ctx(ctxs);
        cons.scan(&sctx, Some(&mut pred))
    }

    /// Cluster with a lightly-loaded donor (host 0, one VM) and a
    /// moderately-loaded receiver (host 1).
    fn setup() -> (Cluster, BTreeMap<VmId, VmContext>, Telemetry) {
        let mut c = Cluster::homogeneous(3);
        let vm0 = c.create_vm(MEDIUM, JobId(0), 0.0);
        c.place_vm(vm0, HostId(0)).unwrap();
        let vm1 = c.create_vm(MEDIUM, JobId(1), 0.0);
        c.place_vm(vm1, HostId(1)).unwrap();
        c.host_mut(HostId(0)).demand = Demand {
            cpu: 1.5,
            mem_gb: 6.0,
            disk_mbps: 80.0,
            net_mbps: 20.0,
        };
        c.host_mut(HostId(1)).demand = Demand {
            cpu: 10.0,
            mem_gb: 12.0,
            disk_mbps: 100.0,
            net_mbps: 30.0,
        };
        let mut ctxs = BTreeMap::new();
        ctxs.insert(vm0, ctx());
        ctxs.insert(vm1, ctx());
        // Telemetry: a few samples reflecting current state.
        let mut t = Telemetry::new(3, 1, 0.0);
        let demands = BTreeMap::new();
        for k in 1..=5 {
            t.sample(k as f64 * 5.0, &c, &demands);
        }
        (c, ctxs, t)
    }

    #[test]
    fn evacuates_underutilized_donor_and_powers_off_empty() {
        let (c, ctxs, t) = setup();
        // No spare-host reserve for this test; grace still applies.
        let mut cons = Consolidator::new(ConsolidationParams {
            spare_hosts: 0,
            ..Default::default()
        });
        // First scan observes host 2 empty; no power-off before the
        // grace period elapses (hysteresis).
        let first = scan_at(&mut cons, 1000.0, &c, &t, &ctxs);
        assert!(
            !first.contains(&ControlAction::PowerOff(HostId(2))),
            "power-off before grace: {first:?}"
        );
        // After the grace period: host 2 powers off; host 0 (< δ_low)
        // evacuates its VM to host 1.
        let actions = scan_at(&mut cons, 1000.0 + 151.0, &c, &t, &ctxs);
        assert!(
            actions.contains(&ControlAction::PowerOff(HostId(2))),
            "{actions:?}"
        );
        let vm0 = *c.hosts[0].vms.first().unwrap();
        assert!(
            actions.contains(&ControlAction::Migrate { vm: vm0, to: HostId(1) }),
            "{actions:?}"
        );
    }

    #[test]
    fn spare_host_reserved() {
        let (c, ctxs, t) = setup();
        let mut cons = Consolidator::new(ConsolidationParams {
            spare_hosts: 1,
            ..Default::default()
        });
        scan_at(&mut cons, 1000.0, &c, &t, &ctxs);
        let actions = scan_at(&mut cons, 2000.0, &c, &t, &ctxs);
        // Host 2 is the ONLY empty host → kept on as the spare.
        assert!(
            !actions.iter().any(|a| matches!(a, ControlAction::PowerOff(_))),
            "{actions:?}"
        );
    }

    #[test]
    fn respects_min_hosts_on() {
        let mut c = Cluster::homogeneous(2);
        c.host_mut(HostId(1)).power_off(0.0);
        c.advance_power_states(100.0);
        let t = Telemetry::new(2, 1, 0.0);
        let mut cons = Consolidator::new(ConsolidationParams::default());
        let empty = BTreeMap::new();
        let actions = scan_at(&mut cons, 1000.0, &c, &t, &empty);
        // Host 0 is empty but it's the last one on.
        assert!(actions.is_empty(), "{actions:?}");
    }

    #[test]
    fn postpones_migrations_when_cluster_busy() {
        let (mut c, ctxs, _) = setup();
        // Saturate both active hosts per instantaneous util; telemetry
        // window reflects that.
        c.host_mut(HostId(1)).demand.cpu = 30.0;
        c.host_mut(HostId(2)).demand.cpu = 30.0;
        let vm2 = c.create_vm(MEDIUM, JobId(2), 0.0);
        c.place_vm(vm2, HostId(2)).unwrap();
        let mut t = Telemetry::new(3, 1, 0.0);
        for k in 1..=5 {
            t.sample(k as f64 * 5.0, &c, &BTreeMap::new());
        }
        let mut cons = Consolidator::new(ConsolidationParams::default());
        let actions = scan_at(&mut cons, 1000.0, &c, &t, &ctxs);
        assert!(
            !actions.iter().any(|a| matches!(a, ControlAction::Migrate { .. })),
            "migrations must wait for a low-activity window: {actions:?}"
        );
    }

    #[test]
    fn degraded_host_is_the_preferred_donor() {
        use crate::cluster::HostCondition;
        // Host 0 sits below δ_low — the Eq. 8 donor — but host 1 is
        // degraded: the drain must win, evacuating host 1's VM onto
        // the healthy host 0 and leaving host 0's tenant in place.
        let (mut c, ctxs, _) = setup();
        c.host_mut(HostId(1)).condition = HostCondition::FlakyDisk;
        let mut t = Telemetry::new(3, 1, 0.0);
        for k in 1..=5 {
            t.sample(k as f64 * 5.0, &c, &BTreeMap::new());
        }
        let mut cons = Consolidator::new(ConsolidationParams::default());
        let actions = scan_at(&mut cons, 1000.0, &c, &t, &ctxs);
        let vm1 = *c.hosts[1].vms.first().unwrap();
        assert!(
            actions.contains(&ControlAction::Migrate { vm: vm1, to: HostId(0) }),
            "degraded host must drain: {actions:?}"
        );
        let vm0 = *c.hosts[0].vms.first().unwrap();
        assert!(
            !actions.iter().any(|a| matches!(a, ControlAction::Migrate { vm, .. } if *vm == vm0)),
            "only one donor per shard: {actions:?}"
        );
    }

    #[test]
    fn degraded_hosts_are_rejected_as_migration_targets() {
        use crate::cluster::HostCondition;
        // Host 0 is the usual donor, host 1 the only viable receiver;
        // once host 1 degrades too, the donor must be abandoned — a
        // draining host cannot absorb evacuations. Both hosts are
        // degraded, so the drain picks the quieter host 0 as donor and
        // then finds no target.
        let (mut c, ctxs, t) = setup();
        c.host_mut(HostId(0)).condition = HostCondition::Thermal;
        c.host_mut(HostId(1)).condition = HostCondition::FlakyDisk;
        let mut cons = Consolidator::new(ConsolidationParams::default());
        let actions = scan_at(&mut cons, 1000.0, &c, &t, &ctxs);
        assert!(
            !actions.iter().any(|a| matches!(a, ControlAction::Migrate { .. })),
            "{actions:?}"
        );
    }

    #[test]
    fn drain_bypasses_the_busy_cluster_gate() {
        use crate::cluster::HostCondition;
        // Mean sustained utilization above the migration ceiling
        // normally postpones all migrations — but a degraded host
        // must still drain: waiting risks losing the tenants with it.
        let mut c = Cluster::homogeneous(8);
        let vm0 = c.create_vm(MEDIUM, JobId(0), 0.0);
        c.place_vm(vm0, HostId(0)).unwrap();
        let vm7 = c.create_vm(MEDIUM, JobId(7), 0.0);
        c.place_vm(vm7, HostId(7)).unwrap();
        c.host_mut(HostId(0)).demand.cpu = 25.6; // 0.80
        for h in 1..7 {
            c.host_mut(HostId(h)).demand.cpu = 27.2; // 0.85 each
        }
        c.host_mut(HostId(7)).demand.cpu = 9.6; // 0.30 — the receiver
        c.host_mut(HostId(0)).condition = HostCondition::FlakyDisk;
        let mut t = Telemetry::new(8, 1, 0.0);
        for k in 1..=5 {
            t.sample(k as f64 * 5.0, &c, &BTreeMap::new());
        }
        let mut ctxs = BTreeMap::new();
        ctxs.insert(vm0, ctx());
        ctxs.insert(vm7, ctx());
        let mut cons = Consolidator::new(ConsolidationParams::default());
        let actions = scan_at(&mut cons, 1000.0, &c, &t, &ctxs);
        assert!(
            actions.contains(&ControlAction::Migrate { vm: vm0, to: HostId(7) }),
            "drain must not wait for a low-activity window: {actions:?}"
        );
    }

    #[test]
    fn marks_hot_hosts_restricted() {
        let (mut c, ctxs, _) = setup();
        c.host_mut(HostId(1)).demand.cpu = 29.0; // > 0.85
        let mut t = Telemetry::new(3, 1, 0.0);
        for k in 1..=5 {
            t.sample(k as f64 * 5.0, &c, &BTreeMap::new());
        }
        let mut cons = Consolidator::new(ConsolidationParams::default());
        scan_at(&mut cons, 1000.0, &c, &t, &ctxs);
        assert!(cons.restricted.contains(&HostId(1)));
    }

    #[test]
    fn aborts_evacuation_without_sla_safe_targets() {
        let (mut c, mut ctxs, t) = setup();
        // Make the donor's VM extremely contention-sensitive.
        let vm0 = *c.hosts[0].vms.first().unwrap();
        ctxs.get_mut(&vm0).unwrap().slack_left = 0.0;
        // And make the only target CPU-hot enough that any CPU use slows.
        c.host_mut(HostId(1)).demand.cpu = 31.0;
        ctxs.get_mut(&vm0).unwrap().vector.cpu = 0.9;
        let mut cons = Consolidator::new(ConsolidationParams::default());
        let actions = scan_at(&mut cons, 1000.0, &c, &t, &ctxs);
        assert!(
            !actions.iter().any(|a| matches!(a, ControlAction::Migrate { .. })),
            "{actions:?}"
        );
    }

    #[test]
    fn ignores_hosts_already_migrating() {
        let (mut c, ctxs, t) = setup();
        c.host_mut(HostId(0)).migration_net = 50.0;
        let mut cons = Consolidator::new(ConsolidationParams::default());
        let actions = scan_at(&mut cons, 1000.0, &c, &t, &ctxs);
        assert!(
            !actions.iter().any(|a| matches!(a, ControlAction::Migrate { .. })),
            "{actions:?}"
        );
    }

    /// Oracle-equivalent predictor that counts scoring invocations.
    struct CountingOracle {
        calls: u32,
    }

    impl crate::predict::EnergyPredictor for CountingOracle {
        fn name(&self) -> &'static str {
            "counting-oracle"
        }

        fn predict(&mut self, feats: &[[f32; crate::profile::FEAT_DIM]]) -> Vec<Prediction> {
            self.calls += 1;
            crate::predict::OraclePredictor.predict(feats)
        }

        fn predict_into(
            &mut self,
            feats: &[[f32; crate::profile::FEAT_DIM]],
            out: &mut Vec<Prediction>,
        ) {
            self.calls += 1;
            crate::predict::OraclePredictor.predict_into(feats, out);
        }
    }

    #[test]
    fn scan_issues_exactly_one_predictor_call() {
        // Donor with TWO VMs: the old path scored each VM separately
        // (one predictor call per donor VM); the batched scan must
        // score the whole (VM × target) matrix in ONE call.
        let (mut c, mut ctxs, _) = setup();
        let vm2 = c.create_vm(MEDIUM, JobId(2), 0.0);
        c.place_vm(vm2, HostId(0)).unwrap();
        ctxs.insert(vm2, ctx());
        let mut t = Telemetry::new(3, 1, 0.0);
        for k in 1..=5 {
            t.sample(k as f64 * 5.0, &c, &BTreeMap::new());
        }
        let mut cons = Consolidator::new(ConsolidationParams::default());
        let mut pred = CountingOracle { calls: 0 };
        let sctx = ScheduleContext::new(1000.0, &c)
            .with_telemetry(&t)
            .with_vm_ctx(&ctxs);
        let actions = cons.scan(&sctx, Some(&mut pred));
        let migrations = actions
            .iter()
            .filter(|a| matches!(a, ControlAction::Migrate { .. }))
            .count();
        assert_eq!(migrations, 2, "both donor VMs evacuate: {actions:?}");
        assert_eq!(pred.calls, 1, "one predictor call per scan");
    }

    #[test]
    fn batched_scan_matches_sequential_reference() {
        let (mut c, mut ctxs, _) = setup();
        let vm2 = c.create_vm(MEDIUM, JobId(2), 0.0);
        c.place_vm(vm2, HostId(0)).unwrap();
        ctxs.insert(vm2, ctx());
        let mut t = Telemetry::new(3, 1, 0.0);
        for k in 1..=5 {
            t.sample(k as f64 * 5.0, &c, &BTreeMap::new());
        }
        let sctx = ScheduleContext::new(1000.0, &c)
            .with_telemetry(&t)
            .with_vm_ctx(&ctxs);
        let mut batched = Consolidator::new(ConsolidationParams::default());
        let mut sequential = Consolidator::new(ConsolidationParams::default());
        let mut p1 = OraclePredictor;
        let mut p2 = OraclePredictor;
        assert_eq!(
            batched.scan(&sctx, Some(&mut p1)),
            sequential.scan_sequential(&sctx, &mut p2)
        );
    }

    #[test]
    fn cross_shard_fallback_driven_by_digests() {
        use crate::cluster::ShardedCluster;
        // 2 shards over 4 hosts: host 2 hashes alone into shard 0;
        // hosts 0, 1 and 3 into shard 1 (SplitMix64 of the ids). The
        // donor is the only member of its shard, so evacuation MUST
        // overflow into the remote shard the digests rank best.
        let mut c = Cluster::homogeneous(4);
        let donor_vm = c.create_vm(MEDIUM, JobId(0), 0.0);
        c.place_vm(donor_vm, HostId(2)).unwrap();
        let recv_vm = c.create_vm(MEDIUM, JobId(1), 0.0);
        c.place_vm(recv_vm, HostId(0)).unwrap();
        // Donor far below δ_low; receiver busy enough to not be a
        // donor itself but still SLA-safe as a target.
        c.host_mut(HostId(2)).demand = Demand {
            cpu: 1.5,
            mem_gb: 6.0,
            disk_mbps: 80.0,
            net_mbps: 20.0,
        };
        c.host_mut(HostId(0)).demand = Demand {
            cpu: 12.0,
            mem_gb: 12.0,
            disk_mbps: 100.0,
            net_mbps: 30.0,
        };
        let sc = ShardedCluster::new(c, 2);
        assert_eq!(sc.shard_of(HostId(2)), 0);
        assert_eq!(sc.shard_of(HostId(0)), 1);
        assert_eq!(sc.members(0), &[HostId(2)]);
        let mut ctxs = BTreeMap::new();
        ctxs.insert(donor_vm, ctx());
        ctxs.insert(recv_vm, ctx());
        let mut t = Telemetry::new(4, 1, 0.0);
        for k in 1..=5 {
            t.sample(k as f64 * 5.0, &sc, &BTreeMap::new());
        }
        let sctx = ScheduleContext::new(1000.0, &sc)
            .with_telemetry(&t)
            .with_vm_ctx(&ctxs)
            .with_shards(&sc);
        let mut cons = Consolidator::new(ConsolidationParams::default());
        let mut pred = OraclePredictor;
        let actions = cons.scan(&sctx, Some(&mut pred));
        assert!(
            actions.contains(&ControlAction::Migrate {
                vm: donor_vm,
                to: HostId(0)
            }),
            "expected a cross-shard evacuation: {actions:?}"
        );
        // With no cross-shard budget the donor cannot evacuate.
        let mut cons = Consolidator::new(ConsolidationParams {
            cross_shard_budget: 0,
            ..Default::default()
        });
        let mut pred = OraclePredictor;
        let actions = cons.scan(&sctx, Some(&mut pred));
        assert!(
            !actions
                .iter()
                .any(|a| matches!(a, ControlAction::Migrate { .. })),
            "budget 0 must suppress cross-shard moves: {actions:?}"
        );
    }

    #[test]
    fn plans_nothing_without_a_scoring_handle() {
        let (c, ctxs, t) = setup();
        let mut cons = Consolidator::new(ConsolidationParams::default());
        let sctx = ScheduleContext::new(5000.0, &c)
            .with_telemetry(&t)
            .with_vm_ctx(&ctxs);
        assert!(cons.scan(&sctx, None).is_empty());
        assert_eq!(cons.name(), "consolidation");
    }
}
