//! Best-fit placement: the host whose *remaining* capacity after the
//! placement is smallest (tightest pack). Energy-agnostic but
//! consolidation-friendly — the strongest non-learned baseline.

use crate::sched::policy::{Decision, PlacementPolicy, PlacementRequest};
use crate::sched::ScheduleContext;

#[derive(Debug, Default)]
pub struct BestFit;

impl PlacementPolicy for BestFit {
    fn name(&self) -> &'static str {
        "best_fit"
    }

    fn decide(&mut self, req: &PlacementRequest, ctx: &ScheduleContext<'_>) -> Decision {
        let cluster = ctx.cluster;
        let mut best: Option<(f64, crate::cluster::HostId)> = None;
        for host in &cluster.hosts {
            if !host.fits(&req.flavor, cluster.reserved(host.id)) {
                continue;
            }
            let r = cluster.reserved(host.id);
            let cap = host.spec.capacity();
            // Normalized leftover after placing (cpu + mem balance).
            let left_cpu = (cap.cpu * 1.5 - r.cpu - req.flavor.vcpus) / (cap.cpu * 1.5);
            let left_mem = (cap.mem_gb - r.mem_gb - req.flavor.mem_gb) / cap.mem_gb;
            let leftover = left_cpu + left_mem;
            if best.map(|(b, _)| leftover < b).unwrap_or(true) {
                best = Some((leftover, host.id));
            }
        }
        match best {
            Some((_, h)) => Decision::Place(h),
            None => Decision::Defer,
        }
    }

    fn wants_consolidation(&self) -> bool {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::flavor::MEDIUM;
    use crate::cluster::{Cluster, HostId};
    use crate::profile::ResourceVector;
    use crate::workload::JobId;

    fn req() -> PlacementRequest {
        PlacementRequest {
            job: JobId(0),
            flavor: MEDIUM,
            vector: ResourceVector::default(),
            remaining_solo: 100.0,
            avoid_rack: None,
        }
    }

    fn decide(p: &mut BestFit, req: &PlacementRequest, c: &Cluster) -> Decision {
        p.decide(req, &ScheduleContext::new(0.0, c))
    }

    #[test]
    fn prefers_tightest_host() {
        let mut c = Cluster::homogeneous(3);
        // Pre-load host 1 with two VMs, host 2 with one.
        for (h, n) in [(1usize, 2usize), (2, 1)] {
            for _ in 0..n {
                let vm = c.create_vm(MEDIUM, JobId(0), 0.0);
                c.place_vm(vm, HostId(h)).unwrap();
            }
        }
        let mut bf = BestFit;
        // Tightest = host 1 (least leftover after placement).
        assert_eq!(decide(&mut bf, &req(), &c), Decision::Place(HostId(1)));
    }

    #[test]
    fn falls_back_across_hosts_as_they_fill() {
        let mut c = Cluster::homogeneous(2);
        let mut bf = BestFit;
        let mut placements = Vec::new();
        for _ in 0..8 {
            match decide(&mut bf, &req(), &c) {
                Decision::Place(h) => {
                    let vm = c.create_vm(MEDIUM, JobId(0), 0.0);
                    c.place_vm(vm, h).unwrap();
                    placements.push(h.0);
                }
                other => panic!("unexpected {other:?}"),
            }
        }
        // 4 per host by memory; first host fills completely first.
        assert_eq!(placements, vec![0, 0, 0, 0, 1, 1, 1, 1]);
        assert_eq!(decide(&mut bf, &req(), &c), Decision::Defer);
    }
}
