//! Per-worker cached scoring state for the persistent
//! [`WorkerPool`]: one predictor clone plus the scoring arenas
//! (feature rows, candidates, spans, host views, predictions), kept
//! in the worker's [`WorkerSlot`] so they survive across
//! `decide_batch`, consolidation, DVFS, and power-cap fan-outs
//! instead of being rebuilt per call.
//!
//! # Epoch protocol
//!
//! The coordinator is the only writer and the only epoch-bumper:
//!
//! 1. Before a fan-out, [`stage_installs`] compares each
//!    participating worker's mirrored `(epoch, tag)`
//!    ([`WorkerPool::cached_state`]) against the live predictor's
//!    [`EnergyPredictor::weight_epoch`] and identity tag, and
//!    `try_clone`s a fresh copy **only** for stale workers — zero
//!    clones at steady state, one clone per worker after a
//!    `set_weights`/retrain. The tag (a hash of the engine name)
//!    exists because epochs alone cannot distinguish engines: the
//!    stateless default epoch 0 is shared by every oracle-like type,
//!    and a cache cut from one must never score for another.
//!    `weight_epoch` is read exactly once, here — the staged epoch is
//!    returned to the caller so the jobs and the mirror can never
//!    disagree about which epoch was staged.
//! 2. The first job dispatched to each such worker carries the fresh
//!    clone; [`WorkerScore::fetch`] installs it (jobs for one worker
//!    run FIFO, so the install always lands before any reuse).
//! 3. `fetch` asserts the cached epoch matches the fan-out's staged
//!    epoch — a stale clone can never score; a protocol violation
//!    fails the job loudly (poisoning the pool) instead of silently
//!    producing decisions from old parameters. Engine identity is
//!    enforced coordinator-side only (the mirror tag): clones are
//!    not required to preserve `name()` — a delegating wrapper may
//!    legitimately clone its inner engine.
//!
//! Both scoring fan-outs (the placement sweep and the consolidation
//! scan) share this one cache entry — they score through the same
//! policy predictor, so a retrain invalidates both with one epoch
//! bump and re-clones once per worker, not once per subsystem.

use crate::cluster::{HostId, HostView};
use crate::predict::{EnergyPredictor, Prediction};
use crate::profile::FEAT_DIM;
use crate::runtime::{WorkerPool, WorkerSlot};
use std::collections::BTreeMap;

/// A worker's persistent scoring state (see the module docs).
pub(crate) struct WorkerScore {
    epoch: u64,
    pub predictor: Box<dyn EnergyPredictor + Send>,
    /// Feature-row arena, shared by every scoring fan-out.
    pub feats: Vec<[f32; FEAT_DIM]>,
    /// Placement-sweep candidates with their amortized idle share and
    /// the same-rack (domain-diversity penalty) tag.
    pub cands: Vec<(HostId, f64, bool)>,
    /// Per-request `[start, end)` spans into `cands`/`feats`.
    pub spans: Vec<(usize, usize)>,
    /// Pruned host-view snapshots of this worker's shards.
    pub views: Vec<HostView>,
    /// Predictor output arena.
    pub preds: Vec<Prediction>,
}

impl WorkerScore {
    fn new(epoch: u64, predictor: Box<dyn EnergyPredictor + Send>) -> WorkerScore {
        WorkerScore {
            epoch,
            predictor,
            feats: Vec::new(),
            cands: Vec::new(),
            spans: Vec::new(),
            views: Vec::new(),
            preds: Vec::new(),
        }
    }

    /// Fetch this worker's cached scoring state, installing the
    /// staged predictor clone when the coordinator sent one (step 2
    /// of the epoch protocol). Panics — loudly poisoning the fan-out
    /// — if the cache would be stale, which the staging step makes
    /// unreachable.
    pub(crate) fn fetch(
        slot: &mut WorkerSlot,
        epoch: u64,
        install: Option<Box<dyn EnergyPredictor + Send>>,
    ) -> &mut WorkerScore {
        if let Some(fresh) = install {
            match slot.get_mut::<WorkerScore>() {
                Some(state) => {
                    state.predictor = fresh;
                    state.epoch = epoch;
                }
                None => slot.insert(WorkerScore::new(epoch, fresh)),
            }
        }
        let state = slot
            .get_mut::<WorkerScore>()
            .expect("coordinator stages a predictor clone before first use");
        assert_eq!(
            state.epoch, epoch,
            "stale predictor clone must never score against new weights"
        );
        state
    }
}

/// Identity tag of an engine for the pool's cache mirror: FNV-1a of
/// the engine name. Epochs disambiguate *weights over time* within
/// one engine; the tag disambiguates *engines* (the stateless default
/// epoch 0 is shared across types).
fn engine_tag(predictor: &dyn EnergyPredictor) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in predictor.name().bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// The output of [`stage_installs`]: the epoch everything in this
/// fan-out was staged at (the single `weight_epoch` read), plus one
/// fresh clone per stale worker, to be attached to the first job
/// dispatched to that worker.
pub(crate) struct StagedInstalls {
    pub epoch: u64,
    installs: BTreeMap<usize, Box<dyn EnergyPredictor + Send>>,
}

impl StagedInstalls {
    /// Take `worker`'s install, if one was staged (call when building
    /// that worker's first job of the dispatch).
    pub(crate) fn take(&mut self, worker: usize) -> Option<Box<dyn EnergyPredictor + Send>> {
        self.installs.remove(&worker)
    }

    /// Workers that were staged a fresh clone.
    #[cfg(test)]
    fn staged_workers(&self) -> Vec<usize> {
        self.installs.keys().copied().collect()
    }
}

/// Coordinator-side step 1 of the epoch protocol: for the affinity
/// workers of `keys` (shard indices), clone the predictor for every
/// worker whose mirrored `(epoch, tag)` is stale and record the new
/// state in the pool's mirror. Returns `None` when the predictor
/// cannot be cloned (callers fall back to their serial sweep; the
/// mirror is left untouched).
pub(crate) fn stage_installs(
    pool: &WorkerPool,
    keys: impl Iterator<Item = usize>,
    predictor: &dyn EnergyPredictor,
) -> Option<StagedInstalls> {
    let epoch = predictor.weight_epoch();
    let tag = engine_tag(predictor);
    let mut installs: BTreeMap<usize, Box<dyn EnergyPredictor + Send>> = BTreeMap::new();
    for key in keys {
        let worker = pool.worker_for(key);
        if pool.cached_state(worker) != Some((epoch, tag)) && !installs.contains_key(&worker) {
            installs.insert(worker, predictor.try_clone()?);
        }
    }
    // All clones succeeded — commit the mirror (the matching installs
    // ride along with this very dispatch, keeping mirror and worker
    // state consistent).
    for &worker in installs.keys() {
        pool.note_cached(worker, epoch, tag);
    }
    Some(StagedInstalls { epoch, installs })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::predict::{MlpWeights, NativeMlp, OraclePredictor};
    use std::collections::BTreeSet;

    fn affinity_workers(pool: &WorkerPool, keys: std::ops::Range<usize>) -> Vec<usize> {
        keys.map(|k| pool.worker_for(k))
            .collect::<BTreeSet<_>>()
            .into_iter()
            .collect()
    }

    #[test]
    fn stage_installs_clones_only_stale_workers() {
        let pool = WorkerPool::new(2);
        let mlp = NativeMlp::new(MlpWeights::init(3));
        let expected = affinity_workers(&pool, 0..4);
        let first = stage_installs(&pool, 0..4, &mlp).unwrap();
        assert_eq!(first.staged_workers(), expected);
        assert_eq!(first.epoch, mlp.weight_epoch());
        let second = stage_installs(&pool, 0..4, &mlp).unwrap();
        assert!(
            second.staged_workers().is_empty(),
            "cached workers must not re-clone"
        );
        // A weight change staleness-invalidates every worker.
        let mut mlp = mlp;
        mlp.set_weights(MlpWeights::init(4));
        let third = stage_installs(&pool, 0..4, &mlp).unwrap();
        assert_eq!(
            third.staged_workers(),
            expected,
            "one re-clone per worker per set_weights"
        );
    }

    #[test]
    fn equal_epochs_from_different_engines_do_not_share_caches() {
        // NativeMlp and the oracle can never collide (instance-unique
        // vs 0 epochs), but two stateless engine TYPES both report
        // epoch 0 — the identity tag must force a restage.
        let pool = WorkerPool::new(2);
        let oracle = OraclePredictor;
        assert_eq!(oracle.weight_epoch(), 0);
        let first = stage_installs(&pool, 0..4, &oracle).unwrap();
        assert!(!first.staged_workers().is_empty());
        // Same engine again: cache hit.
        assert!(stage_installs(&pool, 0..4, &oracle)
            .unwrap()
            .staged_workers()
            .is_empty());
        // A different engine type at the same epoch: NOT a hit.
        struct OtherOracle;
        impl EnergyPredictor for OtherOracle {
            fn name(&self) -> &'static str {
                "other-oracle"
            }
            fn predict(&mut self, feats: &[[f32; FEAT_DIM]]) -> Vec<Prediction> {
                OraclePredictor.predict(feats)
            }
            fn try_clone(&self) -> Option<Box<dyn EnergyPredictor + Send>> {
                Some(Box::new(OtherOracle))
            }
        }
        let other = OtherOracle;
        assert_eq!(other.weight_epoch(), 0, "same epoch as the oracle");
        let restaged = stage_installs(&pool, 0..4, &other).unwrap();
        assert_eq!(
            restaged.staged_workers(),
            affinity_workers(&pool, 0..4),
            "equal epoch but different engine must restage every worker"
        );
    }

    #[test]
    fn fetch_installs_then_reuses() {
        let pool = WorkerPool::new(2);
        let mlp = NativeMlp::new(MlpWeights::init(7));
        let mut staged = stage_installs(&pool, std::iter::once(0), &mlp).unwrap();
        let epoch = staged.epoch;
        let worker = pool.worker_for(0);
        // Two jobs on the same worker: the first carries the install,
        // the second reuses the cached state.
        let jobs: Vec<_> = (0..2)
            .map(|j| {
                let install = if j == 0 { staged.take(worker) } else { None };
                (0usize, move |slot: &mut WorkerSlot| {
                    WorkerScore::fetch(slot, epoch, install).predictor.name()
                })
            })
            .collect();
        let out = pool.dispatch(jobs).unwrap();
        assert_eq!(out, vec!["native-mlp", "native-mlp"]);
    }
}
