//! Unified periodic control loops (§III-C's adaptive consolidation
//! and DVFS, plus any future loop — e.g. carbon-aware capping).
//!
//! A [`ControlLoop`] observes the [`ScheduleContext`] on the
//! coordinator's scan cadence and emits [`ControlAction`]s; the
//! coordinator actuates them. Loops that score candidate placements
//! (consolidation's migration targets) borrow the placement policy's
//! prediction engine through an explicit [`ScoringHandle`] — the
//! replacement for the old `as_energy_aware()` downcast hack.

use crate::cluster::{HostId, VmId};
use crate::predict::EnergyPredictor;
use crate::sched::ScheduleContext;

/// One actuation a control loop requests from the coordinator.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ControlAction {
    /// Live-migrate a VM to a new host.
    Migrate { vm: VmId, to: HostId },
    /// Power a (necessarily empty) host down.
    PowerOff(HostId),
    /// Set a host's DVFS point.
    SetFreq { host: HostId, freq: f64 },
    /// Evict this host's expired warm serverless sandboxes (the
    /// keep-alive expiry loop). Actuation revalidates against the
    /// live clock, so the action is idempotent.
    ExpireContainers(HostId),
}

/// Borrowed access to the placement policy's prediction engine, lent
/// to control loops for the duration of one scan. Explicit and
/// object-safe: no `Any`-style downcasting anywhere in `sched`.
pub type ScoringHandle<'a> = &'a mut dyn EnergyPredictor;

/// A periodic datacenter control loop.
///
/// `scan` is pure planning — implementations must not assume their
/// actions are actuated (the coordinator re-validates each one
/// against live cluster state before applying it).
pub trait ControlLoop {
    fn name(&self) -> &'static str;

    /// One scan pass: observe the context, plan actions. `scoring` is
    /// the placement policy's predictor when it has one; loops that
    /// need predictions should plan nothing without it.
    fn scan(
        &mut self,
        ctx: &ScheduleContext<'_>,
        scoring: Option<ScoringHandle<'_>>,
    ) -> Vec<ControlAction>;

    /// A fresh instance carrying this loop's *configuration* but none
    /// of its scan-to-scan state (hysteresis clocks, imposed
    /// ceilings). The coordinator clones registered loops through
    /// this at the start of every campaign, so one
    /// `CampaignConfig` can drive many runs without state bleeding
    /// between them.
    fn box_clone(&self) -> Box<dyn ControlLoop>;
}
