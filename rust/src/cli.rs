//! Command-line argument parsing — `clap` is unavailable offline, so
//! this implements the subset the launcher needs: subcommands,
//! `--flag value` / `--flag=value` options, boolean switches, typed
//! accessors with defaults, and auto-generated usage text.

use std::collections::BTreeMap;

/// Parsed command line: a subcommand path, options, and positionals.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Vec<String>,
    opts: BTreeMap<String, String>,
    switches: Vec<String>,
    positionals: Vec<String>,
}

#[derive(Debug, Clone)]
pub struct ArgError(pub String);

impl std::fmt::Display for ArgError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "argument error: {}", self.0)
    }
}

impl std::error::Error for ArgError {}

impl Args {
    /// Parse raw args (without argv[0]). `n_subcommands` leading bare
    /// words are treated as the subcommand path; later bare words are
    /// positionals. Known boolean switch names must be listed so
    /// `--switch value` is not mis-parsed.
    pub fn parse(
        raw: &[String],
        n_subcommands: usize,
        known_switches: &[&str],
    ) -> Result<Args, ArgError> {
        let mut out = Args::default();
        let mut i = 0;
        while i < raw.len() {
            let a = &raw[i];
            if let Some(name) = a.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    out.opts.insert(k.to_string(), v.to_string());
                } else if known_switches.contains(&name) {
                    out.switches.push(name.to_string());
                } else if i + 1 < raw.len() && !raw[i + 1].starts_with("--") {
                    out.opts.insert(name.to_string(), raw[i + 1].clone());
                    i += 1;
                } else {
                    // Trailing bare --flag: treat as a switch.
                    out.switches.push(name.to_string());
                }
            } else if out.subcommand.len() < n_subcommands
                && out.opts.is_empty()
                && out.switches.is_empty()
                && out.positionals.is_empty()
            {
                out.subcommand.push(a.clone());
            } else {
                out.positionals.push(a.clone());
            }
            i += 1;
        }
        Ok(out)
    }

    pub fn from_env(n_subcommands: usize, known_switches: &[&str]) -> Result<Args, ArgError> {
        let raw: Vec<String> = std::env::args().skip(1).collect();
        Args::parse(&raw, n_subcommands, known_switches)
    }

    pub fn opt(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(String::as_str)
    }

    pub fn str_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.opt(name).unwrap_or(default)
    }

    pub fn f64_or(&self, name: &str, default: f64) -> Result<f64, ArgError> {
        match self.opt(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| ArgError(format!("--{name}: expected number, got '{v}'"))),
        }
    }

    pub fn usize_or(&self, name: &str, default: usize) -> Result<usize, ArgError> {
        match self.opt(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| ArgError(format!("--{name}: expected integer, got '{v}'"))),
        }
    }

    pub fn u64_or(&self, name: &str, default: u64) -> Result<u64, ArgError> {
        match self.opt(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| ArgError(format!("--{name}: expected integer, got '{v}'"))),
        }
    }

    pub fn switch(&self, name: &str) -> bool {
        self.switches.iter().any(|s| s == name)
    }

    pub fn positionals(&self) -> &[String] {
        &self.positionals
    }

    /// Comma-separated list option: `--seeds 1,2,3`.
    pub fn u64_list_or(&self, name: &str, default: &[u64]) -> Result<Vec<u64>, ArgError> {
        match self.opt(name) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|s| {
                    s.trim()
                        .parse()
                        .map_err(|_| ArgError(format!("--{name}: bad list element '{s}'")))
                })
                .collect(),
        }
    }
}

/// Usage text for the launcher.
pub const USAGE: &str = "\
ecosched — energy-aware, workload-profiling VM scheduler (paper reproduction)

USAGE:
    ecosched <COMMAND> [OPTIONS]

COMMANDS:
    run                 Run a scheduling campaign from a config file
                          --config <path>    config file (TOML subset)
                          --policy <name>    round_robin|first_fit|best_fit|energy_aware
                          --seed <n>         RNG seed (default 42)
                          --hours <h>        simulated campaign length (default 2)
    experiment <id>     Reproduce a paper table/figure:
                          fig1 fig2 fig3 fig4 table1 table2 table3 table4 table5
                          abl1 abl2 abl3 scale chaos all
                          --seeds 1,2,3      seeds to average (default 3 seeds)
                          --out <dir>        CSV output dir (default results/)
                          --artifacts <dir>  HLO artifacts dir (default artifacts/)
                          --fast             smaller campaign for smoke runs
    train               Train the energy predictor MLP via train_step.hlo
                          --epochs <n>       (default 60)
                          --samples <n>      history campaign size (default 4000)
                          --artifacts <dir>  HLO artifacts dir (default artifacts/)
    classify            Profile + classify a synthetic trace, print vectors
                          --jobs <n>         number of jobs (default 12)
    help                Show this help
";

#[cfg(test)]
mod tests {
    use super::*;

    fn v(args: &[&str]) -> Vec<String> {
        args.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_and_options() {
        let a = Args::parse(&v(&["experiment", "fig3", "--seed", "7"]), 2, &[]).unwrap();
        assert_eq!(a.subcommand, vec!["experiment", "fig3"]);
        assert_eq!(a.opt("seed"), Some("7"));
    }

    #[test]
    fn equals_form() {
        let a = Args::parse(&v(&["run", "--policy=best_fit"]), 1, &[]).unwrap();
        assert_eq!(a.str_or("policy", ""), "best_fit");
    }

    #[test]
    fn switches_do_not_eat_values() {
        let a = Args::parse(&v(&["run", "--fast", "pos1"]), 1, &["fast"]).unwrap();
        assert!(a.switch("fast"));
        assert_eq!(a.positionals(), &["pos1".to_string()]);
    }

    #[test]
    fn trailing_flag_is_switch() {
        let a = Args::parse(&v(&["run", "--verbose"]), 1, &[]).unwrap();
        assert!(a.switch("verbose"));
    }

    #[test]
    fn typed_accessors() {
        let a = Args::parse(&v(&["run", "--hours", "2.5", "--n", "10"]), 1, &[]).unwrap();
        assert_eq!(a.f64_or("hours", 0.0).unwrap(), 2.5);
        assert_eq!(a.usize_or("n", 0).unwrap(), 10);
        assert_eq!(a.f64_or("missing", 9.0).unwrap(), 9.0);
    }

    #[test]
    fn bad_number_is_error() {
        let a = Args::parse(&v(&["run", "--hours", "abc"]), 1, &[]).unwrap();
        assert!(a.f64_or("hours", 0.0).is_err());
    }

    #[test]
    fn u64_list() {
        let a = Args::parse(&v(&["x", "--seeds", "1,2,3"]), 1, &[]).unwrap();
        assert_eq!(a.u64_list_or("seeds", &[]).unwrap(), vec![1, 2, 3]);
        assert_eq!(a.u64_list_or("other", &[9]).unwrap(), vec![9]);
    }

    #[test]
    fn fewer_subcommands_than_allowed() {
        let a = Args::parse(&v(&["help"]), 2, &[]).unwrap();
        assert_eq!(a.subcommand, vec!["help"]);
    }
}
