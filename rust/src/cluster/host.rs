//! Physical hosts: capacity, utilization accounting, DVFS, and the
//! power-state machine. Host state `R_h = (U_cpu, U_mem, U_io)` (Eq. 3)
//! is derived here from the demands of resident VMs.

use crate::cluster::container::{Container, ContainerState, CONTAINER_BOOT_W};
use crate::cluster::power::{snap_to_pstate, PowerModel, PowerState, BOOT_SECS, SHUTDOWN_SECS};
use crate::cluster::vm::VmId;
use crate::cluster::Demand;
use crate::workload::faas::FunctionId;

/// Stable host identifier (dense index into the cluster).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct HostId(pub usize);

impl std::fmt::Display for HostId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "host-{}", self.0)
    }
}

/// Host hardware description — defaults match the paper's testbed node
/// (Intel Xeon, 64 GB RAM, SSD storage, 1 Gbps Ethernet).
#[derive(Debug, Clone, Copy)]
pub struct HostSpec {
    pub cpu_cores: f64,
    pub mem_gb: f64,
    /// SSD sequential bandwidth budget (MB/s).
    pub disk_mbps: f64,
    /// NIC budget (MB/s); 1 GbE ≈ 117 MB/s usable.
    pub net_mbps: f64,
    pub power: PowerModel,
}

impl HostSpec {
    pub fn paper_testbed() -> HostSpec {
        HostSpec {
            cpu_cores: 32.0,
            mem_gb: 64.0,
            disk_mbps: 1000.0,
            net_mbps: 117.0,
            power: crate::cluster::power::XEON_64GB,
        }
    }

    pub fn capacity(&self) -> Demand {
        Demand {
            cpu: self.cpu_cores,
            mem_gb: self.mem_gb,
            disk_mbps: self.disk_mbps,
            net_mbps: self.net_mbps,
        }
    }
}

/// Health condition of a host, orthogonal to [`PowerState`]: a
/// degraded host is still *on* and still runs its residents, but at
/// reduced capability. Placement admission refuses new VMs on a
/// degraded host, the consolidator drains it proactively, and the
/// DVFS governor respects its frequency ceiling. The condition is
/// mutated only through [`crate::cluster::ShardedCluster`]'s
/// degrade/restore handles so the shard digests stay in sync.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub enum HostCondition {
    #[default]
    Healthy,
    /// Failing storage: effective disk bandwidth halves.
    FlakyDisk,
    /// Thermal event: frequency capped at [`THERMAL_FREQ_CAP`].
    Thermal,
}

/// Frequency ceiling imposed by a thermal event (matches the 0.7
/// catalog p-state so the cap is always a legal DVFS point).
pub const THERMAL_FREQ_CAP: f64 = 0.7;

/// Disk-bandwidth multiplier under [`HostCondition::FlakyDisk`].
pub const FLAKY_DISK_FACTOR: f64 = 0.5;

/// Normalized utilization vector, each component in [0, 1] — the host
/// state R_h of Eq. 3 (we keep net separate rather than folding it into
/// io; the profiler exposes both).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct Utilization {
    pub cpu: f64,
    pub mem: f64,
    pub disk: f64,
    pub net: f64,
}

impl Utilization {
    /// Combined I/O utilization (disk+net, max-normalized) — the `U_io`
    /// the power model (Eq. 5) consumes.
    pub fn io(&self) -> f64 {
        self.disk.max(self.net)
    }
}

/// A physical host.
#[derive(Debug, Clone)]
pub struct Host {
    pub id: HostId,
    pub spec: HostSpec,
    pub state: PowerState,
    /// VMs currently placed here (including migration targets).
    pub vms: Vec<VmId>,
    /// Current DVFS point (relative frequency, one of `PSTATES`).
    pub freq: f64,
    /// Demand aggregated from resident VMs this tick (absolute units).
    pub demand: Demand,
    /// Extra network demand from in-flight migrations (MB/s).
    pub migration_net: f64,
    /// Cumulative count of power cycles (for reports).
    pub power_cycles: u32,
    /// Fault domain (rack) this host belongs to. Defaults to 0 until
    /// [`crate::cluster::ShardedCluster`] assigns the topology
    /// (shard index by default, or an explicit rack map).
    pub rack: usize,
    /// Health condition (degradation layer) — see [`HostCondition`].
    pub condition: HostCondition,
    /// Serverless sandbox slots (booting cold starts + warm pool).
    /// Empty unless the campaign runs the FaaS workload family.
    pub containers: Vec<Container>,
}

impl Host {
    pub fn new(id: HostId, spec: HostSpec) -> Host {
        Host {
            id,
            spec,
            state: PowerState::On,
            vms: Vec::new(),
            freq: 1.0,
            demand: Demand::ZERO,
            migration_net: 0.0,
            power_cycles: 0,
            rack: 0,
            condition: HostCondition::default(),
            containers: Vec::new(),
        }
    }

    /// Whether this host is in a degraded (but still running)
    /// condition. Degraded hosts refuse new placements and become
    /// preferred consolidation donors.
    pub fn is_degraded(&self) -> bool {
        self.condition != HostCondition::Healthy
    }

    /// Effective disk bandwidth (MB/s) under the current condition:
    /// a flaky disk delivers half its nominal budget.
    pub fn effective_disk(&self) -> f64 {
        match self.condition {
            HostCondition::FlakyDisk => self.spec.disk_mbps * FLAKY_DISK_FACTOR,
            _ => self.spec.disk_mbps,
        }
    }

    /// Frequency ceiling under the current condition: a thermal event
    /// caps the clock at [`THERMAL_FREQ_CAP`].
    pub fn freq_cap(&self) -> f64 {
        match self.condition {
            HostCondition::Thermal => THERMAL_FREQ_CAP,
            _ => 1.0,
        }
    }

    /// Normalized utilization from current demand, clamped to capacity.
    /// CPU capacity shrinks with DVFS (lower frequency = less work per
    /// second), which is how frequency scaling can *hurt* CPU-bound
    /// jobs but be free for I/O-bound ones.
    pub fn utilization(&self) -> Utilization {
        if !self.state.is_on() {
            return Utilization::default();
        }
        let cap = self.spec.capacity();
        let cpu_cap = cap.cpu * self.freq;
        Utilization {
            cpu: (self.demand.cpu / cpu_cap).min(1.0),
            // Parked/booting sandboxes hold memory even with no VM
            // demanding it — the energy cost of a warm pool.
            mem: ((self.demand.mem_gb + self.container_mem_gb()) / cap.mem_gb).min(1.0),
            disk: (self.demand.disk_mbps / self.effective_disk()).min(1.0),
            net: ((self.demand.net_mbps + self.migration_net) / cap.net_mbps).min(1.0),
        }
    }

    /// Per-dimension progress factors: when demand exceeds capacity the
    /// dimension is contended and work in it proceeds at cap/demand
    /// speed. Returns (cpu, mem, disk, net) factors in (0, 1].
    pub fn contention(&self) -> (f64, f64, f64, f64) {
        let cap = self.spec.capacity();
        let f = |demand: f64, capacity: f64| {
            if demand <= capacity || demand <= 0.0 {
                1.0
            } else {
                capacity / demand
            }
        };
        (
            f(self.demand.cpu, cap.cpu * self.freq),
            f(self.demand.mem_gb, cap.mem_gb),
            f(self.demand.disk_mbps, self.effective_disk()),
            f(self.demand.net_mbps + self.migration_net, cap.net_mbps),
        )
    }

    /// Instantaneous power draw (W) — Eq. 5 through the state machine,
    /// plus the boot draw of any container cold starts in flight.
    pub fn power(&self) -> f64 {
        let u = self.utilization();
        let base = self.state.power(&self.spec.power, || {
            self.spec
                .power
                .active_power(u.cpu, u.mem, u.io(), self.freq)
        });
        if self.state.is_on() {
            base + CONTAINER_BOOT_W * self.booting_count() as f64
        } else {
            base
        }
    }

    /// Amortized share of the idle power floor a new tenant on this
    /// host would carry: an empty host charges the full `P_idle` to
    /// its first tenant, a busy host's floor is already paid for. The
    /// single definition behind the energy objective of both the
    /// placement argmin (via the [`crate::cluster::HostView`]
    /// snapshot) and the consolidation target selection.
    pub fn idle_share(&self) -> f64 {
        self.spec.power.p_idle / (self.vms.len() as f64 + 1.0)
    }

    /// Free capacity in absolute units (for feasibility checks).
    /// Memory is a hard constraint; cpu/io can be oversubscribed but we
    /// report headroom against nominal capacity.
    pub fn free(&self) -> Demand {
        let cap = self.spec.capacity();
        Demand {
            cpu: (cap.cpu - self.demand.cpu).max(0.0),
            mem_gb: (cap.mem_gb - self.demand.mem_gb).max(0.0),
            disk_mbps: (cap.disk_mbps - self.demand.disk_mbps).max(0.0),
            net_mbps: (cap.net_mbps - self.demand.net_mbps).max(0.0),
        }
    }

    /// Would a VM of this flavor fit under the memory hard-constraint
    /// and a CPU oversubscription cap?
    pub fn fits(&self, flavor: &crate::cluster::flavor::Flavor, reserved: &Demand) -> bool {
        self.state.accepts_vms()
            && !self.is_degraded()
            && admission_fits(&self.spec.capacity(), reserved, flavor)
    }

    /// Begin booting the host at `now`; no-op unless powered off.
    pub fn power_on(&mut self, now: f64) {
        if self.state.is_off() {
            self.state = PowerState::Booting {
                until: now + BOOT_SECS,
            };
            self.power_cycles += 1;
        }
    }

    /// Begin shutting down at `now`; only legal with no resident VMs.
    /// Any parked sandboxes die with the host (caller keeps the shard
    /// digest in sync via [`Host::warm_count`] taken beforehand).
    pub fn power_off(&mut self, now: f64) {
        assert!(
            self.vms.is_empty(),
            "power_off with {} resident VMs",
            self.vms.len()
        );
        if self.state.is_on() {
            self.state = PowerState::ShuttingDown {
                until: now + SHUTDOWN_SECS,
            };
            self.containers.clear();
        }
    }

    /// Crash the host at `now`: resident VMs are gone (the caller —
    /// [`crate::cluster::Cluster::fail_host`] — settles their records
    /// and reservations first), the warm pool dies with the kernel,
    /// and the host draws BMC power until an explicit [`Host::recover`].
    /// Only an `On` host can crash; transitioning or off hosts are
    /// already dark.
    pub fn fail(&mut self, _now: f64) {
        assert!(self.state.is_on(), "fail on a host that is not On");
        assert!(
            self.vms.is_empty(),
            "fail with {} unsettled resident VMs",
            self.vms.len()
        );
        self.state = PowerState::Failed;
        self.containers.clear();
        self.demand = Demand::ZERO;
        self.migration_net = 0.0;
    }

    /// Recover a crashed host at `now`: it reboots through the normal
    /// boot window (and pays the boot transient) before accepting
    /// placements again. No-op unless the host is `Failed`.
    pub fn recover(&mut self, now: f64) {
        if self.state.is_failed() {
            self.state = PowerState::Booting {
                until: now + BOOT_SECS,
            };
            self.freq = 1.0;
            self.power_cycles += 1;
        }
    }

    /// Set the DVFS point to the nearest catalog p-state, clamped to
    /// the condition's frequency ceiling (a thermal event wins over
    /// any governor request to clock back up).
    pub fn set_freq(&mut self, target: f64) {
        self.freq = snap_to_pstate(target.min(self.freq_cap()));
    }

    // --- serverless sandbox slots -------------------------------------

    /// Claim (remove) a warm sandbox for `function`, if one exists.
    pub fn claim_warm(&mut self, function: FunctionId) -> bool {
        let hit = self
            .containers
            .iter()
            .position(|c| c.is_warm() && c.function == function);
        match hit {
            Some(i) => {
                self.containers.remove(i);
                true
            }
            None => false,
        }
    }

    /// Install a sandbox cold-starting until `until`.
    pub fn install_booting(&mut self, function: FunctionId, mem_gb: f64, until: f64) {
        self.containers.push(Container {
            function,
            mem_gb,
            state: ContainerState::Booting { until },
        });
    }

    /// Park a sandbox warm until its keep-alive window `expires_at`.
    pub fn park_warm(&mut self, function: FunctionId, mem_gb: f64, expires_at: f64) {
        self.containers.push(Container {
            function,
            mem_gb,
            state: ContainerState::Warm { expires_at },
        });
    }

    /// Drop warm sandboxes whose keep-alive window has passed; returns
    /// how many were removed. Idempotent — safe to re-run on a stale
    /// scan result.
    pub fn expire_warm(&mut self, now: f64) -> usize {
        let before = self.containers.len();
        self.containers
            .retain(|c| !matches!(c.state, ContainerState::Warm { expires_at } if expires_at <= now));
        before - self.containers.len()
    }

    /// Retire cold starts whose boot window has completed — the
    /// invocation's VM accounts for the sandbox from here on.
    pub fn advance_containers(&mut self, now: f64) {
        self.containers
            .retain(|c| !matches!(c.state, ContainerState::Booting { until } if now >= until));
    }

    /// Any warm sandbox past its keep-alive expiry?
    pub fn has_expired_warm(&self, now: f64) -> bool {
        self.containers
            .iter()
            .any(|c| matches!(c.state, ContainerState::Warm { expires_at } if expires_at <= now))
    }

    pub fn warm_count(&self) -> usize {
        self.containers.iter().filter(|c| c.is_warm()).count()
    }

    pub fn booting_count(&self) -> usize {
        self.containers.iter().filter(|c| c.is_booting()).count()
    }

    /// Memory held by sandboxes (GB), warm and booting alike.
    pub fn container_mem_gb(&self) -> f64 {
        self.containers.iter().map(|c| c.mem_gb).sum()
    }
}

/// Admission arithmetic shared by [`Host::fits`] and the snapshot
/// [`crate::cluster::HostView::fits`] used on the batched scoring
/// path. Memory never oversubscribes (KVM ballooning is off in the
/// paper's setup); CPU allows 1.5× oversubscription like the
/// OpenStack default `cpu_allocation_ratio`. One function so the two
/// paths can never disagree on a borderline placement.
pub fn admission_fits(
    cap: &Demand,
    reserved: &Demand,
    flavor: &crate::cluster::flavor::Flavor,
) -> bool {
    let mem_ok = reserved.mem_gb + flavor.mem_gb <= cap.mem_gb + 1e-9;
    let cpu_ok = reserved.cpu + flavor.vcpus <= cap.cpu * 1.5 + 1e-9;
    mem_ok && cpu_ok
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::flavor::{LARGE, MEDIUM};

    fn host() -> Host {
        Host::new(HostId(0), HostSpec::paper_testbed())
    }

    #[test]
    fn utilization_tracks_demand() {
        let mut h = host();
        h.demand = Demand {
            cpu: 16.0,
            mem_gb: 32.0,
            disk_mbps: 500.0,
            net_mbps: 58.5,
        };
        let u = h.utilization();
        assert!((u.cpu - 0.5).abs() < 1e-9);
        assert!((u.mem - 0.5).abs() < 1e-9);
        assert!((u.disk - 0.5).abs() < 1e-9);
        assert!((u.net - 0.5).abs() < 1e-9);
        assert!((u.io() - 0.5).abs() < 1e-9);
    }

    #[test]
    fn utilization_clamps_at_one() {
        let mut h = host();
        h.demand = Demand {
            cpu: 100.0,
            mem_gb: 100.0,
            disk_mbps: 9999.0,
            net_mbps: 9999.0,
        };
        let u = h.utilization();
        assert_eq!(u.cpu, 1.0);
        assert_eq!(u.mem, 1.0);
        assert_eq!(u.io(), 1.0);
    }

    #[test]
    fn powered_off_host_shows_zero_utilization_and_bmc_power() {
        let mut h = host();
        h.demand.cpu = 10.0;
        h.state = PowerState::Off;
        assert_eq!(h.utilization(), Utilization::default());
        assert_eq!(h.power(), h.spec.power.p_off);
    }

    #[test]
    fn contention_slows_oversubscribed_dimension() {
        let mut h = host();
        h.demand = Demand {
            cpu: 64.0, // 2× capacity
            mem_gb: 10.0,
            disk_mbps: 100.0,
            net_mbps: 10.0,
        };
        let (c, m, d, n) = h.contention();
        assert!((c - 0.5).abs() < 1e-9);
        assert_eq!((m, d, n), (1.0, 1.0, 1.0));
    }

    #[test]
    fn dvfs_shrinks_cpu_capacity() {
        let mut h = host();
        h.demand.cpu = 16.0;
        h.set_freq(0.6);
        assert_eq!(h.freq, 0.6);
        // 16 cores of demand against 32*0.6=19.2 effective cores.
        assert!((h.utilization().cpu - 16.0 / 19.2).abs() < 1e-9);
    }

    #[test]
    fn set_freq_snaps_to_pstate() {
        let mut h = host();
        h.set_freq(0.78);
        assert_eq!(h.freq, 0.85); // nearest of {1.0, 0.85, 0.7, 0.6}
        h.set_freq(0.1);
        assert_eq!(h.freq, 0.6);
    }

    #[test]
    fn fits_enforces_memory_hard_cap() {
        let h = host();
        let reserved = Demand {
            cpu: 0.0,
            mem_gb: 40.0,
            disk_mbps: 0.0,
            net_mbps: 0.0,
        };
        assert!(!h.fits(&LARGE, &reserved)); // 40+32 > 64
        assert!(h.fits(&MEDIUM, &reserved)); // 40+16 <= 64
    }

    #[test]
    fn fits_allows_cpu_oversubscription_to_1_5x() {
        let h = host();
        let reserved = Demand {
            cpu: 40.0,
            mem_gb: 0.0,
            disk_mbps: 0.0,
            net_mbps: 0.0,
        };
        assert!(h.fits(&MEDIUM, &reserved)); // 40+8 <= 48
        let reserved = Demand {
            cpu: 44.0,
            ..reserved
        };
        assert!(!h.fits(&MEDIUM, &reserved)); // 44+8 > 48
    }

    #[test]
    fn power_cycle_bookkeeping() {
        let mut h = host();
        h.power_off(0.0);
        assert!(matches!(h.state, PowerState::ShuttingDown { .. }));
        h.state = h.state.advance(SHUTDOWN_SECS);
        assert!(h.state.is_off());
        h.power_on(100.0);
        assert_eq!(h.power_cycles, 1);
        assert!(matches!(h.state, PowerState::Booting { .. }));
        assert!(!h.state.accepts_vms());
        h.state = h.state.advance(100.0 + BOOT_SECS);
        assert!(h.state.is_on());
    }

    #[test]
    #[should_panic(expected = "resident VMs")]
    fn power_off_with_vms_panics() {
        let mut h = host();
        h.vms.push(VmId(1));
        h.power_off(0.0);
    }

    #[test]
    fn fail_then_recover_pays_a_full_boot() {
        let mut h = host();
        h.park_warm(FunctionId(3), 0.5, 1e9);
        h.demand.cpu = 4.0;
        h.fail(10.0);
        assert!(h.state.is_failed());
        assert!(h.containers.is_empty());
        assert_eq!(h.demand, Demand::ZERO);
        assert_eq!(h.power(), h.spec.power.p_off);
        assert_eq!(h.utilization(), Utilization::default());
        // power_on is for Off hosts only — a crashed host stays dark.
        h.power_on(20.0);
        assert!(h.state.is_failed());
        h.recover(20.0);
        assert_eq!(h.power_cycles, 1);
        assert!(matches!(h.state, PowerState::Booting { .. }));
        h.state = h.state.advance(20.0 + BOOT_SECS);
        assert!(h.state.is_on());
    }

    #[test]
    fn migration_traffic_counts_toward_net() {
        let mut h = host();
        h.migration_net = 58.5;
        assert!((h.utilization().net - 0.5).abs() < 1e-9);
    }

    #[test]
    fn warm_claim_hits_only_matching_function() {
        let mut h = host();
        h.park_warm(FunctionId(1), 0.5, 100.0);
        assert!(!h.claim_warm(FunctionId(2)));
        assert!(h.claim_warm(FunctionId(1)));
        assert!(!h.claim_warm(FunctionId(1))); // pool drained
        assert_eq!(h.warm_count(), 0);
    }

    #[test]
    fn booting_container_draws_extra_power_and_holds_memory() {
        let mut h = host();
        let idle = h.power();
        h.install_booting(FunctionId(0), 1.0, 2.0);
        assert!((h.power() - idle - CONTAINER_BOOT_W) > 0.0);
        assert!(h.utilization().mem > 0.0);
        // Boot completes: sandbox handed to the VM, draw stops.
        h.advance_containers(2.0);
        assert_eq!(h.booting_count(), 0);
        assert!((h.power() - idle).abs() < CONTAINER_BOOT_W);
    }

    #[test]
    fn expire_warm_is_idempotent_and_time_gated() {
        let mut h = host();
        h.park_warm(FunctionId(1), 0.25, 50.0);
        h.park_warm(FunctionId(2), 0.25, 80.0);
        assert!(!h.has_expired_warm(40.0));
        assert_eq!(h.expire_warm(40.0), 0);
        assert!(h.has_expired_warm(60.0));
        assert_eq!(h.expire_warm(60.0), 1);
        assert_eq!(h.expire_warm(60.0), 0);
        assert_eq!(h.warm_count(), 1);
    }

    #[test]
    fn flaky_disk_degrade_halves_effective_disk() {
        let mut h = host();
        h.demand.disk_mbps = 400.0;
        assert!((h.utilization().disk - 0.4).abs() < 1e-9);
        h.condition = HostCondition::FlakyDisk;
        assert!(h.is_degraded());
        assert_eq!(h.effective_disk(), 500.0);
        assert!((h.utilization().disk - 0.8).abs() < 1e-9);
        // Contention kicks in once demand exceeds the halved budget.
        h.demand.disk_mbps = 800.0;
        let (_, _, d, _) = h.contention();
        assert!((d - 500.0 / 800.0).abs() < 1e-9);
    }

    #[test]
    fn thermal_degrade_caps_frequency() {
        let mut h = host();
        h.condition = HostCondition::Thermal;
        assert_eq!(h.freq_cap(), THERMAL_FREQ_CAP);
        // A governor request to run at full clock is clamped.
        h.set_freq(1.0);
        assert_eq!(h.freq, 0.7);
        h.set_freq(0.6);
        assert_eq!(h.freq, 0.6);
    }

    #[test]
    fn degraded_host_refuses_new_placements() {
        let mut h = host();
        assert!(h.fits(&MEDIUM, &Demand::ZERO));
        h.condition = HostCondition::FlakyDisk;
        assert!(!h.fits(&MEDIUM, &Demand::ZERO));
        h.condition = HostCondition::Healthy;
        assert!(h.fits(&MEDIUM, &Demand::ZERO));
    }

    #[test]
    fn power_off_drops_the_warm_pool() {
        let mut h = host();
        h.park_warm(FunctionId(9), 0.5, 1e9);
        h.power_off(0.0);
        assert!(h.containers.is_empty());
    }
}
