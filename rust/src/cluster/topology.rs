//! Fault-domain topology: the host → rack map behind correlated
//! failures (`sim::FaultKind::RackCrash`) and the domain-diversity
//! term in evacuation scoring.
//!
//! Default topology = the shard map: shards already partition the
//! fleet deterministically from `(host id, shard_count)`, so rack
//! faults are meaningful out of the box without extra configuration.
//! An explicit map (`CampaignConfig::rack_map`) overrides it —
//! validated to cover every host with dense rack indices.

use crate::cluster::host::HostId;
use crate::cluster::shard::ShardMap;

/// Host → rack assignment plus the inverse (rack → member hosts).
#[derive(Debug, Clone)]
pub struct Topology {
    /// `rack_of[h]` = rack index of host `h`. Dense in `0..n_racks`.
    rack_of: Vec<usize>,
    /// `members[r]` = hosts in rack `r`, ascending by id.
    members: Vec<Vec<HostId>>,
}

impl Topology {
    /// The default topology: one rack per shard, membership from the
    /// shard map's hash assignment.
    pub fn from_shards(map: &ShardMap, n_hosts: usize) -> Topology {
        let rack_of: Vec<usize> = (0..n_hosts).map(|h| map.shard_of(HostId(h))).collect();
        Topology::from_assignment(rack_of, map.count())
    }

    /// An explicit host → rack map. Errors when a rack index is out of
    /// range or a rack in `0..n_racks` has no members (sparse indices
    /// would silently shrink the fault domain set).
    pub fn from_map(rack_of: Vec<usize>) -> Result<Topology, String> {
        if rack_of.is_empty() {
            return Err("rack map must cover at least one host".to_string());
        }
        let n_racks = rack_of.iter().max().copied().unwrap_or(0) + 1;
        let topo = Topology::from_assignment(rack_of, n_racks);
        for (r, members) in topo.members.iter().enumerate() {
            if members.is_empty() {
                return Err(format!("rack {r} has no member hosts (sparse rack indices)"));
            }
        }
        Ok(topo)
    }

    fn from_assignment(rack_of: Vec<usize>, n_racks: usize) -> Topology {
        let mut members = vec![Vec::new(); n_racks];
        for (h, &r) in rack_of.iter().enumerate() {
            members[r].push(HostId(h));
        }
        Topology { rack_of, members }
    }

    pub fn n_racks(&self) -> usize {
        self.members.len()
    }

    pub fn n_hosts(&self) -> usize {
        self.rack_of.len()
    }

    pub fn rack_of(&self, host: HostId) -> usize {
        self.rack_of[host.0]
    }

    /// Member hosts of `rack`, ascending by host id.
    pub fn members(&self, rack: usize) -> &[HostId] {
        &self.members[rack]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shard_topology_partitions_every_host() {
        let map = ShardMap::new(4);
        let topo = Topology::from_shards(&map, 16);
        assert_eq!(topo.n_racks(), 4);
        assert_eq!(topo.n_hosts(), 16);
        let total: usize = (0..topo.n_racks()).map(|r| topo.members(r).len()).sum();
        assert_eq!(total, 16);
        for h in 0..16 {
            let r = topo.rack_of(HostId(h));
            assert!(topo.members(r).contains(&HostId(h)));
            assert_eq!(r, map.shard_of(HostId(h)));
        }
    }

    #[test]
    fn explicit_map_roundtrips_and_sorts_members() {
        let topo = Topology::from_map(vec![1, 0, 1, 0, 1]).unwrap();
        assert_eq!(topo.n_racks(), 2);
        assert_eq!(topo.members(0), &[HostId(1), HostId(3)]);
        assert_eq!(topo.members(1), &[HostId(0), HostId(2), HostId(4)]);
    }

    #[test]
    fn sparse_rack_indices_are_rejected() {
        assert!(Topology::from_map(vec![0, 2]).is_err());
        assert!(Topology::from_map(Vec::new()).is_err());
        assert!(Topology::from_map(vec![0, 1, 0]).is_ok());
    }
}
