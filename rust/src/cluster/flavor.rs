//! VM flavors — OpenStack-style instance sizes. The paper provisions
//! big-data workers as VMs on five Xeon hosts; flavors bound how much of
//! a host one VM may demand and drive bin-packing granularity.

/// A VM size class: maximum resources the VM may consume.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Flavor {
    pub name: &'static str,
    /// Virtual CPU cores.
    pub vcpus: f64,
    /// Memory in GiB.
    pub mem_gb: f64,
    /// Provisioned disk bandwidth (MB/s) — SSD share.
    pub disk_mbps: f64,
    /// Provisioned network bandwidth (MB/s) — 1 GbE share.
    pub net_mbps: f64,
}

/// The flavor catalog used across experiments, sized so the paper's
/// host (32 vCPU / 64 GB) fits a small number of workers — matching the
/// testbed where each host runs a handful of Hadoop/Spark executors.
pub const SMALL: Flavor = Flavor {
    name: "small",
    vcpus: 4.0,
    mem_gb: 8.0,
    disk_mbps: 120.0,
    net_mbps: 30.0,
};

pub const MEDIUM: Flavor = Flavor {
    name: "medium",
    vcpus: 8.0,
    mem_gb: 16.0,
    disk_mbps: 200.0,
    net_mbps: 60.0,
};

pub const LARGE: Flavor = Flavor {
    name: "large",
    vcpus: 16.0,
    mem_gb: 32.0,
    disk_mbps: 350.0,
    net_mbps: 90.0,
};

/// Serverless function sandbox size — one vCPU, Lambda-style memory
/// cap. Deliberately *not* in [`CATALOG`]: the catalog is the VM
/// bin-packing menu for the batch families; FaaS invocations always
/// use exactly this slot via `workload::flavor_for`.
pub const FAAS: Flavor = Flavor {
    name: "faas",
    vcpus: 1.0,
    mem_gb: 1.0,
    disk_mbps: 20.0,
    net_mbps: 10.0,
};

pub const CATALOG: [Flavor; 3] = [SMALL, MEDIUM, LARGE];

impl Flavor {
    pub fn by_name(name: &str) -> Option<Flavor> {
        if name == FAAS.name {
            return Some(FAAS);
        }
        CATALOG.iter().copied().find(|f| f.name == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn catalog_lookup() {
        assert_eq!(Flavor::by_name("medium").unwrap().vcpus, 8.0);
        assert_eq!(Flavor::by_name("faas").unwrap().vcpus, 1.0);
        assert!(Flavor::by_name("xxl").is_none());
    }

    #[test]
    fn faas_slot_packs_densely() {
        // A 32-core/64 GB host should fit dozens of function slots —
        // the point of the serverless family is high invocation rates.
        assert!(32.0 / FAAS.vcpus >= 32.0);
        assert!(64.0 / FAAS.mem_gb >= 64.0);
        assert!(!CATALOG.iter().any(|f| f.name == FAAS.name));
    }

    #[test]
    fn flavors_fit_paper_host() {
        // The paper's host: 32 vCPU, 64 GB. Every flavor must fit, and
        // smalls must pack at least 8 per host (bin-packing headroom).
        for f in CATALOG {
            assert!(f.vcpus <= 32.0 && f.mem_gb <= 64.0, "{} too big", f.name);
        }
        assert!(32.0 / SMALL.vcpus >= 8.0);
    }

    #[test]
    fn sizes_are_ordered() {
        assert!(SMALL.vcpus < MEDIUM.vcpus && MEDIUM.vcpus < LARGE.vcpus);
        assert!(SMALL.mem_gb < MEDIUM.mem_gb && MEDIUM.mem_gb < LARGE.mem_gb);
    }
}
