//! Virtual machines: the placement unit. A VM hosts exactly one job's
//! worker set in our model (the paper provisions per-job worker VMs via
//! OpenStack); its resource demand at any instant comes from the
//! workload model of the job it runs.

use crate::cluster::flavor::Flavor;
use crate::cluster::HostId;
use crate::workload::JobId;

/// Stable VM identifier.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct VmId(pub u64);

impl std::fmt::Display for VmId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "vm-{}", self.0)
    }
}

/// VM lifecycle.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum VmState {
    /// Created, waiting for a placement decision.
    Pending,
    /// Running on a host.
    Running,
    /// Live-migrating: still consuming on `from`, plus migration
    /// network traffic on both ends, until `done` (sim time).
    Migrating { from: HostId, to: HostId, done: f64 },
    /// Job finished; VM released.
    Terminated,
}

/// A virtual machine.
#[derive(Debug, Clone)]
pub struct Vm {
    pub id: VmId,
    pub flavor: Flavor,
    pub job: JobId,
    /// Current host (target host while migrating).
    pub host: Option<HostId>,
    pub state: VmState,
    /// Simulation time of creation (for age-based policies).
    pub created_at: f64,
    /// Count of completed migrations (overhead accounting, §V-E).
    pub migrations: u32,
    /// Profiled mean demand of the hosted job (absolute units) — the
    /// workload-aware load estimate schedulers use instead of the
    /// instantaneous demand, which phases swing around it. Write
    /// access is restricted to the `cluster` module so
    /// [`crate::cluster::Cluster::set_expected_demand`] stays the only
    /// writer — a direct write would desynchronize the incremental
    /// expected-load cache.
    pub(in crate::cluster) expected: crate::cluster::Demand,
}

impl Vm {
    pub fn new(id: VmId, flavor: Flavor, job: JobId, now: f64) -> Vm {
        Vm {
            id,
            flavor,
            job,
            host: None,
            state: VmState::Pending,
            created_at: now,
            migrations: 0,
            expected: crate::cluster::Demand::ZERO,
        }
    }

    pub fn is_active(&self) -> bool {
        matches!(self.state, VmState::Running | VmState::Migrating { .. })
    }

    /// Profiled mean demand (read-only; updates go through
    /// [`crate::cluster::Cluster::set_expected_demand`]).
    pub fn expected(&self) -> crate::cluster::Demand {
        self.expected
    }
}

/// Live-migration cost model. The paper schedules migrations in
/// low-activity windows and reports the overhead as "negligible,
/// absorbed during low-activity periods" (§V-E); we still charge the
/// real costs so that claim is *measured*:
/// * duration = VM memory / available network bandwidth (pre-copy),
/// * a brief stop-and-copy stall that pauses job progress,
/// * network demand on source and destination during the copy.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MigrationCost {
    /// Total pre-copy duration (s).
    pub duration: f64,
    /// Stop-and-copy stall (s) — job makes no progress.
    pub stall: f64,
    /// Extra network demand during copy (MB/s) on both hosts.
    pub net_mbps: f64,
}

/// Compute migration cost for a VM with `mem_gb` of (touched) memory
/// over a link with `link_mbps` available.
pub fn migration_cost(mem_gb: f64, link_mbps: f64) -> MigrationCost {
    // Live migration is rate-limited to 40 MB/s (a typical
    // libvirt migrate-setspeed throttle on 1 GbE) so the copy never
    // starves co-located shuffle traffic.
    let link = link_mbps.max(10.0).min(40.0);
    // Pre-copy moves ~1.3× memory (dirty-page re-copy rounds).
    let duration = mem_gb * 1024.0 * 1.3 / link;
    MigrationCost {
        duration,
        // Final stop-and-copy: last dirty set, ~1 % of memory.
        stall: (mem_gb * 1024.0 * 0.01 / link).max(0.2),
        net_mbps: link,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::flavor::MEDIUM;

    #[test]
    fn lifecycle_flags() {
        let mut vm = Vm::new(VmId(1), MEDIUM, JobId(9), 0.0);
        assert!(!vm.is_active());
        vm.state = VmState::Running;
        assert!(vm.is_active());
        vm.state = VmState::Migrating {
            from: HostId(0),
            to: HostId(1),
            done: 5.0,
        };
        assert!(vm.is_active());
        vm.state = VmState::Terminated;
        assert!(!vm.is_active());
    }

    #[test]
    fn migration_cost_scales_with_memory() {
        let small = migration_cost(8.0, 100.0);
        let big = migration_cost(32.0, 100.0);
        assert!(big.duration > 3.9 * small.duration);
        assert!(small.stall >= 0.2);
    }

    #[test]
    fn migration_duration_reasonable_for_paper_testbed() {
        // 16 GB VM over an otherwise-idle 1 GbE (~110 MB/s usable):
        // should take minutes, not hours, not milliseconds.
        let c = migration_cost(16.0, 110.0);
        assert!(
            (60.0..600.0).contains(&c.duration),
            "duration {}",
            c.duration
        );
        assert!(c.net_mbps <= 80.0);
    }

    #[test]
    fn migration_cost_degrades_gracefully_on_congested_link() {
        let c = migration_cost(8.0, 0.0); // fully congested link
        assert!(c.duration.is_finite() && c.duration > 0.0);
    }

    #[test]
    fn display_format() {
        assert_eq!(VmId(7).to_string(), "vm-7");
    }
}
