//! Per-host scoring views: the pruned, flat snapshot the batched
//! placement path iterates instead of the live `Host` objects.
//!
//! `EnergyAware::decide_batch` used to recompute effective utilization
//! (max of instantaneous and profiled load) for every (request, host)
//! pair — and `Cluster::expected_load` itself walked the whole VM
//! inventory per host, making a burst of R requests over H hosts an
//! O(R·H·V) scan. With the incrementally-maintained expected-load
//! cache (see `cluster::mod`) a view build is O(H), done **once per
//! frozen decision context**; hot hosts (Eq. 9, above `delta_high`)
//! and non-accepting hosts are pruned here, so each request only
//! touches the surviving shortlist.

use crate::cluster::flavor::Flavor;
use crate::cluster::host::admission_fits;
use crate::cluster::{Cluster, Demand, HostId, Utilization};

/// One host's placement-relevant state, snapshotted at view-build
/// time. `Copy` so policies can keep a scratch `Vec<HostView>` and
/// iterate it while mutating their other buffers.
#[derive(Debug, Clone, Copy)]
pub struct HostView {
    pub id: HostId,
    /// Effective utilization: componentwise max of instantaneous and
    /// profiled expected load — a host whose ETL tenants are between
    /// I/O bursts is *not* free capacity.
    pub util: Utilization,
    pub n_vms: usize,
    pub freq: f64,
    /// Amortized idle-floor share a new tenant would carry
    /// (snapshotted from [`crate::cluster::Host::idle_share`]).
    pub idle_share: f64,
    /// Flavor-based reservations (admission control).
    pub reserved: Demand,
    /// Nominal capacity (admission control).
    pub capacity: Demand,
    /// Fault domain (rack) tag — the domain-diversity input to
    /// evacuation scoring (`PlacementRequest::avoid_rack`).
    pub rack: usize,
}

impl HostView {
    /// Same admission predicate as [`crate::cluster::Host::fits`]
    /// (views only contain hosts that accept VMs, so the power-state
    /// check is already paid).
    pub fn fits(&self, flavor: &Flavor) -> bool {
        admission_fits(&self.capacity, &self.reserved, flavor)
    }
}

impl Cluster {
    /// Effective utilization of one host: componentwise max of
    /// instantaneous and profiled expected load — a host whose ETL
    /// tenants are between I/O bursts is *not* free capacity. The
    /// single definition shared by the placement views and the
    /// consolidation scan, so the two can never disagree on which
    /// hosts are hot.
    pub fn effective_util(&self, id: HostId) -> Utilization {
        let inst = self.hosts[id.0].utilization();
        let prof = self.expected_util(id);
        Utilization {
            cpu: inst.cpu.max(prof.cpu),
            mem: inst.mem.max(prof.mem),
            disk: inst.disk.max(prof.disk),
            net: inst.net.max(prof.net),
        }
    }

    /// One host's scoring view at this instant, or `None` when the
    /// host does not accept VMs or its effective CPU utilization
    /// exceeds `delta_high` (Eq. 9). The single constructor behind
    /// both the whole-cluster and the per-shard view builders, so the
    /// flat and sharded placement paths can never disagree on which
    /// hosts are placeable.
    pub fn scoring_view_of(&self, id: HostId, delta_high: f64) -> Option<HostView> {
        let host = &self.hosts[id.0];
        // Degraded hosts refuse new placements (they are being
        // drained), mirroring `Host::fits`.
        if !host.state.accepts_vms() || host.is_degraded() {
            return None;
        }
        let util = self.effective_util(id);
        if util.cpu > delta_high {
            return None;
        }
        Some(HostView {
            id,
            util,
            n_vms: host.vms.len(),
            freq: host.freq,
            idle_share: host.idle_share(),
            reserved: *self.reserved(id),
            capacity: host.spec.capacity(),
            rack: host.rack,
        })
    }

    /// Build the pruned scoring views for one frozen decision point
    /// into `out` (cleared first; callers reuse the buffer). Hosts
    /// that do not accept VMs or whose effective CPU utilization
    /// exceeds `delta_high` (Eq. 9) are excluded, so per-request
    /// candidate gathering never touches them.
    pub fn scoring_views(&self, delta_high: f64, out: &mut Vec<HostView>) {
        out.clear();
        for host in &self.hosts {
            if let Some(v) = self.scoring_view_of(host.id, delta_high) {
                out.push(v);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::flavor::{CATALOG, MEDIUM};
    use crate::util::rng::Xoshiro256;
    use crate::workload::JobId;

    #[test]
    fn views_prune_hot_and_off_hosts() {
        let mut c = Cluster::homogeneous(3);
        c.host_mut(HostId(0)).demand.cpu = 30.0; // 0.94 > 0.85
        c.host_mut(HostId(2)).power_off(0.0);
        c.advance_power_states(100.0);
        let mut views = Vec::new();
        c.scoring_views(0.85, &mut views);
        assert_eq!(views.len(), 1);
        assert_eq!(views[0].id, HostId(1));
        assert_eq!(views[0].capacity.mem_gb, 64.0);
    }

    #[test]
    fn view_fits_agrees_with_host_fits_on_random_states() {
        // The snapshot predicate and the live predicate must be the
        // same function of the same numbers — borderline disagreement
        // would make the coordinator actuate an infeasible decision.
        let mut rng = Xoshiro256::seed_from_u64(11);
        for _ in 0..200 {
            let mut c = Cluster::homogeneous(2);
            for _ in 0..rng.range(0, 5) {
                let flavor = CATALOG[rng.range(0, 3)];
                let feas = c.feasible_hosts(&flavor);
                if feas.is_empty() {
                    continue;
                }
                let host = feas[rng.range(0, feas.len())];
                let vm = c.create_vm(flavor, JobId(0), 0.0);
                c.place_vm(vm, host).unwrap();
            }
            let mut views = Vec::new();
            c.scoring_views(1.01, &mut views);
            for v in &views {
                for flavor in &CATALOG {
                    assert_eq!(
                        v.fits(flavor),
                        c.host(v.id).fits(flavor, c.reserved(v.id)),
                        "fits divergence on {}",
                        v.id
                    );
                }
            }
        }
    }

    #[test]
    fn views_prune_degraded_hosts() {
        use crate::cluster::HostCondition;
        let mut c = Cluster::homogeneous(2);
        c.host_mut(HostId(0)).condition = HostCondition::FlakyDisk;
        let mut views = Vec::new();
        c.scoring_views(1.01, &mut views);
        assert_eq!(views.len(), 1);
        assert_eq!(views[0].id, HostId(1));
        c.host_mut(HostId(0)).condition = HostCondition::Healthy;
        c.scoring_views(1.01, &mut views);
        assert_eq!(views.len(), 2);
    }

    #[test]
    fn effective_util_reflects_profiled_load() {
        let mut c = Cluster::homogeneous(1);
        let vm = c.create_vm(MEDIUM, JobId(0), 0.0);
        c.place_vm(vm, HostId(0)).unwrap();
        // Quiet instantaneous demand, heavy profiled expectation.
        c.set_expected_demand(
            vm,
            Demand {
                cpu: 16.0,
                mem_gb: 8.0,
                disk_mbps: 0.0,
                net_mbps: 0.0,
            },
        );
        let mut views = Vec::new();
        c.scoring_views(1.01, &mut views);
        assert!((views[0].util.cpu - 0.5).abs() < 1e-9);
        assert_eq!(views[0].n_vms, 1);
        assert_eq!(views[0].idle_share, c.host(HostId(0)).idle_share());
    }
}
