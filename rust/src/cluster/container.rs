//! Per-host container slots for the serverless workload family.
//!
//! A function invocation runs inside a sandbox (container) on its
//! host. If no warm sandbox for the function exists, the invocation
//! pays a *cold start*: the sandbox boots for a latency window during
//! which the host draws extra power but the invocation makes no
//! progress — the container-scale analogue of the host-level
//! `BOOT_SECS` boot in [`crate::cluster::power`]. When an invocation
//! completes, its sandbox is parked *warm* for a keep-alive window
//! (set per function by a [`crate::workload::faas::KeepAlivePolicy`])
//! and the next invocation of the same function can claim it and skip
//! the cold start. Warm sandboxes hold their memory footprint, which
//! feeds the host's memory utilization and hence the β term of the
//! power model — keeping containers warm is not free.

use crate::workload::faas::FunctionId;

/// Extra draw (W) a host pays per in-flight container cold start —
/// the sandbox image pull + runtime boot powering through its window
/// before useful work, mirroring `p_boot` during host boots but at
/// container scale.
pub const CONTAINER_BOOT_W: f64 = 20.0;

/// Sandbox lifecycle. There is no `Busy` state: a warm sandbox is
/// *claimed* (removed from the pool) when an invocation reuses it —
/// the running VM then accounts for all of its resources — and parked
/// back warm when the invocation completes.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ContainerState {
    /// Cold start in progress until the given simulation time; the
    /// host draws [`CONTAINER_BOOT_W`] extra watts meanwhile.
    Booting { until: f64 },
    /// Idle warm sandbox, reusable until its keep-alive expiry.
    Warm { expires_at: f64 },
}

/// One sandbox slot on a host.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Container {
    pub function: FunctionId,
    /// Resident memory the sandbox holds (GB) — charged to the host's
    /// memory utilization while booting or warm.
    pub mem_gb: f64,
    pub state: ContainerState,
}

impl Container {
    pub fn is_warm(&self) -> bool {
        matches!(self.state, ContainerState::Warm { .. })
    }

    pub fn is_booting(&self) -> bool {
        matches!(self.state, ContainerState::Booting { .. })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn state_predicates() {
        let c = Container {
            function: FunctionId(3),
            mem_gb: 0.5,
            state: ContainerState::Warm { expires_at: 10.0 },
        };
        assert!(c.is_warm() && !c.is_booting());
        let b = Container {
            state: ContainerState::Booting { until: 2.0 },
            ..c
        };
        assert!(b.is_booting() && !b.is_warm());
    }
}
