//! Cluster model: hosts, VMs, flavors, the power model, and the
//! placement/migration state machine — the simulated stand-in for the
//! paper's five-node KVM/OpenStack testbed.

pub mod container;
pub mod flavor;
pub mod host;
pub mod index;
pub mod power;
pub mod shard;
pub mod topology;
pub mod vm;

pub use container::{Container, ContainerState, CONTAINER_BOOT_W};
pub use flavor::Flavor;
pub use host::{
    Host, HostCondition, HostId, HostSpec, Utilization, FLAKY_DISK_FACTOR, THERMAL_FREQ_CAP,
};
pub use index::HostView;
pub use power::{PowerModel, PowerState};
pub use shard::{DigestSnapshot, ShardDigest, ShardMap, ShardedCluster};
pub use topology::Topology;
pub use vm::{migration_cost, Vm, VmId, VmState};

use std::collections::BTreeMap;

/// Absolute resource demand: CPU cores, memory GiB, disk MB/s, net MB/s.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct Demand {
    pub cpu: f64,
    pub mem_gb: f64,
    pub disk_mbps: f64,
    pub net_mbps: f64,
}

impl Demand {
    pub const ZERO: Demand = Demand {
        cpu: 0.0,
        mem_gb: 0.0,
        disk_mbps: 0.0,
        net_mbps: 0.0,
    };

    pub fn add(&mut self, other: &Demand) {
        self.cpu += other.cpu;
        self.mem_gb += other.mem_gb;
        self.disk_mbps += other.disk_mbps;
        self.net_mbps += other.net_mbps;
    }

    /// Componentwise subtraction, deliberately unclamped: the
    /// expected-load cache pairs every `sub` with an earlier `add`,
    /// and clamping would silently absorb bookkeeping bugs that
    /// `check_invariants` is meant to catch.
    pub fn sub(&mut self, other: &Demand) {
        self.cpu -= other.cpu;
        self.mem_gb -= other.mem_gb;
        self.disk_mbps -= other.disk_mbps;
        self.net_mbps -= other.net_mbps;
    }

    pub fn scaled(&self, k: f64) -> Demand {
        Demand {
            cpu: self.cpu * k,
            mem_gb: self.mem_gb * k,
            disk_mbps: self.disk_mbps * k,
            net_mbps: self.net_mbps * k,
        }
    }

    /// Clamp each component to the flavor's provisioned maxima — a VM
    /// can never demand more than its size class grants.
    pub fn capped_by(&self, f: &Flavor) -> Demand {
        Demand {
            cpu: self.cpu.min(f.vcpus),
            mem_gb: self.mem_gb.min(f.mem_gb),
            disk_mbps: self.disk_mbps.min(f.disk_mbps),
            net_mbps: self.net_mbps.min(f.net_mbps),
        }
    }
}

/// Flavor-based reservation footprint: admission control reserves
/// CPU and memory; disk/net are contended, not reserved. The ONE
/// definition shared by the cluster's reservation accounting and the
/// shard digests, so the two can never drift.
pub fn reservation_of(f: &Flavor) -> Demand {
    Demand {
        cpu: f.vcpus,
        mem_gb: f.mem_gb,
        disk_mbps: 0.0,
        net_mbps: 0.0,
    }
}

/// The cluster: hosts plus the VM inventory and reservation accounting.
#[derive(Debug, Clone)]
pub struct Cluster {
    pub hosts: Vec<Host>,
    pub vms: BTreeMap<VmId, Vm>,
    next_vm: u64,
    /// Flavor-based reservations per host (for admission control —
    /// distinct from instantaneous demand, which fluctuates by phase).
    reserved: Vec<Demand>,
    /// Per-migration network charge, so completion releases exactly
    /// what start charged.
    migration_net_of: BTreeMap<VmId, f64>,
    /// Incrementally-maintained per-host expected load: resident VMs'
    /// profiled mean demands plus incoming migrations. Makes
    /// [`Cluster::expected_load`] O(1) on the batched scoring path
    /// (it used to walk the whole VM inventory per host). Kept
    /// consistent by every mutator; `Vm::expected` may only change
    /// through [`Cluster::set_expected_demand`].
    expected_cache: Vec<Demand>,
}

impl Cluster {
    /// Build a homogeneous cluster of `n` paper-testbed hosts.
    pub fn homogeneous(n: usize) -> Cluster {
        let spec = HostSpec::paper_testbed();
        Cluster {
            hosts: (0..n).map(|i| Host::new(HostId(i), spec)).collect(),
            vms: BTreeMap::new(),
            next_vm: 0,
            reserved: vec![Demand::ZERO; n],
            migration_net_of: BTreeMap::new(),
            expected_cache: vec![Demand::ZERO; n],
        }
    }

    pub fn n_hosts(&self) -> usize {
        self.hosts.len()
    }

    pub fn host(&self, id: HostId) -> &Host {
        &self.hosts[id.0]
    }

    pub fn host_mut(&mut self, id: HostId) -> &mut Host {
        &mut self.hosts[id.0]
    }

    pub fn reserved(&self, id: HostId) -> &Demand {
        &self.reserved[id.0]
    }

    /// Create a VM (Pending, unplaced).
    pub fn create_vm(&mut self, flavor: Flavor, job: crate::workload::JobId, now: f64) -> VmId {
        let id = VmId(self.next_vm);
        self.next_vm += 1;
        self.vms.insert(id, Vm::new(id, flavor, job, now));
        id
    }

    /// Place a pending VM on a host. Panics on inconsistent state; the
    /// scheduler must have checked `fits` first (returns Err if not).
    pub fn place_vm(&mut self, vm_id: VmId, host_id: HostId) -> Result<(), PlacementError> {
        let flavor = {
            let vm = self.vms.get(&vm_id).ok_or(PlacementError::NoSuchVm)?;
            if !matches!(vm.state, VmState::Pending) {
                return Err(PlacementError::NotPending);
            }
            vm.flavor
        };
        if !self.hosts[host_id.0].fits(&flavor, &self.reserved[host_id.0]) {
            return Err(PlacementError::DoesNotFit);
        }
        let vm = self.vms.get_mut(&vm_id).unwrap();
        vm.host = Some(host_id);
        vm.state = VmState::Running;
        let expected = vm.expected;
        self.hosts[host_id.0].vms.push(vm_id);
        self.reserved[host_id.0].add(&reservation_of(&flavor));
        self.expected_cache[host_id.0].add(&expected);
        Ok(())
    }

    /// Update a VM's profiled mean demand, keeping the per-host
    /// expected-load cache consistent. This is the only sanctioned
    /// way to change `Vm::expected` once the VM exists — a direct
    /// field write would silently desynchronize the cache (caught by
    /// [`Cluster::check_invariants`]).
    pub fn set_expected_demand(&mut self, vm_id: VmId, expected: Demand) {
        let vm = self.vms.get_mut(&vm_id).expect("set_expected_demand on unknown VM");
        let old = vm.expected;
        vm.expected = expected;
        // Mirror expected_load's attribution: residents count on the
        // host that lists them (the source while migrating), and a
        // migrating VM additionally counts on its destination.
        let (resident, incoming) = match vm.state {
            VmState::Migrating { from, to, .. } => (Some(from), Some(to)),
            _ => (vm.host, None),
        };
        for host in [resident, incoming].into_iter().flatten() {
            self.expected_cache[host.0].sub(&old);
            self.expected_cache[host.0].add(&expected);
        }
    }

    /// Begin a live migration; completes via [`Cluster::finish_migration`].
    pub fn start_migration(
        &mut self,
        vm_id: VmId,
        to: HostId,
        now: f64,
        link_mbps: f64,
    ) -> Result<vm::MigrationCost, PlacementError> {
        let (flavor, from) = {
            let vm = self.vms.get(&vm_id).ok_or(PlacementError::NoSuchVm)?;
            if !matches!(vm.state, VmState::Running) {
                return Err(PlacementError::NotRunning);
            }
            (vm.flavor, vm.host.expect("running VM has a host"))
        };
        if from == to {
            return Err(PlacementError::SameHost);
        }
        if !self.hosts[to.0].fits(&flavor, &self.reserved[to.0]) {
            return Err(PlacementError::DoesNotFit);
        }
        let cost = migration_cost(flavor.mem_gb, link_mbps);
        let vm = self.vms.get_mut(&vm_id).unwrap();
        vm.state = VmState::Migrating {
            from,
            to,
            done: now + cost.duration,
        };
        let expected = vm.expected;
        // The destination carries the VM's expected load from copy
        // start (expected_load counts migrating VMs on both ends).
        self.expected_cache[to.0].add(&expected);
        // Reserve on the destination for the duration of the copy; the
        // source keeps its reservation until cut-over.
        self.reserved[to.0].add(&reservation_of(&flavor));
        self.hosts[from.0].migration_net += cost.net_mbps;
        self.hosts[to.0].migration_net += cost.net_mbps;
        self.migration_net_of.insert(vm_id, cost.net_mbps);
        Ok(cost)
    }

    /// Complete a migration: cut the VM over to the destination.
    pub fn finish_migration(&mut self, vm_id: VmId) {
        let (from, to, flavor) = match self.vms.get(&vm_id) {
            Some(vm) => match vm.state {
                VmState::Migrating { from, to, .. } => (from, to, vm.flavor),
                _ => panic!("finish_migration on non-migrating {vm_id}"),
            },
            None => panic!("finish_migration on unknown {vm_id}"),
        };
        let charged = self.migration_net_of.remove(&vm_id).unwrap_or(0.0);
        let vm = self.vms.get_mut(&vm_id).unwrap();
        vm.state = VmState::Running;
        vm.host = Some(to);
        vm.migrations += 1;
        let expected = vm.expected;
        // Source residency ends; the destination's share (added at
        // migration start) becomes the resident contribution.
        self.expected_cache[from.0].sub(&expected);
        self.hosts[from.0].vms.retain(|&v| v != vm_id);
        self.hosts[to.0].vms.push(vm_id);
        self.reserved[from.0] = sub_reservation(&self.reserved[from.0], &flavor);
        self.hosts[from.0].migration_net =
            (self.hosts[from.0].migration_net - charged).max(0.0);
        self.hosts[to.0].migration_net =
            (self.hosts[to.0].migration_net - charged).max(0.0);
    }

    /// Cancel an in-flight migration: the copy is abandoned and the VM
    /// keeps running on its source. Releases exactly the destination
    /// bookkeeping that [`Cluster::start_migration`] charged
    /// (reservation, expected-load share, migration traffic on both
    /// ends). Used when the destination host crashes mid-copy.
    pub fn cancel_migration(&mut self, vm_id: VmId) {
        let (from, to, flavor) = match self.vms.get(&vm_id) {
            Some(vm) => match vm.state {
                VmState::Migrating { from, to, .. } => (from, to, vm.flavor),
                _ => panic!("cancel_migration on non-migrating {vm_id}"),
            },
            None => panic!("cancel_migration on unknown {vm_id}"),
        };
        let charged = self.migration_net_of.remove(&vm_id).unwrap_or(0.0);
        let vm = self.vms.get_mut(&vm_id).unwrap();
        vm.state = VmState::Running;
        vm.host = Some(from);
        let expected = vm.expected;
        self.expected_cache[to.0].sub(&expected);
        self.reserved[to.0] = sub_reservation(&self.reserved[to.0], &flavor);
        self.hosts[from.0].migration_net =
            (self.hosts[from.0].migration_net - charged).max(0.0);
        self.hosts[to.0].migration_net =
            (self.hosts[to.0].migration_net - charged).max(0.0);
    }

    /// Crash a host at `now`. In-flight migrations *into* the host are
    /// cancelled (the VM survives on its source); every VM resident on
    /// the host — including sources of outgoing copies, whose
    /// destination bookkeeping is released — is killed. Returns the
    /// killed and cancelled VM ids in deterministic (residence /
    /// ascending) order so the coordinator can requeue their jobs.
    pub fn fail_host(&mut self, host_id: HostId, now: f64) -> CrashOutcome {
        assert!(
            self.hosts[host_id.0].state.is_on(),
            "fail_host on {host_id} which is not On"
        );
        // Abandon copies targeting the crashed host first, so the
        // resident sweep below only sees residents.
        let cancelled_incoming: Vec<VmId> = self
            .vms
            .values()
            .filter(|vm| matches!(vm.state, VmState::Migrating { to, .. } if to == host_id))
            .map(|vm| vm.id)
            .collect();
        for &vm_id in &cancelled_incoming {
            self.cancel_migration(vm_id);
        }
        let killed = self.hosts[host_id.0].vms.clone();
        for &vm_id in &killed {
            // An outgoing copy dies with its source: release the
            // destination's share before settling the source side.
            if matches!(self.vms[&vm_id].state, VmState::Migrating { .. }) {
                let (from, to, flavor) = match self.vms[&vm_id].state {
                    VmState::Migrating { from, to, .. } => (from, to, self.vms[&vm_id].flavor),
                    _ => unreachable!(),
                };
                debug_assert_eq!(from, host_id);
                let charged = self.migration_net_of.remove(&vm_id).unwrap_or(0.0);
                let expected = self.vms[&vm_id].expected;
                self.expected_cache[to.0].sub(&expected);
                self.reserved[to.0] = sub_reservation(&self.reserved[to.0], &flavor);
                self.hosts[to.0].migration_net =
                    (self.hosts[to.0].migration_net - charged).max(0.0);
                let vm = self.vms.get_mut(&vm_id).unwrap();
                vm.state = VmState::Running;
                vm.host = Some(from);
            }
            let vm = self.vms.get_mut(&vm_id).unwrap();
            let flavor = vm.flavor;
            let expected = vm.expected;
            vm.state = VmState::Terminated;
            vm.host = None;
            self.reserved[host_id.0] = sub_reservation(&self.reserved[host_id.0], &flavor);
            self.expected_cache[host_id.0].sub(&expected);
        }
        self.hosts[host_id.0].vms.clear();
        self.hosts[host_id.0].fail(now);
        CrashOutcome {
            killed,
            cancelled_incoming,
        }
    }

    /// Terminate a VM (job completed) and free its reservation.
    pub fn terminate_vm(&mut self, vm_id: VmId) {
        let vm = self.vms.get_mut(&vm_id).expect("terminate unknown VM");
        assert!(
            matches!(vm.state, VmState::Running),
            "terminate non-running {vm_id} in state {:?}",
            vm.state
        );
        let host = vm.host.take().expect("running VM has a host");
        let flavor = vm.flavor;
        let expected = vm.expected;
        vm.state = VmState::Terminated;
        self.hosts[host.0].vms.retain(|&v| v != vm_id);
        self.reserved[host.0] = sub_reservation(&self.reserved[host.0], &flavor);
        self.expected_cache[host.0].sub(&expected);
    }

    /// Overwrite per-host demand from per-VM demands. Called once per
    /// simulation tick by the engine. Demands are capped by flavor.
    pub fn apply_demands(&mut self, vm_demands: &BTreeMap<VmId, Demand>) {
        for h in &mut self.hosts {
            h.demand = Demand::ZERO;
        }
        for (vm_id, demand) in vm_demands {
            let vm = match self.vms.get(vm_id) {
                Some(v) if v.is_active() => v,
                _ => continue,
            };
            let capped = demand.capped_by(&vm.flavor);
            // During migration the VM still executes on the *source*.
            let host = match vm.state {
                VmState::Migrating { from, .. } => from,
                _ => vm.host.expect("active VM has a host"),
            };
            self.hosts[host.0].demand.add(&capped);
        }
    }

    /// Advance power-state machines to `now`, retiring completed
    /// container cold starts along the way (same clock, same sweep).
    pub fn advance_power_states(&mut self, now: f64) {
        for h in &mut self.hosts {
            h.state = h.state.advance(now);
            h.advance_containers(now);
        }
    }

    /// Profiled (expected-mean) load on a host: sum of resident VMs'
    /// expected demands plus incoming migrations. Workload-aware
    /// policies use this instead of instantaneous demand — a host full
    /// of I/O jobs in a quiet phase is *not* free capacity. O(1): the
    /// cache is maintained incrementally by every cluster mutator (the
    /// old implementation walked the VM inventory per call, which made
    /// batched candidate gathering O(hosts × VMs); it survives as
    /// [`Cluster::recompute_expected_load`] for the invariant check).
    pub fn expected_load(&self, id: HostId) -> Demand {
        self.expected_cache[id.0]
    }

    /// Reference recomputation of [`Cluster::expected_load`] from the
    /// VM inventory — O(VMs), used by `check_invariants` to verify the
    /// incremental cache.
    fn recompute_expected_load(&self, id: HostId) -> Demand {
        let mut total = Demand::ZERO;
        for vm_id in &self.hosts[id.0].vms {
            total.add(&self.vms[vm_id].expected);
        }
        for vm in self.vms.values() {
            if let VmState::Migrating { to, .. } = vm.state {
                if to == id {
                    total.add(&vm.expected);
                }
            }
        }
        total
    }

    /// Expected utilization from [`Cluster::expected_load`], clamped
    /// to [0, 1] (the incremental cache can carry ±ε float residue
    /// after add/sub cycles).
    pub fn expected_util(&self, id: HostId) -> host::Utilization {
        let host = &self.hosts[id.0];
        if !host.state.is_on() {
            return host::Utilization::default();
        }
        let cap = host.spec.capacity();
        let e = self.expected_load(id);
        host::Utilization {
            cpu: (e.cpu / (cap.cpu * host.freq)).clamp(0.0, 1.0),
            mem: (e.mem_gb / cap.mem_gb).clamp(0.0, 1.0),
            disk: (e.disk_mbps / cap.disk_mbps).clamp(0.0, 1.0),
            net: (e.net_mbps / cap.net_mbps).clamp(0.0, 1.0),
        }
    }

    /// Total instantaneous power draw (W) across hosts.
    pub fn total_power(&self) -> f64 {
        self.hosts.iter().map(Host::power).sum()
    }

    /// Number of hosts in the On state.
    pub fn hosts_on(&self) -> usize {
        self.hosts.iter().filter(|h| h.state.is_on()).count()
    }

    /// Hosts that can currently accept a VM of `flavor`.
    pub fn feasible_hosts(&self, flavor: &Flavor) -> Vec<HostId> {
        self.hosts
            .iter()
            .filter(|h| h.fits(flavor, &self.reserved[h.id.0]))
            .map(|h| h.id)
            .collect()
    }

    /// Consistency check used by property tests: reservations equal the
    /// sum of resident flavors; VM/host cross-references agree.
    pub fn check_invariants(&self) -> Result<(), String> {
        for h in &self.hosts {
            if h.state.is_failed() {
                if !h.vms.is_empty() {
                    return Err(format!("failed {} still lists {} VMs", h.id, h.vms.len()));
                }
                let r = &self.reserved[h.id.0];
                if r.cpu.abs() > 1e-6 || r.mem_gb.abs() > 1e-6 {
                    return Err(format!("failed {} holds reservation {:?}", h.id, r));
                }
                if !h.containers.is_empty() {
                    return Err(format!("failed {} still holds sandboxes", h.id));
                }
            }
            let mut expect = Demand::ZERO;
            for vm_id in &h.vms {
                let vm = self
                    .vms
                    .get(vm_id)
                    .ok_or_else(|| format!("{} lists unknown {vm_id}", h.id))?;
                let on_this_host = match vm.state {
                    VmState::Migrating { from, to, .. } => from == h.id || to == h.id,
                    _ => vm.host == Some(h.id),
                };
                if !on_this_host {
                    return Err(format!("{vm_id} listed on {} but points elsewhere", h.id));
                }
                // Migrating VMs are listed on the source until cut-over;
                // the destination carries only a reservation.
                expect.add(&reservation_of(&vm.flavor));
            }
            let r = &self.reserved[h.id.0];
            // Reservation >= resident flavors (migration targets add
            // reservation without residency).
            if r.cpu + 1e-6 < expect.cpu || r.mem_gb + 1e-6 < expect.mem_gb {
                return Err(format!(
                    "{} reservation {:?} < resident {:?}",
                    h.id, r, expect
                ));
            }
            if r.mem_gb > h.spec.mem_gb + 1e-6 {
                return Err(format!("{} memory over-reserved: {}", h.id, r.mem_gb));
            }
            // The incremental expected-load cache agrees with a fresh
            // recomputation from the VM inventory.
            let cached = self.expected_cache[h.id.0];
            let fresh = self.recompute_expected_load(h.id);
            if (cached.cpu - fresh.cpu).abs() > 1e-6
                || (cached.mem_gb - fresh.mem_gb).abs() > 1e-6
                || (cached.disk_mbps - fresh.disk_mbps).abs() > 1e-6
                || (cached.net_mbps - fresh.net_mbps).abs() > 1e-6
            {
                return Err(format!(
                    "{} expected-load cache {cached:?} != recomputed {fresh:?}",
                    h.id
                ));
            }
        }
        Ok(())
    }
}

fn sub_reservation(r: &Demand, f: &Flavor) -> Demand {
    let res = reservation_of(f);
    Demand {
        cpu: (r.cpu - res.cpu).max(0.0),
        mem_gb: (r.mem_gb - res.mem_gb).max(0.0),
        disk_mbps: r.disk_mbps,
        net_mbps: r.net_mbps,
    }
}

/// What a host crash did to the VM inventory — the coordinator's
/// work-list for evacuation.
#[derive(Debug, Clone, Default)]
pub struct CrashOutcome {
    /// VMs that died with the host (residents, including sources of
    /// abandoned outgoing copies), in residence order.
    pub killed: Vec<VmId>,
    /// In-flight migrations into the host that were cancelled; these
    /// VMs survive on their sources.
    pub cancelled_incoming: Vec<VmId>,
}

/// Placement errors surfaced to the scheduler.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PlacementError {
    NoSuchVm,
    NotPending,
    NotRunning,
    DoesNotFit,
    SameHost,
}

impl std::fmt::Display for PlacementError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            PlacementError::NoSuchVm => "no such VM",
            PlacementError::NotPending => "VM is not pending",
            PlacementError::NotRunning => "VM is not running",
            PlacementError::DoesNotFit => "VM does not fit on target host",
            PlacementError::SameHost => "source and destination host are the same",
        })
    }
}

impl std::error::Error for PlacementError {}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::flavor::{LARGE, MEDIUM, SMALL};
    use crate::workload::JobId;

    fn cluster() -> Cluster {
        Cluster::homogeneous(3)
    }

    #[test]
    fn place_and_terminate_roundtrip() {
        let mut c = cluster();
        let vm = c.create_vm(MEDIUM, JobId(1), 0.0);
        c.place_vm(vm, HostId(1)).unwrap();
        assert_eq!(c.vms[&vm].host, Some(HostId(1)));
        assert_eq!(c.host(HostId(1)).vms, vec![vm]);
        assert_eq!(c.reserved(HostId(1)).mem_gb, 16.0);
        c.check_invariants().unwrap();
        c.terminate_vm(vm);
        assert!(c.host(HostId(1)).vms.is_empty());
        assert_eq!(c.reserved(HostId(1)).mem_gb, 0.0);
        c.check_invariants().unwrap();
    }

    #[test]
    fn memory_admission_control() {
        let mut c = cluster();
        // 64 GB host: two LARGE (32 GB) fit, a third does not.
        let a = c.create_vm(LARGE, JobId(1), 0.0);
        let b = c.create_vm(LARGE, JobId(2), 0.0);
        let d = c.create_vm(LARGE, JobId(3), 0.0);
        c.place_vm(a, HostId(0)).unwrap();
        c.place_vm(b, HostId(0)).unwrap();
        assert_eq!(c.place_vm(d, HostId(0)), Err(PlacementError::DoesNotFit));
        assert_eq!(c.feasible_hosts(&LARGE), vec![HostId(1), HostId(2)]);
    }

    #[test]
    fn migration_lifecycle_conserves_vms() {
        let mut c = cluster();
        let vm = c.create_vm(MEDIUM, JobId(1), 0.0);
        c.place_vm(vm, HostId(0)).unwrap();
        let cost = c.start_migration(vm, HostId(2), 10.0, 100.0).unwrap();
        assert!(cost.duration > 0.0);
        // Still resident on source; reserved on both.
        assert_eq!(c.host(HostId(0)).vms, vec![vm]);
        assert_eq!(c.reserved(HostId(2)).mem_gb, 16.0);
        assert!(c.host(HostId(0)).migration_net > 0.0);
        c.check_invariants().unwrap();
        c.finish_migration(vm);
        assert!(c.host(HostId(0)).vms.is_empty());
        assert_eq!(c.host(HostId(2)).vms, vec![vm]);
        assert_eq!(c.reserved(HostId(0)).mem_gb, 0.0);
        assert_eq!(c.vms[&vm].migrations, 1);
        assert_eq!(c.host(HostId(0)).migration_net, 0.0);
        c.check_invariants().unwrap();
    }

    #[test]
    fn migration_to_same_host_rejected() {
        let mut c = cluster();
        let vm = c.create_vm(SMALL, JobId(1), 0.0);
        c.place_vm(vm, HostId(0)).unwrap();
        assert_eq!(
            c.start_migration(vm, HostId(0), 0.0, 100.0),
            Err(PlacementError::SameHost)
        );
    }

    #[test]
    fn demands_aggregate_onto_source_during_migration() {
        let mut c = cluster();
        let vm = c.create_vm(MEDIUM, JobId(1), 0.0);
        c.place_vm(vm, HostId(0)).unwrap();
        c.start_migration(vm, HostId(1), 0.0, 100.0).unwrap();
        let mut demands = BTreeMap::new();
        demands.insert(
            vm,
            Demand {
                cpu: 4.0,
                mem_gb: 8.0,
                disk_mbps: 50.0,
                net_mbps: 10.0,
            },
        );
        c.apply_demands(&demands);
        assert_eq!(c.host(HostId(0)).demand.cpu, 4.0);
        assert_eq!(c.host(HostId(1)).demand.cpu, 0.0);
    }

    #[test]
    fn demand_capped_by_flavor() {
        let mut c = cluster();
        let vm = c.create_vm(SMALL, JobId(1), 0.0); // 4 vcpus max
        c.place_vm(vm, HostId(0)).unwrap();
        let mut demands = BTreeMap::new();
        demands.insert(
            vm,
            Demand {
                cpu: 100.0,
                mem_gb: 100.0,
                disk_mbps: 9999.0,
                net_mbps: 9999.0,
            },
        );
        c.apply_demands(&demands);
        let d = c.host(HostId(0)).demand;
        assert_eq!(d.cpu, 4.0);
        assert_eq!(d.mem_gb, 8.0);
        assert_eq!(d.disk_mbps, 120.0);
    }

    #[test]
    fn total_power_counts_all_states() {
        let mut c = cluster();
        let p_all_on = c.total_power();
        assert!((p_all_on - 3.0 * 110.0).abs() < 1e-9);
        c.host_mut(HostId(2)).power_off(0.0);
        c.advance_power_states(1000.0);
        let p_after = c.total_power();
        assert!((p_after - (2.0 * 110.0 + 5.0)).abs() < 1e-9);
        assert_eq!(c.hosts_on(), 2);
    }

    #[test]
    fn terminated_vm_demand_ignored() {
        let mut c = cluster();
        let vm = c.create_vm(SMALL, JobId(1), 0.0);
        c.place_vm(vm, HostId(0)).unwrap();
        c.terminate_vm(vm);
        let mut demands = BTreeMap::new();
        demands.insert(
            vm,
            Demand {
                cpu: 4.0,
                mem_gb: 1.0,
                disk_mbps: 1.0,
                net_mbps: 1.0,
            },
        );
        c.apply_demands(&demands);
        assert_eq!(c.host(HostId(0)).demand, Demand::ZERO);
    }

    #[test]
    fn expected_load_cache_tracks_migration_lifecycle() {
        let mut c = cluster();
        let vm = c.create_vm(MEDIUM, JobId(1), 0.0);
        c.place_vm(vm, HostId(0)).unwrap();
        let d = Demand {
            cpu: 3.0,
            mem_gb: 6.0,
            disk_mbps: 80.0,
            net_mbps: 12.0,
        };
        c.set_expected_demand(vm, d);
        assert_eq!(c.expected_load(HostId(0)), d);
        c.check_invariants().unwrap();
        // During the copy both ends carry the expected load.
        c.start_migration(vm, HostId(1), 0.0, 100.0).unwrap();
        assert_eq!(c.expected_load(HostId(0)), d);
        assert_eq!(c.expected_load(HostId(1)), d);
        // Updating the profile mid-migration adjusts both ends.
        let d2 = Demand { cpu: 5.0, ..d };
        c.set_expected_demand(vm, d2);
        assert_eq!(c.expected_load(HostId(0)), d2);
        assert_eq!(c.expected_load(HostId(1)), d2);
        c.check_invariants().unwrap();
        c.finish_migration(vm);
        assert_eq!(c.expected_load(HostId(0)).cpu, 0.0);
        assert_eq!(c.expected_load(HostId(1)), d2);
        c.check_invariants().unwrap();
        c.terminate_vm(vm);
        assert_eq!(c.expected_load(HostId(1)).cpu, 0.0);
        c.check_invariants().unwrap();
    }

    #[test]
    fn fail_host_kills_residents_and_releases_reservations() {
        let mut c = cluster();
        let a = c.create_vm(MEDIUM, JobId(1), 0.0);
        let b = c.create_vm(SMALL, JobId(2), 0.0);
        c.place_vm(a, HostId(0)).unwrap();
        c.place_vm(b, HostId(0)).unwrap();
        c.set_expected_demand(
            a,
            Demand {
                cpu: 3.0,
                mem_gb: 6.0,
                disk_mbps: 10.0,
                net_mbps: 2.0,
            },
        );
        let out = c.fail_host(HostId(0), 5.0);
        assert_eq!(out.killed, vec![a, b]);
        assert!(out.cancelled_incoming.is_empty());
        assert!(c.host(HostId(0)).state.is_failed());
        assert!(c.host(HostId(0)).vms.is_empty());
        assert_eq!(c.reserved(HostId(0)).mem_gb, 0.0);
        assert_eq!(c.expected_load(HostId(0)), Demand::ZERO);
        assert_eq!(c.vms[&a].state, VmState::Terminated);
        assert_eq!(c.vms[&a].host, None);
        c.check_invariants().unwrap();
        // Recovery reboots through the normal boot window.
        c.host_mut(HostId(0)).recover(10.0);
        c.advance_power_states(10.0 + crate::cluster::power::BOOT_SECS);
        assert!(c.host(HostId(0)).state.is_on());
        c.check_invariants().unwrap();
    }

    #[test]
    fn fail_host_source_crash_abandons_outgoing_copy() {
        let mut c = cluster();
        let vm = c.create_vm(MEDIUM, JobId(1), 0.0);
        c.place_vm(vm, HostId(0)).unwrap();
        c.start_migration(vm, HostId(1), 0.0, 100.0).unwrap();
        let out = c.fail_host(HostId(0), 1.0);
        assert_eq!(out.killed, vec![vm]);
        // Destination bookkeeping fully released.
        assert_eq!(c.reserved(HostId(1)).mem_gb, 0.0);
        assert_eq!(c.expected_load(HostId(1)), Demand::ZERO);
        assert_eq!(c.host(HostId(1)).migration_net, 0.0);
        assert_eq!(c.vms[&vm].state, VmState::Terminated);
        c.check_invariants().unwrap();
    }

    #[test]
    fn fail_host_destination_crash_cancels_incoming_copy() {
        let mut c = cluster();
        let vm = c.create_vm(MEDIUM, JobId(1), 0.0);
        c.place_vm(vm, HostId(0)).unwrap();
        c.start_migration(vm, HostId(1), 0.0, 100.0).unwrap();
        let out = c.fail_host(HostId(1), 1.0);
        assert!(out.killed.is_empty());
        assert_eq!(out.cancelled_incoming, vec![vm]);
        // The VM survives on its source, copy abandoned.
        assert_eq!(c.vms[&vm].state, VmState::Running);
        assert_eq!(c.vms[&vm].host, Some(HostId(0)));
        assert_eq!(c.host(HostId(0)).vms, vec![vm]);
        assert_eq!(c.host(HostId(0)).migration_net, 0.0);
        assert_eq!(c.reserved(HostId(0)).mem_gb, 16.0);
        c.check_invariants().unwrap();
    }

    #[test]
    fn place_on_booting_host_rejected() {
        let mut c = cluster();
        c.host_mut(HostId(0)).power_off(0.0);
        c.advance_power_states(100.0);
        c.host_mut(HostId(0)).power_on(100.0);
        let vm = c.create_vm(SMALL, JobId(1), 100.0);
        assert_eq!(
            c.place_vm(vm, HostId(0)),
            Err(PlacementError::DoesNotFit)
        );
    }
}
