//! Sharded cluster state — the fleet-scale layer over [`Cluster`].
//!
//! The whole-cluster view stops scaling past a few thousand hosts:
//! every `decide_batch` candidate sweep and every consolidation scan
//! walks all hosts, so decision latency grows linearly with fleet
//! size. [`ShardedCluster`] splits the host set into a fixed,
//! power-of-two number of shards (SplitMix64 hash of the host id —
//! stable, no rebalancing) and maintains one [`ShardDigest`] per
//! shard: a thin aggregate (free-capacity headroom, powered-on count,
//! per-class expected load) that the coordinator and the fan-out
//! scheduling paths read *without touching shard interiors*. Policies
//! route work to the top-K shards by digest headroom and only
//! materialize those shards' [`HostView`] snapshots.
//!
//! Mutations route through the sharded handle (place, migrate,
//! terminate, expected-demand updates, power transitions) so the
//! digests stay incrementally consistent — the same discipline the
//! cluster's own expected-load cache imposes one level down. Reads
//! pass through [`Deref`] to the inner [`Cluster`] unchanged.
//! [`ShardedCluster::check_invariants`] cross-checks every digest
//! against a fresh recomputation from the VM inventory, so a mutation
//! path that skips the handle is caught by the property tests.
//!
//! Each shard also carries a monotonically increasing **commit
//! epoch**, bumped by every mutation whose effect is visible to
//! placement (admission capacity, power/crash state, warm pools).
//! The epoch is the staleness currency of the optimistic commit
//! protocol: a coordinator snapshots [`DigestSnapshot`]s (digest +
//! epoch), decides against them, and the
//! [`crate::coordinator::PlacementStore`] compares the snapshot epoch
//! with the live one at commit time to bound how stale a decision may
//! be before its coordinator is forced to refresh.

use crate::cluster::flavor::Flavor;
use crate::cluster::vm::MigrationCost;
use crate::cluster::{
    reservation_of, Cluster, Demand, HostId, HostView, PlacementError, VmId, VmState,
};
use crate::profile::{classify, ResourceVector, WorkloadClass};
use std::ops::Deref;

/// Number of per-class load buckets in a [`ShardDigest`] — the Eq. 2
/// classes: cpu-bound, mem-bound, io-bound, balanced.
pub const N_LOAD_CLASSES: usize = 4;

/// Digest bucket index of a workload class.
pub fn class_index(c: WorkloadClass) -> usize {
    match c {
        WorkloadClass::CpuBound => 0,
        WorkloadClass::MemBound => 1,
        WorkloadClass::IoBound => 2,
        WorkloadClass::Balanced => 3,
    }
}

/// Classify a VM's expected demand (normalized by its flavor) into a
/// digest bucket — the Eq. 2 dominant-resource rule applied to the
/// profiled mean instead of a telemetry window. The flavor is the
/// normalizer (not the host) so the class is stable across
/// migrations.
pub fn demand_class(d: &Demand, f: &Flavor) -> usize {
    let v = ResourceVector {
        cpu: d.cpu / f.vcpus,
        mem: d.mem_gb / f.mem_gb,
        disk: d.disk_mbps / f.disk_mbps,
        net: d.net_mbps / f.net_mbps,
        cpu_peak: 0.0,
        io_peak: 0.0,
        burstiness: 0.0,
    };
    class_index(classify(&v))
}

pub(crate) fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^ (x >> 31)
}

/// Stable host→shard assignment: hash of the host id masked to a
/// power-of-two shard count. Fixed at construction, so membership can
/// be cached everywhere and never rebalances under churn.
#[derive(Debug, Clone, Copy)]
pub struct ShardMap {
    count: usize,
}

impl ShardMap {
    pub fn new(count: usize) -> ShardMap {
        assert!(
            count.is_power_of_two(),
            "shard count must be a power of two, got {count}"
        );
        ShardMap { count }
    }

    pub fn count(&self) -> usize {
        self.count
    }

    pub fn shard_of(&self, host: HostId) -> usize {
        (splitmix64(host.0 as u64) & (self.count as u64 - 1)) as usize
    }
}

/// Cross-shard aggregate of one shard's state — everything the
/// coordinator and the fan-out paths need to *rank* shards without
/// reading their interiors. Maintained incrementally by the
/// [`ShardedCluster`] mutators; `check_invariants` compares it
/// against [`ShardDigest::compute`].
#[derive(Debug, Clone, Copy, Default)]
pub struct ShardDigest {
    /// Member hosts (fixed at construction).
    pub hosts: usize,
    /// Hosts currently in the On state.
    pub on: usize,
    /// Total nominal capacity of hosts currently accepting VMs.
    pub capacity_on: Demand,
    /// Total flavor reservations. Reservations only exist on On hosts
    /// (admission requires `accepts_vms`), so `capacity_on − reserved`
    /// is the shard's admission headroom.
    pub reserved: Demand,
    /// Total profiled expected load over member hosts (migrating VMs
    /// count on both ends, mirroring `Cluster::expected_load`).
    pub expected: Demand,
    /// Expected load split by Eq. 2 workload class
    /// (see [`class_index`]).
    pub per_class: [Demand; N_LOAD_CLASSES],
    /// Warm serverless sandboxes parked on member hosts — the shard's
    /// reuse potential (and idle-memory cost) for FaaS load.
    pub warm_containers: usize,
    /// Member hosts currently crashed (PowerState::Failed).
    pub failed: usize,
    /// Nominal capacity lost to crashed hosts — what recovery would
    /// give back to the shard.
    pub capacity_lost: Demand,
    /// Member hosts in a degraded condition (flaky disk / thermal),
    /// counted regardless of power state — the condition layer is
    /// orthogonal to the power machine.
    pub degraded: usize,
    /// Nominal capacity of degraded member hosts — what a restore
    /// would return to full capability.
    pub capacity_degraded: Demand,
}

impl ShardDigest {
    /// Recompute a digest from cluster state: `hosts` iterates the
    /// shard's members, `in_shard` tests membership (for attributing
    /// per-VM class load). The reference the incremental digests are
    /// checked against.
    pub fn compute<I, F>(cluster: &Cluster, hosts: I, in_shard: F) -> ShardDigest
    where
        I: IntoIterator<Item = HostId>,
        F: Fn(HostId) -> bool,
    {
        let mut d = ShardDigest::default();
        for h in hosts {
            let host = &cluster.hosts[h.0];
            d.hosts += 1;
            if host.state.is_on() {
                d.on += 1;
            }
            if host.state.accepts_vms() {
                d.capacity_on.add(&host.spec.capacity());
            }
            if host.state.is_failed() {
                d.failed += 1;
                d.capacity_lost.add(&host.spec.capacity());
            }
            if host.is_degraded() {
                d.degraded += 1;
                d.capacity_degraded.add(&host.spec.capacity());
            }
            d.reserved.add(cluster.reserved(h));
            d.expected.add(&cluster.expected_load(h));
            d.warm_containers += host.warm_count();
        }
        for vm in cluster.vms.values() {
            let (resident, incoming) = match vm.state {
                VmState::Migrating { from, to, .. } => (Some(from), Some(to)),
                _ => (vm.host, None),
            };
            let expected = vm.expected();
            let cls = demand_class(&expected, &vm.flavor);
            for h in [resident, incoming].into_iter().flatten() {
                if in_shard(h) {
                    d.per_class[cls].add(&expected);
                }
            }
        }
        d
    }

    /// Admission headroom: accepting capacity minus reservations,
    /// clamped at zero componentwise.
    pub fn headroom(&self) -> Demand {
        Demand {
            cpu: (self.capacity_on.cpu - self.reserved.cpu).max(0.0),
            mem_gb: (self.capacity_on.mem_gb - self.reserved.mem_gb).max(0.0),
            disk_mbps: (self.capacity_on.disk_mbps - self.reserved.disk_mbps).max(0.0),
            net_mbps: (self.capacity_on.net_mbps - self.reserved.net_mbps).max(0.0),
        }
    }

    /// Scalar shard-ranking score. Memory is the admission hard
    /// constraint; CPU is weighted by the catalog's ~2 GB-per-vCPU
    /// shape so neither dimension dominates the ranking by unit
    /// choice alone.
    pub fn headroom_score(&self) -> f64 {
        let h = self.headroom();
        h.mem_gb + 2.0 * h.cpu
    }

    /// Expected load attributed to one Eq. 2 class.
    pub fn class_load(&self, c: WorkloadClass) -> Demand {
        self.per_class[class_index(c)]
    }
}

/// One shard's digest stamped with the commit epoch it was read at —
/// the unit of state a coordinator decides against in the optimistic
/// commit protocol. The epoch, not the digest contents, is what the
/// placement store validates: two snapshots with equal digests but
/// different epochs are different snapshots.
#[derive(Debug, Clone, Copy)]
pub struct DigestSnapshot {
    /// Shard the snapshot was taken from.
    pub shard: usize,
    /// The shard's commit epoch at read time.
    pub epoch: u64,
    /// Digest contents at read time (a copy — never ages).
    pub digest: ShardDigest,
}

fn demand_close(a: &Demand, b: &Demand) -> bool {
    (a.cpu - b.cpu).abs() < 1e-6
        && (a.mem_gb - b.mem_gb).abs() < 1e-6
        && (a.disk_mbps - b.disk_mbps).abs() < 1e-6
        && (a.net_mbps - b.net_mbps).abs() < 1e-6
}

/// The cluster plus its shard map and per-shard digests. Reads deref
/// to the inner [`Cluster`]; every mutation goes through the methods
/// below (the "shard handles") so the digests stay consistent.
///
/// Power transitions in particular MUST use
/// [`ShardedCluster::power_on`] / [`ShardedCluster::power_off`] /
/// [`ShardedCluster::advance_power_states`] rather than reaching a
/// `&mut Host` directly — the digest's On count and accepting
/// capacity are maintained there.
#[derive(Debug, Clone)]
pub struct ShardedCluster {
    cluster: Cluster,
    map: ShardMap,
    /// Member host ids per shard, ascending — iteration order inside
    /// a shard matches the unsharded host sweep, which is what makes
    /// single-shard fan-out bit-identical to the flat path.
    members: Vec<Vec<HostId>>,
    digests: Vec<ShardDigest>,
    /// Per-shard commit epochs: bumped by every placement-visible
    /// mutation (see the module docs). Monotone, never reset.
    epochs: Vec<u64>,
}

impl Deref for ShardedCluster {
    type Target = Cluster;

    fn deref(&self) -> &Cluster {
        &self.cluster
    }
}

impl ShardedCluster {
    pub fn new(mut cluster: Cluster, shard_count: usize) -> ShardedCluster {
        let map = ShardMap::new(shard_count);
        let mut members = vec![Vec::new(); shard_count];
        for host in &mut cluster.hosts {
            // Default fault-domain topology: one rack per shard (an
            // explicit map overrides via `set_rack_map`).
            host.rack = map.shard_of(host.id);
            members[map.shard_of(host.id)].push(host.id);
        }
        let digests = (0..shard_count)
            .map(|s| {
                ShardDigest::compute(&cluster, members[s].iter().copied(), |h| {
                    map.shard_of(h) == s
                })
            })
            .collect();
        ShardedCluster {
            cluster,
            map,
            members,
            digests,
            epochs: vec![0; shard_count],
        }
    }

    /// Explicit read access to the inner cluster (also available
    /// through [`Deref`]).
    pub fn cluster(&self) -> &Cluster {
        &self.cluster
    }

    pub fn shard_count(&self) -> usize {
        self.map.count()
    }

    pub fn shard_of(&self, host: HostId) -> usize {
        self.map.shard_of(host)
    }

    pub fn members(&self, shard: usize) -> &[HostId] {
        &self.members[shard]
    }

    pub fn digest(&self, shard: usize) -> &ShardDigest {
        &self.digests[shard]
    }

    pub fn digests(&self) -> &[ShardDigest] {
        &self.digests
    }

    /// One shard's current commit epoch.
    pub fn shard_epoch(&self, shard: usize) -> u64 {
        self.epochs[shard]
    }

    /// All shard commit epochs, indexed by shard id.
    pub fn shard_epochs(&self) -> &[u64] {
        &self.epochs
    }

    /// One shard's digest stamped with its commit epoch — the
    /// coordinator-facing snapshot the commit protocol decides
    /// against.
    pub fn digest_snapshot(&self, shard: usize) -> DigestSnapshot {
        DigestSnapshot {
            shard,
            epoch: self.epochs[shard],
            digest: self.digests[shard],
        }
    }

    /// Bump one shard's commit epoch. Called by every mutator whose
    /// effect placement can observe (admission capacity, power and
    /// crash state, warm pools) — the write half of the staleness
    /// currency read by [`ShardedCluster::digest_snapshot`].
    fn bump_epoch(&mut self, shard: usize) {
        self.epochs[shard] += 1;
    }

    /// Build one shard's pruned scoring views into `out` (cleared
    /// first) — the per-shard analogue of `Cluster::scoring_views`,
    /// sharing the same per-host constructor so the two can never
    /// disagree on which hosts are placeable.
    pub fn shard_scoring_views(&self, shard: usize, delta_high: f64, out: &mut Vec<HostView>) {
        out.clear();
        for &h in &self.members[shard] {
            if let Some(v) = self.cluster.scoring_view_of(h, delta_high) {
                out.push(v);
            }
        }
    }

    // ---- shard handles: mutations with incremental digest upkeep ----

    pub fn create_vm(&mut self, flavor: Flavor, job: crate::workload::JobId, now: f64) -> VmId {
        // A pending VM is unplaced: no digest contribution yet.
        self.cluster.create_vm(flavor, job, now)
    }

    pub fn place_vm(&mut self, vm_id: VmId, host_id: HostId) -> Result<(), PlacementError> {
        let Some((expected, flavor)) = self
            .cluster
            .vms
            .get(&vm_id)
            .map(|vm| (vm.expected(), vm.flavor))
        else {
            return self.cluster.place_vm(vm_id, host_id);
        };
        self.cluster.place_vm(vm_id, host_id)?;
        let shard = self.map.shard_of(host_id);
        let d = &mut self.digests[shard];
        d.reserved.add(&reservation_of(&flavor));
        d.expected.add(&expected);
        d.per_class[demand_class(&expected, &flavor)].add(&expected);
        self.bump_epoch(shard);
        Ok(())
    }

    pub fn start_migration(
        &mut self,
        vm_id: VmId,
        to: HostId,
        now: f64,
        link_mbps: f64,
    ) -> Result<MigrationCost, PlacementError> {
        let info = self
            .cluster
            .vms
            .get(&vm_id)
            .map(|vm| (vm.expected(), vm.flavor));
        let cost = self.cluster.start_migration(vm_id, to, now, link_mbps)?;
        let (expected, flavor) = info.expect("VM exists after successful migration start");
        // The destination carries the reservation and the expected
        // load from copy start (both ends count while migrating).
        let shard = self.map.shard_of(to);
        let d = &mut self.digests[shard];
        d.reserved.add(&reservation_of(&flavor));
        d.expected.add(&expected);
        d.per_class[demand_class(&expected, &flavor)].add(&expected);
        self.bump_epoch(shard);
        Ok(cost)
    }

    pub fn finish_migration(&mut self, vm_id: VmId) {
        let Some((from, expected, flavor)) =
            self.cluster.vms.get(&vm_id).and_then(|vm| match vm.state {
                VmState::Migrating { from, .. } => Some((from, vm.expected(), vm.flavor)),
                _ => None,
            })
        else {
            // Let the cluster raise its own panic message.
            self.cluster.finish_migration(vm_id);
            return;
        };
        self.cluster.finish_migration(vm_id);
        // Source residency (and reservation) ends; the destination's
        // share was added at migration start.
        let shard = self.map.shard_of(from);
        let d = &mut self.digests[shard];
        d.reserved.sub(&reservation_of(&flavor));
        d.expected.sub(&expected);
        d.per_class[demand_class(&expected, &flavor)].sub(&expected);
        self.bump_epoch(shard);
    }

    pub fn terminate_vm(&mut self, vm_id: VmId) {
        let Some((host, expected, flavor)) = self
            .cluster
            .vms
            .get(&vm_id)
            .and_then(|vm| vm.host.map(|h| (h, vm.expected(), vm.flavor)))
        else {
            self.cluster.terminate_vm(vm_id);
            return;
        };
        self.cluster.terminate_vm(vm_id);
        let shard = self.map.shard_of(host);
        let d = &mut self.digests[shard];
        d.reserved.sub(&reservation_of(&flavor));
        d.expected.sub(&expected);
        d.per_class[demand_class(&expected, &flavor)].sub(&expected);
        self.bump_epoch(shard);
    }

    pub fn set_expected_demand(&mut self, vm_id: VmId, expected: Demand) {
        let Some((old, flavor, resident, incoming)) = self.cluster.vms.get(&vm_id).map(|vm| {
            let (r, i) = match vm.state {
                VmState::Migrating { from, to, .. } => (Some(from), Some(to)),
                _ => (vm.host, None),
            };
            (vm.expected(), vm.flavor, r, i)
        }) else {
            self.cluster.set_expected_demand(vm_id, expected);
            return;
        };
        self.cluster.set_expected_demand(vm_id, expected);
        let (oc, nc) = (
            demand_class(&old, &flavor),
            demand_class(&expected, &flavor),
        );
        for h in [resident, incoming].into_iter().flatten() {
            let shard = self.map.shard_of(h);
            let d = &mut self.digests[shard];
            d.expected.sub(&old);
            d.expected.add(&expected);
            d.per_class[oc].sub(&old);
            d.per_class[nc].add(&expected);
            self.bump_epoch(shard);
        }
    }

    pub fn apply_demands(
        &mut self,
        vm_demands: &std::collections::BTreeMap<VmId, Demand>,
    ) {
        // Instantaneous demand is not part of any digest.
        self.cluster.apply_demands(vm_demands);
    }

    /// Advance power-state machines, then recount the power-dependent
    /// digest fields (Booting→On completions happen here). O(hosts),
    /// same as the underlying advance.
    pub fn advance_power_states(&mut self, now: f64) {
        let before: Vec<(usize, usize)> =
            self.digests.iter().map(|d| (d.on, d.failed)).collect();
        self.cluster.advance_power_states(now);
        for d in &mut self.digests {
            d.on = 0;
            d.capacity_on = Demand::ZERO;
            d.failed = 0;
            d.capacity_lost = Demand::ZERO;
        }
        for host in &self.cluster.hosts {
            let d = &mut self.digests[self.map.shard_of(host.id)];
            if host.state.is_on() {
                d.on += 1;
            }
            if host.state.accepts_vms() {
                d.capacity_on.add(&host.spec.capacity());
            }
            if host.state.is_failed() {
                d.failed += 1;
                d.capacity_lost.add(&host.spec.capacity());
            }
        }
        // Boot completions change admission state: bump the epoch of
        // every shard whose power-dependent counts moved.
        for s in 0..self.digests.len() {
            if (self.digests[s].on, self.digests[s].failed) != before[s] {
                self.bump_epoch(s);
            }
        }
    }

    /// Advance ONE host's power-state machine (and container boots) to
    /// `now`, with incremental digest upkeep — the event core's
    /// per-host analogue of [`ShardedCluster::advance_power_states`],
    /// which stays as the tick engine's O(hosts) sweep. Only
    /// Booting→On can flip the On-dependent digest fields here
    /// (ShuttingDown already left them at `power_off` time), but the
    /// transition test is written symmetrically anyway.
    pub fn advance_host(&mut self, host: HostId, now: f64) {
        let was_on = self.cluster.hosts[host.0].state.is_on();
        let h = self.cluster.host_mut(host);
        h.state = h.state.advance(now);
        h.advance_containers(now);
        let is_on = self.cluster.hosts[host.0].state.is_on();
        if was_on != is_on {
            let cap = self.cluster.hosts[host.0].spec.capacity();
            let shard = self.map.shard_of(host);
            let d = &mut self.digests[shard];
            if is_on {
                d.on += 1;
                d.capacity_on.add(&cap);
            } else {
                d.on -= 1;
                d.capacity_on.sub(&cap);
            }
            self.bump_epoch(shard);
        }
    }

    /// Overwrite ONE host's instantaneous demand — the event core's
    /// per-host analogue of [`ShardedCluster::apply_demands`]
    /// (instantaneous demand is not part of any digest). The caller
    /// owns the capping-by-flavor and executing-host resolution that
    /// `apply_demands` does for the whole fleet.
    pub fn set_host_demand(&mut self, host: HostId, demand: Demand) {
        self.cluster.host_mut(host).demand = demand;
    }

    /// Begin booting a host. No digest change until the boot
    /// completes in [`ShardedCluster::advance_power_states`], but the
    /// epoch bumps immediately: the host leaves Off, which commits
    /// targeting it with `PowerOnAndPlace` can observe.
    pub fn power_on(&mut self, host: HostId, now: f64) {
        let was_off = self.cluster.hosts[host.0].state.is_off();
        self.cluster.host_mut(host).power_on(now);
        if was_off {
            self.bump_epoch(self.map.shard_of(host));
        }
    }

    /// Begin shutting a host down; the shard immediately stops
    /// counting it as accepting capacity.
    pub fn power_off(&mut self, host: HostId, now: f64) {
        let was_accepting = self.cluster.hosts[host.0].state.accepts_vms();
        let cap = self.cluster.hosts[host.0].spec.capacity();
        let warm = self.cluster.hosts[host.0].warm_count();
        self.cluster.host_mut(host).power_off(now);
        if was_accepting && !self.cluster.hosts[host.0].state.accepts_vms() {
            let shard = self.map.shard_of(host);
            let d = &mut self.digests[shard];
            d.on -= 1;
            d.capacity_on.sub(&cap);
            // The host's sandbox pool died with it.
            d.warm_containers -= warm;
            self.bump_epoch(shard);
        }
    }

    /// Set a host's DVFS point (frequency does not enter any digest —
    /// capacity aggregates are nominal).
    pub fn set_freq(&mut self, host: HostId, freq: f64) {
        self.cluster.host_mut(host).set_freq(freq);
    }

    /// Crash a host (see [`Cluster::fail_host`]), keeping every
    /// affected shard digest consistent in one pass: the crashed
    /// host's shard loses its On count, accepting capacity, and warm
    /// pool and gains a failed count + lost capacity; every killed
    /// VM's reservation/expected/class load leaves its shard, and
    /// abandoned copies (outgoing *and* incoming) release the
    /// destination's share wherever that destination lives.
    pub fn fail_host(&mut self, host_id: HostId, now: f64) -> crate::cluster::CrashOutcome {
        let shard = self.map.shard_of(host_id);
        let cap = self.cluster.hosts[host_id.0].spec.capacity();
        let warm = self.cluster.hosts[host_id.0].warm_count();
        // Collect (shard, reservation, expected, class) releases before
        // the crash rewrites VM state.
        let mut releases: Vec<(usize, Demand, Demand, usize)> = Vec::new();
        for &vm_id in &self.cluster.hosts[host_id.0].vms {
            let vm = &self.cluster.vms[&vm_id];
            let cls = demand_class(&vm.expected(), &vm.flavor);
            // The killed resident's own share.
            releases.push((shard, reservation_of(&vm.flavor), vm.expected(), cls));
            // An outgoing copy's destination share dies with the source.
            if let VmState::Migrating { to, .. } = vm.state {
                releases.push((self.map.shard_of(to), reservation_of(&vm.flavor), vm.expected(), cls));
            }
        }
        for vm in self.cluster.vms.values() {
            if let VmState::Migrating { from, to, .. } = vm.state {
                // Cancelled incoming copy: the crashed host held only
                // the destination share; the VM survives on `from`.
                if to == host_id && from != host_id {
                    let cls = demand_class(&vm.expected(), &vm.flavor);
                    releases.push((shard, reservation_of(&vm.flavor), vm.expected(), cls));
                }
            }
        }
        let out = self.cluster.fail_host(host_id, now);
        let d = &mut self.digests[shard];
        d.on -= 1;
        d.capacity_on.sub(&cap);
        d.warm_containers -= warm;
        d.failed += 1;
        d.capacity_lost.add(&cap);
        self.bump_epoch(shard);
        for (s, res, exp, cls) in releases {
            let d = &mut self.digests[s];
            d.reserved.sub(&res);
            d.expected.sub(&exp);
            d.per_class[cls].sub(&exp);
            self.bump_epoch(s);
        }
        out
    }

    /// Recover a crashed host: it reboots through the normal boot
    /// window (the shard regains On count and capacity when the boot
    /// completes in [`ShardedCluster::advance_power_states`]); the
    /// failed count and lost capacity are given back immediately.
    /// No-op unless the host is Failed.
    pub fn recover_host(&mut self, host: HostId, now: f64) {
        let was_failed = self.cluster.hosts[host.0].state.is_failed();
        let cap = self.cluster.hosts[host.0].spec.capacity();
        self.cluster.host_mut(host).recover(now);
        if was_failed {
            let shard = self.map.shard_of(host);
            let d = &mut self.digests[shard];
            d.failed -= 1;
            d.capacity_lost.sub(&cap);
            self.bump_epoch(shard);
        }
    }

    /// Override the default (shard-derived) fault-domain topology
    /// with an explicit host → rack assignment. Rack tags feed the
    /// [`HostView`] snapshots and the evacuation path's
    /// domain-diversity scoring; they enter no digest, but the tag is
    /// placement-visible (it biases scoring), so the epoch bumps.
    pub fn set_rack_map(&mut self, rack_of: &[usize]) {
        assert_eq!(
            rack_of.len(),
            self.cluster.n_hosts(),
            "rack map must cover every host"
        );
        for (h, &r) in rack_of.iter().enumerate() {
            self.cluster.hosts[h].rack = r;
        }
        for s in 0..self.map.count() {
            self.bump_epoch(s);
        }
    }

    /// Degrade a host's condition (flaky disk / thermal), with
    /// incremental digest upkeep. The condition layer is orthogonal
    /// to the power machine: a degraded host keeps running its
    /// residents, but admission refuses new VMs, so the epoch bumps.
    /// No-op when the host already carries the same condition.
    pub fn degrade_host(&mut self, host: HostId, condition: crate::cluster::HostCondition) {
        let h = &mut self.cluster.hosts[host.0];
        let was = h.is_degraded();
        h.condition = condition;
        // A thermal cap takes effect immediately on the current clock.
        if h.freq > h.freq_cap() {
            let cap = h.freq_cap();
            h.set_freq(cap);
        }
        let now_degraded = self.cluster.hosts[host.0].is_degraded();
        if was != now_degraded {
            let cap = self.cluster.hosts[host.0].spec.capacity();
            let shard = self.map.shard_of(host);
            let d = &mut self.digests[shard];
            if now_degraded {
                d.degraded += 1;
                d.capacity_degraded.add(&cap);
            } else {
                d.degraded -= 1;
                d.capacity_degraded.sub(&cap);
            }
        }
        self.bump_epoch(self.map.shard_of(host));
    }

    /// Restore a degraded host to full health (the inverse of
    /// [`ShardedCluster::degrade_host`]). The frequency ceiling
    /// lifts; the DVFS governor decides when to clock back up.
    pub fn restore_host(&mut self, host: HostId) {
        if !self.cluster.hosts[host.0].is_degraded() {
            return;
        }
        self.cluster.hosts[host.0].condition = crate::cluster::HostCondition::Healthy;
        let cap = self.cluster.hosts[host.0].spec.capacity();
        let shard = self.map.shard_of(host);
        let d = &mut self.digests[shard];
        d.degraded -= 1;
        d.capacity_degraded.sub(&cap);
        self.bump_epoch(shard);
    }

    // ---- serverless sandbox handles ----------------------------------

    /// Claim a warm sandbox for `function` on `host`; true on a warm
    /// hit (the sandbox leaves the pool and the digest's warm count).
    pub fn claim_warm_container(
        &mut self,
        host: HostId,
        function: crate::workload::faas::FunctionId,
    ) -> bool {
        if self.cluster.host_mut(host).claim_warm(function) {
            let shard = self.map.shard_of(host);
            self.digests[shard].warm_containers -= 1;
            self.bump_epoch(shard);
            true
        } else {
            false
        }
    }

    /// Install a cold-starting sandbox (not warm: no digest change
    /// until it completes an invocation and parks).
    pub fn install_booting_container(
        &mut self,
        host: HostId,
        function: crate::workload::faas::FunctionId,
        mem_gb: f64,
        until: f64,
    ) {
        self.cluster
            .host_mut(host)
            .install_booting(function, mem_gb, until);
    }

    /// Park a sandbox warm until `expires_at`.
    pub fn park_warm_container(
        &mut self,
        host: HostId,
        function: crate::workload::faas::FunctionId,
        mem_gb: f64,
        expires_at: f64,
    ) {
        self.cluster
            .host_mut(host)
            .park_warm(function, mem_gb, expires_at);
        let shard = self.map.shard_of(host);
        self.digests[shard].warm_containers += 1;
        self.bump_epoch(shard);
    }

    /// Evict expired warm sandboxes on `host`; returns how many died.
    /// Idempotent, so actuating a stale scan result is harmless.
    pub fn expire_containers(&mut self, host: HostId, now: f64) -> usize {
        let n = self.cluster.host_mut(host).expire_warm(now);
        if n > 0 {
            let shard = self.map.shard_of(host);
            self.digests[shard].warm_containers -= n;
            self.bump_epoch(shard);
        }
        n
    }

    /// Cluster invariants plus the shard layer's own: the member
    /// lists partition the host set consistently with the map, and
    /// every incremental digest matches a fresh recomputation from
    /// the VM inventory.
    pub fn check_invariants(&self) -> Result<(), String> {
        self.cluster.check_invariants()?;
        let mut seen = vec![false; self.cluster.n_hosts()];
        for (s, members) in self.members.iter().enumerate() {
            for &h in members {
                if self.map.shard_of(h) != s {
                    return Err(format!("{h} listed in shard {s} but hashes elsewhere"));
                }
                if seen[h.0] {
                    return Err(format!("{h} listed in more than one shard"));
                }
                seen[h.0] = true;
            }
        }
        if let Some(missing) = seen.iter().position(|&b| !b) {
            return Err(format!("host-{missing} missing from the shard map"));
        }
        for s in 0..self.map.count() {
            let fresh = ShardDigest::compute(&self.cluster, self.members[s].iter().copied(), |h| {
                self.map.shard_of(h) == s
            });
            let d = &self.digests[s];
            if d.hosts != fresh.hosts || d.on != fresh.on {
                return Err(format!(
                    "shard {s}: digest counts {}/{} != recomputed {}/{}",
                    d.hosts, d.on, fresh.hosts, fresh.on
                ));
            }
            if d.failed != fresh.failed {
                return Err(format!(
                    "shard {s}: failed hosts {} != recomputed {}",
                    d.failed, fresh.failed
                ));
            }
            if !demand_close(&d.capacity_lost, &fresh.capacity_lost) {
                return Err(format!(
                    "shard {s}: capacity_lost {:?} != recomputed {:?}",
                    d.capacity_lost, fresh.capacity_lost
                ));
            }
            if d.degraded != fresh.degraded {
                return Err(format!(
                    "shard {s}: degraded hosts {} != recomputed {}",
                    d.degraded, fresh.degraded
                ));
            }
            if !demand_close(&d.capacity_degraded, &fresh.capacity_degraded) {
                return Err(format!(
                    "shard {s}: capacity_degraded {:?} != recomputed {:?}",
                    d.capacity_degraded, fresh.capacity_degraded
                ));
            }
            if d.warm_containers != fresh.warm_containers {
                return Err(format!(
                    "shard {s}: warm containers {} != recomputed {}",
                    d.warm_containers, fresh.warm_containers
                ));
            }
            if !demand_close(&d.capacity_on, &fresh.capacity_on) {
                return Err(format!(
                    "shard {s}: capacity_on {:?} != recomputed {:?}",
                    d.capacity_on, fresh.capacity_on
                ));
            }
            if !demand_close(&d.reserved, &fresh.reserved) {
                return Err(format!(
                    "shard {s}: reserved {:?} != recomputed {:?}",
                    d.reserved, fresh.reserved
                ));
            }
            if !demand_close(&d.expected, &fresh.expected) {
                return Err(format!(
                    "shard {s}: expected {:?} != recomputed {:?}",
                    d.expected, fresh.expected
                ));
            }
            for k in 0..N_LOAD_CLASSES {
                if !demand_close(&d.per_class[k], &fresh.per_class[k]) {
                    return Err(format!(
                        "shard {s}: class {k} load {:?} != recomputed {:?}",
                        d.per_class[k], fresh.per_class[k]
                    ));
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::flavor::MEDIUM;
    use crate::workload::JobId;

    #[test]
    #[should_panic(expected = "power of two")]
    fn shard_count_must_be_power_of_two() {
        ShardMap::new(3);
    }

    #[test]
    fn members_partition_hosts() {
        for count in [1usize, 2, 4, 8] {
            let sc = ShardedCluster::new(Cluster::homogeneous(23), count);
            let total: usize = (0..count).map(|s| sc.members(s).len()).sum();
            assert_eq!(total, 23);
            for s in 0..count {
                for &h in sc.members(s) {
                    assert_eq!(sc.shard_of(h), s);
                }
                // Ascending member order (matches the unsharded sweep).
                let m = sc.members(s);
                assert!(m.windows(2).all(|w| w[0] < w[1]));
            }
            sc.check_invariants().unwrap();
        }
    }

    #[test]
    fn digest_tracks_placement_lifecycle() {
        let mut sc = ShardedCluster::new(Cluster::homogeneous(4), 2);
        let host = HostId(0);
        let shard = sc.shard_of(host);
        let head0 = sc.digest(shard).headroom();
        let vm = sc.create_vm(MEDIUM, JobId(1), 0.0);
        sc.place_vm(vm, host).unwrap();
        sc.check_invariants().unwrap();
        let head1 = sc.digest(shard).headroom();
        assert!((head0.mem_gb - head1.mem_gb - MEDIUM.mem_gb).abs() < 1e-9);
        let d = Demand {
            cpu: 2.0,
            mem_gb: 6.0,
            disk_mbps: 120.0,
            net_mbps: 20.0,
        };
        sc.set_expected_demand(vm, d);
        sc.check_invariants().unwrap();
        assert!((sc.digest(shard).expected.mem_gb - 6.0).abs() < 1e-9);
        // Migrate to a host in the other shard (both ends count during
        // the copy; the source's share is released at cut-over).
        let to = (0..4)
            .map(HostId)
            .find(|&h| sc.shard_of(h) != shard)
            .expect("4 hosts hash into both of 2 shards");
        sc.start_migration(vm, to, 0.0, 100.0).unwrap();
        sc.check_invariants().unwrap();
        assert!((sc.digest(sc.shard_of(to)).expected.mem_gb - 6.0).abs() < 1e-9);
        sc.finish_migration(vm);
        sc.check_invariants().unwrap();
        assert!(sc.digest(shard).expected.mem_gb.abs() < 1e-9);
        sc.terminate_vm(vm);
        sc.check_invariants().unwrap();
        assert!(sc.digest(sc.shard_of(to)).expected.mem_gb.abs() < 1e-9);
    }

    #[test]
    fn power_transitions_update_digest() {
        let mut sc = ShardedCluster::new(Cluster::homogeneous(4), 2);
        let host = HostId(1);
        let shard = sc.shard_of(host);
        let on0 = sc.digest(shard).on;
        sc.power_off(host, 0.0);
        assert_eq!(sc.digest(shard).on, on0 - 1);
        sc.check_invariants().unwrap();
        sc.advance_power_states(100.0); // ShuttingDown → Off
        sc.check_invariants().unwrap();
        sc.power_on(host, 100.0); // Off → Booting: still not on
        assert_eq!(sc.digest(shard).on, on0 - 1);
        sc.check_invariants().unwrap();
        sc.advance_power_states(300.0); // Booting → On
        assert_eq!(sc.digest(shard).on, on0);
        sc.check_invariants().unwrap();
    }

    #[test]
    fn advance_host_matches_fleet_advance_in_digests() {
        let mut sc = ShardedCluster::new(Cluster::homogeneous(4), 2);
        let host = HostId(1);
        let shard = sc.shard_of(host);
        let on0 = sc.digest(shard).on;
        sc.power_off(host, 0.0);
        assert_eq!(sc.digest(shard).on, on0 - 1);
        // Per-host advance through ShuttingDown→Off: no digest motion.
        sc.advance_host(host, 100.0);
        assert!(sc.cluster().host(host).state.is_off());
        sc.check_invariants().unwrap();
        // Off → Booting → On via the single-host path.
        sc.power_on(host, 100.0);
        sc.advance_host(host, 150.0); // still booting
        assert_eq!(sc.digest(shard).on, on0 - 1);
        sc.advance_host(host, 100.0 + crate::cluster::power::BOOT_SECS);
        assert_eq!(sc.digest(shard).on, on0);
        sc.check_invariants().unwrap();
        // Untouched hosts were never advanced and stay consistent.
        sc.advance_power_states(1000.0);
        sc.check_invariants().unwrap();
    }

    #[test]
    fn set_host_demand_is_digest_free() {
        let mut sc = ShardedCluster::new(Cluster::homogeneous(2), 1);
        let d = Demand {
            cpu: 3.0,
            mem_gb: 6.0,
            disk_mbps: 80.0,
            net_mbps: 12.0,
        };
        sc.set_host_demand(HostId(0), d);
        assert_eq!(sc.cluster().host(HostId(0)).demand, d);
        sc.check_invariants().unwrap();
    }

    #[test]
    fn warm_container_digest_tracks_sandbox_lifecycle() {
        use crate::workload::faas::FunctionId;
        let mut sc = ShardedCluster::new(Cluster::homogeneous(4), 2);
        let host = HostId(0);
        let shard = sc.shard_of(host);
        // Cold start: booting sandboxes are not warm.
        sc.install_booting_container(host, FunctionId(1), 0.5, 2.0);
        assert_eq!(sc.digest(shard).warm_containers, 0);
        sc.check_invariants().unwrap();
        sc.advance_power_states(5.0); // boot completes, no warmth yet
        sc.check_invariants().unwrap();
        // Park warm → counted; claim → released.
        sc.park_warm_container(host, FunctionId(1), 0.5, 100.0);
        assert_eq!(sc.digest(shard).warm_containers, 1);
        sc.check_invariants().unwrap();
        assert!(sc.claim_warm_container(host, FunctionId(1)));
        assert_eq!(sc.digest(shard).warm_containers, 0);
        assert!(!sc.claim_warm_container(host, FunctionId(1)));
        sc.check_invariants().unwrap();
        // Expiry path.
        sc.park_warm_container(host, FunctionId(2), 0.25, 50.0);
        assert_eq!(sc.expire_containers(host, 60.0), 1);
        assert_eq!(sc.digest(shard).warm_containers, 0);
        sc.check_invariants().unwrap();
        // Power-off drops the pool and the digest together.
        sc.park_warm_container(host, FunctionId(3), 0.25, 1e9);
        sc.power_off(host, 0.0);
        assert_eq!(sc.digest(shard).warm_containers, 0);
        sc.check_invariants().unwrap();
    }

    #[test]
    fn fail_host_keeps_digests_consistent_through_crash_and_recovery() {
        use crate::workload::faas::FunctionId;
        let mut sc = ShardedCluster::new(Cluster::homogeneous(8), 2);
        let host = HostId(0);
        let shard = sc.shard_of(host);
        let vm = sc.create_vm(MEDIUM, JobId(1), 0.0);
        sc.place_vm(vm, host).unwrap();
        sc.set_expected_demand(
            vm,
            Demand {
                cpu: 2.0,
                mem_gb: 4.0,
                disk_mbps: 50.0,
                net_mbps: 5.0,
            },
        );
        sc.park_warm_container(host, FunctionId(1), 0.5, 1e9);
        let on0 = sc.digest(shard).on;
        let out = sc.fail_host(host, 10.0);
        assert_eq!(out.killed, vec![vm]);
        let d = *sc.digest(shard);
        assert_eq!(d.failed, 1);
        assert_eq!(d.on, on0 - 1);
        assert!(d.capacity_lost.mem_gb > 0.0);
        assert!(d.reserved.mem_gb.abs() < 1e-9);
        assert!(d.expected.mem_gb.abs() < 1e-9);
        sc.check_invariants().unwrap();
        // A long advance never resurrects a crashed host.
        sc.advance_power_states(1e7);
        assert_eq!(sc.digest(shard).failed, 1);
        sc.check_invariants().unwrap();
        // Recovery reboots through the boot window.
        sc.recover_host(host, 1e7);
        assert_eq!(sc.digest(shard).failed, 0);
        assert!(sc.digest(shard).capacity_lost.mem_gb.abs() < 1e-9);
        assert_eq!(sc.digest(shard).on, on0 - 1); // still booting
        sc.check_invariants().unwrap();
        sc.advance_power_states(1e7 + crate::cluster::power::BOOT_SECS);
        assert_eq!(sc.digest(shard).on, on0);
        sc.check_invariants().unwrap();
    }

    #[test]
    fn fail_host_mid_migration_releases_both_ends_in_digests() {
        let mut sc = ShardedCluster::new(Cluster::homogeneous(8), 2);
        let src = HostId(0);
        let dst = (1..8)
            .map(HostId)
            .find(|&h| sc.shard_of(h) != sc.shard_of(src))
            .expect("8 hosts hash into both of 2 shards");
        let vm = sc.create_vm(MEDIUM, JobId(1), 0.0);
        sc.place_vm(vm, src).unwrap();
        sc.start_migration(vm, dst, 0.0, 100.0).unwrap();
        // Destination crashes: copy cancelled, VM survives on source.
        sc.fail_host(dst, 1.0);
        assert_eq!(sc.cluster().vms[&vm].state, VmState::Running);
        assert!((sc.digest(sc.shard_of(src)).reserved.mem_gb - MEDIUM.mem_gb).abs() < 1e-9);
        assert!(sc.digest(sc.shard_of(dst)).reserved.mem_gb.abs() < 1e-9);
        sc.check_invariants().unwrap();
        // Now the source crashes too: the VM dies with it.
        sc.fail_host(src, 2.0);
        assert_eq!(sc.cluster().vms[&vm].state, VmState::Terminated);
        assert!(sc.digest(sc.shard_of(src)).reserved.mem_gb.abs() < 1e-9);
        sc.check_invariants().unwrap();
    }

    #[test]
    fn commit_epochs_advance_with_placement_visible_mutations() {
        let mut sc = ShardedCluster::new(Cluster::homogeneous(4), 2);
        assert!(sc.shard_epochs().iter().all(|&e| e == 0));
        let host = HostId(0);
        let shard = sc.shard_of(host);
        let other = 1 - shard;
        let snap = sc.digest_snapshot(shard);
        assert_eq!(snap.epoch, 0);
        assert_eq!(snap.shard, shard);
        // Placement bumps the target shard only.
        let vm = sc.create_vm(MEDIUM, JobId(1), 0.0);
        sc.place_vm(vm, host).unwrap();
        assert_eq!(sc.shard_epoch(shard), 1);
        assert_eq!(sc.shard_epoch(other), 0);
        // The snapshot taken before the placement never ages.
        assert_eq!(snap.epoch, 0);
        assert!(sc.digest_snapshot(shard).epoch > snap.epoch);
        // Termination releases capacity: another bump.
        sc.terminate_vm(vm);
        assert_eq!(sc.shard_epoch(shard), 2);
        // Power-off flips admission state; the later ShuttingDown→Off
        // advance changes no digest counts and is epoch-silent.
        sc.power_off(host, 0.0);
        assert_eq!(sc.shard_epoch(shard), 3);
        sc.advance_power_states(100.0);
        assert_eq!(sc.shard_epoch(shard), 3);
        // Off→Booting (power_on) and Booting→On (advance) both bump.
        sc.power_on(host, 100.0);
        assert_eq!(sc.shard_epoch(shard), 4);
        sc.advance_power_states(100.0 + crate::cluster::power::BOOT_SECS);
        assert_eq!(sc.shard_epoch(shard), 5);
        assert_eq!(sc.shard_epoch(other), 0);
        sc.check_invariants().unwrap();
    }

    #[test]
    fn degrade_and_restore_keep_digests_consistent() {
        use crate::cluster::HostCondition;
        let mut sc = ShardedCluster::new(Cluster::homogeneous(4), 2);
        let host = HostId(0);
        let shard = sc.shard_of(host);
        let e0 = sc.shard_epoch(shard);
        sc.degrade_host(host, HostCondition::FlakyDisk);
        assert_eq!(sc.digest(shard).degraded, 1);
        assert!(sc.digest(shard).capacity_degraded.mem_gb > 0.0);
        assert!(sc.shard_epoch(shard) > e0, "degrade is placement-visible");
        sc.check_invariants().unwrap();
        // A thermal degrade on an already-degraded host changes the
        // condition but not the count.
        sc.degrade_host(host, HostCondition::Thermal);
        assert_eq!(sc.digest(shard).degraded, 1);
        assert!(sc.cluster().host(host).freq <= crate::cluster::THERMAL_FREQ_CAP);
        sc.check_invariants().unwrap();
        sc.restore_host(host);
        assert_eq!(sc.digest(shard).degraded, 0);
        assert!(sc.digest(shard).capacity_degraded.mem_gb.abs() < 1e-9);
        sc.check_invariants().unwrap();
        // Restore on a healthy host is a no-op.
        let e1 = sc.shard_epoch(shard);
        sc.restore_host(host);
        assert_eq!(sc.shard_epoch(shard), e1);
        sc.check_invariants().unwrap();
    }

    #[test]
    fn rack_tags_default_to_shards_and_accept_overrides() {
        let mut sc = ShardedCluster::new(Cluster::homogeneous(6), 2);
        for h in 0..6 {
            assert_eq!(sc.cluster().host(HostId(h)).rack, sc.shard_of(HostId(h)));
        }
        sc.set_rack_map(&[0, 0, 1, 1, 2, 2]);
        assert_eq!(sc.cluster().host(HostId(4)).rack, 2);
        sc.check_invariants().unwrap();
    }

    #[test]
    fn class_buckets_attribute_expected_load() {
        let mut sc = ShardedCluster::new(Cluster::homogeneous(2), 1);
        let vm = sc.create_vm(MEDIUM, JobId(0), 0.0);
        sc.place_vm(vm, HostId(0)).unwrap();
        // Disk-dominant expectation → io-bound bucket.
        sc.set_expected_demand(
            vm,
            Demand {
                cpu: 0.5,
                mem_gb: 1.0,
                disk_mbps: 180.0,
                net_mbps: 5.0,
            },
        );
        let io = sc.digest(0).class_load(WorkloadClass::IoBound);
        assert!((io.disk_mbps - 180.0).abs() < 1e-9);
        assert_eq!(
            sc.digest(0).class_load(WorkloadClass::CpuBound).disk_mbps,
            0.0
        );
        sc.check_invariants().unwrap();
    }
}
