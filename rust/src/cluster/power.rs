//! Host power model — Eq. 5 of the paper:
//!
//! ```text
//! E_h(t) = P_idle + α·U_cpu(t) + β·U_mem(t) + γ·U_io(t)
//! ```
//!
//! plus the pieces the equation abstracts over but the evaluation
//! depends on: powered-off draw, boot/shutdown transients, and DVFS
//! (the paper applies CPU frequency scaling to I/O-bound workloads,
//! §III-C). Coefficients are calibrated to the testbed class the paper
//! reports (dual-socket Intel Xeon, 64 GB, SSD): idle ≈ 110 W, full
//! load ≈ 280 W — consistent with SPECpower results for that class and
//! with Morabito's virtualization power study the paper cites [20].

/// Discrete DVFS operating points: relative core frequency.
pub const PSTATES: [f64; 4] = [1.0, 0.85, 0.7, 0.6];

/// Snap a requested frequency to the nearest catalog p-state — the
/// ONE snapping rule shared by `Host::set_freq` and planning models
/// (the power-cap loop) that predict a SetFreq's effect before
/// actuating it, so plan and actuation can never diverge.
pub fn snap_to_pstate(target: f64) -> f64 {
    PSTATES
        .iter()
        .copied()
        .min_by(|a, b| {
            (a - target)
                .abs()
                .partial_cmp(&(b - target).abs())
                .unwrap()
        })
        .unwrap()
}

/// Linear-in-utilization power model with DVFS-aware CPU term.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PowerModel {
    /// Idle draw with the OS up, no load (W).
    pub p_idle: f64,
    /// CPU coefficient α (W at 100 % CPU, full frequency).
    pub alpha: f64,
    /// Memory coefficient β (W at 100 % memory bandwidth pressure).
    pub beta: f64,
    /// I/O coefficient γ (W at 100 % disk+net activity).
    pub gamma: f64,
    /// Draw when powered off — BMC/IPMI keeps sipping (W).
    pub p_off: f64,
    /// Mean draw while booting (W) — BIOS/POST spins fans and disks at
    /// full tilt before any governor engages.
    pub p_boot: f64,
    /// Mean draw while shutting down cleanly (W) — service teardown at
    /// mostly-idle CPU.
    pub p_shutdown: f64,
}

/// Default model for the paper's Xeon host class.
pub const XEON_64GB: PowerModel = PowerModel {
    p_idle: 110.0,
    alpha: 140.0,
    beta: 16.0,
    gamma: 14.0,
    p_off: 5.0,
    p_boot: HOST_START_UP_POWER,
    p_shutdown: HOST_SHUT_DOWN_POWER,
};

impl PowerModel {
    /// Instantaneous active power (W) for the given utilizations
    /// (each in [0,1]) at DVFS point `freq` (relative frequency in
    /// (0,1]).
    ///
    /// The CPU term scales ≈ quadratically with frequency (dynamic
    /// power ∝ f·V² and V tracks f in the DVFS range); a floor of 0.3
    /// captures static/leakage power that frequency scaling cannot
    /// remove. Memory and I/O draws are frequency-independent.
    pub fn active_power(&self, u_cpu: f64, u_mem: f64, u_io: f64, freq: f64) -> f64 {
        debug_assert!((0.0..=1.0).contains(&u_cpu), "u_cpu={u_cpu}");
        debug_assert!((0.0..=1.0).contains(&u_mem), "u_mem={u_mem}");
        debug_assert!((0.0..=1.0).contains(&u_io), "u_io={u_io}");
        debug_assert!(freq > 0.0 && freq <= 1.0);
        let cpu_scale = 0.3 + 0.7 * freq * freq;
        self.p_idle + self.alpha * u_cpu * cpu_scale + self.beta * u_mem + self.gamma * u_io
    }

    /// Peak power at full load, full frequency.
    pub fn p_peak(&self) -> f64 {
        self.active_power(1.0, 1.0, 1.0, 1.0)
    }

    /// Energy-proportionality ratio (idle/peak) — the figure-1 context
    /// metric: Xeon-class servers idle at ~40 % of peak, which is what
    /// makes consolidation + power-down profitable.
    pub fn idle_fraction(&self) -> f64 {
        self.p_idle / self.p_peak()
    }
}

/// Power state machine for a host. Transitions carry real delays and
/// energy cost, so the consolidation policy pays honestly for cycling
/// hosts (the reason Eq. 8 migrations only pay off on sustained idle).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum PowerState {
    On,
    /// Booting until the contained simulation time.
    Booting { until: f64 },
    Off,
    /// Shutting down until the contained simulation time.
    ShuttingDown { until: f64 },
    /// Crashed. Resident VMs and warm containers are gone; the host
    /// draws BMC power only and stays here until an explicit
    /// recovery (`Host::recover`) reboots it — `advance` never
    /// leaves this state on its own.
    Failed,
}

/// Boot duration for the Xeon class (BIOS + kernel + services),
/// seconds — CloudSim Plus's `HOST_START_UP_DELAY`.
pub const HOST_START_UP_DELAY: f64 = 90.0;
/// Clean shutdown duration, seconds — CloudSim Plus's
/// `HOST_SHUT_DOWN_DELAY`.
pub const HOST_SHUT_DOWN_DELAY: f64 = 30.0;
/// Mean draw during boot, W — CloudSim Plus's `HOST_START_UP_POWER`.
/// Above idle: POST runs fans/disks flat out with no governor.
pub const HOST_START_UP_POWER: f64 = 160.0;
/// Mean draw during clean shutdown, W — CloudSim Plus's
/// `HOST_SHUT_DOWN_POWER`. Near idle: service teardown is I/O-light.
pub const HOST_SHUT_DOWN_POWER: f64 = 120.0;

/// Boot duration alias kept for the many call sites that predate the
/// CloudSim-Plus-style naming.
pub const BOOT_SECS: f64 = HOST_START_UP_DELAY;
/// Shutdown duration alias, likewise.
pub const SHUTDOWN_SECS: f64 = HOST_SHUT_DOWN_DELAY;

impl PowerState {
    pub fn is_on(&self) -> bool {
        matches!(self, PowerState::On)
    }

    pub fn is_off(&self) -> bool {
        matches!(self, PowerState::Off)
    }

    /// Crashed and not yet recovered?
    pub fn is_failed(&self) -> bool {
        matches!(self, PowerState::Failed)
    }

    /// Can the host accept placements right now?
    pub fn accepts_vms(&self) -> bool {
        self.is_on()
    }

    /// Advance the state machine to time `now`, completing any due
    /// transition. Returns the new state.
    pub fn advance(self, now: f64) -> PowerState {
        match self {
            PowerState::Booting { until } if now >= until => PowerState::On,
            PowerState::ShuttingDown { until } if now >= until => PowerState::Off,
            s => s,
        }
    }

    /// Draw (W) in this state given the active-power callback.
    pub fn power(&self, model: &PowerModel, active: impl Fn() -> f64) -> f64 {
        match self {
            PowerState::On => active(),
            PowerState::Off | PowerState::Failed => model.p_off,
            PowerState::Booting { .. } => model.p_boot,
            PowerState::ShuttingDown { .. } => model.p_shutdown,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn idle_and_peak_match_xeon_class() {
        let m = XEON_64GB;
        assert_eq!(m.active_power(0.0, 0.0, 0.0, 1.0), 110.0);
        let peak = m.p_peak();
        assert!(
            (270.0..=290.0).contains(&peak),
            "peak {peak} outside Xeon class"
        );
    }

    #[test]
    fn power_is_monotone_in_utilization() {
        let m = XEON_64GB;
        let mut last = 0.0;
        for i in 0..=10 {
            let u = i as f64 / 10.0;
            let p = m.active_power(u, u, u, 1.0);
            assert!(p > last);
            last = p;
        }
    }

    #[test]
    fn dvfs_reduces_cpu_power() {
        let m = XEON_64GB;
        let full = m.active_power(0.8, 0.2, 0.6, 1.0);
        let scaled = m.active_power(0.8, 0.2, 0.6, 0.6);
        assert!(scaled < full);
        // Only the CPU term scales: the delta is bounded by α·u_cpu.
        assert!(full - scaled < m.alpha * 0.8);
        // Leakage floor: even at the lowest p-state some CPU power remains.
        let floor = m.active_power(0.8, 0.0, 0.0, PSTATES[3]);
        assert!(floor > m.p_idle + 0.3 * m.alpha * 0.8 * 0.99);
    }

    #[test]
    fn idle_fraction_around_forty_percent() {
        let f = XEON_64GB.idle_fraction();
        assert!((0.35..=0.45).contains(&f), "idle fraction {f}");
    }

    #[test]
    fn state_machine_transitions() {
        let s = PowerState::Booting { until: 100.0 };
        assert_eq!(s.advance(50.0), PowerState::Booting { until: 100.0 });
        assert_eq!(s.advance(100.0), PowerState::On);
        let s = PowerState::ShuttingDown { until: 30.0 };
        assert_eq!(s.advance(31.0), PowerState::Off);
        assert!(!s.accepts_vms());
        assert!(PowerState::On.accepts_vms());
    }

    #[test]
    fn failed_state_is_terminal_and_draws_bmc_power() {
        let m = XEON_64GB;
        let s = PowerState::Failed;
        assert!(!s.accepts_vms());
        assert!(!s.is_on());
        assert!(!s.is_off());
        assert!(s.is_failed());
        // advance never auto-recovers a crashed host.
        assert_eq!(s.advance(1e12), PowerState::Failed);
        let p = s.power(&m, || panic!("active must not be called"));
        assert_eq!(p, m.p_off);
    }

    #[test]
    fn off_state_draws_bmc_power() {
        let m = XEON_64GB;
        let p = PowerState::Off.power(&m, || panic!("active must not be called"));
        assert_eq!(p, m.p_off);
        let p = PowerState::Booting { until: 1.0 }.power(&m, || 0.0);
        assert_eq!(p, m.p_boot);
        let p = PowerState::ShuttingDown { until: 1.0 }.power(&m, || 0.0);
        assert_eq!(p, m.p_shutdown);
        // Transient draws bracket idle the way real hosts do.
        assert!(m.p_boot > m.p_idle);
        assert!(m.p_shutdown >= m.p_idle);
    }

    #[test]
    fn cycling_a_host_costs_energy() {
        // Boot (90 s @160 W) + shutdown (30 s @120 W) = 18 kJ; idling
        // the same 120 s costs 13.2 kJ — power cycling only pays off on
        // sustained idle (> ~35 s extra beyond the cycle itself).
        let m = XEON_64GB;
        let cycle_j = m.p_boot * BOOT_SECS + m.p_shutdown * SHUTDOWN_SECS;
        let idle_j = m.p_idle * (BOOT_SECS + SHUTDOWN_SECS);
        assert!(cycle_j > idle_j);
    }
}
