//! Time-series recording: (t, value) samples with step-function
//! integration. Used for active-host counts, utilization timelines, and
//! power traces (§V-D plots and the energy meter's integration checks).

/// A step-function time series: value is `v[i]` on `[t[i], t[i+1])`.
#[derive(Debug, Clone, Default)]
pub struct Timeline {
    ts: Vec<f64>,
    vs: Vec<f64>,
}

impl Timeline {
    pub fn new() -> Timeline {
        Timeline::default()
    }

    /// Record a sample. Times must be non-decreasing; a sample at the
    /// same time overwrites (last write wins — matches events that
    /// change state multiple times in one instant).
    pub fn push(&mut self, t: f64, v: f64) {
        if let Some(&last) = self.ts.last() {
            assert!(
                t >= last,
                "timeline must be monotone: got {t} after {last}"
            );
            if (t - last).abs() < 1e-12 {
                *self.vs.last_mut().unwrap() = v;
                return;
            }
        }
        self.ts.push(t);
        self.vs.push(v);
    }

    pub fn len(&self) -> usize {
        self.ts.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ts.is_empty()
    }

    pub fn times(&self) -> &[f64] {
        &self.ts
    }

    pub fn values(&self) -> &[f64] {
        &self.vs
    }

    /// Value at time t (step semantics); None before the first sample.
    pub fn at(&self, t: f64) -> Option<f64> {
        if self.ts.is_empty() || t < self.ts[0] {
            return None;
        }
        // Binary search for the last sample with ts <= t.
        let idx = match self
            .ts
            .binary_search_by(|x| x.partial_cmp(&t).expect("NaN time"))
        {
            Ok(i) => i,
            Err(i) => i - 1,
        };
        Some(self.vs[idx])
    }

    /// ∫ v dt over [t0, t1] with step semantics. The series is treated
    /// as holding its last value until t1.
    pub fn integrate(&self, t0: f64, t1: f64) -> f64 {
        assert!(t1 >= t0);
        if self.ts.is_empty() {
            return 0.0;
        }
        let mut total = 0.0;
        for i in 0..self.ts.len() {
            let seg_start = self.ts[i].max(t0);
            let seg_end = if i + 1 < self.ts.len() {
                self.ts[i + 1].min(t1)
            } else {
                t1
            };
            if seg_end > seg_start {
                total += self.vs[i] * (seg_end - seg_start);
            }
        }
        total
    }

    /// Time-weighted mean over [t0, t1].
    pub fn time_mean(&self, t0: f64, t1: f64) -> f64 {
        if t1 <= t0 {
            return 0.0;
        }
        self.integrate(t0, t1) / (t1 - t0)
    }

    /// Total time in [t0, t1] during which value ≥ threshold.
    pub fn time_above(&self, threshold: f64, t0: f64, t1: f64) -> f64 {
        if self.ts.is_empty() || t1 <= t0 {
            return 0.0;
        }
        let mut total = 0.0;
        for i in 0..self.ts.len() {
            if self.vs[i] < threshold {
                continue;
            }
            let seg_start = self.ts[i].max(t0);
            let seg_end = if i + 1 < self.ts.len() {
                self.ts[i + 1].min(t1)
            } else {
                t1
            };
            if seg_end > seg_start {
                total += seg_end - seg_start;
            }
        }
        total
    }

    /// Downsample to `n` evenly spaced points over [t0, t1] — for ASCII
    /// plots and CSV figure exports.
    pub fn resample(&self, t0: f64, t1: f64, n: usize) -> Vec<(f64, f64)> {
        assert!(n >= 2);
        (0..n)
            .map(|i| {
                let t = t0 + (t1 - t0) * i as f64 / (n - 1) as f64;
                (t, self.at(t).unwrap_or(0.0))
            })
            .collect()
    }
}

/// Render a compact ASCII sparkline of a series (figure exports get the
/// CSV; the terminal gets this).
pub fn sparkline(values: &[f64]) -> String {
    const TICKS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
    if values.is_empty() {
        return String::new();
    }
    let lo = values.iter().cloned().fold(f64::INFINITY, f64::min);
    let hi = values.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let span = (hi - lo).max(1e-12);
    values
        .iter()
        .map(|v| {
            let idx = (((v - lo) / span) * 7.0).round() as usize;
            TICKS[idx.min(7)]
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tl() -> Timeline {
        let mut t = Timeline::new();
        t.push(0.0, 100.0);
        t.push(10.0, 200.0);
        t.push(20.0, 50.0);
        t
    }

    #[test]
    fn at_step_semantics() {
        let t = tl();
        assert_eq!(t.at(-1.0), None);
        assert_eq!(t.at(0.0), Some(100.0));
        assert_eq!(t.at(9.99), Some(100.0));
        assert_eq!(t.at(10.0), Some(200.0));
        assert_eq!(t.at(100.0), Some(50.0));
    }

    #[test]
    fn integrate_full_range() {
        let t = tl();
        // 10s*100 + 10s*200 + 10s*50 = 3500 over [0,30].
        assert!((t.integrate(0.0, 30.0) - 3500.0).abs() < 1e-9);
    }

    #[test]
    fn integrate_partial_range() {
        let t = tl();
        // [5, 15]: 5s*100 + 5s*200 = 1500.
        assert!((t.integrate(5.0, 15.0) - 1500.0).abs() < 1e-9);
    }

    #[test]
    fn integrate_before_first_sample_is_zero() {
        let t = tl();
        assert_eq!(t.integrate(-10.0, 0.0), 0.0);
    }

    #[test]
    fn time_mean() {
        let t = tl();
        assert!((t.time_mean(0.0, 20.0) - 150.0).abs() < 1e-9);
    }

    #[test]
    fn time_above_threshold() {
        let t = tl();
        // ≥100 during [0,20): 20 s out of [0,30].
        assert!((t.time_above(100.0, 0.0, 30.0) - 20.0).abs() < 1e-9);
        // ≥250 never.
        assert_eq!(t.time_above(250.0, 0.0, 30.0), 0.0);
    }

    #[test]
    fn same_time_overwrites() {
        let mut t = Timeline::new();
        t.push(1.0, 5.0);
        t.push(1.0, 7.0);
        assert_eq!(t.len(), 1);
        assert_eq!(t.at(1.0), Some(7.0));
    }

    #[test]
    #[should_panic(expected = "monotone")]
    fn non_monotone_panics() {
        let mut t = Timeline::new();
        t.push(5.0, 1.0);
        t.push(4.0, 1.0);
    }

    #[test]
    fn resample_endpoints() {
        let t = tl();
        let pts = t.resample(0.0, 30.0, 4);
        assert_eq!(pts.len(), 4);
        assert_eq!(pts[0], (0.0, 100.0));
        assert_eq!(pts[3].0, 30.0);
        assert_eq!(pts[3].1, 50.0);
    }

    #[test]
    fn sparkline_shape() {
        let s = sparkline(&[0.0, 1.0, 0.5]);
        assert_eq!(s.chars().count(), 3);
        let first = s.chars().next().unwrap();
        let second = s.chars().nth(1).unwrap();
        assert!(first < second);
    }

    #[test]
    fn sparkline_empty_and_flat() {
        assert_eq!(sparkline(&[]), "");
        let flat = sparkline(&[2.0, 2.0]);
        assert_eq!(flat.chars().count(), 2);
    }
}
