//! Minimal JSON value model, serializer, and recursive-descent parser.
//!
//! `serde`/`serde_json` are not in the offline vendor set, so weights
//! interchange (`artifacts/weights.json`), experiment result files and
//! the AOT metadata (`artifacts/meta.json`) use this module. It supports
//! the full JSON grammar except for exotic number forms (we parse via
//! `f64::from_str`, which covers everything JAX/Python emit).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use `BTreeMap` so serialization is
/// deterministic (stable key order) — important for golden tests.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    /// Insert into an object; panics if self is not an object.
    pub fn set(&mut self, key: &str, val: Json) -> &mut Self {
        match self {
            Json::Obj(m) => {
                m.insert(key.to_string(), val);
            }
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Array of numbers → Vec<f64>; None if any element is non-numeric.
    pub fn as_f64_vec(&self) -> Option<Vec<f64>> {
        self.as_arr()?.iter().map(Json::as_f64).collect()
    }

    /// Array of numbers → Vec<f32> (weights interchange).
    pub fn as_f32_vec(&self) -> Option<Vec<f32>> {
        Some(self.as_f64_vec()?.into_iter().map(|x| x as f32).collect())
    }

    pub fn from_f32_slice(xs: &[f32]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    pub fn from_f64_slice(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => f.write_str("null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    write!(f, "{}", *x as i64)
                } else {
                    // Ryu-style shortest repr is what {} gives for f64.
                    write!(f, "{x}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(v) => {
                f.write_str("[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        f.write_str(",")?;
                    }
                    write!(f, "{x}")?;
                }
                f.write_str("]")
            }
            Json::Obj(m) => {
                f.write_str("{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        f.write_str(",")?;
                    }
                    write_escaped(f, k)?;
                    f.write_str(":")?;
                    write!(f, "{v}")?;
                }
                f.write_str("}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    f.write_str("\"")?;
    for c in s.chars() {
        match c {
            '"' => f.write_str("\\\"")?,
            '\\' => f.write_str("\\\\")?,
            '\n' => f.write_str("\\n")?,
            '\r' => f.write_str("\\r")?,
            '\t' => f.write_str("\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    f.write_str("\"")
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, word: &str, val: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(val)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return Err(self.err("truncated \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // Surrogate pairs are not needed for our data;
                            // map unpaired surrogates to the replacement char.
                            out.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar (multi-byte safe).
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for src in ["null", "true", "false", "42", "-3.5", "\"hi\""] {
            let v = Json::parse(src).unwrap();
            let v2 = Json::parse(&v.to_string()).unwrap();
            assert_eq!(v, v2, "roundtrip of {src}");
        }
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x\ny");
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[0].as_f64(), Some(1.0));
        assert_eq!(arr[2].get("b"), Some(&Json::Null));
    }

    #[test]
    fn parse_scientific_numbers() {
        let v = Json::parse("[1e3, -2.5E-2, 0.001]").unwrap();
        let xs = v.as_f64_vec().unwrap();
        assert_eq!(xs, vec![1000.0, -0.025, 0.001]);
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(Json::parse("{} x").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn deterministic_object_order() {
        let mut o = Json::obj();
        o.set("zeta", Json::Num(1.0)).set("alpha", Json::Num(2.0));
        assert_eq!(o.to_string(), r#"{"alpha":2,"zeta":1}"#);
    }

    #[test]
    fn f32_vec_roundtrip() {
        let xs: Vec<f32> = vec![0.5, -1.25, 3.0e-4, 1024.0];
        let j = Json::from_f32_slice(&xs);
        let back = Json::parse(&j.to_string()).unwrap().as_f32_vec().unwrap();
        assert_eq!(xs, back);
    }

    #[test]
    fn escapes_control_chars() {
        let s = Json::Str("a\"b\\c\nd\u{1}".into()).to_string();
        assert_eq!(s, "\"a\\\"b\\\\c\\nd\\u0001\"");
        assert_eq!(
            Json::parse(&s).unwrap().as_str().unwrap(),
            "a\"b\\c\nd\u{1}"
        );
    }

    #[test]
    fn unicode_passthrough() {
        let v = Json::parse("\"héllo ☃\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo ☃");
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::obj());
        assert_eq!(Json::parse(" [ ] ").unwrap(), Json::Arr(vec![]));
    }

    #[test]
    fn integers_serialize_without_dot() {
        assert_eq!(Json::Num(5.0).to_string(), "5");
        assert_eq!(Json::Num(5.5).to_string(), "5.5");
    }
}
