//! Tiny leveled logger implementing the `log` facade.
//!
//! `env_logger` is not in the offline vendor set; this does the 10% we
//! need: level filtering via `ECOSCHED_LOG` (error|warn|info|debug|trace),
//! timestamps relative to process start, and module-path prefixes.

use log::{Level, LevelFilter, Metadata, Record};
use std::time::Instant;

struct Logger {
    start: Instant,
}

impl log::Log for Logger {
    fn enabled(&self, _metadata: &Metadata) -> bool {
        true
    }

    fn log(&self, record: &Record) {
        if self.enabled(record.metadata()) {
            let t = self.start.elapsed().as_secs_f64();
            let lvl = match record.level() {
                Level::Error => "ERROR",
                Level::Warn => "WARN ",
                Level::Info => "INFO ",
                Level::Debug => "DEBUG",
                Level::Trace => "TRACE",
            };
            eprintln!(
                "[{t:9.3}s {lvl} {}] {}",
                record.module_path().unwrap_or("?"),
                record.args()
            );
        }
    }

    fn flush(&self) {}
}

/// Install the logger once; later calls are no-ops. Level comes from
/// `ECOSCHED_LOG` (default: warn, so tests and benches stay quiet).
pub fn init() {
    static INIT: std::sync::Once = std::sync::Once::new();
    INIT.call_once(|| {
        let level = match std::env::var("ECOSCHED_LOG").as_deref() {
            Ok("error") => LevelFilter::Error,
            Ok("warn") => LevelFilter::Warn,
            Ok("info") => LevelFilter::Info,
            Ok("debug") => LevelFilter::Debug,
            Ok("trace") => LevelFilter::Trace,
            _ => LevelFilter::Warn,
        };
        let logger = Box::new(Logger {
            start: Instant::now(),
        });
        if log::set_boxed_logger(logger).is_ok() {
            log::set_max_level(level);
        }
    });
}

#[cfg(test)]
mod tests {
    #[test]
    fn init_is_idempotent() {
        super::init();
        super::init();
        log::info!("logger smoke test");
    }
}
