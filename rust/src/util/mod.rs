//! Utility substrates built from scratch (the offline vendor set lacks
//! `rand`, `serde`, `clap`, `criterion`, `proptest`): deterministic RNG,
//! statistics, JSON, config parsing, table/CSV rendering, logging,
//! time-series, and the bench harness.

pub mod bench;
pub mod config;
pub mod json;
pub mod logger;
pub mod rng;
pub mod stats;
pub mod table;
pub mod timeline;
