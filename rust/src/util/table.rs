//! Plain-text / markdown table rendering and CSV output for experiment
//! reports. All paper tables are printed through this module so the
//! formatting (alignment, units, ±std columns) is uniform.

use std::fmt::Write as _;
use std::path::Path;

/// Column alignment.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Align {
    Left,
    Right,
}

/// A simple table builder.
#[derive(Debug, Clone)]
pub struct TableBuilder {
    title: String,
    headers: Vec<String>,
    aligns: Vec<Align>,
    rows: Vec<Vec<String>>,
}

impl TableBuilder {
    pub fn new(title: &str, headers: &[&str]) -> TableBuilder {
        TableBuilder {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            aligns: headers
                .iter()
                .enumerate()
                .map(|(i, _)| if i == 0 { Align::Left } else { Align::Right })
                .collect(),
            rows: Vec::new(),
        }
    }

    pub fn align(mut self, aligns: &[Align]) -> TableBuilder {
        assert_eq!(aligns.len(), self.headers.len());
        self.aligns = aligns.to_vec();
        self
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.headers.len(),
            "row width {} != header width {}",
            cells.len(),
            self.headers.len()
        );
        self.rows.push(cells.to_vec());
        self
    }

    /// Convenience: row from display-ables.
    pub fn rowf(&mut self, cells: &[&dyn std::fmt::Display]) -> &mut Self {
        let cells: Vec<String> = cells.iter().map(|c| c.to_string()).collect();
        self.row(&cells)
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    /// Render as an aligned monospace table with a title rule.
    pub fn render(&self) -> String {
        let ncols = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.chars().count()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.chars().count());
            }
        }
        let total: usize = widths.iter().sum::<usize>() + 3 * (ncols - 1);
        let mut out = String::new();
        let _ = writeln!(out, "{}", self.title);
        let _ = writeln!(out, "{}", "=".repeat(total.max(self.title.chars().count())));
        for (i, h) in self.headers.iter().enumerate() {
            if i > 0 {
                out.push_str(" | ");
            }
            pad(&mut out, h, widths[i], self.aligns[i]);
        }
        out.push('\n');
        let _ = writeln!(out, "{}", "-".repeat(total));
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                if i > 0 {
                    out.push_str(" | ");
                }
                pad(&mut out, c, widths[i], self.aligns[i]);
            }
            out.push('\n');
        }
        out
    }

    /// Render as GitHub-flavored markdown.
    pub fn render_markdown(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "### {}\n", self.title);
        let _ = writeln!(out, "| {} |", self.headers.join(" | "));
        let seps: Vec<&str> = self
            .aligns
            .iter()
            .map(|a| match a {
                Align::Left => ":--",
                Align::Right => "--:",
            })
            .collect();
        let _ = writeln!(out, "| {} |", seps.join(" | "));
        for row in &self.rows {
            let _ = writeln!(out, "| {} |", row.join(" | "));
        }
        out
    }

    /// Render as CSV (RFC-4180-ish quoting).
    pub fn render_csv(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "{}", csv_row(&self.headers));
        for row in &self.rows {
            let _ = writeln!(out, "{}", csv_row(row));
        }
        out
    }

    /// Write CSV under `results/`, creating the directory.
    pub fn write_csv(&self, path: &Path) -> std::io::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.render_csv())
    }
}

fn pad(out: &mut String, s: &str, width: usize, align: Align) {
    let len = s.chars().count();
    let fill = width.saturating_sub(len);
    match align {
        Align::Left => {
            out.push_str(s);
            out.push_str(&" ".repeat(fill));
        }
        Align::Right => {
            out.push_str(&" ".repeat(fill));
            out.push_str(s);
        }
    }
}

fn csv_row(cells: &[String]) -> String {
    cells
        .iter()
        .map(|c| {
            if c.contains(',') || c.contains('"') || c.contains('\n') {
                format!("\"{}\"", c.replace('"', "\"\""))
            } else {
                c.clone()
            }
        })
        .collect::<Vec<_>>()
        .join(",")
}

/// Format helpers used across experiment reports.
pub fn fmt_f(x: f64, decimals: usize) -> String {
    format!("{x:.decimals$}")
}

pub fn fmt_pct(x: f64) -> String {
    format!("{:.1}%", x * 100.0)
}

pub fn fmt_pm(mean: f64, std: f64, decimals: usize) -> String {
    format!("{mean:.decimals$} ± {std:.decimals$}")
}

/// Joules → human-friendly Wh/kWh.
pub fn fmt_energy(joules: f64) -> String {
    let wh = joules / 3600.0;
    if wh >= 1000.0 {
        format!("{:.2} kWh", wh / 1000.0)
    } else {
        format!("{wh:.1} Wh")
    }
}

/// Seconds → "1h02m", "3m20s", "42s".
pub fn fmt_dur(secs: f64) -> String {
    let s = secs.round() as u64;
    if s >= 3600 {
        format!("{}h{:02}m", s / 3600, (s % 3600) / 60)
    } else if s >= 60 {
        format!("{}m{:02}s", s / 60, s % 60)
    } else {
        format!("{s}s")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> TableBuilder {
        let mut t = TableBuilder::new("Test", &["workload", "energy", "savings"]);
        t.row(&["terasort".into(), "1234.5".into(), "19.0%".into()]);
        t.row(&["kmeans".into(), "987.0".into(), "15.2%".into()]);
        t
    }

    #[test]
    fn render_contains_all_cells() {
        let r = sample().render();
        for s in ["workload", "terasort", "19.0%", "kmeans", "987.0"] {
            assert!(r.contains(s), "missing {s} in\n{r}");
        }
    }

    #[test]
    fn alignment_right_pads_left() {
        let r = sample().render();
        // "energy" column is right-aligned: "1234.5" and "987.0" end at
        // the same column.
        let lines: Vec<&str> = r.lines().collect();
        let terasort = lines.iter().find(|l| l.contains("terasort")).unwrap();
        let kmeans = lines.iter().find(|l| l.contains("kmeans")).unwrap();
        let t_end = terasort.find("1234.5").unwrap() + "1234.5".len();
        let k_end = kmeans.find("987.0").unwrap() + "987.0".len();
        assert_eq!(t_end, k_end);
    }

    #[test]
    fn markdown_has_separator() {
        let md = sample().render_markdown();
        assert!(md.contains("| :-- | --: | --: |"), "{md}");
    }

    #[test]
    fn csv_quotes_commas() {
        let mut t = TableBuilder::new("q", &["a", "b"]);
        t.row(&["x,y".into(), "pla\"in".into()]);
        let csv = t.render_csv();
        assert!(csv.contains("\"x,y\""));
        assert!(csv.contains("\"pla\"\"in\""));
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn row_width_mismatch_panics() {
        let mut t = TableBuilder::new("t", &["a", "b"]);
        t.row(&["only-one".into()]);
    }

    #[test]
    fn formatters() {
        assert_eq!(fmt_pct(0.1925), "19.2%");
        assert_eq!(fmt_pm(1.234, 0.056, 2), "1.23 ± 0.06");
        assert_eq!(fmt_energy(3600.0), "1.0 Wh");
        assert_eq!(fmt_energy(7.2e6), "2.00 kWh");
        assert_eq!(fmt_dur(42.4), "42s");
        assert_eq!(fmt_dur(200.0), "3m20s");
        assert_eq!(fmt_dur(3725.0), "1h02m");
    }
}
