//! Configuration file parser — a pragmatic TOML subset.
//!
//! The offline crate set has neither `serde` nor `toml`, so experiment
//! and cluster configs use this parser. Supported grammar:
//!
//! ```toml
//! # comment
//! [section]            # tables
//! [[section.array]]    # arrays of tables
//! key = 1.5            # numbers (int/float)
//! key = "string"
//! key = true | false
//! key = [1, 2, 3]      # homogeneous scalar arrays
//! key = ["a", "b"]
//! ```
//!
//! Values are exposed through a typed accessor API with good error
//! messages; every experiment config ships with defaults so a missing
//! key is not fatal unless the caller says so.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Num(f64),
    Str(String),
    Bool(bool),
    NumArr(Vec<f64>),
    StrArr(Vec<String>),
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Value::Num(x) => write!(f, "{x}"),
            Value::Str(s) => write!(f, "\"{s}\""),
            Value::Bool(b) => write!(f, "{b}"),
            Value::NumArr(v) => write!(f, "{v:?}"),
            Value::StrArr(v) => write!(f, "{v:?}"),
        }
    }
}

/// One table of key → value pairs.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Table {
    pub entries: BTreeMap<String, Value>,
}

impl Table {
    pub fn get(&self, key: &str) -> Option<&Value> {
        self.entries.get(key)
    }

    pub fn f64(&self, key: &str, default: f64) -> f64 {
        match self.entries.get(key) {
            Some(Value::Num(x)) => *x,
            _ => default,
        }
    }

    pub fn usize(&self, key: &str, default: usize) -> usize {
        match self.entries.get(key) {
            Some(Value::Num(x)) => *x as usize,
            _ => default,
        }
    }

    pub fn u64(&self, key: &str, default: u64) -> u64 {
        match self.entries.get(key) {
            Some(Value::Num(x)) => *x as u64,
            _ => default,
        }
    }

    pub fn str<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        match self.entries.get(key) {
            Some(Value::Str(s)) => s,
            _ => default,
        }
    }

    pub fn bool(&self, key: &str, default: bool) -> bool {
        match self.entries.get(key) {
            Some(Value::Bool(b)) => *b,
            _ => default,
        }
    }

    pub fn f64_arr(&self, key: &str) -> Option<&[f64]> {
        match self.entries.get(key) {
            Some(Value::NumArr(v)) => Some(v),
            _ => None,
        }
    }

    pub fn str_arr(&self, key: &str) -> Option<&[String]> {
        match self.entries.get(key) {
            Some(Value::StrArr(v)) => Some(v),
            _ => None,
        }
    }

    /// Required key with a typed error.
    pub fn require_f64(&self, key: &str) -> Result<f64, ConfigError> {
        match self.entries.get(key) {
            Some(Value::Num(x)) => Ok(*x),
            Some(other) => Err(ConfigError::new(format!(
                "key '{key}' has type {other}, expected number"
            ))),
            None => Err(ConfigError::new(format!("missing required key '{key}'"))),
        }
    }

    pub fn set(&mut self, key: &str, v: Value) {
        self.entries.insert(key.to_string(), v);
    }
}

/// Parsed config: a root table, named tables, and arrays of tables.
#[derive(Debug, Clone, Default)]
pub struct Config {
    pub root: Table,
    pub tables: BTreeMap<String, Table>,
    pub arrays: BTreeMap<String, Vec<Table>>,
}

#[derive(Debug, Clone)]
pub struct ConfigError {
    pub msg: String,
}

impl std::fmt::Display for ConfigError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "config error: {}", self.msg)
    }
}

impl std::error::Error for ConfigError {}

impl ConfigError {
    fn new(msg: impl Into<String>) -> Self {
        ConfigError { msg: msg.into() }
    }

    fn at(line_no: usize, msg: impl Into<String>) -> Self {
        ConfigError {
            msg: format!("line {}: {}", line_no + 1, msg.into()),
        }
    }
}

impl Config {
    /// Table accessor returning an empty table when absent, so callers
    /// can chain `.f64(key, default)` without Option plumbing.
    pub fn table(&self, name: &str) -> Table {
        self.tables.get(name).cloned().unwrap_or_default()
    }

    pub fn array(&self, name: &str) -> &[Table] {
        self.arrays.get(name).map(Vec::as_slice).unwrap_or(&[])
    }

    pub fn parse(text: &str) -> Result<Config, ConfigError> {
        let mut cfg = Config::default();
        // Where new keys land: root, a table, or the last array element.
        enum Cursor {
            Root,
            Table(String),
            Array(String),
        }
        let mut cur = Cursor::Root;

        for (ln, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix("[[").and_then(|s| s.strip_suffix("]]")) {
                let name = name.trim().to_string();
                if name.is_empty() {
                    return Err(ConfigError::at(ln, "empty array-of-tables name"));
                }
                cfg.arrays.entry(name.clone()).or_default().push(Table::default());
                cur = Cursor::Array(name);
            } else if let Some(name) = line.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
                let name = name.trim().to_string();
                if name.is_empty() {
                    return Err(ConfigError::at(ln, "empty table name"));
                }
                cfg.tables.entry(name.clone()).or_default();
                cur = Cursor::Table(name);
            } else if let Some(eq) = line.find('=') {
                let key = line[..eq].trim();
                if key.is_empty() {
                    return Err(ConfigError::at(ln, "empty key"));
                }
                let val = parse_value(line[eq + 1..].trim())
                    .map_err(|m| ConfigError::at(ln, m))?;
                let table = match &cur {
                    Cursor::Root => &mut cfg.root,
                    Cursor::Table(name) => cfg.tables.get_mut(name).unwrap(),
                    Cursor::Array(name) => {
                        cfg.arrays.get_mut(name).unwrap().last_mut().unwrap()
                    }
                };
                table.set(key, val);
            } else {
                return Err(ConfigError::at(ln, format!("unparseable line: '{line}'")));
            }
        }
        Ok(cfg)
    }

    pub fn load(path: &std::path::Path) -> Result<Config, ConfigError> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| ConfigError::new(format!("read {}: {e}", path.display())))?;
        Config::parse(&text)
    }
}

fn strip_comment(line: &str) -> &str {
    // '#' inside quoted strings must survive.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value, String> {
    if s.is_empty() {
        return Err("empty value".into());
    }
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if let Some(inner) = s.strip_prefix('"').and_then(|x| x.strip_suffix('"')) {
        return Ok(Value::Str(inner.to_string()));
    }
    if let Some(inner) = s.strip_prefix('[').and_then(|x| x.strip_suffix(']')) {
        let inner = inner.trim();
        if inner.is_empty() {
            return Ok(Value::NumArr(vec![]));
        }
        let items: Vec<&str> = split_top_level(inner);
        if items.iter().all(|i| i.starts_with('"')) {
            let mut out = Vec::new();
            for i in items {
                match i.trim().strip_prefix('"').and_then(|x| x.strip_suffix('"')) {
                    Some(v) => out.push(v.to_string()),
                    None => return Err(format!("bad string array element '{i}'")),
                }
            }
            return Ok(Value::StrArr(out));
        }
        let mut out = Vec::new();
        for i in items {
            out.push(
                i.trim()
                    .parse::<f64>()
                    .map_err(|_| format!("bad number array element '{i}'"))?,
            );
        }
        return Ok(Value::NumArr(out));
    }
    s.parse::<f64>()
        .map(Value::Num)
        .map_err(|_| format!("unrecognized value '{s}'"))
}

fn split_top_level(s: &str) -> Vec<&str> {
    let mut out = Vec::new();
    let mut start = 0;
    let mut in_str = false;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            ',' if !in_str => {
                out.push(s[start..i].trim());
                start = i + 1;
            }
            _ => {}
        }
    }
    out.push(s[start..].trim());
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# cluster spec
seed = 42
name = "five-node"     # inline comment
verbose = true

[cluster]
hosts = 5
idle_w = 110.5
caps = [32, 64, 500]

[sched]
policy = "energy_aware"
thresholds = [0.2, 0.85]

[[workloads]]
kind = "terasort"
gb = 50

[[workloads]]
kind = "kmeans"
gb = 10
"#;

    #[test]
    fn parses_root_keys() {
        let c = Config::parse(SAMPLE).unwrap();
        assert_eq!(c.root.f64("seed", 0.0), 42.0);
        assert_eq!(c.root.str("name", ""), "five-node");
        assert!(c.root.bool("verbose", false));
    }

    #[test]
    fn parses_tables() {
        let c = Config::parse(SAMPLE).unwrap();
        assert_eq!(c.table("cluster").usize("hosts", 0), 5);
        assert!((c.table("cluster").f64("idle_w", 0.0) - 110.5).abs() < 1e-12);
        assert_eq!(c.table("sched").str("policy", ""), "energy_aware");
        assert_eq!(
            c.table("cluster").f64_arr("caps").unwrap(),
            &[32.0, 64.0, 500.0]
        );
    }

    #[test]
    fn parses_arrays_of_tables() {
        let c = Config::parse(SAMPLE).unwrap();
        let ws = c.array("workloads");
        assert_eq!(ws.len(), 2);
        assert_eq!(ws[0].str("kind", ""), "terasort");
        assert_eq!(ws[1].f64("gb", 0.0), 10.0);
    }

    #[test]
    fn defaults_apply_for_missing_keys() {
        let c = Config::parse("").unwrap();
        assert_eq!(c.root.f64("nothing", 7.5), 7.5);
        assert_eq!(c.table("nope").usize("x", 3), 3);
        assert!(c.array("none").is_empty());
    }

    #[test]
    fn hash_inside_string_survives() {
        let c = Config::parse("label = \"a#b\"").unwrap();
        assert_eq!(c.root.str("label", ""), "a#b");
    }

    #[test]
    fn string_arrays() {
        let c = Config::parse(r#"kinds = ["wordcount", "grep"]"#).unwrap();
        assert_eq!(
            c.root.str_arr("kinds").unwrap(),
            &["wordcount".to_string(), "grep".to_string()]
        );
    }

    #[test]
    fn errors_carry_line_numbers() {
        let err = Config::parse("x = 1\nbogus line\n").unwrap_err();
        assert!(err.msg.contains("line 2"), "{}", err.msg);
    }

    #[test]
    fn require_f64_errors() {
        let c = Config::parse("a = \"s\"").unwrap();
        assert!(c.root.require_f64("a").is_err());
        assert!(c.root.require_f64("missing").is_err());
        let c2 = Config::parse("a = 3").unwrap();
        assert_eq!(c2.root.require_f64("a").unwrap(), 3.0);
    }

    #[test]
    fn empty_array_is_num_arr() {
        let c = Config::parse("xs = []").unwrap();
        assert_eq!(c.root.f64_arr("xs").unwrap(), &[] as &[f64]);
    }
}
