//! Deterministic pseudo-random number generation for the simulator.
//!
//! The offline crate set has no `rand`, so we implement the generators we
//! need from scratch: [`SplitMix64`] for seeding / cheap streams and
//! [`Xoshiro256`] (xoshiro256**) as the workhorse generator, plus the
//! distributions the workload models and noise injectors use
//! (uniform, normal, exponential, Pareto, categorical).
//!
//! Every stochastic component of `ecosched` draws from a seeded stream so
//! experiments are reproducible bit-for-bit; the paper averages 3 runs,
//! we run 3 seeds.

/// SplitMix64: tiny, fast, passes BigCrush when used for seeding.
/// Reference: Steele, Lea, Flood — "Fast Splittable Pseudorandom Number
/// Generators" (OOPSLA 2014).
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256** 1.0 — the main generator. Public-domain algorithm by
/// Blackman & Vigna (<https://prng.di.unimi.it/>).
#[derive(Debug, Clone)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

impl Xoshiro256 {
    /// Seed via SplitMix64 as the authors recommend (avoids the all-zero
    /// state and decorrelates nearby integer seeds).
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    /// Derive an independent child stream. Used to give each host,
    /// workload, and noise source its own stream so adding a component
    /// never perturbs the draws of another (stable randomness).
    pub fn child(&mut self, tag: u64) -> Xoshiro256 {
        let mixed = self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        Xoshiro256::seed_from_u64(mixed)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1) with 53 bits of entropy.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in [lo, hi).
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        debug_assert!(hi >= lo);
        lo + (hi - lo) * self.next_f64()
    }

    /// Uniform integer in [0, n) via Lemire's multiply-shift rejection.
    pub fn next_below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let lo = m as u64;
            if lo >= n || lo >= n.wrapping_neg() % n {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform usize in [lo, hi) (half-open).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(hi > lo);
        lo + self.next_below((hi - lo) as u64) as usize
    }

    /// Standard normal via Box–Muller (polar form avoided; trig is fine
    /// off the hot path — the sim draws a handful per event).
    pub fn normal(&mut self, mean: f64, std: f64) -> f64 {
        // Guard against log(0).
        let u1 = (1.0 - self.next_f64()).max(f64::MIN_POSITIVE);
        let u2 = self.next_f64();
        let z = (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
        mean + std * z
    }

    /// Normal clamped to [lo, hi] — used for bounded noise like
    /// per-sample telemetry jitter.
    pub fn normal_clamped(&mut self, mean: f64, std: f64, lo: f64, hi: f64) -> f64 {
        self.normal(mean, std).clamp(lo, hi)
    }

    /// Log-normal with the given *underlying* mu/sigma.
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        self.normal(mu, sigma).exp()
    }

    /// Exponential with rate `lambda` (mean 1/lambda). Used for Poisson
    /// arrival inter-arrival times.
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        debug_assert!(lambda > 0.0);
        let u = (1.0 - self.next_f64()).max(f64::MIN_POSITIVE);
        -u.ln() / lambda
    }

    /// Bounded Pareto — heavy-tailed dataset / burst sizes.
    pub fn pareto(&mut self, scale: f64, shape: f64) -> f64 {
        let u = (1.0 - self.next_f64()).max(f64::MIN_POSITIVE);
        scale / u.powf(1.0 / shape)
    }

    /// Burr Type XII via inverse CDF: `x = scale·((1−u)^(−1/k) − 1)^(1/c)`.
    /// The distribution the Azure Functions 2021 trace analysis fits to
    /// per-function inter-arrival times — Pareto-like tail (exponent
    /// `c·k`) with a Weibull-like body. At `c = 2, k = 1.5` the mean is
    /// exactly `scale` (E[X] = k·scale·B(k−1/c, 1+1/c) = scale) with
    /// CV 1, which is how the FaaS trace sampler parameterizes it.
    pub fn burr12(&mut self, scale: f64, c: f64, k: f64) -> f64 {
        debug_assert!(scale > 0.0 && c > 0.0 && k > 0.0);
        let u = (1.0 - self.next_f64()).max(f64::MIN_POSITIVE);
        scale * (u.powf(-1.0 / k) - 1.0).powf(1.0 / c)
    }

    /// Bernoulli trial.
    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Sample an index from unnormalized weights.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        debug_assert!(total > 0.0);
        let mut x = self.next_f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_known_sequence_is_deterministic() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn splitmix_differs_by_seed() {
        let mut a = SplitMix64::new(1);
        let mut b = SplitMix64::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn xoshiro_deterministic_per_seed() {
        let mut a = Xoshiro256::seed_from_u64(7);
        let mut b = Xoshiro256::seed_from_u64(7);
        let xs: Vec<u64> = (0..32).map(|_| a.next_u64()).collect();
        let ys: Vec<u64> = (0..32).map(|_| b.next_u64()).collect();
        assert_eq!(xs, ys);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Xoshiro256::seed_from_u64(3);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x), "{x} out of [0,1)");
        }
    }

    #[test]
    fn uniform_respects_bounds() {
        let mut r = Xoshiro256::seed_from_u64(9);
        for _ in 0..10_000 {
            let x = r.uniform(-3.0, 5.0);
            assert!((-3.0..5.0).contains(&x));
        }
    }

    #[test]
    fn next_below_is_unbiased_enough() {
        let mut r = Xoshiro256::seed_from_u64(11);
        let mut counts = [0usize; 5];
        let n = 50_000;
        for _ in 0..n {
            counts[r.next_below(5) as usize] += 1;
        }
        for &c in &counts {
            let expect = n / 5;
            assert!(
                (c as i64 - expect as i64).unsigned_abs() < (expect / 10) as u64,
                "bucket count {c} too far from {expect}"
            );
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Xoshiro256::seed_from_u64(13);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal(2.0, 3.0)).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!((mean - 2.0).abs() < 0.05, "mean {mean}");
        assert!((var - 9.0).abs() < 0.3, "var {var}");
    }

    #[test]
    fn exponential_mean_matches_rate() {
        let mut r = Xoshiro256::seed_from_u64(17);
        let n = 100_000;
        let mean = (0..n).map(|_| r.exponential(0.5)).sum::<f64>() / n as f64;
        assert!((mean - 2.0).abs() < 0.05, "mean {mean}");
    }

    #[test]
    fn pareto_respects_scale() {
        let mut r = Xoshiro256::seed_from_u64(19);
        for _ in 0..10_000 {
            assert!(r.pareto(5.0, 1.5) >= 5.0);
        }
    }

    #[test]
    fn burr12_mean_and_median_match_analytics() {
        // At c=2, k=1.5 the mean equals the scale parameter; the median
        // is scale·(2^(1/k)−1)^(1/c) for any (c, k).
        let mut r = Xoshiro256::seed_from_u64(41);
        let n = 200_000;
        let mut xs: Vec<f64> = (0..n).map(|_| r.burr12(10.0, 2.0, 1.5)).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        assert!((mean - 10.0).abs() < 0.2, "mean {mean}");
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = xs[n / 2];
        let analytic = 10.0 * (2f64.powf(1.0 / 1.5) - 1.0).sqrt();
        assert!((median - analytic).abs() < 0.1, "median {median} vs {analytic}");
        assert!(xs.iter().all(|&x| x > 0.0));
    }

    #[test]
    fn burr12_deterministic_per_seed() {
        let mut a = Xoshiro256::seed_from_u64(43);
        let mut b = Xoshiro256::seed_from_u64(43);
        for _ in 0..100 {
            assert_eq!(a.burr12(5.0, 1.5, 1.2), b.burr12(5.0, 1.5, 1.2));
        }
    }

    #[test]
    fn categorical_prefers_heavy_weight() {
        let mut r = Xoshiro256::seed_from_u64(23);
        let mut hits = [0usize; 3];
        for _ in 0..30_000 {
            hits[r.categorical(&[1.0, 8.0, 1.0])] += 1;
        }
        assert!(hits[1] > hits[0] * 4 && hits[1] > hits[2] * 4, "{hits:?}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Xoshiro256::seed_from_u64(29);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<u32>>());
    }

    #[test]
    fn child_streams_are_independent_of_sibling_count() {
        // Drawing a child with the same tag after the same parent history
        // yields the same stream.
        let mut p1 = Xoshiro256::seed_from_u64(31);
        let mut p2 = Xoshiro256::seed_from_u64(31);
        let mut c1 = p1.child(5);
        let mut c2 = p2.child(5);
        assert_eq!(c1.next_u64(), c2.next_u64());
    }

    #[test]
    fn normal_clamped_within_bounds() {
        let mut r = Xoshiro256::seed_from_u64(37);
        for _ in 0..5_000 {
            let x = r.normal_clamped(0.0, 10.0, -1.0, 1.0);
            assert!((-1.0..=1.0).contains(&x));
        }
    }
}
