//! Micro-benchmark harness — `criterion` is unavailable offline, so the
//! `cargo bench` targets (harness = false) use this: warmup, timed
//! batches, outlier-robust statistics, throughput reporting, and a
//! uniform one-line output format that `bench_output.txt` collects.

use crate::util::stats::Summary;
use std::time::Instant;

/// One benchmark runner with criterion-like ergonomics.
pub struct Bench {
    name: String,
    warmup_iters: u64,
    samples: usize,
    iters_per_sample: u64,
    min_sample_time: f64,
}

/// Result of a benchmark: per-iteration timing summary in seconds.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub per_iter: Summary,
    pub total_iters: u64,
}

impl BenchResult {
    pub fn print(&self) {
        println!(
            "bench {:<44} {:>12}/iter  (p50 {:>12}, p95 {:>12}, n={} iters={})",
            self.name,
            fmt_time(self.per_iter.mean),
            fmt_time(self.per_iter.p50),
            fmt_time(self.per_iter.p95),
            self.per_iter.n,
            self.total_iters,
        );
    }

    pub fn print_throughput(&self, unit: &str, per_iter_units: f64) {
        let rate = per_iter_units / self.per_iter.mean;
        println!(
            "bench {:<44} {:>12}/iter  {:>14.1} {unit}/s",
            self.name,
            fmt_time(self.per_iter.mean),
            rate
        );
    }
}

pub fn fmt_time(secs: f64) -> String {
    if secs < 1e-6 {
        format!("{:.1} ns", secs * 1e9)
    } else if secs < 1e-3 {
        format!("{:.2} µs", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.2} ms", secs * 1e3)
    } else {
        format!("{:.3} s", secs)
    }
}

impl Bench {
    pub fn new(name: &str) -> Bench {
        Bench {
            name: name.to_string(),
            warmup_iters: 3,
            samples: 20,
            iters_per_sample: 0, // 0 = auto-calibrate
            min_sample_time: 0.01,
        }
    }

    pub fn warmup(mut self, iters: u64) -> Self {
        self.warmup_iters = iters;
        self
    }

    pub fn samples(mut self, n: usize) -> Self {
        self.samples = n.max(2);
        self
    }

    /// Fix the number of iterations per sample (skip auto-calibration) —
    /// for expensive end-to-end benches.
    pub fn iters(mut self, n: u64) -> Self {
        self.iters_per_sample = n.max(1);
        self
    }

    /// Run the benchmark. `f` is called once per iteration; use
    /// `std::hint::black_box` inside to defeat DCE.
    pub fn run<F: FnMut()>(&self, mut f: F) -> BenchResult {
        for _ in 0..self.warmup_iters {
            f();
        }
        // Auto-calibrate iterations so each sample takes >= min_sample_time.
        let iters = if self.iters_per_sample > 0 {
            self.iters_per_sample
        } else {
            let t0 = Instant::now();
            f();
            let one = t0.elapsed().as_secs_f64().max(1e-9);
            ((self.min_sample_time / one).ceil() as u64).clamp(1, 1_000_000)
        };
        let mut per_iter = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t0 = Instant::now();
            for _ in 0..iters {
                f();
            }
            per_iter.push(t0.elapsed().as_secs_f64() / iters as f64);
        }
        BenchResult {
            name: self.name.clone(),
            per_iter: Summary::of(&per_iter),
            total_iters: iters * self.samples as u64,
        }
    }
}

/// Standard bench-main prologue: prints a header once per binary.
pub fn bench_header(group: &str) {
    println!("== bench group: {group} ==");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let r = Bench::new("noop").samples(5).run(|| {
            std::hint::black_box(1 + 1);
        });
        assert!(r.per_iter.mean >= 0.0);
        assert!(r.per_iter.mean < 0.1, "noop should be fast");
        assert_eq!(r.per_iter.n, 5);
    }

    #[test]
    fn fixed_iters_respected() {
        let mut count = 0u64;
        let r = Bench::new("count").warmup(0).samples(3).iters(7).run(|| {
            count += 1;
        });
        assert_eq!(count, 21);
        assert_eq!(r.total_iters, 21);
    }

    #[test]
    fn fmt_time_units() {
        assert!(fmt_time(5e-9).contains("ns"));
        assert!(fmt_time(5e-6).contains("µs"));
        assert!(fmt_time(5e-3).contains("ms"));
        assert!(fmt_time(5.0).contains(" s"));
    }

    #[test]
    fn timing_orders_workloads() {
        // A heavier closure must not appear faster (sanity of the harness).
        let light = Bench::new("light").samples(5).run(|| {
            std::hint::black_box((0..10u64).sum::<u64>());
        });
        let heavy = Bench::new("heavy").samples(5).run(|| {
            std::hint::black_box((0..100_000u64).sum::<u64>());
        });
        assert!(heavy.per_iter.p50 > light.per_iter.p50);
    }
}
