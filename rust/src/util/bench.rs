//! Micro-benchmark harness — `criterion` is unavailable offline, so the
//! `cargo bench` targets (harness = false) use this: warmup, timed
//! batches, outlier-robust statistics, throughput reporting, and a
//! uniform one-line output format that `bench_output.txt` collects.

use crate::util::json::Json;
use crate::util::stats::Summary;
use std::path::PathBuf;
use std::time::Instant;

/// One benchmark runner with criterion-like ergonomics.
pub struct Bench {
    name: String,
    warmup_iters: u64,
    samples: usize,
    iters_per_sample: u64,
    min_sample_time: f64,
}

/// Result of a benchmark: per-iteration timing summary in seconds.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub per_iter: Summary,
    pub total_iters: u64,
}

impl BenchResult {
    pub fn print(&self) {
        println!(
            "bench {:<44} {:>12}/iter  (p50 {:>12}, p95 {:>12}, n={} iters={})",
            self.name,
            fmt_time(self.per_iter.mean),
            fmt_time(self.per_iter.p50),
            fmt_time(self.per_iter.p95),
            self.per_iter.n,
            self.total_iters,
        );
    }

    pub fn print_throughput(&self, unit: &str, per_iter_units: f64) {
        let rate = per_iter_units / self.per_iter.mean;
        println!(
            "bench {:<44} {:>12}/iter  {:>14.1} {unit}/s",
            self.name,
            fmt_time(self.per_iter.mean),
            rate
        );
    }
}

pub fn fmt_time(secs: f64) -> String {
    if secs < 1e-6 {
        format!("{:.1} ns", secs * 1e9)
    } else if secs < 1e-3 {
        format!("{:.2} µs", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.2} ms", secs * 1e3)
    } else {
        format!("{:.3} s", secs)
    }
}

impl Bench {
    pub fn new(name: &str) -> Bench {
        Bench {
            name: name.to_string(),
            warmup_iters: 3,
            samples: 20,
            iters_per_sample: 0, // 0 = auto-calibrate
            min_sample_time: 0.01,
        }
    }

    pub fn warmup(mut self, iters: u64) -> Self {
        self.warmup_iters = iters;
        self
    }

    pub fn samples(mut self, n: usize) -> Self {
        self.samples = n.max(2);
        self
    }

    /// Fix the number of iterations per sample (skip auto-calibration) —
    /// for expensive end-to-end benches.
    pub fn iters(mut self, n: u64) -> Self {
        self.iters_per_sample = n.max(1);
        self
    }

    /// Run the benchmark. `f` is called once per iteration; use
    /// `std::hint::black_box` inside to defeat DCE.
    pub fn run<F: FnMut()>(&self, mut f: F) -> BenchResult {
        for _ in 0..self.warmup_iters {
            f();
        }
        // Auto-calibrate iterations so each sample takes >= min_sample_time.
        let iters = if self.iters_per_sample > 0 {
            self.iters_per_sample
        } else {
            let t0 = Instant::now();
            f();
            let one = t0.elapsed().as_secs_f64().max(1e-9);
            ((self.min_sample_time / one).ceil() as u64).clamp(1, 1_000_000)
        };
        let mut per_iter = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t0 = Instant::now();
            for _ in 0..iters {
                f();
            }
            per_iter.push(t0.elapsed().as_secs_f64() / iters as f64);
        }
        BenchResult {
            name: self.name.clone(),
            per_iter: Summary::of(&per_iter),
            total_iters: iters * self.samples as u64,
        }
    }
}

/// Standard bench-main prologue: prints a header once per binary.
pub fn bench_header(group: &str) {
    println!("== bench group: {group} ==");
}

/// True when `BENCH_SHORT` is set (and not "0"): benches shrink
/// problem sizes / sample counts so the CI smoke job stays fast while
/// still exercising every measured path and emitting the JSON report.
pub fn short_mode() -> bool {
    std::env::var("BENCH_SHORT").is_ok_and(|v| v != "0")
}

/// Machine-readable bench report: collects [`BenchResult`]s (plus
/// free-form numeric tags like batch size or throughput) and writes
/// them as `BENCH_<group>.json` so the repo's perf trajectory is
/// recorded run over run instead of scraped from stdout. The output
/// directory comes from `BENCH_JSON_DIR` (default: the current
/// working directory).
pub struct JsonReport {
    group: String,
    entries: Vec<Json>,
}

impl JsonReport {
    pub fn new(group: &str) -> JsonReport {
        JsonReport {
            group: group.to_string(),
            entries: Vec::new(),
        }
    }

    /// Record one result with extra numeric tags (e.g. `("batch", 64)`
    /// or `("rows_per_s", rate)`).
    pub fn record_with(&mut self, r: &BenchResult, tags: &[(&str, f64)]) {
        let mut e = Json::obj();
        e.set("name", Json::Str(r.name.clone()))
            .set("mean_s", Json::Num(r.per_iter.mean))
            .set("p50_s", Json::Num(r.per_iter.p50))
            .set("p95_s", Json::Num(r.per_iter.p95))
            .set("samples", Json::Num(r.per_iter.n as f64))
            .set("iters", Json::Num(r.total_iters as f64));
        for (k, v) in tags {
            e.set(k, Json::Num(*v));
        }
        self.entries.push(e);
    }

    pub fn record(&mut self, r: &BenchResult) {
        self.record_with(r, &[]);
    }

    /// Write `BENCH_<group>.json` into `dir` and return its path.
    pub fn write_to(&self, dir: &std::path::Path) -> std::io::Result<PathBuf> {
        let path = dir.join(format!("BENCH_{}.json", self.group));
        let mut doc = Json::obj();
        doc.set("group", Json::Str(self.group.clone()))
            .set("short_mode", Json::Bool(short_mode()))
            .set("results", Json::Arr(self.entries.clone()));
        std::fs::write(&path, doc.to_string())?;
        println!("bench json: {}", path.display());
        Ok(path)
    }

    /// Write `BENCH_<group>.json` into `BENCH_JSON_DIR` (default: the
    /// current working directory) and return its path.
    pub fn write(&self) -> std::io::Result<PathBuf> {
        let dir = std::env::var("BENCH_JSON_DIR").unwrap_or_else(|_| ".".to_string());
        self.write_to(std::path::Path::new(&dir))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let r = Bench::new("noop").samples(5).run(|| {
            std::hint::black_box(1 + 1);
        });
        assert!(r.per_iter.mean >= 0.0);
        assert!(r.per_iter.mean < 0.1, "noop should be fast");
        assert_eq!(r.per_iter.n, 5);
    }

    #[test]
    fn fixed_iters_respected() {
        let mut count = 0u64;
        let r = Bench::new("count").warmup(0).samples(3).iters(7).run(|| {
            count += 1;
        });
        assert_eq!(count, 21);
        assert_eq!(r.total_iters, 21);
    }

    #[test]
    fn fmt_time_units() {
        assert!(fmt_time(5e-9).contains("ns"));
        assert!(fmt_time(5e-6).contains("µs"));
        assert!(fmt_time(5e-3).contains("ms"));
        assert!(fmt_time(5.0).contains(" s"));
    }

    #[test]
    fn json_report_round_trips() {
        let r = Bench::new("demo").warmup(0).samples(3).iters(2).run(|| {
            std::hint::black_box(1 + 1);
        });
        let mut report = JsonReport::new("unit");
        report.record_with(&r, &[("batch", 64.0)]);
        let dir = std::env::temp_dir().join("ecosched-bench-json-test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = report.write_to(&dir).unwrap();
        let doc = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(doc.get("group").unwrap().as_str(), Some("unit"));
        let results = doc.get("results").unwrap().as_arr().unwrap();
        assert_eq!(results.len(), 1);
        assert_eq!(results[0].get("name").unwrap().as_str(), Some("demo"));
        assert_eq!(results[0].get("batch").unwrap().as_f64(), Some(64.0));
        assert!(results[0].get("mean_s").unwrap().as_f64().unwrap() >= 0.0);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn timing_orders_workloads() {
        // A heavier closure must not appear faster (sanity of the harness).
        let light = Bench::new("light").samples(5).run(|| {
            std::hint::black_box((0..10u64).sum::<u64>());
        });
        let heavy = Bench::new("heavy").samples(5).run(|| {
            std::hint::black_box((0..100_000u64).sum::<u64>());
        });
        assert!(heavy.per_iter.p50 > light.per_iter.p50);
    }
}
