//! Statistics toolkit: summary statistics, percentiles, confidence
//! intervals, online (Welford) accumulators, and histograms.
//!
//! `criterion` is not available in the offline crate set, so the bench
//! harness (`rust/benches/harness.rs`) and the experiment reports both
//! build on this module.

/// Summary of a sample: n, mean, std (sample), min/max, percentiles.
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Summary {
        assert!(!xs.is_empty(), "Summary::of on empty sample");
        let mut sorted = xs.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).expect("NaN in sample"));
        let n = sorted.len();
        let mean = mean(&sorted);
        Summary {
            n,
            mean,
            std: std_dev(&sorted),
            min: sorted[0],
            max: sorted[n - 1],
            p50: percentile_sorted(&sorted, 50.0),
            p95: percentile_sorted(&sorted, 95.0),
            p99: percentile_sorted(&sorted, 99.0),
        }
    }

    /// Half-width of the ~95% normal-approximation confidence interval.
    pub fn ci95(&self) -> f64 {
        if self.n < 2 {
            return 0.0;
        }
        1.96 * self.std / (self.n as f64).sqrt()
    }
}

/// Arithmetic mean. Panics on empty input.
pub fn mean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation (n-1 denominator); 0 for n<2.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    let ss: f64 = xs.iter().map(|x| (x - m) * (x - m)).sum();
    (ss / (xs.len() - 1) as f64).sqrt()
}

/// Linear-interpolated percentile on a *sorted* slice, q in [0, 100].
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=100.0).contains(&q));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = q / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Percentile of an unsorted slice (copies + sorts).
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).expect("NaN in sample"));
    percentile_sorted(&sorted, q)
}

/// Welford online mean/variance accumulator — used by telemetry ring
/// buffers and the coordinator's overhead accounting, where samples
/// stream in and we cannot afford to retain them all.
#[derive(Debug, Clone, Default)]
pub struct Online {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
    sum: f64,
}

impl Online {
    pub fn new() -> Online {
        Online {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
            sum: 0.0,
        }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        self.sum += x;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn n(&self) -> u64 {
        self.n
    }
    pub fn sum(&self) -> f64 {
        self.sum
    }
    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.mean
        }
    }
    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }
    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }
    pub fn min(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.min
        }
    }
    pub fn max(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.max
        }
    }

    /// Merge another accumulator (Chan et al. parallel variance).
    pub fn merge(&mut self, other: &Online) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n1 = self.n as f64;
        let n2 = other.n as f64;
        let delta = other.mean - self.mean;
        let n = n1 + n2;
        self.mean += delta * n2 / n;
        self.m2 += other.m2 + delta * delta * n1 * n2 / n;
        self.n += other.n;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

/// Fixed-bucket histogram over [lo, hi); out-of-range values clamp into
/// the edge buckets. Used for utilization distributions (§V-D).
#[derive(Debug, Clone)]
pub struct Histogram {
    lo: f64,
    hi: f64,
    buckets: Vec<u64>,
    total: u64,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, nbuckets: usize) -> Histogram {
        assert!(hi > lo && nbuckets > 0);
        Histogram {
            lo,
            hi,
            buckets: vec![0; nbuckets],
            total: 0,
        }
    }

    pub fn push(&mut self, x: f64) {
        let nb = self.buckets.len();
        let idx = ((x - self.lo) / (self.hi - self.lo) * nb as f64) as isize;
        let idx = idx.clamp(0, nb as isize - 1) as usize;
        self.buckets[idx] += 1;
        self.total += 1;
    }

    pub fn total(&self) -> u64 {
        self.total
    }

    pub fn buckets(&self) -> &[u64] {
        &self.buckets
    }

    /// Fraction of mass in bucket i.
    pub fn frac(&self, i: usize) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.buckets[i] as f64 / self.total as f64
        }
    }

    /// Bucket label "lo–hi" for report rows; unit-interval histograms
    /// (utilization) render as percentages.
    pub fn label(&self, i: usize) -> String {
        let w = (self.hi - self.lo) / self.buckets.len() as f64;
        let (a, b) = (self.lo + w * i as f64, self.lo + w * (i + 1) as f64);
        if self.hi <= 1.0 + 1e-9 {
            format!("{:.0}–{:.0}%", a * 100.0, b * 100.0)
        } else {
            format!("{a:.0}–{b:.0}")
        }
    }
}

/// Simple ordinary-least-squares y = a + b·x fit; returns (a, b, r2).
/// Used by experiment sanity checks (e.g. energy vs dataset size).
pub fn linear_fit(xs: &[f64], ys: &[f64]) -> (f64, f64, f64) {
    assert_eq!(xs.len(), ys.len());
    assert!(xs.len() >= 2);
    let mx = mean(xs);
    let my = mean(ys);
    let mut sxy = 0.0;
    let mut sxx = 0.0;
    let mut syy = 0.0;
    for (x, y) in xs.iter().zip(ys) {
        sxy += (x - mx) * (y - my);
        sxx += (x - mx) * (x - mx);
        syy += (y - my) * (y - my);
    }
    let b = if sxx == 0.0 { 0.0 } else { sxy / sxx };
    let a = my - b * mx;
    let r2 = if sxx == 0.0 || syy == 0.0 {
        1.0
    } else {
        (sxy * sxy) / (sxx * syy)
    };
    (a, b, r2)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_std() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        // Sample std of this classic set is ~2.138.
        assert!((std_dev(&xs) - 2.13809).abs() < 1e-4);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((percentile(&xs, 0.0) - 1.0).abs() < 1e-12);
        assert!((percentile(&xs, 100.0) - 4.0).abs() < 1e-12);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn percentile_single_element() {
        assert_eq!(percentile(&[42.0], 99.0), 42.0);
    }

    #[test]
    fn summary_fields_consistent() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let s = Summary::of(&xs);
        assert_eq!(s.n, 100);
        assert!((s.mean - 50.5).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 100.0);
        assert!((s.p50 - 50.5).abs() < 1e-9);
        assert!(s.p95 > 94.0 && s.p95 < 97.0);
    }

    #[test]
    fn online_matches_batch() {
        let xs: Vec<f64> = (0..1000).map(|i| (i as f64 * 0.37).sin() * 10.0).collect();
        let mut o = Online::new();
        for &x in &xs {
            o.push(x);
        }
        assert!((o.mean() - mean(&xs)).abs() < 1e-9);
        assert!((o.std() - std_dev(&xs)).abs() < 1e-9);
        assert_eq!(o.n(), 1000);
    }

    #[test]
    fn online_merge_matches_single_stream() {
        let xs: Vec<f64> = (0..500).map(|i| i as f64).collect();
        let ys: Vec<f64> = (500..1000).map(|i| i as f64 * 1.5).collect();
        let mut a = Online::new();
        let mut b = Online::new();
        for &x in &xs {
            a.push(x);
        }
        for &y in &ys {
            b.push(y);
        }
        let mut merged = a.clone();
        merged.merge(&b);
        let mut all = Online::new();
        for &x in xs.iter().chain(&ys) {
            all.push(x);
        }
        assert!((merged.mean() - all.mean()).abs() < 1e-9);
        assert!((merged.std() - all.std()).abs() < 1e-9);
        assert_eq!(merged.n(), 1000);
    }

    #[test]
    fn online_empty_is_zero() {
        let o = Online::new();
        assert_eq!(o.mean(), 0.0);
        assert_eq!(o.std(), 0.0);
        assert_eq!(o.n(), 0);
    }

    #[test]
    fn histogram_clamps_and_counts() {
        let mut h = Histogram::new(0.0, 100.0, 10);
        h.push(-5.0); // clamps into bucket 0
        h.push(5.0);
        h.push(95.0);
        h.push(150.0); // clamps into bucket 9
        assert_eq!(h.total(), 4);
        assert_eq!(h.buckets()[0], 2);
        assert_eq!(h.buckets()[9], 2);
        assert!((h.frac(0) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn histogram_labels() {
        let h = Histogram::new(0.0, 100.0, 4);
        assert_eq!(h.label(0), "0–25");
        assert_eq!(h.label(3), "75–100");
        // Unit-interval histograms render percentages.
        let u = Histogram::new(0.0, 1.0, 10);
        assert_eq!(u.label(0), "0–10%");
        assert_eq!(u.label(9), "90–100%");
    }

    #[test]
    fn linear_fit_recovers_line() {
        let xs: Vec<f64> = (0..50).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 3.0 + 2.0 * x).collect();
        let (a, b, r2) = linear_fit(&xs, &ys);
        assert!((a - 3.0).abs() < 1e-9);
        assert!((b - 2.0).abs() < 1e-9);
        assert!((r2 - 1.0).abs() < 1e-9);
    }

    #[test]
    fn ci95_shrinks_with_n() {
        let small = Summary::of(&[1.0, 2.0, 3.0, 4.0]);
        let xs: Vec<f64> = (0..400).map(|i| 1.0 + (i % 4) as f64).collect();
        let large = Summary::of(&xs);
        assert!(large.ci95() < small.ci95());
    }
}
