//! Deterministic fault injection — the chaos half of the robustness
//! story. A [`FaultPlan`] is generated **up front** from `(seed,
//! config, cluster shape)` alone: a time-ordered schedule of host
//! crashes and recoveries, per-shard telemetry blackout windows, and
//! scoring-worker panics, plus a stateless Bernoulli oracle for
//! transient migration failures. The coordinator replays the plan by
//! pushing each entry into its [`crate::sim::EventQueue`]; because the
//! plan is closed over before the campaign starts, the *same* faults
//! hit at the *same* simulated times regardless of worker width,
//! policy, or how the campaign otherwise unfolds — which is what lets
//! the chaos property tests demand bit-identical reports at widths
//! 1 and 8.
//!
//! Plan entries are **advisory**: a `HostCrash` for a host that is
//! not `On` when the event fires is simply dropped by the coordinator
//! (the plan is generated blind to power state), and a `HostRecover`
//! may be deferred past its scheduled time by the flapping-host
//! quarantine. Both resolutions depend only on simulation state, so
//! they replay identically too.

use crate::cluster::shard::splitmix64;
use crate::cluster::{HostCondition, HostId};
use crate::util::rng::Xoshiro256;

/// Energy cost of writing one checkpoint, joules per GB of the VM's
/// memory footprint (flavor `mem_gb`). Order-of-magnitude for a
/// DRAM→local-SSD snapshot; priced into the owning job's energy and
/// surfaced as `checkpoint_energy_j` in the fault ledger.
pub const CHECKPOINT_J_PER_GB: f64 = 18.0;

/// Fault-injection knobs. All rates are *per hour* so configs read
/// like the availability numbers operators actually quote; a rate of
/// zero disables that fault class entirely.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultConfig {
    /// Mean crashes per host-hour (Poisson). 0 = hosts never crash.
    pub host_crash_rate_per_hour: f64,
    /// Mean downtime after a crash before the scheduled recovery
    /// (exponential), seconds.
    pub mean_downtime_s: f64,
    /// Mean telemetry blackout windows per shard-hour (Poisson).
    pub blackout_rate_per_hour: f64,
    /// Mean blackout window length (exponential), seconds.
    pub mean_blackout_s: f64,
    /// Probability that any single migration actuation fails
    /// transiently and must be retried.
    pub migration_failure_prob: f64,
    /// Number of scoring-worker panic probes injected across the
    /// horizon (uniform times).
    pub worker_panics: usize,
    /// Plan horizon, seconds — faults are only scheduled in
    /// `[0, horizon_s)`.
    pub horizon_s: f64,
    /// Crashes within [`FaultConfig::flap_window_s`] that mark a host
    /// as flapping (quarantined from placement for
    /// [`FaultConfig::quarantine_s`] past its scheduled recovery).
    pub flap_threshold: usize,
    /// Sliding window for flap detection, seconds.
    pub flap_window_s: f64,
    /// Extra downtime a quarantined host serves, seconds.
    pub quarantine_s: f64,
    /// Mean correlated crashes per *rack*-hour (Poisson). A rack crash
    /// fails every `On` member host at one instant; 0 = no rack
    /// faults. Rack streams are independent of the per-host crash
    /// streams, so enabling them never reshuffles existing plans.
    pub rack_crash_rate_per_hour: f64,
    /// Mean partial-degradation events per host-hour (Poisson): a host
    /// stays up but turns [`HostCondition::FlakyDisk`] (halved disk
    /// bandwidth) or [`HostCondition::Thermal`] (capped frequency)
    /// until the paired `Restore`. 0 = hosts never degrade.
    pub degrade_rate_per_hour: f64,
    /// Mean length of a degradation episode (exponential), seconds.
    pub degraded_duration_s: f64,
    /// Checkpoint cadence for running jobs, seconds. When set, a
    /// crashed job resumes from its last checkpoint boundary instead
    /// of from scratch; each checkpoint costs
    /// [`CHECKPOINT_J_PER_GB`] × flavor memory. `None` = no
    /// checkpointing (crashes lose all progress). Does not enter plan
    /// generation, so toggling it replays the identical fault
    /// schedule.
    pub checkpoint_interval_s: Option<f64>,
}

impl Default for FaultConfig {
    /// A lively but survivable default: roughly one crash per 20
    /// host-hours, 3-minute mean downtime, occasional 30 s telemetry
    /// blackouts, 5 % transient migration failures.
    fn default() -> FaultConfig {
        FaultConfig {
            host_crash_rate_per_hour: 0.05,
            mean_downtime_s: 180.0,
            blackout_rate_per_hour: 0.1,
            mean_blackout_s: 30.0,
            migration_failure_prob: 0.05,
            worker_panics: 2,
            horizon_s: 4.0 * 3600.0,
            flap_threshold: 3,
            flap_window_s: 1800.0,
            quarantine_s: 900.0,
            rack_crash_rate_per_hour: 0.0,
            degrade_rate_per_hour: 0.0,
            degraded_duration_s: 600.0,
            checkpoint_interval_s: None,
        }
    }
}

/// One scheduled fault. `Copy` so it can ride inside the
/// coordinator's event enum.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultKind {
    /// Host crashes: resident VMs and warm containers are lost, the
    /// host enters [`crate::cluster::PowerState::Failed`]. Dropped if
    /// the host is not `On` at fire time.
    HostCrash(HostId),
    /// Scheduled end of the downtime: the host reboots (pays a full
    /// boot). Deferred by the quarantine when the host is flapping.
    HostRecover(HostId),
    /// Telemetry from every host in `shard` goes dark until `until`:
    /// the coordinator masks those samples, so scoring sees stale
    /// utilization for the window.
    BlackoutStart { shard: usize, until: f64 },
    /// A panic probe is dispatched to the scoring worker pool: the
    /// in-flight fan-out fails once with `WorkerPanicked` and the
    /// pool must heal.
    WorkerPanic,
    /// Correlated fault-domain failure: every `On` host in `rack`
    /// crashes at this instant (hosts already down are unaffected).
    /// The coordinator schedules each member's recovery at
    /// `t + downtime_s` — drawn at generation time from the rack's
    /// own stream, so the whole episode is fixed by the plan.
    RackCrash { rack: usize, downtime_s: f64 },
    /// The host stays up but enters `condition` (flaky disk or
    /// thermal throttling): it stops accepting placements, its
    /// effective capacity shrinks, and the consolidator drains it.
    /// Dropped if the host is not `On` at fire time.
    Degrade {
        host: HostId,
        condition: HostCondition,
    },
    /// End of the degradation episode: the host returns to
    /// [`HostCondition::Healthy`]. The condition layer is orthogonal
    /// to the power machine, so the restore applies even if the host
    /// crashed or parked in between (and no-ops if the paired
    /// `Degrade` was dropped on a non-`On` host).
    Restore { host: HostId },
}

/// A fault with its fire time.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultEvent {
    pub t: f64,
    pub kind: FaultKind,
}

/// The full, immutable fault schedule for one campaign. Replayable
/// from `(seed, config, n_hosts, shard_count, n_racks)` alone — generation
/// consumes nothing but its own child RNG streams, so building a plan
/// never perturbs workload or policy randomness.
#[derive(Debug, Clone)]
pub struct FaultPlan {
    events: Vec<FaultEvent>,
    /// Seed for the stateless migration-failure oracle; derived from
    /// the plan seed, independent of the schedule streams.
    migration_seed: u64,
    migration_failure_prob: f64,
}

impl FaultPlan {
    /// Generate the schedule. Each fault class draws from its own
    /// `child` stream (and each host / shard from a per-entity
    /// sub-stream), so changing one rate never reshuffles the other
    /// classes' timings — the same stable-randomness discipline the
    /// workload generators use.
    pub fn generate(
        seed: u64,
        cfg: &FaultConfig,
        n_hosts: usize,
        shard_count: usize,
        n_racks: usize,
    ) -> FaultPlan {
        let mut root = Xoshiro256::seed_from_u64(seed ^ 0xFA_017_FA_017);
        let mut crash_root = root.child(1);
        let mut blackout_root = root.child(2);
        let mut panic_rng = root.child(3);
        let migration_seed = root.next_u64();
        // New classes derive *after* every pre-existing stream, so a
        // plan with rack/degrade rates at zero is bit-identical to one
        // generated before those classes existed.
        let mut rack_root = root.child(4);
        let mut degrade_root = root.child(5);

        let mut events: Vec<FaultEvent> = Vec::new();

        // Host crash/recover pairs: per-host Poisson process, paused
        // during the downtime (a host cannot crash while already
        // down).
        if cfg.host_crash_rate_per_hour > 0.0 && cfg.mean_downtime_s > 0.0 {
            let lambda = cfg.host_crash_rate_per_hour / 3600.0;
            for h in 0..n_hosts {
                let mut rng = crash_root.child(h as u64);
                let mut t = rng.exponential(lambda);
                while t < cfg.horizon_s {
                    events.push(FaultEvent {
                        t,
                        kind: FaultKind::HostCrash(HostId(h)),
                    });
                    let downtime = rng.exponential(1.0 / cfg.mean_downtime_s);
                    let recover_at = t + downtime;
                    events.push(FaultEvent {
                        t: recover_at,
                        kind: FaultKind::HostRecover(HostId(h)),
                    });
                    // Next candidate crash only after the recovery
                    // completes its boot.
                    t = recover_at + crate::cluster::power::BOOT_SECS + rng.exponential(lambda);
                }
            }
        }

        // Telemetry blackouts: per-shard Poisson windows.
        if cfg.blackout_rate_per_hour > 0.0 && cfg.mean_blackout_s > 0.0 {
            let lambda = cfg.blackout_rate_per_hour / 3600.0;
            for s in 0..shard_count {
                let mut rng = blackout_root.child(s as u64);
                let mut t = rng.exponential(lambda);
                while t < cfg.horizon_s {
                    let len = rng.exponential(1.0 / cfg.mean_blackout_s);
                    events.push(FaultEvent {
                        t,
                        kind: FaultKind::BlackoutStart {
                            shard: s,
                            until: t + len,
                        },
                    });
                    t += len + rng.exponential(lambda);
                }
            }
        }

        // Correlated rack crashes: per-rack Poisson process. The
        // downtime every member serves is drawn here so the whole
        // episode is closed over at generation; member recoveries are
        // pushed by the coordinator at fire time (it alone knows which
        // members were actually `On`).
        if cfg.rack_crash_rate_per_hour > 0.0 && cfg.mean_downtime_s > 0.0 {
            let lambda = cfg.rack_crash_rate_per_hour / 3600.0;
            for r in 0..n_racks {
                let mut rng = rack_root.child(r as u64);
                let mut t = rng.exponential(lambda);
                while t < cfg.horizon_s {
                    let downtime_s = rng.exponential(1.0 / cfg.mean_downtime_s);
                    events.push(FaultEvent {
                        t,
                        kind: FaultKind::RackCrash { rack: r, downtime_s },
                    });
                    // The rack cannot meaningfully crash again until
                    // its members have recovered and rebooted.
                    t += downtime_s + crate::cluster::power::BOOT_SECS + rng.exponential(lambda);
                }
            }
        }

        // Partial degradation: per-host alternating Degrade/Restore
        // episodes, condition chosen per episode.
        if cfg.degrade_rate_per_hour > 0.0 && cfg.degraded_duration_s > 0.0 {
            let lambda = cfg.degrade_rate_per_hour / 3600.0;
            for h in 0..n_hosts {
                let mut rng = degrade_root.child(h as u64);
                let mut t = rng.exponential(lambda);
                while t < cfg.horizon_s {
                    let condition = if rng.chance(0.5) {
                        HostCondition::FlakyDisk
                    } else {
                        HostCondition::Thermal
                    };
                    events.push(FaultEvent {
                        t,
                        kind: FaultKind::Degrade {
                            host: HostId(h),
                            condition,
                        },
                    });
                    let dur = rng.exponential(1.0 / cfg.degraded_duration_s);
                    events.push(FaultEvent {
                        t: t + dur,
                        kind: FaultKind::Restore { host: HostId(h) },
                    });
                    t += dur + rng.exponential(lambda);
                }
            }
        }

        // Worker panic probes: uniform over the horizon.
        for _ in 0..cfg.worker_panics {
            events.push(FaultEvent {
                t: panic_rng.uniform(0.0, cfg.horizon_s),
                kind: FaultKind::WorkerPanic,
            });
        }

        // Time order with generation order as the tie-break (stable
        // sort), so exact float ties resolve identically everywhere.
        events.sort_by(|a, b| a.t.partial_cmp(&b.t).expect("fault times are finite"));

        FaultPlan {
            events,
            migration_seed,
            migration_failure_prob: cfg.migration_failure_prob,
        }
    }

    /// An empty plan (no faults, migrations never fail).
    pub fn none() -> FaultPlan {
        FaultPlan {
            events: Vec::new(),
            migration_seed: 0,
            migration_failure_prob: 0.0,
        }
    }

    /// The schedule, time-ordered.
    pub fn events(&self) -> &[FaultEvent] {
        &self.events
    }

    /// Does migration attempt number `attempt` (a campaign-global
    /// counter) fail transiently? Stateless — a pure hash of
    /// `(plan seed, attempt)` — so actuation order alone determines
    /// the outcome and the oracle can be consulted from anywhere
    /// without threading an RNG.
    pub fn migration_fails(&self, attempt: u64) -> bool {
        if self.migration_failure_prob <= 0.0 {
            return false;
        }
        let x = splitmix64(self.migration_seed ^ attempt.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        (x >> 11) as f64 * (1.0 / (1u64 << 53) as f64) < self.migration_failure_prob
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn busy_cfg() -> FaultConfig {
        FaultConfig {
            host_crash_rate_per_hour: 2.0,
            mean_downtime_s: 120.0,
            blackout_rate_per_hour: 1.0,
            mean_blackout_s: 45.0,
            migration_failure_prob: 0.2,
            worker_panics: 3,
            horizon_s: 3600.0,
            ..FaultConfig::default()
        }
    }

    #[test]
    fn plan_is_replayable_from_seed_and_config() {
        let cfg = busy_cfg();
        let a = FaultPlan::generate(99, &cfg, 16, 4, 4);
        let b = FaultPlan::generate(99, &cfg, 16, 4, 4);
        assert!(!a.events().is_empty(), "busy config must schedule faults");
        assert_eq!(a.events(), b.events());
        for i in 0..1000 {
            assert_eq!(a.migration_fails(i), b.migration_fails(i));
        }
        let c = FaultPlan::generate(100, &cfg, 16, 4, 4);
        assert_ne!(a.events(), c.events(), "different seed, different plan");
    }

    #[test]
    fn schedule_is_time_ordered_and_within_horizon() {
        let cfg = busy_cfg();
        let plan = FaultPlan::generate(7, &cfg, 16, 4, 4);
        let mut last = 0.0;
        for e in plan.events() {
            assert!(e.t >= last, "events out of order at t={}", e.t);
            last = e.t;
            // Recoveries and degradation restores may land past the
            // horizon (their opening event fired inside it);
            // everything else must not.
            if !matches!(
                e.kind,
                FaultKind::HostRecover(_) | FaultKind::Restore { .. }
            ) {
                assert!(e.t < cfg.horizon_s, "{:?} past horizon", e);
            }
        }
    }

    #[test]
    fn crashes_and_recoveries_alternate_per_host() {
        let cfg = busy_cfg();
        let plan = FaultPlan::generate(21, &cfg, 8, 2, 2);
        for h in 0..8 {
            let mut down = false;
            let mut saw_any = false;
            for e in plan.events() {
                match e.kind {
                    FaultKind::HostCrash(id) if id == HostId(h) => {
                        assert!(!down, "host {h} crashed while already down");
                        down = true;
                        saw_any = true;
                    }
                    FaultKind::HostRecover(id) if id == HostId(h) => {
                        assert!(down, "host {h} recovered while up");
                        down = false;
                    }
                    _ => {}
                }
            }
            // 2 crashes/hour for an hour: overwhelmingly likely that
            // at least one host in 8 crashed; assert per-plan below.
            let _ = saw_any;
        }
        assert!(
            plan.events()
                .iter()
                .any(|e| matches!(e.kind, FaultKind::HostCrash(_))),
            "busy plan scheduled no crashes at all"
        );
    }

    #[test]
    fn per_class_streams_are_independent() {
        // Turning off blackouts must not move the crash schedule.
        let cfg = busy_cfg();
        let quiet = FaultConfig {
            blackout_rate_per_hour: 0.0,
            worker_panics: 0,
            ..cfg
        };
        let full = FaultPlan::generate(5, &cfg, 8, 4, 4);
        let crashes_only = FaultPlan::generate(5, &quiet, 8, 4, 4);
        let crash_times = |p: &FaultPlan| -> Vec<(f64, HostId)> {
            p.events()
                .iter()
                .filter_map(|e| match e.kind {
                    FaultKind::HostCrash(h) => Some((e.t, h)),
                    _ => None,
                })
                .collect()
        };
        assert_eq!(crash_times(&full), crash_times(&crashes_only));
    }

    #[test]
    fn migration_oracle_matches_configured_probability() {
        let cfg = FaultConfig {
            migration_failure_prob: 0.25,
            ..busy_cfg()
        };
        let plan = FaultPlan::generate(3, &cfg, 4, 2, 2);
        let n = 100_000u64;
        let fails = (0..n).filter(|&i| plan.migration_fails(i)).count();
        let rate = fails as f64 / n as f64;
        assert!((rate - 0.25).abs() < 0.01, "failure rate {rate}");
        // Zero probability: never fails, regardless of seed.
        assert!(!FaultPlan::none().migration_fails(42));
    }

    #[test]
    fn empty_config_schedules_nothing() {
        let cfg = FaultConfig {
            host_crash_rate_per_hour: 0.0,
            blackout_rate_per_hour: 0.0,
            worker_panics: 0,
            migration_failure_prob: 0.0,
            ..FaultConfig::default()
        };
        let plan = FaultPlan::generate(1, &cfg, 32, 8, 8);
        assert!(plan.events().is_empty());
    }

    fn chaotic_cfg() -> FaultConfig {
        FaultConfig {
            rack_crash_rate_per_hour: 2.0,
            degrade_rate_per_hour: 1.5,
            degraded_duration_s: 300.0,
            ..busy_cfg()
        }
    }

    #[test]
    fn enabling_rack_and_degrade_streams_never_reshuffles_existing_classes() {
        // The new classes draw from their own child streams, derived
        // after every pre-existing stream — so a legacy config and a
        // fully chaotic one must agree exactly on crashes, blackouts,
        // panics, and the migration oracle.
        let legacy = FaultPlan::generate(5, &busy_cfg(), 8, 4, 4);
        let chaotic = FaultPlan::generate(5, &chaotic_cfg(), 8, 4, 4);
        let old_classes = |p: &FaultPlan| -> Vec<FaultEvent> {
            p.events()
                .iter()
                .filter(|e| {
                    !matches!(
                        e.kind,
                        FaultKind::RackCrash { .. }
                            | FaultKind::Degrade { .. }
                            | FaultKind::Restore { .. }
                    )
                })
                .copied()
                .collect()
        };
        assert_eq!(old_classes(&legacy), old_classes(&chaotic));
        for i in 0..1000 {
            assert_eq!(legacy.migration_fails(i), chaotic.migration_fails(i));
        }
        // And the new classes actually fired.
        assert!(chaotic
            .events()
            .iter()
            .any(|e| matches!(e.kind, FaultKind::RackCrash { .. })));
        assert!(chaotic
            .events()
            .iter()
            .any(|e| matches!(e.kind, FaultKind::Degrade { .. })));
    }

    #[test]
    fn degrades_and_restores_alternate_per_host() {
        let plan = FaultPlan::generate(13, &chaotic_cfg(), 6, 2, 2);
        for h in 0..6 {
            let mut degraded = false;
            for e in plan.events() {
                match e.kind {
                    FaultKind::Degrade { host, .. } if host == HostId(h) => {
                        assert!(!degraded, "host {h} degraded while already degraded");
                        degraded = true;
                    }
                    FaultKind::Restore { host } if host == HostId(h) => {
                        assert!(degraded, "host {h} restored while healthy");
                        degraded = false;
                    }
                    _ => {}
                }
            }
        }
        // Both conditions appear across a busy enough plan.
        let conditions: std::collections::BTreeSet<_> = plan
            .events()
            .iter()
            .filter_map(|e| match e.kind {
                FaultKind::Degrade { condition, .. } => Some(format!("{condition:?}")),
                _ => None,
            })
            .collect();
        assert_eq!(conditions.len(), 2, "expected both degrade conditions");
    }

    #[test]
    fn rack_crashes_carry_positive_downtime_and_respect_rack_count() {
        let plan = FaultPlan::generate(17, &chaotic_cfg(), 12, 3, 3);
        let mut seen = 0;
        for e in plan.events() {
            if let FaultKind::RackCrash { rack, downtime_s } = e.kind {
                assert!(rack < 3, "rack {rack} out of range");
                assert!(downtime_s > 0.0);
                seen += 1;
            }
        }
        assert!(seen > 0, "2 rack-crashes/hour over 3 racks scheduled none");
    }

    #[test]
    fn checkpoint_interval_does_not_enter_plan_generation() {
        // Same seed, checkpointing on vs off: the fault schedule is
        // identical, so A/B energy comparisons isolate the policy.
        let base = chaotic_cfg();
        let ckpt = FaultConfig {
            checkpoint_interval_s: Some(60.0),
            ..base
        };
        let a = FaultPlan::generate(29, &base, 8, 2, 2);
        let b = FaultPlan::generate(29, &ckpt, 8, 2, 2);
        assert_eq!(a.events(), b.events());
    }
}
