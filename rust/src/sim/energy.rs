//! Energy metering — the simulated Watts Up Pro (§IV-D).
//!
//! The physical meters sample instantaneous draw at 1 s granularity;
//! total energy is the integral of power over job duration, and
//! workload-specific energy subtracts the idle baseline. We reproduce
//! exactly that pipeline, including ±1 % instrument noise
//! (the Watts Up Pro datasheet specifies ±1.5 % accuracy), so the
//! experiment harness measures energy the way the authors did rather
//! than reading the model's ground truth.

use crate::cluster::Cluster;
use crate::util::rng::Xoshiro256;
use crate::util::timeline::Timeline;

/// Per-cluster energy meter.
#[derive(Debug, Clone)]
pub struct EnergyMeter {
    /// Joules accumulated per host (measured, i.e. with noise).
    per_host_j: Vec<f64>,
    /// Ground-truth joules per host (noise-free; used in tests and to
    /// validate that noise is unbiased).
    per_host_true_j: Vec<f64>,
    /// Cluster power trace (W) at each sample, for figures.
    pub power_trace: Timeline,
    /// Active-host-count trace, for the §V-D utilization figure.
    pub hosts_on_trace: Timeline,
    last_sample: f64,
    noise: Xoshiro256,
    /// Relative meter noise (σ). 0 disables.
    noise_sigma: f64,
}

impl EnergyMeter {
    pub fn new(n_hosts: usize, seed: u64, noise_sigma: f64) -> EnergyMeter {
        EnergyMeter {
            per_host_j: vec![0.0; n_hosts],
            per_host_true_j: vec![0.0; n_hosts],
            power_trace: Timeline::new(),
            hosts_on_trace: Timeline::new(),
            last_sample: 0.0,
            noise: Xoshiro256::seed_from_u64(seed ^ 0xE0E0),
            noise_sigma,
        }
    }

    /// Integrate power over [last_sample, now]. Call at 1 s ticks (the
    /// meter granularity); works for any dt.
    pub fn sample(&mut self, now: f64, cluster: &Cluster) {
        let dt = now - self.last_sample;
        if dt <= 0.0 {
            return;
        }
        let mut total_w = 0.0;
        for (i, host) in cluster.hosts.iter().enumerate() {
            let p = host.power();
            let measured = if self.noise_sigma > 0.0 {
                p * self.noise.normal_clamped(1.0, self.noise_sigma, 0.9, 1.1)
            } else {
                p
            };
            self.per_host_j[i] += measured * dt;
            self.per_host_true_j[i] += p * dt;
            total_w += p;
        }
        self.power_trace.push(now, total_w);
        self.hosts_on_trace.push(now, cluster.hosts_on() as f64);
        self.last_sample = now;
    }

    /// Integrate one host's constant draw `watts` over a `dt`-second
    /// segment — the discrete-event analogue of [`EnergyMeter::sample`].
    /// The event core calls this lazily at per-host sync points (a
    /// host's segments are bounded by its own events), so `last_sample`
    /// is deliberately untouched: event-mode segment bookkeeping lives
    /// with the caller. One noise draw per segment, mirroring the
    /// one-draw-per-host-per-sample of tick mode.
    pub fn accumulate(&mut self, host: usize, watts: f64, dt: f64) {
        if dt <= 0.0 {
            return;
        }
        let measured = if self.noise_sigma > 0.0 {
            watts * self.noise.normal_clamped(1.0, self.noise_sigma, 0.9, 1.1)
        } else {
            watts
        };
        self.per_host_j[host] += measured * dt;
        self.per_host_true_j[host] += watts * dt;
    }

    /// Record one point on the fleet power / hosts-on traces without
    /// integrating energy — event mode emits these at telemetry events
    /// from its incrementally maintained fleet wattage.
    pub fn trace_point(&mut self, now: f64, total_w: f64, hosts_on: usize) {
        self.power_trace.push(now, total_w);
        self.hosts_on_trace.push(now, hosts_on as f64);
    }

    /// Total measured energy (J).
    pub fn total_j(&self) -> f64 {
        self.per_host_j.iter().sum()
    }

    /// Ground-truth energy (J).
    pub fn total_true_j(&self) -> f64 {
        self.per_host_true_j.iter().sum()
    }

    pub fn per_host_j(&self) -> &[f64] {
        &self.per_host_j
    }

    /// Workload-attributable energy: measured minus the idle baseline
    /// the same fleet would have drawn doing nothing (§IV-D's
    /// "subtracting idle baseline power").
    pub fn active_j(&self, idle_w_per_host: f64, horizon: f64) -> f64 {
        let baseline = idle_w_per_host * self.per_host_j.len() as f64 * horizon;
        (self.total_j() - baseline).max(0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Cluster;

    #[test]
    fn integrates_idle_cluster_exactly() {
        let cluster = Cluster::homogeneous(2);
        let mut m = EnergyMeter::new(2, 1, 0.0);
        for t in 1..=100 {
            m.sample(t as f64, &cluster);
        }
        // 2 hosts × 110 W × 100 s.
        assert!((m.total_j() - 22_000.0).abs() < 1e-6);
        assert_eq!(m.total_j(), m.total_true_j());
    }

    #[test]
    fn noise_is_small_and_unbiased() {
        let cluster = Cluster::homogeneous(5);
        let mut m = EnergyMeter::new(5, 7, 0.01);
        for t in 1..=3600 {
            m.sample(t as f64, &cluster);
        }
        let rel = (m.total_j() - m.total_true_j()).abs() / m.total_true_j();
        assert!(rel < 0.005, "noise bias {rel}");
    }

    #[test]
    fn powered_off_host_contributes_bmc_only() {
        let mut cluster = Cluster::homogeneous(2);
        cluster.host_mut(crate::cluster::HostId(1)).power_off(0.0);
        cluster.advance_power_states(1000.0);
        let mut m = EnergyMeter::new(2, 1, 0.0);
        m.sample(100.0, &cluster);
        // host0 idle 110 W, host1 off 5 W, over 100 s.
        assert!((m.total_j() - 11_500.0).abs() < 1e-6);
        assert!((m.per_host_j()[1] - 500.0).abs() < 1e-6);
    }

    #[test]
    fn active_energy_subtracts_baseline() {
        let cluster = Cluster::homogeneous(1);
        let mut m = EnergyMeter::new(1, 1, 0.0);
        for t in 1..=10 {
            m.sample(t as f64, &cluster);
        }
        // Fully idle: active ≈ 0.
        assert!(m.active_j(110.0, 10.0) < 1e-6);
    }

    #[test]
    fn traces_are_recorded() {
        let cluster = Cluster::homogeneous(3);
        let mut m = EnergyMeter::new(3, 1, 0.0);
        m.sample(1.0, &cluster);
        m.sample(2.0, &cluster);
        assert_eq!(m.power_trace.len(), 2);
        assert_eq!(m.hosts_on_trace.at(1.5), Some(3.0));
    }

    #[test]
    fn accumulate_matches_sample_for_constant_power() {
        // Tick-mode sample vs event-mode accumulate over the same
        // noise-free segment must integrate identical joules.
        let cluster = Cluster::homogeneous(2);
        let mut tick = EnergyMeter::new(2, 1, 0.0);
        for t in 1..=50 {
            tick.sample(t as f64, &cluster);
        }
        let mut event = EnergyMeter::new(2, 1, 0.0);
        for (i, h) in cluster.hosts.iter().enumerate() {
            event.accumulate(i, h.power(), 50.0);
        }
        assert!((tick.total_j() - event.total_j()).abs() < 1e-9);
        assert!((tick.total_true_j() - event.total_true_j()).abs() < 1e-9);
    }

    #[test]
    fn trace_point_records_without_integrating() {
        let mut m = EnergyMeter::new(1, 1, 0.0);
        m.trace_point(5.0, 220.0, 1);
        assert_eq!(m.power_trace.len(), 1);
        assert_eq!(m.hosts_on_trace.at(5.0), Some(1.0));
        assert_eq!(m.total_j(), 0.0);
    }

    #[test]
    fn zero_dt_sample_is_noop() {
        let cluster = Cluster::homogeneous(1);
        let mut m = EnergyMeter::new(1, 1, 0.0);
        m.sample(1.0, &cluster);
        let j = m.total_j();
        m.sample(1.0, &cluster);
        assert_eq!(m.total_j(), j);
    }
}
