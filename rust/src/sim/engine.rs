//! Discrete-event engine: a deterministic time-ordered event queue.
//!
//! Simulation time is `f64` seconds. Entries are ordered by
//! `(time, class, seq)` ascending: same-instant events pop in event-
//! class order, and within one class by insertion sequence (FIFO) —
//! which makes runs bit-for-bit reproducible, a hard requirement for
//! the paper's averaged-over-three-runs methodology to be implemented
//! as averaged-over-three-seeds. `push` uses a single default class,
//! so callers that never call `push_class` get pure FIFO ties.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Tie-break class assigned by plain `push`. Mid-range so class-aware
/// callers can schedule both before and after default-class events.
pub const DEFAULT_CLASS: u8 = 128;

/// Queue entry; ordered by (time, class, seq) ascending.
struct Entry<E> {
    time: f64,
    class: u8,
    seq: u64,
    event: E,
}

impl<E> PartialEq for Entry<E> {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.class == other.class && self.seq == other.seq
    }
}
impl<E> Eq for Entry<E> {}

impl<E> Ord for Entry<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reverse: BinaryHeap is a max-heap, we want earliest first.
        other
            .time
            .partial_cmp(&self.time)
            .expect("NaN event time")
            .then(other.class.cmp(&self.class))
            .then(other.seq.cmp(&self.seq))
    }
}
impl<E> PartialOrd for Entry<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// A deterministic discrete-event queue.
pub struct EventQueue<E> {
    heap: BinaryHeap<Entry<E>>,
    seq: u64,
    now: f64,
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> EventQueue<E> {
    pub fn new() -> EventQueue<E> {
        EventQueue {
            heap: BinaryHeap::new(),
            seq: 0,
            now: 0.0,
        }
    }

    /// Current simulation time (time of the last popped event).
    pub fn now(&self) -> f64 {
        self.now
    }

    /// Schedule an event at absolute time `t` with the default
    /// tie-break class. Scheduling in the past (before the last popped
    /// event) is a logic error.
    pub fn push(&mut self, t: f64, event: E) {
        self.push_class(t, DEFAULT_CLASS, event);
    }

    /// Schedule an event at absolute time `t` with an explicit
    /// tie-break class: among same-instant events, lower classes pop
    /// first, and equal classes pop FIFO.
    pub fn push_class(&mut self, t: f64, class: u8, event: E) {
        assert!(!t.is_nan(), "NaN event time");
        assert!(
            t >= self.now - 1e-9,
            "scheduling into the past: {t} < now {}",
            self.now
        );
        self.heap.push(Entry {
            time: t,
            class,
            seq: self.seq,
            event,
        });
        self.seq += 1;
    }

    /// Schedule relative to now.
    pub fn push_in(&mut self, dt: f64, event: E) {
        self.push(self.now + dt, event);
    }

    /// Schedule relative to now with an explicit tie-break class.
    pub fn push_class_in(&mut self, dt: f64, class: u8, event: E) {
        self.push_class(self.now + dt, class, event);
    }

    /// Pop the earliest event, advancing the clock.
    pub fn pop(&mut self) -> Option<(f64, E)> {
        let e = self.heap.pop()?;
        debug_assert!(e.time >= self.now - 1e-9);
        self.now = e.time.max(self.now);
        Some((self.now, e.event))
    }

    /// Time of the next event without popping.
    pub fn peek_time(&self) -> Option<f64> {
        self.heap.peek().map(|e| e.time)
    }

    /// The next event (time + payload) without popping — the
    /// coordinator uses this to coalesce same-instant submit bursts
    /// into one batched placement decision.
    pub fn peek(&self) -> Option<(f64, &E)> {
        self.heap.peek().map(|e| (e.time, &e.event))
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(3.0, "c");
        q.push(1.0, "a");
        q.push(2.0, "b");
        assert_eq!(q.pop(), Some((1.0, "a")));
        assert_eq!(q.pop(), Some((2.0, "b")));
        assert_eq!(q.pop(), Some((3.0, "c")));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn ties_break_fifo() {
        let mut q = EventQueue::new();
        q.push(1.0, "first");
        q.push(1.0, "second");
        q.push(1.0, "third");
        assert_eq!(q.pop().unwrap().1, "first");
        assert_eq!(q.pop().unwrap().1, "second");
        assert_eq!(q.pop().unwrap().1, "third");
    }

    #[test]
    fn clock_advances_monotonically() {
        let mut q = EventQueue::new();
        q.push(5.0, ());
        q.push(10.0, ());
        assert_eq!(q.now(), 0.0);
        q.pop();
        assert_eq!(q.now(), 5.0);
        q.push_in(1.0, ());
        let (t, _) = q.pop().unwrap();
        assert_eq!(t, 6.0);
        q.pop();
        assert_eq!(q.now(), 10.0);
    }

    #[test]
    #[should_panic(expected = "past")]
    fn scheduling_into_past_panics() {
        let mut q = EventQueue::new();
        q.push(5.0, ());
        q.pop();
        q.push(1.0, ());
    }

    #[test]
    fn peek_does_not_advance() {
        let mut q = EventQueue::new();
        q.push(2.0, ());
        assert_eq!(q.peek_time(), Some(2.0));
        assert_eq!(q.now(), 0.0);
        assert_eq!(q.len(), 1);
        assert!(!q.is_empty());
    }

    #[test]
    fn peek_sees_the_fifo_head() {
        let mut q = EventQueue::new();
        q.push(1.0, "second");
        q.push(0.5, "first");
        assert_eq!(q.peek(), Some((0.5, &"first")));
        q.pop();
        assert_eq!(q.peek(), Some((1.0, &"second")));
        q.pop();
        assert_eq!(q.peek(), None);
    }

    #[test]
    fn same_instant_events_pop_in_class_order() {
        let mut q = EventQueue::new();
        // Insert out of class order at one instant: classes must win.
        q.push_class(5.0, 7, "job_advance");
        q.push_class(5.0, 0, "power_transition");
        q.push_class(5.0, 6, "scan");
        q.push_class(5.0, 1, "fault");
        assert_eq!(q.pop().unwrap().1, "power_transition");
        assert_eq!(q.pop().unwrap().1, "fault");
        assert_eq!(q.pop().unwrap().1, "scan");
        assert_eq!(q.pop().unwrap().1, "job_advance");
    }

    #[test]
    fn classes_only_break_ties_never_reorder_time() {
        let mut q = EventQueue::new();
        q.push_class(2.0, 0, "later-but-low-class");
        q.push_class(1.0, 255, "earlier-but-high-class");
        assert_eq!(q.pop().unwrap().1, "earlier-but-high-class");
        assert_eq!(q.pop().unwrap().1, "later-but-low-class");
    }

    #[test]
    fn equal_class_ties_stay_fifo() {
        let mut q = EventQueue::new();
        q.push_class(1.0, 3, "first");
        q.push_class(1.0, 3, "second");
        q.push(1.0, "default-a"); // DEFAULT_CLASS = 128 > 3
        q.push_class(1.0, 3, "third");
        q.push(1.0, "default-b");
        assert_eq!(q.pop().unwrap().1, "first");
        assert_eq!(q.pop().unwrap().1, "second");
        assert_eq!(q.pop().unwrap().1, "third");
        assert_eq!(q.pop().unwrap().1, "default-a");
        assert_eq!(q.pop().unwrap().1, "default-b");
    }

    #[test]
    fn push_class_in_is_relative_to_now() {
        let mut q = EventQueue::new();
        q.push(5.0, "base");
        q.pop();
        q.push_class_in(1.0, 2, "low");
        q.push_class_in(1.0, 1, "lower");
        assert_eq!(q.pop(), Some((6.0, "lower")));
        assert_eq!(q.pop(), Some((6.0, "low")));
    }

    #[test]
    fn interleaved_push_pop_stays_ordered() {
        let mut q = EventQueue::new();
        q.push(1.0, 1);
        q.push(4.0, 4);
        assert_eq!(q.pop().unwrap().1, 1);
        q.push(2.0, 2);
        q.push(3.0, 3);
        assert_eq!(q.pop().unwrap().1, 2);
        assert_eq!(q.pop().unwrap().1, 3);
        assert_eq!(q.pop().unwrap().1, 4);
    }
}
