//! Simulation substrate: the discrete-event engine, the energy meter
//! (simulated Watts Up Pro), and the telemetry pipeline (simulated
//! dstat/perf). The coordinator composes these with the cluster and
//! workload models into full campaigns.

pub mod energy;
pub mod engine;
pub mod fault;
pub mod telemetry;

pub use energy::EnergyMeter;
pub use engine::EventQueue;
pub use fault::{FaultConfig, FaultEvent, FaultKind, FaultPlan, CHECKPOINT_J_PER_GB};
pub use telemetry::{Telemetry, SAMPLE_INTERVAL};
