//! Telemetry pipeline — the simulated dstat/perf monitors (§IV-C).
//!
//! Lightweight samplers record per-host utilization and per-VM demand
//! at 5-second intervals into bounded ring buffers. The profiler
//! (Eq. 1) consumes these series; the L1 `featurize` kernel's input
//! windows are exactly these buffers. Sampling jitter and quantization
//! reproduce what tool-based monitors actually deliver.

use crate::cluster::{Cluster, Demand, Utilization, VmId};
use crate::util::rng::Xoshiro256;
use std::collections::BTreeMap;

/// The paper's sampling interval (§IV-C).
pub const SAMPLE_INTERVAL: f64 = 5.0;

/// One host utilization sample.
#[derive(Debug, Clone, Copy)]
pub struct HostSample {
    pub t: f64,
    pub util: Utilization,
    pub power_w: f64,
}

/// One VM demand sample (absolute units).
#[derive(Debug, Clone, Copy)]
pub struct VmSample {
    pub t: f64,
    pub demand: Demand,
}

/// Bounded ring buffer of samples.
#[derive(Debug, Clone)]
pub struct Ring<T> {
    buf: Vec<T>,
    cap: usize,
    head: usize,
    len: usize,
}

impl<T: Copy> Ring<T> {
    pub fn new(cap: usize) -> Ring<T> {
        assert!(cap > 0);
        // Lazily allocated: `push` grows the buffer on demand up to
        // `cap`. A 10k-host campaign carries 10k host rings — eagerly
        // reserving `cap` samples each would burn hundreds of MB for
        // hosts that may never be sampled (sparse event-mode runs).
        Ring {
            buf: Vec::new(),
            cap,
            head: 0,
            len: 0,
        }
    }

    pub fn push(&mut self, x: T) {
        if self.buf.len() < self.cap {
            self.buf.push(x);
            self.len = self.buf.len();
        } else {
            self.buf[self.head] = x;
            self.head = (self.head + 1) % self.cap;
            self.len = self.cap;
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Samples oldest → newest.
    pub fn iter(&self) -> impl Iterator<Item = &T> {
        let (a, b) = self.buf.split_at(self.head.min(self.buf.len()));
        b.iter().chain(a.iter())
    }

    /// The most recent `n` samples, oldest → newest.
    pub fn last_n(&self, n: usize) -> Vec<T> {
        let all: Vec<T> = self.iter().copied().collect();
        let start = all.len().saturating_sub(n);
        all[start..].to_vec()
    }
}

/// The telemetry collector.
#[derive(Debug, Clone)]
pub struct Telemetry {
    pub hosts: Vec<Ring<HostSample>>,
    pub vms: BTreeMap<VmId, Ring<VmSample>>,
    noise: Xoshiro256,
    /// Relative sampling noise on utilization readings.
    noise_sigma: f64,
    vm_ring_cap: usize,
}

impl Telemetry {
    pub fn new(n_hosts: usize, seed: u64, noise_sigma: f64) -> Telemetry {
        // ~2 h of 5 s samples per host ring.
        let host_cap = 1500;
        Telemetry {
            hosts: (0..n_hosts).map(|_| Ring::new(host_cap)).collect(),
            vms: BTreeMap::new(),
            noise: Xoshiro256::seed_from_u64(seed ^ 0x7E1E),
            noise_sigma,
            vm_ring_cap: 720, // 1 h per VM
        }
    }

    /// Take one sampling pass over the cluster and the active VM
    /// demands. Call every [`SAMPLE_INTERVAL`].
    pub fn sample(&mut self, now: f64, cluster: &Cluster, vm_demands: &BTreeMap<VmId, Demand>) {
        self.sample_masked(now, cluster, vm_demands, &[]);
    }

    /// Sampling pass with per-host blackout masking. `masked[i]`
    /// (missing entries read as unmasked, so `&[]` is a plain
    /// [`Telemetry::sample`]) marks host `i`'s monitors dark for this
    /// pass: no sample lands — consumers see the stale tail of the
    /// ring — no noise draws are consumed for it, and the demand
    /// series of VMs executing on it pause too.
    pub fn sample_masked(
        &mut self,
        now: f64,
        cluster: &Cluster,
        vm_demands: &BTreeMap<VmId, Demand>,
        masked: &[bool],
    ) {
        for (i, host) in cluster.hosts.iter().enumerate() {
            if masked.get(i).copied().unwrap_or(false) {
                continue;
            }
            let u = host.utilization();
            let j = |x: f64, rng: &mut Xoshiro256| {
                if x == 0.0 {
                    0.0
                } else {
                    (x * rng.normal_clamped(1.0, 0.02, 0.9, 1.1)).clamp(0.0, 1.0)
                }
            };
            let util = if self.noise_sigma > 0.0 {
                Utilization {
                    cpu: j(u.cpu, &mut self.noise),
                    mem: j(u.mem, &mut self.noise),
                    disk: j(u.disk, &mut self.noise),
                    net: j(u.net, &mut self.noise),
                }
            } else {
                u
            };
            self.hosts[i].push(HostSample {
                t: now,
                util,
                power_w: host.power(),
            });
        }
        for (vm_id, demand) in vm_demands {
            // A VM executes on its (source, while migrating) host —
            // its monitor is dark whenever that host's is.
            let exec_host = cluster.vms.get(vm_id).and_then(|v| match v.state {
                crate::cluster::VmState::Migrating { from, .. } => Some(from),
                _ => v.host,
            });
            if let Some(h) = exec_host {
                if masked.get(h.0).copied().unwrap_or(false) {
                    continue;
                }
            }
            let ring = self
                .vms
                .entry(*vm_id)
                .or_insert_with(|| Ring::new(self.vm_ring_cap));
            ring.push(VmSample {
                t: now,
                demand: *demand,
            });
        }
    }

    /// Drop a finished VM's series (history is persisted elsewhere).
    pub fn forget_vm(&mut self, vm: VmId) {
        self.vms.remove(&vm);
    }

    /// Mean utilization of a host over its retained window.
    pub fn host_mean_util(&self, host: usize) -> Utilization {
        let ring = &self.hosts[host];
        if ring.is_empty() {
            return Utilization::default();
        }
        let mut acc = Utilization::default();
        let n = ring.len() as f64;
        for s in ring.iter() {
            acc.cpu += s.util.cpu;
            acc.mem += s.util.mem;
            acc.disk += s.util.disk;
            acc.net += s.util.net;
        }
        Utilization {
            cpu: acc.cpu / n,
            mem: acc.mem / n,
            disk: acc.disk / n,
            net: acc.net / n,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{Cluster, HostId};

    #[test]
    fn ring_wraps_and_orders() {
        let mut r = Ring::new(3);
        for i in 0..5 {
            r.push(i);
        }
        assert_eq!(r.len(), 3);
        let xs: Vec<i32> = r.iter().copied().collect();
        assert_eq!(xs, vec![2, 3, 4]);
        assert_eq!(r.last_n(2), vec![3, 4]);
        assert_eq!(r.last_n(10), vec![2, 3, 4]);
    }

    #[test]
    fn ring_before_wrap() {
        let mut r = Ring::new(10);
        r.push(1);
        r.push(2);
        let xs: Vec<i32> = r.iter().copied().collect();
        assert_eq!(xs, vec![1, 2]);
    }

    #[test]
    fn samples_hosts_and_vms() {
        let mut cluster = Cluster::homogeneous(2);
        let vm = cluster.create_vm(
            crate::cluster::flavor::SMALL,
            crate::workload::JobId(0),
            0.0,
        );
        cluster.place_vm(vm, HostId(0)).unwrap();
        let mut demands = BTreeMap::new();
        demands.insert(
            vm,
            Demand {
                cpu: 2.0,
                mem_gb: 4.0,
                disk_mbps: 10.0,
                net_mbps: 5.0,
            },
        );
        cluster.apply_demands(&demands);
        let mut t = Telemetry::new(2, 1, 0.0);
        t.sample(5.0, &cluster, &demands);
        t.sample(10.0, &cluster, &demands);
        assert_eq!(t.hosts[0].len(), 2);
        assert_eq!(t.vms[&vm].len(), 2);
        let u = t.host_mean_util(0);
        assert!(u.cpu > 0.0);
        assert_eq!(t.host_mean_util(1).cpu, 0.0);
    }

    #[test]
    fn noise_stays_clamped() {
        let mut cluster = Cluster::homogeneous(1);
        let vm = cluster.create_vm(
            crate::cluster::flavor::LARGE,
            crate::workload::JobId(0),
            0.0,
        );
        cluster.place_vm(vm, HostId(0)).unwrap();
        let mut demands = BTreeMap::new();
        demands.insert(
            vm,
            Demand {
                cpu: 16.0,
                mem_gb: 32.0,
                disk_mbps: 350.0,
                net_mbps: 90.0,
            },
        );
        cluster.apply_demands(&demands);
        let mut t = Telemetry::new(1, 3, 0.02);
        for i in 1..=200 {
            t.sample(i as f64 * 5.0, &cluster, &demands);
        }
        for s in t.hosts[0].iter() {
            assert!((0.0..=1.0).contains(&s.util.cpu));
            assert!((0.0..=1.0).contains(&s.util.net));
        }
    }

    #[test]
    fn masked_hosts_keep_stale_samples() {
        let mut cluster = Cluster::homogeneous(2);
        let vm = cluster.create_vm(
            crate::cluster::flavor::SMALL,
            crate::workload::JobId(0),
            0.0,
        );
        cluster.place_vm(vm, HostId(0)).unwrap();
        let mut demands = BTreeMap::new();
        demands.insert(
            vm,
            Demand {
                cpu: 2.0,
                mem_gb: 4.0,
                disk_mbps: 10.0,
                net_mbps: 5.0,
            },
        );
        cluster.apply_demands(&demands);
        let mut t = Telemetry::new(2, 1, 0.0);
        t.sample(5.0, &cluster, &demands);
        // Blackout on host 0: its ring (and its VM's) stays at one
        // sample while host 1 keeps collecting.
        t.sample_masked(10.0, &cluster, &demands, &[true, false]);
        t.sample_masked(15.0, &cluster, &demands, &[true, false]);
        assert_eq!(t.hosts[0].len(), 1, "masked host must not sample");
        assert_eq!(t.hosts[1].len(), 3);
        assert_eq!(t.vms[&vm].len(), 1, "VM on masked host pauses too");
        // Stale tail: the retained sample is the pre-blackout one.
        assert_eq!(t.hosts[0].last_n(1)[0].t, 5.0);
        // Window over: sampling resumes.
        t.sample(20.0, &cluster, &demands);
        assert_eq!(t.hosts[0].len(), 2);
        assert_eq!(t.vms[&vm].len(), 2);
    }

    #[test]
    fn forget_vm_drops_series() {
        let mut t = Telemetry::new(1, 1, 0.0);
        let cluster = Cluster::homogeneous(1);
        let mut demands = BTreeMap::new();
        demands.insert(VmId(9), Demand::ZERO);
        t.sample(5.0, &cluster, &demands);
        assert!(t.vms.contains_key(&VmId(9)));
        t.forget_vm(VmId(9));
        assert!(!t.vms.contains_key(&VmId(9)));
    }
}
