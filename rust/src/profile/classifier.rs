//! Dominant-resource classification — Eq. 2 of the paper:
//!
//! ```text
//! T_i = argmax{c_i, m_i, d_i}
//! ```
//!
//! CPU-intensive Spark MLlib tasks vs I/O-heavy ETL/shuffle pipelines.
//! We add a `Balanced` class for vectors whose components are within a
//! small margin of each other (argmax is noise-sensitive exactly when
//! the components tie, and placement treats balanced workloads
//! differently — they pack well anywhere).

use crate::profile::vector::ResourceVector;

/// Workload class per Eq. 2.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum WorkloadClass {
    CpuBound,
    MemBound,
    IoBound,
    /// No dominant component (within `BALANCED_MARGIN`).
    Balanced,
}

impl WorkloadClass {
    pub fn name(&self) -> &'static str {
        match self {
            WorkloadClass::CpuBound => "cpu-bound",
            WorkloadClass::MemBound => "mem-bound",
            WorkloadClass::IoBound => "io-bound",
            WorkloadClass::Balanced => "balanced",
        }
    }
}

/// Components within this relative margin of the max are considered
/// tied; if ≥2 tie, the workload is Balanced.
const BALANCED_MARGIN: f64 = 0.06;

/// Classify a profiled workload (Eq. 2 with the balanced extension).
pub fn classify(v: &ResourceVector) -> WorkloadClass {
    let c = v.cpu;
    let m = v.mem;
    let d = v.io(); // the paper's d_i: storage I/O behaviour (disk ∨ net)
    let max = c.max(m).max(d);
    if max < 1e-9 {
        return WorkloadClass::Balanced;
    }
    let near: Vec<bool> = [c, m, d]
        .iter()
        .map(|&x| (max - x) / max < BALANCED_MARGIN)
        .collect();
    if near.iter().filter(|&&b| b).count() >= 2 {
        return WorkloadClass::Balanced;
    }
    if c == max {
        WorkloadClass::CpuBound
    } else if m == max {
        WorkloadClass::MemBound
    } else {
        WorkloadClass::IoBound
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::flavor::MEDIUM;
    use crate::profile::vector::ResourceVector;
    use crate::util::rng::Xoshiro256;
    use crate::workload::{phases_for, WorkloadKind};

    fn vec3(c: f64, m: f64, io: f64) -> ResourceVector {
        ResourceVector {
            cpu: c,
            mem: m,
            disk: io,
            net: 0.0,
            ..Default::default()
        }
    }

    #[test]
    fn clear_dominance() {
        assert_eq!(classify(&vec3(0.9, 0.3, 0.2)), WorkloadClass::CpuBound);
        assert_eq!(classify(&vec3(0.2, 0.9, 0.3)), WorkloadClass::MemBound);
        assert_eq!(classify(&vec3(0.2, 0.3, 0.9)), WorkloadClass::IoBound);
    }

    #[test]
    fn near_ties_are_balanced() {
        assert_eq!(classify(&vec3(0.80, 0.78, 0.3)), WorkloadClass::Balanced);
        assert_eq!(classify(&vec3(0.0, 0.0, 0.0)), WorkloadClass::Balanced);
    }

    #[test]
    fn paper_benchmarks_classify_as_expected() {
        // §III-A: "CPU-intensive Spark MLlib tasks versus I/O-heavy ETL
        // pipelines"; §V-C adds shuffle-heavy Hadoop as I/O-bound.
        let mut rng = Xoshiro256::seed_from_u64(9);
        let mut class_of = |kind| {
            let phases = phases_for(kind, 20.0, &mut rng);
            classify(&ResourceVector::from_phases(&phases, &MEDIUM))
        };
        assert_eq!(class_of(WorkloadKind::SparkLogReg), WorkloadClass::CpuBound);
        assert_eq!(class_of(WorkloadKind::SparkKMeans), WorkloadClass::CpuBound);
        assert_eq!(class_of(WorkloadKind::HadoopGrep), WorkloadClass::IoBound);
        assert_eq!(
            class_of(WorkloadKind::EtlPipeline),
            WorkloadClass::IoBound
        );
        // TeraSort: shuffle-dominated → I/O-bound.
        assert_eq!(
            class_of(WorkloadKind::HadoopTeraSort),
            WorkloadClass::IoBound
        );
    }

    #[test]
    fn io_uses_max_of_disk_and_net() {
        let v = ResourceVector {
            cpu: 0.4,
            mem: 0.2,
            disk: 0.1,
            net: 0.9,
            ..Default::default()
        };
        assert_eq!(classify(&v), WorkloadClass::IoBound);
    }

    #[test]
    fn names() {
        assert_eq!(WorkloadClass::CpuBound.name(), "cpu-bound");
        assert_eq!(WorkloadClass::Balanced.name(), "balanced");
    }
}
