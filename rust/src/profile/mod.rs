//! Workload profiling (§III-A): Eq. 1 resource vectors from telemetry
//! or history, Eq. 2 dominant-resource classification, the execution
//! history store, and feature construction for the prediction engine.

pub mod classifier;
pub mod features;
pub mod history;
pub mod vector;

pub use classifier::{classify, WorkloadClass};
pub use features::{build_features, FEAT_DIM};
pub use history::{ExecutionRecord, HistoryStore};
pub use vector::ResourceVector;
