//! Workload resource-utilization vectors — Eq. 1 of the paper:
//!
//! ```text
//! W_i = (c_i, m_i, d_i, n_i)
//! ```
//!
//! built from telemetry windows (real-time path) or from phase models
//! (historical path), normalized to the worker flavor so vectors are
//! comparable across VM sizes. Beyond the paper's four means we retain
//! peaks and burstiness — the features §III-A's "static execution logs
//! and runtime performance counters" imply and the predictor needs.

use crate::cluster::{Demand, Flavor};
use crate::sim::telemetry::VmSample;
use crate::workload::Phase;

/// Normalized workload profile. All fields in [0, 1] except
/// `burstiness` (coefficient of variation, unbounded but typically <2).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct ResourceVector {
    /// Mean normalized demands — the paper's (c, m, d, n).
    pub cpu: f64,
    pub mem: f64,
    pub disk: f64,
    pub net: f64,
    /// 95th-percentile normalized CPU demand.
    pub cpu_peak: f64,
    /// 95th-percentile normalized I/O demand (max of disk, net).
    pub io_peak: f64,
    /// CPU coefficient of variation (std/mean) — phase burstiness.
    pub burstiness: f64,
}

impl ResourceVector {
    /// Combined I/O component `d_i` used by the Eq. 2 classifier
    /// (disk and network collapse into "storage I/O behaviour").
    pub fn io(&self) -> f64 {
        self.disk.max(self.net)
    }

    /// Build from a telemetry window of VM samples.
    pub fn from_samples(samples: &[VmSample], flavor: &Flavor) -> ResourceVector {
        if samples.is_empty() {
            return ResourceVector::default();
        }
        let n = samples.len() as f64;
        let norm = |d: &Demand| {
            (
                (d.cpu / flavor.vcpus).min(1.0),
                (d.mem_gb / flavor.mem_gb).min(1.0),
                (d.disk_mbps / flavor.disk_mbps).min(1.0),
                (d.net_mbps / flavor.net_mbps).min(1.0),
            )
        };
        let mut cpu_series = Vec::with_capacity(samples.len());
        let mut io_series = Vec::with_capacity(samples.len());
        let (mut sc, mut sm, mut sd, mut sn) = (0.0, 0.0, 0.0, 0.0);
        for s in samples {
            let (c, m, d, nn) = norm(&s.demand);
            sc += c;
            sm += m;
            sd += d;
            sn += nn;
            cpu_series.push(c);
            io_series.push(d.max(nn));
        }
        let cpu_mean = sc / n;
        let std = crate::util::stats::std_dev(&cpu_series);
        ResourceVector {
            cpu: cpu_mean,
            mem: sm / n,
            disk: sd / n,
            net: sn / n,
            cpu_peak: crate::util::stats::percentile(&cpu_series, 95.0),
            io_peak: crate::util::stats::percentile(&io_series, 95.0),
            burstiness: if cpu_mean > 1e-6 { std / cpu_mean } else { 0.0 },
        }
    }

    /// Build from a phase list, duration-weighted — the "historical
    /// execution logs" path (Eq. 1's static source): when a recurring
    /// job kind is submitted, its profile comes from the history store
    /// before any runtime telemetry exists.
    pub fn from_phases(phases: &[Phase], flavor: &Flavor) -> ResourceVector {
        let total: f64 = phases.iter().map(|p| p.duration).sum();
        if total <= 0.0 {
            return ResourceVector::default();
        }
        let mut v = ResourceVector::default();
        let mut cpu_peak: f64 = 0.0;
        let mut io_peak: f64 = 0.0;
        // Duration-weighted second moment for burstiness.
        let mut cpu_sq = 0.0;
        for p in phases {
            let w = p.duration / total;
            let c = (p.demand.cpu / flavor.vcpus).min(1.0);
            let m = (p.demand.mem_gb / flavor.mem_gb).min(1.0);
            let d = (p.demand.disk_mbps / flavor.disk_mbps).min(1.0);
            let n = (p.demand.net_mbps / flavor.net_mbps).min(1.0);
            v.cpu += w * c;
            v.mem += w * m;
            v.disk += w * d;
            v.net += w * n;
            cpu_sq += w * c * c;
            cpu_peak = cpu_peak.max(c);
            io_peak = io_peak.max(d.max(n));
        }
        v.cpu_peak = cpu_peak;
        v.io_peak = io_peak;
        let var = (cpu_sq - v.cpu * v.cpu).max(0.0);
        v.burstiness = if v.cpu > 1e-6 {
            var.sqrt() / v.cpu
        } else {
            0.0
        };
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::flavor::MEDIUM;
    use crate::util::rng::Xoshiro256;
    use crate::workload::{phases_for, WorkloadKind};

    fn sample(cpu: f64, disk: f64, net: f64) -> VmSample {
        VmSample {
            t: 0.0,
            demand: Demand {
                cpu,
                mem_gb: 8.0,
                disk_mbps: disk,
                net_mbps: net,
            },
        }
    }

    #[test]
    fn empty_window_is_default() {
        assert_eq!(
            ResourceVector::from_samples(&[], &MEDIUM),
            ResourceVector::default()
        );
    }

    #[test]
    fn means_normalize_to_flavor() {
        let samples = vec![sample(4.0, 100.0, 30.0); 10];
        let v = ResourceVector::from_samples(&samples, &MEDIUM);
        assert!((v.cpu - 0.5).abs() < 1e-9); // 4/8
        assert!((v.mem - 0.5).abs() < 1e-9); // 8/16
        assert!((v.disk - 0.5).abs() < 1e-9); // 100/200
        assert!((v.net - 0.5).abs() < 1e-9); // 30/60
        assert!(v.burstiness.abs() < 1e-9); // constant series
    }

    #[test]
    fn peaks_capture_spikes() {
        let mut samples = vec![sample(2.0, 20.0, 5.0); 18];
        samples.push(sample(8.0, 200.0, 60.0));
        samples.push(sample(8.0, 200.0, 60.0));
        let v = ResourceVector::from_samples(&samples, &MEDIUM);
        assert!(v.cpu_peak > 0.9, "cpu_peak {}", v.cpu_peak);
        assert!(v.cpu < 0.35);
        assert!(v.burstiness > 0.3);
    }

    #[test]
    fn phase_vector_weights_by_duration() {
        let mut rng = Xoshiro256::seed_from_u64(4);
        let ts = ResourceVector::from_phases(
            &phases_for(WorkloadKind::HadoopTeraSort, 20.0, &mut rng),
            &MEDIUM,
        );
        // TeraSort: io (net-dominated shuffle is the longest phase)
        // must dominate cpu.
        assert!(ts.io() > ts.cpu, "terasort io {} vs cpu {}", ts.io(), ts.cpu);

        let lr = ResourceVector::from_phases(
            &phases_for(WorkloadKind::SparkLogReg, 10.0, &mut rng),
            &MEDIUM,
        );
        assert!(lr.cpu > lr.io(), "logreg cpu {} vs io {}", lr.cpu, lr.io());
        assert!(lr.cpu > 0.6);
    }

    #[test]
    fn samples_and_phases_agree_for_flat_profile() {
        // A single flat phase sampled repeatedly must give ≈ the same
        // vector through both constructors.
        let phases = vec![Phase {
            name: "flat",
            duration: 100.0,
            demand: Demand {
                cpu: 6.0,
                mem_gb: 12.0,
                disk_mbps: 50.0,
                net_mbps: 20.0,
            },
        }];
        let from_phase = ResourceVector::from_phases(&phases, &MEDIUM);
        let samples: Vec<VmSample> = (0..20)
            .map(|_| VmSample {
                t: 0.0,
                demand: phases[0].demand,
            })
            .collect();
        let from_samples = ResourceVector::from_samples(&samples, &MEDIUM);
        assert!((from_phase.cpu - from_samples.cpu).abs() < 1e-9);
        assert!((from_phase.disk - from_samples.disk).abs() < 1e-9);
    }

    #[test]
    fn io_is_max_of_disk_net() {
        let v = ResourceVector {
            disk: 0.3,
            net: 0.7,
            ..Default::default()
        };
        assert_eq!(v.io(), 0.7);
    }
}
