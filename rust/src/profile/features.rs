//! Feature construction for the prediction engine — the contract
//! between L3 (this module), L2 (`python/compile/model.py`) and L1
//! (`python/compile/kernels/score_hosts.py`).
//!
//! **The layout below must match `FEATURE_NAMES` in model.py exactly.**
//!
//! | idx | feature                                  | range   |
//! |-----|------------------------------------------|---------|
//! | 0   | workload mean CPU (normalized)           | [0,1]   |
//! | 1   | workload mean memory                     | [0,1]   |
//! | 2   | workload mean disk                       | [0,1]   |
//! | 3   | workload mean net                        | [0,1]   |
//! | 4   | workload p95 CPU                         | [0,1]   |
//! | 5   | workload p95 I/O                         | [0,1]   |
//! | 6   | workload CPU burstiness (CoV, capped 2)  | [0,2]   |
//! | 7   | log1p(remaining solo seconds)/10         | [0,~1.2]|
//! | 8   | host CPU utilization                     | [0,1]   |
//! | 9   | host memory utilization                  | [0,1]   |
//! | 10  | host disk utilization                    | [0,1]   |
//! | 11  | host net utilization                     | [0,1]   |
//! | 12  | host resident-VM count / 8               | [0,~1]  |
//! | 13  | host DVFS frequency                      | [0.6,1] |
//! | 14  | cpu contention interaction w0·h8         | [0,1]   |
//! | 15  | memory pressure max(0, w1+h9−1)          | [0,1]   |

use crate::cluster::Host;
use crate::profile::vector::ResourceVector;

/// Number of input features — keep in sync with model.py.
pub const FEAT_DIM: usize = 16;

/// Build the feature vector for scoring (workload, host) placement
/// from the host's *instantaneous* utilization.
pub fn build_features(
    w: &ResourceVector,
    remaining_solo_secs: f64,
    host: &Host,
) -> [f32; FEAT_DIM] {
    build_features_from(w, remaining_solo_secs, &host.utilization(), host.vms.len(), host.freq)
}

/// Build the feature vector from an explicit utilization estimate —
/// the energy-aware policy passes max(instantaneous, profiled) so the
/// prediction reflects expected load, not the current phase trough.
pub fn build_features_from(
    w: &ResourceVector,
    remaining_solo_secs: f64,
    u: &crate::cluster::Utilization,
    n_vms: usize,
    freq: f64,
) -> [f32; FEAT_DIM] {
    let mut f = [0f32; FEAT_DIM];
    f[0] = w.cpu as f32;
    f[1] = w.mem as f32;
    f[2] = w.disk as f32;
    f[3] = w.net as f32;
    f[4] = w.cpu_peak as f32;
    f[5] = w.io_peak as f32;
    f[6] = w.burstiness.min(2.0) as f32;
    f[7] = ((remaining_solo_secs.max(0.0)).ln_1p() / 10.0) as f32;
    f[8] = u.cpu as f32;
    f[9] = u.mem as f32;
    f[10] = u.disk as f32;
    f[11] = u.net as f32;
    f[12] = (n_vms as f64 / 8.0) as f32;
    f[13] = freq as f32;
    f[14] = (w.cpu * u.cpu) as f32;
    f[15] = ((w.mem + u.mem - 1.0).max(0.0)) as f32;
    f
}

// A `[B, FEAT_DIM]` batch of rows is already the contiguous row-major
// layout the `predict.hlo` executable takes — consumers flatten with
// `slice::as_flattened`, no copy needed (the old `flatten_batch`
// helper allocated a Vec per call and is gone).

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{Cluster, Demand, HostId};

    fn host_with_load(cpu: f64, mem: f64) -> crate::cluster::Host {
        let mut c = Cluster::homogeneous(1);
        c.host_mut(HostId(0)).demand = Demand {
            cpu: cpu * 32.0,
            mem_gb: mem * 64.0,
            disk_mbps: 0.0,
            net_mbps: 0.0,
        };
        c.hosts[0].clone()
    }

    fn wvec() -> ResourceVector {
        ResourceVector {
            cpu: 0.8,
            mem: 0.5,
            disk: 0.2,
            net: 0.1,
            cpu_peak: 0.95,
            io_peak: 0.3,
            burstiness: 0.4,
        }
    }

    #[test]
    fn layout_matches_documentation() {
        let h = host_with_load(0.5, 0.6);
        let f = build_features(&wvec(), 300.0, &h);
        assert_eq!(f[0], 0.8f32);
        assert_eq!(f[8], 0.5f32);
        assert_eq!(f[9], 0.6f32);
        assert!((f[7] - ((301.0f64).ln() / 10.0) as f32).abs() < 1e-5);
        assert!((f[14] - 0.4f32).abs() < 1e-6); // 0.8*0.5
        assert!((f[15] - 0.1f32).abs() < 1e-6); // 0.5+0.6-1
        assert_eq!(f[13], 1.0f32);
    }

    #[test]
    fn memory_pressure_clamps_at_zero() {
        let h = host_with_load(0.1, 0.1);
        let f = build_features(&wvec(), 10.0, &h);
        assert_eq!(f[15], 0.0);
    }

    #[test]
    fn burstiness_capped() {
        let mut w = wvec();
        w.burstiness = 5.0;
        let h = host_with_load(0.0, 0.0);
        assert_eq!(build_features(&w, 10.0, &h)[6], 2.0);
    }

    #[test]
    fn all_features_finite_and_bounded() {
        let h = host_with_load(1.0, 1.0);
        let f = build_features(&wvec(), 1e6, &h);
        for (i, x) in f.iter().enumerate() {
            assert!(x.is_finite(), "feature {i} not finite");
            assert!((-0.01..=2.5).contains(&(*x as f64)), "feature {i} = {x}");
        }
    }

    #[test]
    fn batch_rows_flatten_row_major() {
        let mut a = [0f32; FEAT_DIM];
        let mut b = [0f32; FEAT_DIM];
        a[0] = 1.0;
        b[0] = 2.0;
        let batch = [a, b];
        let flat = batch.as_flattened();
        assert_eq!(flat.len(), 2 * FEAT_DIM);
        assert_eq!(flat[0], 1.0);
        assert_eq!(flat[FEAT_DIM], 2.0);
    }
}
