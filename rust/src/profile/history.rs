//! Execution-history store — the paper's "historical execution logs"
//! (§III-A): an append-only record of completed jobs with their
//! profiles and measured outcomes, indexed by workload kind.
//!
//! Two uses:
//! 1. **Profiling**: a newly submitted job of a known kind gets its
//!    Eq. 1 vector from history before any runtime telemetry exists.
//! 2. **Training**: `predict::trainer` derives (features → outcome)
//!    examples from these records.
//!
//! Persistence is JSON-lines (one record per line) so logs append
//! cheaply and survive restarts.

use crate::profile::vector::ResourceVector;
use crate::util::json::Json;
use crate::workload::WorkloadKind;
use std::collections::BTreeMap;
use std::io::Write;
use std::path::Path;

/// One completed-job record.
#[derive(Debug, Clone, PartialEq)]
pub struct ExecutionRecord {
    pub kind: WorkloadKind,
    pub gb: f64,
    /// The job's Eq. 1 profile (as measured by telemetry during the run).
    pub profile: ResourceVector,
    /// Measured job completion time (s).
    pub jct: f64,
    /// Calibrated solo JCT (s) — the SLA reference.
    pub solo: f64,
    /// Energy attributed to the job (J, idle-subtracted share).
    pub energy_j: f64,
    /// Mean CPU utilization of the hosting machine during the run.
    pub host_cpu_mean: f64,
}

impl ExecutionRecord {
    pub fn slowdown(&self) -> f64 {
        if self.solo <= 0.0 {
            0.0
        } else {
            (self.jct / self.solo - 1.0).max(0.0)
        }
    }

    fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("kind", Json::Str(self.kind.name().to_string()))
            .set("gb", Json::Num(self.gb))
            .set(
                "profile",
                Json::from_f64_slice(&[
                    self.profile.cpu,
                    self.profile.mem,
                    self.profile.disk,
                    self.profile.net,
                    self.profile.cpu_peak,
                    self.profile.io_peak,
                    self.profile.burstiness,
                ]),
            )
            .set("jct", Json::Num(self.jct))
            .set("solo", Json::Num(self.solo))
            .set("energy_j", Json::Num(self.energy_j))
            .set("host_cpu_mean", Json::Num(self.host_cpu_mean));
        o
    }

    fn from_json(j: &Json) -> Option<ExecutionRecord> {
        let p = j.get("profile")?.as_f64_vec()?;
        if p.len() != 7 {
            return None;
        }
        Some(ExecutionRecord {
            kind: WorkloadKind::by_name(j.get("kind")?.as_str()?)?,
            gb: j.get("gb")?.as_f64()?,
            profile: ResourceVector {
                cpu: p[0],
                mem: p[1],
                disk: p[2],
                net: p[3],
                cpu_peak: p[4],
                io_peak: p[5],
                burstiness: p[6],
            },
            jct: j.get("jct")?.as_f64()?,
            solo: j.get("solo")?.as_f64()?,
            energy_j: j.get("energy_j")?.as_f64()?,
            host_cpu_mean: j.get("host_cpu_mean")?.as_f64()?,
        })
    }
}

/// The store: in-memory index over an append-only log.
#[derive(Debug, Default)]
pub struct HistoryStore {
    records: Vec<ExecutionRecord>,
    by_kind: BTreeMap<WorkloadKind, Vec<usize>>,
}

impl HistoryStore {
    pub fn new() -> HistoryStore {
        HistoryStore::default()
    }

    pub fn len(&self) -> usize {
        self.records.len()
    }

    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    pub fn records(&self) -> &[ExecutionRecord] {
        &self.records
    }

    pub fn push(&mut self, r: ExecutionRecord) {
        self.by_kind
            .entry(r.kind)
            .or_default()
            .push(self.records.len());
        self.records.push(r);
    }

    pub fn of_kind(&self, kind: WorkloadKind) -> impl Iterator<Item = &ExecutionRecord> {
        self.by_kind
            .get(&kind)
            .into_iter()
            .flatten()
            .map(|&i| &self.records[i])
    }

    /// Historical mean profile for a kind — the static-log side of
    /// Eq. 1. None if the kind was never seen.
    pub fn mean_profile(&self, kind: WorkloadKind) -> Option<ResourceVector> {
        let rs: Vec<&ExecutionRecord> = self.of_kind(kind).collect();
        if rs.is_empty() {
            return None;
        }
        let n = rs.len() as f64;
        let mut v = ResourceVector::default();
        for r in &rs {
            v.cpu += r.profile.cpu;
            v.mem += r.profile.mem;
            v.disk += r.profile.disk;
            v.net += r.profile.net;
            v.cpu_peak += r.profile.cpu_peak;
            v.io_peak += r.profile.io_peak;
            v.burstiness += r.profile.burstiness;
        }
        v.cpu /= n;
        v.mem /= n;
        v.disk /= n;
        v.net /= n;
        v.cpu_peak /= n;
        v.io_peak /= n;
        v.burstiness /= n;
        Some(v)
    }

    /// Mean JCT per GB for a kind — used for SLA calibration of unseen
    /// sizes of recurring workloads.
    pub fn mean_solo_per_gb(&self, kind: WorkloadKind) -> Option<f64> {
        let rs: Vec<&ExecutionRecord> = self.of_kind(kind).collect();
        if rs.is_empty() {
            return None;
        }
        Some(rs.iter().map(|r| r.solo / r.gb.max(1.0)).sum::<f64>() / rs.len() as f64)
    }

    /// Append records to a JSON-lines log.
    pub fn save(&self, path: &Path) -> std::io::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::fs::File::create(path)?;
        for r in &self.records {
            writeln!(f, "{}", r.to_json())?;
        }
        Ok(())
    }

    /// Load a JSON-lines log; malformed lines are skipped with a count.
    pub fn load(path: &Path) -> std::io::Result<(HistoryStore, usize)> {
        let text = std::fs::read_to_string(path)?;
        let mut store = HistoryStore::new();
        let mut skipped = 0;
        for line in text.lines() {
            if line.trim().is_empty() {
                continue;
            }
            match Json::parse(line).ok().and_then(|j| ExecutionRecord::from_json(&j)) {
                Some(r) => store.push(r),
                None => skipped += 1,
            }
        }
        Ok((store, skipped))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(kind: WorkloadKind, cpu: f64, jct: f64, solo: f64) -> ExecutionRecord {
        ExecutionRecord {
            kind,
            gb: 10.0,
            profile: ResourceVector {
                cpu,
                mem: 0.4,
                disk: 0.3,
                net: 0.2,
                cpu_peak: cpu,
                io_peak: 0.3,
                burstiness: 0.1,
            },
            jct,
            solo,
            energy_j: 5000.0,
            host_cpu_mean: 0.5,
        }
    }

    #[test]
    fn push_and_query_by_kind() {
        let mut s = HistoryStore::new();
        s.push(rec(WorkloadKind::SparkKMeans, 0.9, 100.0, 95.0));
        s.push(rec(WorkloadKind::EtlPipeline, 0.2, 200.0, 210.0));
        s.push(rec(WorkloadKind::SparkKMeans, 0.8, 110.0, 100.0));
        assert_eq!(s.len(), 3);
        assert_eq!(s.of_kind(WorkloadKind::SparkKMeans).count(), 2);
        assert_eq!(s.of_kind(WorkloadKind::HadoopGrep).count(), 0);
    }

    #[test]
    fn mean_profile_averages() {
        let mut s = HistoryStore::new();
        s.push(rec(WorkloadKind::SparkKMeans, 0.9, 100.0, 95.0));
        s.push(rec(WorkloadKind::SparkKMeans, 0.7, 110.0, 100.0));
        let v = s.mean_profile(WorkloadKind::SparkKMeans).unwrap();
        assert!((v.cpu - 0.8).abs() < 1e-9);
        assert!(s.mean_profile(WorkloadKind::HadoopGrep).is_none());
    }

    #[test]
    fn slowdown_computation() {
        let r = rec(WorkloadKind::EtlPipeline, 0.2, 220.0, 200.0);
        assert!((r.slowdown() - 0.1).abs() < 1e-9);
        // Faster than solo (reduced contention) floors at 0.
        let r2 = rec(WorkloadKind::EtlPipeline, 0.2, 180.0, 200.0);
        assert_eq!(r2.slowdown(), 0.0);
    }

    #[test]
    fn save_load_roundtrip() {
        let dir = std::env::temp_dir().join("ecosched-test-history");
        let path = dir.join("log.jsonl");
        let mut s = HistoryStore::new();
        s.push(rec(WorkloadKind::HadoopTeraSort, 0.3, 500.0, 480.0));
        s.push(rec(WorkloadKind::SparkLogReg, 0.9, 120.0, 118.0));
        s.save(&path).unwrap();
        let (loaded, skipped) = HistoryStore::load(&path).unwrap();
        assert_eq!(skipped, 0);
        assert_eq!(loaded.len(), 2);
        assert_eq!(loaded.records()[0], s.records()[0]);
        assert_eq!(loaded.records()[1].kind, WorkloadKind::SparkLogReg);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn load_skips_malformed_lines() {
        let dir = std::env::temp_dir().join("ecosched-test-history2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("log.jsonl");
        let mut s = HistoryStore::new();
        s.push(rec(WorkloadKind::HadoopGrep, 0.2, 60.0, 58.0));
        s.save(&path).unwrap();
        let mut text = std::fs::read_to_string(&path).unwrap();
        text.push_str("not json\n{\"kind\":\"unknown-kind\"}\n");
        std::fs::write(&path, text).unwrap();
        let (loaded, skipped) = HistoryStore::load(&path).unwrap();
        assert_eq!(loaded.len(), 1);
        assert_eq!(skipped, 2);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn mean_solo_per_gb() {
        let mut s = HistoryStore::new();
        s.push(rec(WorkloadKind::HadoopGrep, 0.2, 60.0, 50.0)); // 5 s/GB
        assert!((s.mean_solo_per_gb(WorkloadKind::HadoopGrep).unwrap() - 5.0).abs() < 1e-9);
    }
}
