//! Big-data workload models — the simulated stand-ins for the paper's
//! benchmark suite (Hadoop MapReduce, Spark MLlib, ETL pipelines) plus
//! trace generation for multi-tenant campaigns.

pub mod etl;
pub mod hadoop;
pub mod mix;
pub mod model;
pub mod spark;
pub mod tracegen;

pub use mix::Mix;
pub use model::{Job, JobId, JobState, Phase, WorkloadKind};
pub use tracegen::{Arrivals, TraceSpec};

use crate::cluster::flavor::{Flavor, MEDIUM};
use crate::util::rng::Xoshiro256;

/// Generate the phase list for a job of the given kind and size.
pub fn phases_for(kind: WorkloadKind, gb: f64, rng: &mut Xoshiro256) -> Vec<Phase> {
    match kind {
        WorkloadKind::HadoopWordCount => hadoop::wordcount(gb, rng),
        WorkloadKind::HadoopTeraSort => hadoop::terasort(gb, rng),
        WorkloadKind::HadoopGrep => hadoop::grep(gb, rng),
        WorkloadKind::SparkLogReg => spark::logreg(gb, rng),
        WorkloadKind::SparkKMeans => spark::kmeans(gb, rng),
        WorkloadKind::EtlPipeline => etl::etl(gb, rng),
    }
}

/// Worker VM flavor per kind. All benchmarks use MEDIUM workers —
/// matching the per-worker demand calibration in each model module.
pub fn flavor_for(_kind: WorkloadKind) -> Flavor {
    MEDIUM
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn phases_for_dispatches_every_kind() {
        let mut rng = Xoshiro256::seed_from_u64(1);
        for kind in WorkloadKind::ALL {
            let phases = phases_for(kind, 10.0, &mut rng);
            assert!(!phases.is_empty(), "{kind:?}");
            let total: f64 = phases.iter().map(|p| p.duration).sum();
            assert!(total > 10.0, "{kind:?} too short: {total}");
            assert!(total < 4000.0, "{kind:?} too long: {total}");
        }
    }

    #[test]
    fn demands_never_exceed_worker_flavor() {
        let mut rng = Xoshiro256::seed_from_u64(2);
        for kind in WorkloadKind::ALL {
            let f = flavor_for(kind);
            for p in phases_for(kind, 50.0, &mut rng) {
                // capped_by() in the cluster enforces this at runtime;
                // models should stay within ~5 % of the flavor already.
                assert!(p.demand.cpu <= f.vcpus * 1.05, "{kind:?}/{}", p.name);
                assert!(p.demand.mem_gb <= f.mem_gb * 1.05, "{kind:?}/{}", p.name);
            }
        }
    }
}
