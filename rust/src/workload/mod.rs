//! Workload models, organized as three families:
//!
//! 1. **Batch generators** (`hadoop`, `spark`, `etl` + [`tracegen`]) —
//!    the paper's benchmark suite: multi-phase jobs in MEDIUM worker
//!    VMs, arrival processes (Poisson/diurnal/batch) over a [`Mix`].
//! 2. **FaaS** ([`faas`]) — serverless function invocations: short
//!    single-phase jobs in one-vCPU sandboxes with cold starts, warm
//!    pools, and keep-alive policies.
//! 3. **Trace replay** ([`trace`]) — a seeded Azure-2021-shaped Burr
//!    sampler and a generic CSV reader, emitting the same `Job`
//!    stream the generators do.

pub mod etl;
pub mod faas;
pub mod hadoop;
pub mod mix;
pub mod model;
pub mod spark;
pub mod trace;
pub mod tracegen;

pub use faas::{FaasConfig, FunctionId, KeepAliveConfig};
pub use mix::Mix;
pub use model::{Job, JobId, JobState, Phase, WorkloadKind};
pub use trace::FaasTraceSpec;
pub use tracegen::{Arrivals, TraceSpec};

use crate::cluster::flavor::{Flavor, FAAS, MEDIUM};
use crate::util::rng::Xoshiro256;

/// Generate the phase list for a job of the given kind and size.
pub fn phases_for(kind: WorkloadKind, gb: f64, rng: &mut Xoshiro256) -> Vec<Phase> {
    match kind {
        WorkloadKind::HadoopWordCount => hadoop::wordcount(gb, rng),
        WorkloadKind::HadoopTeraSort => hadoop::terasort(gb, rng),
        WorkloadKind::HadoopGrep => hadoop::grep(gb, rng),
        WorkloadKind::SparkLogReg => spark::logreg(gb, rng),
        WorkloadKind::SparkKMeans => spark::kmeans(gb, rng),
        WorkloadKind::EtlPipeline => etl::etl(gb, rng),
        WorkloadKind::Faas => faas::default_invocation(gb, rng),
    }
}

/// Worker VM flavor per kind. The batch benchmarks use MEDIUM workers
/// (matching the per-worker demand calibration in each model module);
/// FaaS invocations run in the one-vCPU FAAS sandbox slot.
pub fn flavor_for(kind: WorkloadKind) -> Flavor {
    match kind {
        WorkloadKind::Faas => FAAS,
        _ => MEDIUM,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn phases_for_dispatches_every_kind() {
        let mut rng = Xoshiro256::seed_from_u64(1);
        for kind in WorkloadKind::ALL {
            let phases = phases_for(kind, 10.0, &mut rng);
            assert!(!phases.is_empty(), "{kind:?}");
            let total: f64 = phases.iter().map(|p| p.duration).sum();
            assert!(total > 10.0, "{kind:?} too short: {total}");
            assert!(total < 4000.0, "{kind:?} too long: {total}");
        }
    }

    #[test]
    fn faas_dispatch_uses_the_sandbox_flavor() {
        let mut rng = Xoshiro256::seed_from_u64(3);
        assert_eq!(flavor_for(WorkloadKind::Faas).name, "faas");
        let phases = phases_for(WorkloadKind::Faas, 0.5, &mut rng);
        assert_eq!(phases.len(), 1);
        assert!(phases[0].duration < 100.0, "invocations are short");
    }

    #[test]
    fn demands_never_exceed_worker_flavor() {
        let mut rng = Xoshiro256::seed_from_u64(2);
        for kind in WorkloadKind::ALL {
            let f = flavor_for(kind);
            for p in phases_for(kind, 50.0, &mut rng) {
                // capped_by() in the cluster enforces this at runtime;
                // models should stay within ~5 % of the flavor already.
                assert!(p.demand.cpu <= f.vcpus * 1.05, "{kind:?}/{}", p.name);
                assert!(p.demand.mem_gb <= f.mem_gb * 1.05, "{kind:?}/{}", p.name);
            }
        }
    }
}
