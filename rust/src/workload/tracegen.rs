//! Workload trace generation: arrival processes and job synthesis.
//!
//! The paper's campaigns run each benchmark category under both
//! schedulers on the five-node testbed. We reproduce that as traces:
//! a list of (kind, size, submit-time) tuples realized into [`Job`]s
//! with per-job seeded phase jitter. Arrivals follow either a Poisson
//! process (steady multi-tenant load) or a diurnal profile (the
//! day/night cycle that gives ETL its off-peak opportunity, §V-C).

use crate::util::rng::Xoshiro256;
use crate::workload::mix::Mix;
use crate::workload::model::{Job, JobId, WorkloadKind};
use crate::workload::phases_for;

/// Arrival process shapes.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Arrivals {
    /// Homogeneous Poisson with the given mean inter-arrival (s).
    Poisson { mean_gap: f64 },
    /// Poisson modulated by a 24 h sinusoid compressed into the
    /// campaign: rate peaks mid-campaign and troughs at the edges.
    /// `peak_to_trough` ≥ 1 controls the swing.
    Diurnal { mean_gap: f64, peak_to_trough: f64 },
    /// All jobs submitted at t=0 (closed batch, like the paper's
    /// per-benchmark runs).
    Batch,
}

/// Trace generation parameters.
#[derive(Debug, Clone)]
pub struct TraceSpec {
    pub mix: Mix,
    pub n_jobs: usize,
    pub arrivals: Arrivals,
    /// Campaign horizon (s) used by the diurnal modulator.
    pub horizon: f64,
}

impl TraceSpec {
    /// Realize the trace into jobs, deterministically per seed.
    pub fn generate(&self, seed: u64) -> Vec<Job> {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let mut arrival_rng = rng.child(0xA11);
        let mut t = 0.0;
        let mut jobs = Vec::with_capacity(self.n_jobs);
        for i in 0..self.n_jobs {
            let kind = self.mix.sample(&mut rng);
            let gb = sample_gb(kind, &mut rng);
            let submit_at = match self.arrivals {
                Arrivals::Batch => 0.0,
                Arrivals::Poisson { mean_gap } => {
                    t += arrival_rng.exponential(1.0 / mean_gap);
                    t
                }
                Arrivals::Diurnal {
                    mean_gap,
                    peak_to_trough,
                } => {
                    // Thin a Poisson stream by the diurnal envelope.
                    let gap = loop {
                        let g = arrival_rng.exponential(1.0 / mean_gap);
                        let phase = ((t + g) / self.horizon).clamp(0.0, 1.0);
                        let envelope = diurnal_envelope(phase, peak_to_trough);
                        if arrival_rng.next_f64() < envelope {
                            break g;
                        }
                        t += g;
                    };
                    t += gap;
                    t
                }
            };
            let mut job_rng = rng.child(0xB0B + i as u64);
            let phases = phases_for(kind, gb, &mut job_rng);
            jobs.push(Job::new(JobId(i as u64), kind, gb, phases, submit_at));
        }
        jobs
    }
}

/// Relative arrival intensity at campaign phase `x` in [0,1]:
/// sinusoid peaking at x = 0.5, normalized to max 1.
fn diurnal_envelope(x: f64, peak_to_trough: f64) -> f64 {
    let trough = 1.0 / peak_to_trough.max(1.0);
    let s = 0.5 - 0.5 * (2.0 * std::f64::consts::PI * x).cos(); // 0 at edges, 1 mid
    trough + (1.0 - trough) * s
}

/// Dataset sizes per kind (§IV-B: Hadoop 5–50 GB; Spark bounded by
/// executor memory; ETL mid-sized warehousing batches).
pub fn sample_gb(kind: WorkloadKind, rng: &mut Xoshiro256) -> f64 {
    let (lo, hi) = gb_range(kind);
    // Mild heavy tail: most jobs small, a few near the max.
    let u = rng.next_f64().powf(1.4);
    (lo + (hi - lo) * u).round().max(1.0)
}

pub fn gb_range(kind: WorkloadKind) -> (f64, f64) {
    match kind {
        WorkloadKind::HadoopWordCount
        | WorkloadKind::HadoopTeraSort
        | WorkloadKind::HadoopGrep => (5.0, 50.0),
        WorkloadKind::SparkLogReg | WorkloadKind::SparkKMeans => (5.0, 20.0),
        WorkloadKind::EtlPipeline => (5.0, 25.0),
        // FaaS "gb" is the function working set, capped by its 1 GB
        // sandbox; `sample_gb`'s round-up floor makes this always 1.
        WorkloadKind::Faas => (1.0, 1.0),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::mix::Mix;

    fn spec(arrivals: Arrivals) -> TraceSpec {
        TraceSpec {
            mix: Mix::paper(),
            n_jobs: 60,
            arrivals,
            horizon: 7200.0,
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let s = spec(Arrivals::Poisson { mean_gap: 60.0 });
        let a = s.generate(7);
        let b = s.generate(7);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.kind, y.kind);
            assert_eq!(x.gb, y.gb);
            assert_eq!(x.submit_at, y.submit_at);
            assert_eq!(x.solo_duration(), y.solo_duration());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let s = spec(Arrivals::Poisson { mean_gap: 60.0 });
        let a = s.generate(1);
        let b = s.generate(2);
        let same = a
            .iter()
            .zip(&b)
            .filter(|(x, y)| x.kind == y.kind && x.gb == y.gb)
            .count();
        assert!(same < a.len(), "seeds produced identical traces");
    }

    #[test]
    fn poisson_gaps_average_out() {
        let s = TraceSpec {
            mix: Mix::paper(),
            n_jobs: 2000,
            arrivals: Arrivals::Poisson { mean_gap: 30.0 },
            horizon: 1e9,
        };
        let jobs = s.generate(11);
        let last = jobs.last().unwrap().submit_at;
        let mean_gap = last / (jobs.len() - 1) as f64;
        assert!((mean_gap - 30.0).abs() < 3.0, "mean gap {mean_gap}");
    }

    #[test]
    fn batch_arrivals_all_at_zero() {
        let s = spec(Arrivals::Batch);
        assert!(s.generate(3).iter().all(|j| j.submit_at == 0.0));
    }

    #[test]
    fn diurnal_concentrates_mid_campaign() {
        let s = TraceSpec {
            mix: Mix::paper(),
            n_jobs: 600,
            arrivals: Arrivals::Diurnal {
                mean_gap: 8.0,
                peak_to_trough: 4.0,
            },
            horizon: 7200.0,
        };
        let jobs = s.generate(5);
        let horizon = 7200.0;
        let mid = jobs
            .iter()
            .filter(|j| j.submit_at > horizon * 0.3 && j.submit_at < horizon * 0.7)
            .count() as f64;
        let edge = jobs
            .iter()
            .filter(|j| j.submit_at < horizon * 0.2)
            .count() as f64;
        assert!(
            mid / 0.4 > edge / 0.2,
            "diurnal should concentrate arrivals mid-campaign (mid {mid}, edge {edge})"
        );
    }

    #[test]
    fn sizes_respect_ranges() {
        let mut rng = Xoshiro256::seed_from_u64(13);
        for kind in WorkloadKind::ALL {
            let (lo, hi) = gb_range(kind);
            for _ in 0..200 {
                let gb = sample_gb(kind, &mut rng);
                assert!(gb >= lo && gb <= hi, "{kind:?} size {gb}");
            }
        }
    }

    #[test]
    fn envelope_bounds() {
        for i in 0..=10 {
            let e = diurnal_envelope(i as f64 / 10.0, 4.0);
            assert!((0.25..=1.0).contains(&e));
        }
        assert!((diurnal_envelope(0.5, 4.0) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn job_ids_are_sequential() {
        let jobs = spec(Arrivals::Batch).generate(1);
        for (i, j) in jobs.iter().enumerate() {
            assert_eq!(j.id, JobId(i as u64));
        }
    }
}
