//! Workload mixes: the tenant compositions experiments run against.

use crate::util::rng::Xoshiro256;
use crate::workload::model::WorkloadKind;

/// A categorical distribution over workload kinds.
#[derive(Debug, Clone)]
pub struct Mix {
    pub name: &'static str,
    kinds: Vec<WorkloadKind>,
    weights: Vec<f64>,
}

impl Mix {
    pub fn new(name: &'static str, entries: &[(WorkloadKind, f64)]) -> Mix {
        assert!(!entries.is_empty());
        assert!(entries.iter().all(|(_, w)| *w > 0.0));
        Mix {
            name,
            kinds: entries.iter().map(|(k, _)| *k).collect(),
            weights: entries.iter().map(|(_, w)| *w).collect(),
        }
    }

    /// The paper's evaluation mix: all three categories, Hadoop split
    /// across its three benchmarks (§IV-B).
    pub fn paper() -> Mix {
        Mix::new(
            "paper",
            &[
                (WorkloadKind::HadoopWordCount, 1.0),
                (WorkloadKind::HadoopTeraSort, 1.0),
                (WorkloadKind::HadoopGrep, 1.0),
                (WorkloadKind::SparkLogReg, 1.5),
                (WorkloadKind::SparkKMeans, 1.5),
                (WorkloadKind::EtlPipeline, 3.0),
            ],
        )
    }

    /// Single-kind mix (per-benchmark campaigns, Table 1 rows).
    pub fn only(kind: WorkloadKind) -> Mix {
        Mix::new(kind.name_static(), &[(kind, 1.0)])
    }

    /// CPU-heavy tenant (Spark analytics shop).
    pub fn cpu_heavy() -> Mix {
        Mix::new(
            "cpu_heavy",
            &[
                (WorkloadKind::SparkLogReg, 3.0),
                (WorkloadKind::SparkKMeans, 3.0),
                (WorkloadKind::HadoopWordCount, 1.0),
            ],
        )
    }

    /// I/O-heavy tenant (warehousing + batch sort).
    pub fn io_heavy() -> Mix {
        Mix::new(
            "io_heavy",
            &[
                (WorkloadKind::HadoopTeraSort, 2.0),
                (WorkloadKind::HadoopGrep, 2.0),
                (WorkloadKind::EtlPipeline, 3.0),
            ],
        )
    }

    pub fn sample(&self, rng: &mut Xoshiro256) -> WorkloadKind {
        self.kinds[rng.categorical(&self.weights)]
    }
}

impl WorkloadKind {
    /// `name()` with 'static lifetime for Mix labels.
    pub fn name_static(&self) -> &'static str {
        self.name()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_mix_covers_all_kinds() {
        let mix = Mix::paper();
        let mut rng = Xoshiro256::seed_from_u64(1);
        let mut seen = std::collections::BTreeSet::new();
        for _ in 0..2000 {
            seen.insert(mix.sample(&mut rng));
        }
        assert_eq!(seen.len(), WorkloadKind::ALL.len());
    }

    #[test]
    fn only_mix_is_pure() {
        let mix = Mix::only(WorkloadKind::HadoopTeraSort);
        let mut rng = Xoshiro256::seed_from_u64(2);
        for _ in 0..100 {
            assert_eq!(mix.sample(&mut rng), WorkloadKind::HadoopTeraSort);
        }
    }

    #[test]
    fn faas_works_as_a_single_kind_mix() {
        // Faas is outside the paper mix (and ALL) but a pure-FaaS
        // tenant is a legal campaign composition.
        let mix = Mix::only(WorkloadKind::Faas);
        assert_eq!(mix.name, "faas");
        let mut rng = Xoshiro256::seed_from_u64(4);
        for _ in 0..50 {
            assert_eq!(mix.sample(&mut rng), WorkloadKind::Faas);
        }
    }

    #[test]
    fn weights_bias_sampling() {
        let mix = Mix::cpu_heavy();
        let mut rng = Xoshiro256::seed_from_u64(3);
        let mut spark = 0;
        let n = 5000;
        for _ in 0..n {
            if mix.sample(&mut rng).category() == "spark" {
                spark += 1;
            }
        }
        let frac = spark as f64 / n as f64;
        assert!((0.8..0.93).contains(&frac), "spark fraction {frac}");
    }

    #[test]
    #[should_panic]
    fn zero_weight_rejected() {
        Mix::new("bad", &[(WorkloadKind::EtlPipeline, 0.0)]);
    }
}
