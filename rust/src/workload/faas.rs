//! Serverless function-invocation workload family.
//!
//! Each invocation is an ordinary [`crate::workload::Job`] — one
//! short execution phase sized by the function's footprint — tagged
//! with a [`FunctionId`] and placed through the existing policy path
//! in a one-vCPU [`crate::cluster::flavor::FAAS`] slot. What makes
//! the family distinct is the sandbox lifecycle around each job (see
//! [`crate::cluster::container`]): a cold start stalls the invocation
//! through a boot-draw window (latency *and* energy), a warm hit
//! skips it, and completed invocations park their sandbox warm for a
//! keep-alive window chosen by a [`KeepAlivePolicy`].
//!
//! # The keep-alive control loop
//!
//! Warm sandboxes must eventually be evicted or the fleet pays their
//! memory (β-term) power forever. Expiry runs as [`KeepAliveLoop`],
//! a standard [`ControlLoop`] on the coordinator's scan cadence and
//! registered whenever the campaign has a
//! [`FaasConfig`] — under *every* placement policy, unlike the
//! consolidation/DVFS loops which only run for policies that opt in.
//! The scan is a per-shard pass through
//! [`ScheduleContext::for_each_shard`] (pooled at width > 1, inline
//! otherwise) that emits one `ExpireContainers` action per host
//! holding an expired warm sandbox; actuation revalidates against the
//! live clock, so a stale scan is harmless. It is deliberately
//! ordered before consolidation and DVFS in the loop list so those
//! observe the post-expiry warm footprint.
//!
//! Keep-alive policies:
//! - [`FixedKeepAlive`] — one global window (OpenWhisk-style).
//! - [`HybridHistogram`] — per-function inter-arrival histograms in
//!   the manner of the hybrid policy of the Azure "Serverless in the
//!   Wild" line of work (and dslab-faas): frequent, predictable
//!   functions get a window just past their observed inter-arrival
//!   quantile; rare or erratic ones get a minimal window instead of
//!   wasting warm memory.

use crate::cluster::Demand;
use crate::sched::control::{ControlAction, ControlLoop, ScoringHandle};
use crate::sched::ScheduleContext;
use crate::util::rng::Xoshiro256;
use crate::workload::model::Phase;
use std::collections::BTreeMap;

/// Stable identifier of a serverless function (dense index into the
/// trace's function population).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct FunctionId(pub u32);

impl std::fmt::Display for FunctionId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "fn-{}", self.0)
    }
}

/// Phase list for one invocation: a single short execution burst at
/// the function's footprint. Demands stay within the FAAS flavor
/// (1 vCPU / 1 GB) so the slot never oversubscribes its own sandbox.
pub fn invocation_phases(cpu: f64, mem_gb: f64, exec_s: f64) -> Vec<Phase> {
    vec![Phase {
        name: "invoke",
        duration: exec_s.max(0.05),
        demand: Demand {
            cpu: cpu.clamp(0.05, 1.0),
            mem_gb: mem_gb.clamp(0.05, 1.0),
            // Small flows: below the progress-rate thresholds, so
            // invocations are gated by CPU/mem contention only.
            disk_mbps: 2.0,
            net_mbps: 1.0,
        },
    }]
}

/// Generic dispatch entry (`phases_for(WorkloadKind::Faas, ..)`):
/// footprint jittered per job, `gb` read as the function's working
/// set. Trace fronts with real per-function specs call
/// [`invocation_phases`] directly instead.
pub fn default_invocation(gb: f64, rng: &mut Xoshiro256) -> Vec<Phase> {
    let cpu = rng.uniform(0.2, 1.0);
    let exec = rng.lognormal(0.8, 0.6).clamp(0.2, 60.0);
    invocation_phases(cpu, gb.clamp(0.125, 1.0), exec)
}

/// Per-function keep-alive decisions: how long a sandbox parked at
/// invocation completion stays warm. `observe_arrival` is fed every
/// invocation arrival (once, at submit time); `window` is read when a
/// sandbox is parked.
pub trait KeepAlivePolicy {
    fn name(&self) -> &'static str;
    fn observe_arrival(&mut self, function: FunctionId, now: f64);
    fn window(&self, function: FunctionId) -> f64;
}

/// One global keep-alive window for every function — the fixed
/// OpenWhisk-style baseline.
#[derive(Debug, Clone, Copy)]
pub struct FixedKeepAlive {
    pub window: f64,
}

impl Default for FixedKeepAlive {
    fn default() -> Self {
        FixedKeepAlive { window: 120.0 }
    }
}

impl KeepAlivePolicy for FixedKeepAlive {
    fn name(&self) -> &'static str {
        "fixed"
    }

    fn observe_arrival(&mut self, _function: FunctionId, _now: f64) {}

    fn window(&self, _function: FunctionId) -> f64 {
        self.window
    }
}

/// Tuning knobs of [`HybridHistogram`]. `Copy` so it can ride inside
/// [`KeepAliveConfig`] in a `CampaignConfig`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct HybridParams {
    /// Histogram bin width (s).
    pub bin_secs: f64,
    /// Number of bins; inter-arrivals past `bin_secs · n_bins` land
    /// in the out-of-bounds bucket.
    pub n_bins: usize,
    /// Inter-arrival quantile the window must cover.
    pub quantile: f64,
    /// Safety margin multiplied onto the quantile bin's upper edge.
    pub margin: f64,
    /// Window for functions not worth keeping warm (rare/erratic).
    pub min_window: f64,
    /// Window before enough observations accrue — matches the fixed
    /// baseline so the comparison is cold-start-honest at the head.
    pub default_window: f64,
    /// Out-of-bounds fraction above which the function is declared
    /// unpredictable and parked with `min_window`.
    pub oob_threshold: f64,
}

impl Default for HybridParams {
    fn default() -> Self {
        HybridParams {
            bin_secs: 10.0,
            n_bins: 60, // 600 s of range, one order past the fixed window
            quantile: 0.97,
            margin: 1.15,
            min_window: 5.0,
            default_window: 120.0,
            oob_threshold: 0.5,
        }
    }
}

/// Per-function inter-arrival histogram.
#[derive(Debug, Clone)]
struct FnHist {
    bins: Vec<u32>,
    oob: u32,
    total: u32,
    last_arrival: Option<f64>,
}

/// Hybrid-histogram keep-alive: tracks each function's inter-arrival
/// distribution and grants a per-function window that covers its
/// `quantile` inter-arrival (plus margin), falling back to
/// `default_window` while data is scarce and to `min_window` when the
/// function's arrivals are too spread out for warmth to pay off.
#[derive(Debug, Clone)]
pub struct HybridHistogram {
    pub params: HybridParams,
    hists: BTreeMap<FunctionId, FnHist>,
}

impl HybridHistogram {
    pub fn new(params: HybridParams) -> HybridHistogram {
        HybridHistogram {
            params,
            hists: BTreeMap::new(),
        }
    }
}

impl KeepAlivePolicy for HybridHistogram {
    fn name(&self) -> &'static str {
        "hybrid_histogram"
    }

    fn observe_arrival(&mut self, function: FunctionId, now: f64) {
        let p = self.params;
        let h = self.hists.entry(function).or_insert_with(|| FnHist {
            bins: vec![0; p.n_bins],
            oob: 0,
            total: 0,
            last_arrival: None,
        });
        if let Some(last) = h.last_arrival {
            let iat = (now - last).max(0.0);
            let bin = (iat / p.bin_secs) as usize;
            if bin < p.n_bins {
                h.bins[bin] += 1;
            } else {
                h.oob += 1;
            }
            h.total += 1;
        }
        h.last_arrival = Some(now);
    }

    fn window(&self, function: FunctionId) -> f64 {
        let p = self.params;
        let Some(h) = self.hists.get(&function) else {
            return p.default_window;
        };
        if h.total < 4 {
            return p.default_window;
        }
        if f64::from(h.oob) > p.oob_threshold * f64::from(h.total) {
            return p.min_window;
        }
        let target = (p.quantile * f64::from(h.total)).ceil() as u32;
        let mut acc = 0u32;
        for (i, &count) in h.bins.iter().enumerate() {
            acc += count;
            if acc >= target {
                // Upper edge of the quantile bin, with margin.
                return (p.margin * (i as f64 + 1.0) * p.bin_secs).max(p.min_window);
            }
        }
        // The quantile sits in the out-of-bounds tail: covering it
        // would need a window past the histogram range — not worth
        // the warm memory.
        p.min_window
    }
}

/// Serializable keep-alive choice for `CampaignConfig`; built into a
/// live policy object by the coordinator at campaign start.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum KeepAliveConfig {
    Fixed { window: f64 },
    Hybrid(HybridParams),
}

impl Default for KeepAliveConfig {
    fn default() -> Self {
        KeepAliveConfig::Fixed { window: 120.0 }
    }
}

impl KeepAliveConfig {
    pub fn build(self) -> Box<dyn KeepAlivePolicy> {
        match self {
            KeepAliveConfig::Fixed { window } => Box::new(FixedKeepAlive { window }),
            KeepAliveConfig::Hybrid(params) => Box::new(HybridHistogram::new(params)),
        }
    }
}

/// Campaign-level switch for the serverless subsystem. `None` in
/// `CampaignConfig.faas` (the default) means function-tagged jobs run
/// as plain VMs — no sandboxes, no cold starts — and nothing in the
/// batch families changes.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaasConfig {
    /// Sandbox cold-start latency (s) — the container-scale
    /// `BOOT_SECS`; the invocation stalls and the host draws
    /// [`crate::cluster::container::CONTAINER_BOOT_W`] through it.
    pub cold_start_secs: f64,
    pub keep_alive: KeepAliveConfig,
}

impl Default for FaasConfig {
    fn default() -> Self {
        FaasConfig {
            cold_start_secs: 2.0,
            keep_alive: KeepAliveConfig::default(),
        }
    }
}

/// Keep-alive expiry as a [`ControlLoop`]: per-shard scans emitting
/// one [`ControlAction::ExpireContainers`] per host with an expired
/// warm sandbox (see module docs).
#[derive(Debug, Default)]
pub struct KeepAliveLoop;

impl ControlLoop for KeepAliveLoop {
    fn name(&self) -> &'static str {
        "keep_alive"
    }

    fn box_clone(&self) -> Box<dyn ControlLoop> {
        Box::new(KeepAliveLoop)
    }

    fn scan(
        &mut self,
        ctx: &ScheduleContext<'_>,
        _scoring: Option<ScoringHandle<'_>>,
    ) -> Vec<ControlAction> {
        // Per-shard passes on the pool (inline when serial); flatten
        // in ascending shard order — the deterministic merge.
        ctx.for_each_shard(|shard| scan_shard(ctx, shard))
            .into_iter()
            .flatten()
            .collect()
    }
}

/// One shard's expiry pass. Read-only — the actual eviction happens
/// at actuation, revalidated against the then-current clock.
fn scan_shard(ctx: &ScheduleContext<'_>, shard: usize) -> Vec<ControlAction> {
    let mut out = Vec::new();
    for host_id in ctx.shard(shard).hosts() {
        if ctx.cluster.hosts[host_id.0].has_expired_warm(ctx.now) {
            out.push(ControlAction::ExpireContainers(host_id));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::flavor::FAAS;
    use crate::cluster::{Cluster, HostId};

    #[test]
    fn invocation_demands_fit_the_faas_flavor() {
        let mut rng = Xoshiro256::seed_from_u64(1);
        for _ in 0..200 {
            let gb = rng.uniform(0.05, 2.0);
            for p in default_invocation(gb, &mut rng) {
                assert!(p.demand.cpu <= FAAS.vcpus * 1.05, "{}", p.demand.cpu);
                assert!(p.demand.mem_gb <= FAAS.mem_gb * 1.05, "{}", p.demand.mem_gb);
                assert!(p.duration > 0.0);
            }
        }
    }

    #[test]
    fn fixed_policy_is_flat() {
        let mut p = FixedKeepAlive { window: 60.0 };
        p.observe_arrival(FunctionId(0), 0.0);
        p.observe_arrival(FunctionId(0), 1.0);
        assert_eq!(p.window(FunctionId(0)), 60.0);
        assert_eq!(p.window(FunctionId(99)), 60.0);
        assert_eq!(p.name(), "fixed");
    }

    #[test]
    fn hybrid_defaults_before_enough_observations() {
        let params = HybridParams::default();
        let mut p = HybridHistogram::new(params);
        assert_eq!(p.window(FunctionId(0)), params.default_window);
        // 3 arrivals = 2 inter-arrivals < 4 observations.
        for k in 0..3 {
            p.observe_arrival(FunctionId(0), k as f64 * 30.0);
        }
        assert_eq!(p.window(FunctionId(0)), params.default_window);
    }

    #[test]
    fn hybrid_covers_a_regular_functions_interarrival() {
        let params = HybridParams::default();
        let mut p = HybridHistogram::new(params);
        // Steady 45 s cadence: window must cover 45 s but stay well
        // under the 600 s histogram range.
        for k in 0..40 {
            p.observe_arrival(FunctionId(1), k as f64 * 45.0);
        }
        let w = p.window(FunctionId(1));
        assert!(w >= 45.0, "window {w} misses the 45 s cadence");
        assert!(w <= 100.0, "window {w} wastes warmth");
        assert_eq!(p.name(), "hybrid_histogram");
    }

    #[test]
    fn hybrid_gives_up_on_sparse_functions() {
        let params = HybridParams::default();
        let mut p = HybridHistogram::new(params);
        // Inter-arrivals way past the histogram range (> 600 s).
        for k in 0..20 {
            p.observe_arrival(FunctionId(2), k as f64 * 2000.0);
        }
        assert_eq!(p.window(FunctionId(2)), params.min_window);
    }

    #[test]
    fn hybrid_window_shorter_than_fixed_for_hot_functions() {
        // A 5 s cadence function needs only a ~12 s window under the
        // hybrid policy versus the 120 s fixed default.
        let mut p = HybridHistogram::new(HybridParams::default());
        for k in 0..50 {
            p.observe_arrival(FunctionId(3), k as f64 * 5.0);
        }
        let w = p.window(FunctionId(3));
        assert!(w < 120.0, "hot function window {w} not tighter than fixed");
        assert!(w >= 5.0);
    }

    #[test]
    fn config_builds_matching_policy() {
        assert_eq!(KeepAliveConfig::Fixed { window: 9.0 }.build().name(), "fixed");
        assert_eq!(
            KeepAliveConfig::Hybrid(HybridParams::default()).build().name(),
            "hybrid_histogram"
        );
        assert!(matches!(
            KeepAliveConfig::default(),
            KeepAliveConfig::Fixed { window } if window == 120.0
        ));
    }

    #[test]
    fn keep_alive_loop_flags_only_hosts_with_expired_warmth() {
        let mut c = Cluster::homogeneous(3);
        c.host_mut(HostId(0)).park_warm(FunctionId(0), 0.5, 50.0);
        c.host_mut(HostId(1)).park_warm(FunctionId(1), 0.5, 500.0);
        let ctx = ScheduleContext::new(100.0, &c);
        let mut l = KeepAliveLoop;
        assert_eq!(
            l.scan(&ctx, None),
            vec![ControlAction::ExpireContainers(HostId(0))]
        );
        assert_eq!(l.name(), "keep_alive");
    }

    #[test]
    fn keep_alive_loop_is_pool_invariant() {
        use crate::cluster::ShardedCluster;
        use crate::runtime::WorkerPool;
        let mut c = Cluster::homogeneous(8);
        for h in [0, 3, 5, 7] {
            c.host_mut(HostId(h)).park_warm(FunctionId(h as u32), 0.25, 10.0);
        }
        let sc = ShardedCluster::new(c, 4);
        let ctx = ScheduleContext::new(20.0, &sc).with_shards(&sc);
        let serial = KeepAliveLoop.scan(&ctx, None);
        let pool = WorkerPool::new(4);
        let pctx = ScheduleContext::new(20.0, &sc).with_shards(&sc).with_pool(&pool);
        let pooled = KeepAliveLoop.scan(&pctx, None);
        assert_eq!(serial, pooled);
        assert_eq!(serial.len(), 4);
    }
}
