//! Trace replay front end: invocation streams that feed the existing
//! `Job`/submit-event pipeline, so `decide_batch`, consolidation,
//! DVFS, and power capping all run unchanged on serverless load.
//!
//! Two sources:
//! - [`FaasTraceSpec`] — a seeded synthetic sampler shaped after the
//!   Azure Functions 2021 trace analysis: a heavy-tailed population
//!   of per-function rates (a few hot functions dominate), Burr
//!   Type XII per-function inter-arrival times (the distribution the
//!   Azure analysis fits; `c = 2, k = 1.5` gives mean = scale and
//!   CV 1), and lognormal execution times.
//! - [`read_csv_trace`] — a generic CSV reader replaying recorded
//!   traces of either family.

use crate::util::rng::Xoshiro256;
use crate::workload::faas::{invocation_phases, FunctionId};
use crate::workload::model::{Job, JobId, WorkloadKind};
use crate::workload::phases_for;
use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// One function of the synthetic population: footprint plus arrival
/// and execution-time parameters.
#[derive(Debug, Clone, Copy)]
pub struct FunctionSpec {
    pub id: FunctionId,
    /// Working-set memory (GB) the sandbox holds.
    pub mem_gb: f64,
    /// CPU footprint while executing (cores, ≤ the FAAS slot's 1).
    pub cpu: f64,
    /// Mean inter-arrival time (s); per-invocation gaps are
    /// Burr XII (`c = 2, k = 1.5`) with exactly this mean.
    pub mean_iat: f64,
    /// Lognormal execution-time parameters (underlying μ, σ).
    pub exec_mu: f64,
    pub exec_sigma: f64,
}

/// Seeded Azure-2021-shaped invocation stream generator.
#[derive(Debug, Clone, Copy)]
pub struct FaasTraceSpec {
    /// Function population size.
    pub n_functions: usize,
    /// Total invocations to emit (across all functions).
    pub n_invocations: usize,
    /// Scale (s) of the heavy-tailed cross-function mean-IAT
    /// distribution — smaller means a hotter population.
    pub iat_scale: f64,
}

impl Default for FaasTraceSpec {
    fn default() -> Self {
        FaasTraceSpec {
            n_functions: 200,
            n_invocations: 20_000,
            iat_scale: 20.0,
        }
    }
}

impl FaasTraceSpec {
    /// Sample the function population, deterministically per seed.
    pub fn functions(&self, seed: u64) -> Vec<FunctionSpec> {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        (0..self.n_functions)
            .map(|i| {
                let mut frng = rng.child(0xFA50 + i as u64);
                // Cross-function rate population: Burr-tailed, so a
                // few functions are invoked every few seconds while
                // the long tail sees minutes between calls.
                let mean_iat = frng.burr12(self.iat_scale, 1.5, 1.2).clamp(2.0, 3600.0);
                let mem_gb = [0.125, 0.25, 0.5, 1.0][frng.categorical(&[3.0, 3.0, 2.0, 1.0])];
                let cpu = frng.uniform(0.1, 1.0);
                let exec_sigma = frng.uniform(0.3, 0.8);
                // Mean execution in [0.5, 8] s; μ back-solved so the
                // lognormal's mean (not median) hits it.
                let exec_mean: f64 = frng.uniform(0.5, 8.0);
                let exec_mu = exec_mean.ln() - exec_sigma * exec_sigma / 2.0;
                FunctionSpec {
                    id: FunctionId(i as u32),
                    mem_gb,
                    cpu,
                    mean_iat,
                    exec_mu,
                    exec_sigma,
                }
            })
            .collect()
    }

    /// Realize the invocation stream: per-function Burr renewal
    /// processes merged through a min-heap into one submit-ordered
    /// job list. Heap keys are `f64::to_bits` (order-preserving for
    /// the positive arrival times) with the function index as
    /// tie-break, so the merge is fully deterministic.
    pub fn generate(&self, seed: u64) -> Vec<Job> {
        let specs = self.functions(seed);
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let mut streams: Vec<Xoshiro256> = (0..self.n_functions)
            .map(|i| rng.child(0xBEA7 + i as u64))
            .collect();
        let mut heap: BinaryHeap<Reverse<(u64, usize)>> = BinaryHeap::with_capacity(specs.len());
        for (i, s) in specs.iter().enumerate() {
            let t = streams[i].burr12(s.mean_iat, 2.0, 1.5);
            heap.push(Reverse((t.to_bits(), i)));
        }
        let mut jobs = Vec::with_capacity(self.n_invocations);
        while jobs.len() < self.n_invocations {
            let Reverse((bits, i)) = heap.pop().expect("non-empty function population");
            let t = f64::from_bits(bits);
            let s = specs[i];
            let exec = streams[i].lognormal(s.exec_mu, s.exec_sigma).clamp(0.1, 120.0);
            let phases = invocation_phases(s.cpu, s.mem_gb, exec);
            jobs.push(
                Job::new(JobId(jobs.len() as u64), WorkloadKind::Faas, s.mem_gb, phases, t)
                    .with_function(s.id),
            );
            let next = t + streams[i].burr12(s.mean_iat, 2.0, 1.5);
            heap.push(Reverse((next.to_bits(), i)));
        }
        jobs
    }
}

/// Read a recorded trace from CSV. Header-free; `#` comments and
/// blank lines are skipped, and a leading `submit_at,...` header row
/// is tolerated. Two row shapes, distinguished by the kind column:
///
/// - `submit_at,faas,function_id,mem_gb,cpu,exec_s` — one function
///   invocation (exact phases, no sampling).
/// - `submit_at,<kind>,gb` — one batch job of a paper benchmark
///   (`wordcount`, `terasort`, ... per `WorkloadKind::by_name`);
///   phases are synthesized per job from `seed`, exactly like the
///   generator path.
pub fn read_csv_trace(content: &str, seed: u64) -> Result<Vec<Job>, String> {
    let mut rng = Xoshiro256::seed_from_u64(seed);
    let mut jobs = Vec::new();
    for (lineno, raw) in content.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() || line.starts_with('#') || line.starts_with("submit_at") {
            continue;
        }
        let err = |what: &str| format!("line {}: {what}: {line:?}", lineno + 1);
        let cols: Vec<&str> = line.split(',').map(str::trim).collect();
        if cols.len() < 3 {
            return Err(err("expected at least 3 columns"));
        }
        let submit_at: f64 = cols[0].parse().map_err(|_| err("bad submit_at"))?;
        let kind = WorkloadKind::by_name(cols[1]).ok_or_else(|| err("unknown kind"))?;
        let id = JobId(jobs.len() as u64);
        let job = if kind == WorkloadKind::Faas {
            if cols.len() != 6 {
                return Err(err("faas rows take 6 columns"));
            }
            let function: u32 = cols[2].parse().map_err(|_| err("bad function_id"))?;
            let mem_gb: f64 = cols[3].parse().map_err(|_| err("bad mem_gb"))?;
            let cpu: f64 = cols[4].parse().map_err(|_| err("bad cpu"))?;
            let exec_s: f64 = cols[5].parse().map_err(|_| err("bad exec_s"))?;
            let phases = invocation_phases(cpu, mem_gb, exec_s);
            Job::new(id, kind, mem_gb, phases, submit_at).with_function(FunctionId(function))
        } else {
            let gb: f64 = cols[2].parse().map_err(|_| err("bad gb"))?;
            let phases = phases_for(kind, gb, &mut rng.child(0xC57 + id.0));
            Job::new(id, kind, gb, phases, submit_at)
        };
        jobs.push(job);
    }
    Ok(jobs)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> FaasTraceSpec {
        FaasTraceSpec {
            n_functions: 50,
            n_invocations: 5000,
            iat_scale: 15.0,
        }
    }

    #[test]
    fn generate_is_deterministic_per_seed() {
        let (a, b) = (spec().generate(9), spec().generate(9));
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.submit_at, y.submit_at);
            assert_eq!(x.function, y.function);
            assert_eq!(x.gb, y.gb);
            assert_eq!(x.solo_duration(), y.solo_duration());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let (a, b) = (spec().generate(1), spec().generate(2));
        let same = a
            .iter()
            .zip(&b)
            .filter(|(x, y)| x.submit_at == y.submit_at)
            .count();
        assert!(same < a.len() / 10, "seeds nearly identical ({same})");
    }

    #[test]
    fn stream_is_submit_ordered_with_sequential_ids() {
        let jobs = spec().generate(3);
        assert_eq!(jobs.len(), 5000);
        for (i, w) in jobs.windows(2).enumerate() {
            assert!(w[0].submit_at <= w[1].submit_at, "disorder at {i}");
        }
        for (i, j) in jobs.iter().enumerate() {
            assert_eq!(j.id, JobId(i as u64));
            assert_eq!(j.kind, WorkloadKind::Faas);
            assert!(j.function.is_some());
            assert!(j.submit_at > 0.0);
        }
    }

    #[test]
    fn hot_functions_dominate_invocations() {
        // Azure shape: the busiest decile of functions carries well
        // over half the invocations.
        let jobs = spec().generate(7);
        let mut per_fn = std::collections::BTreeMap::new();
        for j in &jobs {
            *per_fn.entry(j.function.unwrap()).or_insert(0usize) += 1;
        }
        let mut counts: Vec<usize> = per_fn.values().copied().collect();
        counts.sort_unstable_by(|a, b| b.cmp(a));
        let top = counts.iter().take(counts.len().div_ceil(10)).sum::<usize>();
        assert!(
            top as f64 > 0.5 * jobs.len() as f64,
            "top decile carries only {top}/{}",
            jobs.len()
        );
    }

    #[test]
    fn per_function_mean_iat_matches_spec() {
        // The Burr (c=2, k=1.5) renewal stream's empirical mean gap
        // must track the spec's mean_iat for a busy function.
        let s = FaasTraceSpec {
            n_functions: 4,
            n_invocations: 20_000,
            iat_scale: 10.0,
        };
        let specs = s.functions(5);
        let jobs = s.generate(5);
        for f in specs {
            let times: Vec<f64> = jobs
                .iter()
                .filter(|j| j.function == Some(f.id))
                .map(|j| j.submit_at)
                .collect();
            if times.len() < 500 {
                continue; // tail function: too few samples to test
            }
            let span = times.last().unwrap() - times.first().unwrap();
            let mean_gap = span / (times.len() - 1) as f64;
            assert!(
                (mean_gap - f.mean_iat).abs() / f.mean_iat < 0.15,
                "fn {} gap {mean_gap} vs spec {}",
                f.id,
                f.mean_iat
            );
        }
    }

    #[test]
    fn csv_roundtrip_both_families() {
        let csv = "\
submit_at,kind,cols
# a comment
0.0,terasort,12

1.5,faas,3,0.5,0.8,2.5
2.0,faas,3,0.5,0.8,1.0
";
        let jobs = read_csv_trace(csv, 11).unwrap();
        assert_eq!(jobs.len(), 3);
        assert_eq!(jobs[0].kind, WorkloadKind::HadoopTeraSort);
        assert_eq!(jobs[0].gb, 12.0);
        assert_eq!(jobs[0].function, None);
        assert!(jobs[0].solo_duration() > 10.0);
        assert_eq!(jobs[1].function, Some(FunctionId(3)));
        assert_eq!(jobs[1].solo_duration(), 2.5);
        assert_eq!(jobs[2].id, JobId(2));
        // Batch phase synthesis is seed-stable.
        let again = read_csv_trace(csv, 11).unwrap();
        assert_eq!(jobs[0].solo_duration(), again[0].solo_duration());
    }

    #[test]
    fn csv_rejects_malformed_rows() {
        assert!(read_csv_trace("1.0,nope,5", 0).is_err());
        assert!(read_csv_trace("x,terasort,5", 0).is_err());
        assert!(read_csv_trace("1.0,faas,1,0.5", 0).is_err());
        assert!(read_csv_trace("1.0,terasort", 0).is_err());
    }
}
