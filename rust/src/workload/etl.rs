//! ETL pipeline workload model (§IV-B: Python extract/transform tasks
//! against a PostgreSQL backend — warehousing / data-preparation jobs).
//!
//! The pipeline processes the dataset in chunks; each chunk runs
//! extract (network read from the source system + staging writes),
//! transform (CPU), and load (bulk insert into PostgreSQL: disk +
//! network, throttled by DB backpressure). The result is a bursty,
//! I/O-dominated profile with idle-ish CPU — the class the paper finds
//! easiest to consolidate and to schedule into off-peak windows (§V-C).

use crate::cluster::Demand;
use crate::util::rng::Xoshiro256;
use crate::workload::model::Phase;

/// Chunk size the pipeline commits at (GB).
const CHUNK_GB: f64 = 5.0;

/// DB backpressure factor: the load phase's effective throughput is
/// reduced when the (simulated) PostgreSQL instance compacts/checkpoints;
/// modeled as a per-chunk slowdown in [1.0, 1.6].
fn backpressure(rng: &mut Xoshiro256) -> f64 {
    1.0 + rng.pareto(0.05, 2.5).min(0.6)
}

pub fn etl(gb: f64, rng: &mut Xoshiro256) -> Vec<Phase> {
    let chunks = (gb / CHUNK_GB).ceil().max(1.0) as usize;
    let chunk_gb = gb / chunks as f64;
    let mut phases = Vec::with_capacity(3 * chunks);
    for _ in 0..chunks {
        phases.push(Phase {
            name: "etl-extract",
            duration: 6.0 * chunk_gb * rng.lognormal(0.0, 0.1),
            demand: Demand {
                cpu: 2.0,
                mem_gb: 4.0,
                disk_mbps: 50.0,
                net_mbps: 35.0,
            }
            .scaled(rng.uniform(0.95, 1.05)),
        });
        phases.push(Phase {
            name: "etl-transform",
            duration: 4.0 * chunk_gb * rng.lognormal(0.0, 0.08),
            demand: Demand {
                cpu: 4.5,
                mem_gb: 6.0,
                disk_mbps: 25.0,
                net_mbps: 2.0,
            }
            .scaled(rng.uniform(0.95, 1.05)),
        });
        phases.push(Phase {
            name: "etl-load",
            duration: 5.0 * chunk_gb * backpressure(rng),
            demand: Demand {
                cpu: 2.0,
                mem_gb: 4.0,
                disk_mbps: 120.0,
                net_mbps: 22.0,
            }
            .scaled(rng.uniform(0.95, 1.05)),
        });
    }
    phases
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rng() -> Xoshiro256 {
        Xoshiro256::seed_from_u64(3)
    }

    #[test]
    fn chunked_structure() {
        let p = etl(12.0, &mut rng());
        // ceil(12/5) = 3 chunks × 3 phases.
        assert_eq!(p.len(), 9);
        assert_eq!(p[0].name, "etl-extract");
        assert_eq!(p[1].name, "etl-transform");
        assert_eq!(p[2].name, "etl-load");
    }

    #[test]
    fn io_dominates_cpu_time() {
        let p = etl(20.0, &mut rng());
        let io_time: f64 = p
            .iter()
            .filter(|ph| ph.demand.disk_mbps + ph.demand.net_mbps > 50.0)
            .map(|ph| ph.duration)
            .sum();
        let total: f64 = p.iter().map(|ph| ph.duration).sum();
        assert!(io_time / total > 0.6, "io fraction {}", io_time / total);
    }

    #[test]
    fn transform_is_the_only_cpu_phase() {
        let p = etl(10.0, &mut rng());
        for ph in &p {
            if ph.name == "etl-transform" {
                assert!(ph.demand.cpu > 4.0);
            } else {
                assert!(ph.demand.cpu < 3.0, "{} cpu {}", ph.name, ph.demand.cpu);
            }
        }
    }

    #[test]
    fn backpressure_extends_load_but_bounded() {
        let mut r = rng();
        for _ in 0..200 {
            let b = backpressure(&mut r);
            assert!((1.0..=1.6).contains(&b), "backpressure {b}");
        }
    }

    #[test]
    fn small_dataset_single_chunk() {
        assert_eq!(etl(3.0, &mut rng()).len(), 3);
    }

    #[test]
    fn duration_scales_with_size() {
        let small: f64 = etl(5.0, &mut rng()).iter().map(|p| p.duration).sum();
        let large: f64 = etl(25.0, &mut rng()).iter().map(|p| p.duration).sum();
        assert!(large > 3.5 * small);
    }
}
