//! Spark MLlib workload models: Logistic Regression and K-Means
//! (§IV-B: CPU-intensive analytical pipelines).
//!
//! Both are iterative: a cache-warm-up phase reads the dataset from
//! disk into executor memory, then supersteps alternate a CPU-dominant
//! compute phase with a brief all-reduce/broadcast synchronization
//! pulse on the network. This reproduces the §V-C observation that
//! Spark jobs have *limited consolidation potential* (the CPU demand is
//! near the flavor cap almost continuously) but benefit from placement
//! that avoids CPU contention.

use crate::cluster::Demand;
use crate::util::rng::Xoshiro256;
use crate::workload::model::Phase;

fn warmup(gb: f64, rng: &mut Xoshiro256) -> Phase {
    Phase {
        name: "spark-cache-warmup",
        duration: 1.6 * gb * rng.lognormal(0.0, 0.08),
        demand: Demand {
            cpu: 3.0,
            mem_gb: (0.9 * gb).min(14.0),
            disk_mbps: 150.0,
            net_mbps: 8.0,
        }
        .scaled(rng.uniform(0.95, 1.05)),
    }
}

fn iteration(name: &'static str, cpu: f64, secs: f64, gb: f64, rng: &mut Xoshiro256) -> Phase {
    Phase {
        name,
        duration: secs * rng.lognormal(0.0, 0.06),
        demand: Demand {
            cpu,
            mem_gb: (0.6 * gb + 2.0).min(12.0),
            disk_mbps: 4.0,
            net_mbps: 3.0,
        }
        .scaled(rng.uniform(0.97, 1.03)),
    }
}

fn sync_pulse(name: &'static str, rng: &mut Xoshiro256) -> Phase {
    Phase {
        name,
        duration: rng.uniform(1.5, 3.0),
        demand: Demand {
            cpu: 1.0,
            mem_gb: 4.0,
            disk_mbps: 2.0,
            net_mbps: 20.0,
        },
    }
}

/// Logistic Regression: gradient passes over the cached dataset.
/// 10 iterations; per-iteration time scales with data size.
pub fn logreg(gb: f64, rng: &mut Xoshiro256) -> Vec<Phase> {
    let mut phases = vec![warmup(gb, rng)];
    let iters = 10;
    for _ in 0..iters {
        phases.push(iteration("lr-gradient", 7.8, 1.8 * gb + 10.0, gb, rng));
        phases.push(sync_pulse("lr-allreduce", rng));
    }
    phases
}

/// K-Means: assignment + update steps; slightly more iterations,
/// marginally lower arithmetic intensity than LR.
pub fn kmeans(gb: f64, rng: &mut Xoshiro256) -> Vec<Phase> {
    let mut phases = vec![warmup(gb, rng)];
    let iters = 12;
    for _ in 0..iters {
        phases.push(iteration("km-assign", 7.4, 1.5 * gb + 8.0, gb, rng));
        phases.push(sync_pulse("km-broadcast", rng));
    }
    phases
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rng() -> Xoshiro256 {
        Xoshiro256::seed_from_u64(2)
    }

    #[test]
    fn iterative_structure() {
        let lr = logreg(10.0, &mut rng());
        assert_eq!(lr.len(), 1 + 2 * 10);
        let km = kmeans(10.0, &mut rng());
        assert_eq!(km.len(), 1 + 2 * 12);
        assert_eq!(lr[0].name, "spark-cache-warmup");
    }

    #[test]
    fn compute_phases_are_cpu_dominant() {
        let lr = logreg(10.0, &mut rng());
        let grad = lr.iter().find(|p| p.name == "lr-gradient").unwrap();
        // CPU near the 8-vCPU cap; disk/net negligible.
        assert!(grad.demand.cpu > 7.0);
        assert!(grad.demand.disk_mbps < 10.0);
        assert!(grad.demand.net_mbps < 10.0);
    }

    #[test]
    fn cpu_time_dominates_wall_profile() {
        let lr = logreg(10.0, &mut rng());
        let total: f64 = lr.iter().map(|p| p.duration).sum();
        let cpu_time: f64 = lr
            .iter()
            .filter(|p| p.demand.cpu > 6.0)
            .map(|p| p.duration)
            .sum();
        assert!(cpu_time / total > 0.75, "cpu fraction {}", cpu_time / total);
    }

    #[test]
    fn memory_tracks_dataset_but_respects_flavor() {
        let small = logreg(5.0, &mut rng());
        let large = logreg(50.0, &mut rng());
        let m_small = small[1].demand.mem_gb;
        let m_large = large[1].demand.mem_gb;
        assert!(m_large >= m_small);
        assert!(m_large <= 16.0);
    }

    #[test]
    fn deterministic_per_seed() {
        let a: f64 = kmeans(8.0, &mut rng()).iter().map(|p| p.duration).sum();
        let b: f64 = kmeans(8.0, &mut rng()).iter().map(|p| p.duration).sum();
        assert_eq!(a, b);
    }
}
