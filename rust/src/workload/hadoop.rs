//! Hadoop MapReduce workload models: WordCount, TeraSort, Grep
//! (§IV-B: dataset sizes 5–50 GB, varying I/O and shuffle intensity).
//!
//! Each benchmark is modeled as map → shuffle → reduce phases whose
//! durations scale with dataset size and whose demand vectors reproduce
//! the published resource signatures: TeraSort is shuffle-dominated
//! (network + disk), Grep is a scan (disk-dominated, tiny shuffle),
//! WordCount is CPU-leaning with a moderate shuffle.
//!
//! Demands are per worker VM, sized for the `MEDIUM` flavor
//! (8 vCPU / 16 GB / 200 MB/s disk / 60 MB/s net).

use crate::cluster::Demand;
use crate::util::rng::Xoshiro256;
use crate::workload::model::Phase;

/// Relative jitter applied to durations (lognormal σ) and demands
/// (uniform ±5 %) — run-to-run variability the paper averages away over
/// three runs.
const DUR_SIGMA: f64 = 0.08;

fn jit_dur(rng: &mut Xoshiro256, base: f64) -> f64 {
    base * rng.lognormal(0.0, DUR_SIGMA)
}

fn jit_demand(rng: &mut Xoshiro256, d: Demand) -> Demand {
    let k = rng.uniform(0.95, 1.05);
    d.scaled(k)
}

pub fn wordcount(gb: f64, rng: &mut Xoshiro256) -> Vec<Phase> {
    vec![
        Phase {
            name: "wc-map",
            duration: jit_dur(rng, 8.0 * gb),
            demand: jit_demand(
                rng,
                Demand {
                    cpu: 7.0,
                    mem_gb: 8.0,
                    disk_mbps: 110.0,
                    net_mbps: 3.0,
                },
            ),
        },
        Phase {
            name: "wc-shuffle",
            duration: jit_dur(rng, 2.0 * gb),
            demand: jit_demand(
                rng,
                Demand {
                    cpu: 2.0,
                    mem_gb: 8.0,
                    disk_mbps: 30.0,
                    net_mbps: 25.0,
                },
            ),
        },
        Phase {
            name: "wc-reduce",
            duration: jit_dur(rng, 2.5 * gb),
            demand: jit_demand(
                rng,
                Demand {
                    cpu: 5.0,
                    mem_gb: 8.0,
                    disk_mbps: 60.0,
                    net_mbps: 5.0,
                },
            ),
        },
    ]
}

pub fn terasort(gb: f64, rng: &mut Xoshiro256) -> Vec<Phase> {
    vec![
        Phase {
            name: "ts-map",
            duration: jit_dur(rng, 6.0 * gb),
            demand: jit_demand(
                rng,
                Demand {
                    cpu: 5.0,
                    mem_gb: 8.0,
                    disk_mbps: 160.0,
                    net_mbps: 5.0,
                },
            ),
        },
        Phase {
            // The dominant phase: all-to-all shuffle saturating the NIC
            // with heavy spill traffic — this is what makes TeraSort the
            // paper's best consolidation case (§V-A: 19 % savings).
            name: "ts-shuffle",
            duration: jit_dur(rng, 8.0 * gb),
            demand: jit_demand(
                rng,
                Demand {
                    cpu: 2.5,
                    mem_gb: 8.0,
                    disk_mbps: 70.0,
                    net_mbps: 30.0,
                },
            ),
        },
        Phase {
            name: "ts-reduce",
            duration: jit_dur(rng, 5.0 * gb),
            demand: jit_demand(
                rng,
                Demand {
                    cpu: 4.0,
                    mem_gb: 8.0,
                    disk_mbps: 170.0,
                    net_mbps: 8.0,
                },
            ),
        },
    ]
}

pub fn grep(gb: f64, rng: &mut Xoshiro256) -> Vec<Phase> {
    vec![
        Phase {
            name: "grep-scan",
            duration: jit_dur(rng, 5.0 * gb),
            demand: jit_demand(
                rng,
                Demand {
                    cpu: 3.5,
                    mem_gb: 6.0,
                    disk_mbps: 190.0,
                    net_mbps: 2.0,
                },
            ),
        },
        Phase {
            name: "grep-shuffle",
            duration: jit_dur(rng, 0.4 * gb),
            demand: jit_demand(
                rng,
                Demand {
                    cpu: 1.0,
                    mem_gb: 4.0,
                    disk_mbps: 10.0,
                    net_mbps: 10.0,
                },
            ),
        },
        Phase {
            name: "grep-reduce",
            duration: jit_dur(rng, 0.3 * gb),
            demand: jit_demand(
                rng,
                Demand {
                    cpu: 1.5,
                    mem_gb: 4.0,
                    disk_mbps: 20.0,
                    net_mbps: 3.0,
                },
            ),
        },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rng() -> Xoshiro256 {
        Xoshiro256::seed_from_u64(1)
    }

    #[test]
    fn durations_scale_with_dataset_size() {
        let small: f64 = terasort(5.0, &mut rng()).iter().map(|p| p.duration).sum();
        let large: f64 = terasort(50.0, &mut rng()).iter().map(|p| p.duration).sum();
        assert!(large > 8.0 * small, "50GB {large} vs 5GB {small}");
    }

    #[test]
    fn terasort_is_shuffle_dominated() {
        let phases = terasort(20.0, &mut rng());
        let shuffle = phases.iter().find(|p| p.name == "ts-shuffle").unwrap();
        for p in &phases {
            assert!(shuffle.duration >= p.duration * 0.99);
        }
        // Network is the shuffle's dominant demand (per-worker share
        // of the shared 1 GbE NIC).
        assert!(shuffle.demand.net_mbps > 25.0);
    }

    #[test]
    fn grep_is_scan_dominated() {
        let phases = grep(20.0, &mut rng());
        let scan = &phases[0];
        assert!(scan.demand.disk_mbps > 150.0);
        let scan_frac =
            scan.duration / phases.iter().map(|p| p.duration).sum::<f64>();
        assert!(scan_frac > 0.8, "scan fraction {scan_frac}");
    }

    #[test]
    fn wordcount_map_is_cpu_leaning() {
        let phases = wordcount(20.0, &mut rng());
        let map = &phases[0];
        // CPU demand near the 8-vCPU flavor cap; I/O moderate.
        assert!(map.demand.cpu > 6.0);
        assert!(map.demand.disk_mbps < 150.0);
    }

    #[test]
    fn jitter_is_bounded_and_deterministic() {
        let a: f64 = wordcount(10.0, &mut rng()).iter().map(|p| p.duration).sum();
        let b: f64 = wordcount(10.0, &mut rng()).iter().map(|p| p.duration).sum();
        assert_eq!(a, b, "same seed, same phases");
        let nominal = (8.0 + 2.0 + 2.5) * 10.0;
        assert!((a / nominal - 1.0).abs() < 0.35, "jitter too large: {a} vs {nominal}");
    }

    #[test]
    fn demands_fit_medium_flavor() {
        for phases in [
            wordcount(50.0, &mut rng()),
            terasort(50.0, &mut rng()),
            grep(50.0, &mut rng()),
        ] {
            for p in phases {
                assert!(p.demand.cpu <= 8.0 * 1.05, "{} cpu {}", p.name, p.demand.cpu);
                assert!(p.demand.mem_gb <= 16.0 * 1.05);
                assert!(p.demand.disk_mbps <= 200.0 * 1.05);
                assert!(p.demand.net_mbps <= 60.0 * 1.05);
            }
        }
    }
}
