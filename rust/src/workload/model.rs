//! Job execution model: a job is a sequence of *phases*, each with a
//! nominal solo duration and a resource demand vector. Contention on
//! the hosting machine slows a phase in its bottleneck dimensions —
//! this is the mechanism through which bad placements extend job
//! completion time (and hence threaten SLAs) while good co-location
//! saves energy at no JCT cost.

use crate::cluster::Demand;

/// Stable job identifier.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct JobId(pub u64);

impl std::fmt::Display for JobId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "job-{}", self.0)
    }
}

/// The benchmark suite of the paper's evaluation (§IV-B), plus the
/// serverless function-invocation family added on top of it.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum WorkloadKind {
    HadoopWordCount,
    HadoopTeraSort,
    HadoopGrep,
    SparkLogReg,
    SparkKMeans,
    EtlPipeline,
    /// A single serverless function invocation (workload::faas). Not
    /// part of [`WorkloadKind::ALL`]: `ALL` is the paper's batch
    /// suite, which mixes and per-benchmark campaigns iterate over —
    /// FaaS jobs enter through `workload::trace` instead.
    Faas,
}

impl WorkloadKind {
    pub const ALL: [WorkloadKind; 6] = [
        WorkloadKind::HadoopWordCount,
        WorkloadKind::HadoopTeraSort,
        WorkloadKind::HadoopGrep,
        WorkloadKind::SparkLogReg,
        WorkloadKind::SparkKMeans,
        WorkloadKind::EtlPipeline,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            WorkloadKind::HadoopWordCount => "wordcount",
            WorkloadKind::HadoopTeraSort => "terasort",
            WorkloadKind::HadoopGrep => "grep",
            WorkloadKind::SparkLogReg => "logreg",
            WorkloadKind::SparkKMeans => "kmeans",
            WorkloadKind::EtlPipeline => "etl",
            WorkloadKind::Faas => "faas",
        }
    }

    pub fn by_name(name: &str) -> Option<WorkloadKind> {
        if name == "faas" {
            return Some(WorkloadKind::Faas);
        }
        Self::ALL.iter().copied().find(|k| k.name() == name)
    }

    /// Paper workload category (§IV-B).
    pub fn category(&self) -> &'static str {
        match self {
            WorkloadKind::HadoopWordCount
            | WorkloadKind::HadoopTeraSort
            | WorkloadKind::HadoopGrep => "hadoop",
            WorkloadKind::SparkLogReg | WorkloadKind::SparkKMeans => "spark",
            WorkloadKind::EtlPipeline => "etl",
            WorkloadKind::Faas => "faas",
        }
    }
}

/// One execution phase: nominal solo duration and flat demand.
#[derive(Debug, Clone)]
pub struct Phase {
    pub name: &'static str,
    /// Solo duration in seconds (no contention, full frequency).
    pub duration: f64,
    /// Resource demand while the phase runs (per worker VM).
    pub demand: Demand,
}

impl Phase {
    /// Progress rate on a host with per-dimension contention factors
    /// `(cpu, mem, disk, net)` — the minimum factor over dimensions the
    /// phase *meaningfully* uses. Thresholds approximate max-min
    /// fairness: a phase sipping 3 MB/s of network on a congested NIC
    /// still gets its share (small flows are unaffected by
    /// oversubscription), so only phases demanding a sizeable fraction
    /// of the worker flavor's budget are gated by that dimension.
    pub fn progress_rate(&self, contention: (f64, f64, f64, f64)) -> f64 {
        let (c, m, d, n) = contention;
        let mut rate: f64 = 1.0;
        if self.demand.cpu > 0.2 {
            rate = rate.min(c);
        }
        if self.demand.mem_gb > 0.5 {
            rate = rate.min(m);
        }
        if self.demand.disk_mbps > 25.0 {
            rate = rate.min(d);
        }
        if self.demand.net_mbps > 9.0 {
            rate = rate.min(n);
        }
        rate.max(0.01) // forward progress guarantee (no livelock)
    }
}

/// Job lifecycle.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum JobState {
    Queued,
    Running,
    Finished,
}

/// A job instance: immutable description plus execution progress.
#[derive(Debug, Clone)]
pub struct Job {
    pub id: JobId,
    pub kind: WorkloadKind,
    /// Dataset size in GB (the 5–50 GB sweep of §IV-B).
    pub gb: f64,
    pub phases: Vec<Phase>,
    pub submit_at: f64,
    pub state: JobState,
    pub started_at: Option<f64>,
    pub finished_at: Option<f64>,
    /// Index of the currently executing phase.
    pub phase_idx: usize,
    /// Accumulated progress-time within the current phase (s).
    pub phase_progress: f64,
    /// Job paused until this time (migration stop-and-copy stall, or
    /// a serverless cold-start boot window).
    pub stalled_until: f64,
    /// Cumulative seconds lost to contention (JCT − solo gap source).
    pub slowdown_secs: f64,
    /// For serverless invocations: the function this job invokes.
    /// `None` for the batch families — set via [`Job::with_function`].
    pub function: Option<crate::workload::faas::FunctionId>,
    /// Solo-progress point the job last restarted from (0 if it never
    /// crashed). Checkpoint boundaries at or before this point were
    /// written — and charged — by an earlier incarnation; the energy
    /// accounting bills only boundaries crossed beyond it.
    pub restored_from: f64,
}

impl Job {
    pub fn new(id: JobId, kind: WorkloadKind, gb: f64, phases: Vec<Phase>, submit_at: f64) -> Job {
        assert!(!phases.is_empty());
        Job {
            id,
            kind,
            gb,
            phases,
            submit_at,
            state: JobState::Queued,
            started_at: None,
            finished_at: None,
            phase_idx: 0,
            phase_progress: 0.0,
            stalled_until: 0.0,
            slowdown_secs: 0.0,
            function: None,
            restored_from: 0.0,
        }
    }

    /// Tag this job as an invocation of `function` (builder-style).
    pub fn with_function(mut self, function: crate::workload::faas::FunctionId) -> Job {
        self.function = Some(function);
        self
    }

    /// Solo JCT: the sum of nominal phase durations — the SLA baseline.
    pub fn solo_duration(&self) -> f64 {
        self.phases.iter().map(|p| p.duration).sum()
    }

    /// Current demand; `Demand::ZERO` when not running or stalled.
    pub fn current_demand(&self, now: f64) -> Demand {
        if self.state != JobState::Running || now < self.stalled_until {
            return Demand::ZERO;
        }
        self.phases[self.phase_idx].demand
    }

    pub fn current_phase(&self) -> &Phase {
        &self.phases[self.phase_idx]
    }

    pub fn start(&mut self, now: f64) {
        assert_eq!(self.state, JobState::Queued);
        self.state = JobState::Running;
        // Preserve the first start across evacuation restarts: JCT
        // (and hence SLA compliance) must honestly span the crash and
        // the re-placement, not restart the clock.
        if self.started_at.is_none() {
            self.started_at = Some(now);
        }
    }

    /// Accumulated progress through the phase plan, in nominal solo
    /// seconds: completed phases' durations plus progress into the
    /// current one. The quantity checkpoints snapshot.
    pub fn progress_time(&self) -> f64 {
        self.phases[..self.phase_idx]
            .iter()
            .map(|p| p.duration)
            .sum::<f64>()
            + self.phase_progress
    }

    /// Throw the job back to `Queued` after its host crashed. Without
    /// checkpointing all phase progress is lost (the paper's batch
    /// frameworks restart failed work from the last materialized
    /// boundary — the conservative full restart); with a checkpoint
    /// interval, progress rewinds only to the last completed boundary
    /// `floor(progress / interval) · interval`. Either way
    /// `started_at` survives so the eventual JCT covers the whole
    /// ordeal. Returns the progress preserved, in solo seconds.
    pub fn requeue_after_crash(&mut self, now: f64, checkpoint_interval: Option<f64>) -> f64 {
        assert_eq!(self.state, JobState::Running, "requeue a non-running job");
        let saved = match checkpoint_interval {
            Some(interval) if interval > 0.0 => {
                (self.progress_time() / interval).floor() * interval
            }
            _ => 0.0,
        };
        self.state = JobState::Queued;
        self.stalled_until = 0.0;
        // Rewind the phase cursor to `saved` solo seconds in.
        self.phase_idx = 0;
        self.phase_progress = 0.0;
        let mut remaining = saved;
        while remaining > 0.0 && self.phase_idx < self.phases.len() {
            let dur = self.phases[self.phase_idx].duration;
            if remaining >= dur {
                remaining -= dur;
                self.phase_idx += 1;
            } else {
                self.phase_progress = remaining;
                remaining = 0.0;
            }
        }
        // Keep the cursor valid if `saved` lands exactly on the end
        // of the plan (float-boundary corner).
        if self.phase_idx == self.phases.len() {
            self.phase_idx = self.phases.len() - 1;
            self.phase_progress = self.phases[self.phase_idx].duration;
        }
        // Wall time spent so far minus the progress we kept is lost.
        if let Some(t0) = self.started_at {
            self.slowdown_secs = (now - t0 - saved).max(0.0);
        }
        self.restored_from = saved;
        saved
    }

    /// Advance the job by `dt` seconds of wall time under the given
    /// host contention. Returns `true` when the job finishes in this
    /// step.
    pub fn advance(&mut self, now: f64, dt: f64, contention: (f64, f64, f64, f64)) -> bool {
        if self.state != JobState::Running {
            return false;
        }
        if now + dt <= self.stalled_until {
            self.slowdown_secs += dt;
            return false;
        }
        // Portion of the step not stalled.
        let effective_dt = (now + dt - self.stalled_until.max(now)).min(dt);
        self.slowdown_secs += dt - effective_dt;
        let mut remaining = effective_dt;
        while remaining > 1e-12 {
            let rate = self.phases[self.phase_idx].progress_rate(contention);
            let need = self.phases[self.phase_idx].duration - self.phase_progress;
            let wall_to_finish = need / rate;
            if wall_to_finish <= remaining {
                remaining -= wall_to_finish;
                self.slowdown_secs += wall_to_finish * (1.0 - rate);
                self.phase_progress = 0.0;
                self.phase_idx += 1;
                if self.phase_idx == self.phases.len() {
                    self.phase_idx = self.phases.len() - 1; // keep index valid
                    self.state = JobState::Finished;
                    self.finished_at = Some(now + dt - remaining);
                    return true;
                }
            } else {
                self.phase_progress += remaining * rate;
                self.slowdown_secs += remaining * (1.0 - rate);
                remaining = 0.0;
            }
        }
        false
    }

    /// Closed-form prediction of the next demand-change boundary for
    /// this job under piecewise-constant `contention`: the end of a
    /// stall window, or the wall-clock time at which the current phase
    /// completes at the current progress rate. `None` when not
    /// running. The discrete-event core schedules a `JobAdvance` at
    /// this time and invalidates it (by epoch) whenever the hosting
    /// machine's resident set or frequency changes.
    pub fn predict_next_boundary(&self, now: f64, contention: (f64, f64, f64, f64)) -> Option<f64> {
        if self.state != JobState::Running {
            return None;
        }
        if now < self.stalled_until {
            return Some(self.stalled_until);
        }
        let phase = &self.phases[self.phase_idx];
        let rate = phase.progress_rate(contention);
        let need = (phase.duration - self.phase_progress).max(0.0);
        Some(now + need / rate)
    }

    /// Force-cross a phase boundary the solver left a float-epsilon
    /// short: when the remaining need of the current phase is ≤ `tol`
    /// progress-seconds, cross it at zero wall cost. Returns `true`
    /// when the job finishes via the snap. The event core calls this
    /// after advancing a job to its own predicted boundary, so that
    /// `need/rate` round-tripping through wall time can never strand a
    /// phase at 99.9999…% forever.
    pub fn snap_phase_boundary(&mut self, now: f64, tol: f64) -> bool {
        if self.state != JobState::Running || now < self.stalled_until {
            return false;
        }
        let need = self.phases[self.phase_idx].duration - self.phase_progress;
        if need > tol {
            return false;
        }
        self.phase_progress = 0.0;
        self.phase_idx += 1;
        if self.phase_idx == self.phases.len() {
            self.phase_idx = self.phases.len() - 1; // keep index valid
            self.state = JobState::Finished;
            self.finished_at = Some(now);
            return true;
        }
        false
    }

    /// Actual JCT once finished.
    pub fn jct(&self) -> Option<f64> {
        Some(self.finished_at? - self.started_at?)
    }

    /// Stall the job (stop-and-copy during migration).
    pub fn stall(&mut self, until: f64) {
        self.stalled_until = self.stalled_until.max(until);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn phase(name: &'static str, dur: f64, cpu: f64, disk: f64) -> Phase {
        Phase {
            name,
            duration: dur,
            demand: Demand {
                cpu,
                mem_gb: 4.0,
                disk_mbps: disk,
                net_mbps: 0.0,
            },
        }
    }

    fn job() -> Job {
        Job::new(
            JobId(0),
            WorkloadKind::HadoopWordCount,
            10.0,
            vec![phase("map", 100.0, 6.0, 50.0), phase("reduce", 50.0, 4.0, 20.0)],
            0.0,
        )
    }

    #[test]
    fn solo_duration_is_phase_sum() {
        assert_eq!(job().solo_duration(), 150.0);
    }

    #[test]
    fn uncontended_job_finishes_in_solo_time() {
        let mut j = job();
        j.start(0.0);
        let mut t = 0.0;
        let mut done = false;
        while t < 200.0 && !done {
            done = j.advance(t, 1.0, (1.0, 1.0, 1.0, 1.0));
            t += 1.0;
        }
        assert!(done);
        let jct = j.jct().unwrap();
        assert!((jct - 150.0).abs() < 1e-6, "jct={jct}");
        assert!(j.slowdown_secs < 1e-9);
    }

    #[test]
    fn contention_extends_jct_proportionally() {
        let mut j = job();
        j.start(0.0);
        let mut t = 0.0;
        let mut done = false;
        // CPU at half speed the whole time → JCT doubles.
        while t < 400.0 && !done {
            done = j.advance(t, 1.0, (0.5, 1.0, 1.0, 1.0));
            t += 1.0;
        }
        assert!(done);
        assert!((j.jct().unwrap() - 300.0).abs() < 1.0);
        assert!((j.slowdown_secs - 150.0).abs() < 1.0);
    }

    #[test]
    fn phase_boundary_within_one_step() {
        // A single big step must cross phase boundaries correctly.
        let mut j = job();
        j.start(0.0);
        let done = j.advance(0.0, 150.0, (1.0, 1.0, 1.0, 1.0));
        assert!(done);
        assert_eq!(j.finished_at, Some(150.0));
    }

    #[test]
    fn stall_pauses_progress() {
        let mut j = job();
        j.start(0.0);
        j.stall(10.0);
        assert_eq!(j.current_demand(5.0), Demand::ZERO);
        // First 10 s stalled: after 20 s only 10 s of progress.
        j.advance(0.0, 20.0, (1.0, 1.0, 1.0, 1.0));
        assert!((j.phase_progress - 10.0).abs() < 1e-9);
    }

    #[test]
    fn progress_rate_ignores_unused_dimensions() {
        let p = phase("cpu-only", 10.0, 6.0, 0.0);
        // Disk fully contended but phase uses no disk.
        assert_eq!(p.progress_rate((1.0, 1.0, 0.1, 0.1)), 1.0);
        // CPU contended: gated.
        assert_eq!(p.progress_rate((0.25, 1.0, 1.0, 1.0)), 0.25);
    }

    #[test]
    fn progress_rate_has_floor() {
        let p = phase("x", 10.0, 6.0, 50.0);
        assert!(p.progress_rate((0.0, 0.0, 0.0, 0.0)) >= 0.01);
    }

    #[test]
    fn requeue_after_crash_keeps_first_start_and_loses_progress() {
        let mut j = job();
        j.start(10.0);
        j.advance(10.0, 60.0, (1.0, 1.0, 1.0, 1.0));
        assert!(j.phase_progress > 0.0);
        let saved = j.requeue_after_crash(70.0, None);
        assert_eq!(saved, 0.0, "no checkpointing, nothing preserved");
        assert_eq!(j.state, JobState::Queued);
        assert_eq!(j.phase_idx, 0);
        assert_eq!(j.phase_progress, 0.0);
        assert!((j.slowdown_secs - 60.0).abs() < 1e-9, "lost time counts");
        // Restart after evacuation: the JCT clock keeps its origin.
        j.start(100.0);
        assert_eq!(j.started_at, Some(10.0));
        let done = j.advance(100.0, 150.0, (1.0, 1.0, 1.0, 1.0));
        assert!(done);
        assert!((j.jct().unwrap() - 240.0).abs() < 1e-6);
    }

    #[test]
    fn checkpointed_requeue_resumes_from_last_boundary() {
        // 100 s map + 50 s reduce, crash 130 s in (30 s into reduce)
        // with 40 s checkpoints: last boundary at 120 s → resume 20 s
        // into the reduce phase.
        let mut j = job();
        j.start(0.0);
        j.advance(0.0, 130.0, (1.0, 1.0, 1.0, 1.0));
        assert_eq!(j.phase_idx, 1);
        assert!((j.progress_time() - 130.0).abs() < 1e-9);
        let saved = j.requeue_after_crash(130.0, Some(40.0));
        assert!((saved - 120.0).abs() < 1e-9);
        assert_eq!(j.phase_idx, 1, "cursor rewinds into the reduce phase");
        assert!((j.phase_progress - 20.0).abs() < 1e-9);
        assert!((j.slowdown_secs - 10.0).abs() < 1e-9, "only 10 s lost");
        // Only 30 s of work remain.
        j.start(200.0);
        let done = j.advance(200.0, 30.0, (1.0, 1.0, 1.0, 1.0));
        assert!(done);
        assert!((j.jct().unwrap() - 230.0).abs() < 1e-6);
    }

    #[test]
    fn checkpoint_boundary_inside_first_phase_rewinds_phase_cursor() {
        let mut j = job();
        j.start(0.0);
        j.advance(0.0, 110.0, (1.0, 1.0, 1.0, 1.0));
        assert_eq!(j.phase_idx, 1);
        // 60 s checkpoints: last boundary at 60 s, inside the map.
        let saved = j.requeue_after_crash(110.0, Some(60.0));
        assert!((saved - 60.0).abs() < 1e-9);
        assert_eq!(j.phase_idx, 0);
        assert!((j.phase_progress - 60.0).abs() < 1e-9);
    }

    #[test]
    fn predicted_boundary_matches_stepped_advance() {
        let mut j = job();
        j.start(0.0);
        let contention = (0.5, 1.0, 1.0, 1.0);
        // Phase 1: 100 s of need at rate 0.5 → boundary at t=200.
        let t1 = j.predict_next_boundary(0.0, contention).unwrap();
        assert!((t1 - 200.0).abs() < 1e-9, "t1={t1}");
        assert!(!j.advance(0.0, t1, contention));
        j.snap_phase_boundary(t1, 1e-6);
        assert_eq!(j.phase_idx, 1);
        // Phase 2 uncontended: 50 s more.
        let t2 = j.predict_next_boundary(t1, (1.0, 1.0, 1.0, 1.0)).unwrap();
        assert!((t2 - 250.0).abs() < 1e-9);
        let done =
            j.advance(t1, t2 - t1, (1.0, 1.0, 1.0, 1.0)) || j.snap_phase_boundary(t2, 1e-6);
        assert!(done);
        assert!((j.jct().unwrap() - 250.0).abs() < 1e-6);
    }

    #[test]
    fn predicted_boundary_respects_stall_window() {
        let mut j = job();
        j.start(0.0);
        j.stall(10.0);
        assert_eq!(j.predict_next_boundary(0.0, (1.0, 1.0, 1.0, 1.0)), Some(10.0));
        // After the stall ends, prediction is the phase end.
        let t = j.predict_next_boundary(10.0, (1.0, 1.0, 1.0, 1.0)).unwrap();
        assert!((t - 110.0).abs() < 1e-9);
        assert_eq!(j.predict_next_boundary(0.0, (1.0, 1.0, 1.0, 1.0)), Some(10.0));
    }

    #[test]
    fn snap_only_crosses_epsilon_boundaries() {
        let mut j = job();
        j.start(0.0);
        // Mid-phase: snap must be a no-op.
        j.advance(0.0, 40.0, (1.0, 1.0, 1.0, 1.0));
        assert!(!j.snap_phase_boundary(40.0, 1e-6));
        assert_eq!(j.phase_idx, 0);
        // A float-epsilon short of the boundary: snap crosses it.
        j.phase_progress = 100.0 - 1e-9;
        assert!(!j.snap_phase_boundary(40.0, 1e-6));
        assert_eq!(j.phase_idx, 1);
        assert_eq!(j.phase_progress, 0.0);
        // Last phase: snapping across finishes the job.
        j.phase_progress = 50.0 - 1e-9;
        assert!(j.snap_phase_boundary(123.0, 1e-6));
        assert_eq!(j.state, JobState::Finished);
        assert_eq!(j.finished_at, Some(123.0));
    }

    #[test]
    fn queued_job_demands_nothing() {
        let j = job();
        assert_eq!(j.current_demand(0.0), Demand::ZERO);
        assert_eq!(j.state, JobState::Queued);
    }

    #[test]
    fn kind_name_roundtrip() {
        for k in WorkloadKind::ALL {
            assert_eq!(WorkloadKind::by_name(k.name()), Some(k));
        }
        // Faas sits outside ALL (it is not part of the paper's batch
        // suite) but still round-trips by name.
        assert_eq!(WorkloadKind::by_name("faas"), Some(WorkloadKind::Faas));
        assert!(!WorkloadKind::ALL.contains(&WorkloadKind::Faas));
        assert_eq!(WorkloadKind::by_name("nope"), None);
    }

    #[test]
    fn with_function_tags_the_job() {
        use crate::workload::faas::FunctionId;
        assert_eq!(job().function, None);
        let j = job().with_function(FunctionId(7));
        assert_eq!(j.function, Some(FunctionId(7)));
    }

    #[test]
    fn categories() {
        assert_eq!(WorkloadKind::HadoopTeraSort.category(), "hadoop");
        assert_eq!(WorkloadKind::SparkKMeans.category(), "spark");
        assert_eq!(WorkloadKind::EtlPipeline.category(), "etl");
    }
}
